//! End-to-end driver (the DESIGN.md §end-to-end validation run):
//! load the build-time-trained transformer, stream calibration through
//! the PJRT artifacts, compress every projection with COALA at several
//! ratios, and report perplexity + probe-task accuracy before/after —
//! against the SVD-LLM baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_pipeline
//! ```

use coala::calib::dataset::{Corpus, TaskBank};
use coala::coala::{Method, MuRule};
use coala::coordinator::{CompressionJob, Pipeline};
use coala::eval::{eval_tasks, perplexity};
use coala::model::ModelWeights;
use coala::runtime::Executor;

fn main() -> coala::Result<()> {
    let ex = Executor::new("artifacts")?;
    let corpus = Corpus::load("artifacts")?;
    let spec = ex.manifest.config("tiny")?.clone();
    let weights = ModelWeights::load("artifacts", &spec)?;
    let bank = TaskBank::load("artifacts", "base", &ex.manifest.task_names)?;

    println!(
        "model `tiny`: {} params, pretrain loss {:.2} → {:.2}, build ppl {:.2}",
        weights.param_count(),
        weights.pretrain_loss.first().unwrap_or(&f32::NAN),
        weights.pretrain_loss.last().unwrap_or(&f32::NAN),
        weights.build_val_ppl
    );
    let val = corpus.split("val")?;
    let base_ppl = perplexity(&ex, &spec, &weights, val, 4)?;
    let base_acc = eval_tasks(&ex, &spec, &weights, &bank, Some(256))?.average();
    println!("baseline: ppl {base_ppl:.2}, probe avg {base_acc:.1}%\n");

    let pipe = Pipeline::new(&ex, spec.clone(), &weights);
    for ratio in [0.8, 0.5, 0.3] {
        for (label, method) in [
            ("COALA(λ=3)", Method::Coala(MuRule::Adaptive { lambda: 3.0 })),
            ("SVD-LLM", Method::SvdLlm),
        ] {
            let mut job = CompressionJob::new("tiny", method, ratio);
            job.calib_batches = 4;
            let out = pipe.run(&job, &corpus)?;
            let rec = out.model.reconstruct_into(&weights)?;
            let ppl = perplexity(&ex, &spec, &rec, val, 4)?;
            let acc = eval_tasks(&ex, &spec, &rec, &bank, Some(256))?.average();
            println!(
                "{label:<12} keep {:>3.0}%: ppl {ppl:7.2}  acc {acc:5.1}%  ({:.1}s, achieved {:.3})",
                ratio * 100.0,
                out.timings.total_s,
                out.model.achieved_ratio(&weights, &spec),
            );
        }
    }
    Ok(())
}
