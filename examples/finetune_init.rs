//! Adapter-initialization demo (the Table 4 scenario, abridged):
//! initialize rank-8 adapters with LoRA / PiSSA / COALA(α=1), fine-tune
//! briefly on the shifted fact distribution, and compare probe accuracy
//! on the NEW facts.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_init
//! ```

use coala::calib::dataset::{Corpus, TaskBank};
use coala::finetune::{init_adapters, AdapterInit, DeviceFineTuner, FineTuner};
use coala::model::ModelWeights;
use coala::runtime::Executor;

fn main() -> coala::Result<()> {
    let ex = Executor::new("artifacts")?;
    let corpus = Corpus::load("artifacts")?;
    let spec = ex.manifest.config("tiny")?.clone();
    let rank = ex.manifest.ft_rank;
    let weights = ModelWeights::load("artifacts", &spec)?;
    let bank = TaskBank::load("artifacts", "ft", &ex.manifest.task_names)?;
    let pool = corpus.train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)?;

    for strat in [AdapterInit::LoRA, AdapterInit::PiSSA, AdapterInit::CoalaA1] {
        let mut set =
            init_adapters(&ex, &spec, &weights, &corpus, strat, rank, "ft_calib", 3)?;
        let tuner = DeviceFineTuner::new(&ex, &spec, rank);
        let before = tuner.eval_tasks(&set, &bank, Some(128))?.average();
        let losses = tuner.train_on_batches(&mut set, &pool, 60, 1e-3)?;
        let after = tuner.eval_tasks(&set, &bank, Some(128))?.average();
        println!(
            "{:<12} loss {:.3}→{:.3}   new-fact probe acc {before:5.1}% → {after:5.1}%",
            strat.name(),
            losses.first().unwrap(),
            losses.last().unwrap()
        );
    }
    Ok(())
}
