//! Quickstart: factor one weight matrix with COALA in 30 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use coala::coala::coala_from_x;
use coala::tensor::ops::context_rel_err;
use coala::tensor::Matrix;

fn main() -> coala::Result<()> {
    // a weight matrix and some calibration activations X (n × k)
    let w: Matrix<f64> = Matrix::randn(64, 48, 1);
    let x: Matrix<f64> = Matrix::randn(48, 400, 2);

    // Algorithm 1: QR of Xᵀ → SVD of W·Rᵀ → W′ = U_r U_rᵀ W.
    // No Gram matrix, no inversion, no rank assumptions on X.
    let full = coala_from_x(&w, &x, 30)?;

    for rank in [4, 8, 16, 32] {
        let f = full.truncate(rank);
        let err = context_rel_err(&w, &f.reconstruct()?, &x)?;
        println!(
            "rank {rank:>2}: ‖(W−W′)X‖/‖WX‖ = {err:.4}   ({} → {} params)",
            w.rows * w.cols,
            f.param_count()
        );
    }

    // the regularized variant (Alg. 2) for low-data robustness:
    let x_tiny: Matrix<f64> = Matrix::randn(48, 12, 3); // fewer samples than dims!
    let r = coala::linalg::qr_r_square(&x_tiny.transpose())?;
    let f = coala::coala::coala_regularized(&w, &r, 1e-2, 30)?.truncate(8);
    println!(
        "low-data (k=12 < n=48) with μ=1e-2: finite={} err={:.4}",
        f.a.all_finite(),
        context_rel_err(&w, &f.reconstruct()?, &x_tiny)?
    );
    Ok(())
}
