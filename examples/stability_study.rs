//! Stability study on synthetic ill-conditioned calibration (a
//! self-contained Fig.-1-style demonstration without the artifacts).
//!
//! ```bash
//! cargo run --release --example stability_study
//! ```

use coala::coala::baselines::{svdllm_factorize, svdllm_v2_factorize};
use coala::coala::coala_factorize;
use coala::linalg::qr_r_square;
use coala::tensor::lowp::{gram_lowp, quantize, Precision};
use coala::tensor::ops::{fro, matmul};
use coala::tensor::Matrix;

fn main() -> coala::Result<()> {
    // X with geometrically decaying singular values (cond ≈ 1e6)
    let n = 48;
    let k = 400;
    let mut x: Matrix<f32> = Matrix::randn(n, k, 1);
    for i in 0..n {
        let s = 10f32.powf(-(6.0 * i as f32) / (n - 1) as f32);
        for j in 0..k {
            x.set(i, j, x.get(i, j) * s);
        }
    }
    let w: Matrix<f32> = Matrix::randn(32, n, 2);

    // fp64 reference (inversion-free COALA)
    let w64: Matrix<f64> = w.cast();
    let x64: Matrix<f64> = x.cast();
    let r64 = qr_r_square(&x64.transpose())?;
    let reference = coala_factorize(&w64, &r64, 40)?;

    // fp16-emulated Gram for the baselines (the paper's working precision)
    let xt16 = quantize(&x.transpose(), Precision::F16);
    let gram = gram_lowp(&xt16, Precision::F16);
    let r32 = qr_r_square(&x.transpose())?;

    println!("rank  COALA(QR,f32)  SVD-LLM(chol,f16)  SVD-LLM-v2(eig,f16)");
    for rank in [2usize, 4, 8, 16, 32] {
        let wref: Matrix<f64> = reference.truncate(rank).reconstruct()?;
        let rel = |f: &coala::coala::factorize::FullFactors<f32>| -> String {
            match f.truncate(rank).reconstruct() {
                Ok(wp) if wp.all_finite() => {
                    let d: Matrix<f64> = wp.cast::<f64>().sub(&wref).unwrap();
                    format!("{:.2e}", fro(&d) / fro(&wref))
                }
                _ => "NaN/Inf".to_string(),
            }
        };
        let c = coala_factorize(&w, &r32, 40)?;
        let s1 = svdllm_factorize(&w, &gram, 40)?;
        let s2 = svdllm_v2_factorize(&w, &gram, 40)?;
        println!("{rank:>4}  {:>13}  {:>17}  {:>19}", rel(&c), rel(&s1), rel(&s2));
    }
    println!("\n(the Gram-based errors are dominated by the fp16 XXᵀ formation;\n the QR route tracks the fp64 reference — the paper's Fig. 1 shape)");
    let _ = matmul::<f32>; // keep import used in all cfgs
    Ok(())
}
