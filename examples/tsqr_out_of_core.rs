//! Out-of-core TSQR demonstration (§4.2): process a calibration matrix
//! far larger than "device memory" in bounded chunks, sequentially and
//! with the simulated-multi-device tree, and verify both against the
//! direct Gram computation.
//!
//! ```bash
//! make artifacts && cargo run --release --example tsqr_out_of_core
//! ```

use coala::coordinator::TsqrTreeRunner;
use coala::runtime::Executor;
use coala::runtime::ops;
use coala::tensor::ops::{fro, gram_t, matmul};
use coala::tensor::Matrix;
use std::time::Instant;

fn main() -> coala::Result<()> {
    let ex = Executor::new("artifacts")?;
    let cfg = ex.manifest.config("tiny")?;
    let n = cfg.d_model;
    let c = cfg.chunk_cols();
    let n_chunks = 16;
    println!(
        "X is {n}×{} ({:.1} MB) — processed as {n_chunks} chunks of {c} columns ({:.1} MB peak)",
        c * n_chunks,
        (n * c * n_chunks * 4) as f64 / 1e6,
        (n * c * 4) as f64 / 1e6
    );
    let chunks: Vec<Matrix<f32>> = (0..n_chunks).map(|i| Matrix::randn(c, n, i as u64)).collect();

    // ground truth Gram
    let mut full = chunks[0].clone();
    for ch in &chunks[1..] {
        full = full.vstack(ch)?;
    }
    let want = gram_t(&full);

    // sequential streaming through the PJRT artifact
    let t0 = Instant::now();
    let mut r = Matrix::<f32>::zeros(n, n);
    for ch in &chunks {
        r = ops::tsqr_step(&ex, &r, ch)?;
    }
    let seq_s = t0.elapsed().as_secs_f64();
    let err = fro(&matmul(&r.transpose(), &r)?.sub(&want)?) / fro(&want);
    println!("sequential streaming: {seq_s:.2}s, RᵀR error {err:.2e}");

    // simulated multi-device tree
    for workers in [2usize, 4] {
        let t1 = Instant::now();
        let runner = TsqrTreeRunner::new("artifacts", workers);
        let rt = runner.run(chunks.clone())?;
        let tree_s = t1.elapsed().as_secs_f64();
        let err = fro(&matmul(&rt.transpose(), &rt)?.sub(&want)?) / fro(&want);
        println!("tree with {workers} devices : {tree_s:.2}s, RᵀR error {err:.2e}");
    }
    Ok(())
}
