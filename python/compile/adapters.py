"""L2: low-rank adapter (LoRA-style) fine-tuning graphs — Table 4 substrate.

The PEFT-initialization experiment adapts a frozen base model with rank-r
factors per projection:  W_eff = W_res + A·B  (A: out×r, B: r×in).  The
*initialization* of (A, B, W_res) is what differs between LoRA / PiSSA /
CorDA / COALA-α — that part happens in the rust coordinator using the
factorization artifacts; the graphs here only do the generic adapted
forward + one Adam step over the adapters, exported as
`ft_step_<cfg>_r<r>` / `ft_logits_<cfg>_r<r>`.

Adapter ABI (order matters — recorded in the manifest):
  frozen params  : cfg.param_names() order (projections hold W_res)
  adapters       : for each cfg.compressible() projection, A then B
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M


def adapter_shapes(cfg: M.ModelConfig, rank: int) -> list[tuple[str, tuple[int, int]]]:
    """Ordered [(name, shape)] list: '<proj>.A' (out, r), '<proj>.B' (r, in)."""
    shapes = cfg.param_shapes()
    out = []
    for proj in cfg.compressible():
        o, i = shapes[proj]
        out.append((f"{proj}.A", (o, rank)))
        out.append((f"{proj}.B", (rank, i)))
    return out


def _layer_adapted(cfg, frozen, adapters, i, h):
    def proj(x, name):
        w = frozen[f"l{i}.{name}"]
        a = adapters[f"l{i}.{name}.A"]
        b = adapters[f"l{i}.{name}.B"]
        return x @ w.T + (x @ b.T) @ a.T

    x_attn = M.rms_norm(h, frozen[f"l{i}.ln1"])
    q, k, v = proj(x_attn, "wq"), proj(x_attn, "wk"), proj(x_attn, "wv")
    mix = M._attention(cfg, q, k, v)
    h = h + proj(mix, "wo")
    x_up = M.rms_norm(h, frozen[f"l{i}.ln2"])
    up = jax.nn.gelu(proj(x_up, "w_up"))
    h = h + proj(up, "w_down")
    return h


def forward_adapted(cfg: M.ModelConfig, frozen, adapters, tokens):
    h = jnp.take(frozen["tok_emb"], tokens, axis=0) + frozen["pos_emb"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        h = _layer_adapted(cfg, frozen, adapters, i, h)
    h = M.rms_norm(h, frozen["ln_f"])
    return h @ frozen["lm_head"].T


def loss_adapted(cfg: M.ModelConfig, frozen, adapters, tokens):
    # one-hot instead of take_along_axis: see model.loss_fn (conformance)
    logits = forward_adapted(cfg, frozen, adapters, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logp.dtype)
    return -jnp.mean(jnp.sum(logp * onehot, axis=-1))


def adapter_train_step(cfg: M.ModelConfig, frozen, adapters, m, v, tokens, lr, step):
    """One Adam step on the adapters only (frozen base untouched).

    Returns (loss, adapters′, m′, v′).  ``lr`` and ``step`` are traced
    scalars so the rust trainer controls schedule + bias correction.
    """
    b1, b2, eps = 0.9, 0.95, 1e-8
    loss, grads = jax.value_and_grad(lambda a: loss_adapted(cfg, frozen, a, tokens))(adapters)
    t = step + 1.0
    new_a, new_m, new_v = {}, {}, {}
    for k in adapters:
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * g * g
        mhat = m_k / (1 - b1**t)
        vhat = v_k / (1 - b2**t)
        new_a[k] = adapters[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m_k, v_k
    return loss, new_a, new_m, new_v
