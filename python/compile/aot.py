"""AOT entry point: lower every compute graph to HLO **text** + build data.

`make artifacts` runs `python -m compile.aot --out-dir ../artifacts` once;
after that the rust binary is fully self-contained.  Interchange is HLO
text (NOT `lowered.compiler_ir(...).serialize()`): jax ≥ 0.5 emits protos
with 64-bit instruction ids that the pinned xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (all under artifacts/):
  <name>.hlo.txt      one per compute-graph × shape variant
  manifest.json       name → file + input/output specs + model configs
  weights_<cfg>.cbt   trained parameters (+ pretrain loss curve)
  corpus.cbt          train/val/calib/ft token streams
  tasks.cbt           probe-task banks (base + fine-tune fact sets)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import adapters as A
from . import coala as C
from . import data as D
from . import linalg as L
from . import model as M
from . import pretrain as P
from . import serialize

F32 = jnp.float32
I32 = jnp.int32

ABI_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Emitter:
    """Collects lowered artifacts + their manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: dict[str, dict] = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs: list, arg_names: list[str]):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *arg_specs)
        flat_out, _ = jax.tree.flatten(out_tree)
        self.entries[name] = {
            "file": fname,
            "inputs": [
                {
                    "name": n,
                    "dtype": str(s.dtype),
                    "shape": list(s.shape),
                }
                for n, s in zip(arg_names, arg_specs)
            ],
            "outputs": [
                {"dtype": str(o.dtype), "shape": list(o.shape)} for o in flat_out
            ],
        }
        print(
            f"  [aot] {name:<28} {len(text)/1024:8.1f} KiB  "
            f"in={len(arg_specs):3d} out={len(flat_out):3d}  ({time.time()-t0:.1f}s)",
            flush=True,
        )


# ---------------------------------------------------------------------------
# per-config artifact families
# ---------------------------------------------------------------------------


def sweeps_for(n: int) -> int:
    """Jacobi sweep count per problem size (validated in python/tests)."""
    return 8 if n >= 512 else 10


def emit_model_artifacts(em: Emitter, cfg: M.ModelConfig):
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    param_specs = [spec(shapes[n]) for n in names]
    tok_spec = spec((cfg.batch, cfg.seq_len), I32)
    tok1_spec = spec((cfg.batch, cfg.seq_len + 1), I32)

    def fwd_logits(tokens, *flat):
        return M.forward(cfg, M.list_to_params(cfg, list(flat)), tokens)

    def fwd_acts(tokens, *flat):
        logits, acts = M.forward_with_acts(cfg, M.list_to_params(cfg, list(flat)), tokens)
        flat_acts = [acts[i][s] for i in range(cfg.n_layers) for s in M.ACT_STREAMS]
        return (logits, *flat_acts)

    def loss(tokens, *flat):
        return M.loss_fn(cfg, M.list_to_params(cfg, list(flat)), tokens)

    arg_names = ["tokens", *names]
    em.emit(f"fwd_logits_{cfg.name}", fwd_logits, [tok_spec, *param_specs], arg_names)
    em.emit(f"fwd_acts_{cfg.name}", fwd_acts, [tok_spec, *param_specs], arg_names)
    em.emit(f"loss_{cfg.name}", loss, [tok1_spec, *param_specs], arg_names)


def emit_factorize_artifacts(em: Emitter, cfg: M.ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    c = cfg.batch * cfg.seq_len  # calibration chunk = one forward batch
    widths = sorted({d, f})
    pairs = sorted({(d, d), (d, f), (f, d)})

    for n in widths:
        sw = sweeps_for(n)
        em.emit(
            f"tsqr_step_{n}x{c}",
            lambda r, x: L.tsqr_step(r, x),
            [spec((n, n)), spec((c, n))],
            ["r_prev", "xt_chunk"],
        )
        em.emit(
            f"tsqr_merge_{n}",
            lambda ra, rb: L.tsqr_merge(ra, rb),
            [spec((n, n)), spec((n, n))],
            ["r_a", "r_b"],
        )
        em.emit(
            f"qr_aug_{n}",
            lambda r, mu: C.regularized_r(r, mu),
            [spec((n, n)), spec((), F32)],
            ["r", "mu"],
        )
        em.emit(
            f"gram_update_{n}x{c}",
            lambda g, x: C.mm.tiled_matmul(x.T, x) + g,
            [spec((n, n)), spec((c, n))],
            ["g", "xt_chunk"],
        )

    for m, n in pairs:
        sw = sweeps_for(max(m, n))
        p = min(m, n)
        em.emit(
            f"factorize_{m}x{n}",
            lambda w, r, _s=sw: C.coala_factorize(w, r, sweeps=_s),
            [spec((m, n)), spec((n, n))],
            ["w", "r"],
        )
        em.emit(
            f"factorize_reg_{m}x{n}",
            lambda w, r, mu, _s=sw: C.coala_factorize_regularized(w, r, mu, sweeps=_s),
            [spec((m, n)), spec((n, n)), spec((), F32)],
            ["w", "r", "mu"],
        )
        em.emit(
            f"alpha2_{m}x{n}",
            lambda w, r, _s=sw: C.alpha_factorize(w, r, 2, sweeps=_s),
            [spec((m, n)), spec((n, n))],
            ["w", "r"],
        )
        em.emit(
            f"plainsvd_{m}x{n}",
            lambda w, _s=sw: C.plain_svd_factorize(w, sweeps=_s),
            [spec((m, n))],
            ["w"],
        )
        em.emit(
            f"mu_terms_{m}x{n}",
            lambda w, u, pp, r, mask: C.mu_from_lambda(w, u, pp, r, mask),
            [spec((m, n)), spec((m, p)), spec((p, n)), spec((n, n)), spec((p,))],
            ["w", "u", "p", "r", "rank_mask"],
        )
        em.emit(
            f"svdllm_{m}x{n}",
            lambda w, g, _s=sw: C.svdllm_factorize(w, g, sweeps=_s),
            [spec((m, n)), spec((n, n))],
            ["w", "gram"],
        )
        em.emit(
            f"svdllm2_{m}x{n}",
            lambda w, g, _s=sw: C.svdllm_v2_factorize(w, g, sweeps=_s),
            [spec((m, n)), spec((n, n))],
            ["w", "gram"],
        )
        em.emit(
            f"corda_{m}x{n}",
            lambda w, g, _s=sw: C.corda_unrobust(w, g, sweeps=_s),
            [spec((m, n)), spec((n, n))],
            ["w", "gram"],
        )
        em.emit(
            f"asvd_{m}x{n}",
            lambda w, s, _s=sw: C.asvd_factorize(w, s, sweeps=_s),
            [spec((m, n)), spec((n,))],
            ["w", "col_scales"],
        )


def emit_finetune_artifacts(em: Emitter, cfg: M.ModelConfig, rank: int):
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    ad_shapes = A.adapter_shapes(cfg, rank)
    ad_names = [n for n, _ in ad_shapes]
    frozen_specs = [spec(shapes[n]) for n in names]
    ad_specs = [spec(s) for _, s in ad_shapes]
    tok1_spec = spec((cfg.batch, cfg.seq_len + 1), I32)
    tok_spec = spec((cfg.batch, cfg.seq_len), I32)

    n_f, n_a = len(names), len(ad_names)

    def ft_step(tokens, lr, step, *flat):
        frozen = M.list_to_params(cfg, list(flat[:n_f]))
        ads = dict(zip(ad_names, flat[n_f : n_f + n_a]))
        m = dict(zip(ad_names, flat[n_f + n_a : n_f + 2 * n_a]))
        v = dict(zip(ad_names, flat[n_f + 2 * n_a :]))
        loss, a2, m2, v2 = A.adapter_train_step(cfg, frozen, ads, m, v, tokens, lr, step)
        return (
            loss,
            *[a2[k] for k in ad_names],
            *[m2[k] for k in ad_names],
            *[v2[k] for k in ad_names],
        )

    def ft_logits(tokens, *flat):
        frozen = M.list_to_params(cfg, list(flat[:n_f]))
        ads = dict(zip(ad_names, flat[n_f:]))
        return A.forward_adapted(cfg, frozen, ads, tokens)

    em.emit(
        f"ft_step_{cfg.name}_r{rank}",
        ft_step,
        [tok1_spec, spec((), F32), spec((), F32), *frozen_specs, *ad_specs, *ad_specs, *ad_specs],
        ["tokens", "lr", "step", *names, *ad_names,
         *[f"m.{n}" for n in ad_names], *[f"v.{n}" for n in ad_names]],
    )
    em.emit(
        f"ft_logits_{cfg.name}_r{rank}",
        ft_logits,
        [tok_spec, *frozen_specs, *ad_specs],
        ["tokens", *names, *ad_names],
    )


# ---------------------------------------------------------------------------
# data + weights
# ---------------------------------------------------------------------------


def build_data(out_dir: str, seq_len: int):
    lang = D.SyntheticLanguage(D.LanguageSpec(), fact_seed=0)
    lang_ft = D.SyntheticLanguage(D.LanguageSpec(), fact_seed=1)

    splits = D.build_splits(lang, seq_len, train_tokens=600_000, val_tokens=60_000, calib_tokens=120_000)
    ft_train = lang_ft.sample_stream(120_000, seed=404)
    ft_calib = lang_ft.sample_stream(24 * seq_len, seed=505)  # 24 examples: low-data regime
    corpus = {**splits, "ft_train": ft_train, "ft_calib": ft_calib}
    serialize.save_cbt(os.path.join(out_dir, "corpus.cbt"), corpus)

    tasks_base = lang.make_tasks(seq_len, per_task=64, seed=606)
    tasks_ft = lang_ft.make_tasks(seq_len, per_task=64, seed=707)
    tasks = {f"base.{k}": v for k, v in tasks_base.items()}
    tasks.update({f"ft.{k}": v for k, v in tasks_ft.items()})
    tasks["task_names"] = np.arange(len(D.TASK_NAMES), dtype=np.int32)  # names in manifest
    serialize.save_cbt(os.path.join(out_dir, "tasks.cbt"), tasks)
    print(f"  [aot] corpus.cbt + tasks.cbt written ({len(splits['train'])} train tokens)")
    return corpus


def build_weights(out_dir: str, cfg: M.ModelConfig, corpus, steps: int):
    path = os.path.join(out_dir, f"weights_{cfg.name}.cbt")
    if os.path.exists(path):
        print(f"  [aot] {path} exists — skipping pretrain")
        return
    params, losses = P.pretrain(cfg, corpus["train"], steps=steps)
    ppl = P.eval_ppl(cfg, params, corpus["val"])
    print(f"  [aot] {cfg.name}: val ppl {ppl:.2f} (uniform would be {cfg.vocab})")
    tensors = {k: np.asarray(v) for k, v in params.items()}
    tensors["pretrain_loss"] = losses
    tensors["val_ppl"] = np.array([ppl], np.float32)
    serialize.save_cbt(path, tensors)


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--steps", type=int, default=700)
    ap.add_argument("--ft-rank", type=int, default=8)
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()

    em = Emitter(args.out_dir)
    cfgs = [M.CONFIGS[c] for c in args.configs.split(",")]

    corpus = build_data(args.out_dir, cfgs[0].seq_len)
    for cfg in cfgs:
        if not args.skip_train:
            build_weights(args.out_dir, cfg, corpus, steps=args.steps)
        emit_model_artifacts(em, cfg)
        emit_factorize_artifacts(em, cfg)
    emit_finetune_artifacts(em, cfgs[0], args.ft_rank)

    manifest = {
        "abi_version": ABI_VERSION,
        "task_names": D.TASK_NAMES,
        "configs": {
            cfg.name: {
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "d_ff": cfg.d_ff,
                "seq_len": cfg.seq_len,
                "batch": cfg.batch,
                "param_names": cfg.param_names(),
                "param_shapes": {k: list(v) for k, v in cfg.param_shapes().items()},
                "compressible": cfg.compressible(),
                "proj_input_stream": M.PROJ_INPUT_STREAM,
                "act_streams": list(M.ACT_STREAMS),
                "weights_file": f"weights_{cfg.name}.cbt",
            }
            for cfg in cfgs
        },
        "ft_rank": args.ft_rank,
        "artifacts": em.entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(em.entries)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
