"""L2: COALA factorization graphs (Alg. 1 / Alg. 2 / Prop. 4) + baselines.

Every function here is pure jnp/lax over the hand-rolled numerics in
``linalg`` so the whole graph lowers to plain HLO for the rust runtime.

Rank is *not* an argument: each graph returns full-size factors
(U, σ, P = UᵀW or B = ΣVᵀS⁻¹) and the rust coordinator slices the first
r rows/columns host-side.  That keeps one compiled executable per matrix
*shape* instead of per (shape, rank) pair — the rank sweep in Fig. 1 then
reuses a single artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import linalg
from .kernels import matmul as mm


def _svd_any(a: jax.Array, sweeps: int = 12):
    """Jacobi SVD for any aspect ratio (transpose trick for wide inputs)."""
    m, n = a.shape
    if m >= n:
        return linalg.jacobi_svd(a, sweeps=sweeps)
    v, s, u = linalg.jacobi_svd(a.T, sweeps=sweeps)
    return u, s, v


# ---------------------------------------------------------------------------
# COALA (this paper)
# ---------------------------------------------------------------------------


def coala_factorize(w: jax.Array, r_factor: jax.Array, sweeps: int = 12):
    """Alg. 1 core given the preprocessed R (RᵀR = XXᵀ): inversion-free.

    w        : (m, n) weight matrix.
    r_factor : (n, n) upper-triangular R from (TS)QR of Xᵀ.
    Returns (U, σ, P) with  WRᵀ = U·diag(σ)·Vᵀ  and  P = UᵀW.
    The rank-r approximation is  W'_r = U[:, :r] · P[:r, :]  — no Gram
    matrix, no inversion, no full-column-rank assumption on X.
    """
    wr_t = mm.tiled_matmul(w, r_factor.T)  # (m, n) — L1 hot spot
    u, sigma, _v = _svd_any(wr_t, sweeps=sweeps)
    p = mm.tiled_matmul(u.T, w)  # (min(m,n), n)
    return u, sigma, p


def coala_factorize_from_x(w: jax.Array, x: jax.Array, sweeps: int = 12):
    """Alg. 1 end-to-end from raw X (n × k): QR preprocessing + core."""
    r = linalg.qr_r_square(x.T)
    return coala_factorize(w, r, sweeps=sweeps)


def regularized_r(r_factor: jax.Array, mu: jax.Array) -> jax.Array:
    """Alg. 2 absorbed into the R factor.

    Prop. 3: the regularized problem is the plain problem with
    X̃ = [X  √μ·I].  Since only RᵀR = X̃X̃ᵀ = XXᵀ + μI matters
    (Prop. 2 remark), we re-factor [R ; √μ·I] — an (2n × n) QR instead of
    touching the raw calibration stream again.  μ is a runtime *input*
    (traced scalar), so one artifact serves the whole λ sweep of Fig. 5.
    """
    n = r_factor.shape[0]
    aug = jnp.concatenate(
        [r_factor, jnp.sqrt(mu) * jnp.eye(n, dtype=r_factor.dtype)], axis=0
    )
    return linalg.qr_r_square(aug)


def coala_factorize_regularized(
    w: jax.Array, r_factor: jax.Array, mu: jax.Array, sweeps: int = 12
):
    """Alg. 2: regularized COALA = Alg. 1 on the μ-augmented R."""
    return coala_factorize(w, regularized_r(r_factor, mu), sweeps=sweeps)


def mu_from_lambda(
    w: jax.Array, u: jax.Array, p: jax.Array, r_factor: jax.Array, rank_mask: jax.Array
):
    """Eq. (5) numerator/denominator for the layer-adaptive μ rule.

    Given the *unregularized* solution factors (U, P) and a 0/1 mask over
    the spectrum selecting the first r directions, returns
    (‖W₀X − WX‖²_F, ‖W₀ − W‖²_F);  μ = λ · num / den.
    ‖·X‖ is evaluated through R (‖AX‖_F = ‖ARᵀ‖_F), so no raw X needed.
    """
    w0 = mm.tiled_matmul(u * rank_mask[None, :], p)  # U_r P_r with masked columns
    diff = w0 - w
    num = jnp.sum(mm.tiled_matmul(diff, r_factor.T) ** 2)
    den = jnp.sum(diff**2)
    return num, den


# ---------------------------------------------------------------------------
# Prop. 4 α-family (PiSSA α=0, new method α=1, robust CorDA α=2)
# ---------------------------------------------------------------------------


def alpha_factorize(w: jax.Array, r_factor: jax.Array, alpha: int, sweeps: int = 12):
    """min tr((W−W')(XXᵀ)^α(W−W')ᵀ) solved inversion-free (Prop. 4).

    α = 0 → PiSSA (plain SVD of W); α = 1 → the paper's new method
    (≡ Alg. 1); α = 2 → robustified CorDA.  All three reduce to an SVD of
    W·(XXᵀ)^{α/2}·(rotation):  since only the *left* singular vectors are
    used (W' = U_rU_rᵀW, Prop. 4), any M with M·Mᵀ = W(XXᵀ)^αWᵀ gives the
    same U — so α=1 uses W·Rᵀ and α=2 uses W·RᵀR (RᵀR = XXᵀ from QR of
    Xᵀ), and no Gram matrix, square root, or inversion ever appears.
    Returns (U, σ, P = UᵀW).
    """
    if alpha == 0:
        target = w
    elif alpha == 1:
        target = mm.tiled_matmul(w, r_factor.T)
    elif alpha == 2:
        target = mm.tiled_matmul(mm.tiled_matmul(w, r_factor.T), r_factor)
    else:
        raise ValueError(f"alpha ∈ {{0, 1, 2}} supported, got {alpha}")
    u, sigma, _ = _svd_any(target, sweeps=sweeps)
    p = mm.tiled_matmul(u.T, w)
    return u, sigma, p


def corda_unrobust(w: jax.Array, g: jax.Array, sweeps: int = 12):
    """The *original* CorDA construction (Remark 1), kept as the baseline
    whose inversion of XXᵀ blows up on singular calibration — Table 4's
    collapsing row.  W' = U_r Σ_r V_rᵀ (XXᵀ)⁻¹ with UΣVᵀ = W·XXᵀ.

    g : the explicitly-formed Gram matrix XXᵀ (n × n), accumulated the
    way CorDA does it (streamed XᵢXᵢᵀ adds).
    Returns (U, σ, B_full = ΣVᵀ(XXᵀ)⁻¹); rank-slice host-side.
    The inverse is applied via the eigendecomposition of the Gram matrix
    with *no* clamping of tiny eigenvalues (faithful to the failure mode).
    """
    wg = mm.tiled_matmul(w, g)
    u, sigma, v = _svd_any(wg, sweeps=sweeps)
    lam, q = linalg.eigh_psd(g, sweeps=sweeps)
    ginv = (q / lam[None, :]) @ q.T
    b = mm.tiled_matmul(sigma[:, None] * v.T, ginv)
    return u, sigma, b


# ---------------------------------------------------------------------------
# Gram-based baselines (SVD-LLM / SVD-LLM v2 / ASVD / plain SVD)
# ---------------------------------------------------------------------------


def svdllm_factorize(w: jax.Array, gram: jax.Array, sweeps: int = 12):
    """SVD-LLM (Alg. 3): Cholesky of XXᵀ, SVD of W·Lᵀ…, B = ΣVᵀ·S⁻¹.

    Uses S = Lᵀ (upper) with SᵀS… — any S with S·Sᵀ = XXᵀ works; we take
    S = L (lower Cholesky), exactly mirroring the reference pseudocode up
    to transposition convention.  Near-singular Gram ⇒ NaNs/garbage, which
    is the instability Fig. 1 measures.
    Returns (U, σ, B_full = ΣVᵀL⁻¹  (applied via triangular solve)).
    """
    l = linalg.cholesky(gram)
    ws = mm.tiled_matmul(w, l)
    u, sigma, v = _svd_any(ws, sweeps=sweeps)
    # B = Σ Vᵀ L⁻¹  ⇔  solve Lᵀ · Bᵀ = V·Σ
    bt = linalg.solve_triangular(l, v * sigma[None, :], lower=True, trans=True)
    return u, sigma, bt.T


def svdllm_v2_factorize(w: jax.Array, gram: jax.Array, sweeps: int = 12):
    """SVD-LLM v2 (Alg. 4): eig of XXᵀ, S = U_s·Λ^{1/2}, …, B = ΣVᵀΛ^{-1/2}U_sᵀ.

    Inverts Λ^{1/2} elementwise — the second Gram-based failure mode.
    """
    lam, us = linalg.eigh_psd(gram, sweeps=sweeps)
    sqrt_lam = jnp.sqrt(jnp.maximum(lam, 0.0))
    m_mat = mm.tiled_matmul(w, us * sqrt_lam[None, :])
    u, sigma, v = _svd_any(m_mat, sweeps=sweeps)
    inv_sqrt = 1.0 / sqrt_lam  # no clamping: faithful
    b = mm.tiled_matmul((sigma[:, None] * v.T) * inv_sqrt[None, :], us.T)
    return u, sigma, b


def asvd_factorize(w: jax.Array, col_scales: jax.Array, sweeps: int = 12):
    """ASVD: scale columns of W by activation magnitudes, SVD, unscale.

    col_scales : (n,) — typically (mean |X| over the calibration set)^0.5.
    W' = U_r Σ_r V_rᵀ · D⁻¹ with UΣVᵀ = W·D.  Suboptimal for problem (1)
    (per the paper) but a required comparison row in Tables 2/3.
    """
    d = col_scales
    u, sigma, v = _svd_any(w * d[None, :], sweeps=sweeps)
    b = (sigma[:, None] * v.T) / d[None, :]
    return u, sigma, b


def plain_svd_factorize(w: jax.Array, sweeps: int = 12):
    """Eckart–Young: context-free truncated SVD of W (the α=0 row)."""
    u, sigma, v = _svd_any(w, sweeps=sweeps)
    return u, sigma, sigma[:, None] * v.T
