"""Conformance suite: prove the lowered HLO computes what jax computed.

The pinned xla_extension 0.5.1 runtime is old enough to *miscompile* some
valid HLO (observed: gathers/scatters with runtime-computed index arrays
inside while-loop bodies silently misbehave).  Numerical parity between
the jax execution and the rust/PJRT execution therefore cannot be
assumed — it is *tested*, routine by routine:

  python -m compile.conformance --out-dir ../artifacts/conformance

emits, for every core routine, a small `<case>.hlo.txt` plus a CBT file
holding the inputs and the jax-computed expected outputs.  The rust
integration test `tests/conformance.rs` (and `coala selfcheck`) loads
each case, executes it through the PJRT runtime, and asserts allclose.

Any new jnp construct used on the request path MUST gain a case here.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import coala as C
from . import linalg as L
from . import serialize
from .kernels import gram, matmul, trailing

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Suite:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.names: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def case(self, name: str, fn, inputs: list[np.ndarray], tol: float = 1e-3):
        specs = [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in inputs]
        lowered = jax.jit(fn).lower(*specs)
        with open(os.path.join(self.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        outs = jax.jit(fn)(*[jnp.asarray(x) for x in inputs])
        flat, _ = jax.tree.flatten(outs)
        tensors: dict[str, np.ndarray] = {"__tol": np.array([tol], np.float32)}
        for i, x in enumerate(inputs):
            tensors[f"in{i}"] = np.asarray(x)
        for i, o in enumerate(flat):
            tensors[f"out{i}"] = np.asarray(o)
        serialize.save_cbt(os.path.join(self.out_dir, f"{name}.cbt"), tensors)
        self.names.append(name)
        print(f"  [conformance] {name:<28} in={len(inputs)} out={len(flat)}")

    def finish(self):
        with open(os.path.join(self.out_dir, "cases.txt"), "w") as f:
            f.write("\n".join(self.names) + "\n")
        print(f"[conformance] {len(self.names)} cases -> {self.out_dir}")


def rand(seed, *shape, dtype=np.float32):
    return np.random.default_rng(seed).standard_normal(shape).astype(dtype)


def build(out_dir: str):
    s = Suite(out_dir)
    # --- L1 kernels ---------------------------------------------------------
    s.case("matmul", lambda x, y: matmul.tiled_matmul(x, y, block=(32, 32, 32)),
           [rand(0, 70, 50), rand(1, 50, 90)])
    s.case("gram_update", lambda g, x: gram.gram_update(g, x, block=(32, 32)),
           [np.zeros((40, 40), np.float32), rand(2, 77, 40)])
    s.case("trailing", trailing.trailing_update,
           [rand(3, 60, 30), rand(4, 60, 8), np.triu(rand(5, 8, 8))])
    # --- QR family ----------------------------------------------------------
    s.case("householder_qr", L.householder_qr_r, [rand(6, 48, 16)])
    s.case("blocked_qr", lambda a: L.blocked_qr_r(a, panel=32), [rand(7, 96, 64)])
    s.case("tsqr_step", L.tsqr_step, [np.triu(rand(8, 16, 16)), rand(9, 24, 16)])
    s.case("tsqr_merge", L.tsqr_merge, [np.triu(rand(10, 16, 16)), np.triu(rand(11, 16, 16))])
    s.case("qr_aug", C.regularized_r, [np.triu(rand(12, 16, 16)), np.array(0.25, np.float32)])
    # --- Jacobi family (the miscompile hot-zone) ------------------------------
    s.case("jacobi_svd", lambda a: L.jacobi_svd(a, sweeps=10), [rand(13, 24, 12)])
    s.case("jacobi_svd_odd", lambda a: L.jacobi_svd(a, sweeps=10), [rand(14, 15, 7)])
    s.case("eigh_psd", lambda g: L.eigh_psd(g, sweeps=10),
           [(lambda a: a.T @ a)(rand(15, 20, 12)).astype(np.float32)])
    # --- Cholesky / solves ----------------------------------------------------
    g = rand(16, 24, 16)
    g = (g.T @ g + 0.5 * np.eye(16)).astype(np.float32)
    s.case("cholesky", L.cholesky, [g])
    t = (np.tril(rand(17, 12, 12)) + 3 * np.eye(12)).astype(np.float32)
    s.case("solve_lower", lambda tt, b: L.solve_triangular(tt, b, lower=True), [t, rand(18, 12, 5)])
    s.case("solve_lower_t", lambda tt, b: L.solve_triangular(tt, b, lower=True, trans=True),
           [t, rand(19, 12, 5)])
    # --- factorization graphs -------------------------------------------------
    w, x = rand(20, 16, 12), rand(21, 12, 40)
    r = np.linalg.qr(x.T)[1].astype(np.float32)
    gm = (x @ x.T).astype(np.float32)
    s.case("coala_factorize", lambda ww, rr: C.coala_factorize(ww, rr, sweeps=10), [w, r])
    s.case("coala_reg", lambda ww, rr, mu: C.coala_factorize_regularized(ww, rr, mu, sweeps=10),
           [w, r, np.array(0.1, np.float32)])
    s.case("alpha2", lambda ww, rr: C.alpha_factorize(ww, rr, 2, sweeps=10), [w, r])
    s.case("plainsvd", lambda ww: C.plain_svd_factorize(ww, sweeps=10), [w])
    s.case("svdllm", lambda ww, gg: C.svdllm_factorize(ww, gg, sweeps=10), [w, gm])
    s.case("svdllm2", lambda ww, gg: C.svdllm_v2_factorize(ww, gg, sweeps=10), [w, gm], tol=5e-3)
    s.case("corda", lambda ww, gg: C.corda_unrobust(ww, gg, sweeps=10), [w, gm], tol=5e-3)
    s.case("asvd", lambda ww, sc: C.asvd_factorize(ww, sc, sweeps=10),
           [w, (np.abs(x).mean(axis=1) ** 0.5 + 1e-3).astype(np.float32)])
    s.case("mu_terms", C.mu_from_lambda,
           [w, *[np.asarray(o) for o in (lambda u, sg, p: (u, p))(*C.coala_factorize(jnp.asarray(w), jnp.asarray(r), sweeps=10))],
            r, (np.arange(12) < 4).astype(np.float32)])
    # wide W (the down-projection aspect)
    w2 = rand(22, 12, 30)
    r2 = np.linalg.qr(rand(23, 40, 30))[1].astype(np.float32)
    s.case("coala_factorize_wide", lambda ww, rr: C.coala_factorize(ww, rr, sweeps=10), [w2, r2])
    s.finish()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/conformance")
    args = ap.parse_args()
    build(args.out_dir)
