"""Synthetic corpus + probe-task generator (build-time, deterministic).

Stands in for WikiText2 / the commonsense-reasoning suite (DESIGN.md
§substitutions).  The language has enough structure that (a) a small
transformer really learns it (ppl drops ~vocab → ~20), (b) activation
matrices develop the ill-conditioned spectra the paper exploits, and
(c) "knowledge" probes analogous to boolQ/PIQA/… can be scored exactly.

Construction
  * bigram Markov backbone: each token has 24 successors with Dirichlet
    weights; successor sets follow a Zipfian popularity so the unigram
    distribution is heavy-tailed (like natural text).
  * facts: (subject s, relation p, object o) triples.  Relations are
    drawn from 8 disjoint relation-token groups — one group per probe
    task.  Whenever the generator emits "s p", the next token is o with
    probability 0.95.  Fine-tune adaptation uses a *disjoint* fact set
    over the same relation groups (new knowledge, same format).
  * probe tasks: contexts ending in "… s p" with 4 candidate objects
    (1 correct + 3 distractors that are objects of *other* facts of the
    same relation group).  Accuracy = argmax over the 4 choice logits —
    the multiple-choice scoring used by lm-eval-harness.

Everything is seeded; the rust side only ever reads the CBT outputs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

TASK_NAMES = [
    "boolq_px",
    "piqa_px",
    "siqa_px",
    "hswag_px",
    "winog_px",
    "arc_e_px",
    "arc_c_px",
    "obqa_px",
]


@dataclasses.dataclass
class LanguageSpec:
    vocab: int = 512
    n_successors: int = 24
    n_relation_groups: int = 8
    relations_per_group: int = 4
    n_subjects: int = 96
    n_objects: int = 96
    facts_per_group: int = 24
    fact_prob: float = 0.12
    seed: int = 1234


class SyntheticLanguage:
    """Deterministic generator for the corpus and its probe tasks."""

    def __init__(self, spec: LanguageSpec, fact_seed: int = 0):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        v = spec.vocab

        # --- token inventory -------------------------------------------------
        # [0, 4) reserved; relations next; subjects/objects after; rest free.
        n_rel = spec.n_relation_groups * spec.relations_per_group
        self.relation_tokens = 4 + np.arange(n_rel)
        self.subject_tokens = 4 + n_rel + np.arange(spec.n_subjects)
        self.object_tokens = 4 + n_rel + spec.n_subjects + np.arange(spec.n_objects)

        # --- Zipfian bigram backbone -----------------------------------------
        ranks = np.arange(1, v + 1, dtype=np.float64)
        zipf = 1.0 / ranks**1.1
        zipf /= zipf.sum()
        self.successors = np.empty((v, spec.n_successors), np.int64)
        self.succ_probs = np.empty((v, spec.n_successors), np.float64)
        for t in range(v):
            succ = rng.choice(v, size=spec.n_successors, replace=False, p=zipf)
            w = rng.dirichlet(np.full(spec.n_successors, 0.4))
            self.successors[t] = succ
            self.succ_probs[t] = w

        # --- facts ------------------------------------------------------------
        # fact_seed selects the fact universe (base vs fine-tune adaptation).
        frng = np.random.default_rng(spec.seed * 7919 + 17 + fact_seed)
        self.facts: list[list[tuple[int, int, int]]] = []
        for g in range(spec.n_relation_groups):
            rels = self.relation_tokens[
                g * spec.relations_per_group : (g + 1) * spec.relations_per_group
            ]
            group = []
            # subjects unique within a group so (s, p) determines o
            subs = frng.choice(self.subject_tokens, size=spec.facts_per_group, replace=False)
            for s in subs:
                p = int(frng.choice(rels))
                o = int(frng.choice(self.object_tokens))
                group.append((int(s), p, o))
            self.facts.append(group)

    # -------------------------------------------------------------------------
    def sample_stream(self, n_tokens: int, seed: int) -> np.ndarray:
        """Sample a token stream (used for train/val/calibration splits)."""
        spec = self.spec
        rng = np.random.default_rng(seed)
        flat_facts = [f for group in self.facts for f in group]
        out = np.empty(n_tokens, np.int32)
        t = int(rng.integers(4, spec.vocab))
        i = 0
        while i < n_tokens:
            if rng.random() < spec.fact_prob and i + 3 <= n_tokens:
                s, p, o = flat_facts[int(rng.integers(len(flat_facts)))]
                out[i : i + 2] = (s, p)
                # 0.95 consistency: occasionally corrupt the object
                out[i + 2] = o if rng.random() < 0.95 else int(rng.choice(self.object_tokens))
                i += 3
                t = int(out[i - 1])
            else:
                j = rng.choice(spec.n_successors, p=self.succ_probs[t])
                t = int(self.successors[t, j])
                out[i] = t
                i += 1
        return out

    # -------------------------------------------------------------------------
    def make_tasks(
        self, seq_len: int, per_task: int, seed: int
    ) -> dict[str, np.ndarray]:
        """Build the 8 probe tasks.

        Returns CBT-ready arrays: contexts (N, seq_len) i32 (the fact query
        "… s p" right-aligned over backbone text), choices (N, 4) i32,
        labels (N,) i32 (index of correct choice), task_ids (N,) i32.
        """
        rng = np.random.default_rng(seed)
        n = per_task * self.spec.n_relation_groups
        contexts = np.empty((n, seq_len), np.int32)
        choices = np.empty((n, 4), np.int32)
        labels = np.empty(n, np.int32)
        task_ids = np.empty(n, np.int32)
        row = 0
        for g, group in enumerate(self.facts):
            objects_in_group = np.array(sorted({o for (_, _, o) in group}), np.int64)
            for _ in range(per_task):
                s, p, o = group[int(rng.integers(len(group)))]
                ctx = self.sample_stream(seq_len, int(rng.integers(1 << 30)))
                ctx[-2:] = (s, p)
                distract_pool = objects_in_group[objects_in_group != o]
                if len(distract_pool) < 3:
                    distract_pool = self.object_tokens[self.object_tokens != o]
                d = rng.choice(distract_pool, size=3, replace=False)
                opts = np.array([o, *d], np.int32)
                perm = rng.permutation(4)
                contexts[row] = ctx
                choices[row] = opts[perm]
                labels[row] = int(np.where(perm == 0)[0][0])
                task_ids[row] = g
                row += 1
        return {
            "contexts": contexts,
            "choices": choices,
            "labels": labels,
            "task_ids": task_ids,
        }


def build_splits(
    lang: SyntheticLanguage, seq_len: int, train_tokens: int, val_tokens: int, calib_tokens: int
) -> dict[str, np.ndarray]:
    """Train / validation / calibration token streams (disjoint seeds)."""
    return {
        "train": lang.sample_stream(train_tokens, seed=101),
        "val": lang.sample_stream(val_tokens, seed=202),
        "calib": lang.sample_stream(calib_tokens, seed=303),
    }
