"""L1: Pallas kernels for COALA's compute hot-spots (interpret=True on CPU).

Modules:
  matmul   — MXU-tiled GEMM (the universal BLAS-3 primitive here)
  gram     — streamed Gram-chunk accumulation (baseline path, Fig. 3R)
  trailing — blocked-Householder compact-WY trailing update (QR hot spot)
  ref      — naive jnp oracles for all of the above
"""

from . import gram, matmul, ref, trailing  # noqa: F401
