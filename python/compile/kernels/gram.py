"""L1 Pallas kernel: Gram-matrix chunk accumulation  G ← G + XcᵀXc.

This is the *baseline* hot spot (SVD-LLM / SVD-LLM v2 form XXᵀ =
Σᵢ XᵢXᵢᵀ over calibration chunks; COALA itself never forms a Gram
matrix).  We still implement it as a first-class kernel because every
paper table/figure compares against the Gram-based methods, and the
Fig. 3 (right) experiment times exactly this accumulation against TSQR.

TPU mapping: the (n × n) output is tiled into (bn × bn) VMEM-resident
blocks; the chunk's k rows are streamed through VMEM in bk-slabs with the
same revisiting-accumulation schedule as the matmul kernel.  Because the
Gram matrix is symmetric the strict upper-triangle tiles could be skipped
(≈2× fewer MXU passes); we keep them for bit-exact parity with the
reference and note the halving in the §Perf roofline estimate.

Note the kernel computes ``XcᵀXc`` for a chunk laid out as Xcᵀ (rows =
calibration vectors), matching how activations arrive row-major from the
model: the paper's X (n × k) is our chunk transposed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128)  # (bn, bk)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _gram_kernel(g_ref, xt_ref_i, xt_ref_j, o_ref):
    """Grid point (i, j, l): o[i,j] += (Xᵀ chunk slab l, cols-block i)ᵀ @ (slab l, cols-block j).

    First visit seeds the tile with the running Gram block g_ref so that
    accumulation across calibration chunks composes without a separate add.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = g_ref[...]

    o_ref[...] += jnp.dot(
        xt_ref_i[...].T, xt_ref_j[...], preferred_element_type=o_ref.dtype
    )


def gram_update(
    g: jax.Array,
    xt_chunk: jax.Array,
    *,
    block: tuple[int, int] | None = None,
) -> jax.Array:
    """Return ``g + xt_chunkᵀ @ xt_chunk`` (one streamed Gram update).

    g        : (n, n) running Gram matrix.
    xt_chunk : (c, n) chunk of Xᵀ (c calibration vectors of width n).
    """
    n = g.shape[0]
    c, n2 = xt_chunk.shape
    if g.shape != (n, n) or n2 != n:
        raise ValueError(f"shape mismatch: G {g.shape}, chunk {xt_chunk.shape}")

    bn, bk = block or DEFAULT_BLOCK
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(c, 8))
    np_, cp = _round_up(n, bn), _round_up(c, bk)

    gp = jnp.pad(g, ((0, np_ - n), (0, np_ - n)))
    xp = jnp.pad(xt_chunk, ((0, cp - c), (0, np_ - n)))

    out = pl.pallas_call(
        _gram_kernel,
        grid=(np_ // bn, np_ // bn, cp // bk),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, l: (i, j)),   # G tile
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, i)),   # Xᵀ slab, cols i
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),   # Xᵀ slab, cols j
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), g.dtype),
        interpret=True,
    )(gp, xp, xp)
    return out[:n, :n]


def gram_flops(n: int, c: int) -> int:
    """FLOPs of one full (non-symmetry-exploiting) Gram update."""
    return 2 * n * n * c
