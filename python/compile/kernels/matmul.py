"""L1 Pallas kernel: tiled matmul, the BLAS-3 hot spot of COALA.

Every FLOP-heavy step of the pipeline (W·Rᵀ, the Householder trailing
update, Gram accumulation for the baselines, the UᵀW projection) reduces
to GEMM.  On TPU the kernel below is shaped for the MXU systolic array:

  * blocks default to 128×128×128 so each `jnp.dot` maps onto full MXU
    passes (the TPU analogue of a CUDA WMMA tensor-core tile);
  * `BlockSpec` index maps express the HBM→VMEM streaming schedule the
    paper's GPU implementation gets from threadblock tiling: the output
    tile (i, j) stays resident in VMEM while the K dimension is streamed
    (grid order (i, j, k) with k innermost → revisiting accumulation);
  * VMEM footprint per step is (bm·bk + bk·bn + bm·bn)·4 bytes, far under
    the ≈16 MiB VMEM budget for the default tile (192 KiB).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (the numbers are
identical; real-TPU perf is *estimated* in DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile.  Overridable per call for the block-shape sweep
# in the §Perf pass and for small matrices.
DEFAULT_BLOCK = (128, 128, 128)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    m, n = x.shape
    if m == rows and n == cols:
        return x
    return jnp.pad(x, ((0, rows - m), (0, cols - n)))


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps: int):
    """Grid point (i, j, k): accumulate one K-panel into output tile (i, j).

    The output BlockSpec maps every k to the same (i, j) tile, so o_ref is
    *revisited* across the innermost grid dimension — the canonical MXU
    accumulation schedule (zero on first visit, add afterwards).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def tiled_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Compute ``x @ y`` with an MXU-tiled Pallas kernel.

    Arbitrary (static) shapes are supported by zero-padding up to the tile
    grid; the result is sliced back.  For matrices smaller than one tile
    the block collapses to the (padded) matrix itself.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"tiled_matmul expects 2-D operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {y.shape}")

    bm, bn, bk = block or DEFAULT_BLOCK
    bm, bn, bk = min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)), min(bk, _round_up(k, 8))

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matmul_flops(m: int, n: int, k: int) -> int:
    """FLOPs of one GEMM (for the MXU-utilization estimates in §Perf)."""
    return 2 * m * n * k


def vmem_bytes(block: tuple[int, int, int] = DEFAULT_BLOCK, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM residency of the kernel (three tiles)."""
    bm, bn, bk = block
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes
