"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references the pytest suite (and the build-time
`make artifacts` self-check) compares the kernels against with
``assert_allclose``.  They are deliberately the most naive possible
formulations — a single un-tiled op each — so that any tiling /
revisiting / padding bug in the kernels shows up as a numeric diff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul.tiled_matmul."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def gram_update_ref(g: jax.Array, xt_chunk: jax.Array) -> jax.Array:
    """Oracle for kernels.gram.gram_update."""
    return g + jnp.dot(xt_chunk.T, xt_chunk, preferred_element_type=g.dtype)


def trailing_update_ref(a: jax.Array, v: jax.Array, t: jax.Array) -> jax.Array:
    """Oracle for kernels.trailing.trailing_update."""
    return a - v @ (t @ (v.T @ a))
