"""L1 Pallas kernel: blocked-Householder trailing-matrix update.

Blocked QR (the COALA preprocessing step, Prop. 2) factors a b-column
panel into compact-WY form (V, T) and then applies

    A ← (I − V·T·Vᵀ) A  =  A − V·(T·(Vᵀ·A))

to the trailing columns.  >90 % of the QR FLOPs live in this update, and
it is pure GEMM — exactly the part a CUDA implementation would hand to
cuBLAS and a TPU implementation hands to the MXU.  The panel factor
itself is O(m·b²) VPU work and stays in lax loops at L2.

The three chained GEMMs are expressed with the tiled matmul kernel; the
intermediate (b × n) and (b × n) products are tiny (b ≤ 64) and stay
VMEM-resident between stages on real hardware (here: XLA fuses the
interpret-mode HLO).
"""

from __future__ import annotations

import jax

from . import matmul


def trailing_update(
    a: jax.Array,
    v: jax.Array,
    t: jax.Array,
    *,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Return ``a - v @ (t @ (vᵀ @ a))``.

    a : (m, n) trailing columns.
    v : (m, b) unit-lower-trapezoidal Householder vectors (compact WY).
    t : (b, b) upper-triangular T factor with Q = I − V·T·Vᵀ.
    """
    m, n = a.shape
    m2, b = v.shape
    if m2 != m or t.shape != (b, b):
        raise ValueError(f"shape mismatch: A {a.shape}, V {v.shape}, T {t.shape}")
    w = matmul.tiled_matmul(v.T, a, block=block)        # (b, n)
    w = matmul.tiled_matmul(t, w, block=block)          # (b, n)
    return a - matmul.tiled_matmul(v, w, block=block)   # (m, n)


def trailing_flops(m: int, n: int, b: int) -> int:
    """FLOPs of one trailing update (three GEMMs)."""
    return 2 * b * n * m + 2 * b * b * n + 2 * m * n * b
