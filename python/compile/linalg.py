"""L2: inversion-free numerics, hand-rolled in pure jnp/lax.

Why from scratch: `jnp.linalg.{qr,svd,cholesky,eigh}` lower on CPU to
`lapack_*` custom-calls that the pinned xla_extension 0.5.1 runtime (the
`xla` 0.1.6 rust crate) cannot resolve, so every factorization used on
the request path is written here from first principles using only ops
that lower to plain HLO (while/fori loops, gathers/scatters, dots).

Contents:
  householder_qr_r        — unblocked masked Householder QR → R
  blocked_qr_r            — blocked (compact-WY) QR; trailing updates via
                            the L1 Pallas kernel (the FLOP hot spot)
  tsqr_step               — streaming TSQR: QR of [R ; Xᵀ-chunk]
  jacobi_svd              — one-sided Jacobi SVD with round-robin
                            *parallel* orderings (all n/2 disjoint column
                            pairs rotated per step — the TPU-friendly
                            formulation of the paper's `gesvd` calls)
  eigh_psd                — eigendecomposition of a PSD matrix (via
                            one-sided Jacobi; for SVD-LLM v2)
  cholesky                — unblocked masked Cholesky (for SVD-LLM)
  solve_triangular        — forward/back substitution (for baselines'
                            S⁻¹ application — COALA itself never inverts)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import trailing as trailing_kernel

# ---------------------------------------------------------------------------
# Householder QR
# ---------------------------------------------------------------------------


def _householder_vector(x: jax.Array, j: jnp.int32, m: int):
    """Householder vector annihilating x[j+1:] , masked for rows < j.

    Returns (v, beta, alpha): H = I - beta·vvᵀ, H x = alpha·e_j.
    Safe for the zero column (beta = 0 → H = I).
    """
    rows = jnp.arange(m)
    xm = jnp.where(rows >= j, x, 0.0)
    normx = jnp.sqrt(jnp.sum(xm * xm))
    xj = xm[j]
    # sign chosen to avoid cancellation
    alpha = jnp.where(xj >= 0, -normx, normx)
    v = xm.at[j].add(-alpha)
    vnorm2 = jnp.sum(v * v)
    beta = jnp.where(vnorm2 > 0, 2.0 / jnp.where(vnorm2 > 0, vnorm2, 1.0), 0.0)
    return v, beta, alpha


def householder_qr_r(a: jax.Array) -> jax.Array:
    """R factor of the QR decomposition of ``a`` (m × n, any aspect).

    Unblocked masked Householder via fori_loop: one while-loop in HLO, no
    per-column unrolling.  Returns the (min(m,n) × n) upper-triangular R
    padded/cut to (n × n) when m ≥ n (the COALA use-case: Rᵀ with
    RᵀR = XXᵀ).
    """
    m, n = a.shape
    steps = min(m, n)

    def body(j, acc):
        v, beta, _ = _householder_vector(acc[:, j], j, m)
        w = beta * (v @ acc)
        return acc - jnp.outer(v, w)

    r = lax.fori_loop(0, steps, body, a)
    k = min(m, n)
    r = r[:k, :]
    # numerical noise below the diagonal is exactly zeroed
    return jnp.triu(r) if m >= n else jnp.triu(r)


def qr_r_square(a: jax.Array, *, panel: int = 64) -> jax.Array:
    """R as a square (n × n) matrix for m ≥ n inputs (zero-pad if m < n).

    Dispatches to the blocked (Pallas-accelerated) algorithm whenever the
    width is an exact multiple of the panel size and large enough for the
    trailing GEMMs to dominate; falls back to the unblocked loop.
    """
    m, n = a.shape
    if n >= 2 * panel and n % panel == 0 and m >= n:
        r = blocked_qr_r(a, panel=panel)
    else:
        r = householder_qr_r(a)
    if r.shape[0] < n:
        r = jnp.pad(r, ((0, n - r.shape[0]), (0, 0)))
    return r[:n, :n]


def _panel_factor(a_panel: jax.Array, col0: int, b: int, m: int):
    """Factor an m × b panel whose pivot rows start at ``col0``.

    Returns (v_panel, t, r_panel): compact-WY with Q = I − V·T·Vᵀ.
    Loops over the b panel columns with a fori_loop (VPU-ish O(m·b²)).
    """

    def body(jj, carry):
        acc, v_acc, beta_acc = carry
        j = col0 + jj
        v, beta, _ = _householder_vector(acc[:, jj], j, m)
        w = beta * (v @ acc)
        acc = acc - jnp.outer(v, w)
        v_acc = v_acc.at[:, jj].set(v)
        beta_acc = beta_acc.at[jj].set(beta)
        return acc, v_acc, beta_acc

    v0 = jnp.zeros((m, b), a_panel.dtype)
    b0 = jnp.zeros((b,), a_panel.dtype)
    r_panel, v_panel, betas = lax.fori_loop(0, b, body, (a_panel, v0, b0))

    # Build T (upper triangular) from V and betas:
    #   T[0,0] = beta_0 ;  T[:j, j] = -beta_j · T[:j,:j] · (Vᵀ[:, j] of V[:j])
    vtv = v_panel.T @ v_panel  # (b, b)

    def t_body(j, t):
        col = -betas[j] * (t @ vtv[:, j])
        col = jnp.where(jnp.arange(b) < j, col, 0.0)
        col = col.at[j].set(betas[j])
        return t.at[:, j].set(col)

    t = lax.fori_loop(0, b, t_body, jnp.zeros((b, b), a_panel.dtype))
    return v_panel, t, r_panel


def blocked_qr_r(a: jax.Array, panel: int = 64, use_kernel: bool = False) -> jax.Array:
    """Blocked Householder QR → R, compact-WY trailing updates.

    The panel loop is a static python loop (n/panel iterations unrolled in
    HLO); within each panel the column loop is a fori_loop.  ``use_kernel``
    switches the trailing GEMMs between the tiled Pallas kernel and plain
    jnp dots.

    §Perf note (measured, see EXPERIMENTS.md): under ``interpret=True`` on
    the CPU runtime the Pallas grid becomes a scan of dynamic-sliced tile
    dots that XLA cannot fuse — 13× slower than the plain-jnp trailing
    update at (1792×768).  Interpret mode is a *correctness* vehicle; the
    CPU artifacts therefore default to the fused jnp path (panel=64), and
    a real-TPU build flips ``use_kernel=True`` so the MXU-tiled kernel
    (validated against the same oracle) takes over.
    """
    m, n = a.shape
    if n % panel != 0:
        pad = panel - n % panel
        a = jnp.pad(a, ((0, 0), (0, pad)))
        return blocked_qr_r(a, panel=panel, use_kernel=use_kernel)[:, :n][: min(m, n), :]

    update = (
        trailing_kernel.trailing_update
        if use_kernel
        else (lambda x, v, t: x - v @ (t @ (v.T @ x)))
    )

    acc = a
    for p in range(n // panel):
        col0 = p * panel
        v, t, r_panel = _panel_factor(acc[:, col0 : col0 + panel], col0, panel, m)
        rest = acc[:, col0 + panel :]
        if rest.shape[1] > 0:
            # apply Qᵀ = (I − V·T·Vᵀ)ᵀ = I − V·Tᵀ·Vᵀ to the trailing columns
            rest = update(rest, v, t.T)
        acc = jnp.concatenate([acc[:, :col0], r_panel, rest], axis=1)
    k = min(m, n)
    return jnp.triu(acc[:k, :])


def tsqr_step(r_prev: jax.Array, xt_chunk: jax.Array) -> jax.Array:
    """One streaming-TSQR step: R′ from QR of [R_prev ; Xᵀ-chunk].

    r_prev   : (n, n) upper triangular (R of everything seen so far;
               zeros on the first step).
    xt_chunk : (c, n) new chunk of Xᵀ.
    Satisfies  R′ᵀR′ = R_prevᵀR_prev + chunkᵀ·chunk  — i.e. exactly the
    Gram information, but accumulated in factored (stable) form.
    """
    stacked = jnp.concatenate([r_prev, xt_chunk], axis=0)
    return qr_r_square(stacked)


def tsqr_merge(r_a: jax.Array, r_b: jax.Array) -> jax.Array:
    """Tree-TSQR reduction: combine two R factors (both n × n)."""
    return qr_r_square(jnp.concatenate([r_a, r_b], axis=0))


# ---------------------------------------------------------------------------
# One-sided Jacobi SVD (round-robin parallel orderings)
# ---------------------------------------------------------------------------


def _brent_luk_perm(n: int) -> np.ndarray:
    """Static Brent–Luk column-position permutation for parallel Jacobi.

    Columns live in 2p = n positions: "left" slots 0..p−1 paired with
    "right" slots p..2p−1 (pair i = positions (i, p+i) — *static* slices).
    After each round the columns move one step around the tournament ring
    (left slot 0 pinned), which is the same constant permutation every
    round:

        new[0]     = old[0]
        new[1]     = old[p]          (R₀ promotes to L₁)
        new[i]     = old[i−1]        2 ≤ i < p
        new[p+i]   = old[p+i+1]      0 ≤ i < p−1
        new[2p−1]  = old[p−1]        (L_{p−1} demotes to R_{p−1})

    n−1 rounds make every pair of columns meet exactly once (the circle
    method).  Crucially this needs **no runtime-computed gather indices**
    — the pinned xla_extension 0.5.1 runtime miscompiles gathers/scatters
    with dynamic index operands inside while-loop bodies (verified by the
    conformance probes), and this is also exactly the systolic ordering
    Brent & Luk designed for processor arrays — i.e. the right TPU shape.
    """
    assert n % 2 == 0
    p = n // 2
    if p == 1:
        return np.array([0, 1], np.int32)  # single pair: nothing to rotate
    idx = np.empty(n, np.int32)
    idx[0] = 0
    idx[1] = p
    for i in range(2, p):
        idx[i] = i - 1
    for i in range(p - 1):
        idx[p + i] = p + i + 1
    idx[n - 1] = p - 1
    return idx


def _round_robin_pairs(n: int) -> np.ndarray:
    """(n−1, 2, n/2) pair schedule implied by `_brent_luk_perm` (testing aid).

    Tracks which *logical* columns occupy the paired positions in each
    round; used by the tests to prove all n(n−1)/2 pairs meet once.
    """
    assert n % 2 == 0
    p = n // 2
    perm = _brent_luk_perm(n)
    pos = np.arange(n)  # pos[slot] = logical column currently in slot
    rounds = []
    for _ in range(n - 1):
        left, right = pos[:p], pos[p:]
        rounds.append(np.stack([np.minimum(left, right), np.maximum(left, right)]))
        pos = pos[perm]
    return np.stack(rounds).astype(np.int32)


def jacobi_svd(
    a: jax.Array, sweeps: int = 12, sort: bool = True
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-sided Jacobi SVD of ``a`` (m × n, m ≥ n): returns (U, σ, V).

    a = U·diag(σ)·Vᵀ with U (m × n) orthonormal columns, V (n × n).
    Parallel one-sided Jacobi in the Brent–Luk systolic ordering: each
    fori step rotates all n/2 position-pairs (first half vs second half —
    static slices) to orthogonalize them, then applies the constant
    ring permutation.  A and V are permuted identically, so their columns
    stay aligned and no inverse permutation is ever needed.
    ``sweeps`` full sweeps of (n−1) rounds are run (no data-dependent
    early exit → static HLO; 12 sweeps ≫ what's needed in practice).
    """
    m, n = a.shape
    if m < n:
        raise ValueError(f"jacobi_svd requires m ≥ n, got {a.shape}")
    n_pad = n + (n % 2)
    if n_pad != n:
        a = jnp.pad(a, ((0, 0), (0, 1)))
    half = n_pad // 2
    rounds = n_pad - 1

    def ring_shift(mat):
        """Apply `_brent_luk_perm` as pure static slices + concat.

        NOT a gather: the pinned runtime miscompiles even constant-index
        gathers inside loop bodies at some (non-power-of-two) widths —
        bisected in the conformance suite.  Slice/concatenate lower to
        plain HLO slice ops and are safe everywhere.
        """
        if half == 1:
            return mat
        return jnp.concatenate(
            [
                mat[:, :1],                # L0 stays
                mat[:, half : half + 1],   # R0 promotes to L1
                mat[:, 1 : half - 1],      # L shifts right
                mat[:, half + 1 :],        # R shifts left
                mat[:, half - 1 : half],   # L_{p-1} demotes to R_{p-1}
            ],
            axis=1,
        )

    v0 = jnp.eye(n_pad, dtype=a.dtype)

    def body(_step, carry):
        acc, v = carry
        ap, aq = acc[:, :half], acc[:, half:]
        app = jnp.sum(ap * ap, axis=0)
        aqq = jnp.sum(aq * aq, axis=0)
        apq = jnp.sum(ap * aq, axis=0)
        # closed-form Jacobi rotation zeroing the (p,q) inner product
        denom_ok = jnp.abs(apq) > 1e-30
        tau = (aqq - app) / (2.0 * jnp.where(denom_ok, apq, 1.0))
        tden = jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau)
        tan = jnp.where(tau >= 0, 1.0 / tden, -1.0 / tden)
        cos = 1.0 / jnp.sqrt(1.0 + tan * tan)
        sin = cos * tan
        cos = jnp.where(denom_ok, cos, 1.0)
        sin = jnp.where(denom_ok, sin, 0.0)

        def rotate_and_shift(mat):
            cp, cq = mat[:, :half], mat[:, half:]
            new_p = cos * cp - sin * cq
            new_q = sin * cp + cos * cq
            return ring_shift(jnp.concatenate([new_p, new_q], axis=1))

        return rotate_and_shift(acc), rotate_and_shift(v)

    acc, v = lax.fori_loop(0, sweeps * rounds, body, (a, v0))

    sigma = jnp.sqrt(jnp.sum(acc * acc, axis=0))
    if sort:
        # Descending reorder WITHOUT a computed-index gather (argsort +
        # fancy indexing miscompiles on xla_extension 0.5.1 — see the
        # conformance suite).  lax.sort with a broadcast key and
        # is_stable=True applies the same permutation to every row.
        neg = -sigma
        key_a = jnp.broadcast_to(neg[None, :], acc.shape)
        _, acc = lax.sort((key_a, acc), dimension=1, is_stable=True, num_keys=1)
        key_v = jnp.broadcast_to(neg[None, :], v.shape)
        _, v = lax.sort((key_v, v), dimension=1, is_stable=True, num_keys=1)
        sigma = -jnp.sort(neg)
    safe = jnp.where(sigma > 0, sigma, 1.0)
    u = acc / safe[None, :]
    # drop padding column (it stays exactly zero → sorted last)
    if n_pad != n:
        u, sigma, v = u[:, :n], sigma[:n], v[:n, :n]
    return u, sigma, v


def eigh_psd(s: jax.Array, sweeps: int = 12) -> tuple[jax.Array, jax.Array]:
    """Eigendecomposition of a symmetric PSD matrix: S = U·diag(λ)·Uᵀ.

    For PSD S the left singular vectors coincide with eigenvectors and
    singular values with eigenvalues, so one-sided Jacobi suffices (this
    is the SVD-LLM v2 substrate; COALA never needs it).
    Returns (λ descending, U).
    """
    u, sigma, _ = jacobi_svd(s, sweeps=sweeps)
    return sigma, u


# ---------------------------------------------------------------------------
# Cholesky + triangular solves (baseline substrate)
# ---------------------------------------------------------------------------


def cholesky(s: jax.Array) -> jax.Array:
    """Lower Cholesky factor L with L·Lᵀ = S (masked right-looking).

    No pivoting and no regularization — deliberately the textbook
    algorithm SVD-LLM uses, so the numerical breakdown on near-singular
    Gram matrices (Fig. 1 / Example G.1) is reproduced faithfully.
    NaNs from a negative pivot propagate (as they do in torch.cholesky).
    """
    n = s.shape[0]
    rows = jnp.arange(n)

    def body(j, l):
        # pivot
        d = jnp.sqrt(l[j, j])
        col = l[:, j] / d
        col = jnp.where(rows >= j, col, 0.0)
        l = l.at[:, j].set(col)
        # rank-1 update of the trailing submatrix (masked)
        mask = ((rows[:, None] > j) & (rows[None, :] > j)).astype(l.dtype)
        l = l - mask * jnp.outer(col, col)
        return l

    l = lax.fori_loop(0, n, body, s)
    return jnp.tril(l)


def solve_triangular(
    l_or_u: jax.Array, b: jax.Array, *, lower: bool, trans: bool = False
) -> jax.Array:
    """Solve T·X = B (or Tᵀ·X = B) by substitution, T triangular (n × n).

    Used only by the Gram-based baselines (their B = Σ_r V_rᵀ S⁻¹ step).
    Column-oriented fori_loop; B is (n, k).
    """
    t = l_or_u.T if trans else l_or_u
    t_lower = lower != trans
    n = t.shape[0]

    if not t_lower:
        # Reverse rows/cols to reduce to the lower-triangular case.  Uses
        # jnp.flip (the HLO `reverse` op) — NOT index-array gathers, which
        # the pinned runtime miscompiles (see conformance suite).
        x = solve_triangular(jnp.flip(t, (0, 1)), jnp.flip(b, 0), lower=True)
        return jnp.flip(x, 0)

    def body(i, x):
        # x[i] = (b[i] - T[i, :i] @ x[:i]) / T[i, i]
        partial = t[i, :] @ x  # rows > i of x are still 0 → only :i counts…
        # careful: x rows ≥ i may be nonzero from init; we init x to 0 so fine
        xi = (b[i] - partial) / t[i, i]
        return x.at[i, :].set(xi)

    x0 = jnp.zeros_like(b)
    return lax.fori_loop(0, n, body, x0)


def matrix_power_half(x: jax.Array, alpha: int, sweeps: int = 12):
    """(XXᵀ)^{α/2} without forming XXᵀ (Prop. 4 substrate).

    SVD X = UΣVᵀ ⇒ (XXᵀ)^{α/2} = U·Σ^α·Uᵀ.  Needs m ≤ k (X is n × k with
    k ≥ n in the α-family use-case); computed via Jacobi SVD of Xᵀ.
    """
    n, k = x.shape
    if k < n:
        raise ValueError("matrix_power_half expects wide X (k ≥ n)")
    u_t, sigma, v_t = jacobi_svd(x.T, sweeps=sweeps)  # Xᵀ = u_t σ v_tᵀ ⇒ X = v_t σ u_tᵀ
    ux = v_t  # left singular vectors of X
    return (ux * (sigma[None, :] ** alpha)) @ ux.T
