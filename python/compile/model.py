"""L2: the compression target — a from-scratch pre-norm transformer LM.

Stands in for LLaMA3-1B / Mistral-7B (see DESIGN.md §substitutions): the
COALA pipeline acts per weight matrix on captured activations, so a small
*really trained* model reproduces all the numerics that matter
(ill-conditioned activation Grams, depth-wise norm growth, outliers).

Architecture (LLaMA-flavoured, but with learned positions and GELU MLP to
stay in plain-HLO ops): token emb + pos emb → L × [RMSNorm → causal MHA →
residual → RMSNorm → MLP → residual] → RMSNorm → tied-untied LM head via
the token embedding transpose.

Weight convention matches the paper and the rust side: every projection
is stored as W ∈ R^{out × in} and applied as  h · Wᵀ  (so the paper's
"input activation matrix X ∈ R^{n×k}" has n = in-features and k = tokens;
our row-major activation chunks are Xᵀ).

``forward_with_acts`` additionally returns, per layer, the four
activation streams the compression pipeline calibrates on:
  x_attn — input of q/k/v projections (post-ln1)
  x_o    — input of the o projection (attention mix output)
  x_up   — input of the up projection (post-ln2)
  x_down — input of the down projection (GELU(up(h)))
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int  # batch used for the AOT-fixed fwd shapes

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_names(self) -> list[str]:
        """Flat, *ordered* parameter list — this order IS the artifact ABI.

        The rust side reads the same list from manifest.json; any change
        here is a breaking ABI change and bumps manifest "abi_version".
        """
        names = ["tok_emb", "pos_emb"]
        for i in range(self.n_layers):
            names += [
                f"l{i}.ln1",
                f"l{i}.wq",
                f"l{i}.wk",
                f"l{i}.wv",
                f"l{i}.wo",
                f"l{i}.ln2",
                f"l{i}.w_up",
                f"l{i}.w_down",
            ]
        names.append("ln_f")
        names.append("lm_head")
        return names

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        d, f, v = self.d_model, self.d_ff, self.vocab
        shapes: dict[str, tuple[int, ...]] = {
            "tok_emb": (v, d),
            "pos_emb": (self.seq_len, d),
            "ln_f": (d,),
            "lm_head": (v, d),
        }
        for i in range(self.n_layers):
            shapes[f"l{i}.ln1"] = (d,)
            shapes[f"l{i}.wq"] = (d, d)
            shapes[f"l{i}.wk"] = (d, d)
            shapes[f"l{i}.wv"] = (d, d)
            shapes[f"l{i}.wo"] = (d, d)
            shapes[f"l{i}.ln2"] = (d,)
            shapes[f"l{i}.w_up"] = (f, d)
            shapes[f"l{i}.w_down"] = (d, f)
        return shapes

    def compressible(self) -> list[str]:
        """The projections the paper compresses: Q, K, V, O, Up, Down."""
        out = []
        for i in range(self.n_layers):
            out += [f"l{i}.{p}" for p in ("wq", "wk", "wv", "wo", "w_up", "w_down")]
        return out


TINY = ModelConfig("tiny", vocab=512, d_model=192, n_layers=4, n_heads=4, d_ff=768, seq_len=128, batch=8)
SMALL = ModelConfig("small", vocab=512, d_model=256, n_layers=6, n_heads=8, d_ff=1024, seq_len=128, batch=8)

CONFIGS = {c.name: c for c in (TINY, SMALL)}


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jax.Array]:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    rng = np.random.default_rng(seed)
    shapes = cfg.param_shapes()
    params: dict[str, jax.Array] = {}
    resid_scale = 1.0 / np.sqrt(2 * cfg.n_layers)
    for name in cfg.param_names():
        shp = shapes[name]
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            arr = np.ones(shp, np.float32)
        else:
            std = 0.02 if name in ("tok_emb", "pos_emb", "lm_head") else (1.0 / np.sqrt(shp[1]))
            arr = (rng.standard_normal(shp) * std).astype(np.float32)
            if name.endswith((".wo", ".w_down")):
                arr *= resid_scale
        params[name] = jnp.asarray(arr)
    return params


def rms_norm(h: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    scale = jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return h * scale * gain


def _attention(cfg: ModelConfig, q, k, v):
    """Causal multi-head attention over (B, T, d) projections."""
    b, t, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(x):
        return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)  # (B, H, T, hd)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    mix = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return mix.transpose(0, 2, 1, 3).reshape(b, t, d)


def _layer(cfg: ModelConfig, p: dict[str, jax.Array], i: int, h: jax.Array):
    """One transformer block; returns (h_out, activation dict)."""
    acts: dict[str, jax.Array] = {}
    x_attn = rms_norm(h, p[f"l{i}.ln1"])
    acts["attn"] = x_attn
    q = x_attn @ p[f"l{i}.wq"].T
    k = x_attn @ p[f"l{i}.wk"].T
    v = x_attn @ p[f"l{i}.wv"].T
    mix = _attention(cfg, q, k, v)
    acts["o"] = mix
    h = h + mix @ p[f"l{i}.wo"].T

    x_up = rms_norm(h, p[f"l{i}.ln2"])
    acts["up"] = x_up
    up = jax.nn.gelu(x_up @ p[f"l{i}.w_up"].T)
    acts["down"] = up
    h = h + up @ p[f"l{i}.w_down"].T
    return h, acts


def forward(cfg: ModelConfig, params: dict[str, jax.Array], tokens: jax.Array):
    """tokens (B, T) int32 → logits (B, T, vocab)."""
    h = jnp.take(params["tok_emb"], tokens, axis=0) + params["pos_emb"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        h, _ = _layer(cfg, params, i, h)
    h = rms_norm(h, params["ln_f"])
    return h @ params["lm_head"].T


def forward_with_acts(cfg: ModelConfig, params: dict[str, jax.Array], tokens: jax.Array):
    """Like ``forward`` but also returns the calibration activations.

    Output: (logits, [per-layer dict(attn, o, up, down)]) — flattened into
    a tuple by the AOT wrapper in a fixed order (layer-major, then
    attn/o/up/down), which the manifest records.
    """
    h = jnp.take(params["tok_emb"], tokens, axis=0) + params["pos_emb"][None, : tokens.shape[1]]
    all_acts = []
    for i in range(cfg.n_layers):
        h, acts = _layer(cfg, params, i, h)
        all_acts.append(acts)
    h = rms_norm(h, params["ln_f"])
    return h @ params["lm_head"].T, all_acts


ACT_STREAMS = ("attn", "o", "up", "down")

# projection → which activation stream feeds it
PROJ_INPUT_STREAM = {
    "wq": "attn",
    "wk": "attn",
    "wv": "attn",
    "wo": "o",
    "w_up": "up",
    "w_down": "down",
}


def loss_fn(cfg: ModelConfig, params: dict[str, jax.Array], tokens: jax.Array):
    """Next-token cross entropy, mean over (B, T−1).

    One-hot formulation instead of take_along_axis: gathers with computed
    index arrays miscompile on the pinned xla_extension 0.5.1 runtime
    (conformance-tested), and this graph ships to that runtime as the
    perplexity-eval artifact.
    """
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logp.dtype)
    return -jnp.mean(jnp.sum(logp * onehot, axis=-1))


def params_to_list(cfg: ModelConfig, params: dict[str, jax.Array]) -> list[jax.Array]:
    return [params[n] for n in cfg.param_names()]


def list_to_params(cfg: ModelConfig, flat: list[Any]) -> dict[str, Any]:
    return dict(zip(cfg.param_names(), flat))
