"""Build-time pretraining of the compression-target transformer (L2).

Runs exactly once inside `make artifacts` (skipped when the weights CBT
already exists).  Hand-rolled Adam + cosine schedule — still only jnp, so
the train step could itself be exported (we export it for the record as
`train_step_<cfg>` but the rust request path never calls it; fine-tuning
uses the dedicated adapter artifacts instead).

The loss curve is saved into the weights CBT (`pretrain_loss`) and
reported in EXPERIMENTS.md — the end-to-end evidence that the model the
pipeline compresses is *really trained*, not noise.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def adam_init(params: dict[str, jax.Array]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in params.items()}


def make_train_step(cfg: M.ModelConfig, base_lr: float, total_steps: int):
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01

    def step_fn(params, m, v, tokens, step):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, tokens))(params)
        warmup = 20.0
        lr = base_lr * jnp.minimum(1.0, step / warmup)
        lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.minimum(step / total_steps, 1.0) * 0.9))
        t = step + 1.0
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            m_k = b1 * m[k] + (1 - b1) * g
            v_k = b2 * v[k] + (1 - b2) * g * g
            mhat = m_k / (1 - b1**t)
            vhat = v_k / (1 - b2**t)
            upd = mhat / (jnp.sqrt(vhat) + eps)
            if k not in ("ln_f",) and not k.endswith((".ln1", ".ln2")):
                upd = upd + wd * params[k]
            new_p[k] = params[k] - lr * upd
            new_m[k], new_v[k] = m_k, v_k
        return new_p, new_m, new_v, loss

    return jax.jit(step_fn)


def batches(stream: np.ndarray, batch: int, seq_len: int, steps: int, seed: int):
    """Sample (batch, seq_len+1) windows for next-token training."""
    rng = np.random.default_rng(seed)
    hi = len(stream) - seq_len - 1
    for _ in range(steps):
        idx = rng.integers(0, hi, size=batch)
        yield np.stack([stream[i : i + seq_len + 1] for i in idx]).astype(np.int32)


def pretrain(
    cfg: M.ModelConfig,
    train_stream: np.ndarray,
    steps: int = 600,
    base_lr: float = 3e-3,
    log_every: int = 25,
    seed: int = 0,
) -> tuple[dict[str, jax.Array], np.ndarray]:
    """Train from scratch; returns (params, loss curve (steps,) f32)."""
    params = M.init_params(cfg, seed=seed)
    m, v = adam_init(params)
    step_fn = make_train_step(cfg, base_lr, steps)
    losses = np.empty(steps, np.float32)
    t0 = time.time()
    for i, tok in enumerate(batches(train_stream, cfg.batch, cfg.seq_len, steps, seed + 1)):
        params, m, v, loss = step_fn(params, m, v, jnp.asarray(tok), jnp.float32(i))
        losses[i] = float(loss)
        if i % log_every == 0 or i == steps - 1:
            print(
                f"[pretrain {cfg.name}] step {i:4d}/{steps}  loss {losses[i]:.4f}  "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, losses


def eval_ppl(cfg: M.ModelConfig, params, stream: np.ndarray, n_batches: int = 8) -> float:
    """Held-out perplexity (python-side sanity; rust re-measures via HLO)."""
    loss_j = jax.jit(functools.partial(M.loss_fn, cfg))
    tot = 0.0
    for i, tok in enumerate(batches(stream, cfg.batch, cfg.seq_len, n_batches, seed=7)):
        tot += float(loss_j(params, jnp.asarray(tok)))
    return float(np.exp(tot / n_batches))
