"""CBT — the COALA Binary Tensor format (build-time writer).

A deliberately boring container shared between the python compile path
(writer) and the rust runtime (reader, `rust/src/runtime/cbt.rs`):

    magic   : 4 bytes  b"CBT1"
    count   : u32 LE   number of tensors
    per tensor:
      name_len : u16 LE
      name     : utf-8 bytes
      dtype    : u8   (0 = f32, 1 = i32, 2 = f64)
      ndim     : u8
      dims     : ndim × u32 LE
      data     : row-major little-endian payload

Everything the rust binary needs at run time (trained weights, corpora,
probe-task banks, pretrain loss curve) ships as CBT files next to the
HLO artifacts.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"CBT1"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.float64): 2}
_RDTYPES = {0: np.float32, 1: np.int32, 2: np.float64}


def save_cbt(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_cbt(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: not a CBT file")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(_RDTYPES[dt])
            n_items = int(np.prod(dims)) if ndim else 1
            data = f.read(n_items * dtype.itemsize)
            out[name] = np.frombuffer(data, dtype=dtype).reshape(dims).copy()
    return out
