"""COALA algorithm properties: optimality, equivalences, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import coala as C
from compile import linalg as L

jax.config.update("jax_platform_name", "cpu")


def rand(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


def reconstruct(u, p, r):
    """W'_r from full factors (the host-side slicing rule)."""
    return np.asarray(u)[:, :r] @ np.asarray(p)[:r, :]


def ctx_err(w, wp, x):
    return np.linalg.norm((w - wp) @ x)


def optimal_err(w, x, r):
    """Closed-form optimum of problem (3) via numpy (Prop. 1 in fp64)."""
    wx = w.astype(np.float64) @ x.astype(np.float64)
    u, _, _ = np.linalg.svd(wx, full_matrices=False)
    ur = u[:, :r]
    wp = ur @ ur.T @ w
    return ctx_err(w, wp, x)


# ---------------------------------------------------------------- Alg. 1


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(4, 30),
    n=st.integers(4, 24),
    k=st.integers(24, 60),
    seed=st.integers(0, 2**16),
)
def test_coala_attains_the_optimum(m, n, k, seed):
    """‖(W−W'_r)X‖ must match the Prop.-1 optimum for every rank."""
    w, x = rand(seed, m, n), rand(seed + 1, n, k)
    u, s, p = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x))
    scale = np.linalg.norm(w @ x)
    for r in (1, min(m, n) // 2, min(m, n)):
        got = ctx_err(w, reconstruct(u, p, r), x)
        want = optimal_err(w, x, r)
        assert got <= want * (1 + 5e-3) + 5e-5 * scale, (r, got, want)


def test_coala_rank_is_bounded():
    w, x = rand(0, 12, 10), rand(1, 10, 40)
    u, s, p = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x))
    wp = reconstruct(u, p, 3)
    assert np.linalg.matrix_rank(wp, tol=1e-4) <= 3


def test_coala_handles_rank_deficient_x():
    """No full-column-rank assumption (the paper's key robustness claim)."""
    w = rand(2, 8, 10)
    x_thin = rand(3, 10, 4)  # only 4 samples < n=10
    u, s, p = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x_thin))
    assert np.all(np.isfinite(np.asarray(u))) and np.all(np.isfinite(np.asarray(p)))
    got = ctx_err(w, reconstruct(u, p, 3), x_thin)
    want = optimal_err(w, x_thin, 3)
    assert got <= want * 1.01 + 1e-4


def test_factorize_from_r_equals_from_x():
    w, x = rand(4, 10, 12), rand(5, 12, 50)
    r = L.qr_r_square(jnp.asarray(x).T)
    u1, s1, p1 = C.coala_factorize(jnp.asarray(w), r)
    u2, s2, p2 = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(
        np.abs(reconstruct(u1, p1, 4)), np.abs(reconstruct(u2, p2, 4)), rtol=0, atol=1e-3
    )


# ---------------------------------------------------------------- Alg. 2 (regularization)


def test_regularized_r_matches_augmented_x():
    """Prop. 3: R of [X √μI] ≡ augmenting R itself."""
    x = rand(6, 9, 33)
    mu = 0.37
    r0 = L.qr_r_square(jnp.asarray(x).T)
    r_aug = np.asarray(C.regularized_r(r0, jnp.float32(mu)))
    want = x @ x.T + mu * np.eye(9, dtype=np.float32)
    np.testing.assert_allclose(r_aug.T @ r_aug, want, rtol=2e-3, atol=2e-3)


def test_regularized_solution_converges_linearly_in_mu():
    """Thm 1: ‖W₀ − W_μ‖_F = O(μ) with the predicted constant as bound."""
    m, n, k, r = 10, 8, 20, 3
    w, x = rand(7, m, n), rand(8, n, k)
    u0, _, p0 = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x))
    w0 = reconstruct(u0, p0, r)

    wx = w @ x
    s = np.linalg.svd(wx, compute_uv=False)
    gap2 = s[r - 1] ** 2 - s[r] ** 2
    const = 2 * np.linalg.norm(w, 2) ** 2 * np.linalg.norm(w) / gap2

    r_factor = L.qr_r_square(jnp.asarray(x).T)
    errs = []
    mus = [1e-3, 1e-2, 1e-1]
    for mu in mus:
        u, _, p = C.coala_factorize_regularized(jnp.asarray(w), r_factor, jnp.float32(mu))
        errs.append(np.linalg.norm(w0 - reconstruct(u, p, r)))
    for mu, err in zip(mus, errs):
        assert err <= const * mu + 5e-3, (mu, err, const * mu)
    # roughly linear decay (allowing fp32 noise floor)
    assert errs[0] < errs[2]


def test_mu_from_lambda_terms():
    """Eq. (5) numerator/denominator against a direct computation."""
    m, n, k, r = 8, 6, 30, 2
    w, x = rand(9, m, n), rand(10, n, k)
    rf = L.qr_r_square(jnp.asarray(x).T)
    u, s, p = C.coala_factorize(jnp.asarray(w), rf)
    mask = (np.arange(min(m, n)) < r).astype(np.float32)
    num, den = C.mu_from_lambda(jnp.asarray(w), u, p, rf, jnp.asarray(mask))
    w0 = reconstruct(u, p, r)
    np.testing.assert_allclose(float(num), np.linalg.norm((w0 - w) @ x) ** 2, rtol=2e-2)
    np.testing.assert_allclose(float(den), np.linalg.norm(w0 - w) ** 2, rtol=2e-2)


# ---------------------------------------------------------------- α-family


def test_alpha0_equals_plain_svd():
    w = rand(11, 9, 7)
    rf = L.qr_r_square(jnp.asarray(rand(12, 7, 30)).T)
    u0, s0, p0 = C.alpha_factorize(jnp.asarray(w), rf, alpha=0)
    u1, s1, b1 = C.plain_svd_factorize(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(reconstruct(u0, p0, 3), reconstruct(u1, b1, 3), atol=1e-3)


def test_alpha1_equals_coala():
    w, x = rand(13, 8, 6), rand(14, 6, 40)
    rf = L.qr_r_square(jnp.asarray(x).T)
    ua, sa, pa = C.alpha_factorize(jnp.asarray(w), rf, alpha=1)
    uc, sc, pc = C.coala_factorize(jnp.asarray(w), rf)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sc), rtol=1e-4)
    np.testing.assert_allclose(reconstruct(ua, pa, 3), reconstruct(uc, pc, 3), atol=1e-3)


def test_alpha2_equals_corda_on_well_conditioned_data():
    """Remark 1: robust α=2 ≡ original CorDA when XXᵀ is well conditioned."""
    m, n, k, r = 8, 6, 60, 3
    w, x = rand(15, m, n), rand(16, n, k)
    rf = L.qr_r_square(jnp.asarray(x).T)
    u2, s2, p2 = C.alpha_factorize(jnp.asarray(w), rf, alpha=2)
    g = (x @ x.T).astype(np.float32)
    uc, sc, bc = C.corda_unrobust(jnp.asarray(w), jnp.asarray(g))
    np.testing.assert_allclose(reconstruct(u2, p2, r), reconstruct(uc, bc, r), rtol=0, atol=5e-3)


def test_alpha_rejects_unknown():
    with pytest.raises(ValueError):
        C.alpha_factorize(jnp.ones((4, 4)), jnp.eye(4), alpha=3)


# ---------------------------------------------------------------- Gram baselines


def test_svdllm_matches_coala_when_well_conditioned():
    m, n, k, r = 10, 8, 80, 4
    w, x = rand(17, m, n), rand(18, n, k)
    g = (x @ x.T).astype(np.float32)
    u, s, b = C.svdllm_factorize(jnp.asarray(w), jnp.asarray(g))
    err = ctx_err(w, reconstruct(u, b, r), x)
    want = optimal_err(w, x, r)
    assert err <= want * 1.02 + 1e-3


def test_svdllm_v2_matches_coala_when_well_conditioned():
    m, n, k, r = 10, 8, 80, 4
    w, x = rand(19, m, n), rand(20, n, k)
    g = (x @ x.T).astype(np.float32)
    u, s, b = C.svdllm_v2_factorize(jnp.asarray(w), jnp.asarray(g))
    err = ctx_err(w, reconstruct(u, b, r), x)
    want = optimal_err(w, x, r)
    assert err <= want * 1.02 + 1e-3


def test_svdllm_breaks_on_singular_gram_but_coala_does_not():
    """The paper's headline stability claim, in miniature."""
    m, n, k, r = 6, 8, 4, 2  # k < n ⇒ XXᵀ singular
    w, x = rand(21, m, n), rand(22, n, k)
    g = (x @ x.T).astype(np.float32)
    u, s, b = C.svdllm_factorize(jnp.asarray(w), jnp.asarray(g))
    assert not np.all(np.isfinite(np.asarray(b)))  # Cholesky of singular G
    uc, sc, pc = C.coala_factorize_from_x(jnp.asarray(w), jnp.asarray(x))
    assert np.all(np.isfinite(reconstruct(uc, pc, r)))


def test_asvd_is_suboptimal_but_finite():
    m, n, k, r = 10, 8, 60, 3
    w, x = rand(23, m, n), rand(24, n, k)
    scales = (np.mean(np.abs(x), axis=1) ** 0.5 + 1e-3).astype(np.float32)
    u, s, b = C.asvd_factorize(jnp.asarray(w), jnp.asarray(scales))
    err = ctx_err(w, reconstruct(u, b, r), x)
    assert np.isfinite(err)
    assert err >= optimal_err(w, x, r) * 0.999  # never beats the optimum
