"""Synthetic language + probe tasks: determinism, well-formedness, learnability signal."""

import numpy as np

from compile import data as D
from compile import serialize


def test_stream_deterministic():
    lang = D.SyntheticLanguage(D.LanguageSpec())
    a = lang.sample_stream(500, seed=1)
    b = lang.sample_stream(500, seed=1)
    np.testing.assert_array_equal(a, b)
    c = lang.sample_stream(500, seed=2)
    assert not np.array_equal(a, c)


def test_stream_token_range():
    lang = D.SyntheticLanguage(D.LanguageSpec())
    s = lang.sample_stream(2000, seed=3)
    assert s.min() >= 0 and s.max() < lang.spec.vocab


def test_facts_have_unique_subject_relation():
    lang = D.SyntheticLanguage(D.LanguageSpec())
    for group in lang.facts:
        pairs = [(s, p) for (s, p, _) in group]
        assert len(pairs) == len(set(pairs))


def test_fact_seed_changes_facts():
    base = D.SyntheticLanguage(D.LanguageSpec(), fact_seed=0)
    ft = D.SyntheticLanguage(D.LanguageSpec(), fact_seed=1)
    assert base.facts != ft.facts
    # but the backbone language is shared
    np.testing.assert_array_equal(base.successors, ft.successors)


def test_tasks_well_formed():
    lang = D.SyntheticLanguage(D.LanguageSpec())
    tasks = lang.make_tasks(seq_len=32, per_task=5, seed=9)
    n = 5 * lang.spec.n_relation_groups
    assert tasks["contexts"].shape == (n, 32)
    assert tasks["choices"].shape == (n, 4)
    assert tasks["labels"].shape == (n,)
    assert set(np.unique(tasks["task_ids"])) == set(range(8))
    for i in range(n):
        row = tasks["choices"][i]
        assert len(set(row.tolist())) == 4  # distinct options
        # the correct choice is the object of a real fact for (s, p)
        s, p = tasks["contexts"][i, -2], tasks["contexts"][i, -1]
        g = tasks["task_ids"][i]
        facts = {(fs, fp): fo for (fs, fp, fo) in lang.facts[g]}
        assert facts[(int(s), int(p))] == int(row[tasks["labels"][i]])


def test_fact_conditional_is_predictable():
    """P(o | s, p) in the stream must be high — the signal probes test."""
    lang = D.SyntheticLanguage(D.LanguageSpec())
    s = lang.sample_stream(200_000, seed=11)
    facts = {(fs, fp): fo for g in lang.facts for (fs, fp, fo) in g}
    hits = total = 0
    for i in range(len(s) - 2):
        key = (int(s[i]), int(s[i + 1]))
        if key in facts:
            total += 1
            hits += int(s[i + 2]) == facts[key]
    assert total > 100
    assert hits / total > 0.75


def test_cbt_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, 2, 3], np.int32),
        "scalar": np.array(3.5, np.float64),
        "empty_name_ok": np.zeros((2, 2, 2), np.float32),
    }
    p = str(tmp_path / "t.cbt")
    serialize.save_cbt(p, tensors)
    out = serialize.load_cbt(p)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype
