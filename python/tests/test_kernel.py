"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (including non-multiples of the tile so the
padding path is exercised) and block sizes; assert_allclose against
ref.py.  f32 everywhere (the artifact dtype); f64 smoke-checked too.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matmul, ref, trailing

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


dims = st.integers(min_value=1, max_value=97)
blocks = st.sampled_from([(8, 8, 8), (16, 32, 8), (32, 32, 32), (128, 128, 128)])


@settings(max_examples=30, deadline=None)
@given(m=dims, k=dims, n=dims, block=blocks, seed=st.integers(0, 2**16))
def test_tiled_matmul_matches_ref(m, k, n, block, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    got = matmul.tiled_matmul(jnp.asarray(x), jnp.asarray(y), block=block)
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 80),
    c=st.integers(1, 200),
    block=st.sampled_from([(16, 16), (32, 64), (128, 128)]),
    seed=st.integers(0, 2**16),
)
def test_gram_update_matches_ref(n, c, block, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, n, n)
    g = g + g.T  # symmetric running Gram
    xt = rand(rng, c, n)
    got = gram.gram_update(jnp.asarray(g), jnp.asarray(xt), block=block)
    want = ref.gram_update_ref(jnp.asarray(g), jnp.asarray(xt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(4, 120),
    n=st.integers(1, 60),
    b=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_trailing_update_matches_ref(m, n, b, seed):
    rng = np.random.default_rng(seed)
    a, v, t = rand(rng, m, n), rand(rng, m, b), np.triu(rand(rng, b, b))
    got = trailing.trailing_update(jnp.asarray(a), jnp.asarray(v), jnp.asarray(t))
    want = ref.trailing_update_ref(jnp.asarray(a), jnp.asarray(v), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_matmul_f64():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((33, 17))
    y = rng.standard_normal((17, 29))
    with jax.enable_x64(True):
        got = matmul.tiled_matmul(jnp.asarray(x), jnp.asarray(y), block=(16, 16, 16))
        np.testing.assert_allclose(np.asarray(got), x @ y, rtol=1e-12)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul.tiled_matmul(jnp.ones((2, 3)), jnp.ones((4, 5)))
    with pytest.raises(ValueError):
        matmul.tiled_matmul(jnp.ones((2, 3, 4)), jnp.ones((4, 5)))


def test_gram_rejects_bad_shapes():
    with pytest.raises(ValueError):
        gram.gram_update(jnp.ones((3, 3)), jnp.ones((5, 4)))


def test_trailing_rejects_bad_shapes():
    with pytest.raises(ValueError):
        trailing.trailing_update(jnp.ones((4, 4)), jnp.ones((5, 2)), jnp.ones((2, 2)))


def test_vmem_and_flops_helpers():
    assert matmul.vmem_bytes((128, 128, 128)) == 3 * 128 * 128 * 4
    assert matmul.matmul_flops(2, 3, 4) == 48
    assert gram.gram_flops(4, 10) == 320
    assert trailing.trailing_flops(8, 4, 2) == 2 * 2 * 4 * 8 + 2 * 4 * 4 + 2 * 8 * 4 * 2
