"""Hand-rolled L2 numerics vs numpy — QR/TSQR/SVD/eig/Cholesky/solves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import linalg as L

jax.config.update("jax_platform_name", "cpu")


def rand(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- QR


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 120), n=st.integers(1, 60), seed=st.integers(0, 2**16))
def test_householder_qr_gram_identity(m, n, seed):
    """RᵀR must equal AᵀA — the only property COALA needs from R."""
    a = rand(seed, m, n)
    r = np.asarray(L.householder_qr_r(jnp.asarray(a)))
    assert r.shape == (min(m, n), n)
    np.testing.assert_allclose(r.T @ r, a.T @ a, rtol=5e-4, atol=5e-4)
    # upper triangular
    np.testing.assert_array_equal(np.tril(r, -1), np.zeros_like(np.tril(r, -1)))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(64, 200),
    npanels=st.integers(2, 4),
    seed=st.integers(0, 2**16),
)
def test_blocked_qr_matches_unblocked(m, npanels, seed):
    n = 32 * npanels
    m = max(m, n)
    a = rand(seed, m, n)
    r_b = np.asarray(L.blocked_qr_r(jnp.asarray(a), panel=32))
    np.testing.assert_allclose(r_b.T @ r_b, a.T @ a, rtol=2e-3, atol=2e-3)


def test_blocked_qr_kernel_vs_oracle_path():
    a = rand(3, 150, 64)
    r1 = np.asarray(L.blocked_qr_r(jnp.asarray(a), panel=32, use_kernel=True))
    r2 = np.asarray(L.blocked_qr_r(jnp.asarray(a), panel=32, use_kernel=False))
    np.testing.assert_allclose(r1, r2, rtol=1e-4, atol=1e-4)


def test_qr_r_square_pads_wide_input():
    a = rand(5, 3, 8)  # m < n
    r = np.asarray(L.qr_r_square(jnp.asarray(a)))
    assert r.shape == (8, 8)
    np.testing.assert_allclose(r.T @ r, a.T @ a, rtol=1e-3, atol=1e-3)


def test_qr_rank_deficient_is_finite():
    a = np.ones((40, 10), np.float32)  # rank 1
    r = np.asarray(L.householder_qr_r(jnp.asarray(a)))
    assert np.all(np.isfinite(r))
    np.testing.assert_allclose(r.T @ r, a.T @ a, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- TSQR


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 24), chunks=st.integers(1, 5), c=st.integers(4, 40), seed=st.integers(0, 2**16))
def test_tsqr_stream_equals_full_qr(n, chunks, c, seed):
    xs = [rand(seed + i, c, n) for i in range(chunks)]
    r = jnp.zeros((n, n), jnp.float32)
    for xc in xs:
        r = L.tsqr_step(r, jnp.asarray(xc))
    full = np.concatenate(xs, axis=0)
    np.testing.assert_allclose(
        np.asarray(r).T @ np.asarray(r), full.T @ full, rtol=2e-3, atol=2e-3
    )


def test_tsqr_tree_merge_matches_sequential():
    n, c = 12, 30
    xs = [rand(50 + i, c, n) for i in range(4)]
    leaves = [L.qr_r_square(jnp.asarray(x)) for x in xs]
    merged = L.tsqr_merge(L.tsqr_merge(leaves[0], leaves[1]), L.tsqr_merge(leaves[2], leaves[3]))
    full = np.concatenate(xs, axis=0)
    np.testing.assert_allclose(
        np.asarray(merged).T @ np.asarray(merged), full.T @ full, rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------- Jacobi SVD


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 60), n=st.integers(2, 24), seed=st.integers(0, 2**16))
def test_jacobi_svd_reconstructs(m, n, seed):
    m = max(m, n)
    a = rand(seed, m, n)
    u, s, v = (np.asarray(t) for t in L.jacobi_svd(jnp.asarray(a)))
    np.testing.assert_allclose(u @ np.diag(s) @ v.T, a, rtol=0, atol=5e-4 * max(1, np.abs(a).max()))
    np.testing.assert_allclose(u.T @ u, np.eye(n), atol=5e-4)
    np.testing.assert_allclose(v.T @ v, np.eye(n), atol=5e-4)
    # singular values match numpy, descending
    s_np = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s_np, rtol=1e-3, atol=1e-3)
    assert np.all(np.diff(s) <= 1e-5)


def test_jacobi_svd_odd_width_pads():
    a = rand(11, 9, 7)
    u, s, v = (np.asarray(t) for t in L.jacobi_svd(jnp.asarray(a)))
    assert u.shape == (9, 7) and s.shape == (7,) and v.shape == (7, 7)
    np.testing.assert_allclose(u @ np.diag(s) @ v.T, a, atol=1e-3)


def test_jacobi_svd_rank_deficient():
    a = np.outer(rand(1, 20), rand(2, 8)).astype(np.float32)
    u, s, v = (np.asarray(t) for t in L.jacobi_svd(jnp.asarray(a)))
    assert s[0] > 1e-3 and np.all(s[1:] < 1e-4)
    np.testing.assert_allclose(u @ np.diag(s) @ v.T, a, atol=1e-4)


def test_jacobi_svd_requires_tall():
    with pytest.raises(ValueError):
        L.jacobi_svd(jnp.ones((3, 5)))


def test_eigh_psd_matches_numpy():
    a = rand(7, 30, 18)
    g = (a.T @ a).astype(np.float32)
    lam, u = (np.asarray(t) for t in L.eigh_psd(jnp.asarray(g)))
    lam_np = np.linalg.eigvalsh(g)[::-1]
    np.testing.assert_allclose(lam, lam_np, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(u @ np.diag(lam) @ u.T, g, rtol=0, atol=2e-2)


# ---------------------------------------------------------------- Cholesky / solves


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**16))
def test_cholesky_matches_numpy(n, seed):
    a = rand(seed, n + 5, n)
    g = a.T @ a + 0.1 * np.eye(n, dtype=np.float32)
    l = np.asarray(L.cholesky(jnp.asarray(g)))
    np.testing.assert_allclose(l @ l.T, g, rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.triu(l, 1), np.zeros_like(np.triu(l, 1)))


def test_cholesky_singular_produces_nonfinite():
    """The SVD-LLM failure mode: singular Gram ⇒ NaN/Inf factor."""
    g = np.ones((6, 6), np.float32)  # rank 1, singular
    l = np.asarray(L.cholesky(jnp.asarray(g)))
    assert not np.all(np.isfinite(l))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 30),
    k=st.integers(1, 10),
    lower=st.booleans(),
    trans=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_solve_triangular(n, k, lower, trans, seed):
    rng = np.random.default_rng(seed)
    t = rng.standard_normal((n, n)).astype(np.float32)
    t = (np.tril(t) if lower else np.triu(t)) + 3 * np.eye(n, dtype=np.float32)
    b = rng.standard_normal((n, k)).astype(np.float32)
    x = np.asarray(L.solve_triangular(jnp.asarray(t), jnp.asarray(b), lower=lower, trans=trans))
    lhs = (t.T if trans else t) @ x
    np.testing.assert_allclose(lhs, b, rtol=2e-3, atol=2e-3)


def test_matrix_power_half():
    x = rand(9, 10, 25)  # n=10, k=25
    got = np.asarray(L.matrix_power_half(jnp.asarray(x), alpha=1))
    g = x @ x.T
    lam, u = np.linalg.eigh(g)
    want = (u * np.sqrt(np.maximum(lam, 0))[None, :]) @ u.T
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-3)


def test_round_robin_schedule_covers_all_pairs():
    for n in (4, 8, 14):
        sched = L._round_robin_pairs(n)
        assert sched.shape == (n - 1, 2, n // 2)
        seen = set()
        for rnd in sched:
            cols = set(rnd[0]) | set(rnd[1])
            assert cols == set(range(n))  # disjoint cover each round
            for p, q in zip(rnd[0], rnd[1]):
                assert p < q
                seen.add((int(p), int(q)))
        assert len(seen) == n * (n - 1) // 2  # every pair exactly once
