"""Transformer L2 graph: shapes, causality, trainability, adapters."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import adapters as A
from compile import model as M
from compile import pretrain as P

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig("test", vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=2)


def toks(seed=0, batch=2, t=16):
    return jnp.asarray(np.random.default_rng(seed).integers(0, CFG.vocab, (batch, t)), jnp.int32)


def test_forward_shapes():
    params = M.init_params(CFG)
    logits = M.forward(CFG, params, toks())
    assert logits.shape == (2, 16, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_inventory_consistent():
    names = CFG.param_names()
    shapes = CFG.param_shapes()
    assert len(names) == len(set(names))
    assert set(names) == set(shapes)
    assert len(CFG.compressible()) == 6 * CFG.n_layers
    for p in CFG.compressible():
        assert p in shapes


def test_causality():
    """Changing a future token must not affect past logits."""
    params = M.init_params(CFG)
    t1 = toks(1)
    t2 = t1.at[:, 10].set((t1[:, 10] + 1) % CFG.vocab)
    l1 = np.asarray(M.forward(CFG, params, t1))
    l2 = np.asarray(M.forward(CFG, params, t2))
    np.testing.assert_allclose(l1[:, :10], l2[:, :10], atol=1e-5)
    assert np.abs(l1[:, 10:] - l2[:, 10:]).max() > 1e-6


def test_activation_capture_matches_forward():
    params = M.init_params(CFG)
    logits1 = M.forward(CFG, params, toks(2))
    logits2, acts = M.forward_with_acts(CFG, params, toks(2))
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2), atol=1e-6)
    assert len(acts) == CFG.n_layers
    for layer in acts:
        assert set(layer) == set(M.ACT_STREAMS)
        assert layer["attn"].shape == (2, 16, CFG.d_model)
        assert layer["down"].shape == (2, 16, CFG.d_ff)


def test_activations_feed_the_right_projection():
    """W'·x over captured acts must reproduce each projection output."""
    params = M.init_params(CFG)
    _, acts = M.forward_with_acts(CFG, params, toks(3))
    x = np.asarray(acts[0]["attn"]).reshape(-1, CFG.d_model)
    q = x @ np.asarray(params["l0.wq"]).T
    assert q.shape == (32, CFG.d_model)
    assert np.isfinite(q).all()


def test_loss_decreases_with_training():
    lang_stream = np.random.default_rng(5).integers(0, CFG.vocab, 8000).astype(np.int32)
    # make it learnable: deterministic successor pattern
    lang_stream[1::2] = (lang_stream[0::2] * 7 + 3) % CFG.vocab
    big = M.ModelConfig("test", vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=8)
    params, losses = P.pretrain(big, lang_stream, steps=200, base_lr=1e-2, log_every=1000)
    assert losses[-5:].mean() < losses[:5].mean() * 0.8


def test_adapter_forward_matches_base_when_zero():
    params = M.init_params(CFG)
    ads = {n: jnp.zeros(s) for n, s in A.adapter_shapes(CFG, 4)}
    l_base = M.forward(CFG, params, toks(4))
    l_ad = A.forward_adapted(CFG, params, ads, toks(4))
    np.testing.assert_allclose(np.asarray(l_base), np.asarray(l_ad), atol=1e-5)


def test_adapter_train_step_reduces_loss():
    params = M.init_params(CFG)
    rng = np.random.default_rng(6)
    ads = {}
    for n, s in A.adapter_shapes(CFG, 4):
        if n.endswith(".A"):
            ads[n] = jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.02)
        else:
            ads[n] = jnp.zeros(s)
    m = {k: jnp.zeros_like(v) for k, v in ads.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in ads.items()}
    batch = toks(7, 2, 17)
    step = jax.jit(lambda a, mm, vv, t, s: A.adapter_train_step(CFG, params, a, mm, vv, t, jnp.float32(1e-2), s))
    loss0 = None
    for i in range(12):
        loss, ads, m, v = step(ads, m, v, batch, jnp.float32(i))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0 - 1e-3


def test_adapter_shapes_abi():
    shp = A.adapter_shapes(CFG, 8)
    assert len(shp) == 2 * 6 * CFG.n_layers
    assert shp[0][0] == "l0.wq.A" and shp[0][1] == (CFG.d_model, 8)
    assert shp[1][0] == "l0.wq.B" and shp[1][1] == (8, CFG.d_model)
