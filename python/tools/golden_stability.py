#!/usr/bin/env python3
"""Generate the canonical golden snapshot for the host-route stability
tables (rust/tests/golden/stability.json).

This is a faithful NumPy port of the quantities the Rust golden test
(rust/tests/repro_host.rs) snapshots from a canonical
`COALA_REPRO_FAST=1 coala repro --route host` run with the default seed:

* ``fig1_coala`` — the Fig. 1 COALA(QR, f32) column: relative spectral
  error of the f32 COALA reconstruction against the fp64 COALA reference
  on the synthetic ``l1.wq`` calibration data (layer 1 = the nearly
  singular regime), at ranks [1, 2, 4, 8, 16, 32];
* ``fig2_sigma`` — per-layer (σ_max, σ_min) of the q-proj activation
  matrix X, all three conditioning regimes (f64 spectra, pinned tightly
  by the Rust test);
* ``g1_exact`` — Example G.1's exact σ_min of X = [[1, 1], [0, √(ε/2)]]
  for fp16 / bf16 / fp32 unit roundoffs.

The PRNG (SplitMix64-seeded xoshiro256**), the synthetic data layout,
and the driver's arithmetic are ported exactly; the QR/SVD use LAPACK
instead of the crate's Householder/Jacobi kernels, which agrees far
inside the order-of-magnitude tolerance the Rust test applies (it
compares decades above a noise floor — see repro_host.rs).

Usage:  python3 python/tools/golden_stability.py  (from the repo root)
"""

import json
import math
import os

import numpy as np

MASK = (1 << 64) - 1
GOLDEN_RATIO = 0x9E3779B97F4A7C15

# ----------------------------------------------------------- util::prng


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via SplitMix64 (rust/src/util/prng.rs)."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm = (sm + GOLDEN_RATIO) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        while True:
            u1 = self.uniform()
            if u1 > 1e-12:
                u2 = self.uniform()
                return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def randn_f32(rows, cols, seed):
    """Matrix::<f32>::randn — row-major fill of f32-cast normals."""
    rng = Rng(seed)
    data = np.array([rng.normal() for _ in range(rows * cols)], dtype=np.float32)
    return data.reshape(rows, cols)


# ----------------------------------------------- model/synthetic weights

TINY = dict(d_model=32, d_ff=96, n_layers=3, batch=4, seq_len=16)
DEFAULT_SEED = 0xC0A1A


def mix(seed, salt):
    return (seed ^ ((salt * GOLDEN_RATIO) & MASK)) & MASK


def tiny_l1_wq():
    """synthetic_weights(tiny, DEFAULT_SEED).matrix("l1.wq")."""
    spec_salt = TINY["d_model"] | (TINY["n_layers"] << 16)
    seed = mix(DEFAULT_SEED, spec_salt)
    # per-layer mat() calls bump salt from 16: l0 takes 17..22, so l1.wq
    # (the first mat of layer 1) is salt 23
    wq_seed = mix(seed, 23)
    inv_d = np.float32(1.0) / np.sqrt(np.float32(TINY["d_model"]))
    return randn_f32(TINY["d_model"], TINY["d_model"], wq_seed) * inv_d


# ------------------------------------------- calib/synthetic activations


def chunk_seed(layer, stream, batch):
    salt = 0xAC71
    for b in stream.encode():
        salt = (salt * 31 + b) & MASK
    salt = (salt * GOLDEN_RATIO + (layer << 32) + batch) & MASK
    return (DEFAULT_SEED ^ salt) & MASK


def near_singular_chunk(rows, width, seed):
    """synth_chunk(.., Regime::NearSingular, seed) — rank width/4 signal
    plus a 1e-2 isotropic floor, all f32 arithmetic."""
    k = max(width // 4, 1)
    g = randn_f32(rows, k, seed)
    b = randn_f32(k, width, seed ^ 0xBA5E)
    m = (g @ b).astype(np.float32)
    noise = randn_f32(rows, width, seed ^ 0x0157) * np.float32(1e-2)
    return (m + noise).astype(np.float32)


def well_conditioned_chunk(rows, width, seed):
    """synth_chunk(.., Regime::WellConditioned, seed)."""
    m = randn_f32(rows, width, seed)
    rng = Rng(seed ^ 0xC01D)
    scales = np.array(
        [np.float32(0.7 + 0.8 * rng.uniform()) for _ in range(width)], dtype=np.float32
    )
    return (m * scales[None, :]).astype(np.float32)


def spiked_chunk(rows, width, seed):
    """synth_chunk(.., Regime::Spiked, seed) — four-decade column decay."""
    m = randn_f32(rows, width, seed)
    j = np.arange(width, dtype=np.float32)
    exponent = (-(np.float32(4.0) * j) / np.float32(width)).astype(np.float32)
    sigma = (np.float32(100.0) * np.power(np.float32(10.0), exponent)).astype(np.float32)
    return (m * sigma[None, :]).astype(np.float32)


CHUNK_FOR_REGIME = {
    0: well_conditioned_chunk,  # regime_for_layer: layer % 3 == 0
    1: near_singular_chunk,
    2: spiked_chunk,
}


def capture_wq_xt(layer, batches):
    """Env::capture_xt("tiny", "l{layer}.wq", batches) on the host route:
    the layer's "attn" stream chunks stacked over batch indices."""
    rows = TINY["batch"] * TINY["seq_len"]
    width = TINY["d_model"]  # "attn" stream width
    gen = CHUNK_FOR_REGIME[layer % 3]
    chunks = [gen(rows, width, chunk_seed(layer, "attn", b)) for b in range(batches)]
    return np.vstack(chunks).astype(np.float32)


# ------------------------------------------------------- fig1 machinery


def spectral_norm(a, iters=60):
    """tensor::ops::spectral_norm — fixed-start power iteration in f64."""
    a = a.astype(np.float64)
    n = a.shape[1]
    if n == 0 or a.shape[0] == 0:
        return 0.0
    v = np.array([1.0 + math.sin(i * 0.7) for i in range(n)])
    norm = 0.0
    for _ in range(iters):
        w = a @ v
        v2 = a.T @ w
        norm = math.sqrt(float(v2 @ v2))
        if norm == 0.0:
            return 0.0
        v = v2 / norm
    return math.sqrt(norm)


def qr_r(x):
    """qr_r_square of a tall (rows × n) matrix → n × n R (sign-free use)."""
    return np.linalg.qr(x, mode="r")


def coala_factors(w, r):
    """coala_factorize: SVD(W·Rᵀ) → (U, P = UᵀW), in w's dtype."""
    target = (w @ r.T).astype(w.dtype)
    u, _s, _vt = np.linalg.svd(target)
    u = u.astype(w.dtype)
    p = (u.T @ w).astype(w.dtype)
    return u, p


def fig2_sigma_values():
    """Per-layer (σ_max, σ_min) of X for l{0,1,2}.wq — σ(R) = σ(X),
    computed in f64 like the fig2 driver (2 batches in fast mode)."""
    out = []
    for layer in range(TINY["n_layers"]):
        xt = capture_wq_xt(layer, batches=2)
        s = np.linalg.svd(xt.astype(np.float64), compute_uv=False)
        out.extend([float(s[0]), float(s[-1])])
    return out


def fig1_coala_errors():
    xt = capture_wq_xt(1, batches=2)  # COALA_REPRO_FAST=1 → 2 batches
    w = tiny_l1_wq()

    w64 = w.astype(np.float64)
    r64 = qr_r(xt.astype(np.float64))
    u64, p64 = coala_factors(w64, r64)

    r32 = qr_r(xt)  # float32 QR
    u32, p32 = coala_factors(w, r32)

    max_rank = min(w.shape)
    ranks = [r for r in [1, 2, 4, 8, 16, 32, 64, 96, 128, 160, 184] if r <= max_rank]
    errs = []
    for r in ranks:
        wref = u64[:, :r] @ p64[:r, :]
        wr32 = (u32[:, :r] @ p32[:r, :]).astype(np.float32).astype(np.float64)
        e = spectral_norm(wr32 - wref) / max(spectral_norm(wref), 1e-300)
        errs.append(e)
    return ranks, errs


# -------------------------------------------------------- Example G.1


def g1_exact_values():
    out = []
    for name, eps_p in [
        ("fp16", 9.765625e-4),
        ("bf16", 7.8125e-3),
        ("fp32", float(np.finfo(np.float32).eps)),
    ]:
        s = np.sqrt(np.float32(eps_p / 2.0))
        x = np.array([[1.0, 1.0], [0.0, float(s)]], dtype=np.float32)
        sv = np.linalg.svd(x.astype(np.float64), compute_uv=False)
        out.append((name, float(sv[-1])))
    return out


def main():
    ranks, errs = fig1_coala_errors()
    print("fig1 COALA(QR,f32) vs fp64 reference:")
    for r, e in zip(ranks, errs):
        print(f"  rank {r:>3}: {e:.3e}")
    # the Rust test's claims on these values — sanity-check the port
    small = sum(1 for e in errs if e < 0.1)
    assert small * 2 >= len(errs), f"claims violated: {errs}"
    assert errs[-1] < 0.05, f"full-rank error too big: {errs[-1]}"

    fig2 = fig2_sigma_values()
    print("fig2 per-layer (σ_max, σ_min):")
    for layer in range(TINY["n_layers"]):
        print(f"  layer {layer}: {fig2[2 * layer]:.6e} / {fig2[2 * layer + 1]:.6e}")
    # the fig2 claims: layer 1 (near-singular) is ≫ worse conditioned
    cond = [fig2[2 * l] / max(fig2[2 * l + 1], 1e-300) for l in range(3)]
    assert cond[1] > 10.0 * cond[0], f"regime claims violated: {cond}"

    g1 = g1_exact_values()
    print("g1 exact σ_min:")
    for name, v in g1:
        print(f"  {name}: {v:.6e}")

    snapshot = {
        "fig1_coala": errs,
        "fig2_sigma": fig2,
        "g1_exact": [v for _, v in g1],
    }
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.normpath(
        os.path.join(here, "..", "..", "rust", "tests", "golden", "stability.json")
    )
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot, f)
    print(f"[{path} written]")


if __name__ == "__main__":
    main()
