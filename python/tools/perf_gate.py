#!/usr/bin/env python3
"""Perf-regression gate over the crate's BENCH_*.json dumps.

The Rust benches (`cargo bench --bench kernels` / `--bench pipeline`)
dump per-target stats plus speedup ratios and per-stage breakdowns.
This tool diffs a fresh dump against the committed baseline in
`rust/benches/baseline/` and fails CI on a regression.

Baseline files wrap the raw BENCH json with provenance:

    {"source": "bootstrap" | "native", "bench": {...}}

* ``bootstrap`` — committed without trusted absolute timings (the
  growth containers have no Rust toolchain).  Gated invariants are
  machine-independent: every baseline record must still exist
  (coverage), every speedup ratio must stay above
  ``baseline_speedup / threshold`` (e.g. the packed GEMM must not
  fall behind the naive loop), and a baseline record carrying
  ``peak_bytes`` (the tracking-allocator watermark the pipeline
  bench dumps) must keep the field in the current run
  (memory coverage — the observability must not silently rot).
* ``native`` — produced by ``perf_gate.py update`` from a real run on
  the CI machine class.  Adds absolute gating: a target whose
  ``mean_s`` exceeds ``baseline * threshold`` (default +30 %) fails,
  with a per-stage diff when both records carry a ``stages`` map.

Modes:
    check    --bench B.json [--bench ...] --baseline-dir DIR [--threshold X]
    update   --bench B.json [--bench ...] --baseline-dir DIR [--source native]
    selftest (no IO: proves the gate rejects an injected 2x slowdown)
"""

import argparse
import json
import sys
from pathlib import Path

DEFAULT_THRESHOLD = 1.30
# timings below this are indistinguishable from scheduler noise on
# shared CI runners — never gated, never flagged in stage diffs
MIN_GATED_MEAN_S = 1e-4


def walk_records(bench):
    """Yield every ``{"name": ...}`` object in a BENCH dump's arrays.

    Entries that are not dicts, lack a ``name``, or carry a foreign
    ``kind`` tag (telemetry records — ``run``/``stage``/``health``/
    ``counter`` — that a future dump may interleave) are skipped, never
    fatal, mirroring how ``coala report`` tolerates unknown kinds.
    """
    for section, val in sorted(bench.items()):
        if isinstance(val, list):
            for rec in val:
                if not isinstance(rec, dict) or "name" not in rec:
                    continue
                if "kind" in rec and rec["kind"] != "bench":
                    continue
                yield section, rec


def index(bench):
    return {rec["name"]: rec for _, rec in walk_records(bench)}


def stage_diff(base_rec, cur_rec, threshold):
    """Per-stage lines for a regressed target (empty without stages)."""
    bs, cs = base_rec.get("stages"), cur_rec.get("stages")
    if not (isinstance(bs, dict) and isinstance(cs, dict)):
        return []
    lines = []
    for stage in sorted(set(bs) | set(cs)):
        b, c = float(bs.get(stage, 0.0)), float(cs.get(stage, 0.0))
        if b >= MIN_GATED_MEAN_S:
            ratio, regressed = c / b, c / b > threshold
        else:
            ratio, regressed = float("inf"), c >= MIN_GATED_MEAN_S * 10
        mark = "  <-- regressed" if regressed else ""
        lines.append(f"    stage {stage:<18} {b:9.4f}s -> {c:9.4f}s ({ratio:6.2f}x){mark}")
    return lines


def compare(bench, baseline, threshold):
    """Diff one BENCH dump against its baseline.

    Returns ``(failures, ok_lines)`` — ``failures`` non-empty means the
    gate must exit non-zero.
    """
    source = baseline.get("source", "bootstrap")
    base, cur = index(baseline.get("bench", {})), index(bench)
    failures, ok = [], []

    for name in sorted(set(base) - set(cur)):
        failures.append(f"coverage: baseline target `{name}` missing from the current run")

    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        if "peak_bytes" in b and "peak_bytes" not in c:
            failures.append(
                f"mem-coverage: baseline target `{name}` records `peak_bytes` "
                f"but the current run dropped the field"
            )
        if "speedup" in b and "speedup" in c:
            floor = float(b["speedup"]) / threshold
            if float(c["speedup"]) < floor:
                failures.append(
                    f"ratio: `{name}` speedup {c['speedup']:.2f}x fell below "
                    f"{floor:.2f}x (baseline {b['speedup']:.2f}x / threshold {threshold:.2f})"
                )
            else:
                ok.append(f"ratio  {name}: {c['speedup']:.2f}x (floor {floor:.2f}x)")
        if source == "native" and float(b.get("mean_s", 0.0)) >= MIN_GATED_MEAN_S:
            limit = float(b["mean_s"]) * threshold
            mean = float(c.get("mean_s", 0.0))
            if mean > limit:
                msg = [
                    f"timing: `{name}` {mean:.4f}s exceeds {limit:.4f}s "
                    f"({mean / float(b['mean_s']):.2f}x of baseline {b['mean_s']:.4f}s)"
                ]
                msg.extend(stage_diff(b, c, threshold))
                failures.append("\n".join(msg))
            else:
                ok.append(f"timing {name}: {mean:.4f}s (limit {limit:.4f}s)")
    return failures, ok


def load(path):
    with open(path) as f:
        return json.load(f)


def cmd_check(args):
    status = 0
    for bench_path in args.bench:
        bench_path = Path(bench_path)
        base_path = Path(args.baseline_dir) / bench_path.name
        if not base_path.exists():
            print(f"perf_gate: no baseline at {base_path} — run `update` first", file=sys.stderr)
            status = 1
            continue
        baseline = load(base_path)
        failures, ok = compare(load(bench_path), baseline, args.threshold)
        src = baseline.get("source", "bootstrap")
        print(f"== {bench_path.name} vs {base_path} (source={src}) ==")
        for line in ok:
            print(f"  ok {line}")
        if src == "bootstrap":
            print("  (bootstrap baseline: absolute timings not gated; ratios + coverage only)")
        for f in failures:
            print(f"REGRESSION {f}", file=sys.stderr)
        if failures:
            status = 1
    if status == 0:
        print("perf gate: no regressions")
    return status


def cmd_update(args):
    out_dir = Path(args.baseline_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for bench_path in args.bench:
        bench_path = Path(bench_path)
        wrapped = {"source": args.source, "bench": load(bench_path)}
        out = out_dir / bench_path.name
        out.write_text(json.dumps(wrapped, indent=1) + "\n")
        print(f"baseline written: {out} (source={args.source})")
    return 0


def cmd_selftest(_args):
    """Prove the gate's behavior on synthetic dumps, no files needed."""

    def synth(mean, speedup, peak=None):
        rec = {
            "name": "gemm/packed 256x192x192",
            "mean_s": mean,
            "stages": {"capture": mean * 0.25, "factorize": mean * 0.75},
        }
        if peak is not None:
            rec["peak_bytes"] = peak
        return {
            "kernels": [rec],
            "ratios": [{"name": "gemm packed/naive 256x192x192", "speedup": speedup}],
        }

    t = DEFAULT_THRESHOLD
    native = {"source": "native", "bench": synth(0.1, 2.0)}
    bootstrap = {"source": "bootstrap", "bench": synth(0.1, 2.0)}

    f, _ = compare(synth(0.1, 2.0), native, t)
    assert not f, f"identical run must pass: {f}"

    f, _ = compare(synth(0.2, 2.0), native, t)
    assert any(x.startswith("timing") for x in f), f"2x slowdown must fail: {f}"
    assert any("stage" in x for x in f), "the failure must carry a per-stage diff"
    assert any("factorize" in x and "regressed" in x for x in f), f"stage blame missing: {f}"

    f, _ = compare(synth(0.9, 2.0), bootstrap, t)
    assert not f, f"bootstrap baseline must not gate absolute timings: {f}"

    f, _ = compare(synth(0.1, 1.0), bootstrap, t)
    assert any(x.startswith("ratio") for x in f), f"halved speedup must fail: {f}"

    f, _ = compare({"kernels": [], "ratios": []}, bootstrap, t)
    assert len(f) == 2 and all(x.startswith("coverage") for x in f), f"coverage loss: {f}"

    # memory coverage: once a baseline records peak_bytes, a dump that
    # drops the field must fail; gaining the field before the baseline
    # has it must pass (that's how the field rolls out)
    with_mem = {"source": "bootstrap", "bench": synth(0.1, 2.0, peak=1 << 20)}
    f, _ = compare(synth(0.1, 2.0), with_mem, t)
    assert any(x.startswith("mem-coverage") for x in f), f"dropped peak_bytes must fail: {f}"
    f, _ = compare(synth(0.1, 2.0, peak=2 << 20), with_mem, t)
    assert not f, f"peak_bytes present on both sides must pass: {f}"
    f, _ = compare(synth(0.1, 2.0, peak=1 << 20), bootstrap, t)
    assert not f, f"a new peak_bytes field without a baseline must pass: {f}"

    # unknown record kinds (telemetry lines a future dump interleaves)
    # must be tolerated on both sides of the diff, never gated
    noisy = synth(0.1, 2.0)
    noisy["kernels"] = noisy["kernels"] + [
        {"kind": "run", "run_id": "deadbeef", "source": "tiny:Host:seed0:b4"},
        {"kind": "health", "probe": "svd", "name": "not-a-bench-target"},
        "torn line",
        7,
    ]
    f, _ = compare(noisy, native, t)
    assert not f, f"unknown record kinds in the current dump must be skipped: {f}"
    f, _ = compare(synth(0.1, 2.0), {"source": "native", "bench": noisy}, t)
    assert not f, f"unknown record kinds in the baseline must be skipped: {f}"

    print(
        "perf_gate selftest: pass / 2x-slowdown / bootstrap / ratio / coverage"
        " / mem-coverage / unknown-kinds all behave"
    )
    return 0


def main():
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="mode", required=True)

    check = sub.add_parser("check", help="diff BENCH dumps against the committed baseline")
    update = sub.add_parser("update", help="replace the baseline with the current dumps")
    sub.add_parser("selftest", help="verify the gate rejects an injected 2x slowdown")

    for s in (check, update):
        s.add_argument("--bench", action="append", required=True, help="BENCH_*.json (repeatable)")
        s.add_argument("--baseline-dir", required=True)
    check.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    update.add_argument("--source", choices=["native", "bootstrap"], default="native")

    args = p.parse_args()
    return {"check": cmd_check, "update": cmd_update, "selftest": cmd_selftest}[args.mode](args)


if __name__ == "__main__":
    sys.exit(main())
