//! Bench: Fig. 3 (left) — QR of Xᵀ vs Gram+eig as the column count grows
//! (host linalg; the crossover claim of §4.2).

use coala::linalg::{eigh, qr_r_square};
use coala::tensor::ops::gram_t;
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts};

fn main() {
    let rows = 192usize;
    let opts = BenchOpts { max_iters: 5, min_iters: 2, ..BenchOpts::default() }
        .from_env()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1)
        });
    println!("== Fig.3 left bench: S with SSᵀ = XXᵀ, X ∈ R^{rows}×k ==");
    for k in [256usize, 512, 1024, 2048, 4096, 8192] {
        let x: Matrix<f32> = Matrix::randn(rows, k, 7);
        let xt = x.transpose();
        bench(&format!("qr/k={k}"), &opts, || {
            std::hint::black_box(qr_r_square(&xt).unwrap());
        });
        bench(&format!("gram+eig/k={k}"), &opts, || {
            let g = gram_t(&xt);
            std::hint::black_box(eigh(&g, 30).unwrap());
        });
    }
}
