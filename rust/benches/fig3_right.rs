//! Bench: Fig. 3 (right) — streamed TSQR chunk-size sweep vs chunked
//! Gram accumulation at fixed total width.

use coala::linalg::{eigh, tsqr_sequential, tsqr_tree};
use coala::tensor::ops::gram_t;
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts};

fn main() {
    let rows = 192usize;
    let total_k = 16384usize;
    let opts = BenchOpts::heavy().from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1)
    });
    println!("== Fig.3 right bench: X ∈ R^{rows}×{total_k} in chunks ==");
    for c in [512usize, 1024, 2048, 4096] {
        let chunks: Vec<Matrix<f32>> =
            (0..total_k / c).map(|i| Matrix::randn(c, rows, i as u64)).collect();
        bench(&format!("tsqr-seq/chunk={c}"), &opts, || {
            std::hint::black_box(tsqr_sequential(&chunks).unwrap());
        });
        bench(&format!("tsqr-tree4/chunk={c}"), &opts, || {
            std::hint::black_box(tsqr_tree(&chunks, 4).unwrap());
        });
        bench(&format!("gram-chunked/chunk={c}"), &opts, || {
            let mut g = Matrix::<f32>::zeros(rows, rows);
            for ch in &chunks {
                g = g.add(&gram_t(ch)).unwrap();
            }
            std::hint::black_box(eigh(&g, 30).unwrap());
        });
    }
}
