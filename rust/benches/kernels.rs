//! Bench: the raw-speed host linalg kernels — packed GEMM vs the naive
//! ikj reference, compact-WY blocked QR vs the unblocked column sweep,
//! the blocked round-robin Jacobi SVD vs the cyclic-sweep reference,
//! the streaming-TSQR fold, and the sketch accumulator (Gaussian GEMM
//! and SRHT) vs the exact TSQR fold — plus the PJRT-executed
//! factorization artifacts when a device is available.
//!
//! Size sweeps cover the `large` synthetic config's hot shapes
//! (≥ 256×192).  Dumps `BENCH_kernels.json` with the per-kernel stats
//! *and* the blocked-vs-naive / sketch-vs-exact / srht-vs-gaussian
//! speedup ratios, so the perf trajectory has committed baselines.
//! `COALA_BENCH_FAST=1` shrinks the iteration budget for smoke runs.

use coala::calib::accumulate::{
    make_accumulator, AccumBackend, AccumKind, CalibAccumulator, CalibState,
};
use coala::linalg::{householder_qr, jacobi_svd, jacobi_svd_cyclic, qr_r_square, TsqrFolder};
use coala::runtime::{ops, Executor};
use coala::tensor::lowp::Precision;
use coala::tensor::ops::matmul;
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts, Stats};
use coala::util::json::Json;

fn record(stats: &Stats) -> Json {
    Json::obj(vec![
        ("name", Json::Str(stats.name.clone())),
        ("iters", Json::Num(stats.iters as f64)),
        ("mean_s", Json::Num(stats.mean_s)),
        ("std_s", Json::Num(stats.std_s)),
        ("min_s", Json::Num(stats.min_s)),
    ])
}

/// A speedup entry: how many times faster `fast` ran than `slow`
/// (by mean wall time).
fn ratio(name: &str, slow: &Stats, fast: &Stats) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("speedup", Json::Num(slow.mean_s / fast.mean_s.max(1e-12))),
        ("slow_mean_s", Json::Num(slow.mean_s)),
        ("fast_mean_s", Json::Num(fast.mean_s)),
    ])
}

/// The pre-PR GEMM: plain single-threaded ikj with no packing — the
/// baseline the packed microkernel is measured against.
fn matmul_naive(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for l in 0..k {
            let av = a.get(i, l);
            let row = &b.data[l * n..(l + 1) * n];
            let dst = &mut out.data[i * n..(i + 1) * n];
            for (d, &bv) in dst.iter_mut().zip(row) {
                *d += av * bv;
            }
        }
    }
    out
}

/// The pre-PR QR: unblocked column-by-column Householder sweep (the
/// exact algorithm `householder_qr_r` ran before panel factorization).
fn qr_r_unblocked(a: &Matrix<f32>) -> Matrix<f32> {
    let (m, n) = (a.rows, a.cols);
    let mut acc = a.clone();
    let steps = m.min(n);
    let mut v = vec![0.0f32; m];
    for j in 0..steps {
        let mut norm = 0.0f32;
        for i in j..m {
            let x = acc.get(i, j);
            norm += x * x;
        }
        let norm = norm.sqrt();
        if norm <= f32::EPSILON {
            continue;
        }
        let x0 = acc.get(j, j);
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut vnorm2 = 0.0f32;
        for i in j..m {
            let vi = if i == j { acc.get(i, j) - alpha } else { acc.get(i, j) };
            v[i] = vi;
            vnorm2 += vi * vi;
        }
        if vnorm2 <= f32::EPSILON {
            continue;
        }
        let beta = 2.0 / vnorm2;
        for c in j..n {
            let mut dot = 0.0f32;
            for i in j..m {
                dot += v[i] * acc.get(i, c);
            }
            let s = beta * dot;
            for i in j..m {
                let cur = acc.get(i, c);
                acc.set(i, c, cur - s * v[i]);
            }
        }
    }
    acc.slice(0, steps, 0, n)
}

fn main() {
    // strict env parsing: a bad COALA_BENCH_FAST value must kill the
    // bench loudly, not silently run the heavy profile
    let opts = BenchOpts::default().from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1)
    });
    let mut gemm = Vec::new();
    let mut qr = Vec::new();
    let mut svd = Vec::new();
    let mut accum = Vec::new();
    let mut ratios = Vec::new();

    // ---- GEMM sweep: packed microkernel vs naive ikj ---------------------
    // shapes bracket the large-config hot paths: trailing updates inside
    // blocked QR (tall-thin times panel) up to the ≥256×192 criterion.
    println!("== GEMM: packed microkernel vs naive ikj ==");
    for (m, k, n) in [(128usize, 128usize, 128usize), (256, 192, 192), (512, 256, 256)] {
        let a = Matrix::<f32>::randn(m, k, 1);
        let b = Matrix::<f32>::randn(k, n, 2);
        let s_naive = bench(&format!("gemm/naive {m}x{k}x{n}"), &opts, || {
            std::hint::black_box(matmul_naive(&a, &b));
        });
        let s_packed = bench(&format!("gemm/packed {m}x{k}x{n}"), &opts, || {
            std::hint::black_box(matmul(&a, &b).unwrap());
        });
        ratios.push(ratio(&format!("gemm packed/naive {m}x{k}x{n}"), &s_naive, &s_packed));
        gemm.push(record(&s_naive));
        gemm.push(record(&s_packed));
    }

    // ---- QR sweep: compact-WY blocked vs unblocked column sweep ----------
    println!("== QR: compact-WY blocked vs unblocked ==");
    for (m, n) in [(256usize, 192usize), (512, 192), (512, 256)] {
        let a = Matrix::<f32>::randn(m, n, 3);
        let s_unblocked = bench(&format!("qr/unblocked {m}x{n}"), &opts, || {
            std::hint::black_box(qr_r_unblocked(&a));
        });
        let s_blocked = bench(&format!("qr/blocked {m}x{n}"), &opts, || {
            std::hint::black_box(coala::linalg::householder_qr_r(&a));
        });
        ratios.push(ratio(&format!("qr blocked/unblocked {m}x{n}"), &s_unblocked, &s_blocked));
        qr.push(record(&s_unblocked));
        qr.push(record(&s_blocked));
    }
    {
        // explicit-Q path (factorize consumers)
        let a = Matrix::<f32>::randn(256, 192, 4);
        qr.push(record(&bench("qr/blocked explicit-Q 256x192", &opts, || {
            std::hint::black_box(householder_qr(&a).unwrap());
        })));
    }

    // ---- SVD sweep (context for where factorize time goes) ---------------
    println!("== SVD: one-sided Jacobi ==");
    for n in [64usize, 128, 192] {
        let a = Matrix::<f32>::randn(n, n, 5);
        svd.push(record(&bench(&format!("svd/jacobi {n}x{n}"), &opts, || {
            std::hint::black_box(jacobi_svd(&a, 12).unwrap());
        })));
    }
    // tall shapes: the blocked path (QR precondition, then round-robin
    // Jacobi with cached norms on the small square R) vs the pre-PR
    // cyclic sweep that rotates the full-height columns every pair
    println!("== SVD: blocked vs naive cyclic on tall inputs ==");
    for (m, n) in [(256usize, 64usize), (512, 96)] {
        let a = Matrix::<f32>::randn(m, n, 6);
        let s_naive = bench(&format!("svd/naive {m}x{n}"), &opts, || {
            std::hint::black_box(jacobi_svd_cyclic(&a, 12).unwrap());
        });
        let s_blocked = bench(&format!("svd/blocked {m}x{n}"), &opts, || {
            std::hint::black_box(jacobi_svd(&a, 12).unwrap());
        });
        ratios.push(ratio(&format!("svd blocked/naive {m}x{n}"), &s_naive, &s_blocked));
        svd.push(record(&s_naive));
        svd.push(record(&s_blocked));
    }

    // ---- accumulators: sketch fold vs exact TSQR fold --------------------
    // per-batch cost at a large-config-like width; the sketch folds
    // O(s·c·n) instead of the exact fold's O((n+c)·n²)
    println!("== accumulate: sketch vs exact TSQR ==");
    let (n, c, folds) = (192usize, 512usize, 8usize);
    let chunks: Vec<Matrix<f32>> = (0..folds).map(|i| Matrix::randn(c, n, i as u64)).collect();
    let fold_all = |kind: AccumKind| {
        let mut acc = make_accumulator(kind, n, AccumBackend::Host, Precision::F32).unwrap();
        for ch in &chunks {
            acc.fold_chunk(ch).unwrap();
        }
        acc.finish()
    };
    let s_exact = bench(&format!("accum/exact-tsqr {n}x{c}x{folds}"), &opts, || {
        std::hint::black_box(fold_all(AccumKind::RFactor));
    });
    let s_sketch = bench(&format!("accum/sketch {n}x{c}x{folds}"), &opts, || {
        std::hint::black_box(fold_all(AccumKind::Sketch));
    });
    ratios.push(ratio(&format!("accum sketch/exact {n}x{c}x{folds}"), &s_exact, &s_sketch));
    accum.push(record(&s_exact));
    accum.push(record(&s_sketch));
    // the SRHT variant of the same fold: sign flip + Walsh–Hadamard +
    // row sample is O(c·log c) per column vs the Gaussian GEMM's O(s·c).
    // set_var is safe here: harness = false, single-threaded main.
    std::env::set_var("COALA_SKETCH_KIND", "srht");
    let s_srht = bench(&format!("accum/sketch-srht {n}x{c}x{folds}"), &opts, || {
        std::hint::black_box(fold_all(AccumKind::Sketch));
    });
    std::env::remove_var("COALA_SKETCH_KIND");
    ratios.push(ratio(&format!("sketch srht/gaussian {n}x{c}x{folds}"), &s_sketch, &s_srht));
    accum.push(record(&s_srht));
    // the one-off QR-of-sketch that turns Y into the approximate R
    if let CalibState::Sketch { y, .. } = fold_all(AccumKind::Sketch) {
        accum.push(record(&bench("accum/sketch qr-of-Y", &opts, || {
            std::hint::black_box(qr_r_square(&y).unwrap());
        })));
    }
    // streaming folder with scratch reuse (the exact route's fast path)
    accum.push(record(&bench(&format!("accum/tsqr-folder {n}x{c}x{folds}"), &opts, || {
        let mut folder = TsqrFolder::with_chunk_capacity(n, c);
        for ch in &chunks {
            folder.fold(ch).unwrap();
        }
        std::hint::black_box(folder.finish());
    })));

    // ---- artifact op benches (need artifacts/ + the pjrt feature) --------
    let mut device = Vec::new();
    if coala::runtime::device_available("artifacts") {
        let ex = Executor::new("artifacts").unwrap();
        let cfg = ex.manifest.config("tiny").unwrap().clone();
        let (dn, df, dc) = (cfg.d_model, cfg.d_ff, cfg.chunk_cols());
        println!("== artifact op benches (tiny shapes) ==");
        let chunk_n = Matrix::<f32>::randn(dc, dn, 1);
        let chunk_f = Matrix::<f32>::randn(dc, df, 2);
        let r0n = Matrix::<f32>::zeros(dn, dn);
        let r0f = Matrix::<f32>::zeros(df, df);
        device.push(record(&bench(&format!("pjrt/tsqr_step {dn}x{dc}"), &opts, || {
            std::hint::black_box(ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap());
        })));
        device.push(record(&bench(&format!("pjrt/tsqr_step {df}x{dc}"), &opts, || {
            std::hint::black_box(ops::tsqr_step(&ex, &r0f, &chunk_f).unwrap());
        })));
        let w = Matrix::<f32>::randn(dn, dn, 3);
        let r = ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap();
        device.push(record(&bench(&format!("pjrt/factorize {dn}x{dn}"), &opts, || {
            std::hint::black_box(ops::factorize(&ex, &w, &r).unwrap());
        })));
        device.push(record(&bench(&format!("pjrt/factorize_reg {dn}x{dn}"), &opts, || {
            std::hint::black_box(ops::factorize_reg(&ex, &w, &r, 1e-2).unwrap());
        })));
        let g = ops::gram_update(&ex, &Matrix::zeros(dn, dn), &chunk_n).unwrap();
        device.push(record(&bench(&format!("pjrt/svdllm {dn}x{dn}"), &opts, || {
            std::hint::black_box(ops::svdllm(&ex, &w, &g).unwrap());
        })));
        device.push(record(&bench(&format!("pjrt/svdllm2 {dn}x{dn}"), &opts, || {
            std::hint::black_box(ops::svdllm2(&ex, &w, &g).unwrap());
        })));
    } else {
        println!("kernels bench: no artifacts or no pjrt feature — skipping PJRT op benches");
    }

    let out = Json::obj(vec![
        ("gemm", Json::Arr(gemm)),
        ("qr", Json::Arr(qr)),
        ("svd", Json::Arr(svd)),
        ("accum", Json::Arr(accum)),
        ("ratios", Json::Arr(ratios)),
        ("device", Json::Arr(device)),
    ]);
    std::fs::write("BENCH_kernels.json", out.dump()).unwrap();
    println!("[BENCH_kernels.json written]");
}
