//! Bench: the PJRT-executed factorization artifacts (the request-path
//! hot ops) + host-linalg equivalents for the speedup ratio.

use coala::linalg::qr_r_square;
use coala::runtime::{ops, Executor};
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("kernels bench: artifacts/ missing — run `make artifacts` first");
        return;
    }
    let ex = Executor::new("artifacts").unwrap();
    let cfg = ex.manifest.config("tiny").unwrap().clone();
    let (n, f, c) = (cfg.d_model, cfg.d_ff, cfg.chunk_cols());
    let opts = BenchOpts::default().from_env();
    println!("== artifact op benches (tiny shapes) ==");

    let chunk_n = Matrix::<f32>::randn(c, n, 1);
    let chunk_f = Matrix::<f32>::randn(c, f, 2);
    let r0n = Matrix::<f32>::zeros(n, n);
    let r0f = Matrix::<f32>::zeros(f, f);
    bench(&format!("pjrt/tsqr_step {n}x{c}"), &opts, || {
        std::hint::black_box(ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap());
    });
    bench(&format!("pjrt/tsqr_step {f}x{c}"), &opts, || {
        std::hint::black_box(ops::tsqr_step(&ex, &r0f, &chunk_f).unwrap());
    });
    bench(&format!("host/qr {c}x{n}"), &opts, || {
        std::hint::black_box(qr_r_square(&chunk_n).unwrap());
    });

    let w = Matrix::<f32>::randn(n, n, 3);
    let r = ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap();
    bench(&format!("pjrt/factorize {n}x{n}"), &opts, || {
        std::hint::black_box(ops::factorize(&ex, &w, &r).unwrap());
    });
    bench(&format!("pjrt/factorize_reg {n}x{n}"), &opts, || {
        std::hint::black_box(ops::factorize_reg(&ex, &w, &r, 1e-2).unwrap());
    });
    let g = ops::gram_update(&ex, &Matrix::zeros(n, n), &chunk_n).unwrap();
    bench(&format!("pjrt/svdllm {n}x{n}"), &opts, || {
        std::hint::black_box(ops::svdllm(&ex, &w, &g).unwrap());
    });
    bench(&format!("pjrt/svdllm2 {n}x{n}"), &opts, || {
        std::hint::black_box(ops::svdllm2(&ex, &w, &g).unwrap());
    });
    bench(&format!("host/coala_factorize {n}x{n}"), &opts, || {
        std::hint::black_box(coala::coala::coala_factorize(&w, &r, 12).unwrap());
    });
}
