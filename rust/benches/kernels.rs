//! Bench: the PJRT-executed factorization artifacts (the request-path
//! hot ops) + host-linalg equivalents for the speedup ratio.
//!
//! The host section needs no artifacts — in particular it measures the
//! streaming-TSQR fold with the reusable scratch buffer
//! (`linalg::tsqr::TsqrFolder`) against the naive re-stacking fold it
//! replaced (`[R ; chunk]` vstack + fresh QR per fold).

use coala::linalg::{qr_r_square, TsqrFolder};
use coala::runtime::{ops, Executor};
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts};

/// The pre-refactor fold: allocate the stacked matrix and a QR working
/// copy on every chunk.
fn tsqr_naive(chunks: &[Matrix<f32>]) -> Matrix<f32> {
    let n = chunks[0].cols;
    let mut r = Matrix::zeros(n, n);
    for c in chunks {
        r = qr_r_square(&r.vstack(c).unwrap()).unwrap();
    }
    r
}

fn host_benches(opts: &BenchOpts) {
    println!("== host linalg benches (no artifacts needed) ==");
    let (n, c, folds) = (192usize, 512usize, 8usize);
    let chunks: Vec<Matrix<f32>> = (0..folds).map(|i| Matrix::randn(c, n, i as u64)).collect();

    bench(&format!("host/tsqr_fold naive {n}x{c}x{folds}"), opts, || {
        std::hint::black_box(tsqr_naive(&chunks));
    });
    bench(&format!("host/tsqr_fold scratch {n}x{c}x{folds}"), opts, || {
        let mut folder = TsqrFolder::with_chunk_capacity(n, c);
        for ch in &chunks {
            folder.fold(ch).unwrap();
        }
        std::hint::black_box(folder.finish());
    });
    bench(&format!("host/qr {c}x{n}"), opts, || {
        std::hint::black_box(qr_r_square(&chunks[0]).unwrap());
    });

    let w = Matrix::<f32>::randn(n, n, 3);
    let r = tsqr_naive(&chunks[..1]);
    bench(&format!("host/coala_factorize {n}x{n}"), opts, || {
        std::hint::black_box(coala::coala::coala_factorize(&w, &r, 12).unwrap());
    });
}

fn main() {
    let opts = BenchOpts::default().from_env();
    host_benches(&opts);

    if !coala::runtime::device_available("artifacts") {
        println!("kernels bench: no artifacts or no pjrt feature — skipping PJRT op benches");
        return;
    }
    let ex = Executor::new("artifacts").unwrap();
    let cfg = ex.manifest.config("tiny").unwrap().clone();
    let (n, f, c) = (cfg.d_model, cfg.d_ff, cfg.chunk_cols());
    println!("== artifact op benches (tiny shapes) ==");

    let chunk_n = Matrix::<f32>::randn(c, n, 1);
    let chunk_f = Matrix::<f32>::randn(c, f, 2);
    let r0n = Matrix::<f32>::zeros(n, n);
    let r0f = Matrix::<f32>::zeros(f, f);
    bench(&format!("pjrt/tsqr_step {n}x{c}"), &opts, || {
        std::hint::black_box(ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap());
    });
    bench(&format!("pjrt/tsqr_step {f}x{c}"), &opts, || {
        std::hint::black_box(ops::tsqr_step(&ex, &r0f, &chunk_f).unwrap());
    });

    let w = Matrix::<f32>::randn(n, n, 3);
    let r = ops::tsqr_step(&ex, &r0n, &chunk_n).unwrap();
    bench(&format!("pjrt/factorize {n}x{n}"), &opts, || {
        std::hint::black_box(ops::factorize(&ex, &w, &r).unwrap());
    });
    bench(&format!("pjrt/factorize_reg {n}x{n}"), &opts, || {
        std::hint::black_box(ops::factorize_reg(&ex, &w, &r, 1e-2).unwrap());
    });
    let g = ops::gram_update(&ex, &Matrix::zeros(n, n), &chunk_n).unwrap();
    bench(&format!("pjrt/svdllm {n}x{n}"), &opts, || {
        std::hint::black_box(ops::svdllm(&ex, &w, &g).unwrap());
    });
    bench(&format!("pjrt/svdllm2 {n}x{n}"), &opts, || {
        std::hint::black_box(ops::svdllm2(&ex, &w, &g).unwrap());
    });
}
