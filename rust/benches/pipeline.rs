//! Bench: the source-agnostic execution engine on the host route —
//! sequential vs parallel plans over worker counts, on both the `small`
//! and `large` synthetic configs — a sharded-calibration sweep over
//! shard counts (accumulate-only + state codec + canonical merge, the
//! multi-process deployment path), the host training subsystem's
//! parallel gradient accumulation, plus the artifact-backed end-to-end
//! pipeline, overlapped scheduler, and tree-TSQR when a device is
//! available.
//!
//! Dumps `BENCH_pipeline.json` (mean/std/min per target) so future PRs
//! have a perf trajectory baseline.  `COALA_BENCH_FAST=1` shrinks the
//! iteration budget for smoke runs.

use coala::calib::accumulate::AccumKind;
use coala::calib::dataset::Corpus;
use coala::calib::synthetic::SyntheticActivations;
use coala::coala::compressor::{resolve, Compressor, Route};
use coala::coordinator::scheduler::calibrate_overlapped;
use coala::coordinator::{CompressionJob, EnginePlan, Pipeline, StageTimings, TsqrTreeRunner};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::model::ModelWeights;
use coala::runtime::Executor;
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts, Stats};
use coala::util::json::Json;

fn record(stats: &Stats, workers: usize) -> Json {
    Json::obj(vec![
        ("name", Json::Str(stats.name.clone())),
        ("workers", Json::Num(workers as f64)),
        ("iters", Json::Num(stats.iters as f64)),
        ("mean_s", Json::Num(stats.mean_s)),
        ("std_s", Json::Num(stats.std_s)),
        ("min_s", Json::Num(stats.min_s)),
    ])
}

/// Same record plus the engine's per-stage busy-time breakdown (the
/// numbers the telemetry sink reports as `stage_s` events) and the
/// allocator peak over one representative run — the perf gate diffs
/// stages and checks memory coverage, not just totals.  `peak_bytes`
/// is 0 on the default build (the tracking allocator needs the
/// `telemetry` feature); the field is always present so the gate's
/// mem-coverage check can key on it.
fn record_with_stages(stats: &Stats, workers: usize, t: &StageTimings, peak_bytes: u64) -> Json {
    let mut rec = vec![
        ("name", Json::Str(stats.name.clone())),
        ("workers", Json::Num(workers as f64)),
        ("iters", Json::Num(stats.iters as f64)),
        ("mean_s", Json::Num(stats.mean_s)),
        ("std_s", Json::Num(stats.std_s)),
        ("min_s", Json::Num(stats.min_s)),
        ("peak_bytes", Json::UInt(peak_bytes)),
    ];
    rec.push((
        "stages",
        Json::obj(vec![
            ("capture", Json::Num(t.calibrate_s)),
            ("accumulate", Json::Num(t.accumulate_s)),
            ("merge_reduce", Json::Num(t.merge_s)),
            ("factorize", Json::Num(t.factorize_s)),
        ]),
    ));
    Json::obj(rec)
}

fn main() {
    // strict env parsing: a bad COALA_BENCH_FAST value must kill the
    // bench loudly, not silently run the heavy profile
    let opts = BenchOpts::heavy().from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1)
    });

    // arm the tracking allocator programmatically (no env knob needed):
    // a no-op on the default build, so `peak_bytes` is 0 there and the
    // real watermark on `--features telemetry` runs
    coala::telemetry::alloc::set_armed(true);

    // ---- host route: engine plans over worker counts (always runs) ------
    // `small` is the historical baseline; `large` (6 layers, 36
    // projections, d=64/ff=192) is big enough that the parallel
    // factorize stage and capture fan-out actually matter.
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let mut host_records = Vec::new();
    for cfg in ["small", "large"] {
        let spec = ex.manifest.config(cfg).unwrap().clone();
        let w = synthetic_weights(&spec, 1);
        let src = SyntheticActivations::new(spec.clone(), 1);
        let mut job = CompressionJob::new(cfg, resolve("coala").unwrap().method(), 0.5);
        job.calib_batches = if cfg == "large" { 8 } else { 6 };
        for workers in [1usize, 2, 4, 8] {
            let pipe = Pipeline::new(&ex, spec.clone(), &w)
                .with_route(Route::Host)
                .with_plan(EnginePlan::with_workers(workers));
            let label = if workers == 1 {
                format!("engine/host {cfg} sequential (workers=1)")
            } else {
                format!("engine/host {cfg} workers={workers}")
            };
            let stats = bench(&label, &opts, || {
                std::hint::black_box(pipe.run_with_source(&job, &src).unwrap());
            });
            // one representative run for the per-stage breakdown and
            // the allocator peak
            let mut mem = coala::telemetry::alloc::MemScope::enter();
            let t = pipe.run_with_source(&job, &src).unwrap().timings;
            let peak = mem.finish().map_or(0, |m| m.peak_bytes);
            host_records.push(record_with_stages(&stats, workers, &t, peak));
        }
    }

    // ---- sharded calibration: N × accumulate-only + codec + merge --------
    // the multi-process deployment path, measured in-process: each shard
    // accumulates its batch range, the state crosses the binary codec
    // (serialize + deserialize, as it would over a filesystem), and the
    // canonical merge reassembles the run.  shards=1 is the degenerate
    // single-shard baseline; the result is bitwise identical at every
    // shard count, so this measures pure orchestration overhead.
    let mut shard_records = Vec::new();
    {
        use coala::calib::accumulate::{AccumBackend, AccumKind};
        use coala::calib::state::ShardState;
        use coala::coordinator::{engine, ShardPlan};
        use coala::tensor::lowp::Precision;
        let spec = ex.manifest.config("small").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 1);
        let total = 8;
        for shards in [1usize, 2, 4, 8] {
            let plan = ShardPlan::new(total, shards).unwrap();
            let run_once = |t: &mut StageTimings| {
                let parts: Vec<ShardState> = (0..shards)
                    .map(|i| {
                        let st = engine::accumulate_shard(
                            &src,
                            AccumKind::RFactor,
                            plan.range(i).unwrap(),
                            AccumBackend::Host,
                            Precision::F32,
                            &EnginePlan::sequential(),
                            t,
                            None,
                            "small:host:seed1",
                        )
                        .unwrap();
                        ShardState::decode(&st.encode(), "<memory>").unwrap()
                    })
                    .collect();
                engine::merge_shard_states(parts, AccumBackend::Host, t).unwrap()
            };
            let stats = bench(&format!("shard/host small shards={shards}"), &opts, || {
                std::hint::black_box(run_once(&mut StageTimings::default()));
            });
            // one representative run for the per-stage breakdown and
            // the allocator peak
            let mut mem = coala::telemetry::alloc::MemScope::enter();
            let mut t = StageTimings::default();
            run_once(&mut t);
            let peak = mem.finish().map_or(0, |m| m.peak_bytes);
            shard_records.push(record_with_stages(&stats, shards, &t, peak));
        }
    }

    // ---- host fine-tuning: parallel gradient accumulation ----------------
    let mut ft_records = Vec::new();
    {
        use coala::finetune::{init_adapters_from_source, AdapterInit, FineTuner, HostFineTuner};
        let spec = ex.manifest.config("large").unwrap().clone();
        let w = synthetic_weights(&spec, 1);
        let src = SyntheticActivations::new(spec.clone(), 1);
        let corpus = Corpus::synthetic(spec.vocab, 4096, 1);
        let set = init_adapters_from_source(&spec, &w, &src, AdapterInit::CoalaA1, 4, 2, 30)
            .unwrap();
        let pool = corpus
            .train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)
            .unwrap();
        for workers in [1usize, 2, 4, 8] {
            let tuner = HostFineTuner::new(spec.clone(), 4).with_workers(workers);
            let stats = bench(&format!("finetune/host large workers={workers}"), &opts, || {
                let mut s = set.clone();
                std::hint::black_box(tuner.train_on_batches(&mut s, &pool, 8, 1e-3).unwrap());
            });
            ft_records.push(record(&stats, workers));
        }
    }

    // ---- artifact-backed targets (need artifacts/ + the pjrt feature) ----
    let mut device_records = Vec::new();
    if coala::runtime::device_available("artifacts") {
        let ex = Executor::new("artifacts").unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();

        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        let mut job = CompressionJob::new("tiny", resolve("coala").unwrap().method(), 0.5);
        job.calib_batches = 4;
        let stats = bench("pipeline/coala e2e (4 batches)", &opts, || {
            std::hint::black_box(pipe.run(&job, &corpus).unwrap());
        });
        device_records.push(record(&stats, 1));

        let batches = corpus.batches("calib", spec.batch, spec.seq_len, 4).unwrap();
        // queue_cap = 2; the overlapped scheduler runs one worker per stage
        let stats = bench("scheduler/overlapped calibrate", &opts, || {
            std::hint::black_box(
                calibrate_overlapped("artifacts", "tiny", batches.clone(), 2, AccumKind::RFactor)
                    .unwrap(),
            );
        });
        device_records.push(record(&stats, 1));

        let chunks: Vec<Matrix<f32>> =
            (0..8).map(|i| Matrix::randn(spec.chunk_cols(), spec.d_model, i as u64)).collect();
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::new("artifacts", workers);
            let stats = bench(&format!("tsqr-tree/workers={workers}"), &opts, || {
                std::hint::black_box(runner.run(chunks.clone()).unwrap());
            });
            device_records.push(record(&stats, workers));
        }
    } else {
        println!("pipeline bench: artifacts/ + pjrt unavailable — device targets skipped");
    }

    let out = Json::obj(vec![
        ("host_engine", Json::Arr(host_records)),
        ("host_shard", Json::Arr(shard_records)),
        ("host_finetune", Json::Arr(ft_records)),
        ("device", Json::Arr(device_records)),
    ]);
    std::fs::write("BENCH_pipeline.json", out.dump()).unwrap();
    println!("[BENCH_pipeline.json written]");
}
