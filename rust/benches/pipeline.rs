//! Bench: end-to-end pipeline stages + the overlapped scheduler vs the
//! sequential calibration (the §Perf L3 target).

use coala::calib::accumulate::AccumKind;
use coala::calib::dataset::Corpus;
use coala::coala::compressor::{resolve, Compressor};
use coala::coordinator::scheduler::calibrate_overlapped;
use coala::coordinator::{CompressionJob, Pipeline, TsqrTreeRunner};
use coala::model::ModelWeights;
use coala::runtime::Executor;
use coala::tensor::Matrix;
use coala::util::bench::{bench, BenchOpts};

fn main() {
    if !coala::runtime::device_available("artifacts") {
        println!("pipeline bench: needs artifacts/ and the pjrt feature");
        return;
    }
    let ex = Executor::new("artifacts").unwrap();
    let corpus = Corpus::load("artifacts").unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = ModelWeights::load("artifacts", &spec).unwrap();
    let opts = BenchOpts::heavy().from_env();

    let pipe = Pipeline::new(&ex, spec.clone(), &w);
    let mut job = CompressionJob::new("tiny", resolve("coala").unwrap().method(), 0.5);
    job.calib_batches = 4;
    bench("pipeline/coala e2e (4 batches)", &opts, || {
        std::hint::black_box(pipe.run(&job, &corpus).unwrap());
    });

    let batches = corpus.batches("calib", spec.batch, spec.seq_len, 4).unwrap();
    bench("scheduler/overlapped calibrate", &opts, || {
        std::hint::black_box(
            calibrate_overlapped("artifacts", "tiny", batches.clone(), 2, AccumKind::RFactor)
                .unwrap(),
        );
    });

    let chunks: Vec<Matrix<f32>> =
        (0..8).map(|i| Matrix::randn(spec.chunk_cols(), spec.d_model, i as u64)).collect();
    for workers in [1usize, 2, 4] {
        let runner = TsqrTreeRunner::new("artifacts", workers);
        bench(&format!("tsqr-tree/workers={workers}"), &opts, || {
            std::hint::black_box(runner.run(chunks.clone()).unwrap());
        });
    }
}
