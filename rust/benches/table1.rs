//! Bench: Table 1 — full-model compression wall-clock per method.
//! (criterion is not vendorable offline; uses the crate's bench harness
//! with the same warmup/mean±std methodology.)

use coala::calib::dataset::Corpus;
use coala::coala::{Method, MuRule};
use coala::coordinator::{CompressionJob, Pipeline};
use coala::model::ModelWeights;
use coala::runtime::Executor;
use coala::util::bench::{bench, BenchOpts};

fn main() {
    if !coala::runtime::device_available("artifacts") {
        println!("table1 bench: needs artifacts/ and the pjrt feature");
        return;
    }
    let ex = Executor::new("artifacts").unwrap();
    let corpus = Corpus::load("artifacts").unwrap();
    let opts = BenchOpts::heavy().from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1)
    });
    println!("== Table 1 bench: compression wall-clock ==");
    for cfg_name in ["tiny", "small"] {
        let spec = ex.manifest.config(cfg_name).unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        for (label, method) in [
            ("SVD-LLM", Method::SvdLlm),
            ("SVD-LLM-v2", Method::SvdLlmV2),
            ("COALA", Method::Coala(MuRule::None)),
        ] {
            let mut job = CompressionJob::new(cfg_name, method, 0.3);
            job.calib_batches = 4;
            bench(&format!("{cfg_name}/{label}"), &opts, || {
                let out = pipe.run(&job, &corpus).unwrap();
                std::hint::black_box(out.model.factored_params());
            });
        }
    }
}
