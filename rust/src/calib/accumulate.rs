//! Streaming calibration accumulators (the "accumulate" stage of the
//! pipeline), factored out of the coordinator so every driver — the
//! sequential pipeline, the overlapped scheduler, and the tree-TSQR
//! runner — folds chunks through one `fold_chunk`/`finish` interface.
//!
//! Four accumulation strategies exist (each
//! [`crate::coala::compressor::Compressor`] declares which one it
//! needs, and `--accum sketch` can swap the R route for the sketch):
//!
//! * **R factor** (COALA / α-family): out-of-core TSQR — fold each
//!   (B·T × n) chunk of Xᵀ into a square R with RᵀR = XXᵀ;
//! * **Sketch** (opt-in for the R consumers): a randomized range
//!   finder — fold each chunk into Y ← Y + Ω_b·chunk where Ω_b is a
//!   seeded s × rows test matrix drawn from the chunk's **global batch
//!   index** b, so the accumulated Y (and everything downstream) is
//!   bitwise independent of worker count, shard geometry, and merge
//!   order.  Two Ω families ([`SketchKind`], `COALA_SKETCH_KIND`): a
//!   dense Gaussian (one packed GEMM per fold, O(s·c·n)) and the SRHT
//!   fast transform (sign flip + Walsh–Hadamard + row sample,
//!   O(L·log L·n)).  s = O(rank) rows (see [`SketchCfg::rows_for`])
//!   beat the exact TSQR's O((n+c)·n²); QR of Y divided by √s then
//!   stands in for R ([`CalibState::r_factor`]) with the range-finder
//!   error bound of "Low-Rank Approximation, Adaptation, and Other
//!   Tales" (PAPERS.md): the expected excess factor over the optimal
//!   rank-r residual is √(1 + r/(p−1)) for oversampling p = s − r;
//! * **Gram** (SVD-LLM / CorDA): G ← G + chunkᵀ·chunk;
//! * **Scales** (ASVD): running Σ|x| and row count per input channel.
//!
//! Every accumulator runs on either backend: `Device` folds through the
//! PJRT artifacts (`runtime::ops`), `Host` through the pure-Rust linalg
//! (`linalg::tsqr::TsqrFolder`, `tensor::ops::gram_t`).  The sketch
//! fold itself is host linalg (one packed GEMM) on both backends.
//! X itself is never materialized on either route.

use crate::error::{Error, Result};
use crate::linalg::qr_r_square;
use crate::linalg::tsqr::TsqrFolder;
use crate::runtime::executor::Executor;
use crate::runtime::ops;
use crate::tensor::lowp::{quantize, Precision};
use crate::tensor::ops::{gram_t, matmul};
use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Which accumulation strategy a compression method consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumKind {
    /// Square R with RᵀR = (seen X)(seen X)ᵀ (QR route).
    RFactor,
    /// Seeded Gaussian range-finder sketch Y = Σ_b Ω_b·chunk_b — the
    /// O(rank)-per-batch stand-in for the exact R (opt-in, `--accum
    /// sketch`).
    Sketch,
    /// G = Σ chunkᵀ·chunk (Gram route).
    Gram,
    /// Running Σ|x| and count per input channel (ASVD route).
    Scales,
    /// Context-free methods (plain SVD): nothing to accumulate.
    None,
}

/// Finished accumulator state — what the factorization stage consumes.
#[derive(Debug, Clone)]
pub enum CalibState {
    R(Matrix<f32>),
    /// Accumulated range-finder sketch Y (s × n), the Ω family it was
    /// drawn from, and the number of batch folds it has absorbed (so a
    /// resumed linear stream keeps drawing fresh Ω indices).
    Sketch { y: Matrix<f32>, folds: u64, kind: SketchKind },
    Gram(Matrix<f32>),
    Scales { sum_abs: Vec<f64>, rows: usize },
    None,
}

impl CalibState {
    pub fn kind(&self) -> AccumKind {
        match self {
            CalibState::R(_) => AccumKind::RFactor,
            CalibState::Sketch { .. } => AccumKind::Sketch,
            CalibState::Gram(_) => AccumKind::Gram,
            CalibState::Scales { .. } => AccumKind::Scales,
            CalibState::None => AccumKind::None,
        }
    }

    pub fn r(&self) -> Result<&Matrix<f32>> {
        match self {
            CalibState::R(r) => Ok(r),
            other => Err(Error::Config(format!(
                "method needs the R-factor route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }

    /// Owned R factor for the R-consuming methods.  Exact states clone
    /// their R; sketch states take the QR of the accumulated Y = Ω·A
    /// and rescale by 1/√s, so R̂ᵀR̂ = YᵀY/s ≈ AᵀA in expectation
    /// (E[ΩᵀΩ] = s·I) and the whitening the consumers perform sees the
    /// right scale even under regularization (α-family λ/μ rules).
    pub fn r_factor(&self) -> Result<Matrix<f32>> {
        match self {
            CalibState::R(r) => Ok(r.clone()),
            CalibState::Sketch { y, .. } => {
                let s = y.rows.max(1) as f32;
                Ok(qr_r_square(y)?.scale(1.0 / s.sqrt()))
            }
            other => Err(Error::Config(format!(
                "method needs the R-factor route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }

    pub fn gram(&self) -> Result<&Matrix<f32>> {
        match self {
            CalibState::Gram(g) => Ok(g),
            other => Err(Error::Config(format!(
                "method needs the Gram route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }

    /// ASVD's per-channel scale rule: (mean |x| + ε)^{1/2}.
    pub fn asvd_scales(&self) -> Result<Vec<f32>> {
        match self {
            CalibState::Scales { sum_abs, rows } => Ok(sum_abs
                .iter()
                .map(|v| ((v / (*rows).max(1) as f64) as f32 + 1e-6).sqrt())
                .collect()),
            other => Err(Error::Config(format!(
                "method needs the scales route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }
}

/// Where folds execute.
#[derive(Clone, Copy)]
pub enum AccumBackend<'a> {
    /// Through the shape-specialized PJRT artifacts.
    Device(&'a Executor),
    /// Pure-Rust host linalg.
    Host,
}

/// One streaming accumulator: fold chunks, merge sibling states (tree
/// reduction), finish into a [`CalibState`].
pub trait CalibAccumulator {
    fn kind(&self) -> AccumKind;
    /// Fold one (rows × width) chunk of Xᵀ.
    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()>;
    /// Absorb the state of a sibling accumulator (tree reduction edge).
    fn merge_state(&mut self, other: CalibState) -> Result<()>;
    fn finish(self: Box<Self>) -> CalibState;
}

/// Build the accumulator a method requires, for `width`-channel chunks.
/// `precision` emulates the accumulation arithmetic (Table 2's fp16).
/// Equivalent to [`make_leaf_accumulator`] at leaf index 0 — the right
/// call for linear streams that fold batch 0, 1, 2, … in order.
///
/// Errors if the sketch knobs (`COALA_SKETCH_ROWS` /
/// `COALA_SKETCH_SEED`) are set but malformed or out of range — loudly,
/// at construction, so a typo'd shard dies instead of silently
/// diverging from its siblings.
pub fn make_accumulator<'a>(
    kind: AccumKind,
    width: usize,
    backend: AccumBackend<'a>,
    precision: Precision,
) -> Result<Box<dyn CalibAccumulator + 'a>> {
    make_leaf_accumulator(kind, width, backend, precision, 0)
}

/// [`make_accumulator`] with an explicit starting leaf index for
/// position-dependent randomness: the engine passes the **global batch
/// index** here so the sketch kind draws Ω from the batch's position in
/// the run, never from worker or shard geometry.  The exact kinds
/// ignore it.
pub fn make_leaf_accumulator<'a>(
    kind: AccumKind,
    width: usize,
    backend: AccumBackend<'a>,
    precision: Precision,
    leaf_index: usize,
) -> Result<Box<dyn CalibAccumulator + 'a>> {
    Ok(match kind {
        AccumKind::RFactor => Box::new(RAccumulator::new(width, backend, precision)),
        AccumKind::Sketch => Box::new(SketchAccumulator::new(
            width,
            precision,
            leaf_index as u64,
            SketchCfg::from_env()?,
        )?),
        AccumKind::Gram => Box::new(GramAccumulator::new(width, backend, precision)),
        AccumKind::Scales => Box::new(ScalesAccumulator::new(width, precision)),
        AccumKind::None => Box::new(NullAccumulator),
    })
}

/// Re-open a finished state as an accumulator (resuming a stream, or
/// seeding a tree-reduction node).
pub fn make_accumulator_from<'a>(
    state: CalibState,
    backend: AccumBackend<'a>,
    precision: Precision,
) -> Result<Box<dyn CalibAccumulator + 'a>> {
    Ok(match state {
        CalibState::R(r) => Box::new(RAccumulator::from_r(r, backend, precision)),
        CalibState::Sketch { y, folds, kind } => {
            let cfg = SketchCfg::from_env()?;
            if cfg.kind != kind {
                // resuming a gaussian stream under COALA_SKETCH_KIND=srht
                // (or vice versa) would silently add incompatible Ω
                // families — the state is self-describing, so refuse
                return Err(Error::Config(format!(
                    "COALA_SKETCH_KIND={} but the resumed state was accumulated with the \
                     {} sketch; unset the knob or match it to the state",
                    cfg.kind.label(),
                    kind.label()
                )));
            }
            Box::new(SketchAccumulator {
                precision,
                y,
                next_index: folds,
                folds,
                seed: cfg.seed,
                kind,
            })
        }
        CalibState::Gram(g) => Box::new(GramAccumulator { backend, precision, g }),
        CalibState::Scales { sum_abs, rows } => {
            Box::new(ScalesAccumulator { precision, sum_abs, rows })
        }
        CalibState::None => Box::new(NullAccumulator),
    })
}

/// Merge two finished states (the tree-reduction edge as a free
/// function).  Seeds the accumulator from `a`, so each edge costs one
/// merge — one `tsqr_merge` launch / one QR — not two.
pub fn merge_states(
    a: CalibState,
    b: CalibState,
    backend: AccumBackend<'_>,
    precision: Precision,
) -> Result<CalibState> {
    let mut acc = make_accumulator_from(a, backend, precision)?;
    acc.merge_state(b)?;
    Ok(acc.finish())
}

// ---------------------------------------------------------------- R route

struct RAccumulator<'a> {
    backend: AccumBackend<'a>,
    precision: Precision,
    /// Device route: the running square R.
    r: Option<Matrix<f32>>,
    /// Host route: scratch-reusing streaming folder.
    folder: Option<TsqrFolder<f32>>,
}

impl<'a> RAccumulator<'a> {
    fn new(width: usize, backend: AccumBackend<'a>, precision: Precision) -> RAccumulator<'a> {
        match backend {
            AccumBackend::Device(_) => RAccumulator {
                backend,
                precision,
                r: Some(Matrix::zeros(width, width)),
                folder: None,
            },
            AccumBackend::Host => RAccumulator {
                backend,
                precision,
                r: None,
                folder: Some(TsqrFolder::new(width)),
            },
        }
    }

    /// Resume from an existing square R (no fold spent on the seed).
    fn from_r(r: Matrix<f32>, backend: AccumBackend<'a>, precision: Precision) -> RAccumulator<'a> {
        match backend {
            AccumBackend::Device(_) => RAccumulator { backend, precision, r: Some(r), folder: None },
            AccumBackend::Host => RAccumulator {
                backend,
                precision,
                r: None,
                folder: Some(TsqrFolder::from_r(&r)),
            },
        }
    }
}

impl CalibAccumulator for RAccumulator<'_> {
    fn kind(&self) -> AccumKind {
        AccumKind::RFactor
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        match self.backend {
            AccumBackend::Device(ex) => {
                let r = self.r.as_mut().expect("device R state");
                *r = ops::tsqr_step(ex, r, xt)?;
            }
            AccumBackend::Host => {
                self.folder.as_mut().expect("host folder").fold(xt)?;
            }
        }
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        let other = other.r()?.clone();
        match self.backend {
            AccumBackend::Device(ex) => {
                let r = self.r.as_mut().expect("device R state");
                *r = ops::tsqr_merge(ex, r, &other)?;
            }
            AccumBackend::Host => {
                self.folder.as_mut().expect("host folder").merge_r(&other)?;
            }
        }
        Ok(())
    }

    fn finish(self: Box<Self>) -> CalibState {
        match self.backend {
            AccumBackend::Device(_) => CalibState::R(self.r.expect("device R state")),
            AccumBackend::Host => CalibState::R(self.folder.expect("host folder").finish()),
        }
    }
}

// ----------------------------------------------------------- Sketch route

/// Default base seed of the Ω family ([`SketchCfg::seed`]).
pub const DEFAULT_SKETCH_SEED: u64 = 0xC0A1A;

/// Which random family the sketch draws Ω from (`COALA_SKETCH_KIND`).
/// Fingerprint-relevant: divergent kinds produce incompatible Y, so the
/// kind is stamped into the state codec and the run fingerprint, and
/// merge/resume refuse a mismatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    /// Dense s × rows Gaussian per batch — one packed GEMM per fold,
    /// O(s·c·n).
    Gaussian,
    /// Subsampled randomized Hadamard transform: random ±1 sign flip,
    /// unnormalized Walsh–Hadamard transform over the (zero-padded)
    /// batch rows, then s row samples with replacement.  O(L·log L·n)
    /// per fold for L = rows rounded up to a power of two — the fast
    /// transform replaces the sketch's own GEMM.  Sampled SHD rows have
    /// iid ±1 entries, so E[ΩᵀΩ] = s·I exactly like the Gaussian family
    /// and the 1/√s rescale in [`CalibState::r_factor`] is unchanged.
    Srht,
}

impl SketchKind {
    /// Strict parser for the `COALA_SKETCH_KIND` grammar
    /// (case-insensitive `gaussian` | `srht`); pure, like
    /// [`crate::util::env::parse_value`].
    pub fn parse_value(name: &str, v: &str) -> Result<SketchKind> {
        match v.trim().to_ascii_lowercase().as_str() {
            "gaussian" => Ok(SketchKind::Gaussian),
            "srht" => Ok(SketchKind::Srht),
            _ => Err(Error::Config(format!(
                "{name}: expected `gaussian` or `srht`, got `{v}`"
            ))),
        }
    }

    /// Lower-case name (fingerprints, error messages).
    pub fn label(&self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gaussian",
            SketchKind::Srht => "srht",
        }
    }
}

/// Parsed-once sketch configuration: `COALA_SKETCH_ROWS` (explicit
/// sketch height), `COALA_SKETCH_SEED` (base seed of the Ω family —
/// override it to draw an independent sketch family, e.g. to estimate
/// sketch variance across repetitions), and `COALA_SKETCH_KIND`
/// (Gaussian GEMM sketch vs SRHT fast transform).
///
/// Every worker **and shard** of a run must agree on both knobs — the
/// sketch Y of divergent shards would silently add incompatible Ω
/// families — which is why (a) malformed or out-of-range values are a
/// hard error at accumulator construction rather than the pre-PR-7
/// silent default/clamp, and (b) `repro::common::Env::source_id` folds
/// both into the run fingerprint so divergent shard states refuse to
/// merge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchCfg {
    /// Explicit row-count override; `None` = the width-derived default
    /// of [`SketchCfg::rows_for`].
    pub rows: Option<usize>,
    /// Base seed of the Ω family.
    pub seed: u64,
    /// Random family Ω is drawn from.
    pub kind: SketchKind,
}

impl Default for SketchCfg {
    fn default() -> Self {
        SketchCfg { rows: None, seed: DEFAULT_SKETCH_SEED, kind: SketchKind::Gaussian }
    }
}

impl SketchCfg {
    /// Read all three knobs from the environment, strictly.
    pub fn from_env() -> Result<SketchCfg> {
        let kind = match crate::util::env::string("COALA_SKETCH_KIND")? {
            None => SketchKind::Gaussian,
            Some(v) => SketchKind::parse_value("COALA_SKETCH_KIND", &v)?,
        };
        SketchCfg::validated(
            crate::util::env::parse::<usize>("COALA_SKETCH_ROWS")?,
            crate::util::env::parse_or::<u64>("COALA_SKETCH_SEED", DEFAULT_SKETCH_SEED)?,
            kind,
        )
    }

    /// Pure core of [`SketchCfg::from_env`] (`None` = knob unset),
    /// testable without mutating the process environment.
    pub fn parse(rows: Option<&str>, seed: Option<&str>, kind: Option<&str>) -> Result<SketchCfg> {
        SketchCfg::validated(
            rows.map(|v| crate::util::env::parse_value::<usize>("COALA_SKETCH_ROWS", v))
                .transpose()?,
            seed.map(|v| crate::util::env::parse_value::<u64>("COALA_SKETCH_SEED", v))
                .transpose()?
                .unwrap_or(DEFAULT_SKETCH_SEED),
            kind.map(|v| SketchKind::parse_value("COALA_SKETCH_KIND", v))
                .transpose()?
                .unwrap_or(SketchKind::Gaussian),
        )
    }

    fn validated(rows: Option<usize>, seed: u64, kind: SketchKind) -> Result<SketchCfg> {
        if rows == Some(0) {
            return Err(Error::Config("COALA_SKETCH_ROWS: must be ≥ 1, got `0`".into()));
        }
        Ok(SketchCfg { rows, seed, kind })
    }

    /// Sketch height for `width`-channel chunks.  The default n/2 + 16
    /// (clamped to [1, width]) sits comfortably above every rank the
    /// ratio knob selects (r ≤ n/2) with the oversampling the
    /// range-finder bound wants (p = s − r ≥ 16 keeps the expected
    /// excess residual factor √(1 + r/(p−1)) below √2 and the tail
    /// probability negligible).  An explicit override outside
    /// [1, width] is an error — never a silent clamp.
    pub fn rows_for(&self, width: usize) -> Result<usize> {
        match self.rows {
            None => Ok((width / 2 + 16).min(width).max(1)),
            Some(r) if r <= width.max(1) => Ok(r),
            Some(r) => Err(Error::Config(format!(
                "COALA_SKETCH_ROWS: {r} is out of range for {width}-channel chunks \
                 (must be in [1, {width}])"
            ))),
        }
    }
}

/// SplitMix64 finalizer over (base, leaf index) → the xoshiro seed for
/// Ω at that leaf.  Consecutive indices decorrelate into independent
/// streams, so E[Ω_aᵀΩ_b] = 0 across batches and E[YᵀY] stays an
/// unbiased multiple of AᵀA.
fn leaf_seed(base: u64, index: u64) -> u64 {
    let mut z = base ^ index.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Randomized range-finder accumulator: Y ← Y + Ω_b·chunk_b with a
/// fresh seeded Gaussian Ω_b per global batch index b.  Merging is
/// elementwise addition of Y, so the canonical merge tree reproduces
/// the linear stream bit for bit at any worker/shard count.  The fold
/// is host linalg (one packed GEMM) on either backend.
struct SketchAccumulator {
    precision: Precision,
    y: Matrix<f32>,
    /// Global batch index the next `fold_chunk` sketches.
    next_index: u64,
    /// Batch folds absorbed so far (incl. merged siblings).
    folds: u64,
    /// Base seed of the Ω family ([`SketchCfg::seed`], captured once at
    /// construction — folds never re-read the environment).
    seed: u64,
    /// Random family Ω is drawn from ([`SketchCfg::kind`], captured
    /// once — fingerprint-relevant).
    kind: SketchKind,
}

impl SketchAccumulator {
    fn new(
        width: usize,
        precision: Precision,
        leaf_index: u64,
        cfg: SketchCfg,
    ) -> Result<SketchAccumulator> {
        Ok(SketchAccumulator {
            precision,
            y: Matrix::zeros(cfg.rows_for(width)?, width),
            next_index: leaf_index,
            folds: 0,
            seed: cfg.seed,
            kind: cfg.kind,
        })
    }

    /// Y ← Y + S·H·D·chunk without materializing Ω: sign-flip the
    /// chunk's rows (D), Walsh–Hadamard over the zero-padded row axis
    /// (H, unnormalized: entries ±1), take the s sampled rows (S).
    /// Draw order per batch index is rows sign bits then s sample
    /// indices, so the fold is a pure function of (seed, batch index,
    /// chunk) like the Gaussian path.
    fn fold_srht(&mut self, xt: &Matrix<f32>) -> Result<()> {
        let (rows, n, s) = (xt.rows, xt.cols, self.y.rows);
        let l = rows.next_power_of_two().max(1);
        let mut rng = Rng::new(leaf_seed(self.seed, self.next_index));
        let signs: Vec<f32> =
            (0..rows).map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 }).collect();
        let samples: Vec<usize> = (0..s).map(|_| rng.below(l)).collect();
        let mut buf = vec![0.0f32; l];
        for j in 0..n {
            for (i, b) in buf.iter_mut().enumerate() {
                *b = if i < rows { signs[i] * xt.get(i, j) } else { 0.0 };
            }
            let mut h = 1;
            while h < l {
                let mut base = 0;
                while base < l {
                    for i in base..base + h {
                        let (x, y) = (buf[i], buf[i + h]);
                        buf[i] = x + y;
                        buf[i + h] = x - y;
                    }
                    base += 2 * h;
                }
                h *= 2;
            }
            for (k, &row) in samples.iter().enumerate() {
                let v = self.y.get(k, j) + buf[row];
                self.y.set(k, j, v);
            }
        }
        Ok(())
    }

    fn post_round(&mut self) {
        if self.precision != Precision::F32 {
            self.y = quantize(&self.y, self.precision);
        }
    }
}

impl CalibAccumulator for SketchAccumulator {
    fn kind(&self) -> AccumKind {
        AccumKind::Sketch
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        if xt.cols != self.y.cols {
            return Err(Error::shape(format!(
                "sketch fold: chunk has {} cols, accumulator is {}-wide",
                xt.cols,
                self.y.cols
            )));
        }
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        match self.kind {
            SketchKind::Gaussian => {
                let s = self.y.rows;
                let mut rng = Rng::new(leaf_seed(self.seed, self.next_index));
                let omega = Matrix::from_vec(s, xt.rows, rng.normal_vec_f32(s * xt.rows))?;
                self.y = self.y.add(&matmul(&omega, xt)?)?;
            }
            SketchKind::Srht => self.fold_srht(xt)?,
        }
        self.next_index += 1;
        self.folds += 1;
        self.post_round();
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        match other {
            CalibState::Sketch { y, folds, kind } => {
                if kind != self.kind {
                    return Err(Error::Config(format!(
                        "sketch merge: sibling was accumulated with the {} sketch, \
                         this state with {}",
                        kind.label(),
                        self.kind.label()
                    )));
                }
                // shape mismatch (different COALA_SKETCH_ROWS) errors here
                self.y = self.y.add(&y)?;
                self.folds += folds;
                self.post_round();
                Ok(())
            }
            other => Err(Error::Config(format!(
                "sketch merge: sibling holds {:?}",
                other.kind()
            ))),
        }
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::Sketch { y: self.y, folds: self.folds, kind: self.kind }
    }
}

// ------------------------------------------------------------- Gram route

struct GramAccumulator<'a> {
    backend: AccumBackend<'a>,
    precision: Precision,
    g: Matrix<f32>,
}

impl<'a> GramAccumulator<'a> {
    fn new(width: usize, backend: AccumBackend<'a>, precision: Precision) -> GramAccumulator<'a> {
        GramAccumulator { backend, precision, g: Matrix::zeros(width, width) }
    }

    fn post_round(&mut self) {
        if self.precision != Precision::F32 {
            self.g = quantize(&self.g, self.precision);
        }
    }
}

impl CalibAccumulator for GramAccumulator<'_> {
    fn kind(&self) -> AccumKind {
        AccumKind::Gram
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        match self.backend {
            AccumBackend::Device(ex) => self.g = ops::gram_update(ex, &self.g, xt)?,
            AccumBackend::Host => self.g = self.g.add(&gram_t(xt))?,
        }
        self.post_round();
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        self.g = self.g.add(other.gram()?)?;
        self.post_round();
        Ok(())
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::Gram(self.g)
    }
}

// ----------------------------------------------------------- Scales route

struct ScalesAccumulator {
    precision: Precision,
    sum_abs: Vec<f64>,
    rows: usize,
}

impl ScalesAccumulator {
    fn new(width: usize, precision: Precision) -> ScalesAccumulator {
        ScalesAccumulator { precision, sum_abs: vec![0.0; width], rows: 0 }
    }
}

impl CalibAccumulator for ScalesAccumulator {
    fn kind(&self) -> AccumKind {
        AccumKind::Scales
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        if xt.cols != self.sum_abs.len() {
            return Err(Error::shape(format!(
                "scales fold: chunk has {} cols, accumulator is {}-wide",
                xt.cols,
                self.sum_abs.len()
            )));
        }
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        for i in 0..xt.rows {
            for (j, acc) in self.sum_abs.iter_mut().enumerate() {
                *acc += xt.get(i, j).abs() as f64;
            }
        }
        self.rows += xt.rows;
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        match other {
            CalibState::Scales { sum_abs, rows } => {
                if sum_abs.len() != self.sum_abs.len() {
                    return Err(Error::shape("scales merge: width mismatch".into()));
                }
                for (a, b) in self.sum_abs.iter_mut().zip(&sum_abs) {
                    *a += b;
                }
                self.rows += rows;
                Ok(())
            }
            other => Err(Error::Config(format!(
                "scales merge: sibling holds {:?}",
                other.kind()
            ))),
        }
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::Scales { sum_abs: self.sum_abs, rows: self.rows }
    }
}

// ------------------------------------------------------------- Null route

struct NullAccumulator;

impl CalibAccumulator for NullAccumulator {
    fn kind(&self) -> AccumKind {
        AccumKind::None
    }

    fn fold_chunk(&mut self, _xt: &Matrix<f32>) -> Result<()> {
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        // refuse to silently discard a sibling's real statistics
        match other {
            CalibState::None => Ok(()),
            other => Err(Error::Config(format!(
                "null accumulator cannot absorb a {:?} sibling",
                other.kind()
            ))),
        }
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, matmul};

    fn chunks(n: usize, rows: usize, count: usize, seed: u64) -> Vec<Matrix<f32>> {
        (0..count).map(|i| Matrix::randn(rows, n, seed + i as u64)).collect()
    }

    fn full_stack(chunks: &[Matrix<f32>]) -> Matrix<f32> {
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        full
    }

    #[test]
    fn host_r_accumulator_satisfies_gram_identity() {
        let cs = chunks(7, 15, 4, 1);
        let mut acc =
            make_accumulator(AccumKind::RFactor, 7, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::R(r) = acc.finish() else { panic!("not R") };
        let got = matmul(&r.transpose(), &r).unwrap();
        let want = gram_t(&full_stack(&cs));
        assert!(fro(&got.sub(&want).unwrap()) < 1e-3 * fro(&want));
    }

    #[test]
    fn host_gram_accumulator_matches_direct() {
        let cs = chunks(6, 11, 3, 10);
        let mut acc =
            make_accumulator(AccumKind::Gram, 6, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::Gram(g) = acc.finish() else { panic!("not Gram") };
        let want = gram_t(&full_stack(&cs));
        assert!(fro(&g.sub(&want).unwrap()) < 1e-4 * fro(&want));
    }

    #[test]
    fn scales_accumulator_means_abs() {
        let cs = chunks(5, 8, 2, 20);
        let mut acc =
            make_accumulator(AccumKind::Scales, 5, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let state = acc.finish();
        let CalibState::Scales { sum_abs, rows } = &state else { panic!("not Scales") };
        assert_eq!(*rows, 16);
        let full = full_stack(&cs);
        for (j, s) in sum_abs.iter().enumerate() {
            let want: f64 = (0..full.rows).map(|i| full.get(i, j).abs() as f64).sum();
            assert!((s - want).abs() < 1e-4 * (1.0 + want));
        }
        let scales = state.asvd_scales().unwrap();
        assert_eq!(scales.len(), 5);
        assert!(scales.iter().all(|s| s.is_finite() && *s > 0.0));
    }

    #[test]
    fn merge_matches_single_stream() {
        // folding [c0, c1] sequentially == fold c0 | fold c1 then merge
        let cs = chunks(6, 9, 2, 30);
        for kind in [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales] {
            let mut seq = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32).unwrap();
            seq.fold_chunk(&cs[0]).unwrap();
            seq.fold_chunk(&cs[1]).unwrap();
            let want = seq.finish();

            let mut a = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32).unwrap();
            a.fold_chunk(&cs[0]).unwrap();
            let mut b = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32).unwrap();
            b.fold_chunk(&cs[1]).unwrap();
            let got = merge_states(a.finish(), b.finish(), AccumBackend::Host, Precision::F32)
                .unwrap();

            match (&want, &got) {
                (CalibState::R(rw), CalibState::R(rg)) => {
                    let gw = matmul(&rw.transpose(), rw).unwrap();
                    let gg = matmul(&rg.transpose(), rg).unwrap();
                    assert!(fro(&gw.sub(&gg).unwrap()) < 1e-3 * (1.0 + fro(&gw)));
                }
                (CalibState::Gram(gw), CalibState::Gram(gg)) => {
                    assert!(fro(&gw.sub(gg).unwrap()) < 1e-5 * (1.0 + fro(gw)));
                }
                (
                    CalibState::Scales { sum_abs: sw, rows: nw },
                    CalibState::Scales { sum_abs: sg, rows: ng },
                ) => {
                    assert_eq!(nw, ng);
                    for (a, b) in sw.iter().zip(sg) {
                        assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()));
                    }
                }
                _ => panic!("kind mismatch after merge"),
            }
        }
    }

    #[test]
    fn state_route_mismatch_reports() {
        let state = CalibState::Gram(Matrix::zeros(3, 3));
        assert!(state.r().is_err());
        assert!(state.asvd_scales().is_err());
        assert!(CalibState::None.gram().is_err());
    }

    #[test]
    fn null_merge_rejects_real_states() {
        let mut acc =
            make_accumulator(AccumKind::None, 0, AccumBackend::Host, Precision::F32).unwrap();
        assert!(acc.merge_state(CalibState::None).is_ok());
        assert!(acc.merge_state(CalibState::Gram(Matrix::zeros(2, 2))).is_err());
    }

    #[test]
    fn seeded_accumulator_resumes_stream() {
        // make_accumulator_from(state) ≡ continuing the original stream
        let cs = chunks(6, 9, 3, 60);
        let mut full =
            make_accumulator(AccumKind::RFactor, 6, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            full.fold_chunk(c).unwrap();
        }
        let want = full.finish();

        let mut first =
            make_accumulator(AccumKind::RFactor, 6, AccumBackend::Host, Precision::F32).unwrap();
        first.fold_chunk(&cs[0]).unwrap();
        let mut resumed =
            make_accumulator_from(first.finish(), AccumBackend::Host, Precision::F32).unwrap();
        resumed.fold_chunk(&cs[1]).unwrap();
        resumed.fold_chunk(&cs[2]).unwrap();
        let got = resumed.finish();

        let gw = matmul(&want.r().unwrap().transpose(), want.r().unwrap()).unwrap();
        let gg = matmul(&got.r().unwrap().transpose(), got.r().unwrap()).unwrap();
        assert!(fro(&gw.sub(&gg).unwrap()) < 1e-3 * (1.0 + fro(&gw)));
    }

    #[test]
    fn sketch_merge_is_bitwise_single_stream() {
        // leaf-indexed Ω makes split-fold-merge ≡ the linear stream,
        // bitwise, regardless of how the batches were partitioned
        let cs = chunks(6, 9, 4, 70);
        let mut seq =
            make_accumulator(AccumKind::Sketch, 6, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            seq.fold_chunk(c).unwrap();
        }
        let CalibState::Sketch { y: yw, folds: fw, .. } = seq.finish() else {
            panic!("not Sketch")
        };
        assert_eq!(fw, 4);

        let mut a =
            make_leaf_accumulator(AccumKind::Sketch, 6, AccumBackend::Host, Precision::F32, 0)
                .unwrap();
        a.fold_chunk(&cs[0]).unwrap();
        a.fold_chunk(&cs[1]).unwrap();
        let mut b =
            make_leaf_accumulator(AccumKind::Sketch, 6, AccumBackend::Host, Precision::F32, 2)
                .unwrap();
        b.fold_chunk(&cs[2]).unwrap();
        b.fold_chunk(&cs[3]).unwrap();
        let got = merge_states(a.finish(), b.finish(), AccumBackend::Host, Precision::F32).unwrap();
        let CalibState::Sketch { y: yg, folds: fg, kind } = got else { panic!("not Sketch") };
        assert_eq!(fg, 4);
        assert_eq!(kind, SketchKind::Gaussian);
        let bits_w: Vec<u32> = yw.data.iter().map(|v| v.to_bits()).collect();
        let bits_g: Vec<u32> = yg.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_w, bits_g);
    }

    #[test]
    fn sketch_r_factor_approximates_exact_gram() {
        // R̂ᵀR̂ from the sketch tracks XᵀX well enough for whitening:
        // same order of magnitude, finite, right shape.  The tight
        // statistical bound is exercised in tests/engine_determinism.rs.
        let cs = chunks(8, 32, 6, 80);
        let mut acc =
            make_accumulator(AccumKind::Sketch, 8, AccumBackend::Host, Precision::F32).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let state = acc.finish();
        assert!(state.r().is_err(), "sketch state must not pose as an exact R");
        let r = state.r_factor().unwrap();
        assert_eq!((r.rows, r.cols), (8, 8));
        assert!(r.all_finite());
        let got = matmul(&r.transpose(), &r).unwrap();
        let want = gram_t(&full_stack(&cs));
        // E[R̂ᵀR̂] = XᵀX, but at s = n = 8 (no oversampling headroom)
        // the estimate fluctuates at O(1) relative error — this is a
        // same-ballpark sanity check, not the statistical bound
        assert!(fro(&got.sub(&want).unwrap()) < 2.5 * fro(&want));
    }

    #[test]
    fn sketch_rejects_mismatched_folds_and_siblings() {
        let mut acc =
            make_accumulator(AccumKind::Sketch, 6, AccumBackend::Host, Precision::F32).unwrap();
        assert!(acc.fold_chunk(&Matrix::randn(4, 5, 1)).is_err());
        assert!(acc.merge_state(CalibState::Gram(Matrix::zeros(6, 6))).is_err());
        let short =
            CalibState::Sketch { y: Matrix::zeros(2, 6), folds: 1, kind: SketchKind::Gaussian };
        assert!(acc.merge_state(short).is_err());
        // kind mismatch: same shape, incompatible Ω family
        let srht = CalibState::Sketch {
            y: Matrix::zeros(acc_rows(6), 6),
            folds: 1,
            kind: SketchKind::Srht,
        };
        let e = acc.merge_state(srht).unwrap_err();
        assert!(e.to_string().contains("srht"), "{e}");
    }

    fn acc_rows(width: usize) -> usize {
        SketchCfg::default().rows_for(width).unwrap()
    }

    fn srht_accumulator(width: usize, leaf: u64, rows: Option<usize>) -> SketchAccumulator {
        let cfg = SketchCfg { rows, seed: DEFAULT_SKETCH_SEED, kind: SketchKind::Srht };
        SketchAccumulator::new(width, Precision::F32, leaf, cfg).unwrap()
    }

    #[test]
    fn srht_merge_is_bitwise_single_stream() {
        // the leaf-indexed draws make split-fold-merge ≡ the linear
        // stream for the fast-transform family too
        let cs = chunks(6, 9, 4, 75);
        let mut seq = srht_accumulator(6, 0, None);
        for c in &cs {
            seq.fold_chunk(c).unwrap();
        }
        let CalibState::Sketch { y: yw, folds: fw, kind } = Box::new(seq).finish() else {
            panic!("not Sketch")
        };
        assert_eq!((fw, kind), (4, SketchKind::Srht));

        let mut a = srht_accumulator(6, 0, None);
        a.fold_chunk(&cs[0]).unwrap();
        a.fold_chunk(&cs[1]).unwrap();
        let mut b = srht_accumulator(6, 2, None);
        b.fold_chunk(&cs[2]).unwrap();
        b.fold_chunk(&cs[3]).unwrap();
        let mut merged = Box::new(a);
        merged.merge_state(Box::new(b).finish()).unwrap();
        let CalibState::Sketch { y: yg, .. } = merged.finish() else { panic!("not Sketch") };
        let bits_w: Vec<u32> = yw.data.iter().map(|v| v.to_bits()).collect();
        let bits_g: Vec<u32> = yg.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_w, bits_g);
    }

    #[test]
    fn srht_r_factor_approximates_exact_gram() {
        // SHD rows have ±1 entries, so E[ΩᵀΩ] = s·I — the r_factor
        // rescale is shared with the Gaussian family and R̂ᵀR̂ tracks
        // XᵀX at the same order of magnitude
        let cs = chunks(8, 32, 6, 85);
        let mut acc = srht_accumulator(8, 0, None);
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let state = Box::new(acc).finish();
        let r = state.r_factor().unwrap();
        assert_eq!((r.rows, r.cols), (8, 8));
        assert!(r.all_finite());
        let got = matmul(&r.transpose(), &r).unwrap();
        let want = gram_t(&full_stack(&cs));
        assert!(fro(&got.sub(&want).unwrap()) < 2.5 * fro(&want));
    }

    #[test]
    fn srht_handles_non_power_of_two_and_single_row_chunks() {
        for rows in [1usize, 3, 9, 16] {
            let c: Matrix<f32> = Matrix::randn(rows, 5, 90 + rows as u64);
            let mut acc = srht_accumulator(5, 0, Some(4));
            acc.fold_chunk(&c).unwrap();
            let CalibState::Sketch { y, .. } = Box::new(acc).finish() else {
                panic!("not Sketch")
            };
            assert_eq!((y.rows, y.cols), (4, 5));
            assert!(y.all_finite());
        }
    }

    #[test]
    fn sketch_kind_grammar() {
        for (v, want) in [
            ("gaussian", SketchKind::Gaussian),
            ("GAUSSIAN", SketchKind::Gaussian),
            ("srht", SketchKind::Srht),
            (" SRHT ", SketchKind::Srht),
        ] {
            assert_eq!(SketchKind::parse_value("COALA_SKETCH_KIND", v).unwrap(), want, "{v:?}");
        }
        for bad in ["", "gauss", "hadamard", "1"] {
            let e = SketchKind::parse_value("COALA_SKETCH_KIND", bad).unwrap_err();
            assert!(e.to_string().contains("COALA_SKETCH_KIND"), "{bad:?}: {e}");
        }
    }

    #[test]
    fn fp16_emulation_rounds_the_sketch() {
        let cs = chunks(4, 30, 2, 45);
        let mut acc =
            make_accumulator(AccumKind::Sketch, 4, AccumBackend::Host, Precision::F16).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::Sketch { y, .. } = acc.finish() else { panic!("not Sketch") };
        for v in &y.data {
            assert_eq!(*v, Precision::F16.round(*v));
        }
    }

    #[test]
    fn fp16_emulation_rounds_the_gram() {
        let cs = chunks(4, 30, 2, 40);
        let mut acc =
            make_accumulator(AccumKind::Gram, 4, AccumBackend::Host, Precision::F16).unwrap();
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::Gram(g) = acc.finish() else { panic!("not Gram") };
        // every entry is representable in fp16
        for v in &g.data {
            assert_eq!(*v, Precision::F16.round(*v));
        }
    }

    #[test]
    fn sketch_cfg_defaults() {
        let cfg = SketchCfg::parse(None, None, None).unwrap();
        assert_eq!(cfg, SketchCfg::default());
        assert_eq!(cfg.seed, DEFAULT_SKETCH_SEED);
        assert_eq!(cfg.kind, SketchKind::Gaussian);
        // width-derived default: n/2 + 16 clamped to [1, n]
        assert_eq!(cfg.rows_for(8).unwrap(), 8);
        assert_eq!(cfg.rows_for(64).unwrap(), 48);
        assert_eq!(cfg.rows_for(0).unwrap(), 1);
    }

    #[test]
    fn sketch_cfg_accepts_explicit_knobs() {
        let cfg = SketchCfg::parse(Some("12"), Some("99"), Some("srht")).unwrap();
        assert_eq!(cfg.rows, Some(12));
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.kind, SketchKind::Srht);
        assert_eq!(cfg.rows_for(64).unwrap(), 12);
    }

    #[test]
    fn sketch_cfg_rejects_malformed_knobs() {
        // the pre-PR-7 parser silently fell back to defaults on these
        for bad in ["abc", "", "-3", "1.5"] {
            let e = SketchCfg::parse(Some(bad), None, None).unwrap_err();
            assert!(e.to_string().contains("COALA_SKETCH_ROWS"), "{bad:?}: {e}");
        }
        for bad in ["xyz", "", "-1"] {
            let e = SketchCfg::parse(None, Some(bad), None).unwrap_err();
            assert!(e.to_string().contains("COALA_SKETCH_SEED"), "{bad:?}: {e}");
        }
        for bad in ["gauss", "", "fast"] {
            let e = SketchCfg::parse(None, None, Some(bad)).unwrap_err();
            assert!(e.to_string().contains("COALA_SKETCH_KIND"), "{bad:?}: {e}");
        }
    }

    #[test]
    fn sketch_cfg_rejects_out_of_range_rows() {
        // the pre-PR-7 parser silently clamped these into [1, width]
        assert!(SketchCfg::parse(Some("0"), None, None).is_err());
        let cfg = SketchCfg::parse(Some("100"), None, None).unwrap();
        let e = cfg.rows_for(8).unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        // boundary values are fine
        assert_eq!(SketchCfg::parse(Some("8"), None, None).unwrap().rows_for(8).unwrap(), 8);
        assert_eq!(SketchCfg::parse(Some("1"), None, None).unwrap().rows_for(8).unwrap(), 1);
    }
}
