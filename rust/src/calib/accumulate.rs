//! Streaming calibration accumulators (the "accumulate" stage of the
//! pipeline), factored out of the coordinator so every driver — the
//! sequential pipeline, the overlapped scheduler, and the tree-TSQR
//! runner — folds chunks through one `fold_chunk`/`finish` interface.
//!
//! Three accumulation strategies exist, one per family of compression
//! methods (each [`crate::coala::compressor::Compressor`] declares which
//! one it needs):
//!
//! * **R factor** (COALA / α-family): out-of-core TSQR — fold each
//!   (B·T × n) chunk of Xᵀ into a square R with RᵀR = XXᵀ;
//! * **Gram** (SVD-LLM / CorDA): G ← G + chunkᵀ·chunk;
//! * **Scales** (ASVD): running Σ|x| and row count per input channel.
//!
//! Every accumulator runs on either backend: `Device` folds through the
//! PJRT artifacts (`runtime::ops`), `Host` through the pure-Rust linalg
//! (`linalg::tsqr::TsqrFolder`, `tensor::ops::gram_t`).  X itself is
//! never materialized on either route.

use crate::error::{Error, Result};
use crate::linalg::tsqr::TsqrFolder;
use crate::runtime::executor::Executor;
use crate::runtime::ops;
use crate::tensor::lowp::{quantize, Precision};
use crate::tensor::ops::gram_t;
use crate::tensor::Matrix;

/// Which accumulation strategy a compression method consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumKind {
    /// Square R with RᵀR = (seen X)(seen X)ᵀ (QR route).
    RFactor,
    /// G = Σ chunkᵀ·chunk (Gram route).
    Gram,
    /// Running Σ|x| and count per input channel (ASVD route).
    Scales,
    /// Context-free methods (plain SVD): nothing to accumulate.
    None,
}

/// Finished accumulator state — what the factorization stage consumes.
#[derive(Debug, Clone)]
pub enum CalibState {
    R(Matrix<f32>),
    Gram(Matrix<f32>),
    Scales { sum_abs: Vec<f64>, rows: usize },
    None,
}

impl CalibState {
    pub fn kind(&self) -> AccumKind {
        match self {
            CalibState::R(_) => AccumKind::RFactor,
            CalibState::Gram(_) => AccumKind::Gram,
            CalibState::Scales { .. } => AccumKind::Scales,
            CalibState::None => AccumKind::None,
        }
    }

    pub fn r(&self) -> Result<&Matrix<f32>> {
        match self {
            CalibState::R(r) => Ok(r),
            other => Err(Error::Config(format!(
                "method needs the R-factor route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }

    pub fn gram(&self) -> Result<&Matrix<f32>> {
        match self {
            CalibState::Gram(g) => Ok(g),
            other => Err(Error::Config(format!(
                "method needs the Gram route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }

    /// ASVD's per-channel scale rule: (mean |x| + ε)^{1/2}.
    pub fn asvd_scales(&self) -> Result<Vec<f32>> {
        match self {
            CalibState::Scales { sum_abs, rows } => Ok(sum_abs
                .iter()
                .map(|v| ((v / (*rows).max(1) as f64) as f32 + 1e-6).sqrt())
                .collect()),
            other => Err(Error::Config(format!(
                "method needs the scales route, accumulator holds {:?}",
                other.kind()
            ))),
        }
    }
}

/// Where folds execute.
#[derive(Clone, Copy)]
pub enum AccumBackend<'a> {
    /// Through the shape-specialized PJRT artifacts.
    Device(&'a Executor),
    /// Pure-Rust host linalg.
    Host,
}

/// One streaming accumulator: fold chunks, merge sibling states (tree
/// reduction), finish into a [`CalibState`].
pub trait CalibAccumulator {
    fn kind(&self) -> AccumKind;
    /// Fold one (rows × width) chunk of Xᵀ.
    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()>;
    /// Absorb the state of a sibling accumulator (tree reduction edge).
    fn merge_state(&mut self, other: CalibState) -> Result<()>;
    fn finish(self: Box<Self>) -> CalibState;
}

/// Build the accumulator a method requires, for `width`-channel chunks.
/// `precision` emulates the accumulation arithmetic (Table 2's fp16).
pub fn make_accumulator<'a>(
    kind: AccumKind,
    width: usize,
    backend: AccumBackend<'a>,
    precision: Precision,
) -> Box<dyn CalibAccumulator + 'a> {
    match kind {
        AccumKind::RFactor => Box::new(RAccumulator::new(width, backend, precision)),
        AccumKind::Gram => Box::new(GramAccumulator::new(width, backend, precision)),
        AccumKind::Scales => Box::new(ScalesAccumulator::new(width, precision)),
        AccumKind::None => Box::new(NullAccumulator),
    }
}

/// Re-open a finished state as an accumulator (resuming a stream, or
/// seeding a tree-reduction node).
pub fn make_accumulator_from<'a>(
    state: CalibState,
    backend: AccumBackend<'a>,
    precision: Precision,
) -> Box<dyn CalibAccumulator + 'a> {
    match state {
        CalibState::R(r) => Box::new(RAccumulator::from_r(r, backend, precision)),
        CalibState::Gram(g) => Box::new(GramAccumulator { backend, precision, g }),
        CalibState::Scales { sum_abs, rows } => {
            Box::new(ScalesAccumulator { precision, sum_abs, rows })
        }
        CalibState::None => Box::new(NullAccumulator),
    }
}

/// Merge two finished states (the tree-reduction edge as a free
/// function).  Seeds the accumulator from `a`, so each edge costs one
/// merge — one `tsqr_merge` launch / one QR — not two.
pub fn merge_states(
    a: CalibState,
    b: CalibState,
    backend: AccumBackend<'_>,
    precision: Precision,
) -> Result<CalibState> {
    let mut acc = make_accumulator_from(a, backend, precision);
    acc.merge_state(b)?;
    Ok(acc.finish())
}

// ---------------------------------------------------------------- R route

struct RAccumulator<'a> {
    backend: AccumBackend<'a>,
    precision: Precision,
    /// Device route: the running square R.
    r: Option<Matrix<f32>>,
    /// Host route: scratch-reusing streaming folder.
    folder: Option<TsqrFolder<f32>>,
}

impl<'a> RAccumulator<'a> {
    fn new(width: usize, backend: AccumBackend<'a>, precision: Precision) -> RAccumulator<'a> {
        match backend {
            AccumBackend::Device(_) => RAccumulator {
                backend,
                precision,
                r: Some(Matrix::zeros(width, width)),
                folder: None,
            },
            AccumBackend::Host => RAccumulator {
                backend,
                precision,
                r: None,
                folder: Some(TsqrFolder::new(width)),
            },
        }
    }

    /// Resume from an existing square R (no fold spent on the seed).
    fn from_r(r: Matrix<f32>, backend: AccumBackend<'a>, precision: Precision) -> RAccumulator<'a> {
        match backend {
            AccumBackend::Device(_) => RAccumulator { backend, precision, r: Some(r), folder: None },
            AccumBackend::Host => RAccumulator {
                backend,
                precision,
                r: None,
                folder: Some(TsqrFolder::from_r(&r)),
            },
        }
    }
}

impl CalibAccumulator for RAccumulator<'_> {
    fn kind(&self) -> AccumKind {
        AccumKind::RFactor
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        match self.backend {
            AccumBackend::Device(ex) => {
                let r = self.r.as_mut().expect("device R state");
                *r = ops::tsqr_step(ex, r, xt)?;
            }
            AccumBackend::Host => {
                self.folder.as_mut().expect("host folder").fold(xt)?;
            }
        }
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        let other = other.r()?.clone();
        match self.backend {
            AccumBackend::Device(ex) => {
                let r = self.r.as_mut().expect("device R state");
                *r = ops::tsqr_merge(ex, r, &other)?;
            }
            AccumBackend::Host => {
                self.folder.as_mut().expect("host folder").merge_r(&other)?;
            }
        }
        Ok(())
    }

    fn finish(self: Box<Self>) -> CalibState {
        match self.backend {
            AccumBackend::Device(_) => CalibState::R(self.r.expect("device R state")),
            AccumBackend::Host => CalibState::R(self.folder.expect("host folder").finish()),
        }
    }
}

// ------------------------------------------------------------- Gram route

struct GramAccumulator<'a> {
    backend: AccumBackend<'a>,
    precision: Precision,
    g: Matrix<f32>,
}

impl<'a> GramAccumulator<'a> {
    fn new(width: usize, backend: AccumBackend<'a>, precision: Precision) -> GramAccumulator<'a> {
        GramAccumulator { backend, precision, g: Matrix::zeros(width, width) }
    }

    fn post_round(&mut self) {
        if self.precision != Precision::F32 {
            self.g = quantize(&self.g, self.precision);
        }
    }
}

impl CalibAccumulator for GramAccumulator<'_> {
    fn kind(&self) -> AccumKind {
        AccumKind::Gram
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        match self.backend {
            AccumBackend::Device(ex) => self.g = ops::gram_update(ex, &self.g, xt)?,
            AccumBackend::Host => self.g = self.g.add(&gram_t(xt))?,
        }
        self.post_round();
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        self.g = self.g.add(other.gram()?)?;
        self.post_round();
        Ok(())
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::Gram(self.g)
    }
}

// ----------------------------------------------------------- Scales route

struct ScalesAccumulator {
    precision: Precision,
    sum_abs: Vec<f64>,
    rows: usize,
}

impl ScalesAccumulator {
    fn new(width: usize, precision: Precision) -> ScalesAccumulator {
        ScalesAccumulator { precision, sum_abs: vec![0.0; width], rows: 0 }
    }
}

impl CalibAccumulator for ScalesAccumulator {
    fn kind(&self) -> AccumKind {
        AccumKind::Scales
    }

    fn fold_chunk(&mut self, xt: &Matrix<f32>) -> Result<()> {
        if xt.cols != self.sum_abs.len() {
            return Err(Error::shape(format!(
                "scales fold: chunk has {} cols, accumulator is {}-wide",
                xt.cols,
                self.sum_abs.len()
            )));
        }
        let xt_q;
        let xt = if self.precision == Precision::F32 {
            xt
        } else {
            xt_q = quantize(xt, self.precision);
            &xt_q
        };
        for i in 0..xt.rows {
            for (j, acc) in self.sum_abs.iter_mut().enumerate() {
                *acc += xt.get(i, j).abs() as f64;
            }
        }
        self.rows += xt.rows;
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        match other {
            CalibState::Scales { sum_abs, rows } => {
                if sum_abs.len() != self.sum_abs.len() {
                    return Err(Error::shape("scales merge: width mismatch".into()));
                }
                for (a, b) in self.sum_abs.iter_mut().zip(&sum_abs) {
                    *a += b;
                }
                self.rows += rows;
                Ok(())
            }
            other => Err(Error::Config(format!(
                "scales merge: sibling holds {:?}",
                other.kind()
            ))),
        }
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::Scales { sum_abs: self.sum_abs, rows: self.rows }
    }
}

// ------------------------------------------------------------- Null route

struct NullAccumulator;

impl CalibAccumulator for NullAccumulator {
    fn kind(&self) -> AccumKind {
        AccumKind::None
    }

    fn fold_chunk(&mut self, _xt: &Matrix<f32>) -> Result<()> {
        Ok(())
    }

    fn merge_state(&mut self, other: CalibState) -> Result<()> {
        // refuse to silently discard a sibling's real statistics
        match other {
            CalibState::None => Ok(()),
            other => Err(Error::Config(format!(
                "null accumulator cannot absorb a {:?} sibling",
                other.kind()
            ))),
        }
    }

    fn finish(self: Box<Self>) -> CalibState {
        CalibState::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, matmul};

    fn chunks(n: usize, rows: usize, count: usize, seed: u64) -> Vec<Matrix<f32>> {
        (0..count).map(|i| Matrix::randn(rows, n, seed + i as u64)).collect()
    }

    fn full_stack(chunks: &[Matrix<f32>]) -> Matrix<f32> {
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        full
    }

    #[test]
    fn host_r_accumulator_satisfies_gram_identity() {
        let cs = chunks(7, 15, 4, 1);
        let mut acc = make_accumulator(AccumKind::RFactor, 7, AccumBackend::Host, Precision::F32);
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::R(r) = acc.finish() else { panic!("not R") };
        let got = matmul(&r.transpose(), &r).unwrap();
        let want = gram_t(&full_stack(&cs));
        assert!(fro(&got.sub(&want).unwrap()) < 1e-3 * fro(&want));
    }

    #[test]
    fn host_gram_accumulator_matches_direct() {
        let cs = chunks(6, 11, 3, 10);
        let mut acc = make_accumulator(AccumKind::Gram, 6, AccumBackend::Host, Precision::F32);
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::Gram(g) = acc.finish() else { panic!("not Gram") };
        let want = gram_t(&full_stack(&cs));
        assert!(fro(&g.sub(&want).unwrap()) < 1e-4 * fro(&want));
    }

    #[test]
    fn scales_accumulator_means_abs() {
        let cs = chunks(5, 8, 2, 20);
        let mut acc = make_accumulator(AccumKind::Scales, 5, AccumBackend::Host, Precision::F32);
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let state = acc.finish();
        let CalibState::Scales { sum_abs, rows } = &state else { panic!("not Scales") };
        assert_eq!(*rows, 16);
        let full = full_stack(&cs);
        for (j, s) in sum_abs.iter().enumerate() {
            let want: f64 = (0..full.rows).map(|i| full.get(i, j).abs() as f64).sum();
            assert!((s - want).abs() < 1e-4 * (1.0 + want));
        }
        let scales = state.asvd_scales().unwrap();
        assert_eq!(scales.len(), 5);
        assert!(scales.iter().all(|s| s.is_finite() && *s > 0.0));
    }

    #[test]
    fn merge_matches_single_stream() {
        // folding [c0, c1] sequentially == fold c0 | fold c1 then merge
        let cs = chunks(6, 9, 2, 30);
        for kind in [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales] {
            let mut seq = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32);
            seq.fold_chunk(&cs[0]).unwrap();
            seq.fold_chunk(&cs[1]).unwrap();
            let want = seq.finish();

            let mut a = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32);
            a.fold_chunk(&cs[0]).unwrap();
            let mut b = make_accumulator(kind, 6, AccumBackend::Host, Precision::F32);
            b.fold_chunk(&cs[1]).unwrap();
            let got = merge_states(a.finish(), b.finish(), AccumBackend::Host, Precision::F32)
                .unwrap();

            match (&want, &got) {
                (CalibState::R(rw), CalibState::R(rg)) => {
                    let gw = matmul(&rw.transpose(), rw).unwrap();
                    let gg = matmul(&rg.transpose(), rg).unwrap();
                    assert!(fro(&gw.sub(&gg).unwrap()) < 1e-3 * (1.0 + fro(&gw)));
                }
                (CalibState::Gram(gw), CalibState::Gram(gg)) => {
                    assert!(fro(&gw.sub(gg).unwrap()) < 1e-5 * (1.0 + fro(gw)));
                }
                (
                    CalibState::Scales { sum_abs: sw, rows: nw },
                    CalibState::Scales { sum_abs: sg, rows: ng },
                ) => {
                    assert_eq!(nw, ng);
                    for (a, b) in sw.iter().zip(sg) {
                        assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()));
                    }
                }
                _ => panic!("kind mismatch after merge"),
            }
        }
    }

    #[test]
    fn state_route_mismatch_reports() {
        let state = CalibState::Gram(Matrix::zeros(3, 3));
        assert!(state.r().is_err());
        assert!(state.asvd_scales().is_err());
        assert!(CalibState::None.gram().is_err());
    }

    #[test]
    fn null_merge_rejects_real_states() {
        let mut acc = make_accumulator(AccumKind::None, 0, AccumBackend::Host, Precision::F32);
        assert!(acc.merge_state(CalibState::None).is_ok());
        assert!(acc.merge_state(CalibState::Gram(Matrix::zeros(2, 2))).is_err());
    }

    #[test]
    fn seeded_accumulator_resumes_stream() {
        // make_accumulator_from(state) ≡ continuing the original stream
        let cs = chunks(6, 9, 3, 60);
        let mut full = make_accumulator(AccumKind::RFactor, 6, AccumBackend::Host, Precision::F32);
        for c in &cs {
            full.fold_chunk(c).unwrap();
        }
        let want = full.finish();

        let mut first = make_accumulator(AccumKind::RFactor, 6, AccumBackend::Host, Precision::F32);
        first.fold_chunk(&cs[0]).unwrap();
        let mut resumed =
            make_accumulator_from(first.finish(), AccumBackend::Host, Precision::F32);
        resumed.fold_chunk(&cs[1]).unwrap();
        resumed.fold_chunk(&cs[2]).unwrap();
        let got = resumed.finish();

        let gw = matmul(&want.r().unwrap().transpose(), want.r().unwrap()).unwrap();
        let gg = matmul(&got.r().unwrap().transpose(), got.r().unwrap()).unwrap();
        assert!(fro(&gw.sub(&gg).unwrap()) < 1e-3 * (1.0 + fro(&gw)));
    }

    #[test]
    fn fp16_emulation_rounds_the_gram() {
        let cs = chunks(4, 30, 2, 40);
        let mut acc = make_accumulator(AccumKind::Gram, 4, AccumBackend::Host, Precision::F16);
        for c in &cs {
            acc.fold_chunk(c).unwrap();
        }
        let CalibState::Gram(g) = acc.finish() else { panic!("not Gram") };
        // every entry is representable in fp16
        for v in &g.data {
            assert_eq!(*v, Precision::F16.round(*v));
        }
    }
}
