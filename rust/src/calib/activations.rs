//! Activation capture: one `fwd_acts` execution → per-stream calibration
//! chunks, each a (B·T × width) row-block of Xᵀ ready for TSQR / Gram
//! streaming.

use crate::calib::dataset::Corpus;
use crate::error::{Error, Result};
use crate::model::weights::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::Matrix;

/// Anything that can produce the per-(layer, stream) calibration chunks
/// of one forward batch.  Two implementations exist: the device capture
/// (`fwd_acts` artifacts, [`DeviceActivationSource`]) and the synthetic
/// PRNG generator ([`crate::calib::synthetic::SyntheticActivations`]),
/// which needs no artifacts at all.  The execution engine
/// (`coordinator::engine`) folds chunks from a source without knowing
/// which one it is; `Sync` is a supertrait because the engine shares
/// one source across its capture workers.
pub trait ActivationSource: Sync {
    /// Chunks for calibration batch `b` — one per (layer, stream) of the
    /// model spec.  Must be deterministic in `b`.
    fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>>;
}

/// The calibration rows for one (layer, stream) from one batch.
#[derive(Debug)]
pub struct CalibChunk {
    pub layer: usize,
    pub stream: String,
    /// (B·T × width) — rows are activation vectors (Xᵀ chunk).
    pub xt: Matrix<f32>,
}

/// Runs `fwd_acts_<cfg>` and splits the outputs into calibration chunks.
pub struct ActivationCapture<'a> {
    pub ex: &'a Executor,
    pub spec: &'a ModelSpec,
    artifact: String,
}

impl<'a> ActivationCapture<'a> {
    pub fn new(ex: &'a Executor, spec: &'a ModelSpec) -> ActivationCapture<'a> {
        ActivationCapture { ex, spec, artifact: format!("fwd_acts_{}", spec.name) }
    }

    /// Forward one token batch; returns (logits value, chunks).
    ///
    /// Output ABI (aot.py): [logits, l0.attn, l0.o, l0.up, l0.down,
    /// l1.attn, …] — layer-major, stream order = spec.act_streams.
    pub fn capture(&self, tokens: &Value, weights: &ModelWeights) -> Result<(Value, Vec<CalibChunk>)> {
        let mut inputs = vec![tokens.clone()];
        inputs.extend(weights.to_values(self.spec)?);
        let mut out = self.ex.run(&self.artifact, &inputs)?;
        if out.len() != 1 + self.spec.n_layers * self.spec.act_streams.len() {
            return Err(Error::shape(format!(
                "fwd_acts returned {} outputs",
                out.len()
            )));
        }
        let rest = out.split_off(1);
        let logits = out.pop().unwrap();
        let rows = self.spec.batch * self.spec.seq_len;
        let mut chunks = Vec::with_capacity(rest.len());
        for (idx, v) in rest.into_iter().enumerate() {
            let layer = idx / self.spec.act_streams.len();
            let stream = self.spec.act_streams[idx % self.spec.act_streams.len()].clone();
            let dims = v.dims().to_vec();
            if dims.len() != 3 || dims[0] * dims[1] != rows {
                return Err(Error::shape(format!("activation dims {dims:?}")));
            }
            let width = dims[2];
            // (B, T, width) row-major flattens directly to (B·T, width)
            let xt = Matrix::from_vec(rows, width, v.f32s()?.to_vec())?;
            chunks.push(CalibChunk { layer, stream, xt });
        }
        Ok((logits, chunks))
    }

    /// Which (layer, stream) chunk feeds a given projection name.
    pub fn chunk_for<'c>(
        &self,
        chunks: &'c [CalibChunk],
        proj: &str,
    ) -> Result<&'c CalibChunk> {
        chunk_for_proj(self.spec, chunks, proj)
    }
}

/// Which (layer, stream) chunk feeds a given projection name — free
/// function so sources without an executor (the synthetic route) share
/// the exact routing rule.
pub fn chunk_for_proj<'c>(
    spec: &ModelSpec,
    chunks: &'c [CalibChunk],
    proj: &str,
) -> Result<&'c CalibChunk> {
    let layer: usize = proj
        .strip_prefix('l')
        .and_then(|s| s.split('.').next())
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Config(format!("bad projection name `{proj}`")))?;
    let stream = spec.stream_of(proj)?;
    chunks
        .iter()
        .find(|c| c.layer == layer && c.stream == stream)
        .ok_or_else(|| Error::Config(format!("no chunk for `{proj}`")))
}

/// The device-backed [`ActivationSource`]: token batches from a corpus
/// split forwarded through the `fwd_acts` artifact.
pub struct DeviceActivationSource<'a> {
    cap: ActivationCapture<'a>,
    weights: &'a ModelWeights,
    tokens: Vec<Value>,
}

impl<'a> DeviceActivationSource<'a> {
    pub fn new(
        ex: &'a Executor,
        spec: &'a ModelSpec,
        weights: &'a ModelWeights,
        corpus: &Corpus,
        split: &str,
        batches: usize,
    ) -> Result<DeviceActivationSource<'a>> {
        let tokens = corpus.batches(split, spec.batch, spec.seq_len, batches)?;
        Ok(DeviceActivationSource { cap: ActivationCapture::new(ex, spec), weights, tokens })
    }

    /// Source over pre-built token batches (the overlapped scheduler's
    /// entry point, where batches arrive already assembled).
    pub fn from_batches(
        ex: &'a Executor,
        spec: &'a ModelSpec,
        weights: &'a ModelWeights,
        tokens: Vec<Value>,
    ) -> DeviceActivationSource<'a> {
        DeviceActivationSource { cap: ActivationCapture::new(ex, spec), weights, tokens }
    }
}

impl ActivationSource for DeviceActivationSource<'_> {
    fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
        let tokens = self.tokens.get(b).ok_or_else(|| {
            Error::Config(format!(
                "calibration batch {b} beyond the {} loaded token batches",
                self.tokens.len()
            ))
        })?;
        Ok(self.cap.capture(tokens, self.weights)?.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::Corpus;

    fn setup() -> Option<(Executor, Corpus)> {
        if !crate::runtime::require_artifacts("activations::setup") {
            return None;
        }
        Some((Executor::new("artifacts").unwrap(), Corpus::load("artifacts").unwrap()))
    }

    #[test]
    fn captures_all_streams_with_sane_stats() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let cap = ActivationCapture::new(&ex, &spec);
        let tokens = corpus.batches("calib", spec.batch, spec.seq_len, 1).unwrap();
        let (logits, chunks) = cap.capture(&tokens[0], &w).unwrap();
        assert_eq!(logits.dims(), &[spec.batch, spec.seq_len, spec.vocab]);
        assert_eq!(chunks.len(), spec.n_layers * 4);
        for c in &chunks {
            assert!(c.xt.all_finite(), "layer {} {}", c.layer, c.stream);
            let width = if c.stream == "down" { spec.d_ff } else { spec.d_model };
            assert_eq!(c.xt.cols, width);
            assert_eq!(c.xt.rows, spec.batch * spec.seq_len);
            // real activations are not all-zero
            let norm = crate::tensor::ops::fro(&c.xt);
            assert!(norm > 1.0, "layer {} {} norm {norm}", c.layer, c.stream);
        }
        // routing
        let q = cap.chunk_for(&chunks, "l2.wq").unwrap();
        assert_eq!((q.layer, q.stream.as_str()), (2, "attn"));
        let d = cap.chunk_for(&chunks, "l0.w_down").unwrap();
        assert_eq!((d.layer, d.stream.as_str()), (0, "down"));
        assert!(cap.chunk_for(&chunks, "garbage").is_err());
    }

    #[test]
    fn logits_match_fwd_logits_artifact() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let cap = ActivationCapture::new(&ex, &spec);
        let tokens = corpus.batches("calib", spec.batch, spec.seq_len, 1).unwrap();
        let (logits_a, _) = cap.capture(&tokens[0], &w).unwrap();
        let mut inputs = vec![tokens[0].clone()];
        inputs.extend(w.to_values(&spec).unwrap());
        let logits_b = ex.run(&format!("fwd_logits_{}", spec.name), &inputs).unwrap();
        let a = logits_a.f32s().unwrap();
        let b = logits_b[0].f32s().unwrap();
        for (x, y) in a.iter().zip(b).step_by(97) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
