//! Corpus + probe-task banks (generated deterministically at build time
//! by `python/compile/data.py`, shipped as CBT).

use crate::error::{Error, Result};
use crate::runtime::cbt::Cbt;
use crate::runtime::executor::Value;
use crate::util::prng::Rng;

/// Token streams: train / val / calib / ft_train / ft_calib.
#[derive(Debug)]
pub struct Corpus {
    pub splits: std::collections::BTreeMap<String, Vec<i32>>,
}

impl Corpus {
    pub fn load(dir: &str) -> Result<Corpus> {
        let cbt = Cbt::load(&format!("{dir}/corpus.cbt"))?;
        let mut splits = std::collections::BTreeMap::new();
        for (name, t) in &cbt.tensors {
            splits.insert(name.clone(), t.i32s()?.to_vec());
        }
        Ok(Corpus { splits })
    }

    pub fn split(&self, name: &str) -> Result<&[i32]> {
        self.splits
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Config(format!("no corpus split `{name}`")))
    }

    /// Deterministic sequential batches of shape (batch, seq_len) used
    /// for calibration forward passes.
    pub fn batches(&self, split: &str, batch: usize, seq_len: usize, count: usize) -> Result<Vec<Value>> {
        let s = self.split(split)?;
        let need = batch * seq_len;
        if s.len() < need {
            return Err(Error::Config(format!("split `{split}` too small: {}", s.len())));
        }
        let mut out = Vec::with_capacity(count);
        for b in 0..count {
            let start = (b * need) % (s.len() - need + 1);
            out.push(Value::I32(vec![batch, seq_len], s[start..start + need].to_vec()));
        }
        Ok(out)
    }

    /// Random (seeded) batches with one extra token (LM targets) — the
    /// fine-tuning feed.
    pub fn train_batches(
        &self,
        split: &str,
        batch: usize,
        seq_len: usize,
        count: usize,
        seed: u64,
    ) -> Result<Vec<Value>> {
        let s = self.split(split)?;
        let win = seq_len + 1;
        if s.len() < win + 1 {
            return Err(Error::Config(format!("split `{split}` too small")));
        }
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let mut data = Vec::with_capacity(batch * win);
            for _ in 0..batch {
                let start = rng.below(s.len() - win);
                data.extend_from_slice(&s[start..start + win]);
            }
            out.push(Value::I32(vec![batch, win], data));
        }
        Ok(out)
    }
}

/// One probe-task bank: contexts ending with an (s, p) fact query and
/// four candidate objects.
#[derive(Debug)]
pub struct TaskBank {
    pub contexts: Vec<i32>, // (n, seq_len) row-major
    pub choices: Vec<i32>,  // (n, 4)
    pub labels: Vec<i32>,   // (n,)
    pub task_ids: Vec<i32>, // (n,)
    pub n: usize,
    pub seq_len: usize,
    pub task_names: Vec<String>,
}

impl TaskBank {
    /// `which` ∈ {"base", "ft"}.
    pub fn load(dir: &str, which: &str, task_names: &[String]) -> Result<TaskBank> {
        let cbt = Cbt::load(&format!("{dir}/tasks.cbt"))?;
        let ctx = cbt.get(&format!("{which}.contexts"))?;
        let dims = ctx.dims().to_vec();
        Ok(TaskBank {
            contexts: ctx.i32s()?.to_vec(),
            choices: cbt.get(&format!("{which}.choices"))?.i32s()?.to_vec(),
            labels: cbt.get(&format!("{which}.labels"))?.i32s()?.to_vec(),
            task_ids: cbt.get(&format!("{which}.task_ids"))?.i32s()?.to_vec(),
            n: dims[0],
            seq_len: dims[1],
            task_names: task_names.to_vec(),
        })
    }

    pub fn context(&self, i: usize) -> &[i32] {
        &self.contexts[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn choice_row(&self, i: usize) -> &[i32] {
        &self.choices[i * 4..(i + 1) * 4]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have() -> bool {
        std::path::Path::new("artifacts/corpus.cbt").exists()
    }

    #[test]
    fn corpus_splits_present() {
        if !have() {
            return;
        }
        let c = Corpus::load("artifacts").unwrap();
        for s in ["train", "val", "calib", "ft_train", "ft_calib"] {
            assert!(c.split(s).unwrap().len() > 1000, "{s}");
        }
        assert!(c.split("nope").is_err());
    }

    #[test]
    fn batches_shapes_and_determinism() {
        if !have() {
            return;
        }
        let c = Corpus::load("artifacts").unwrap();
        let b1 = c.batches("calib", 8, 128, 4).unwrap();
        let b2 = c.batches("calib", 8, 128, 4).unwrap();
        assert_eq!(b1.len(), 4);
        for (x, y) in b1.iter().zip(&b2) {
            assert_eq!(x.dims(), &[8, 128]);
            match (x, y) {
                (Value::I32(_, a), Value::I32(_, b)) => assert_eq!(a, b),
                _ => panic!(),
            }
        }
        let t = c.train_batches("ft_train", 4, 16, 3, 42).unwrap();
        assert_eq!(t[0].dims(), &[4, 17]);
    }

    #[test]
    fn task_bank_well_formed() {
        if !have() {
            return;
        }
        let names: Vec<String> = (0..8).map(|i| format!("t{i}")).collect();
        for which in ["base", "ft"] {
            let tb = TaskBank::load("artifacts", which, &names).unwrap();
            assert!(tb.n >= 100);
            assert_eq!(tb.labels.len(), tb.n);
            for i in 0..tb.n {
                let lab = tb.labels[i];
                assert!((0..4).contains(&lab));
                let row = tb.choice_row(i);
                assert_eq!(row.len(), 4);
                // context's last two tokens are the (s, p) query
                let ctx = tb.context(i);
                assert_eq!(ctx.len(), tb.seq_len);
            }
        }
    }
}
