//! Corpus + probe-task banks: loaded from CBT artifacts (generated
//! deterministically at build time by `python/compile/data.py`), or
//! generated in-memory from a seeded Markov chain for the artifact-free
//! synthetic environment (`repro --route host`).

use crate::error::{Error, Result};
use crate::runtime::cbt::Cbt;
use crate::runtime::executor::Value;
use crate::util::prng::Rng;

/// The synthetic corpus' token process: a first-order Markov chain with
/// two preferred successors per token plus a uniform-noise floor.  The
/// `shifted` variant (the ft_* splits and the "ft" task bank) uses
/// different successor maps, so a model whose head matches the base
/// chain is near chance on the shifted facts — the Table 4 adaptation
/// gap, synthesized.
///
/// Returns the two (successor, probability) pairs; the residual
/// probability mass is uniform over the vocabulary.
pub fn markov_successors(token: usize, vocab: usize, shifted: bool) -> [(usize, f64); 2] {
    if shifted {
        [((3 * token + 17) % vocab, 0.55), ((5 * token + 29) % vocab, 0.30)]
    } else {
        [((3 * token + 7) % vocab, 0.55), ((5 * token + 11) % vocab, 0.30)]
    }
}

/// The chain's most likely successor (the probe tasks' ground truth).
pub fn markov_top(token: usize, vocab: usize, shifted: bool) -> usize {
    markov_successors(token, vocab, shifted)[0].0
}

/// One sampled step of the chain.
fn markov_next(token: usize, vocab: usize, shifted: bool, rng: &mut Rng) -> usize {
    let [(s0, p0), (s1, p1)] = markov_successors(token, vocab, shifted);
    let u = rng.uniform();
    if u < p0 {
        s0
    } else if u < p0 + p1 {
        s1
    } else {
        rng.below(vocab)
    }
}

/// One seeded random walk of the chain.
fn markov_walk(vocab: usize, len: usize, shifted: bool, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut tok = rng.below(vocab);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(tok as i32);
        tok = markov_next(tok, vocab, shifted, &mut rng);
    }
    out
}

/// Token streams: train / val / calib / ft_train / ft_calib.
#[derive(Debug)]
pub struct Corpus {
    pub splits: std::collections::BTreeMap<String, Vec<i32>>,
}

impl Corpus {
    /// Deterministic in-memory corpus for the synthetic environment: the
    /// standard five splits, no files.  train/val/calib follow the base
    /// Markov chain; ft_train/ft_calib follow the shifted one.
    pub fn synthetic(vocab: usize, split_len: usize, seed: u64) -> Corpus {
        let mut splits = std::collections::BTreeMap::new();
        for (i, (name, shifted)) in [
            ("train", false),
            ("val", false),
            ("calib", false),
            ("ft_train", true),
            ("ft_calib", true),
        ]
        .into_iter()
        .enumerate()
        {
            let walk = markov_walk(vocab, split_len, shifted, seed ^ (0x5EED_0 + i as u64));
            splits.insert(name.to_string(), walk);
        }
        Corpus { splits }
    }

    pub fn load(dir: &str) -> Result<Corpus> {
        let cbt = Cbt::load(&format!("{dir}/corpus.cbt"))?;
        let mut splits = std::collections::BTreeMap::new();
        for (name, t) in &cbt.tensors {
            splits.insert(name.clone(), t.i32s()?.to_vec());
        }
        Ok(Corpus { splits })
    }

    pub fn split(&self, name: &str) -> Result<&[i32]> {
        self.splits
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Config(format!("no corpus split `{name}`")))
    }

    /// Deterministic sequential batches of shape (batch, seq_len) used
    /// for calibration forward passes.
    pub fn batches(&self, split: &str, batch: usize, seq_len: usize, count: usize) -> Result<Vec<Value>> {
        let s = self.split(split)?;
        let need = batch * seq_len;
        if s.len() < need {
            return Err(Error::Config(format!("split `{split}` too small: {}", s.len())));
        }
        let mut out = Vec::with_capacity(count);
        for b in 0..count {
            let start = (b * need) % (s.len() - need + 1);
            out.push(Value::I32(vec![batch, seq_len], s[start..start + need].to_vec()));
        }
        Ok(out)
    }

    /// Random (seeded) batches with one extra token (LM targets) — the
    /// fine-tuning feed.
    pub fn train_batches(
        &self,
        split: &str,
        batch: usize,
        seq_len: usize,
        count: usize,
        seed: u64,
    ) -> Result<Vec<Value>> {
        let s = self.split(split)?;
        let win = seq_len + 1;
        if s.len() < win + 1 {
            return Err(Error::Config(format!("split `{split}` too small")));
        }
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let mut data = Vec::with_capacity(batch * win);
            for _ in 0..batch {
                let start = rng.below(s.len() - win);
                data.extend_from_slice(&s[start..start + win]);
            }
            out.push(Value::I32(vec![batch, win], data));
        }
        Ok(out)
    }
}

/// One probe-task bank: contexts ending with an (s, p) fact query and
/// four candidate objects.
#[derive(Debug)]
pub struct TaskBank {
    pub contexts: Vec<i32>, // (n, seq_len) row-major
    pub choices: Vec<i32>,  // (n, 4)
    pub labels: Vec<i32>,   // (n,)
    pub task_ids: Vec<i32>, // (n,)
    pub n: usize,
    pub seq_len: usize,
    pub task_names: Vec<String>,
}

impl TaskBank {
    /// Deterministic in-memory bank for the synthetic environment.
    /// Every row is a Markov-chain context whose last token is the query
    /// `s`; the four choices contain the chain's most likely successor
    /// of `s` (the label) plus three distinct distractors.  `which` ∈
    /// {"base", "ft"}: the ft bank queries the *shifted* chain, so a
    /// base-chain model sits near chance on it.
    pub fn synthetic(
        vocab: usize,
        seq_len: usize,
        which: &str,
        task_names: &[String],
        n: usize,
        seed: u64,
    ) -> Result<TaskBank> {
        let shifted = match which {
            "base" => false,
            "ft" => true,
            other => {
                return Err(Error::Config(format!("task bank is `base` or `ft`, got `{other}`")))
            }
        };
        let mut rng = Rng::new(seed ^ if shifted { 0xF7BA_4C } else { 0xBA5E_7A } );
        let mut contexts = Vec::with_capacity(n * seq_len);
        let mut choices = Vec::with_capacity(n * 4);
        let mut labels = Vec::with_capacity(n);
        let mut task_ids = Vec::with_capacity(n);
        let n_tasks = task_names.len().max(1);
        for i in 0..n {
            let ctx = markov_walk(vocab, seq_len, shifted, seed ^ (0x7A5C_0000 + i as u64));
            let query = *ctx.last().unwrap() as usize;
            contexts.extend_from_slice(&ctx);
            let answer = markov_top(query, vocab, shifted);
            // three distinct distractors, none equal to the answer
            let mut row = vec![answer];
            while row.len() < 4 {
                let d = rng.below(vocab);
                if !row.contains(&d) {
                    row.push(d);
                }
            }
            let label = rng.below(4);
            row.swap(0, label);
            choices.extend(row.iter().map(|&c| c as i32));
            labels.push(label as i32);
            task_ids.push((i % n_tasks) as i32);
        }
        Ok(TaskBank {
            contexts,
            choices,
            labels,
            task_ids,
            n,
            seq_len,
            task_names: task_names.to_vec(),
        })
    }

    /// `which` ∈ {"base", "ft"}.
    pub fn load(dir: &str, which: &str, task_names: &[String]) -> Result<TaskBank> {
        let cbt = Cbt::load(&format!("{dir}/tasks.cbt"))?;
        let ctx = cbt.get(&format!("{which}.contexts"))?;
        let dims = ctx.dims().to_vec();
        Ok(TaskBank {
            contexts: ctx.i32s()?.to_vec(),
            choices: cbt.get(&format!("{which}.choices"))?.i32s()?.to_vec(),
            labels: cbt.get(&format!("{which}.labels"))?.i32s()?.to_vec(),
            task_ids: cbt.get(&format!("{which}.task_ids"))?.i32s()?.to_vec(),
            n: dims[0],
            seq_len: dims[1],
            task_names: task_names.to_vec(),
        })
    }

    pub fn context(&self, i: usize) -> &[i32] {
        &self.contexts[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn choice_row(&self, i: usize) -> &[i32] {
        &self.choices[i * 4..(i + 1) * 4]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have() -> bool {
        if std::path::Path::new("artifacts/corpus.cbt").exists() {
            true
        } else {
            eprintln!("skipped: dataset artifact test (artifacts/corpus.cbt not present)");
            false
        }
    }

    #[test]
    fn corpus_splits_present() {
        if !have() {
            return;
        }
        let c = Corpus::load("artifacts").unwrap();
        for s in ["train", "val", "calib", "ft_train", "ft_calib"] {
            assert!(c.split(s).unwrap().len() > 1000, "{s}");
        }
        assert!(c.split("nope").is_err());
    }

    #[test]
    fn batches_shapes_and_determinism() {
        if !have() {
            return;
        }
        let c = Corpus::load("artifacts").unwrap();
        let b1 = c.batches("calib", 8, 128, 4).unwrap();
        let b2 = c.batches("calib", 8, 128, 4).unwrap();
        assert_eq!(b1.len(), 4);
        for (x, y) in b1.iter().zip(&b2) {
            assert_eq!(x.dims(), &[8, 128]);
            match (x, y) {
                (Value::I32(_, a), Value::I32(_, b)) => assert_eq!(a, b),
                _ => panic!(),
            }
        }
        let t = c.train_batches("ft_train", 4, 16, 3, 42).unwrap();
        assert_eq!(t[0].dims(), &[4, 17]);
    }

    #[test]
    fn synthetic_corpus_deterministic_and_complete() {
        let a = Corpus::synthetic(64, 2048, 7);
        let b = Corpus::synthetic(64, 2048, 7);
        for s in ["train", "val", "calib", "ft_train", "ft_calib"] {
            let sa = a.split(s).unwrap();
            assert_eq!(sa, b.split(s).unwrap(), "{s}");
            assert_eq!(sa.len(), 2048);
            assert!(sa.iter().all(|&t| (0..64).contains(&t)));
        }
        // base and shifted chains are different processes
        assert_ne!(a.split("calib").unwrap(), a.split("ft_calib").unwrap());
        // batching works without artifacts
        let batches = a.batches("calib", 4, 16, 3).unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].dims(), &[4, 16]);
    }

    #[test]
    fn synthetic_corpus_follows_its_chain() {
        // the top successor must be the most frequent bigram continuation
        let c = Corpus::synthetic(64, 8192, 3);
        let s = c.split("train").unwrap();
        let (mut hit, mut total) = (0usize, 0usize);
        for w in s.windows(2) {
            total += 1;
            if w[1] as usize == markov_top(w[0] as usize, 64, false) {
                hit += 1;
            }
        }
        let frac = hit as f64 / total as f64;
        assert!(frac > 0.4 && frac < 0.7, "top-successor frequency {frac}");
    }

    #[test]
    fn synthetic_task_bank_well_formed() {
        let names: Vec<String> = (0..8).map(|i| format!("t{i}")).collect();
        for which in ["base", "ft"] {
            let tb = TaskBank::synthetic(64, 16, which, &names, 160, 11).unwrap();
            assert_eq!(tb.n, 160);
            assert_eq!(tb.seq_len, 16);
            assert_eq!(tb.labels.len(), 160);
            for i in 0..tb.n {
                let lab = tb.labels[i] as usize;
                assert!(lab < 4);
                let row = tb.choice_row(i);
                // choices distinct, label slot holds the chain's answer
                let mut sorted = row.to_vec();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), 4, "duplicate choices in row {i}");
                let query = *tb.context(i).last().unwrap() as usize;
                assert_eq!(
                    row[lab] as usize,
                    markov_top(query, 64, which == "ft"),
                    "row {i} of {which}"
                );
            }
        }
        assert!(TaskBank::synthetic(64, 16, "nope", &names, 8, 1).is_err());
    }

    #[test]
    fn task_bank_well_formed() {
        if !have() {
            return;
        }
        let names: Vec<String> = (0..8).map(|i| format!("t{i}")).collect();
        for which in ["base", "ft"] {
            let tb = TaskBank::load("artifacts", which, &names).unwrap();
            assert!(tb.n >= 100);
            assert_eq!(tb.labels.len(), tb.n);
            for i in 0..tb.n {
                let lab = tb.labels[i];
                assert!((0..4).contains(&lab));
                let row = tb.choice_row(i);
                assert_eq!(row.len(), 4);
                // context's last two tokens are the (s, p) query
                let ctx = tb.context(i);
                assert_eq!(ctx.len(), tb.seq_len);
            }
        }
    }
}
