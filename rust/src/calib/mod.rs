//! Calibration data plumbing (S11): corpus, batching, activation
//! capture through the `fwd_acts` artifact, and the streaming
//! accumulators every compression method folds its chunks through.

pub mod accumulate;
pub mod activations;
pub mod dataset;

pub use accumulate::{
    make_accumulator, make_accumulator_from, merge_states, AccumBackend, AccumKind,
    CalibAccumulator, CalibState,
};
pub use activations::{ActivationCapture, CalibChunk};
pub use dataset::{Corpus, TaskBank};
