//! Calibration data plumbing (S11): corpus, batching, activation
//! capture (through the `fwd_acts` artifact on the device route, or the
//! PRNG generator on the synthetic host route), the streaming
//! accumulators every compression method folds its chunks through, and
//! the binary state codec ([`state`]) that makes accumulator states
//! durable and mergeable across processes.

pub mod accumulate;
pub mod activations;
pub mod dataset;
pub mod state;
pub mod synthetic;

pub use accumulate::{
    make_accumulator, make_accumulator_from, make_leaf_accumulator, merge_states, AccumBackend,
    AccumKind, CalibAccumulator, CalibState, SketchCfg, SketchKind,
};
pub use activations::{ActivationCapture, ActivationSource, CalibChunk, DeviceActivationSource};
pub use dataset::{Corpus, TaskBank};
pub use state::{ShardState, StateNode};
pub use synthetic::SyntheticActivations;
