//! Calibration data plumbing (S11): corpus, batching, and activation
//! capture through the `fwd_acts` artifact.

pub mod activations;
pub mod dataset;

pub use activations::{ActivationCapture, CalibChunk};
pub use dataset::{Corpus, TaskBank};
