//! `calib::state` — the versioned binary codec that makes accumulator
//! states durable and mergeable across processes.
//!
//! Everything the engine's merge tree passes between workers in RAM can
//! be written to disk and read back **bit-exactly**: the four
//! [`CalibState`] merge states (TSQR R, range-finder sketch, streamed
//! Gram, activation scales), compressed factor outputs
//! ([`CompressedModel`]), and
//! fine-tuning adapters ([`AdapterSet`]).  Floats are serialized as
//! their IEEE-754 bit patterns (`to_bits`/`from_bits`, little-endian),
//! so NaN payloads, infinities, and signed zeros round-trip unchanged —
//! the determinism guarantees of `coordinator::engine` extend across a
//! serialize/deserialize boundary, which is what lets N `coala shard`
//! processes plus one `coala merge` reproduce the single-process run
//! bitwise.
//!
//! ## File format
//!
//! Every file starts with a fixed header:
//!
//! ```text
//!   magic   [4]  = b"CALS"
//!   version u16  = 1            (little-endian)
//!   payload u8   — 1 shard state, 2 factors, 3 adapters
//! ```
//!
//! followed by the payload.  Unknown magic, a different version, or a
//! payload-kind mismatch are rejected with the offending file named
//! ([`crate::error::Error::Format`]); filesystem failures carry their
//! path ([`crate::error::Error::io`]).  Writes go through a temp file +
//! rename, so a kill mid-write never leaves a torn state file — the
//! property checkpoint/resume relies on.
//!
//! The shard-state payload is the unit of multi-process calibration: a
//! [`ShardState`] holds the *pending merge-tree nodes* of a batch range
//! `[start, done)` of a `total`-batch run — exactly what
//! `coordinator::engine` holds in RAM mid-run.  `done == end` marks a
//! complete shard (what `coala shard` emits); `done < end` is a resume
//! checkpoint.

use crate::calib::accumulate::{AccumKind, CalibState, SketchKind};
use crate::coala::factorize::Factors;
use crate::error::{Error, Result};
use crate::finetune::AdapterSet;
use crate::model::{CompressedModel, ModelWeights};
use crate::tensor::lowp::Precision;
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use std::path::Path;

/// File magic: "CALibration State".
pub const MAGIC: [u8; 4] = *b"CALS";
/// Codec version this build reads and writes.  Bumped 1 → 2 when the
/// sketch payload gained its Ω-family byte ([`SketchKind`]) — version-1
/// sketch states are ambiguous about the family, so they are refused
/// rather than guessed.
pub const VERSION: u16 = 2;

const PAYLOAD_SHARD: u8 = 1;
const PAYLOAD_FACTORS: u8 = 2;
const PAYLOAD_ADAPTERS: u8 = 3;

fn payload_name(p: u8) -> &'static str {
    match p {
        PAYLOAD_SHARD => "shard state",
        PAYLOAD_FACTORS => "factors",
        PAYLOAD_ADAPTERS => "adapters",
        _ => "unknown",
    }
}

// ------------------------------------------------------------- writer

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(payload: u8) -> Writer {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(payload);
        Writer { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn size(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.size(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.size(v.len());
        for &x in v {
            self.f32(x);
        }
    }
    fn f64s(&mut self, v: &[f64]) {
        self.size(v.len());
        for &x in v {
            self.f64(x);
        }
    }
    fn matrix(&mut self, m: &Matrix<f32>) {
        self.size(m.rows);
        self.size(m.cols);
        for &x in &m.data {
            self.f32(x);
        }
    }
}

// ------------------------------------------------------------- reader

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// where the bytes came from (file path or "<memory>") — every
    /// decode error names it.
    src: &'a str,
}

impl<'a> Reader<'a> {
    /// Validate the magic/version/payload header and position the
    /// reader at the payload.
    fn open(buf: &'a [u8], src: &'a str, payload: u8) -> Result<Reader<'a>> {
        let mut r = Reader { buf, pos: 0, src };
        let magic = r.bytes(4, "magic")?;
        if magic != &MAGIC[..] {
            return Err(r.err("not a COALA state file (bad magic)"));
        }
        let version = u16::from_le_bytes(r.bytes(2, "version")?.try_into().unwrap());
        if version != VERSION {
            return Err(r.err(format!(
                "state-codec version {version} (this build reads version {VERSION})"
            )));
        }
        let got = r.u8("payload kind")?;
        if got != payload {
            return Err(r.err(format!(
                "payload is {} (kind {got}), expected {} (kind {payload})",
                payload_name(got),
                payload_name(payload)
            )));
        }
        Ok(r)
    }

    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Format { path: self.src.to_string(), msg: msg.into() }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| self.err(format!("truncated: {what} needs {n} more bytes")))?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.bytes(1, what)?[0])
    }
    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }
    fn size(&mut self, what: &str) -> Result<usize> {
        let v = self.u64(what)?;
        usize::try_from(v).map_err(|_| self.err(format!("{what} {v} overflows usize")))
    }
    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_bits(self.u32(what)?))
    }
    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.size(what)?;
        let raw = self.bytes(n, what)?;
        String::from_utf8(raw.to_vec()).map_err(|_| self.err(format!("{what} is not UTF-8")))
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.size(what)?;
        // bound before allocating: each element is 4 bytes
        if n > self.buf.len() - self.pos.min(self.buf.len()) {
            return Err(self.err(format!("truncated: {what} claims {n} elements")));
        }
        (0..n).map(|_| self.f32(what)).collect()
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.size(what)?;
        if n > self.buf.len() - self.pos.min(self.buf.len()) {
            return Err(self.err(format!("truncated: {what} claims {n} elements")));
        }
        (0..n).map(|_| self.f64(what)).collect()
    }

    fn matrix(&mut self, what: &str) -> Result<Matrix<f32>> {
        let rows = self.size(what)?;
        let cols = self.size(what)?;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| self.err(format!("{what}: {rows}x{cols} overflows")))?;
        if n > (self.buf.len() - self.pos.min(self.buf.len())) / 4 + 1 {
            return Err(self.err(format!("truncated: {what} claims {rows}x{cols}")));
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.f32(what)?);
        }
        Matrix::from_vec(rows, cols, data)
    }

    /// Every payload byte must be consumed — trailing garbage means a
    /// torn or concatenated file.
    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(self.err(format!(
                "{} trailing bytes after the payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ----------------------------------------------------- enum tag codecs

fn kind_tag(k: AccumKind) -> u8 {
    match k {
        AccumKind::None => 0,
        AccumKind::RFactor => 1,
        AccumKind::Gram => 2,
        AccumKind::Scales => 3,
        AccumKind::Sketch => 4,
    }
}

fn kind_of(tag: u8, r: &Reader) -> Result<AccumKind> {
    match tag {
        0 => Ok(AccumKind::None),
        1 => Ok(AccumKind::RFactor),
        2 => Ok(AccumKind::Gram),
        3 => Ok(AccumKind::Scales),
        4 => Ok(AccumKind::Sketch),
        t => Err(r.err(format!("unknown accumulator kind tag {t}"))),
    }
}

fn precision_tag(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::F16 => 1,
        Precision::Bf16 => 2,
    }
}

fn precision_of(tag: u8, r: &Reader) -> Result<Precision> {
    match tag {
        0 => Ok(Precision::F32),
        1 => Ok(Precision::F16),
        2 => Ok(Precision::Bf16),
        t => Err(r.err(format!("unknown precision tag {t}"))),
    }
}

fn put_state(w: &mut Writer, s: &CalibState) {
    match s {
        CalibState::None => w.u8(0),
        CalibState::R(m) => {
            w.u8(1);
            w.matrix(m);
        }
        CalibState::Gram(m) => {
            w.u8(2);
            w.matrix(m);
        }
        CalibState::Scales { sum_abs, rows } => {
            w.u8(3);
            w.size(*rows);
            w.f64s(sum_abs);
        }
        CalibState::Sketch { y, folds, kind } => {
            w.u8(4);
            w.u8(sketch_kind_tag(*kind));
            w.u64(*folds);
            w.matrix(y);
        }
    }
}

fn sketch_kind_tag(k: SketchKind) -> u8 {
    match k {
        SketchKind::Gaussian => 0,
        SketchKind::Srht => 1,
    }
}

fn sketch_kind_of(tag: u8, r: &Reader) -> Result<SketchKind> {
    match tag {
        0 => Ok(SketchKind::Gaussian),
        1 => Ok(SketchKind::Srht),
        t => Err(r.err(format!("unknown sketch-kind tag {t}"))),
    }
}

fn take_state(r: &mut Reader) -> Result<CalibState> {
    match r.u8("state tag")? {
        0 => Ok(CalibState::None),
        1 => Ok(CalibState::R(r.matrix("R state")?)),
        2 => Ok(CalibState::Gram(r.matrix("Gram state")?)),
        3 => {
            let rows = r.size("scales rows")?;
            let sum_abs = r.f64s("scales sums")?;
            Ok(CalibState::Scales { sum_abs, rows })
        }
        4 => {
            let kind = sketch_kind_of(r.u8("sketch kind")?, r)?;
            let folds = r.u64("sketch folds")?;
            let y = r.matrix("sketch state")?;
            Ok(CalibState::Sketch { y, folds, kind })
        }
        t => Err(r.err(format!("unknown calibration-state tag {t}"))),
    }
}

// --------------------------------------------------------- shard state

/// One pending merge-tree node: the finished state of the canonical
/// subtree rooted at `(level, index)` for a `(layer, stream)` key.
/// Leaf `b` sits at `(0, b)` with *global* batch indices, so nodes from
/// different shards slot into one tree.
#[derive(Debug, Clone)]
pub struct StateNode {
    pub layer: usize,
    pub stream: String,
    pub level: u32,
    pub index: usize,
    pub state: CalibState,
}

/// Serializable calibration progress: the pending merge-tree nodes
/// after folding batches `[start, done)` of a run whose canonical tree
/// spans `total` batches.  `coala shard` emits a complete one
/// (`done == end`); the engine's checkpointing writes partial ones and
/// resumes from them.
#[derive(Debug, Clone)]
pub struct ShardState {
    pub kind: AccumKind,
    /// Emulated accumulation arithmetic (Table 2's fp16) — merges of
    /// resumed/shipped states must round exactly like the original run.
    pub precision: Precision,
    /// Free-form fingerprint of the activation source that produced
    /// these states (model config, route, seed, …).  Merging shards or
    /// resuming a checkpoint from a *different* source would silently
    /// produce states no real run computes, so merge and resume both
    /// require the fingerprints to match.
    pub source: String,
    /// Batch count of the whole (multi-shard) run — fixes the tree shape.
    pub total: usize,
    /// This shard's batch range `[start, end)`.
    pub start: usize,
    pub end: usize,
    /// Batches actually folded: `[start, done)`; `done == end` ⇔ complete.
    pub done: usize,
    /// Pending nodes in canonical (layer, stream, level, index) order.
    pub nodes: Vec<StateNode>,
}

impl ShardState {
    pub fn is_complete(&self) -> bool {
        self.done == self.end
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(PAYLOAD_SHARD);
        w.u8(kind_tag(self.kind));
        w.u8(precision_tag(self.precision));
        w.str(&self.source);
        w.size(self.total);
        w.size(self.start);
        w.size(self.end);
        w.size(self.done);
        w.size(self.nodes.len());
        for n in &self.nodes {
            w.size(n.layer);
            w.str(&n.stream);
            w.u32(n.level);
            w.size(n.index);
            put_state(&mut w, &n.state);
        }
        w.buf
    }

    /// Decode from bytes; `src` names the origin in error messages.
    pub fn decode(bytes: &[u8], src: &str) -> Result<ShardState> {
        let mut r = Reader::open(bytes, src, PAYLOAD_SHARD)?;
        let kind = kind_of(r.u8("accumulator kind")?, &r)?;
        let precision = precision_of(r.u8("precision")?, &r)?;
        let source = r.str("source fingerprint")?;
        let total = r.size("total batches")?;
        let start = r.size("shard start")?;
        let end = r.size("shard end")?;
        let done = r.size("shard done")?;
        if !(start <= done && done <= end && end <= total) {
            return Err(r.err(format!(
                "inconsistent shard header: start {start} ≤ done {done} ≤ end {end} ≤ total {total} violated"
            )));
        }
        let n_nodes = r.size("node count")?;
        let mut nodes = Vec::with_capacity(n_nodes.min(1 << 20));
        for _ in 0..n_nodes {
            let layer = r.size("node layer")?;
            let stream = r.str("node stream")?;
            let level = r.u32("node level")?;
            let index = r.size("node index")?;
            let state = take_state(&mut r)?;
            if state.kind() != kind {
                return Err(r.err(format!(
                    "node ({layer}, {stream}) holds a {:?} state in a {kind:?} shard",
                    state.kind()
                )));
            }
            nodes.push(StateNode { layer, stream, level, index, state });
        }
        r.finish()?;
        Ok(ShardState { kind, precision, source, total, start, end, done, nodes })
    }

    /// Write atomically (temp file + rename): a kill mid-write never
    /// leaves a torn file behind.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        write_atomic(path.as_ref(), &self.encode())
    }

    /// Atomic write of pre-encoded bytes.  The engine uses this to time
    /// codec encode and checkpoint IO as separate telemetry stages
    /// without double-encoding.
    pub(crate) fn write_bytes(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
        write_atomic(path.as_ref(), bytes)
    }

    pub fn read(path: impl AsRef<Path>) -> Result<ShardState> {
        let p = path.as_ref();
        let bytes = std::fs::read(p).map_err(|e| Error::io(p, e))?;
        ShardState::decode(&bytes, &p.display().to_string())
    }
}

// ------------------------------------------------------------- factors

/// Serialize a compressed model's factor outputs.  Deterministic
/// (BTreeMap order), so two runs that agree bitwise on factors produce
/// byte-identical files — `cmp` is a valid equality check.
pub fn encode_factors(model: &CompressedModel) -> Vec<u8> {
    let mut w = Writer::new(PAYLOAD_FACTORS);
    w.str(&model.base_config);
    w.size(model.factors.len());
    for (proj, f) in &model.factors {
        w.str(proj);
        w.matrix(&f.a);
        w.matrix(&f.b);
        w.f32s(&f.spectrum);
    }
    w.buf
}

pub fn decode_factors(bytes: &[u8], src: &str) -> Result<CompressedModel> {
    let mut r = Reader::open(bytes, src, PAYLOAD_FACTORS)?;
    let base_config = r.str("config name")?;
    let n = r.size("factor count")?;
    let mut factors = BTreeMap::new();
    for _ in 0..n {
        let proj = r.str("projection name")?;
        let a = r.matrix("A factor")?;
        let b = r.matrix("B factor")?;
        let spectrum = r.f32s("spectrum")?;
        factors.insert(proj, Factors { a, b, spectrum });
    }
    r.finish()?;
    Ok(CompressedModel { base_config, factors })
}

pub fn write_factors(path: impl AsRef<Path>, model: &CompressedModel) -> Result<()> {
    write_atomic(path.as_ref(), &encode_factors(model))
}

pub fn read_factors(path: impl AsRef<Path>) -> Result<CompressedModel> {
    let p = path.as_ref();
    let bytes = std::fs::read(p).map_err(|e| Error::io(p, e))?;
    decode_factors(&bytes, &p.display().to_string())
}

// ------------------------------------------------------------ adapters

/// Serialize an adapter set (factors + the frozen residual weights), so
/// a trained or initialized [`AdapterSet`] survives a process boundary.
pub fn encode_adapters(set: &AdapterSet) -> Vec<u8> {
    let mut w = Writer::new(PAYLOAD_ADAPTERS);
    w.size(set.rank);
    w.size(set.adapters.len());
    for (proj, (a, b)) in &set.adapters {
        w.str(proj);
        w.matrix(a);
        w.matrix(b);
    }
    w.str(&set.frozen.config);
    w.size(set.frozen.tensors.len());
    for (name, (dims, data)) in &set.frozen.tensors {
        w.str(name);
        w.size(dims.len());
        for &d in dims {
            w.size(d);
        }
        w.f32s(data);
    }
    w.f32s(&set.frozen.pretrain_loss);
    w.f32(set.frozen.build_val_ppl);
    w.buf
}

pub fn decode_adapters(bytes: &[u8], src: &str) -> Result<AdapterSet> {
    let mut r = Reader::open(bytes, src, PAYLOAD_ADAPTERS)?;
    let rank = r.size("rank")?;
    let n = r.size("adapter count")?;
    let mut adapters = BTreeMap::new();
    for _ in 0..n {
        let proj = r.str("projection name")?;
        let a = r.matrix("adapter A")?;
        let b = r.matrix("adapter B")?;
        adapters.insert(proj, (a, b));
    }
    let config = r.str("frozen config")?;
    let n_tensors = r.size("tensor count")?;
    let mut tensors = BTreeMap::new();
    for _ in 0..n_tensors {
        let name = r.str("tensor name")?;
        let n_dims = r.size("tensor rank")?;
        if n_dims > 8 {
            return Err(r.err(format!("tensor `{name}` claims {n_dims} dims")));
        }
        let mut dims = Vec::with_capacity(n_dims);
        for _ in 0..n_dims {
            dims.push(r.size("tensor dim")?);
        }
        let data = r.f32s("tensor data")?;
        let want: usize = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| r.err(format!("tensor `{name}` shape overflows")))?;
        if data.len() != want {
            return Err(r.err(format!(
                "tensor `{name}`: {} values for shape {dims:?}",
                data.len()
            )));
        }
        tensors.insert(name, (dims, data));
    }
    let pretrain_loss = r.f32s("pretrain loss")?;
    let build_val_ppl = r.f32("val ppl")?;
    r.finish()?;
    Ok(AdapterSet {
        rank,
        adapters,
        frozen: ModelWeights { config, tensors, pretrain_loss, build_val_ppl },
    })
}

pub fn write_adapters(path: impl AsRef<Path>, set: &AdapterSet) -> Result<()> {
    write_atomic(path.as_ref(), &encode_adapters(set))
}

pub fn read_adapters(path: impl AsRef<Path>) -> Result<AdapterSet> {
    let p = path.as_ref();
    let bytes = std::fs::read(p).map_err(|e| Error::io(p, e))?;
    decode_adapters(&bytes, &p.display().to_string())
}

// ------------------------------------------------------------ file io

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
        }
    }
    let tmp = path.with_file_name(format!(
        "{}.tmp",
        path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default()
    ));
    std::fs::write(&tmp, bytes).map_err(|e| Error::io(&tmp, e))?;
    std::fs::rename(&tmp, path).map_err(|e| Error::io(path, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nasty_matrix(rows: usize, cols: usize, seed: u64) -> Matrix<f32> {
        let mut m = Matrix::randn(rows, cols, seed);
        // non-finite and sign-sensitive payloads must survive bit-exactly
        m.data[0] = f32::NAN;
        m.data[1] = f32::from_bits(0x7fc0_1234); // NaN with payload
        m.data[2] = f32::INFINITY;
        m.data[3] = f32::NEG_INFINITY;
        m.data[4] = -0.0;
        m.data[5] = f32::MIN_POSITIVE / 2.0; // subnormal
        m
    }

    fn bits32(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn bits64(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn shard_state_roundtrips_every_kind_bit_exactly() {
        let states = vec![
            (AccumKind::RFactor, CalibState::R(nasty_matrix(6, 6, 1))),
            (AccumKind::Gram, CalibState::Gram(nasty_matrix(5, 5, 2))),
            (
                AccumKind::Scales,
                CalibState::Scales {
                    sum_abs: vec![f64::NAN, f64::INFINITY, -0.0, 1.5e-310, 3.25],
                    rows: 17,
                },
            ),
            (
                AccumKind::Sketch,
                CalibState::Sketch {
                    y: nasty_matrix(4, 6, 3),
                    folds: u64::MAX,
                    kind: SketchKind::Gaussian,
                },
            ),
            (
                AccumKind::Sketch,
                CalibState::Sketch {
                    y: nasty_matrix(3, 5, 4),
                    folds: 7,
                    kind: SketchKind::Srht,
                },
            ),
            (AccumKind::None, CalibState::None),
        ];
        for (kind, state) in states {
            let st = ShardState {
                kind,
                precision: Precision::F16,
                source: "tiny:host:seed7".into(),
                total: 8,
                start: 2,
                end: 6,
                done: 4,
                nodes: vec![StateNode {
                    layer: 3,
                    stream: "down".into(),
                    level: 1,
                    index: 1,
                    state,
                }],
            };
            let got = ShardState::decode(&st.encode(), "<memory>").unwrap();
            assert_eq!(got.kind, st.kind);
            assert_eq!(got.precision, st.precision);
            assert_eq!(got.source, st.source);
            assert_eq!(
                (got.total, got.start, got.end, got.done),
                (st.total, st.start, st.end, st.done)
            );
            assert!(!got.is_complete());
            assert_eq!(got.nodes.len(), 1);
            let (a, b) = (&st.nodes[0], &got.nodes[0]);
            assert_eq!((a.layer, &a.stream, a.level, a.index), (b.layer, &b.stream, b.level, b.index));
            match (&a.state, &b.state) {
                (CalibState::R(x), CalibState::R(y)) | (CalibState::Gram(x), CalibState::Gram(y)) => {
                    assert_eq!(bits32(&x.data), bits32(&y.data));
                    assert_eq!((x.rows, x.cols), (y.rows, y.cols));
                }
                (
                    CalibState::Scales { sum_abs: x, rows: rx },
                    CalibState::Scales { sum_abs: y, rows: ry },
                ) => {
                    assert_eq!(bits64(x), bits64(y));
                    assert_eq!(rx, ry);
                }
                (
                    CalibState::Sketch { y: x, folds: fx, kind: kx },
                    CalibState::Sketch { y, folds: fy, kind: ky },
                ) => {
                    assert_eq!(fx, fy);
                    assert_eq!(kx, ky);
                    assert_eq!(bits32(&x.data), bits32(&y.data));
                    assert_eq!((x.rows, x.cols), (y.rows, y.cols));
                }
                (CalibState::None, CalibState::None) => {}
                other => panic!("kind changed in roundtrip: {other:?}"),
            }
        }
    }

    #[test]
    fn header_mismatches_are_rejected_with_source() {
        let st = ShardState {
            kind: AccumKind::Gram,
            precision: Precision::F32,
            source: String::new(),
            total: 1,
            start: 0,
            end: 1,
            done: 1,
            nodes: vec![],
        };
        let good = st.encode();

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let e = ShardState::decode(&bad_magic, "m.state").unwrap_err().to_string();
        assert!(e.contains("m.state") && e.contains("magic"), "{e}");

        let mut bad_version = good.clone();
        bad_version[4] = 99;
        let e = ShardState::decode(&bad_version, "v.state").unwrap_err().to_string();
        assert!(e.contains("version 99"), "{e}");

        // a factors payload is not a shard state
        let factors = encode_factors(&CompressedModel::new("tiny"));
        let e = ShardState::decode(&factors, "f.state").unwrap_err().to_string();
        assert!(e.contains("factors") && e.contains("shard state"), "{e}");
        assert!(decode_factors(&good, "s.state").is_err());

        // truncation and trailing garbage
        assert!(ShardState::decode(&good[..good.len() - 1], "t.state").is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(ShardState::decode(&trailing, "g.state").is_err());

        // inconsistent header arithmetic
        let mut inconsistent = st.clone();
        inconsistent.done = 2; // done > end
        assert!(ShardState::decode(&inconsistent.encode(), "h.state").is_err());
    }

    #[test]
    fn factors_roundtrip_bit_exactly() {
        let mut model = CompressedModel::new("small");
        model.insert(
            "l0.wq",
            Factors { a: nasty_matrix(8, 3, 3), b: nasty_matrix(3, 8, 4), spectrum: vec![f32::NAN, 2.0, 0.0] },
        );
        model.insert(
            "l1.w_up",
            Factors { a: nasty_matrix(8, 2, 5), b: nasty_matrix(2, 12, 6), spectrum: vec![] },
        );
        let bytes = encode_factors(&model);
        let got = decode_factors(&bytes, "<memory>").unwrap();
        assert_eq!(got.base_config, "small");
        assert_eq!(got.factors.len(), 2);
        for (proj, f) in &model.factors {
            let g = &got.factors[proj];
            assert_eq!(bits32(&f.a.data), bits32(&g.a.data));
            assert_eq!(bits32(&f.b.data), bits32(&g.b.data));
            assert_eq!(bits32(&f.spectrum), bits32(&g.spectrum));
        }
        // determinism: encoding the decoded model reproduces the bytes
        assert_eq!(bytes, encode_factors(&got));
    }

    #[test]
    fn adapters_roundtrip_with_frozen_weights() {
        let mut adapters = BTreeMap::new();
        adapters.insert("l0.wq".to_string(), (nasty_matrix(6, 2, 7), nasty_matrix(2, 6, 8)));
        let mut tensors = BTreeMap::new();
        tensors.insert("embed".to_string(), (vec![4, 6], nasty_matrix(4, 6, 9).data));
        tensors.insert("l0.norm".to_string(), (vec![6], vec![1.0f32; 6]));
        let set = AdapterSet {
            rank: 2,
            adapters,
            frozen: ModelWeights {
                config: "tiny".into(),
                tensors,
                pretrain_loss: vec![2.5, 1.25],
                build_val_ppl: f32::NAN,
            },
        };
        let got = decode_adapters(&encode_adapters(&set), "<memory>").unwrap();
        assert_eq!(got.rank, 2);
        let (a0, b0) = &set.adapters["l0.wq"];
        let (a1, b1) = &got.adapters["l0.wq"];
        assert_eq!(bits32(&a0.data), bits32(&a1.data));
        assert_eq!(bits32(&b0.data), bits32(&b1.data));
        assert_eq!(got.frozen.config, "tiny");
        assert_eq!(got.frozen.tensors["embed"].0, vec![4, 6]);
        assert_eq!(
            bits32(&set.frozen.tensors["embed"].1),
            bits32(&got.frozen.tensors["embed"].1)
        );
        assert_eq!(bits32(&set.frozen.pretrain_loss), bits32(&got.frozen.pretrain_loss));
        assert_eq!(set.frozen.build_val_ppl.to_bits(), got.frozen.build_val_ppl.to_bits());
    }

    #[test]
    fn file_errors_name_the_path() {
        let e = ShardState::read("/nonexistent-dir/nope.state").unwrap_err().to_string();
        assert!(e.contains("/nonexistent-dir/nope.state"), "{e}");
        let e = read_factors("/nonexistent-dir/nope.factors").unwrap_err().to_string();
        assert!(e.contains("nope.factors"), "{e}");
    }

    #[test]
    fn write_is_atomic_and_reads_back() {
        let dir = std::env::temp_dir().join(format!("coala-state-{}", std::process::id()));
        let path = dir.join("x.state");
        let st = ShardState {
            kind: AccumKind::RFactor,
            precision: Precision::F32,
            source: "atomic-test".into(),
            total: 4,
            start: 0,
            end: 4,
            done: 4,
            nodes: vec![StateNode {
                layer: 0,
                stream: "attn".into(),
                level: 2,
                index: 0,
                state: CalibState::R(nasty_matrix(7, 7, 10)),
            }],
        };
        st.write(&path).unwrap();
        // no temp residue
        assert!(!dir.join("x.state.tmp").exists());
        let got = ShardState::read(&path).unwrap();
        assert!(got.is_complete());
        let (CalibState::R(a), CalibState::R(b)) = (&st.nodes[0].state, &got.nodes[0].state)
        else {
            panic!("kind changed");
        };
        assert_eq!(bits32(&a.data), bits32(&b.data));
        std::fs::remove_dir_all(&dir).ok();
    }
}
