//! PRNG-generated calibration activations with *controlled conditioning
//! regimes* — the synthetic host route's stand-in for `fwd_acts`
//! capture.
//!
//! The paper's three calibration scenarios are reproduced by
//! construction rather than by luck: every layer of the synthetic model
//! is assigned a [`Regime`] that fixes the spectrum of its activation
//! distribution, so the stability drivers exercise well-conditioned,
//! nearly singular, and heavy-spiked Gram matrices deterministically.
//! The under-determined (k < n) scenario falls out of batch counts: one
//! calibration batch contributes `batch · seq_len` activation rows,
//! which is fewer than the `d_ff`-wide "down" stream's feature count.
//!
//! Chunks are keyed by (layer, stream, batch index) and fully
//! reproducible from the environment seed — no files, no executor.

use crate::calib::activations::{ActivationSource, CalibChunk};
use crate::error::Result;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::ops::matmul;
use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Conditioning regime of one layer's activation distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// Mildly scaled Gaussian features: cond(X) ~ O(10).
    WellConditioned,
    /// Rows live (almost) in a width/4-dimensional subspace, with a
    /// 1e-2-scale isotropic floor: cond(X) ~ 1e2–1e3, so the f32 Gram
    /// route survives degraded while the bf16/fp16 Gram route collapses
    /// — the Fig. 1 separation.
    NearSingular,
    /// Geometrically decaying per-feature scales over four decades: the
    /// sharp-drop spectra of Fig. 2.
    Spiked,
}

/// Layers cycle through the three regimes, so any model with ≥ 3 layers
/// exhibits all of them (the synthetic `tiny` config has exactly 3).
pub fn regime_for_layer(layer: usize) -> Regime {
    match layer % 3 {
        0 => Regime::WellConditioned,
        1 => Regime::NearSingular,
        _ => Regime::Spiked,
    }
}

/// Generate one (rows × width) chunk of Xᵀ under a regime.  Chunks with
/// different seeds are independent draws of the same distribution.
pub fn synth_chunk(rows: usize, width: usize, regime: Regime, seed: u64) -> Matrix<f32> {
    match regime {
        Regime::WellConditioned => {
            let mut m = Matrix::<f32>::randn(rows, width, seed);
            let mut rng = Rng::new(seed ^ 0xC01D);
            let scales: Vec<f32> =
                (0..width).map(|_| (0.7 + 0.8 * rng.uniform()) as f32).collect();
            for i in 0..rows {
                for (j, s) in scales.iter().enumerate() {
                    m.set(i, j, m.get(i, j) * s);
                }
            }
            m
        }
        Regime::NearSingular => {
            let k = (width / 4).max(1);
            let g = Matrix::<f32>::randn(rows, k, seed);
            let b = Matrix::<f32>::randn(k, width, seed ^ 0xBA5E);
            // shapes agree by construction
            let mut m = matmul(&g, &b).expect("synth chunk shapes");
            let noise = Matrix::<f32>::randn(rows, width, seed ^ 0x0157).scale(1e-2);
            m = m.add(&noise).expect("synth chunk shapes");
            m
        }
        Regime::Spiked => {
            let mut m = Matrix::<f32>::randn(rows, width, seed);
            for j in 0..width {
                let sigma = 100.0f32 * 10f32.powf(-(4.0 * j as f32) / width as f32);
                for i in 0..rows {
                    m.set(i, j, m.get(i, j) * sigma);
                }
            }
            m
        }
    }
}

/// The synthetic [`ActivationSource`]: deterministic chunks for every
/// (layer, stream) of a model spec, with per-layer regimes.
pub struct SyntheticActivations {
    spec: ModelSpec,
    seed: u64,
}

impl SyntheticActivations {
    pub fn new(spec: ModelSpec, seed: u64) -> SyntheticActivations {
        SyntheticActivations { spec, seed }
    }

    /// The chunk for one (layer, stream, batch) triple.
    pub fn chunk(&self, layer: usize, stream: &str, batch: usize) -> Matrix<f32> {
        let width = if stream == "down" { self.spec.d_ff } else { self.spec.d_model };
        let rows = self.spec.batch * self.spec.seq_len;
        // distinct stream per (layer, stream, batch); SplitMix inside
        // Rng::new decorrelates the nearby seeds
        let mut salt = 0xAC71_u64;
        for b in stream.as_bytes() {
            salt = salt.wrapping_mul(31).wrapping_add(*b as u64);
        }
        salt = salt
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((layer as u64) << 32)
            .wrapping_add(batch as u64);
        synth_chunk(rows, width, regime_for_layer(layer), self.seed ^ salt)
    }
}

impl ActivationSource for SyntheticActivations {
    fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
        let mut out =
            Vec::with_capacity(self.spec.n_layers * self.spec.act_streams.len());
        for layer in 0..self.spec.n_layers {
            for stream in &self.spec.act_streams {
                out.push(CalibChunk {
                    layer,
                    stream: stream.clone(),
                    xt: self.chunk(layer, stream, b),
                });
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{jacobi_svd, qr_r_square};
    use crate::model::synthetic::synthetic_manifest;

    /// cond(X) from the R factor of Xᵀ (σ(R) = σ(X)).
    fn cond(xt: &Matrix<f32>) -> f64 {
        let xt64: Matrix<f64> = xt.cast();
        let r = qr_r_square(&xt64).unwrap();
        let svd = jacobi_svd(&r, 60).unwrap();
        svd.s[0] / svd.s.last().unwrap().max(1e-300)
    }

    #[test]
    fn regimes_have_their_spectra() {
        let well = synth_chunk(128, 24, Regime::WellConditioned, 1);
        let sing = synth_chunk(128, 24, Regime::NearSingular, 2);
        let spik = synth_chunk(128, 24, Regime::Spiked, 3);
        let (cw, cn, cs) = (cond(&well), cond(&sing), cond(&spik));
        assert!(cw < 50.0, "well-conditioned cond {cw}");
        assert!(cn > 10.0 * cw, "near-singular cond {cn} vs {cw}");
        assert!(cs > 10.0 * cw, "spiked cond {cs} vs {cw}");
        for m in [&well, &sing, &spik] {
            assert!(m.all_finite());
        }
    }

    #[test]
    fn source_is_deterministic_and_complete() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 42);
        let a = src.capture_batch(0).unwrap();
        let b = src.capture_batch(0).unwrap();
        assert_eq!(a.len(), spec.n_layers * spec.act_streams.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.layer, &x.stream), (y.layer, &y.stream));
            assert_eq!(x.xt.data, y.xt.data, "layer {} {}", x.layer, x.stream);
            let width = if x.stream == "down" { spec.d_ff } else { spec.d_model };
            assert_eq!((x.xt.rows, x.xt.cols), (spec.batch * spec.seq_len, width));
        }
        // different batches are different draws
        let c = src.capture_batch(1).unwrap();
        assert_ne!(a[0].xt.data, c[0].xt.data);
    }

    #[test]
    fn all_three_regimes_appear_across_tiny_layers() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        assert!(spec.n_layers >= 3, "tiny must exhibit every regime");
        let regimes: Vec<Regime> = (0..spec.n_layers).map(regime_for_layer).collect();
        for want in [Regime::WellConditioned, Regime::NearSingular, Regime::Spiked] {
            assert!(regimes.contains(&want), "{want:?} missing");
        }
    }
}
