//! Prop. 4: the (XXᵀ)^α family unifying PiSSA (α=0), the new α=1 method
//! (≡ Alg. 1), and robustified CorDA (α=2) — all inversion-free.

use super::factorize::{svd_any, FullFactors};
use crate::error::{Error, Result};
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// Solve min tr((W−W′)(XXᵀ)^α(W−W′)ᵀ) given the square R (RᵀR = XXᵀ).
///
/// Only the left singular vectors matter (W′ = U_rU_rᵀW), so any M with
/// M·Mᵀ = W(XXᵀ)^αWᵀ yields the same U:
///   α = 0 → M = W;   α = 1 → M = W·Rᵀ;   α = 2 → M = W·Rᵀ·R.
/// No Gram matrix, matrix square root, or inversion appears for any α.
pub fn alpha_factorize<T: Scalar>(
    w: &Matrix<T>,
    r_factor: &Matrix<T>,
    alpha: u32,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let target = match alpha {
        0 => w.clone(),
        1 => matmul(w, &r_factor.transpose())?,
        2 => matmul(&matmul(w, &r_factor.transpose())?, r_factor)?,
        a => return Err(Error::Config(format!("alpha ∈ {{0,1,2}}, got {a}"))),
    };
    let (u, sigma) = svd_any(&target, sweeps)?;
    let p = matmul(&u.transpose(), w)?;
    Ok(FullFactors { u, sigma, p })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_factorize;
    use crate::linalg::qr_r_square;
    use crate::tensor::ops::{fro, gram_t};

    fn setup(seed: u64) -> (Matrix<f64>, Matrix<f64>, Matrix<f64>) {
        let w: Matrix<f64> = Matrix::randn(9, 7, seed);
        let x: Matrix<f64> = Matrix::randn(7, 40, seed + 1);
        let r = qr_r_square(&x.transpose()).unwrap();
        (w, x, r)
    }

    #[test]
    fn alpha1_equals_coala() {
        let (w, _x, r) = setup(1);
        let a1 = alpha_factorize(&w, &r, 1, 60).unwrap().truncate(3).reconstruct().unwrap();
        let cf = coala_factorize(&w, &r, 60).unwrap().truncate(3).reconstruct().unwrap();
        assert!(fro(&a1.sub(&cf).unwrap()) < 1e-10);
    }

    #[test]
    fn alpha0_is_plain_svd_truncation() {
        let (w, _x, r) = setup(2);
        let a0 = alpha_factorize(&w, &r, 0, 60).unwrap().truncate(3).reconstruct().unwrap();
        let svd = crate::linalg::jacobi_svd(&w, 60).unwrap();
        let best = svd.truncate(3);
        assert!(fro(&a0.sub(&best).unwrap()) < 1e-9);
    }

    #[test]
    fn alpha2_matches_corda_objective() {
        // W' from α=2 must solve min ‖(W−W')XXᵀ‖_F: compare against the
        // direct (Gram-forming) construction on well-conditioned data.
        let (w, x, r) = setup(3);
        let a2 = alpha_factorize(&w, &r, 2, 60).unwrap().truncate(3).reconstruct().unwrap();
        let g = gram_t(&x.transpose());
        // direct: left singular vectors of W·G
        let wg = matmul(&w, &g).unwrap();
        let (u, _) = super::svd_any(&wg, 60).unwrap();
        let ur = u.first_cols(3);
        let direct = matmul(&ur, &matmul(&ur.transpose(), &w).unwrap()).unwrap();
        assert!(fro(&a2.sub(&direct).unwrap()) < 1e-8 * (1.0 + fro(&direct)));
    }

    #[test]
    fn alpha_objective_ordering() {
        // each α solution must minimize ITS objective at least as well as
        // the other α solutions do.
        let (w, _x, r) = setup(4);
        let obj = |wp: &Matrix<f64>, alpha: u32| -> f64 {
            let diff = w.sub(wp).unwrap();
            let t = match alpha {
                0 => diff.clone(),
                1 => matmul(&diff, &r.transpose()).unwrap(),
                _ => matmul(&matmul(&diff, &r.transpose()).unwrap(), &r).unwrap(),
            };
            fro(&t)
        };
        let sols: Vec<Matrix<f64>> = (0..3u32)
            .map(|a| alpha_factorize(&w, &r, a, 60).unwrap().truncate(2).reconstruct().unwrap())
            .collect();
        for a in 0..3u32 {
            let own = obj(&sols[a as usize], a);
            for b in 0..3u32 {
                assert!(own <= obj(&sols[b as usize], a) * (1.0 + 1e-8) + 1e-10);
            }
        }
    }

    #[test]
    fn invalid_alpha_rejected() {
        let (w, _x, r) = setup(5);
        assert!(alpha_factorize(&w, &r, 3, 10).is_err());
    }
}
