//! ASVD: activation-aware SVD — scale W's columns by activation
//! magnitudes, truncate, unscale.  Reasonable but provably suboptimal
//! for problem (1) (the paper's Related-Work discussion).

use crate::coala::factorize::{svd_any, FullFactors};
use crate::error::{Error, Result};
use crate::tensor::{Matrix, Scalar};

/// ASVD with per-input-channel scales d (typically (mean |X|)^{1/2}).
/// W′ = U_rΣ_rV_rᵀ·D⁻¹ with UΣVᵀ = W·D.
pub fn asvd_factorize<T: Scalar>(
    w: &Matrix<T>,
    col_scales: &[T],
    sweeps: usize,
) -> Result<FullFactors<T>> {
    if col_scales.len() != w.cols {
        return Err(Error::shape(format!(
            "asvd: {} scales for {} columns",
            col_scales.len(),
            w.cols
        )));
    }
    let mut ws = w.clone();
    for i in 0..w.rows {
        let row = ws.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r = *r * col_scales[j];
        }
    }
    let (u, sigma) = svd_any(&ws, sweeps)?;
    let sv = crate::tensor::ops::matmul(&u.transpose(), &ws)?; // ΣVᵀ
    let mut p = sv;
    for i in 0..p.rows {
        let row = p.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r = *r / col_scales[j];
        }
    }
    Ok(FullFactors { u, sigma, p })
}

/// The scale rule used in the paper's comparisons: (mean |X| + ε)^{1/2}.
pub fn activation_scales<T: Scalar>(x: &Matrix<T>) -> Vec<T> {
    (0..x.rows)
        .map(|i| {
            let mean_abs =
                x.row(i).iter().map(|v| v.to_f64().abs()).sum::<f64>() / x.cols.max(1) as f64;
            T::from_f64((mean_abs + 1e-6).sqrt())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_from_x;
    use crate::tensor::ops::context_rel_err;

    #[test]
    fn finite_but_suboptimal() {
        let w: Matrix<f64> = Matrix::randn(10, 8, 1);
        // heteroscedastic activations so the context matters
        let mut x: Matrix<f64> = Matrix::randn(8, 60, 2);
        for j in 0..60 {
            for i in 0..8 {
                x.set(i, j, x.get(i, j) * (1.0 + 5.0 * (i as f64)));
            }
        }
        let scales = activation_scales(&x);
        let f = asvd_factorize(&w, &scales, 60).unwrap().truncate(3);
        let e_asvd = context_rel_err(&w, &f.reconstruct().unwrap(), &x).unwrap();
        assert!(e_asvd.is_finite());
        let e_opt = {
            let c = coala_from_x(&w, &x, 60).unwrap().truncate(3).reconstruct().unwrap();
            context_rel_err(&w, &c, &x).unwrap()
        };
        assert!(e_asvd >= e_opt * (1.0 - 1e-9), "{e_asvd} vs optimal {e_opt}");
    }

    #[test]
    fn identity_scales_reduce_to_plain_svd() {
        let w: Matrix<f64> = Matrix::randn(6, 5, 3);
        let ones = vec![1.0f64; 5];
        let f = asvd_factorize(&w, &ones, 60).unwrap().truncate(2).reconstruct().unwrap();
        let svd = crate::linalg::jacobi_svd(&w, 60).unwrap();
        let best = svd.truncate(2);
        assert!(crate::tensor::ops::fro(&f.sub(&best).unwrap()) < 1e-9);
    }

    #[test]
    fn scale_arity_checked() {
        let w: Matrix<f64> = Matrix::randn(3, 4, 5);
        assert!(asvd_factorize(&w, &[1.0, 2.0], 10).is_err());
    }
}
