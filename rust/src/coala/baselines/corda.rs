//! CorDA, *original* construction (Remark 1): W′ = U_rΣ_rV_rᵀ(XXᵀ)⁻¹
//! with UΣVᵀ = SVD(W·XXᵀ).  Kept exactly as published — including the
//! explicit Gram inversion through an unclamped eigendecomposition —
//! because Table 4 measures precisely this construction collapsing while
//! the robustified α=2 solution (coala::alpha) does not.

use crate::coala::factorize::{svd_any, FullFactors};
use crate::error::Result;
use crate::linalg::eigh;
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// CorDA from the explicitly-formed Gram matrix G = XXᵀ.
pub fn corda_factorize<T: Scalar>(
    w: &Matrix<T>,
    gram: &Matrix<T>,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let n = gram.rows;
    let wg = matmul(w, gram)?;
    let (u, sigma) = svd_any(&wg, sweeps)?;
    let sv = matmul(&u.transpose(), &wg)?; // ΣVᵀ
    // G⁻¹ = Q Λ⁻¹ Qᵀ, no clamping of tiny λ (the published failure mode)
    let (lam, q) = eigh(gram, sweeps)?;
    let mut q_scaled = q.clone();
    for i in 0..n {
        for j in 0..n {
            let inv = 1.0 / lam[j].to_f64();
            q_scaled.set(i, j, T::from_f64(q.get(i, j).to_f64() * inv));
        }
    }
    let ginv = matmul(&q_scaled, &q.transpose())?;
    let p = matmul(&sv, &ginv)?;
    Ok(FullFactors { u, sigma, p })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::alpha::alpha_factorize;
    use crate::linalg::qr_r_square;
    use crate::tensor::ops::{fro, gram_t};

    #[test]
    fn matches_alpha2_when_well_conditioned() {
        let w: Matrix<f64> = Matrix::randn(8, 6, 1);
        let x: Matrix<f64> = Matrix::randn(6, 60, 2);
        let g = gram_t(&x.transpose());
        let c = corda_factorize(&w, &g, 60).unwrap().truncate(3).reconstruct().unwrap();
        let r = qr_r_square(&x.transpose()).unwrap();
        let a2 = alpha_factorize(&w, &r, 2, 60).unwrap().truncate(3).reconstruct().unwrap();
        assert!(fro(&c.sub(&a2).unwrap()) < 1e-6 * (1.0 + fro(&a2)));
    }

    #[test]
    fn b_factor_explodes_on_singular_gram() {
        // Exactly-singular Gram (k < n, the low-data regime of Table 4):
        // CorDA's B = Σ_rV_rᵀG⁻¹ inflates by ~1/λ_min.  The rank-r
        // *reconstruction* partially cancels the inverse, but the factor
        // pair itself — which is what initializes the (A, B) adapters —
        // is garbage: ‖B‖ ≫ ‖W‖.  The robust α=2 factors stay bounded.
        let w: Matrix<f64> = Matrix::randn(6, 10, 3);
        let x: Matrix<f64> = Matrix::randn(10, 4, 4);
        let g = gram_t(&x.transpose());
        let fc = corda_factorize(&w, &g, 60).unwrap();
        let r = qr_r_square(&x.transpose()).unwrap();
        let a2 = alpha_factorize(&w, &r, 2, 60).unwrap();
        let inflated = !fc.p.all_finite() || fro(&fc.p) > 10.0 * fro(&w);
        assert!(inflated, "CorDA B should explode: ‖B‖={} ‖W‖={}", fro(&fc.p), fro(&w));
        assert!(fro(&a2.p) <= 2.0 * fro(&w), "robust B bounded: {}", fro(&a2.p));
    }
}
