//! Every comparator the paper evaluates against (S4).
//!
//! All baselines are implemented from their reference pseudocode
//! (Appendix B, Remark 1, the ASVD paper) — including their failure
//! modes: Gram formation, Cholesky of near-singular matrices, inversion
//! of tiny eigenvalues.  Nothing is "fixed", because the instabilities
//! are the phenomenon under study.

pub mod asvd;
pub mod corda;
pub mod plain_svd;
pub mod svdllm;
pub mod svdllm_v2;

pub use asvd::asvd_factorize;
pub use corda::corda_factorize;
pub use plain_svd::plain_svd_factorize;
pub use svdllm::svdllm_factorize;
pub use svdllm_v2::svdllm_v2_factorize;
