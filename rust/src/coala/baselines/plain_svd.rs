//! Eckart–Young: context-free truncated SVD of W (≡ PiSSA's projection,
//! α = 0 in Prop. 4).  The weakest baseline for compression, the
//! strongest prior for adapter init.

use crate::coala::factorize::{svd_any, FullFactors};
use crate::error::Result;
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// Plain truncated SVD, in the common (U, σ, P) factor ABI.
pub fn plain_svd_factorize<T: Scalar>(w: &Matrix<T>, sweeps: usize) -> Result<FullFactors<T>> {
    let (u, sigma) = svd_any(w, sweeps)?;
    let p = matmul(&u.transpose(), w)?; // = ΣVᵀ for the plain case
    Ok(FullFactors { u, sigma, p })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::fro;

    #[test]
    fn matches_eckart_young() {
        let w: Matrix<f64> = Matrix::randn(11, 6, 1);
        let f = plain_svd_factorize(&w, 60).unwrap();
        for r in [1, 3, 6] {
            let wp = f.truncate(r).reconstruct().unwrap();
            let err = fro(&wp.sub(&w).unwrap());
            let svd = crate::linalg::jacobi_svd(&w, 60).unwrap();
            let want: f64 = svd.s[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
            assert!((err - want).abs() < 1e-9, "r={r}");
        }
    }

    #[test]
    fn wide_matrices() {
        let w: Matrix<f64> = Matrix::randn(4, 12, 2);
        let f = plain_svd_factorize(&w, 60).unwrap().truncate(2);
        assert_eq!((f.a.rows, f.a.cols), (4, 2));
        assert_eq!((f.b.rows, f.b.cols), (2, 12));
        assert!(f.a.all_finite());
    }
}
