//! SVD-LLM (Appendix B, Alg. 3): whitening via the Cholesky factor of
//! the explicitly-formed Gram matrix, then S⁻¹ by triangular solve.

use crate::coala::factorize::{svd_any, FullFactors};
use crate::error::Result;
use crate::linalg::cholesky::cholesky_unchecked;
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// SVD-LLM from the Gram matrix G = XXᵀ.
///
/// S = L (lower Cholesky, L·Lᵀ = G); SVD(W·L) = UΣVᵀ;
/// A = U_r, B = Σ_rV_rᵀL⁻¹ (via Lᵀ·Bᵀ = V·Σ forward/back substitution).
/// On near-singular G the Cholesky pivots underflow and B blows up —
/// faithfully (this is the Fig. 1 red curve).
pub fn svdllm_factorize<T: Scalar>(
    w: &Matrix<T>,
    gram: &Matrix<T>,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let l = cholesky_unchecked(gram)?;
    let ws = matmul(w, &l)?;
    let (u, sigma) = svd_any(&ws, sweeps)?;
    // B = Σ Vᵀ L⁻¹. Recover ΣVᵀ = Uᵀ·W·L, then solve (·)L⁻¹ via Lᵀxᵀ…
    // Equivalent and simpler: B = Uᵀ·W·L·L⁻¹ = Uᵀ W?  NO — that would be
    // COALA's projection.  SVD-LLM defines B through the whitened SVD:
    //   ΣVᵀ = Uᵀ·(W·L)  ⇒  B = (Uᵀ W L) L⁻¹  computed by substitution,
    // which is numerically NOT the same as Uᵀ W once L is ill-conditioned
    // (that numerical difference is the whole point of the comparison).
    let sv = matmul(&u.transpose(), &ws)?; // Σ Vᵀ (p × n)
    // solve B·L = ΣVᵀ  ⇔  Lᵀ·Bᵀ = (ΣVᵀ)ᵀ: lower-solve with Lᵀ reversed…
    // Lᵀ is upper; use upper solve on Bᵀ.
    let bt = crate::linalg::triangular::solve_upper(&l.transpose(), &sv.transpose())?;
    let p = bt.transpose();
    Ok(FullFactors { u, sigma, p })
}

/// Convenience: form the Gram matrix from X and factorize (the end-to-end
/// path Table 1 times, including the XXᵀ formation cost).
pub fn svdllm_from_x<T: Scalar>(w: &Matrix<T>, x: &Matrix<T>, sweeps: usize) -> Result<FullFactors<T>> {
    let gram = crate::tensor::ops::gram_t(&x.transpose());
    svdllm_factorize(w, &gram, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_from_x;
    use crate::tensor::ops::{context_rel_err, gram_t};

    #[test]
    fn optimal_on_well_conditioned_data() {
        let w: Matrix<f64> = Matrix::randn(10, 8, 1);
        let x: Matrix<f64> = Matrix::randn(8, 60, 2);
        let f = svdllm_from_x(&w, &x, 60).unwrap().truncate(4);
        let wp = f.reconstruct().unwrap();
        let coala = coala_from_x(&w, &x, 60).unwrap().truncate(4).reconstruct().unwrap();
        let e1 = context_rel_err(&w, &wp, &x).unwrap();
        let e2 = context_rel_err(&w, &coala, &x).unwrap();
        assert!((e1 - e2).abs() < 1e-8, "{e1} vs {e2}");
    }

    #[test]
    fn breaks_on_singular_gram() {
        // k < n ⇒ singular Gram ⇒ non-finite factors (the headline claim)
        let w: Matrix<f64> = Matrix::randn(6, 9, 3);
        let x: Matrix<f64> = Matrix::randn(9, 4, 4);
        let gram = gram_t(&x.transpose());
        let f = svdllm_factorize(&w, &gram, 60).unwrap();
        let finite = f.u.all_finite() && f.p.all_finite();
        assert!(!finite, "SVD-LLM should break on singular Gram");
    }
}
