//! SVD-LLM v2 (Appendix B, Alg. 4): whitening through the eigendecompo-
//! sition of the Gram matrix; inverts Λ^{1/2} elementwise.

use crate::coala::factorize::{svd_any, FullFactors};
use crate::error::Result;
use crate::linalg::eigh;
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// SVD-LLM v2 from the Gram matrix G = XXᵀ.
///
/// eig(G) = U_sΛU_sᵀ; M = W·U_s·Λ^{1/2}; SVD(M) = UΣVᵀ;
/// B = Σ_rV_rᵀ·Λ^{-1/2}·U_sᵀ.  The elementwise 1/√λ on nearly-zero
/// eigenvalues is the failure mode (Fig. 1 orange curve) — deliberately
/// unclamped.
pub fn svdllm_v2_factorize<T: Scalar>(
    w: &Matrix<T>,
    gram: &Matrix<T>,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let n = gram.rows;
    let (lam, us) = eigh(gram, sweeps)?;
    let sqrt_lam: Vec<f64> = lam.iter().map(|l| l.to_f64().max(0.0).sqrt()).collect();

    // M = W · (U_s scaled by √λ per column)
    let mut us_scaled = us.clone();
    for i in 0..n {
        for j in 0..n {
            us_scaled.set(i, j, T::from_f64(us.get(i, j).to_f64() * sqrt_lam[j]));
        }
    }
    let m_mat = matmul(w, &us_scaled)?;
    let (u, sigma) = svd_any(&m_mat, sweeps)?;

    // B = (ΣVᵀ) Λ^{-1/2} U_sᵀ, with ΣVᵀ = Uᵀ M
    let sv = matmul(&u.transpose(), &m_mat)?;
    let mut sv_scaled = sv.clone();
    for i in 0..sv.rows {
        for j in 0..n {
            let inv = 1.0 / sqrt_lam[j]; // unclamped: may be inf
            sv_scaled.set(i, j, T::from_f64(sv.get(i, j).to_f64() * inv));
        }
    }
    let p = matmul(&sv_scaled, &us.transpose())?;
    Ok(FullFactors { u, sigma, p })
}

/// End-to-end from X (forms the Gram matrix; Table 1 timing path).
pub fn svdllm_v2_from_x<T: Scalar>(
    w: &Matrix<T>,
    x: &Matrix<T>,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let gram = crate::tensor::ops::gram_t(&x.transpose());
    svdllm_v2_factorize(w, &gram, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_from_x;
    use crate::tensor::ops::{context_rel_err, gram_t};

    #[test]
    fn optimal_on_well_conditioned_data() {
        let w: Matrix<f64> = Matrix::randn(9, 7, 1);
        let x: Matrix<f64> = Matrix::randn(7, 50, 2);
        let f = svdllm_v2_from_x(&w, &x, 60).unwrap().truncate(3);
        let e1 = context_rel_err(&w, &f.reconstruct().unwrap(), &x).unwrap();
        let coala = coala_from_x(&w, &x, 60).unwrap().truncate(3).reconstruct().unwrap();
        let e2 = context_rel_err(&w, &coala, &x).unwrap();
        assert!((e1 - e2).abs() < 1e-7, "{e1} vs {e2}");
    }

    #[test]
    fn breaks_on_singular_gram() {
        let w: Matrix<f64> = Matrix::randn(5, 8, 3);
        let x: Matrix<f64> = Matrix::randn(8, 3, 4);
        let gram = gram_t(&x.transpose());
        let f = svdllm_v2_factorize(&w, &gram, 60).unwrap();
        assert!(!(f.u.all_finite() && f.p.all_finite()));
    }
}
