//! The `Compressor` trait and method registry — the one seam through
//! which every compression method is reached.
//!
//! The paper's observation is architectural as much as numerical: COALA
//! and the Gram-based baselines differ only in *which statistic of the
//! calibration stream they accumulate* and *how they factorize it*.
//! This module encodes exactly that:
//!
//! * [`Compressor::accum_kind`] names the streaming accumulator the
//!   method consumes ([`crate::calib::accumulate`]);
//! * [`Compressor::factorize_device`] is the PJRT artifact route
//!   (wrapping `runtime::ops`);
//! * [`Compressor::factorize_host`] is the pure-Rust route (wrapping
//!   `coala::factorize` / `coala::baselines`), so accumulation and
//!   factorization run end-to-end where no artifacts or PJRT runtime
//!   exist (activation capture still needs the `fwd_acts` artifacts).
//!
//! The coordinator, repro harness, CLI, and benches resolve methods by
//! name through [`resolve`] / [`registry`] and never match on
//! [`Method`] variants themselves — adding a method means adding one
//! impl here and one registry row.

use super::baselines;
use super::factorize::FullFactors;
use super::method::Method;
use super::mu::MuRule;
use super::{alpha, coala_factorize, coala_regularized, mu_from_lambda};
use crate::calib::accumulate::{AccumKind, CalibState};
use crate::error::{Error, Result};
use crate::runtime::executor::Executor;
use crate::runtime::ops;
use crate::tensor::Matrix;

/// Result of one projection's factorization: the full-spectrum factors
/// plus the μ the method chose (diagnostics for the adaptive rule).
#[derive(Debug)]
pub struct Factorization {
    pub factors: FullFactors<f32>,
    pub mu: Option<f64>,
}

impl Factorization {
    fn plain(factors: FullFactors<f32>) -> Factorization {
        Factorization { factors, mu: None }
    }
}

/// Which execution backend factorizes (and accumulates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Shape-specialized PJRT artifacts (`runtime::ops`).
    Device,
    /// Pure-Rust host linalg — works with no artifacts at all.
    Host,
}

/// Default Jacobi sweeps for the host route's SVDs.
pub const HOST_SWEEPS: usize = 30;

/// One compression method behind the uniform interface.
pub trait Compressor {
    /// The value-level descriptor (naming, serialization, sweeps).
    fn method(&self) -> Method;

    /// Human-readable display label (tables, logs).
    fn name(&self) -> String {
        self.method().name()
    }

    /// Registry spec — the string [`resolve`] parses back to this
    /// compressor (what the CLI accepts for `--method`).
    fn spec(&self) -> String {
        self.method().spec()
    }

    /// Which calibration statistic this method consumes.
    fn accum_kind(&self) -> AccumKind;

    /// Factorize through the PJRT artifacts.
    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        rank: usize,
    ) -> Result<Factorization>;

    /// Factorize on the host (pure Rust, `sweeps` Jacobi sweeps).
    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        rank: usize,
        sweeps: usize,
    ) -> Result<Factorization>;

    /// Route dispatch — the only branch between device and host.
    fn factorize(
        &self,
        route: Route,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        match route {
            Route::Device => self.factorize_device(ex, w, calib, rank),
            Route::Host => self.factorize_host(w, calib, rank, sweeps),
        }
    }
}

/// Gram-consuming baselines inherit their instability from the Gram
/// matrix itself; the host route *reports* a near-singular collapse as a
/// numerical error instead of letting ±inf/NaN factors flow downstream.
fn check_finite(name: &str, f: Factorization) -> Result<Factorization> {
    if f.factors.u.all_finite() && f.factors.p.all_finite() {
        Ok(f)
    } else {
        Err(Error::Numerical(format!(
            "{name}: non-finite factors (near-singular Gram matrix)"
        )))
    }
}

// ------------------------------------------------------------------ COALA

/// COALA (Alg. 1 / Alg. 2) with a μ rule; consumes the R factor.
pub struct CoalaCompressor {
    pub rule: MuRule,
}

impl Compressor for CoalaCompressor {
    fn method(&self) -> Method {
        Method::Coala(self.rule)
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::RFactor
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        rank: usize,
    ) -> Result<Factorization> {
        // r_factor(): the exact TSQR R, or QR-of-sketch under `--accum sketch`
        let r = &calib.r_factor()?;
        match self.rule {
            MuRule::None => Ok(Factorization::plain(ops::factorize(ex, w, r)?)),
            MuRule::Constant { mu } => Ok(Factorization {
                factors: ops::factorize_reg(ex, w, r, mu as f32)?,
                mu: Some(mu),
            }),
            MuRule::Adaptive { lambda } => {
                let f0 = ops::factorize(ex, w, r)?;
                let (num, den) = ops::mu_terms(ex, w, &f0, r, rank)?;
                let mu = if den > 1e-20 { lambda * num as f64 / den as f64 } else { 0.0 };
                let factors =
                    if mu == 0.0 { f0 } else { ops::factorize_reg(ex, w, r, mu as f32)? };
                Ok(Factorization { factors, mu: Some(mu) })
            }
        }
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        let r = &calib.r_factor()?;
        match self.rule {
            MuRule::None => Ok(Factorization::plain(coala_factorize(w, r, sweeps)?)),
            MuRule::Constant { mu } => Ok(Factorization {
                factors: coala_regularized(w, r, mu, sweeps)?,
                mu: Some(mu),
            }),
            MuRule::Adaptive { lambda } => {
                let f0 = coala_factorize(w, r, sweeps)?;
                let mu = mu_from_lambda(w, &f0, r, rank, lambda)?;
                let factors =
                    if mu == 0.0 { f0 } else { coala_regularized(w, r, mu, sweeps)? };
                Ok(Factorization { factors, mu: Some(mu) })
            }
        }
    }
}

// ---------------------------------------------------------------- α-family

/// Prop. 4 α-family (inversion-free; α ∈ {0, 1, 2}); consumes R.
pub struct AlphaCompressor {
    pub alpha: u32,
}

impl Compressor for AlphaCompressor {
    fn method(&self) -> Method {
        Method::Alpha(self.alpha)
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::RFactor
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        let factors = match self.alpha {
            0 => ops::plainsvd(ex, w)?,
            1 => ops::factorize(ex, w, &calib.r_factor()?)?,
            2 => ops::alpha2(ex, w, &calib.r_factor()?)?,
            a => return Err(Error::Config(format!("alpha ∈ {{0,1,2}}, got {a}"))),
        };
        Ok(Factorization::plain(factors))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(alpha::alpha_factorize(
            w,
            &calib.r_factor()?,
            self.alpha,
            sweeps,
        )?))
    }
}

// -------------------------------------------------------------- plain SVD

/// Context-free truncated SVD (PiSSA's projection); needs no calibration.
pub struct PlainSvdCompressor;

impl Compressor for PlainSvdCompressor {
    fn method(&self) -> Method {
        Method::PlainSvd
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::None
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        _calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(ops::plainsvd(ex, w)?))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        _calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(baselines::plain_svd_factorize(w, sweeps)?))
    }
}

// ---------------------------------------------------------- Gram baselines

/// SVD-LLM: Cholesky-of-Gram whitening.
pub struct SvdLlmCompressor;

impl Compressor for SvdLlmCompressor {
    fn method(&self) -> Method {
        Method::SvdLlm
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::Gram
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(ops::svdllm(ex, w, calib.gram()?)?))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        let f = Factorization::plain(baselines::svdllm_factorize(w, calib.gram()?, sweeps)?);
        check_finite("SVD-LLM", f)
    }
}

/// SVD-LLM v2: eig-of-Gram whitening.
pub struct SvdLlmV2Compressor;

impl Compressor for SvdLlmV2Compressor {
    fn method(&self) -> Method {
        Method::SvdLlmV2
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::Gram
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(ops::svdllm2(ex, w, calib.gram()?)?))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        let f = Factorization::plain(baselines::svdllm_v2_factorize(w, calib.gram()?, sweeps)?);
        check_finite("SVD-LLM-v2", f)
    }
}

/// Original CorDA (explicit Gram inversion).
pub struct CordaCompressor;

impl Compressor for CordaCompressor {
    fn method(&self) -> Method {
        Method::Corda
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::Gram
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(ops::corda(ex, w, calib.gram()?)?))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        let f = Factorization::plain(baselines::corda_factorize(w, calib.gram()?, sweeps)?);
        check_finite("CorDA", f)
    }
}

// -------------------------------------------------------------------- ASVD

/// ASVD activation scaling; consumes the per-channel scale statistics.
pub struct AsvdCompressor;

impl Compressor for AsvdCompressor {
    fn method(&self) -> Method {
        Method::Asvd
    }

    fn accum_kind(&self) -> AccumKind {
        AccumKind::Scales
    }

    fn factorize_device(
        &self,
        ex: &Executor,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(ops::asvd(ex, w, &calib.asvd_scales()?)?))
    }

    fn factorize_host(
        &self,
        w: &Matrix<f32>,
        calib: &CalibState,
        _rank: usize,
        sweeps: usize,
    ) -> Result<Factorization> {
        Ok(Factorization::plain(baselines::asvd_factorize(
            w,
            &calib.asvd_scales()?,
            sweeps,
        )?))
    }
}

// ---------------------------------------------------------------- registry

/// Build the compressor implementing a [`Method`] descriptor.
pub fn compressor_for(method: &Method) -> Box<dyn Compressor> {
    match *method {
        Method::Coala(rule) => Box::new(CoalaCompressor { rule }),
        Method::Alpha(alpha) => Box::new(AlphaCompressor { alpha }),
        Method::PlainSvd => Box::new(PlainSvdCompressor),
        Method::SvdLlm => Box::new(SvdLlmCompressor),
        Method::SvdLlmV2 => Box::new(SvdLlmV2Compressor),
        Method::Corda => Box::new(CordaCompressor),
        Method::Asvd => Box::new(AsvdCompressor),
    }
}

/// The registry names (what [`resolve`] accepts before `:param=value`).
pub const METHOD_NAMES: &[&str] = &[
    "coala", "svdllm", "svdllm2", "corda", "asvd", "svd", "alpha0", "alpha1", "alpha2",
];

/// Resolve a method spec to a compressor.
///
/// Specs are `name` or `name:key=value`:
/// `coala`, `coala:lambda=3`, `coala:mu=0.1`, `svdllm`, `svdllm2`,
/// `corda`, `asvd`, `svd`, `alpha0|1|2`.
pub fn resolve(spec: &str) -> Result<Box<dyn Compressor>> {
    let (name, param) = match spec.split_once(':') {
        Some((n, p)) => (n, Some(p)),
        None => (spec, None),
    };
    let parse_param = |p: &str| -> Result<(String, f64)> {
        let (k, v) = p
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("bad method parameter `{p}` (want key=value)")))?;
        let v: f64 = v
            .parse()
            .map_err(|_| Error::Config(format!("bad method parameter value in `{p}`")))?;
        Ok((k.to_string(), v))
    };
    let method = match name {
        "coala" => match param {
            None => Method::Coala(MuRule::None),
            Some(p) => {
                let (k, v) = parse_param(p)?;
                match k.as_str() {
                    "lambda" => Method::Coala(MuRule::Adaptive { lambda: v }),
                    "mu" => Method::Coala(MuRule::Constant { mu: v }),
                    other => {
                        return Err(Error::Config(format!(
                            "coala takes lambda= or mu=, not `{other}`"
                        )))
                    }
                }
            }
        },
        "svdllm" => Method::SvdLlm,
        "svdllm2" => Method::SvdLlmV2,
        "corda" => Method::Corda,
        "asvd" => Method::Asvd,
        "svd" => Method::PlainSvd,
        "alpha0" => Method::Alpha(0),
        "alpha1" => Method::Alpha(1),
        "alpha2" => Method::Alpha(2),
        other => {
            return Err(Error::Config(format!(
                "unknown method `{other}` (known: {})",
                METHOD_NAMES.join(", ")
            )))
        }
    };
    if param.is_some() && name != "coala" {
        return Err(Error::Config(format!("method `{name}` takes no parameters")));
    }
    Ok(compressor_for(&method))
}

/// Every registered method, canonically parameterized — what the
/// conformance suite iterates and what sweeps default to.
pub fn registry() -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(CoalaCompressor { rule: MuRule::None }),
        Box::new(CoalaCompressor { rule: MuRule::Adaptive { lambda: 3.0 } }),
        Box::new(CoalaCompressor { rule: MuRule::Constant { mu: 1e-2 } }),
        Box::new(SvdLlmCompressor),
        Box::new(SvdLlmV2Compressor),
        Box::new(CordaCompressor),
        Box::new(AsvdCompressor),
        Box::new(PlainSvdCompressor),
        Box::new(AlphaCompressor { alpha: 0 }),
        Box::new(AlphaCompressor { alpha: 1 }),
        Box::new(AlphaCompressor { alpha: 2 }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::accumulate::{make_accumulator, AccumBackend, CalibAccumulator};
    use crate::tensor::lowp::Precision;
    use crate::tensor::ops::context_rel_err;

    /// Accumulate a chunked X stream on the host for a given kind.
    fn accumulate(kind: AccumKind, x: &Matrix<f32>) -> CalibState {
        let xt = x.transpose();
        let mut acc = make_accumulator(kind, xt.cols, AccumBackend::Host, Precision::F32).unwrap();
        // stream in two chunks to exercise real folding
        let half = xt.rows / 2;
        acc.fold_chunk(&xt.slice(0, half, 0, xt.cols)).unwrap();
        acc.fold_chunk(&xt.slice(half, xt.rows, 0, xt.cols)).unwrap();
        acc.finish()
    }

    #[test]
    fn registry_names_unique_and_resolvable() {
        let regs = registry();
        let mut names: Vec<String> = regs.iter().map(|c| c.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), regs.len());
        for n in METHOD_NAMES {
            assert!(resolve(n).is_ok(), "{n} must resolve");
        }
    }

    #[test]
    fn registry_covers_every_method_name() {
        // a method reachable through resolve() must also be in registry(),
        // or the cross-method conformance suite silently skips it
        let regs = registry();
        for n in METHOD_NAMES {
            let m = resolve(n).unwrap().method();
            assert!(
                regs.iter().any(|c| c.method() == m),
                "`{n}` resolves to a method registry() omits"
            );
        }
    }

    #[test]
    fn resolve_parses_parameters() {
        let c = resolve("coala:lambda=2.5").unwrap();
        assert_eq!(c.method(), Method::Coala(MuRule::Adaptive { lambda: 2.5 }));
        let c = resolve("coala:mu=0.125").unwrap();
        assert_eq!(c.method(), Method::Coala(MuRule::Constant { mu: 0.125 }));
        assert!(resolve("coala:sigma=1").is_err());
        assert!(resolve("svdllm:lambda=1").is_err());
        assert!(resolve("nope").is_err());
        assert!(resolve("coala:lambda").is_err());
    }

    #[test]
    fn host_route_runs_every_method_end_to_end() {
        let w: Matrix<f32> = Matrix::randn(8, 6, 1);
        let x: Matrix<f32> = Matrix::randn(6, 48, 2);
        for comp in registry() {
            let calib = accumulate(comp.accum_kind(), &x);
            let f = comp.factorize_host(&w, &calib, 3, 40).unwrap();
            let rec = f.factors.truncate(3).reconstruct().unwrap();
            let err = context_rel_err(&w, &rec, &x).unwrap();
            assert!(err.is_finite() && err < 1.0, "{}: {err}", comp.name());
        }
    }

    #[test]
    fn adaptive_rule_reports_mu() {
        let w: Matrix<f32> = Matrix::randn(8, 6, 3);
        let x: Matrix<f32> = Matrix::randn(6, 40, 4);
        let comp = CoalaCompressor { rule: MuRule::Adaptive { lambda: 2.0 } };
        let calib = accumulate(AccumKind::RFactor, &x);
        let f = comp.factorize_host(&w, &calib, 2, 40).unwrap();
        assert!(f.mu.is_some());
        assert!(f.mu.unwrap() > 0.0);
        let comp0 = CoalaCompressor { rule: MuRule::None };
        assert!(comp0.factorize_host(&w, &calib, 2, 40).unwrap().mu.is_none());
    }

    #[test]
    fn r_consumers_accept_sketch_states() {
        // `--accum sketch` hands the R consumers a Sketch state; the
        // QR-of-sketch stand-in must flow through factorization
        let w: Matrix<f32> = Matrix::randn(8, 6, 6);
        let x: Matrix<f32> = Matrix::randn(6, 48, 7);
        let calib = accumulate(AccumKind::Sketch, &x);
        for comp in [
            Box::new(CoalaCompressor { rule: MuRule::None }) as Box<dyn Compressor>,
            Box::new(AlphaCompressor { alpha: 1 }),
        ] {
            let f = comp.factorize_host(&w, &calib, 3, 40).unwrap();
            assert!(f.factors.u.all_finite() && f.factors.p.all_finite(), "{}", comp.name());
        }
        // Gram consumers still reject it
        assert!(SvdLlmCompressor.factorize_host(&w, &calib, 3, 20).is_err());
    }

    #[test]
    fn wrong_accumulator_kind_reports_config_error() {
        let w: Matrix<f32> = Matrix::randn(6, 5, 5);
        let gram_state = CalibState::Gram(Matrix::zeros(5, 5));
        let err = CoalaCompressor { rule: MuRule::None }
            .factorize_host(&w, &gram_state, 2, 20)
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }
}
