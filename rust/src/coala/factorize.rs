//! Algorithm 1: the stable, inversion-free COALA factorization.

use crate::error::Result;
use crate::linalg::{jacobi_svd, qr_r_square};
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// Low-rank factor pair: W′ = A·B with A (m × r), B (r × n).
#[derive(Debug, Clone)]
pub struct Factors<T: Scalar> {
    pub a: Matrix<T>,
    pub b: Matrix<T>,
    /// Full singular spectrum of the factorization target (diagnostics,
    /// rank selection, Eq. 5).
    pub spectrum: Vec<T>,
}

impl<T: Scalar> Factors<T> {
    pub fn rank(&self) -> usize {
        self.a.cols
    }

    /// Dense reconstruction W′ = A·B.
    pub fn reconstruct(&self) -> Result<Matrix<T>> {
        matmul(&self.a, &self.b)
    }

    /// Parameters stored by the factored form.
    pub fn param_count(&self) -> usize {
        self.a.rows * self.a.cols + self.b.rows * self.b.cols
    }
}

/// Full-spectrum COALA factors (rank = min(m, n)); slice with
/// [`truncate`] for a specific rank.  This mirrors the artifact ABI:
/// (U, σ, P = UᵀW).
#[derive(Debug, Clone)]
pub struct FullFactors<T: Scalar> {
    pub u: Matrix<T>,
    pub sigma: Vec<T>,
    pub p: Matrix<T>,
}

impl<T: Scalar> FullFactors<T> {
    /// Rank-r slice: A = U[:, :r], B = P[:r, :].
    pub fn truncate(&self, r: usize) -> Factors<T> {
        let r = r.min(self.sigma.len()).max(1);
        Factors {
            a: self.u.first_cols(r),
            b: self.p.first_rows(r),
            spectrum: self.sigma.clone(),
        }
    }
}

/// Algorithm 1 given the preprocessed square R (RᵀR = XXᵀ):
/// SVD(W·Rᵀ) → U, then W′_r = U_r·U_rᵀ·W.  No Gram matrix, no inverse,
/// no rank assumptions on X.
pub fn coala_factorize<T: Scalar>(
    w: &Matrix<T>,
    r_factor: &Matrix<T>,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    let target = matmul(w, &r_factor.transpose())?;
    let svd = svd_any(&target, sweeps)?;
    let p = matmul(&svd.0.transpose(), w)?;
    Ok(FullFactors { u: svd.0, sigma: svd.1, p })
}

/// Algorithm 1 end-to-end from raw X (n × k): Prop. 2 QR preprocessing.
pub fn coala_from_x<T: Scalar>(w: &Matrix<T>, x: &Matrix<T>, sweeps: usize) -> Result<FullFactors<T>> {
    let r = qr_r_square(&x.transpose())?;
    coala_factorize(w, &r, sweeps)
}

/// SVD for any aspect ratio, returning (U, σ) — only the left vectors
/// are needed by Prop. 1.  `jacobi_svd` handles wide inputs itself.
pub(crate) fn svd_any<T: Scalar>(a: &Matrix<T>, sweeps: usize) -> Result<(Matrix<T>, Vec<T>)> {
    let s = jacobi_svd(a, sweeps)?;
    Ok((s.u, s.s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{context_rel_err, fro, matmul};

    /// Closed-form optimum of problem (3) in f64 (Prop. 1 via full SVD).
    fn optimal_err(w: &Matrix<f64>, x: &Matrix<f64>, r: usize) -> f64 {
        let wx = matmul(w, x).unwrap();
        let (u, _) = svd_any(&wx, 60).unwrap();
        let ur = u.first_cols(r);
        let wp = matmul(&ur, &matmul(&ur.transpose(), w).unwrap()).unwrap();
        let diff = matmul(&w.sub(&wp).unwrap(), x).unwrap();
        fro(&diff)
    }

    #[test]
    fn attains_optimum_every_rank() {
        let w: Matrix<f64> = Matrix::randn(14, 10, 1);
        let x: Matrix<f64> = Matrix::randn(10, 50, 2);
        let full = coala_from_x(&w, &x, 60).unwrap();
        for r in [1, 3, 5, 10] {
            let wp = full.truncate(r).reconstruct().unwrap();
            let got = fro(&matmul(&w.sub(&wp).unwrap(), &x).unwrap());
            let want = optimal_err(&w, &x, r);
            assert!(got <= want * (1.0 + 1e-8) + 1e-9, "r={r}: {got} vs {want}");
        }
    }

    #[test]
    fn handles_rank_deficient_x() {
        // fewer samples than features: Gram is singular, COALA is fine
        let w: Matrix<f64> = Matrix::randn(8, 12, 3);
        let x: Matrix<f64> = Matrix::randn(12, 5, 4);
        let full = coala_from_x(&w, &x, 60).unwrap();
        let f = full.truncate(3);
        assert!(f.a.all_finite() && f.b.all_finite());
        let got = context_rel_err(&w, &f.reconstruct().unwrap(), &x).unwrap();
        assert!(got.is_finite());
    }

    #[test]
    fn factor_shapes_and_rank() {
        let w: Matrix<f64> = Matrix::randn(6, 9, 5);
        let x: Matrix<f64> = Matrix::randn(9, 30, 6);
        let full = coala_from_x(&w, &x, 40).unwrap();
        let f = full.truncate(4);
        assert_eq!((f.a.rows, f.a.cols), (6, 4));
        assert_eq!((f.b.rows, f.b.cols), (4, 9));
        assert_eq!(f.param_count(), 6 * 4 + 4 * 9);
        assert_eq!(f.rank(), 4);
    }

    #[test]
    fn full_rank_reproduces_wx() {
        let w: Matrix<f64> = Matrix::randn(7, 5, 7);
        let x: Matrix<f64> = Matrix::randn(5, 22, 8);
        let full = coala_from_x(&w, &x, 60).unwrap();
        let wp = full.truncate(5).reconstruct().unwrap();
        let err = context_rel_err(&w, &wp, &x).unwrap();
        assert!(err < 1e-10, "{err}");
    }
}
