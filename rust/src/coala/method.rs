//! Unified method descriptor so the coordinator, evaluator, and benches
//! can sweep compression methods uniformly.

use super::baselines;
use super::factorize::FullFactors;
use super::{alpha, coala_factorize, coala_regularized, MuRule};
use crate::error::Result;
use crate::linalg::qr_r_square;
use crate::tensor::ops::gram_t;
use crate::tensor::{Matrix, Scalar};

/// Every factorization method the experiments compare.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// COALA (Alg. 1 / Alg. 2) with a μ rule.
    Coala(MuRule),
    /// SVD-LLM: Cholesky-of-Gram whitening.
    SvdLlm,
    /// SVD-LLM v2: eig-of-Gram whitening.
    SvdLlmV2,
    /// ASVD activation scaling.
    Asvd,
    /// Plain truncated SVD (Eckart–Young; PiSSA's projection).
    PlainSvd,
    /// Original CorDA (Gram inversion).
    Corda,
    /// Prop. 4 α-family, inversion-free (α ∈ {0, 1, 2}).
    Alpha(u32),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Coala(MuRule::None) => "COALA(mu=0)".into(),
            Method::Coala(r) => format!("COALA[{}]", r.label()),
            Method::SvdLlm => "SVD-LLM".into(),
            Method::SvdLlmV2 => "SVD-LLM-v2".into(),
            Method::Asvd => "ASVD".into(),
            Method::PlainSvd => "SVD".into(),
            Method::Corda => "CorDA".into(),
            Method::Alpha(a) => format!("COALA(a={a})"),
        }
    }

    /// Does this method consume the QR route (R factor) or the Gram route?
    pub fn needs_gram(&self) -> bool {
        matches!(self, Method::SvdLlm | Method::SvdLlmV2 | Method::Corda)
    }

    /// The registry spec that resolves back to this method through
    /// `coala::compressor::resolve` (round-trip guaranteed).
    pub fn spec(&self) -> String {
        match self {
            Method::Coala(MuRule::None) => "coala".into(),
            Method::Coala(MuRule::Adaptive { lambda }) => format!("coala:lambda={lambda}"),
            Method::Coala(MuRule::Constant { mu }) => format!("coala:mu={mu}"),
            Method::SvdLlm => "svdllm".into(),
            Method::SvdLlmV2 => "svdllm2".into(),
            Method::Asvd => "asvd".into(),
            Method::PlainSvd => "svd".into(),
            Method::Corda => "corda".into(),
            Method::Alpha(a) => format!("alpha{a}"),
        }
    }

    /// Host-edition end-to-end factorization from raw calibration X.
    ///
    /// `rank` only matters for the adaptive-μ rule (which needs the
    /// unregularized rank-r solution first); truncation itself is the
    /// caller's job via [`FullFactors::truncate`].
    pub fn factorize_host<T: Scalar>(
        &self,
        w: &Matrix<T>,
        x: &Matrix<T>,
        rank: usize,
        sweeps: usize,
    ) -> Result<FullFactors<T>> {
        match self {
            Method::Coala(MuRule::None) => {
                let r = qr_r_square(&x.transpose())?;
                coala_factorize(w, &r, sweeps)
            }
            Method::Coala(MuRule::Adaptive { lambda }) => {
                let r = qr_r_square(&x.transpose())?;
                let f0 = coala_factorize(w, &r, sweeps)?;
                let mu = super::mu_from_lambda(w, &f0, &r, rank, *lambda)?;
                coala_regularized(w, &r, mu, sweeps)
            }
            Method::Coala(MuRule::Constant { mu }) => {
                let r = qr_r_square(&x.transpose())?;
                coala_regularized(w, &r, *mu, sweeps)
            }
            Method::SvdLlm => {
                let g = gram_t(&x.transpose());
                baselines::svdllm_factorize(w, &g, sweeps)
            }
            Method::SvdLlmV2 => {
                let g = gram_t(&x.transpose());
                baselines::svdllm_v2_factorize(w, &g, sweeps)
            }
            Method::Asvd => {
                let scales = baselines::asvd::activation_scales(x);
                baselines::asvd_factorize(w, &scales, sweeps)
            }
            Method::PlainSvd => baselines::plain_svd_factorize(w, sweeps),
            Method::Corda => {
                let g = gram_t(&x.transpose());
                baselines::corda_factorize(w, &g, sweeps)
            }
            Method::Alpha(a) => {
                let r = qr_r_square(&x.transpose())?;
                alpha::alpha_factorize(w, &r, *a, sweeps)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::context_rel_err;

    #[test]
    fn all_methods_run_on_good_data() {
        let w: Matrix<f64> = Matrix::randn(8, 6, 1);
        let x: Matrix<f64> = Matrix::randn(6, 48, 2);
        let methods = [
            Method::Coala(MuRule::None),
            Method::Coala(MuRule::Adaptive { lambda: 2.0 }),
            Method::Coala(MuRule::Constant { mu: 1e-2 }),
            Method::SvdLlm,
            Method::SvdLlmV2,
            Method::Asvd,
            Method::PlainSvd,
            Method::Corda,
            Method::Alpha(0),
            Method::Alpha(1),
            Method::Alpha(2),
        ];
        for m in methods {
            let f = m.factorize_host(&w, &x, 3, 60).unwrap().truncate(3);
            let err = context_rel_err(&w, &f.reconstruct().unwrap(), &x).unwrap();
            assert!(err.is_finite(), "{}: {err}", m.name());
            assert!(err < 1.0, "{}: {err}", m.name());
        }
    }

    #[test]
    fn names_unique() {
        let methods = [
            Method::Coala(MuRule::None),
            Method::SvdLlm,
            Method::SvdLlmV2,
            Method::Asvd,
            Method::PlainSvd,
            Method::Corda,
            Method::Alpha(2),
        ];
        let mut names: Vec<String> = methods.iter().map(|m| m.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), methods.len());
    }

    #[test]
    fn gram_route_flag() {
        assert!(Method::SvdLlm.needs_gram());
        assert!(Method::Corda.needs_gram());
        assert!(!Method::Coala(MuRule::None).needs_gram());
        assert!(!Method::Alpha(2).needs_gram());
    }
}
