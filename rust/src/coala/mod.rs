//! The paper's algorithms, host edition (S3/S4).
//!
//! Every method produces a [`Factors`] pair (A: m × r, B: r × n) so the
//! coordinator, evaluator, and benches treat methods uniformly.  The
//! PJRT-accelerated editions of the same algorithms live behind
//! `runtime::ops`; these host versions are the fp64 ground truth and the
//! arbitrary-precision laboratory for the stability studies.

pub mod alpha;
pub mod baselines;
pub mod compressor;
pub mod factorize;
pub mod method;
pub mod mu;
pub mod regularized;

pub use compressor::{compressor_for, registry, resolve, Compressor, Factorization, Route};
pub use factorize::{coala_factorize, coala_from_x, Factors};
pub use method::Method;
pub use mu::{mu_from_lambda, MuRule};
pub use regularized::{coala_regularized, regularized_r};
