//! Eq. (5): the layer-adaptive regularization rule.
//!
//! μ = λ · ‖W₀X − WX‖²_F / ‖W₀ − W‖²_F, where W₀ is the unregularized
//! rank-r solution.  The ‖·X‖ norms are evaluated through R
//! (‖AX‖_F = ‖ARᵀ‖_F), so the raw calibration stream never needs to be
//! re-read — this is what makes the rule cheap enough to apply per layer.

use super::factorize::FullFactors;
use crate::error::Result;
use crate::tensor::ops::{fro, matmul};
use crate::tensor::{Matrix, Scalar};

/// How μ is chosen for a layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MuRule {
    /// μ = 0 (the unregularized COALA_{μ=0} rows of Tables 2/3).
    None,
    /// Layer-adaptive Eq. (5) with hyperparameter λ.
    Adaptive { lambda: f64 },
    /// A single constant μ for every layer (the Fig. 4 strawman).
    Constant { mu: f64 },
}

impl MuRule {
    pub fn label(&self) -> String {
        match self {
            MuRule::None => "mu=0".into(),
            MuRule::Adaptive { lambda } => format!("adaptive(λ={lambda})"),
            MuRule::Constant { mu } => format!("const(μ={mu})"),
        }
    }
}

/// Eq. (5): compute μ from the unregularized solution at rank `r`.
pub fn mu_from_lambda<T: Scalar>(
    w: &Matrix<T>,
    full: &FullFactors<T>,
    r_factor: &Matrix<T>,
    rank: usize,
    lambda: f64,
) -> Result<f64> {
    let w0 = full.truncate(rank).reconstruct()?;
    let diff = w0.sub(w)?;
    let num = fro(&matmul(&diff, &r_factor.transpose())?).powi(2);
    let den = fro(&diff).powi(2);
    let scale = fro(w).powi(2);
    if den <= 1e-20 * scale.max(1e-300) {
        return Ok(0.0); // (numerically) exact reconstruction: nothing to regularize
    }
    Ok(lambda * num / den)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_from_x;
    use crate::linalg::qr_r_square;

    #[test]
    fn matches_direct_formula() {
        let w: Matrix<f64> = Matrix::randn(8, 6, 1);
        let x: Matrix<f64> = Matrix::randn(6, 30, 2);
        let full = coala_from_x(&w, &x, 60).unwrap();
        let r = qr_r_square(&x.transpose()).unwrap();
        let mu = mu_from_lambda(&w, &full, &r, 2, 2.0).unwrap();

        let w0 = full.truncate(2).reconstruct().unwrap();
        let diff = w0.sub(&w).unwrap();
        let num = fro(&matmul(&diff, &x).unwrap()).powi(2);
        let den = fro(&diff).powi(2);
        assert!((mu - 2.0 * num / den).abs() < 1e-8 * mu.abs().max(1.0));
    }

    #[test]
    fn scales_linearly_in_lambda() {
        let w: Matrix<f64> = Matrix::randn(8, 6, 3);
        let x: Matrix<f64> = Matrix::randn(6, 30, 4);
        let full = coala_from_x(&w, &x, 60).unwrap();
        let r = qr_r_square(&x.transpose()).unwrap();
        let m1 = mu_from_lambda(&w, &full, &r, 3, 1.0).unwrap();
        let m5 = mu_from_lambda(&w, &full, &r, 3, 5.0).unwrap();
        assert!((m5 - 5.0 * m1).abs() < 1e-9 * m5.abs());
    }

    #[test]
    fn full_rank_gives_zero() {
        let w: Matrix<f64> = Matrix::randn(5, 5, 5);
        let x: Matrix<f64> = Matrix::randn(5, 25, 6);
        let full = coala_from_x(&w, &x, 60).unwrap();
        let r = qr_r_square(&x.transpose()).unwrap();
        let mu = mu_from_lambda(&w, &full, &r, 5, 3.0).unwrap();
        assert!(mu.abs() < 1e-12);
    }

    #[test]
    fn labels() {
        assert_eq!(MuRule::None.label(), "mu=0");
        assert!(MuRule::Adaptive { lambda: 2.0 }.label().contains("2"));
        assert!(MuRule::Constant { mu: 0.5 }.label().contains("0.5"));
    }
}
