//! Algorithm 2: regularized COALA via the augmented matrix (Prop. 3).

use super::factorize::{coala_factorize, FullFactors};
use crate::error::Result;
use crate::linalg::qr_r_square;
use crate::tensor::{Matrix, Scalar};

/// Absorb the μ‖W−W′‖² term into the R factor: re-factor [R ; √μ·I]
/// (2n × n QR) so that R̃ᵀR̃ = XXᵀ + μI = X̃X̃ᵀ with X̃ = [X √μI].
pub fn regularized_r<T: Scalar>(r_factor: &Matrix<T>, mu: f64) -> Result<Matrix<T>> {
    let n = r_factor.rows;
    let sq = Matrix::eye(n).scale(T::from_f64(mu.sqrt()));
    let aug = r_factor.vstack(&sq)?;
    qr_r_square(&aug)
}

/// Algorithm 2: COALA on the μ-augmented problem.
pub fn coala_regularized<T: Scalar>(
    w: &Matrix<T>,
    r_factor: &Matrix<T>,
    mu: f64,
    sweeps: usize,
) -> Result<FullFactors<T>> {
    // health probe: record the effective μ actually absorbed into R̃
    if crate::telemetry::health::enabled() {
        crate::telemetry::health::note(
            crate::telemetry::health::HealthEvent::new("regularize").num("mu", mu),
        );
    }
    coala_factorize(w, &regularized_r(r_factor, mu)?, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::factorize::coala_from_x;
    use crate::linalg::qr_r_square;
    use crate::tensor::ops::{fro, gram_t, matmul, spectral_norm};

    #[test]
    fn augmented_gram_identity() {
        let x: Matrix<f64> = Matrix::randn(9, 40, 1);
        let r0 = qr_r_square(&x.transpose()).unwrap();
        let mu = 0.37;
        let r = regularized_r(&r0, mu).unwrap();
        let got = matmul(&r.transpose(), &r).unwrap();
        let mut want = gram_t(&x.transpose());
        for i in 0..9 {
            want.set(i, i, want.get(i, i) + mu);
        }
        assert!(fro(&got.sub(&want).unwrap()) < 1e-10 * fro(&want));
    }

    #[test]
    fn theorem1_linear_convergence() {
        // ‖W₀ − W_μ‖_F ≤ 2‖W‖₂²‖W‖_F / (σ_r² − σ_{r+1}²) · μ
        let (m, n, k, r) = (10usize, 8usize, 25usize, 3usize);
        let w: Matrix<f64> = Matrix::randn(m, n, 2);
        let x: Matrix<f64> = Matrix::randn(n, k, 3);
        let w0 = coala_from_x(&w, &x, 60).unwrap().truncate(r).reconstruct().unwrap();

        let wx = matmul(&w, &x).unwrap();
        let svd = crate::linalg::jacobi_svd(&wx, 60).unwrap();
        let gap2 = svd.s[r - 1] * svd.s[r - 1] - svd.s[r] * svd.s[r];
        let c = 2.0 * spectral_norm(&w, 200).powi(2) * fro(&w) / gap2;

        let r0 = qr_r_square(&x.transpose()).unwrap();
        let mut last = f64::INFINITY;
        for mu in [1e-1, 1e-2, 1e-3, 1e-4] {
            let wmu = coala_regularized(&w, &r0, mu, 60)
                .unwrap()
                .truncate(r)
                .reconstruct()
                .unwrap();
            let err = fro(&w0.sub(&wmu).unwrap());
            assert!(err <= c * mu * (1.0 + 1e-6) + 1e-9, "mu={mu}: {err} > {}", c * mu);
            assert!(err <= last + 1e-12);
            last = err;
        }
    }

    #[test]
    fn mu_zero_is_identity() {
        let x: Matrix<f64> = Matrix::randn(6, 20, 4);
        let w: Matrix<f64> = Matrix::randn(5, 6, 5);
        let r0 = qr_r_square(&x.transpose()).unwrap();
        let a = coala_factorize(&w, &r0, 60).unwrap().truncate(2).reconstruct().unwrap();
        let b = coala_regularized(&w, &r0, 0.0, 60).unwrap().truncate(2).reconstruct().unwrap();
        assert!(fro(&a.sub(&b).unwrap()) < 1e-9);
    }

    #[test]
    fn regularization_fixes_degenerate_x() {
        // k < n: the unregularized problem has non-unique solutions; the
        // regularized one is unique and finite for any μ > 0.
        let w: Matrix<f64> = Matrix::randn(7, 10, 6);
        let x: Matrix<f64> = Matrix::randn(10, 4, 7);
        let r0 = qr_r_square(&x.transpose()).unwrap();
        let f = coala_regularized(&w, &r0, 1e-2, 60).unwrap().truncate(3);
        assert!(f.a.all_finite() && f.b.all_finite());
    }
}
