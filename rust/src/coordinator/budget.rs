//! Rank/budget allocator (S8): compression ratio → per-projection rank.
//!
//! The paper compresses Q, K, V, O, Up, Down "with the same rank r to
//! achieve the desired parameter ratio" — that is the `Uniform` policy.
//! `PerMatrix` (an ablation the DESIGN calls out) instead equalizes the
//! per-matrix ratio, giving wide MLP matrices proportionally larger
//! ranks.

use crate::error::{Error, Result};
use crate::runtime::manifest::ModelSpec;
use std::collections::BTreeMap;

/// Allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankPolicy {
    /// One common rank for every projection (the paper's rule).
    Uniform,
    /// rank_p ∝ per-matrix budget: r_p = ratio·m_p·n_p / (m_p + n_p).
    PerMatrix,
}

/// The resolved allocation.
#[derive(Debug, Clone)]
pub struct RankBudget {
    pub policy: RankPolicy,
    pub target_ratio: f64,
    pub ranks: BTreeMap<String, usize>,
}

impl RankBudget {
    /// Allocate for `target_ratio` = kept-parameters / original (e.g.
    /// Table 3's "80 %" row keeps 0.8 of the parameters ⇒ ratio 0.8 of
    /// the projection budget).
    pub fn allocate(spec: &ModelSpec, target_ratio: f64, policy: RankPolicy) -> Result<RankBudget> {
        if !(0.0..=1.0).contains(&target_ratio) {
            return Err(Error::Config(format!("ratio {target_ratio} outside [0, 1]")));
        }
        let mut ranks = BTreeMap::new();
        match policy {
            RankPolicy::Uniform => {
                // Σ r(m+n) = ratio Σ mn  ⇒  r = ratio Σmn / Σ(m+n)
                let (mut num, mut den) = (0.0f64, 0.0f64);
                for p in &spec.compressible {
                    let (m, n) = spec.proj_shape(p)?;
                    num += (m * n) as f64;
                    den += (m + n) as f64;
                }
                let r = ((target_ratio * num / den).floor() as usize).max(1);
                for p in &spec.compressible {
                    let (m, n) = spec.proj_shape(p)?;
                    ranks.insert(p.clone(), r.min(m.min(n)));
                }
            }
            RankPolicy::PerMatrix => {
                for p in &spec.compressible {
                    let (m, n) = spec.proj_shape(p)?;
                    let r = ((target_ratio * (m * n) as f64 / (m + n) as f64).floor() as usize)
                        .max(1)
                        .min(m.min(n));
                    ranks.insert(p.clone(), r);
                }
            }
        }
        Ok(RankBudget { policy, target_ratio, ranks })
    }

    pub fn rank(&self, proj: &str) -> Result<usize> {
        self.ranks
            .get(proj)
            .copied()
            .ok_or_else(|| Error::Config(format!("no rank for `{proj}`")))
    }

    /// Parameters kept by this allocation.
    pub fn kept_params(&self, spec: &ModelSpec) -> Result<usize> {
        let mut total = 0;
        for (p, &r) in &self.ranks {
            let (m, n) = spec.proj_shape(p)?;
            total += r * (m + n);
        }
        Ok(total)
    }

    /// Achieved ratio vs the original projection parameters.
    pub fn achieved_ratio(&self, spec: &ModelSpec) -> Result<f64> {
        let mut orig = 0usize;
        for p in &self.ranks.keys().cloned().collect::<Vec<_>>() {
            let (m, n) = spec.proj_shape(p)?;
            orig += m * n;
        }
        Ok(self.kept_params(spec)? as f64 / orig as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::prop::assert_prop;

    fn spec() -> Option<ModelSpec> {
        Manifest::load("artifacts").ok().and_then(|m| m.config("tiny").ok().cloned())
    }

    #[test]
    fn uniform_hits_target_within_one_rank_step() {
        let Some(s) = spec() else { return };
        for ratio in [0.1, 0.2, 0.3, 0.5, 0.8] {
            let b = RankBudget::allocate(&s, ratio, RankPolicy::Uniform).unwrap();
            let achieved = b.achieved_ratio(&s).unwrap();
            // floor() undershoots by at most one rank step
            assert!(achieved <= ratio + 1e-9, "{ratio}: {achieved}");
            let r = *b.ranks.values().next().unwrap();
            let b2_ratio = (r + 1) as f64 / r.max(1) as f64 * achieved;
            assert!(b2_ratio >= ratio * 0.99, "{ratio}: way under");
        }
    }

    #[test]
    fn uniform_assigns_same_rank() {
        let Some(s) = spec() else { return };
        let b = RankBudget::allocate(&s, 0.3, RankPolicy::Uniform).unwrap();
        let ranks: Vec<usize> = b.ranks.values().copied().collect();
        assert!(ranks.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn per_matrix_gives_wider_mats_larger_ranks() {
        let Some(s) = spec() else { return };
        let b = RankBudget::allocate(&s, 0.3, RankPolicy::PerMatrix).unwrap();
        let r_attn = b.rank("l0.wq").unwrap();
        let r_up = b.rank("l0.w_up").unwrap();
        assert!(r_up > r_attn, "{r_up} vs {r_attn}");
    }

    #[test]
    fn property_monotone_and_bounded() {
        let Some(s) = spec() else { return };
        // property: achieved ratio is monotone in target and never
        // exceeds it; every rank ≤ min(m, n); kept_params consistent.
        assert_prop(
            "budget-monotone",
            7,
            60,
            |rng| (1 + rng.below(99), 1 + rng.below(99)),
            |&(a, b)| {
                let (lo, hi) = (a.min(b) as f64 / 100.0, a.max(b) as f64 / 100.0);
                let blo = RankBudget::allocate(&s, lo, RankPolicy::Uniform).map_err(|e| e.to_string())?;
                let bhi = RankBudget::allocate(&s, hi, RankPolicy::Uniform).map_err(|e| e.to_string())?;
                let alo = blo.achieved_ratio(&s).map_err(|e| e.to_string())?;
                let ahi = bhi.achieved_ratio(&s).map_err(|e| e.to_string())?;
                if alo > ahi + 1e-9 {
                    return Err(format!("not monotone: {alo} > {ahi}"));
                }
                for (p, &r) in &bhi.ranks {
                    let (m, n) = s.proj_shape(p).map_err(|e| e.to_string())?;
                    if r > m.min(n) {
                        return Err(format!("{p}: rank {r} > min dim"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rejects_bad_ratio() {
        let Some(s) = spec() else { return };
        assert!(RankBudget::allocate(&s, 1.5, RankPolicy::Uniform).is_err());
        assert!(RankBudget::allocate(&s, -0.1, RankPolicy::Uniform).is_err());
    }
}
