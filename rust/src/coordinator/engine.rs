//! The source-agnostic parallel execution engine — the one
//! calibrate → accumulate → factorize control flow in the crate.
//!
//! Every driver that used to hand-roll this staging (the sequential
//! [`super::pipeline::Pipeline`], the overlapped
//! [`super::scheduler::calibrate_overlapped`], the multi-device
//! [`super::tsqr_tree::TsqrTreeRunner`]) is now a thin configuration of
//! this module: an [`EnginePlan`] choosing how many workers each stage
//! gets, plus an [`ActivationSource`] saying where chunks come from
//! (device capture or the synthetic host generator).
//!
//! ```text
//!   capture workers ──(b, chunks)──▶ bounded channel (backpressure)
//!        │                               │
//!        │ source.capture_batch(b)       ▼
//!        │                    accumulate shards: per-(layer, stream,
//!        │                    batch) leaf states via CalibAccumulator
//!        ▼                               │
//!   canonical pairwise merge tree over batch order (merge_state)
//!        ▼
//!   CalibStates ──▶ factorize workers fan the Compressor registry
//!                   across projections ──▶ CompressedModel
//! ```
//!
//! **Determinism.** Results are bitwise-independent of every worker
//! count.  Each (layer, stream, batch) leaf folds exactly that batch's
//! chunks for the key (in the source's chunk order), so leaves are
//! identical no matter which worker computes them, and the
//! partial states reduce through a *canonical* pairwise merge tree over
//! ascending batch index — the tree shape depends only on the batch
//! count, never on `capture_workers`/`accum_shards` (floating-point
//! merges are not associative, so an opportunistic reduction order would
//! leak the worker count into the bits).  Sibling pairs merge as soon as
//! both subtrees are finished, whichever shard holds the second one, so
//! the reduction overlaps with capture.  The factorize stage is
//! embarrassingly parallel per projection and collects results in
//! projection order.  This is the stable parallel-merge-of-partial-
//! factors regime where the paper's inversion-free accumulation pays off
//! over Gram-based schemes (cf. Phan et al., 2020).
//!
//! X is never materialized: peak memory is `queue_cap` batches of chunks
//! in flight plus O(log batches) pending merge-tree nodes per (layer,
//! stream) key.  A failure in either stage cancels the other promptly
//! (capture workers stop pulling batches; shards drain the channel
//! without folding), and both errors surface via [`Error::context`].

use crate::calib::accumulate::{
    make_accumulator, merge_states, AccumBackend, AccumKind, CalibAccumulator, CalibState,
};
use crate::calib::activations::{ActivationSource, CalibChunk};
use crate::coala::compressor::{compressor_for, Compressor, Route};
use crate::coala::factorize::Factors;
use crate::coala::Method;
use crate::error::{Error, Result};
use crate::model::{CompressedModel, ModelWeights};
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::lowp::Precision;
use crate::util::threads::parallel_map;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Per-(layer, stream) finished accumulator states.
pub type CalibStates = BTreeMap<(usize, String), CalibState>;

/// Per-stage busy time (drives Table 1 + the §Perf profile).  With
/// overlapped stages these are *worker-seconds per stage* (summed across
/// workers), not wall-clock; `total_s` is set to the wall-clock of the
/// whole run by the pipeline entry points.
#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub calibrate_s: f64,
    pub accumulate_s: f64,
    pub factorize_s: f64,
    pub total_s: f64,
}

/// How many workers each engine stage gets.  Every plan computes
/// bitwise-identical results; the plan only chooses the parallelism.
#[derive(Debug, Clone, Copy)]
pub struct EnginePlan {
    /// Threads calling `ActivationSource::capture_batch` concurrently.
    pub capture_workers: usize,
    /// Threads folding chunks into leaf states (sharded accumulate).
    pub accum_shards: usize,
    /// Threads fanning per-projection factorizations.
    pub factorize_workers: usize,
    /// Bounded-channel capacity in batches (the backpressure knob): if
    /// accumulation falls behind, capture blocks instead of buffering
    /// unbounded chunks.
    pub queue_cap: usize,
}

impl Default for EnginePlan {
    fn default() -> Self {
        EnginePlan::sequential()
    }
}

impl EnginePlan {
    /// One worker per stage — the sequential configuration (capture and
    /// accumulate still overlap through the channel).
    pub fn sequential() -> EnginePlan {
        EnginePlan { capture_workers: 1, accum_shards: 1, factorize_workers: 1, queue_cap: 2 }
    }

    /// `workers` threads for every stage (the `--workers` CLI knob).
    pub fn with_workers(workers: usize) -> EnginePlan {
        let w = workers.max(1);
        EnginePlan { capture_workers: w, accum_shards: w, factorize_workers: w, queue_cap: 2 }
    }

    fn normalized(&self) -> EnginePlan {
        EnginePlan {
            capture_workers: self.capture_workers.max(1),
            accum_shards: self.accum_shards.max(1),
            factorize_workers: self.factorize_workers.max(1),
            queue_cap: self.queue_cap.max(1),
        }
    }
}

/// Capture + sharded accumulate + canonical merge-tree reduction: drive
/// `batches` batches of `source` into per-(layer, stream) states.
///
/// Capture workers and accumulate shards run concurrently, connected by
/// a bounded channel.  Errors from *both* stages are surfaced: when both
/// fail, the capture error carries the accumulate error in its
/// [`Error::context`] chain instead of silently dropping one of them.
pub fn calibrate(
    source: &dyn ActivationSource,
    kind: AccumKind,
    batches: usize,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    timings: &mut StageTimings,
) -> Result<CalibStates> {
    let plan = plan.normalized();
    let next_batch = AtomicUsize::new(0);
    let cancelled = AtomicBool::new(false);
    let slots: Mutex<SlotMap> = Mutex::new(HashMap::new());
    let (tx, rx) = mpsc::sync_channel::<(usize, Vec<CalibChunk>)>(plan.queue_cap);
    // each shard owns an Arc share of the receiver, so if every shard
    // dies (even by panic) the channel closes and blocked senders exit
    let rx = Arc::new(Mutex::new(rx));

    let mut capture_secs = 0.0;
    let mut accum_secs = 0.0;
    let mut capture_err: Option<Error> = None;
    let mut accum_err: Option<Error> = None;

    std::thread::scope(|s| {
        let mut cap_handles = Vec::new();
        for _ in 0..plan.capture_workers {
            let tx = tx.clone();
            let next = &next_batch;
            let cancelled = &cancelled;
            cap_handles.push(s.spawn(move || -> (f64, Result<()>) {
                let mut busy = 0.0;
                loop {
                    if cancelled.load(Ordering::Relaxed) {
                        // some stage failed; its error surfaces below
                        return (busy, Ok(()));
                    }
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= batches {
                        return (busy, Ok(()));
                    }
                    let t0 = Instant::now();
                    let chunks = match source.capture_batch(b) {
                        Ok(c) => c,
                        Err(e) => {
                            cancelled.store(true, Ordering::Relaxed);
                            return (busy + t0.elapsed().as_secs_f64(), Err(e));
                        }
                    };
                    busy += t0.elapsed().as_secs_f64();
                    if tx.send((b, chunks)).is_err() {
                        // every accumulate shard died; their error
                        // surfaces below — stop producing
                        return (busy, Ok(()));
                    }
                }
            }));
        }
        drop(tx); // shards see EOF once every capture worker finishes

        let mut acc_handles = Vec::new();
        for _ in 0..plan.accum_shards {
            let rx = rx.clone();
            let slots = &slots;
            let cancelled = &cancelled;
            acc_handles.push(s.spawn(move || -> (f64, Result<()>) {
                let mut busy = 0.0;
                let mut failed: Option<Error> = None;
                loop {
                    let payload = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok((b, chunks)) = payload else {
                        // channel closed: every batch was delivered
                        return (busy, failed.map_or(Ok(()), Err));
                    };
                    if failed.is_some() || cancelled.load(Ordering::Relaxed) {
                        continue; // drain so blocked capture workers exit
                    }
                    let t0 = Instant::now();
                    let res = (|| -> Result<()> {
                        // fold every chunk of the batch into its key's
                        // leaf (a source may emit several chunks per
                        // (layer, stream); chunk order within a batch
                        // is the source's, so leaves stay worker-count
                        // independent), then push the finished leaves
                        // into the merge tree
                        let mut leaf: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> =
                            BTreeMap::new();
                        for c in chunks {
                            let acc = leaf
                                .entry((c.layer, c.stream.clone()))
                                .or_insert_with(|| {
                                    make_accumulator(kind, c.xt.cols, backend, precision)
                                });
                            acc.fold_chunk(&c.xt)?;
                        }
                        for (key, acc) in leaf {
                            insert_state(slots, batches, &key, acc.finish(), backend, precision, b)?;
                        }
                        Ok(())
                    })();
                    if let Err(e) = res {
                        cancelled.store(true, Ordering::Relaxed);
                        failed = Some(e);
                    }
                    busy += t0.elapsed().as_secs_f64();
                }
            }));
        }
        drop(rx); // only the shards keep the receiver alive now

        for h in cap_handles {
            match h.join() {
                Ok((secs, res)) => {
                    capture_secs += secs;
                    if let Err(e) = res {
                        capture_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    capture_err.get_or_insert(Error::msg("capture worker panicked"));
                }
            }
        }
        for h in acc_handles {
            match h.join() {
                Ok((secs, res)) => {
                    accum_secs += secs;
                    if let Err(e) = res {
                        accum_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    accum_err.get_or_insert(Error::msg("accumulate worker panicked"));
                }
            }
        }
    });

    match (capture_err, accum_err) {
        (Some(c), Some(a)) => {
            // both stages failed: chain so neither error is lost
            return Err(c.context(format!(
                "capture stage failed (accumulate stage also failed: {a})"
            )));
        }
        (Some(c), None) => return Err(c.context("capture stage failed")),
        (None, Some(a)) => return Err(a.context("accumulate stage failed")),
        (None, None) => {}
    }

    // ---- collect the merge-tree roots -----------------------------------
    // On the normal path every key has exactly one finished root.  A key
    // the source omitted from some batches leaves orphan subtrees; fold
    // them in canonical (level, index) order so even that is worker-
    // count independent.
    let t_red = Instant::now();
    let mut per_key: BTreeMap<(usize, String), Vec<((u32, usize), CalibState)>> = BTreeMap::new();
    for ((key, level, index), state) in slots.into_inner().unwrap() {
        per_key.entry(key).or_default().push(((level, index), state));
    }
    let mut out = CalibStates::new();
    for (key, mut nodes) in per_key {
        nodes.sort_by_key(|(pos, _)| *pos);
        let state = if nodes.len() == 1 {
            nodes.pop().unwrap().1
        } else {
            reduce_tree(nodes.into_iter().map(|(_, st)| st).collect(), backend, precision)?
        };
        out.insert(key, state);
    }
    timings.calibrate_s += capture_secs;
    timings.accumulate_s += accum_secs + t_red.elapsed().as_secs_f64();
    Ok(out)
}

/// Pending merge-tree nodes: (key, level, index) → finished subtree
/// state.  Leaf `b` sits at (0, b); node (L, i) is the merge of
/// (L−1, 2i) and (L−1, 2i+1), with a trailing odd node promoting
/// unchanged — the same shape as [`reduce_tree`].
type SlotMap = HashMap<((usize, String), u32, usize), CalibState>;

/// Node count at a merge-tree level: ceil(batches / 2^level).
fn level_size(batches: usize, level: u32) -> usize {
    let mut n = batches;
    for _ in 0..level {
        if n <= 1 {
            break;
        }
        n = n.div_ceil(2);
    }
    n
}

/// Insert a finished subtree node and greedily merge completed sibling
/// pairs up the canonical tree.  Pairs always merge left-to-right, so
/// the result is bitwise-independent of arrival order and worker count,
/// and at most O(log batches) nodes per key are pending at any moment —
/// the out-of-core property the streaming design exists for.
fn insert_state(
    slots: &Mutex<SlotMap>,
    batches: usize,
    key: &(usize, String),
    state: CalibState,
    backend: AccumBackend<'_>,
    precision: Precision,
    batch: usize,
) -> Result<()> {
    let mut level = 0u32;
    let mut index = batch;
    let mut state = state;
    loop {
        let size = level_size(batches, level);
        if size <= 1 {
            // the root: the only node of its level
            slots.lock().unwrap().insert((key.clone(), level, 0), state);
            return Ok(());
        }
        if index == size - 1 && size % 2 == 1 {
            // odd tail: no sibling at this level — promote unchanged
            level += 1;
            index /= 2;
            continue;
        }
        let sibling = (key.clone(), level, index ^ 1);
        let mut guard = slots.lock().unwrap();
        match guard.remove(&sibling) {
            Some(other) => {
                drop(guard); // merge outside the lock
                let (a, b) = if index % 2 == 0 { (state, other) } else { (other, state) };
                state = merge_states(a, b, backend, precision)?;
                level += 1;
                index /= 2;
            }
            None => {
                guard.insert((key.clone(), level, index), state);
                return Ok(());
            }
        }
    }
}

/// Pairwise merge of partial states in a fixed left-to-right tree: the
/// shape depends only on the partial count, so the result is independent
/// of how many workers produced the partials.  [`insert_state`] performs
/// the same reduction incrementally; this eager form serves the orphan
/// fallback and the single-vector case.
fn reduce_tree(
    mut level: Vec<CalibState>,
    backend: AccumBackend<'_>,
    precision: Precision,
) -> Result<CalibState> {
    if level.is_empty() {
        return Err(Error::Config("reduce over zero partial states".into()));
    }
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(merge_states(a, b, backend, precision)?),
                None => next.push(a),
            }
        }
        level = next;
    }
    Ok(level.pop().unwrap())
}

/// Parallel factorize stage: fan the per-projection factorizations of a
/// method across `workers` threads through the `Compressor` registry.
/// Results assemble in projection order, so the outcome is independent
/// of the worker count.
#[allow(clippy::too_many_arguments)]
pub fn factorize(
    config: &str,
    spec: &ModelSpec,
    weights: &ModelWeights,
    method: &Method,
    budget: &super::budget::RankBudget,
    accums: &CalibStates,
    route: Route,
    ex: &Executor,
    host_sweeps: usize,
    workers: usize,
) -> Result<(CompressedModel, BTreeMap<String, f64>)> {
    type ProjResult = Result<(String, Option<f64>, Factors<f32>)>;
    let projs = &spec.compressible;
    let results = parallel_map(projs.len(), workers.max(1), |i| -> ProjResult {
        let proj = &projs[i];
        let w = weights.matrix(proj)?;
        let layer: usize = proj[1..]
            .split('.')
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Config(format!("bad projection name `{proj}`")))?;
        let stream = spec.stream_of(proj)?.to_string();
        let calib = accums
            .get(&(layer, stream))
            .ok_or_else(|| Error::Config(format!("no accumulator for {proj}")))?;
        let rank = budget.rank(proj)?;
        let comp = compressor_for(method);
        let fz = comp.factorize(route, ex, &w, calib, rank, host_sweeps)?;
        Ok((proj.clone(), fz.mu, fz.factors.truncate(rank)))
    });

    let mut model = CompressedModel::new(config);
    let mut mus = BTreeMap::new();
    for res in results {
        let (proj, mu, factors) = res?;
        if let Some(mu) = mu {
            mus.insert(proj.clone(), mu);
        }
        model.insert(&proj, factors);
    }
    Ok((model, mus))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::SyntheticActivations;
    use crate::model::synthetic::synthetic_manifest;
    use crate::tensor::Matrix;

    struct FailingSource {
        fail_at: usize,
    }

    impl ActivationSource for FailingSource {
        fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
            if b >= self.fail_at {
                return Err(Error::msg(format!("capture exploded at batch {b}")));
            }
            Ok(vec![CalibChunk {
                layer: 0,
                stream: "s".into(),
                xt: Matrix::randn(6, 4, b as u64),
            }])
        }
    }

    #[test]
    fn calibrate_covers_every_stream_and_is_plan_invariant() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 3);
        let mut reference: Option<CalibStates> = None;
        for plan in [
            EnginePlan::sequential(),
            EnginePlan::with_workers(3),
            EnginePlan { capture_workers: 2, accum_shards: 4, factorize_workers: 1, queue_cap: 1 },
        ] {
            let mut t = StageTimings::default();
            let states = calibrate(
                &src,
                AccumKind::RFactor,
                2,
                AccumBackend::Host,
                Precision::F32,
                &plan,
                &mut t,
            )
            .unwrap();
            assert_eq!(states.len(), spec.n_layers * spec.act_streams.len());
            match &reference {
                None => reference = Some(states),
                Some(want) => {
                    for (k, s) in want {
                        let (a, b) = (s.r().unwrap(), states[k].r().unwrap());
                        assert_eq!(a.data, b.data, "{k:?} differs across plans");
                    }
                }
            }
        }
    }

    #[test]
    fn capture_error_surfaces() {
        let src = FailingSource { fail_at: 1 };
        let err = calibrate(
            &src,
            AccumKind::RFactor,
            3,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::with_workers(2),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("capture stage failed"), "{msg}");
        assert!(msg.contains("capture exploded"), "{msg}");
    }

    #[test]
    fn concurrent_stage_failures_surface_with_stage_context() {
        // capture dies on batch 1 while the accumulate stage dies
        // folding batch 0 (the synthetic manifest has no artifacts, so
        // the device backend's tsqr_step fails).  Scheduling decides
        // whether cancellation prevents the second failure; in every
        // interleaving the surfaced error names its failed stage (and
        // when both fail, the context chain carries both — the old
        // scheduler silently dropped one).
        let ex = crate::runtime::executor::Executor::from_manifest(synthetic_manifest()).unwrap();
        let src = FailingSource { fail_at: 1 };
        let err = calibrate(
            &src,
            AccumKind::RFactor,
            2,
            AccumBackend::Device(&ex),
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("stage failed"), "{msg}");
    }

    #[test]
    fn stage_failure_cancels_remaining_batches_promptly() {
        // a merge failure at batch 1 (width change, scales route) must
        // stop the run long before all 1000 batches are captured
        struct CountingSource {
            calls: std::sync::atomic::AtomicUsize,
        }
        impl ActivationSource for CountingSource {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let cols = if b == 0 { 4 } else { 3 };
                Ok(vec![CalibChunk {
                    layer: 0,
                    stream: "s".into(),
                    xt: Matrix::randn(5, cols, b as u64),
                }])
            }
        }
        let src = CountingSource { calls: std::sync::atomic::AtomicUsize::new(0) };
        let err = calibrate(
            &src,
            AccumKind::Scales,
            1000,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("accumulate stage failed"), "{err}");
        let captured = src.calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(captured < 900, "cancellation did not stop capture: {captured} batches");
    }

    #[test]
    fn merge_width_mismatch_is_reported() {
        struct TwoWidths;
        impl ActivationSource for TwoWidths {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                let cols = if b == 0 { 4 } else { 3 };
                Ok(vec![CalibChunk {
                    layer: 0,
                    stream: "s".into(),
                    xt: Matrix::randn(5, cols, b as u64),
                }])
            }
        }
        let err = calibrate(
            &TwoWidths,
            AccumKind::Scales,
            2,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("width mismatch"), "{err}");
    }

    #[test]
    fn reduce_tree_rejects_empty() {
        assert!(reduce_tree(Vec::new(), AccumBackend::Host, Precision::F32).is_err());
    }

    #[test]
    fn multiple_chunks_per_stream_in_one_batch_all_fold() {
        // a source may split a batch into several chunks for the same
        // (layer, stream); every chunk must land in the leaf (an early
        // engine draft overwrote the first with the second)
        struct SplitSource;
        impl ActivationSource for SplitSource {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                Ok(vec![
                    CalibChunk { layer: 0, stream: "s".into(), xt: Matrix::randn(5, 4, b as u64) },
                    CalibChunk {
                        layer: 0,
                        stream: "s".into(),
                        xt: Matrix::randn(7, 4, 100 + b as u64),
                    },
                ])
            }
        }
        let mut reference: Option<CalibStates> = None;
        for plan in [EnginePlan::sequential(), EnginePlan::with_workers(4)] {
            let states = calibrate(
                &SplitSource,
                AccumKind::Scales,
                3,
                AccumBackend::Host,
                Precision::F32,
                &plan,
                &mut StageTimings::default(),
            )
            .unwrap();
            let CalibState::Scales { rows, .. } = &states[&(0, "s".to_string())] else {
                panic!("not scales");
            };
            // 3 batches × (5 + 7) rows: nothing silently dropped
            assert_eq!(*rows, 3 * 12);
            match &reference {
                None => reference = Some(states),
                Some(want) => {
                    let (CalibState::Scales { sum_abs: a, .. }, CalibState::Scales { sum_abs: b, .. }) =
                        (&want[&(0, "s".to_string())], &states[&(0, "s".to_string())])
                    else {
                        panic!("not scales");
                    };
                    assert_eq!(a, b, "split-chunk leaves differ across plans");
                }
            }
        }
    }
}
