//! The source-agnostic parallel execution engine — the one
//! calibrate → accumulate → factorize control flow in the crate.
//!
//! Every driver that used to hand-roll this staging (the sequential
//! [`super::pipeline::Pipeline`], the overlapped
//! [`super::scheduler::calibrate_overlapped`], the multi-device
//! [`super::tsqr_tree::TsqrTreeRunner`]) is now a thin configuration of
//! this module: an [`EnginePlan`] choosing how many workers each stage
//! gets, plus an [`ActivationSource`] saying where chunks come from
//! (device capture or the synthetic host generator).
//!
//! ```text
//!   capture workers ──(b, chunks)──▶ bounded channel (backpressure)
//!        │                               │
//!        │ source.capture_batch(b)       ▼
//!        │                    accumulate shards: per-(layer, stream,
//!        │                    batch) leaf states via CalibAccumulator
//!        ▼                               │
//!   canonical pairwise merge tree over batch order (merge_state)
//!        ▼
//!   CalibStates ──▶ factorize workers fan the Compressor registry
//!                   across projections ──▶ CompressedModel
//! ```
//!
//! **Determinism.** Results are bitwise-independent of every worker
//! count.  Each (layer, stream, batch) leaf folds exactly that batch's
//! chunks for the key (in the source's chunk order), so leaves are
//! identical no matter which worker computes them, and the
//! partial states reduce through a *canonical* pairwise merge tree over
//! ascending batch index — the tree shape depends only on the batch
//! count, never on `capture_workers`/`accum_shards` (floating-point
//! merges are not associative, so an opportunistic reduction order would
//! leak the worker count into the bits).  Sibling pairs merge as soon as
//! both subtrees are finished, whichever shard holds the second one, so
//! the reduction overlaps with capture.  The factorize stage is
//! embarrassingly parallel per projection and collects results in
//! projection order.  This is the stable parallel-merge-of-partial-
//! factors regime where the paper's inversion-free accumulation pays off
//! over Gram-based schemes (cf. Phan et al., 2020).
//!
//! X is never materialized: peak memory is `queue_cap` batches of chunks
//! in flight plus O(log batches) pending merge-tree nodes per (layer,
//! stream) key.  A failure in either stage cancels the other promptly
//! (capture workers stop pulling batches; shards drain the channel
//! without folding), and both errors surface via [`Error::context`].

use crate::calib::accumulate::{
    make_leaf_accumulator, merge_states, AccumBackend, AccumKind, CalibAccumulator, CalibState,
};
use crate::calib::activations::{ActivationSource, CalibChunk};
use crate::calib::state::{ShardState, StateNode};
use crate::coala::compressor::{compressor_for, Compressor, Route};
use crate::coala::factorize::Factors;
use crate::coala::Method;
use crate::error::{Error, Result};
use crate::model::{CompressedModel, ModelWeights};
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::telemetry::{alloc, health, TelemetrySink};
use crate::tensor::lowp::Precision;
use crate::util::threads::parallel_map;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Per-(layer, stream) finished accumulator states.
pub type CalibStates = BTreeMap<(usize, String), CalibState>;

/// Per-stage busy time (drives Table 1 + the §Perf profile).  With
/// overlapped stages these are *worker-seconds per stage* (summed across
/// workers), not wall-clock; `total_s` is set to the wall-clock of the
/// whole run by the pipeline entry points.
#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub calibrate_s: f64,
    pub accumulate_s: f64,
    /// Canonical merge-tree reductions (sibling merges in
    /// [`insert_node`] plus the orphan fallback in `collect_states`),
    /// split out from leaf folding so a slow merge kernel is visible.
    pub merge_s: f64,
    pub factorize_s: f64,
    pub total_s: f64,
    /// Worker-seconds capture spent blocked in `send` on the bounded
    /// channel (accumulate fell behind — the backpressure the
    /// `queue_cap` knob exists to create, now visible).
    pub capture_stall_s: f64,
    /// Worker-seconds accumulate shards spent blocked in `recv`
    /// waiting for capture to produce (the opposite imbalance).
    pub accum_idle_s: f64,
    /// Allocator peak watermark over the calibration window(s)
    /// (`COALA_ALLOC_STATS=1`; 0 when disarmed).  Capture, accumulate,
    /// and merge run concurrently and share one working set, so one
    /// shared watermark is attributed to all of them.
    pub calib_peak_bytes: u64,
    /// Live bytes when the last calibration window closed.
    pub calib_cur_bytes: u64,
    /// Allocation-count delta over the calibration window(s) — the
    /// churn the `with_capacity` sweeps exist to shrink.
    pub calib_allocs: u64,
    /// High-water mark of batches in flight between capture and
    /// accumulate (bounded-channel depth; always tracked — two relaxed
    /// atomic ops per batch).
    pub queue_depth_hwm: usize,
}

/// How many workers each engine stage gets.  Every plan computes
/// bitwise-identical results; the plan only chooses the parallelism.
#[derive(Debug, Clone)]
pub struct EnginePlan {
    /// Threads calling `ActivationSource::capture_batch` concurrently.
    pub capture_workers: usize,
    /// Threads folding chunks into leaf states (sharded accumulate).
    pub accum_shards: usize,
    /// Threads fanning per-projection factorizations.
    pub factorize_workers: usize,
    /// Bounded-channel capacity in batches (the backpressure knob): if
    /// accumulation falls behind, capture blocks instead of buffering
    /// unbounded chunks.
    pub queue_cap: usize,
    /// Where stage timings and counters go.  Observes only — a run with
    /// telemetry enabled is bitwise-identical to one without.  Defaults
    /// to disabled (a no-op on the default build).
    pub telemetry: TelemetrySink,
}

impl Default for EnginePlan {
    fn default() -> Self {
        EnginePlan::sequential()
    }
}

impl EnginePlan {
    /// One worker per stage — the sequential configuration (capture and
    /// accumulate still overlap through the channel).
    pub fn sequential() -> EnginePlan {
        EnginePlan {
            capture_workers: 1,
            accum_shards: 1,
            factorize_workers: 1,
            queue_cap: 2,
            telemetry: TelemetrySink::disabled(),
        }
    }

    /// `workers` threads for every stage (the `--workers` CLI knob).
    pub fn with_workers(workers: usize) -> EnginePlan {
        let w = workers.max(1);
        EnginePlan {
            capture_workers: w,
            accum_shards: w,
            factorize_workers: w,
            ..EnginePlan::sequential()
        }
    }

    fn normalized(&self) -> EnginePlan {
        EnginePlan {
            capture_workers: self.capture_workers.max(1),
            accum_shards: self.accum_shards.max(1),
            factorize_workers: self.factorize_workers.max(1),
            queue_cap: self.queue_cap.max(1),
            telemetry: self.telemetry.clone(),
        }
    }
}

/// A contiguous batch range `[start, end)` of a calibration run whose
/// canonical merge tree spans `total` batches.  Leaf indices stay
/// *global* (the batch number), so states accumulated over one shard's
/// range slot into the same tree as every other shard's — the invariant
/// behind the bitwise shard/merge guarantee.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    pub start: usize,
    pub end: usize,
    pub total: usize,
}

impl ShardRange {
    /// The whole run as one range (the single-process case).
    pub fn full(batches: usize) -> ShardRange {
        ShardRange { start: 0, end: batches, total: batches }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    fn validate(&self) -> Result<()> {
        if self.start > self.end || self.end > self.total {
            return Err(Error::Config(format!(
                "bad shard range: [{}, {}) of {} batches",
                self.start, self.end, self.total
            )));
        }
        Ok(())
    }
}

/// Checkpoint/resume configuration for a calibration run: every `every`
/// batches the pending merge-tree states are written (atomically) to
/// `dir`, and with `resume` an existing checkpoint is loaded instead of
/// starting from batch `start`.  Checkpointed runs produce bitwise the
/// same result as uninterrupted ones: the canonical tree does not care
/// where the run was cut.
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    pub dir: String,
    /// Batches between checkpoint writes (≥ 1).
    pub every: usize,
    /// Load `dir`'s checkpoint for the range, if present.
    pub resume: bool,
    /// Extra identity folded into the run's source fingerprint (e.g.
    /// the synthetic seed) so one checkpoint directory can serve many
    /// runs without a stale checkpoint resuming the wrong one.
    pub source: String,
}

impl CheckpointCfg {
    pub fn new(dir: impl Into<String>, every: usize, resume: bool) -> CheckpointCfg {
        CheckpointCfg { dir: dir.into(), every: every.max(1), resume, source: String::new() }
    }

    /// Same configuration with an identity stamp (see `source`).
    pub fn with_source(mut self, source: impl Into<String>) -> CheckpointCfg {
        self.source = source.into();
        self
    }

    /// The checkpoint file for one run: keyed by accumulator kind,
    /// precision, the source fingerprint (hashed), and the batch range
    /// — so one directory holds many shards'/methods'/configs'
    /// checkpoints side by side, and a driver sweeping several methods
    /// never trips over another run's file.
    pub fn file(
        &self,
        kind: AccumKind,
        precision: Precision,
        range: &ShardRange,
        source_id: &str,
    ) -> std::path::PathBuf {
        // FNV-1a over the fingerprint: short, stable, filename-safe
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in source_id.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        std::path::Path::new(&self.dir).join(format!(
            "ckpt-{kind:?}-{precision:?}-{h:016x}-{}-{}-of-{}.state",
            range.start, range.end, range.total
        ))
    }
}

/// Capture + sharded accumulate + canonical merge-tree reduction: drive
/// `batches` batches of `source` into per-(layer, stream) states.
///
/// Capture workers and accumulate shards run concurrently, connected by
/// a bounded channel.  Errors from *both* stages are surfaced: when both
/// fail, the capture error carries the accumulate error in its
/// [`Error::context`] chain instead of silently dropping one of them.
pub fn calibrate(
    source: &dyn ActivationSource,
    kind: AccumKind,
    batches: usize,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    timings: &mut StageTimings,
) -> Result<CalibStates> {
    calibrate_checkpointed(source, kind, batches, backend, precision, plan, timings, None, "")
}

/// [`calibrate`] with optionally durable progress: with `Some(ckpt)`
/// the pending merge-tree states are checkpointed to `ckpt.dir` every
/// `ckpt.every` batches, and a killed run resumes from the last
/// checkpoint (`ckpt.resume`) — producing bitwise the same factors as
/// an uninterrupted run.  `source_id` fingerprints the activation
/// source (model, route, seed, …); a checkpoint recorded under a
/// different fingerprint is rejected instead of silently mixing runs.
#[allow(clippy::too_many_arguments)]
pub fn calibrate_checkpointed(
    source: &dyn ActivationSource,
    kind: AccumKind,
    batches: usize,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    timings: &mut StageTimings,
    ckpt: Option<&CheckpointCfg>,
    source_id: &str,
) -> Result<CalibStates> {
    let slots = run_windowed(
        source,
        kind,
        ShardRange::full(batches),
        backend,
        precision,
        plan,
        timings,
        ckpt,
        source_id,
    )?;
    collect_states(slots, backend, precision, timings)
}

/// Accumulate-only over one shard's batch range: fold batches
/// `[range.start, range.end)` and return the pending merge-tree nodes
/// as a serializable [`ShardState`] — no factorization, no reduction
/// past what the range allows.  `coala shard` writes the result to
/// disk; [`merge_shard_states`] (or `coala merge`) turns N of them back
/// into the exact states the single-process run computes.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_shard(
    source: &dyn ActivationSource,
    kind: AccumKind,
    range: ShardRange,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    timings: &mut StageTimings,
    ckpt: Option<&CheckpointCfg>,
    source_id: &str,
) -> Result<ShardState> {
    let slots =
        run_windowed(source, kind, range, backend, precision, plan, timings, ckpt, source_id)?;
    Ok(snapshot(&slots, kind, precision, &range, range.end, source_id))
}

/// Merge complete shard states (from N `coala shard` processes) into
/// per-(layer, stream) states.  Every node re-enters the canonical tree
/// at its recorded (level, index), so the result is **bitwise
/// identical** to the single-process engine run at any shard count:
/// sibling merges happen between exactly the same operands in exactly
/// the same order.  The shards must tile `[0, total)` with one
/// consistent (kind, precision, total) header.
pub fn merge_shard_states(
    parts: Vec<ShardState>,
    backend: AccumBackend<'_>,
    timings: &mut StageTimings,
) -> Result<CalibStates> {
    let first = parts.first().ok_or_else(|| Error::Config("merge of zero shard states".into()))?;
    let (kind, precision, total) = (first.kind, first.precision, first.total);
    let source = first.source.clone();
    for p in &parts {
        if p.kind != kind || p.precision != precision || p.total != total {
            return Err(Error::Config(format!(
                "mixed shard headers: ({:?}, {:?}, {} batches) vs ({:?}, {:?}, {} batches)",
                kind, precision, total, p.kind, p.precision, p.total
            )));
        }
        if p.source != source {
            return Err(Error::Config(format!(
                "shards come from different sources: `{source}` vs `{}` — merging them would \
                 produce states no real run computes",
                p.source
            )));
        }
        if !p.is_complete() {
            return Err(Error::Config(format!(
                "shard [{}, {}) is an incomplete checkpoint (folded through batch {}) — finish it before merging",
                p.start, p.end, p.done
            )));
        }
    }
    let mut spans: Vec<(usize, usize)> = parts.iter().map(|p| (p.start, p.end)).collect();
    spans.sort_unstable();
    let mut cursor = 0;
    for (s, e) in spans {
        if s != cursor {
            return Err(Error::Config(format!(
                "shards do not tile [0, {total}): expected a shard starting at batch {cursor}, found [{s}, {e})"
            )));
        }
        cursor = e;
    }
    if cursor != total {
        return Err(Error::Config(format!(
            "shards do not tile [0, {total}): coverage stops at batch {cursor}"
        )));
    }

    let slots: Mutex<SlotMap> = Mutex::new(HashMap::new());
    for p in parts {
        for node in p.nodes {
            timings.merge_s += insert_node(
                &slots,
                total,
                &(node.layer, node.stream),
                node.state,
                backend,
                precision,
                node.level,
                node.index,
            )?;
        }
    }
    collect_states(slots.into_inner().unwrap(), backend, precision, timings)
}

/// The windowed capture ∥ accumulate driver behind every entry point:
/// runs `range` in windows of `ckpt.every` batches (one window when not
/// checkpointing), persisting the pending slots after each window.  On
/// error the in-memory slots are discarded — the last on-disk
/// checkpoint stays consistent, which is what makes kill/resume safe.
#[allow(clippy::too_many_arguments)]
fn run_windowed(
    source: &dyn ActivationSource,
    kind: AccumKind,
    range: ShardRange,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    timings: &mut StageTimings,
    ckpt: Option<&CheckpointCfg>,
    source_id: &str,
) -> Result<SlotMap> {
    range.validate()?;
    let mut map = SlotMap::new();
    let mut done = range.start;
    if let Some(c) = ckpt {
        std::fs::create_dir_all(&c.dir).map_err(|e| Error::io(&c.dir, e))?;
        let file = c.file(kind, precision, &range, source_id);
        if c.resume && file.exists() {
            let bytes = {
                let _t = plan.telemetry.start_timer("checkpoint_resume");
                std::fs::read(&file).map_err(|e| Error::io(&file, e))?
            };
            let st = {
                let _t = plan.telemetry.start_timer("codec_decode");
                ShardState::decode(&bytes, &file.display().to_string())?
            };
            if st.kind != kind || st.precision != precision {
                return Err(Error::Config(format!(
                    "checkpoint {} holds ({:?}, {:?}), run wants ({kind:?}, {precision:?})",
                    file.display(),
                    st.kind,
                    st.precision
                )));
            }
            if st.source != source_id {
                return Err(Error::Config(format!(
                    "checkpoint {} was recorded from source `{}`, run uses `{source_id}` — \
                     refusing to mix calibration runs",
                    file.display(),
                    st.source
                )));
            }
            if st.total != range.total || st.start != range.start || st.end != range.end {
                return Err(Error::Config(format!(
                    "checkpoint {} covers [{}, {}) of {}, run wants [{}, {}) of {}",
                    file.display(),
                    st.start,
                    st.end,
                    st.total,
                    range.start,
                    range.end,
                    range.total
                )));
            }
            done = st.done;
            for n in st.nodes {
                map.insert(((n.layer, n.stream), n.level, n.index), n.state);
            }
        }
    }
    let slots = Mutex::new(map);
    while done < range.end {
        let w1 = match ckpt {
            Some(c) => (done + c.every).min(range.end),
            None => range.end,
        };
        // one memory scope around the whole capture ∥ accumulate ∥
        // merge window: the stages share a working set, so the shared
        // watermark is the honest per-stage attribution (codec and
        // checkpoint IO below carry their own scopes via StageTimer)
        let mut mem = alloc::MemScope::enter();
        run_pass(source, kind, &range, done, w1, backend, precision, plan, &slots, timings)?;
        if let Some(m) = mem.finish() {
            timings.calib_peak_bytes = timings.calib_peak_bytes.max(m.peak_bytes);
            timings.calib_cur_bytes = m.cur_bytes;
            timings.calib_allocs += m.allocs;
        }
        done = w1;
        if let Some(c) = ckpt {
            let st = snapshot(&slots.lock().unwrap(), kind, precision, &range, done, source_id);
            let bytes = {
                let _t = plan.telemetry.start_timer("codec_encode");
                st.encode()
            };
            let _t = plan.telemetry.start_timer("checkpoint_write");
            ShardState::write_bytes(c.file(kind, precision, &range, source_id), &bytes)?;
        }
    }
    Ok(slots.into_inner().unwrap())
}

/// Snapshot the pending slots as a [`ShardState`] in canonical node
/// order (deterministic bytes for deterministic content).
fn snapshot(
    slots: &SlotMap,
    kind: AccumKind,
    precision: Precision,
    range: &ShardRange,
    done: usize,
    source_id: &str,
) -> ShardState {
    let mut nodes: Vec<StateNode> = slots
        .iter()
        .map(|((key, level, index), state)| StateNode {
            layer: key.0,
            stream: key.1.clone(),
            level: *level,
            index: *index,
            state: state.clone(),
        })
        .collect();
    nodes.sort_by(|a, b| {
        (a.layer, &a.stream, a.level, a.index).cmp(&(b.layer, &b.stream, b.level, b.index))
    });
    ShardState {
        kind,
        precision,
        source: source_id.to_string(),
        total: range.total,
        start: range.start,
        end: range.end,
        done,
        nodes,
    }
}

/// One capture ∥ accumulate pass over batches `[w0, w1)` of the range,
/// folding leaves into `slots` through the canonical tree.
#[allow(clippy::too_many_arguments)]
fn run_pass(
    source: &dyn ActivationSource,
    kind: AccumKind,
    range: &ShardRange,
    w0: usize,
    w1: usize,
    backend: AccumBackend<'_>,
    precision: Precision,
    plan: &EnginePlan,
    slots: &Mutex<SlotMap>,
    timings: &mut StageTimings,
) -> Result<()> {
    let plan = plan.normalized();
    let batches = range.total;
    let next_batch = AtomicUsize::new(w0);
    let cancelled = AtomicBool::new(false);
    let (tx, rx) = mpsc::sync_channel::<(usize, Vec<CalibChunk>)>(plan.queue_cap);
    // each shard owns an Arc share of the receiver, so if every shard
    // dies (even by panic) the channel closes and blocked senders exit
    let rx = Arc::new(Mutex::new(rx));
    // batches in flight between capture and accumulate (incremented
    // before send so the pair can never underflow); the high-water
    // mark is the observed queue pressure the `queue_cap` knob bounds
    let depth = AtomicUsize::new(0);
    let depth_hwm = AtomicUsize::new(0);

    let mut capture_secs = 0.0;
    let mut accum_secs = 0.0;
    let mut merge_secs = 0.0;
    let mut capture_stall_secs = 0.0;
    let mut accum_idle_secs = 0.0;
    let mut capture_err: Option<Error> = None;
    let mut accum_err: Option<Error> = None;

    std::thread::scope(|s| {
        let mut cap_handles = Vec::with_capacity(plan.capture_workers);
        for _ in 0..plan.capture_workers {
            let tx = tx.clone();
            let next = &next_batch;
            let cancelled = &cancelled;
            let depth = &depth;
            let depth_hwm = &depth_hwm;
            cap_handles.push(s.spawn(move || -> (f64, f64, Result<()>) {
                let mut busy = 0.0;
                let mut stall = 0.0;
                loop {
                    if cancelled.load(Ordering::Relaxed) {
                        // some stage failed; its error surfaces below
                        return (busy, stall, Ok(()));
                    }
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= w1 {
                        return (busy, stall, Ok(()));
                    }
                    let t0 = Instant::now();
                    let chunks = match source.capture_batch(b) {
                        Ok(c) => c,
                        Err(e) => {
                            cancelled.store(true, Ordering::Relaxed);
                            return (busy + t0.elapsed().as_secs_f64(), stall, Err(e));
                        }
                    };
                    busy += t0.elapsed().as_secs_f64();
                    // time blocked in send = backpressure from a full
                    // bounded channel (accumulate is the bottleneck)
                    let t_send = Instant::now();
                    let d = depth.fetch_add(1, Ordering::Relaxed) + 1;
                    depth_hwm.fetch_max(d, Ordering::Relaxed);
                    let sent = tx.send((b, chunks));
                    stall += t_send.elapsed().as_secs_f64();
                    if sent.is_err() {
                        // every accumulate shard died; their error
                        // surfaces below — stop producing
                        depth.fetch_sub(1, Ordering::Relaxed);
                        return (busy, stall, Ok(()));
                    }
                }
            }));
        }
        drop(tx); // shards see EOF once every capture worker finishes

        let mut acc_handles = Vec::with_capacity(plan.accum_shards);
        for _ in 0..plan.accum_shards {
            let rx = rx.clone();
            let slots = &slots;
            let cancelled = &cancelled;
            let depth = &depth;
            acc_handles.push(s.spawn(move || -> (f64, f64, f64, Result<()>) {
                let mut fold_busy = 0.0;
                let mut merge_busy = 0.0;
                let mut idle = 0.0;
                let mut failed: Option<Error> = None;
                loop {
                    // time blocked waiting for a payload (receiver
                    // lock + recv) = capture is the bottleneck
                    let t_recv = Instant::now();
                    let payload = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    idle += t_recv.elapsed().as_secs_f64();
                    let Ok((b, chunks)) = payload else {
                        // channel closed: every batch was delivered
                        return (fold_busy, merge_busy, idle, failed.map_or(Ok(()), Err));
                    };
                    depth.fetch_sub(1, Ordering::Relaxed);
                    if failed.is_some() || cancelled.load(Ordering::Relaxed) {
                        continue; // drain so blocked capture workers exit
                    }
                    let t0 = Instant::now();
                    let res = (|| -> Result<f64> {
                        // fold every chunk of the batch into its key's
                        // leaf (a source may emit several chunks per
                        // (layer, stream); chunk order within a batch
                        // is the source's, so leaves stay worker-count
                        // independent), then push the finished leaves
                        // into the merge tree
                        let mut leaf: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> =
                            BTreeMap::new();
                        for c in chunks {
                            let acc = match leaf.entry((c.layer, c.stream.clone())) {
                                std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
                                std::collections::btree_map::Entry::Vacant(v) => {
                                    // the *global* batch index seeds
                                    // position-dependent kinds (sketch Ω),
                                    // keeping leaves worker/shard blind
                                    v.insert(make_leaf_accumulator(
                                        kind,
                                        c.xt.cols,
                                        backend,
                                        precision,
                                        b,
                                    )?)
                                }
                            };
                            acc.fold_chunk(&c.xt)?;
                        }
                        let mut merged = 0.0;
                        for (key, acc) in leaf {
                            // leaf b enters the canonical tree at (0, b)
                            merged += insert_node(
                                slots,
                                batches,
                                &key,
                                acc.finish(),
                                backend,
                                precision,
                                0,
                                b,
                            )?;
                        }
                        Ok(merged)
                    })();
                    let merged = match res {
                        Ok(m) => m,
                        Err(e) => {
                            cancelled.store(true, Ordering::Relaxed);
                            failed = Some(e);
                            0.0
                        }
                    };
                    merge_busy += merged;
                    fold_busy += (t0.elapsed().as_secs_f64() - merged).max(0.0);
                }
            }));
        }
        drop(rx); // only the shards keep the receiver alive now

        for h in cap_handles {
            match h.join() {
                Ok((secs, stall, res)) => {
                    capture_secs += secs;
                    capture_stall_secs += stall;
                    if let Err(e) = res {
                        capture_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    capture_err.get_or_insert(Error::msg("capture worker panicked"));
                }
            }
        }
        for h in acc_handles {
            match h.join() {
                Ok((fold, merge, idle, res)) => {
                    accum_secs += fold;
                    merge_secs += merge;
                    accum_idle_secs += idle;
                    if let Err(e) = res {
                        accum_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    accum_err.get_or_insert(Error::msg("accumulate worker panicked"));
                }
            }
        }
    });

    match (capture_err, accum_err) {
        (Some(c), Some(a)) => {
            // both stages failed: chain so neither error is lost
            return Err(c.context(format!(
                "capture stage failed (accumulate stage also failed: {a})"
            )));
        }
        (Some(c), None) => return Err(c.context("capture stage failed")),
        (None, Some(a)) => return Err(a.context("accumulate stage failed")),
        (None, None) => {}
    }

    timings.calibrate_s += capture_secs;
    timings.accumulate_s += accum_secs;
    timings.merge_s += merge_secs;
    timings.capture_stall_s += capture_stall_secs;
    timings.accum_idle_s += accum_idle_secs;
    timings.queue_depth_hwm = timings.queue_depth_hwm.max(depth_hwm.load(Ordering::Relaxed));
    Ok(())
}

/// Emit the calibration-window stage records (`capture`, `accumulate`,
/// `merge_reduce`, `capture_stall`, `accum_idle`) from an engine's
/// finished [`StageTimings`], plus the queue-depth high-water counter
/// and — with `COALA_ALLOC_STATS=1` — the run-end allocator/OS memory
/// cross-check counters (`alloc_peak_bytes` / `alloc_count` /
/// `vm_hwm_bytes`; VmHWM from `/proc/self/status` must dominate the
/// allocator's own peak).  The concurrent calibration stages share one
/// working set, so all five records carry the same window watermark.
/// Shared by the pipeline and the `coala shard` driver so a stage
/// record means the same thing everywhere.
pub fn emit_stage_records(tel: &TelemetrySink, t: &StageTimings) {
    let mem = alloc::armed().then(|| alloc::MemStats {
        peak_bytes: t.calib_peak_bytes,
        cur_bytes: t.calib_cur_bytes,
        allocs: t.calib_allocs,
    });
    tel.stage_mem("capture", t.calibrate_s, mem);
    tel.stage_mem("accumulate", t.accumulate_s, mem);
    tel.stage_mem("merge_reduce", t.merge_s, mem);
    // bounded-channel backpressure, measured around the engine's
    // existing send/recv — capture_stall = accumulate was the
    // bottleneck, accum_idle = capture was
    tel.stage_mem("capture_stall", t.capture_stall_s, mem);
    tel.stage_mem("accum_idle", t.accum_idle_s, mem);
    tel.counter("queue_depth_hwm", t.queue_depth_hwm as u64);
    if let Some(s) = alloc::snapshot() {
        tel.counter("alloc_peak_bytes", s.peak_bytes);
        tel.counter("alloc_count", s.allocs);
        if let Some(hwm) = alloc::vm_hwm_bytes() {
            tel.counter("vm_hwm_bytes", hwm);
        }
    }
}

/// Collect the merge-tree roots into per-(layer, stream) states.
/// On the normal path every key has exactly one finished root.  A key
/// the source omitted from some batches leaves orphan subtrees; fold
/// them in canonical (level, index) order so even that is worker-
/// count (and shard-count) independent.
fn collect_states(
    slots: SlotMap,
    backend: AccumBackend<'_>,
    precision: Precision,
    timings: &mut StageTimings,
) -> Result<CalibStates> {
    let t_red = Instant::now();
    let mut per_key: BTreeMap<(usize, String), Vec<((u32, usize), CalibState)>> = BTreeMap::new();
    for ((key, level, index), state) in slots {
        per_key.entry(key).or_default().push(((level, index), state));
    }
    let mut out = CalibStates::new();
    for (key, mut nodes) in per_key {
        nodes.sort_by_key(|(pos, _)| *pos);
        let state = if nodes.len() == 1 {
            nodes.pop().unwrap().1
        } else {
            reduce_tree(nodes.into_iter().map(|(_, st)| st).collect(), backend, precision)?
        };
        out.insert(key, state);
    }
    timings.merge_s += t_red.elapsed().as_secs_f64();
    Ok(out)
}

/// Pending merge-tree nodes: (key, level, index) → finished subtree
/// state.  Leaf `b` sits at (0, b); node (L, i) is the merge of
/// (L−1, 2i) and (L−1, 2i+1), with a trailing odd node promoting
/// unchanged — the same shape as [`reduce_tree`].
type SlotMap = HashMap<((usize, String), u32, usize), CalibState>;

/// Node count at a merge-tree level: ceil(batches / 2^level).
fn level_size(batches: usize, level: u32) -> usize {
    let mut n = batches;
    for _ in 0..level {
        if n <= 1 {
            break;
        }
        n = n.div_ceil(2);
    }
    n
}

/// Insert a finished subtree node at (level, index) and greedily merge
/// completed sibling pairs up the canonical tree.  Pairs always merge
/// left-to-right, so the result is bitwise-independent of arrival order
/// and worker count, and at most O(log batches) nodes per key are
/// pending at any moment — the out-of-core property the streaming
/// design exists for.  Leaves enter at (0, batch); shard files re-enter
/// wherever their subtree stalled, which is why merging shard files
/// replays the single-process reduction exactly.
///
/// Returns seconds spent in sibling merges (the `merge_s` stage).
#[allow(clippy::too_many_arguments)]
fn insert_node(
    slots: &Mutex<SlotMap>,
    batches: usize,
    key: &(usize, String),
    state: CalibState,
    backend: AccumBackend<'_>,
    precision: Precision,
    level: u32,
    index: usize,
) -> Result<f64> {
    let mut level = level;
    let mut index = index;
    let mut state = state;
    let mut merged = 0.0;
    loop {
        let size = level_size(batches, level);
        if size <= 1 {
            // the root: the only node of its level
            slots.lock().unwrap().insert((key.clone(), level, 0), state);
            return Ok(merged);
        }
        if index == size - 1 && size % 2 == 1 {
            // odd tail: no sibling at this level — promote unchanged
            level += 1;
            index /= 2;
            continue;
        }
        let sibling = (key.clone(), level, index ^ 1);
        let mut guard = slots.lock().unwrap();
        match guard.remove(&sibling) {
            Some(other) => {
                drop(guard); // merge outside the lock
                let (a, b) = if index % 2 == 0 { (state, other) } else { (other, state) };
                let t0 = Instant::now();
                state = merge_states(a, b, backend, precision)?;
                merged += t0.elapsed().as_secs_f64();
                level += 1;
                index /= 2;
            }
            None => {
                guard.insert((key.clone(), level, index), state);
                return Ok(merged);
            }
        }
    }
}

/// Pairwise merge of partial states in a fixed left-to-right tree: the
/// shape depends only on the partial count, so the result is independent
/// of how many workers produced the partials.  [`insert_node`] performs
/// the same reduction incrementally; this eager form serves the orphan
/// fallback and the single-vector case.
fn reduce_tree(
    mut level: Vec<CalibState>,
    backend: AccumBackend<'_>,
    precision: Precision,
) -> Result<CalibState> {
    if level.is_empty() {
        return Err(Error::Config("reduce over zero partial states".into()));
    }
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(merge_states(a, b, backend, precision)?),
                None => next.push(a),
            }
        }
        level = next;
    }
    Ok(level.pop().unwrap())
}

/// Parallel factorize stage: fan the per-projection factorizations of a
/// method across `workers` threads through the `Compressor` registry.
/// Results assemble in projection order, so the outcome is independent
/// of the worker count.
///
/// With `COALA_HEALTH=1` each projection also flushes the health
/// events its kernels buffered thread-locally (Jacobi convergence,
/// applied μ) and a non-finite factor check, all under the span
/// `factorize/<proj>` — pure observation of already-computed state.
#[allow(clippy::too_many_arguments)]
pub fn factorize(
    config: &str,
    spec: &ModelSpec,
    weights: &ModelWeights,
    method: &Method,
    budget: &super::budget::RankBudget,
    accums: &CalibStates,
    route: Route,
    ex: &Executor,
    host_sweeps: usize,
    workers: usize,
    telemetry: &TelemetrySink,
) -> Result<(CompressedModel, BTreeMap<String, f64>)> {
    type ProjResult = Result<(String, Option<f64>, Factors<f32>)>;
    let projs = &spec.compressible;
    let results = parallel_map(projs.len(), workers.max(1), |i| -> ProjResult {
        let proj = &projs[i];
        let w = weights.matrix(proj)?;
        let layer: usize = proj[1..]
            .split('.')
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Config(format!("bad projection name `{proj}`")))?;
        let stream = spec.stream_of(proj)?.to_string();
        let calib = accums
            .get(&(layer, stream))
            .ok_or_else(|| Error::Config(format!("no accumulator for {proj}")))?;
        let rank = budget.rank(proj)?;
        let comp = compressor_for(method);
        if health::enabled() {
            // clear leftovers so the drain below is exactly this
            // projection's evidence
            health::drain();
        }
        let fz = comp.factorize(route, ex, &w, calib, rank, host_sweeps)?;
        let factors = fz.factors.truncate(rank);
        if health::enabled() {
            let span = format!("factorize/{proj}");
            for ev in health::drain() {
                telemetry.health_event(Some(&span), &ev);
            }
            let nonfinite = [&factors.a, &factors.b].iter().filter(|m| !m.all_finite()).count();
            telemetry.health_event(
                Some(&span),
                &health::HealthEvent::new("factors")
                    .num("rank", rank as f64)
                    .num("nonfinite", nonfinite as f64),
            );
        }
        Ok((proj.clone(), fz.mu, factors))
    });

    let mut model = CompressedModel::new(config);
    let mut mus = BTreeMap::new();
    for res in results {
        let (proj, mu, factors) = res?;
        if let Some(mu) = mu {
            mus.insert(proj.clone(), mu);
        }
        model.insert(&proj, factors);
    }
    Ok((model, mus))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::SyntheticActivations;
    use crate::model::synthetic::synthetic_manifest;
    use crate::tensor::Matrix;

    struct FailingSource {
        fail_at: usize,
    }

    impl ActivationSource for FailingSource {
        fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
            if b >= self.fail_at {
                return Err(Error::msg(format!("capture exploded at batch {b}")));
            }
            Ok(vec![CalibChunk {
                layer: 0,
                stream: "s".into(),
                xt: Matrix::randn(6, 4, b as u64),
            }])
        }
    }

    #[test]
    fn calibrate_covers_every_stream_and_is_plan_invariant() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 3);
        let mut reference: Option<CalibStates> = None;
        for plan in [
            EnginePlan::sequential(),
            EnginePlan::with_workers(3),
            EnginePlan {
                capture_workers: 2,
                accum_shards: 4,
                queue_cap: 1,
                ..EnginePlan::sequential()
            },
        ] {
            let mut t = StageTimings::default();
            let states = calibrate(
                &src,
                AccumKind::RFactor,
                2,
                AccumBackend::Host,
                Precision::F32,
                &plan,
                &mut t,
            )
            .unwrap();
            assert_eq!(states.len(), spec.n_layers * spec.act_streams.len());
            match &reference {
                None => reference = Some(states),
                Some(want) => {
                    for (k, s) in want {
                        let (a, b) = (s.r().unwrap(), states[k].r().unwrap());
                        assert_eq!(a.data, b.data, "{k:?} differs across plans");
                    }
                }
            }
        }
    }

    #[test]
    fn capture_error_surfaces() {
        let src = FailingSource { fail_at: 1 };
        let err = calibrate(
            &src,
            AccumKind::RFactor,
            3,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::with_workers(2),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("capture stage failed"), "{msg}");
        assert!(msg.contains("capture exploded"), "{msg}");
    }

    #[test]
    fn concurrent_stage_failures_surface_with_stage_context() {
        // capture dies on batch 1 while the accumulate stage dies
        // folding batch 0 (the synthetic manifest has no artifacts, so
        // the device backend's tsqr_step fails).  Scheduling decides
        // whether cancellation prevents the second failure; in every
        // interleaving the surfaced error names its failed stage (and
        // when both fail, the context chain carries both — the old
        // scheduler silently dropped one).
        let ex = crate::runtime::executor::Executor::from_manifest(synthetic_manifest()).unwrap();
        let src = FailingSource { fail_at: 1 };
        let err = calibrate(
            &src,
            AccumKind::RFactor,
            2,
            AccumBackend::Device(&ex),
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("stage failed"), "{msg}");
    }

    #[test]
    fn stage_failure_cancels_remaining_batches_promptly() {
        // a merge failure at batch 1 (width change, scales route) must
        // stop the run long before all 1000 batches are captured
        struct CountingSource {
            calls: std::sync::atomic::AtomicUsize,
        }
        impl ActivationSource for CountingSource {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let cols = if b == 0 { 4 } else { 3 };
                Ok(vec![CalibChunk {
                    layer: 0,
                    stream: "s".into(),
                    xt: Matrix::randn(5, cols, b as u64),
                }])
            }
        }
        let src = CountingSource { calls: std::sync::atomic::AtomicUsize::new(0) };
        let err = calibrate(
            &src,
            AccumKind::Scales,
            1000,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("accumulate stage failed"), "{err}");
        let captured = src.calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(captured < 900, "cancellation did not stop capture: {captured} batches");
    }

    #[test]
    fn merge_width_mismatch_is_reported() {
        struct TwoWidths;
        impl ActivationSource for TwoWidths {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                let cols = if b == 0 { 4 } else { 3 };
                Ok(vec![CalibChunk {
                    layer: 0,
                    stream: "s".into(),
                    xt: Matrix::randn(5, cols, b as u64),
                }])
            }
        }
        let err = calibrate(
            &TwoWidths,
            AccumKind::Scales,
            2,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("width mismatch"), "{err}");
    }

    #[test]
    fn reduce_tree_rejects_empty() {
        assert!(reduce_tree(Vec::new(), AccumBackend::Host, Precision::F32).is_err());
    }

    fn assert_gram_states_eq(want: &CalibStates, got: &CalibStates, label: &str) {
        assert_eq!(want.len(), got.len(), "{label}");
        for (k, s) in want {
            let (a, b) = (s.gram().unwrap(), got[k].gram().unwrap());
            assert_eq!(a.data, b.data, "{label} {k:?}");
        }
    }

    #[test]
    fn shard_accumulate_plus_merge_reproduces_in_process_states() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 3);
        let total = 5;
        let mut t = StageTimings::default();
        let want = calibrate(
            &src,
            AccumKind::Gram,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut t,
        )
        .unwrap();
        for shards in [1usize, 2, 3, 5] {
            let plan = super::super::shard::ShardPlan::new(total, shards).unwrap();
            let parts: Vec<ShardState> = (0..shards)
                .map(|i| {
                    accumulate_shard(
                        &src,
                        AccumKind::Gram,
                        plan.range(i).unwrap(),
                        AccumBackend::Host,
                        Precision::F32,
                        &EnginePlan::with_workers(2),
                        &mut StageTimings::default(),
                        None,
                        "tiny:test",
                    )
                    .unwrap()
                })
                .collect();
            let got = merge_shard_states(parts, AccumBackend::Host, &mut StageTimings::default())
                .unwrap();
            assert_gram_states_eq(&want, &got, &format!("shards={shards}"));
        }
    }

    #[test]
    fn merge_rejects_gaps_overlaps_and_incomplete_checkpoints() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 3);
        let shard = |start: usize, end: usize| {
            accumulate_shard(
                &src,
                AccumKind::Gram,
                ShardRange { start, end, total: 4 },
                AccumBackend::Host,
                Precision::F32,
                &EnginePlan::sequential(),
                &mut StageTimings::default(),
                None,
                "tiny:test",
            )
            .unwrap()
        };
        let mut t = StageTimings::default();
        // gap: [0,2) + [3,4)
        let err = merge_shard_states(vec![shard(0, 2), shard(3, 4)], AccumBackend::Host, &mut t)
            .unwrap_err();
        assert!(err.to_string().contains("tile"), "{err}");
        // short coverage: [0,2) alone
        assert!(merge_shard_states(vec![shard(0, 2)], AccumBackend::Host, &mut t).is_err());
        // incomplete checkpoint
        let mut partial = shard(2, 4);
        partial.done = 3;
        let err = merge_shard_states(vec![shard(0, 2), partial], AccumBackend::Host, &mut t)
            .unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        // shards from different sources must not merge silently
        let mut alien = shard(2, 4);
        alien.source = "other-model:seed9".into();
        let err = merge_shard_states(vec![shard(0, 2), alien], AccumBackend::Host, &mut t)
            .unwrap_err();
        assert!(err.to_string().contains("different sources"), "{err}");
        // zero shards
        assert!(merge_shard_states(Vec::new(), AccumBackend::Host, &mut t).is_err());
    }

    #[test]
    fn checkpointed_run_survives_a_kill_and_resumes_bitwise() {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 4);
        let total = 6;
        let mut t = StageTimings::default();
        let want = calibrate(
            &src,
            AccumKind::Gram,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut t,
        )
        .unwrap();

        struct DieAt<'a> {
            inner: &'a SyntheticActivations,
            from: usize,
        }
        impl ActivationSource for DieAt<'_> {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                if b >= self.from {
                    return Err(Error::msg(format!("killed at batch {b}")));
                }
                self.inner.capture_batch(b)
            }
        }

        let dir = std::env::temp_dir().join(format!("coala-engine-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = CheckpointCfg::new(dir.display().to_string(), 2, true);
        let sid = "tiny:host:seed4";
        let range = ShardRange::full(total);
        // "kill" mid-run: capture dies at batch 4, checkpoints for
        // [0, 4) are already on disk
        let err = calibrate_checkpointed(
            &DieAt { inner: &src, from: 4 },
            AccumKind::Gram,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::with_workers(2),
            &mut StageTimings::default(),
            Some(&ckpt),
            sid,
        )
        .unwrap_err();
        assert!(err.to_string().contains("capture stage failed"), "{err}");
        let file = ckpt.file(AccumKind::Gram, Precision::F32, &range, sid);
        let saved = ShardState::read(&file).unwrap();
        assert_eq!(saved.done, 4, "checkpoint did not persist the completed windows");
        assert_eq!(saved.source, sid);

        // resume with the healthy source: bitwise equal to uninterrupted
        let got = calibrate_checkpointed(
            &src,
            AccumKind::Gram,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::with_workers(2),
            &mut StageTimings::default(),
            Some(&ckpt),
            sid,
        )
        .unwrap();
        assert_gram_states_eq(&want, &got, "resumed");

        // a mismatched checkpoint on the expected filename (here: the
        // Gram file copied over the RFactor slot, simulating a renamed
        // or hash-colliding file) is rejected loudly, not resumed
        let r_file = ckpt.file(AccumKind::RFactor, Precision::F32, &range, sid);
        std::fs::copy(&file, &r_file).unwrap();
        let err = calibrate_checkpointed(
            &src,
            AccumKind::RFactor,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
            Some(&ckpt),
            sid,
        )
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
        // a different source fingerprint resolves to a different file,
        // so the stale Gram checkpoint is simply not picked up
        let fresh = calibrate_checkpointed(
            &src,
            AccumKind::Gram,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
            Some(&ckpt),
            "tiny:host:seed5-different",
        )
        .unwrap();
        assert_gram_states_eq(&want, &fresh, "fresh-start under new fingerprint");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multiple_chunks_per_stream_in_one_batch_all_fold() {
        // a source may split a batch into several chunks for the same
        // (layer, stream); every chunk must land in the leaf (an early
        // engine draft overwrote the first with the second)
        struct SplitSource;
        impl ActivationSource for SplitSource {
            fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
                Ok(vec![
                    CalibChunk { layer: 0, stream: "s".into(), xt: Matrix::randn(5, 4, b as u64) },
                    CalibChunk {
                        layer: 0,
                        stream: "s".into(),
                        xt: Matrix::randn(7, 4, 100 + b as u64),
                    },
                ])
            }
        }
        let mut reference: Option<CalibStates> = None;
        for plan in [EnginePlan::sequential(), EnginePlan::with_workers(4)] {
            let states = calibrate(
                &SplitSource,
                AccumKind::Scales,
                3,
                AccumBackend::Host,
                Precision::F32,
                &plan,
                &mut StageTimings::default(),
            )
            .unwrap();
            let CalibState::Scales { rows, .. } = &states[&(0, "s".to_string())] else {
                panic!("not scales");
            };
            // 3 batches × (5 + 7) rows: nothing silently dropped
            assert_eq!(*rows, 3 * 12);
            match &reference {
                None => reference = Some(states),
                Some(want) => {
                    let (CalibState::Scales { sum_abs: a, .. }, CalibState::Scales { sum_abs: b, .. }) =
                        (&want[&(0, "s".to_string())], &states[&(0, "s".to_string())])
                    else {
                        panic!("not scales");
                    };
                    assert_eq!(a, b, "split-chunk leaves differ across plans");
                }
            }
        }
    }
}
