//! The L3 coordinator (S7/S8) — the systems half of the reproduction.
//!
//! Compressing a model is a staged job graph, executed by the one
//! source-agnostic engine ([`engine`]):
//!
//! ```text
//!   ActivationSource ─▶ capture workers ─▶ bounded channel (backpressure)
//!   (fwd_acts device       │                    │
//!    capture or the        ▼                    ▼
//!    synthetic host   per-(layer, stream, batch) leaf states
//!    generator)            │   (CalibAccumulator: TSQR R / Gram / scales)
//!                          ▼
//!        canonical pairwise merge tree (merge_state, batch order)
//!                          ▼
//!   per-projection CalibState ─▶ rank budget ─▶ factorize workers
//!                          ▼              (Compressor registry, device
//!   CompressedModel ◀──────┘               or host route)
//! ```
//!
//! X is never materialized: each forward batch contributes a (B·T × n)
//! chunk that is folded into the accumulator a method declares
//! (`calib::accumulate`) and dropped — the paper's §4.2 out-of-memory
//! scenario.  Results are bitwise-independent of every worker count
//! (the merge tree is fixed by the batch order), so parallelism is a
//! pure deployment knob.  Method dispatch is indirect through the
//! `Compressor` registry (`coala::compressor`); the coordinator never
//! matches on method variants.  The sequential pipeline ([`pipeline`]),
//! the overlapped scheduler ([`scheduler`]), and the multi-device tree
//! TSQR ([`tsqr_tree`]) are thin [`engine::EnginePlan`] configurations
//! of the same engine.
//!
//! The same property makes calibration *durable and multi-process*
//! ([`shard`] + [`crate::calib::state`]): a [`shard::ShardPlan`]
//! partitions the batches, `coala shard` runs accumulate-only over one
//! range and serializes its pending merge-tree nodes, `coala merge`
//! folds N state files back into the canonical tree — bitwise identical
//! to the single-process run — and any run can checkpoint its pending
//! states every N batches ([`engine::CheckpointCfg`]) and resume after
//! a kill with no effect on the resulting bits.

pub mod budget;
pub mod engine;
pub mod pipeline;
pub mod scheduler;
pub mod shard;
pub mod tsqr_tree;

pub use budget::RankBudget;
pub use engine::{CalibStates, CheckpointCfg, EnginePlan, ShardRange, StageTimings};
pub use pipeline::{resolve_accum_kind, CompressionJob, CompressionOutcome, Pipeline};
pub use shard::ShardPlan;
pub use tsqr_tree::TsqrTreeRunner;
