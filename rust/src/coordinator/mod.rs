//! The L3 coordinator (S7/S8) — the systems half of the reproduction.
//!
//! Compressing a model is a streaming pipeline:
//!
//! ```text
//!   corpus ─▶ capture (fwd_acts) ─▶ accumulate (CalibAccumulator:
//!                 │                  TSQR R / Gram / scales)
//!                 │ batch-sized chunks, bounded channel (backpressure)
//!                 ▼
//!   per-projection CalibState ─▶ rank budget ─▶ factorize (Compressor:
//!                 ▼                              │ device or host route)
//!   CompressedModel ◀────────────────────────────┘
//! ```
//!
//! X is never materialized: each forward batch contributes a (B·T × n)
//! chunk that is folded into the accumulator a method declares
//! (`calib::accumulate`) and dropped — the paper's §4.2 out-of-memory
//! scenario.  Method dispatch is indirect through the `Compressor`
//! registry (`coala::compressor`); the coordinator never matches on
//! method variants, so new methods and new accumulation strategies plug
//! in without touching this layer.  Multi-device tree TSQR is simulated
//! by a worker pool where every worker owns its *own* PJRT client
//! ([`tsqr_tree`]).

pub mod budget;
pub mod pipeline;
pub mod scheduler;
pub mod tsqr_tree;

pub use budget::RankBudget;
pub use pipeline::{CalibStates, CompressionJob, CompressionOutcome, Pipeline};
pub use tsqr_tree::TsqrTreeRunner;
