//! The L3 coordinator (S7/S8) — the systems half of the reproduction.
//!
//! Compressing a model is a staged job graph, executed by the one
//! source-agnostic engine ([`engine`]):
//!
//! ```text
//!   ActivationSource ─▶ capture workers ─▶ bounded channel (backpressure)
//!   (fwd_acts device       │                    │
//!    capture or the        ▼                    ▼
//!    synthetic host   per-(layer, stream, batch) leaf states
//!    generator)            │   (CalibAccumulator: TSQR R / Gram / scales)
//!                          ▼
//!        canonical pairwise merge tree (merge_state, batch order)
//!                          ▼
//!   per-projection CalibState ─▶ rank budget ─▶ factorize workers
//!                          ▼              (Compressor registry, device
//!   CompressedModel ◀──────┘               or host route)
//! ```
//!
//! X is never materialized: each forward batch contributes a (B·T × n)
//! chunk that is folded into the accumulator a method declares
//! (`calib::accumulate`) and dropped — the paper's §4.2 out-of-memory
//! scenario.  Results are bitwise-independent of every worker count
//! (the merge tree is fixed by the batch order), so parallelism is a
//! pure deployment knob.  Method dispatch is indirect through the
//! `Compressor` registry (`coala::compressor`); the coordinator never
//! matches on method variants.  The sequential pipeline ([`pipeline`]),
//! the overlapped scheduler ([`scheduler`]), and the multi-device tree
//! TSQR ([`tsqr_tree`]) are thin [`engine::EnginePlan`] configurations
//! of the same engine.

pub mod budget;
pub mod engine;
pub mod pipeline;
pub mod scheduler;
pub mod tsqr_tree;

pub use budget::RankBudget;
pub use engine::{CalibStates, EnginePlan, StageTimings};
pub use pipeline::{CompressionJob, CompressionOutcome, Pipeline};
pub use tsqr_tree::TsqrTreeRunner;
