//! The L3 coordinator (S7/S8) — the systems half of the reproduction.
//!
//! Compressing a model is a streaming pipeline:
//!
//! ```text
//!   corpus ─▶ capture (fwd_acts) ─▶ accumulate (TSQR / Gram / scales)
//!                 │ batch-sized chunks, bounded channel (backpressure)
//!                 ▼
//!   per-projection R or G ─▶ rank budget ─▶ factorize (PJRT artifacts)
//!                 ▼                              │ μ-rule (Eq. 5)
//!   CompressedModel ◀────────────────────────────┘
//! ```
//!
//! X is never materialized: each forward batch contributes a (B·T × n)
//! chunk that is folded into a square R (COALA route) or accumulated
//! into the Gram matrix (baseline route) and dropped — the paper's §4.2
//! out-of-memory scenario.  Multi-device tree TSQR is simulated by a
//! worker pool where every worker owns its *own* PJRT client
//! ([`tsqr_tree`]).

pub mod budget;
pub mod pipeline;
pub mod scheduler;
pub mod tsqr_tree;

pub use budget::RankBudget;
pub use pipeline::{CompressionJob, CompressionOutcome, Pipeline};
pub use tsqr_tree::TsqrTreeRunner;
