//! The per-model compression pipeline — streaming calibration in, a
//! `CompressedModel` out.

use crate::calib::activations::ActivationCapture;
use crate::calib::dataset::Corpus;
use crate::coala::factorize::FullFactors;
use crate::coala::{Method, MuRule};
use crate::error::{Error, Result};
use crate::model::{CompressedModel, ModelWeights};
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::ops;
use crate::tensor::lowp::{quantize, Precision};
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use std::time::Instant;

/// What to compress and how.
#[derive(Debug, Clone)]
pub struct CompressionJob {
    pub config: String,
    pub method: Method,
    /// kept-parameter ratio over the compressible projections
    pub ratio: f64,
    /// calibration forward batches (each B×T tokens)
    pub calib_batches: usize,
    /// which corpus split feeds calibration
    pub calib_split: String,
    /// emulated precision of the *accumulation* stage (Table 2's fp16)
    pub accum_precision: Precision,
    pub rank_policy: super::budget::RankPolicy,
}

impl CompressionJob {
    pub fn new(config: &str, method: Method, ratio: f64) -> CompressionJob {
        CompressionJob {
            config: config.to_string(),
            method,
            ratio,
            calib_batches: 8,
            calib_split: "calib".to_string(),
            accum_precision: Precision::F32,
            rank_policy: super::budget::RankPolicy::Uniform,
        }
    }
}

/// Per-stage wall-clock (drives Table 1 + the §Perf profile).
#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub calibrate_s: f64,
    pub accumulate_s: f64,
    pub factorize_s: f64,
    pub total_s: f64,
}

/// Result of one compression run.
#[derive(Debug)]
pub struct CompressionOutcome {
    pub model: CompressedModel,
    pub budget: super::budget::RankBudget,
    pub timings: StageTimings,
    /// per-projection chosen μ (adaptive rule diagnostics)
    pub mus: BTreeMap<String, f64>,
}

/// Per-(layer, stream) streaming accumulator state.
pub enum Accum {
    /// COALA route: square R with RᵀR = (seen X)(seen X)ᵀ
    R(Matrix<f32>),
    /// Gram route: G = Σ chunkᵀ·chunk
    Gram(Matrix<f32>),
    /// ASVD route: running Σ|x| and count per input channel
    Scales(Vec<f64>, usize),
}

/// The pipeline: owns nothing but borrows the executor (compile cache is
/// shared across jobs — e.g. the whole Fig. 5 λ sweep reuses artifacts).
pub struct Pipeline<'a> {
    pub ex: &'a Executor,
    pub spec: ModelSpec,
    pub weights: &'a ModelWeights,
}

impl<'a> Pipeline<'a> {
    pub fn new(ex: &'a Executor, spec: ModelSpec, weights: &'a ModelWeights) -> Pipeline<'a> {
        Pipeline { ex, spec, weights }
    }

    /// Streaming calibration: fold every batch into per-stream accumulators.
    /// X is never materialized (peak memory = one chunk + accumulators).
    pub fn calibrate(
        &self,
        job: &CompressionJob,
        corpus: &Corpus,
        timings: &mut StageTimings,
    ) -> Result<BTreeMap<(usize, String), Accum>> {
        let cap = ActivationCapture::new(self.ex, &self.spec);
        let batches =
            corpus.batches(&job.calib_split, self.spec.batch, self.spec.seq_len, job.calib_batches)?;
        let mut accums: BTreeMap<(usize, String), Accum> = BTreeMap::new();
        let gram_route = job.method.needs_gram();
        let scales_route = matches!(job.method, Method::Asvd);
        for tokens in &batches {
            let t0 = Instant::now();
            let (_logits, chunks) = cap.capture(tokens, self.weights)?;
            timings.calibrate_s += t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            for c in chunks {
                let xt = if job.accum_precision == Precision::F32 {
                    c.xt
                } else {
                    quantize(&c.xt, job.accum_precision)
                };
                let key = (c.layer, c.stream.clone());
                let n = xt.cols;
                let entry = accums.entry(key).or_insert_with(|| {
                    if scales_route {
                        Accum::Scales(vec![0.0; n], 0)
                    } else if gram_route {
                        Accum::Gram(Matrix::zeros(n, n))
                    } else {
                        Accum::R(Matrix::zeros(n, n))
                    }
                });
                match entry {
                    Accum::R(r) => *r = ops::tsqr_step(self.ex, r, &xt)?,
                    Accum::Gram(g) => {
                        let g2 = ops::gram_update(self.ex, g, &xt)?;
                        *g = if job.accum_precision == Precision::F32 {
                            g2
                        } else {
                            quantize(&g2, job.accum_precision)
                        };
                    }
                    Accum::Scales(s, cnt) => {
                        for i in 0..xt.rows {
                            for (j, acc) in s.iter_mut().enumerate() {
                                *acc += xt.get(i, j).abs() as f64;
                            }
                        }
                        *cnt += xt.rows;
                    }
                }
            }
            timings.accumulate_s += t1.elapsed().as_secs_f64();
        }
        Ok(accums)
    }

    /// Factorize one projection given its accumulator.
    fn factorize_one(
        &self,
        job: &CompressionJob,
        w: &Matrix<f32>,
        accum: &Accum,
        rank: usize,
        mus: &mut BTreeMap<String, f64>,
        proj: &str,
    ) -> Result<FullFactors<f32>> {
        match (&job.method, accum) {
            (Method::Coala(MuRule::None), Accum::R(r)) => ops::factorize(self.ex, w, r),
            (Method::Coala(MuRule::Constant { mu }), Accum::R(r)) => {
                mus.insert(proj.to_string(), *mu);
                ops::factorize_reg(self.ex, w, r, *mu as f32)
            }
            (Method::Coala(MuRule::Adaptive { lambda }), Accum::R(r)) => {
                let f0 = ops::factorize(self.ex, w, r)?;
                let (num, den) = ops::mu_terms(self.ex, w, &f0, r, rank)?;
                let mu = if den > 1e-20 { lambda * num as f64 / den as f64 } else { 0.0 };
                mus.insert(proj.to_string(), mu);
                if mu == 0.0 {
                    return Ok(f0);
                }
                ops::factorize_reg(self.ex, w, r, mu as f32)
            }
            (Method::Alpha(0), Accum::R(_)) => ops::plainsvd(self.ex, w),
            (Method::Alpha(1), Accum::R(r)) => ops::factorize(self.ex, w, r),
            (Method::Alpha(2), Accum::R(r)) => ops::alpha2(self.ex, w, r),
            (Method::PlainSvd, _) => ops::plainsvd(self.ex, w),
            (Method::SvdLlm, Accum::Gram(g)) => ops::svdllm(self.ex, w, g),
            (Method::SvdLlmV2, Accum::Gram(g)) => ops::svdllm2(self.ex, w, g),
            (Method::Corda, Accum::Gram(g)) => ops::corda(self.ex, w, g),
            (Method::Asvd, Accum::Scales(s, cnt)) => {
                let scales: Vec<f32> = s
                    .iter()
                    .map(|v| ((v / (*cnt).max(1) as f64) as f32 + 1e-6).sqrt())
                    .collect();
                ops::asvd(self.ex, w, &scales)
            }
            (m, _) => Err(Error::Config(format!(
                "method {} incompatible with accumulated route",
                m.name()
            ))),
        }
    }

    /// Run the full job.
    pub fn run(&self, job: &CompressionJob, corpus: &Corpus) -> Result<CompressionOutcome> {
        let t_start = Instant::now();
        let mut timings = StageTimings::default();
        let accums = self.calibrate(job, corpus, &mut timings)?;
        let mut out = self.run_with_accums(job, &accums, timings)?;
        out.timings.total_s = t_start.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Factorize + assemble given pre-computed accumulators — lets a μ/λ
    /// sweep (Figs. 4/5) reuse one calibration pass across many jobs.
    pub fn run_with_accums(
        &self,
        job: &CompressionJob,
        accums: &BTreeMap<(usize, String), Accum>,
        mut timings: StageTimings,
    ) -> Result<CompressionOutcome> {
        let budget = super::budget::RankBudget::allocate(&self.spec, job.ratio, job.rank_policy)?;

        let mut model = CompressedModel::new(&job.config);
        let mut mus = BTreeMap::new();
        let t2 = Instant::now();
        for proj in self.spec.compressible.clone() {
            let w = self.weights.matrix(&proj)?;
            let layer: usize = proj[1..].split('.').next().unwrap().parse().unwrap();
            let stream = self.spec.stream_of(&proj)?.to_string();
            let accum = accums
                .get(&(layer, stream))
                .ok_or_else(|| Error::Config(format!("no accumulator for {proj}")))?;
            let rank = budget.rank(&proj)?;
            let full = self.factorize_one(job, &w, accum, rank, &mut mus, &proj)?;
            model.insert(&proj, full.truncate(rank));
        }
        timings.factorize_s = t2.elapsed().as_secs_f64();
        timings.total_s = timings.calibrate_s + timings.accumulate_s + timings.factorize_s;
        Ok(CompressionOutcome { model, budget, timings, mus })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::perplexity;

    fn setup() -> Option<(Executor, Corpus)> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return None;
        }
        Some((Executor::new("artifacts").unwrap(), Corpus::load("artifacts").unwrap()))
    }

    #[test]
    fn coala_compression_end_to_end_preserves_model_better_than_random() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::None), 0.5);
        job.calib_batches = 4;
        let out = pipe.run(&job, &corpus).unwrap();
        assert!(out.model.all_finite());
        assert_eq!(out.model.factors.len(), spec.compressible.len());
        let achieved = out.model.achieved_ratio(&w, &spec);
        assert!((achieved - 0.5).abs() < 0.1, "achieved {achieved}");

        let val = corpus.split("val").unwrap();
        let base = perplexity(&ex, &spec, &w, val, 2).unwrap();
        let rec = out.model.reconstruct_into(&w).unwrap();
        let comp = perplexity(&ex, &spec, &rec, val, 2).unwrap();
        assert!(comp.is_finite());
        // 50 % compression shouldn't destroy the model (<4× ppl)
        assert!(comp < base * 4.0, "base {base} compressed {comp}");
        assert!(out.timings.total_s > 0.0);
    }

    #[test]
    fn adaptive_mu_records_per_layer_values() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        let mut job =
            CompressionJob::new("tiny", Method::Coala(MuRule::Adaptive { lambda: 2.0 }), 0.3);
        job.calib_batches = 2;
        let out = pipe.run(&job, &corpus).unwrap();
        assert_eq!(out.mus.len(), spec.compressible.len());
        // layer norms differ → adaptive μ varies across layers
        let vals: Vec<f64> = out.mus.values().copied().collect();
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vals.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.5, "μ did not vary: {min}..{max}");
        assert!(out.model.all_finite());
    }

    #[test]
    fn gram_route_methods_run() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        for method in [Method::SvdLlm, Method::Asvd, Method::PlainSvd] {
            let mut job = CompressionJob::new("tiny", method, 0.4);
            job.calib_batches = 2;
            let out = pipe.run(&job, &corpus).unwrap();
            assert_eq!(out.model.factors.len(), spec.compressible.len(), "{}", method.name());
        }
    }
}
