//! The per-model compression pipeline — streaming calibration in, a
//! `CompressedModel` out.
//!
//! The pipeline owns no control flow of its own: both stages are thin
//! configurations of the source-agnostic execution engine
//! ([`super::engine`]).  `calibrate_from` runs the engine's capture ∥
//! sharded-accumulate graph over any [`ActivationSource`];
//! `run_with_accums` runs the engine's parallel factorize stage through
//! the [`Compressor`] registry.  An [`EnginePlan`] chooses the worker
//! counts (the default is the sequential plan); every plan produces
//! bitwise-identical results.
//!
//! Method dispatch is fully indirect: the job's [`Method`] descriptor
//! resolves to a [`Compressor`] through `coala::compressor`, which names
//! the accumulator it consumes (`calib::accumulate`) and factorizes on
//! either the PJRT device route or the pure-Rust host route.  The
//! pipeline itself never matches on method variants.

use crate::calib::accumulate::{AccumBackend, AccumKind, CalibState};
use crate::calib::activations::{ActivationSource, DeviceActivationSource};
use crate::calib::dataset::Corpus;
use crate::coala::compressor::{compressor_for, Compressor, Route, HOST_SWEEPS};
use crate::coala::Method;
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::lowp::Precision;
use std::collections::BTreeMap;
use std::time::Instant;

use super::engine::{self, CheckpointCfg, EnginePlan};

pub use super::engine::{CalibStates, StageTimings};

/// What to compress and how.
#[derive(Debug, Clone)]
pub struct CompressionJob {
    pub config: String,
    pub method: Method,
    /// kept-parameter ratio over the compressible projections
    pub ratio: f64,
    /// calibration forward batches (each B×T tokens)
    pub calib_batches: usize,
    /// which corpus split feeds calibration
    pub calib_split: String,
    /// emulated precision of the *accumulation* stage (Table 2's fp16)
    pub accum_precision: Precision,
    pub rank_policy: super::budget::RankPolicy,
}

impl CompressionJob {
    pub fn new(config: &str, method: Method, ratio: f64) -> CompressionJob {
        CompressionJob {
            config: config.to_string(),
            method,
            ratio,
            calib_batches: 8,
            calib_split: "calib".to_string(),
            accum_precision: Precision::F32,
            rank_policy: super::budget::RankPolicy::Uniform,
        }
    }
}

/// Result of one compression run.
#[derive(Debug)]
pub struct CompressionOutcome {
    pub model: crate::model::CompressedModel,
    pub budget: super::budget::RankBudget,
    pub timings: StageTimings,
    /// per-projection chosen μ (adaptive rule diagnostics)
    pub mus: BTreeMap<String, f64>,
}

/// The pipeline: owns nothing but borrows the executor (compile cache is
/// shared across jobs — e.g. the whole Fig. 5 λ sweep reuses artifacts).
pub struct Pipeline<'a> {
    pub ex: &'a Executor,
    pub spec: ModelSpec,
    pub weights: &'a ModelWeights,
    /// Accumulate + factorize on PJRT artifacts or pure-Rust host linalg.
    pub route: Route,
    /// Jacobi sweeps for the host route's SVDs.
    pub host_sweeps: usize,
    /// Worker counts per engine stage (sequential by default).
    pub plan: EnginePlan,
    /// When set, calibration checkpoints its pending merge states to
    /// disk every N batches and can resume after a kill
    /// (`--checkpoint-dir`/`--resume`); results are bitwise unchanged.
    pub checkpoint: Option<CheckpointCfg>,
    /// Accumulator-kind override (`--accum sketch`): swap the exact
    /// TSQR R for the randomized range-finder sketch.  Only valid for
    /// methods that consume the R factor; `None` keeps each method's
    /// declared kind.
    pub accum: Option<AccumKind>,
}

/// Resolve the accumulator kind a run uses: the method's declared kind,
/// or the `--accum` override when the method consumes the R factor (the
/// only kind with a drop-in approximation).  Overriding a non-R method
/// is a configuration error, not a silent fallback.
pub fn resolve_accum_kind(comp: &dyn Compressor, over: Option<AccumKind>) -> Result<AccumKind> {
    let declared = comp.accum_kind();
    match over {
        None => Ok(declared),
        Some(k) if k == declared => Ok(declared),
        Some(AccumKind::Sketch) if declared == AccumKind::RFactor => Ok(AccumKind::Sketch),
        Some(k) => Err(Error::Config(format!(
            "--accum {k:?} does not apply to {} (consumes {declared:?})",
            comp.name()
        ))),
    }
}

impl<'a> Pipeline<'a> {
    pub fn new(ex: &'a Executor, spec: ModelSpec, weights: &'a ModelWeights) -> Pipeline<'a> {
        Pipeline {
            ex,
            spec,
            weights,
            route: Route::Device,
            host_sweeps: HOST_SWEEPS,
            plan: EnginePlan::default(),
            checkpoint: None,
            accum: None,
        }
    }

    /// Same pipeline, factorizing (and accumulating) on the host route.
    pub fn with_route(mut self, route: Route) -> Pipeline<'a> {
        self.route = route;
        self
    }

    /// Same pipeline, with an explicit engine plan (worker counts).
    pub fn with_plan(mut self, plan: EnginePlan) -> Pipeline<'a> {
        self.plan = plan;
        self
    }

    /// Same pipeline, checkpointing calibration progress to disk.
    pub fn with_checkpoint(mut self, ckpt: Option<CheckpointCfg>) -> Pipeline<'a> {
        self.checkpoint = ckpt;
        self
    }

    /// Same pipeline, with an accumulator-kind override (`--accum`).
    pub fn with_accum(mut self, accum: Option<AccumKind>) -> Pipeline<'a> {
        self.accum = accum;
        self
    }

    fn accum_backend(&self) -> AccumBackend<'a> {
        match self.route {
            Route::Device => AccumBackend::Device(self.ex),
            Route::Host => AccumBackend::Host,
        }
    }

    /// Streaming calibration through the device capture (`fwd_acts`
    /// artifacts): token batches from the corpus split.
    pub fn calibrate(
        &self,
        job: &CompressionJob,
        corpus: &Corpus,
        timings: &mut StageTimings,
    ) -> Result<CalibStates> {
        let source = DeviceActivationSource::new(
            self.ex,
            &self.spec,
            self.weights,
            corpus,
            &job.calib_split,
            job.calib_batches,
        )?;
        self.calibrate_from(job, &source, timings)
    }

    /// Streaming calibration from *any* [`ActivationSource`] — the
    /// device capture or the synthetic PRNG generator — through the
    /// engine's capture ∥ accumulate graph.  X is never materialized
    /// (peak memory = the in-flight queue + partial accumulators).
    pub fn calibrate_from(
        &self,
        job: &CompressionJob,
        source: &dyn ActivationSource,
        timings: &mut StageTimings,
    ) -> Result<CalibStates> {
        let comp = compressor_for(&job.method);
        let kind = resolve_accum_kind(comp.as_ref(), self.accum)?;
        // fingerprint of this calibration run (model config, route,
        // batch count, plus whatever identity the checkpoint config
        // carries — e.g. the synthetic seed): keys the checkpoint file
        // and guards resume against mixing different runs
        let sid = self.checkpoint.as_ref().map_or_else(String::new, |c| {
            format!("{}:{:?}:b{}:{}", self.spec.name, self.route, job.calib_batches, c.source)
        });
        engine::calibrate_checkpointed(
            source,
            kind,
            job.calib_batches,
            self.accum_backend(),
            job.accum_precision,
            &self.plan,
            timings,
            self.checkpoint.as_ref(),
            &sid,
        )
    }

    /// Run the full job (device capture route).
    pub fn run(&self, job: &CompressionJob, corpus: &Corpus) -> Result<CompressionOutcome> {
        let t_start = Instant::now();
        let mut timings = StageTimings::default();
        let accums = self.calibrate(job, corpus, &mut timings)?;
        let mut out = self.run_with_accums(job, &accums, timings)?;
        out.timings.total_s = t_start.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Run the full job with activations from an explicit source — the
    /// synthetic host route's entry point (no artifacts anywhere).
    pub fn run_with_source(
        &self,
        job: &CompressionJob,
        source: &dyn ActivationSource,
    ) -> Result<CompressionOutcome> {
        let t_start = Instant::now();
        let mut timings = StageTimings::default();
        let accums = self.calibrate_from(job, source, &mut timings)?;
        let mut out = self.run_with_accums(job, &accums, timings)?;
        out.timings.total_s = t_start.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Factorize + assemble given pre-computed accumulators — lets a μ/λ
    /// sweep (Figs. 4/5) reuse one calibration pass across many jobs.
    /// The per-projection factorizations fan across the plan's
    /// `factorize_workers`.
    ///
    /// `total_s` here is the *sum of stage busy-times* (the
    /// serial-equivalent cost; calibrate/accumulate are worker-seconds
    /// when stages overlapped).  [`Pipeline::run`] and
    /// [`Pipeline::run_with_source`] overwrite it with the actual
    /// wall-clock of the whole run.
    pub fn run_with_accums(
        &self,
        job: &CompressionJob,
        accums: &CalibStates,
        mut timings: StageTimings,
    ) -> Result<CompressionOutcome> {
        let budget = super::budget::RankBudget::allocate(&self.spec, job.ratio, job.rank_policy)?;
        let tel = &self.plan.telemetry;
        self.probe_accum_health(accums);
        let t2 = Instant::now();
        let sweeps_before = crate::linalg::svd_sweep_total();
        // factorize runs serially after calibration, so it gets its
        // own memory scope (a true per-stage peak delta)
        let mut fz_mem = crate::telemetry::alloc::MemScope::enter();
        let (model, mus) = engine::factorize(
            &job.config,
            &self.spec,
            self.weights,
            &job.method,
            &budget,
            accums,
            self.route,
            self.ex,
            self.host_sweeps,
            self.plan.factorize_workers,
            tel,
        )?;
        let fz_stats = fz_mem.finish();
        timings.factorize_s = t2.elapsed().as_secs_f64();
        timings.total_s =
            timings.calibrate_s + timings.accumulate_s + timings.merge_s + timings.factorize_s;
        // report the engine's busy-time breakdown as telemetry stage
        // records — the engine already tracked these, never re-time
        engine::emit_stage_records(tel, &timings);
        tel.stage_mem("factorize", timings.factorize_s, fz_stats);
        tel.counter("projections_factorized", model.factors.len() as u64);
        // Jacobi convergence cost of this factorize stage: the global
        // sweep counter is a sum of deterministic per-projection counts,
        // so the delta is worker-count-independent
        tel.counter("svd_sweeps", crate::linalg::svd_sweep_total() - sweeps_before);
        Ok(CompressionOutcome { model, budget, timings, mus })
    }

    /// Health probes over the finished calibration states (when
    /// `COALA_HEALTH=1`): the diagonal of an accumulated R yields a free
    /// condition estimate — |r_ii| are the column norms of Q-projected
    /// data, so max|r_ii|/min|r_ii| lower-bounds cond(R) without any
    /// factorization — and sketch states report their geometry (rows s
    /// vs width, Ω family, folds absorbed).  Pure reads of
    /// already-computed state; zero flops when the knob is off.
    fn probe_accum_health(&self, accums: &CalibStates) {
        use crate::telemetry::health::{self, HealthEvent};
        if !health::enabled() {
            return;
        }
        let tel = &self.plan.telemetry;
        for ((layer, stream), state) in accums {
            let span = format!("accumulate/{layer}.{stream}");
            match state {
                CalibState::R(r) => {
                    let n = r.rows.min(r.cols);
                    let mut dmax = 0.0f64;
                    let mut dmin = f64::INFINITY;
                    for i in 0..n {
                        let d = (r.get(i, i) as f64).abs();
                        dmax = dmax.max(d);
                        dmin = dmin.min(d);
                    }
                    let cond = if dmin > 0.0 { dmax / dmin } else { f64::INFINITY };
                    tel.health_event(
                        Some(&span),
                        &HealthEvent::new("r_cond")
                            .num("cond", cond)
                            .num("diag_max", dmax)
                            .num("diag_min", dmin)
                            .num("n", n as f64),
                    );
                }
                CalibState::Sketch { y, folds, kind } => {
                    tel.health_event(
                        Some(&span),
                        &HealthEvent::new("sketch")
                            .num("rows", y.rows as f64)
                            .num("width", y.cols as f64)
                            .num("folds", *folds as f64)
                            .txt("family", kind.label()),
                    );
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::MuRule;
    use crate::eval::perplexity;

    fn setup() -> Option<(Executor, Corpus)> {
        if !crate::runtime::require_artifacts("pipeline::setup") {
            return None;
        }
        Some((Executor::new("artifacts").unwrap(), Corpus::load("artifacts").unwrap()))
    }

    #[test]
    fn accum_overrides_resolve_strictly() {
        use crate::coala::compressor::resolve;
        let coala = resolve("coala").unwrap();
        let svdllm = resolve("svdllm").unwrap();
        // no override → the declared statistic
        assert_eq!(resolve_accum_kind(coala.as_ref(), None).unwrap(), AccumKind::RFactor);
        assert_eq!(resolve_accum_kind(svdllm.as_ref(), None).unwrap(), AccumKind::Gram);
        // sketch only swaps in for R consumers
        assert_eq!(
            resolve_accum_kind(coala.as_ref(), Some(AccumKind::Sketch)).unwrap(),
            AccumKind::Sketch
        );
        assert!(resolve_accum_kind(svdllm.as_ref(), Some(AccumKind::Sketch)).is_err());
        // a same-kind override is a no-op, any other mismatch is loud
        assert_eq!(
            resolve_accum_kind(svdllm.as_ref(), Some(AccumKind::Gram)).unwrap(),
            AccumKind::Gram
        );
        assert!(resolve_accum_kind(coala.as_ref(), Some(AccumKind::Gram)).is_err());
    }

    #[test]
    fn coala_compression_end_to_end_preserves_model_better_than_random() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::None), 0.5);
        job.calib_batches = 4;
        let out = pipe.run(&job, &corpus).unwrap();
        assert!(out.model.all_finite());
        assert_eq!(out.model.factors.len(), spec.compressible.len());
        let achieved = out.model.achieved_ratio(&w, &spec);
        assert!((achieved - 0.5).abs() < 0.1, "achieved {achieved}");

        let val = corpus.split("val").unwrap();
        let base = perplexity(&ex, &spec, &w, val, 2).unwrap();
        let rec = out.model.reconstruct_into(&w).unwrap();
        let comp = perplexity(&ex, &spec, &rec, val, 2).unwrap();
        assert!(comp.is_finite());
        // 50 % compression shouldn't destroy the model (<4× ppl)
        assert!(comp < base * 4.0, "base {base} compressed {comp}");
        assert!(out.timings.total_s > 0.0);
    }

    #[test]
    fn adaptive_mu_records_per_layer_values() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        let mut job =
            CompressionJob::new("tiny", Method::Coala(MuRule::Adaptive { lambda: 2.0 }), 0.3);
        job.calib_batches = 2;
        let out = pipe.run(&job, &corpus).unwrap();
        assert_eq!(out.mus.len(), spec.compressible.len());
        // layer norms differ → adaptive μ varies across layers
        let vals: Vec<f64> = out.mus.values().copied().collect();
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vals.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.5, "μ did not vary: {min}..{max}");
        assert!(out.model.all_finite());
    }

    #[test]
    fn gram_route_methods_run() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w);
        for method in [Method::SvdLlm, Method::Asvd, Method::PlainSvd] {
            let mut job = CompressionJob::new("tiny", method, 0.4);
            job.calib_batches = 2;
            let out = pipe.run(&job, &corpus).unwrap();
            assert_eq!(out.model.factors.len(), spec.compressible.len(), "{}", method.name());
        }
    }

    #[test]
    fn synthetic_source_runs_host_route_end_to_end() {
        // the artifact-free path: synthetic manifest + weights +
        // activations, host accumulate + factorize — always runs
        use crate::calib::synthetic::SyntheticActivations;
        use crate::model::synthetic::{synthetic_manifest, synthetic_weights};
        let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 1);
        let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host);
        let src = SyntheticActivations::new(spec.clone(), 1);
        let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::None), 0.4);
        job.calib_batches = 2;
        let out = pipe.run_with_source(&job, &src).unwrap();
        assert!(out.model.all_finite());
        assert_eq!(out.model.factors.len(), spec.compressible.len());
        let achieved = out.model.achieved_ratio(&w, &spec);
        assert!((achieved - 0.4).abs() < 0.15, "achieved {achieved}");
    }

    #[test]
    fn parallel_plan_matches_sequential_bitwise() {
        // the host route through a parallel plan is byte-identical to
        // the sequential plan — the engine's core guarantee
        use crate::calib::synthetic::SyntheticActivations;
        use crate::model::synthetic::{synthetic_manifest, synthetic_weights};
        let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 2);
        let src = SyntheticActivations::new(spec.clone(), 2);
        let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::None), 0.4);
        job.calib_batches = 3;
        let seq = Pipeline::new(&ex, spec.clone(), &w)
            .with_route(Route::Host)
            .run_with_source(&job, &src)
            .unwrap();
        let par = Pipeline::new(&ex, spec.clone(), &w)
            .with_route(Route::Host)
            .with_plan(EnginePlan::with_workers(4))
            .run_with_source(&job, &src)
            .unwrap();
        assert_eq!(seq.model.factors.len(), par.model.factors.len());
        for (proj, f_seq) in &seq.model.factors {
            let f_par = &par.model.factors[proj];
            assert_eq!(f_seq.a.data, f_par.a.data, "{proj}: A factor differs");
            assert_eq!(f_seq.b.data, f_par.b.data, "{proj}: B factor differs");
        }
    }

    #[test]
    fn host_route_matches_device_route() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let device = Pipeline::new(&ex, spec.clone(), &w);
        let host = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host);
        let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::None), 0.4);
        job.calib_batches = 2;
        let out_d = device.run(&job, &corpus).unwrap();
        let out_h = host.run(&job, &corpus).unwrap();
        assert!(out_h.model.all_finite());
        let val = corpus.split("val").unwrap();
        let rec_d = out_d.model.reconstruct_into(&w).unwrap();
        let rec_h = out_h.model.reconstruct_into(&w).unwrap();
        let ppl_d = perplexity(&ex, &spec, &rec_d, val, 2).unwrap();
        let ppl_h = perplexity(&ex, &spec, &rec_h, val, 2).unwrap();
        assert!(
            (ppl_d - ppl_h).abs() < 0.05 * ppl_d + 0.5,
            "device {ppl_d} vs host {ppl_h}"
        );
    }
}
