//! Overlapped streaming calibration: capture ∥ accumulate with
//! backpressure, as a thin configuration of the execution engine.
//!
//! The engine always runs capture workers and accumulate shards as
//! separate threads connected by a **bounded** channel — if accumulation
//! falls behind, capture blocks (backpressure) instead of buffering
//! unbounded activation chunks (which is the whole point of the
//! streaming design: X must never materialize).  This module provides
//! the two historical entry points on top:
//!
//! * [`calibrate_overlapped`] — the artifact route: `fwd_acts` capture
//!   on one simulated device, accumulation on another (each with its own
//!   executor), exactly the original two-device overlap;
//! * [`calibrate_overlapped_source`] — the source-agnostic route: any
//!   [`ActivationSource`] (synthetic host generator included, so the
//!   backpressure path runs with zero artifacts) with a chosen worker
//!   count.  Results are bitwise-independent of the worker count.
//!
//! Accumulation goes through the [`crate::calib::accumulate::CalibAccumulator`]
//! interface, so the overlapped path serves any accumulator kind
//! (R / Gram / scales), not just the COALA R route.  A failure in either
//! stage is reported; when both fail, the errors are chained through
//! [`crate::error::Error::context`] so neither is silently dropped.

use super::engine::{self, EnginePlan, StageTimings};
use crate::calib::accumulate::{AccumBackend, AccumKind};
use crate::calib::activations::{ActivationSource, DeviceActivationSource};
use crate::error::Result;
use crate::model::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::tensor::lowp::Precision;

/// Outcome of the overlapped calibration: per-(layer, stream) states.
pub use super::engine::CalibStates;

/// Overlapped calibrate-and-fold over the `fwd_acts` artifacts.
/// `queue_cap` bounds the number of in-flight batches' chunks
/// (backpressure knob).  Capture and accumulation each own a separate
/// executor — the two-simulated-devices setup.
pub fn calibrate_overlapped(
    artifacts_dir: &str,
    config: &str,
    batches: Vec<Value>,
    queue_cap: usize,
    kind: AccumKind,
) -> Result<CalibStates> {
    let ex_capture = Executor::new(artifacts_dir)?; // capture device
    let spec = ex_capture.manifest.config(config)?.clone();
    let weights = ModelWeights::load(artifacts_dir, &spec)?;
    let n_batches = batches.len();
    let source = DeviceActivationSource::from_batches(&ex_capture, &spec, &weights, batches);
    let ex_accum = Executor::new(artifacts_dir)?; // accumulate device
    calibrate_overlapped_source(
        &source,
        n_batches,
        kind,
        AccumBackend::Device(&ex_accum),
        Precision::F32,
        1,
        queue_cap,
    )
}

/// Overlapped calibrate-and-fold from any [`ActivationSource`]:
/// `workers` capture threads feed `workers` accumulate shards through a
/// `queue_cap`-bounded channel; partial states merge through the
/// engine's canonical reduction tree, so the result is bitwise-identical
/// at any worker count.
pub fn calibrate_overlapped_source(
    source: &dyn ActivationSource,
    batches: usize,
    kind: AccumKind,
    backend: AccumBackend<'_>,
    precision: Precision,
    workers: usize,
    queue_cap: usize,
) -> Result<CalibStates> {
    let mut plan = EnginePlan::with_workers(workers);
    plan.queue_cap = queue_cap.max(1);
    engine::calibrate(
        source,
        kind,
        batches,
        backend,
        precision,
        &plan,
        &mut StageTimings::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::accumulate::{make_accumulator, CalibAccumulator};
    use crate::calib::activations::ActivationCapture;
    use crate::calib::dataset::Corpus;
    use crate::calib::synthetic::SyntheticActivations;
    use crate::model::synthetic::synthetic_manifest;
    use crate::tensor::ops::fro;
    use std::collections::BTreeMap;

    #[test]
    fn overlapped_matches_sequential() {
        if !crate::runtime::require_artifacts("scheduler::overlapped_matches_sequential") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let weights = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let batches = corpus.batches("calib", spec.batch, spec.seq_len, 3).unwrap();

        // sequential reference through the same accumulator interface
        let cap = ActivationCapture::new(&ex, &spec);
        let mut seq: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> = BTreeMap::new();
        for t in &batches {
            let (_l, chunks) = cap.capture(t, &weights).unwrap();
            for c in chunks {
                let acc = seq.entry((c.layer, c.stream.clone())).or_insert_with(|| {
                    make_accumulator(
                        AccumKind::RFactor,
                        c.xt.cols,
                        AccumBackend::Device(&ex),
                        crate::tensor::lowp::Precision::F32,
                    )
                    .unwrap()
                });
                acc.fold_chunk(&c.xt).unwrap();
            }
        }
        let seq: CalibStates = seq.into_iter().map(|(k, a)| (k, a.finish())).collect();

        let par =
            calibrate_overlapped("artifacts", "tiny", batches, 2, AccumKind::RFactor).unwrap();
        assert_eq!(par.len(), seq.len());
        for (k, s_seq) in &seq {
            let r_seq = s_seq.r().unwrap();
            let r_par = par[k].r().unwrap();
            // R is unique up to row signs; compare RᵀR
            let g_seq = crate::tensor::ops::matmul(&r_seq.transpose(), r_seq).unwrap();
            let g_par = crate::tensor::ops::matmul(&r_par.transpose(), r_par).unwrap();
            let err = fro(&g_seq.sub(&g_par).unwrap()) / fro(&g_seq).max(1e-9);
            assert!(err < 1e-4, "{k:?}: {err}");
        }
    }

    #[test]
    fn overlapped_source_runs_on_host_and_is_worker_count_invariant() {
        // no artifacts anywhere: the synthetic source through the
        // backpressure path, bitwise identical at every worker count
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 9);
        for kind in [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales] {
            let mut reference: Option<CalibStates> = None;
            for workers in [1usize, 2, 8] {
                let states = calibrate_overlapped_source(
                    &src,
                    3,
                    kind,
                    AccumBackend::Host,
                    Precision::F32,
                    workers,
                    2,
                )
                .unwrap();
                assert_eq!(states.len(), spec.n_layers * spec.act_streams.len());
                match &reference {
                    None => reference = Some(states),
                    Some(want) => {
                        for (k, sw) in want {
                            use crate::calib::accumulate::CalibState;
                            match (sw, &states[k]) {
                                (CalibState::R(a), CalibState::R(b)) => {
                                    assert_eq!(a.data, b.data, "{kind:?} {k:?}")
                                }
                                (CalibState::Gram(a), CalibState::Gram(b)) => {
                                    assert_eq!(a.data, b.data, "{kind:?} {k:?}")
                                }
                                (
                                    CalibState::Scales { sum_abs: a, rows: ra },
                                    CalibState::Scales { sum_abs: b, rows: rb },
                                ) => {
                                    assert_eq!(a, b, "{kind:?} {k:?}");
                                    assert_eq!(ra, rb, "{kind:?} {k:?}");
                                }
                                other => panic!("state kind mismatch: {other:?}"),
                            }
                        }
                    }
                }
            }
        }
    }
}
