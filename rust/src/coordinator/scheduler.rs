//! Two-stage streaming scheduler: capture ∥ accumulate with backpressure.
//!
//! The sequential pipeline alternates "run fwd_acts" and "fold chunks
//! into the accumulator"; both are device-bound, so on a multi-device box
//! they can overlap.  This scheduler runs capture on one simulated device
//! and accumulation on another, connected by a **bounded** channel — if
//! the accumulator falls behind, the capture stage blocks (backpressure)
//! instead of buffering unbounded activation chunks (which is the whole
//! point of the streaming design: X must never materialize).
//!
//! Accumulation goes through the [`CalibAccumulator`] interface, so the
//! overlapped path serves any accumulator kind (R / Gram / scales), not
//! just the COALA R route.

use crate::calib::accumulate::{make_accumulator, AccumBackend, AccumKind, CalibAccumulator};
use crate::calib::activations::ActivationCapture;
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::tensor::lowp::Precision;
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Outcome of the overlapped calibration: per-(layer, stream) states.
pub use super::pipeline::CalibStates;

/// Overlapped calibrate-and-fold.  `queue_cap` bounds the number of
/// in-flight batches' chunks (backpressure knob).
pub fn calibrate_overlapped(
    artifacts_dir: &str,
    config: &str,
    batches: Vec<Value>,
    queue_cap: usize,
    kind: AccumKind,
) -> Result<CalibStates> {
    let (tx, rx) = mpsc::sync_channel::<Vec<(usize, String, Matrix<f32>)>>(queue_cap.max(1));
    let dir_a = artifacts_dir.to_string();
    let dir_b = artifacts_dir.to_string();
    let cfg_name = config.to_string();

    let producer = std::thread::spawn(move || -> Result<()> {
        let ex = Executor::new(&dir_a)?; // capture device
        let spec = ex.manifest.config(&cfg_name)?.clone();
        let weights = ModelWeights::load(&dir_a, &spec)?;
        let cap = ActivationCapture::new(&ex, &spec);
        for tokens in &batches {
            let (_logits, chunks) = cap.capture(tokens, &weights)?;
            let payload: Vec<(usize, String, Matrix<f32>)> =
                chunks.into_iter().map(|c| (c.layer, c.stream, c.xt)).collect();
            if tx.send(payload).is_err() {
                break; // consumer died; its error surfaces below
            }
        }
        Ok(())
    });

    let consumer = std::thread::spawn(move || -> Result<CalibStates> {
        let ex = Executor::new(&dir_b)?; // accumulate device
        let mut accums: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> =
            BTreeMap::new();
        for payload in rx {
            for (layer, stream, xt) in payload {
                let acc = accums.entry((layer, stream)).or_insert_with(|| {
                    make_accumulator(kind, xt.cols, AccumBackend::Device(&ex), Precision::F32)
                });
                acc.fold_chunk(&xt)?;
            }
        }
        Ok(accums.into_iter().map(|(k, a)| (k, a.finish())).collect())
    });

    producer
        .join()
        .map_err(|_| Error::msg("capture stage panicked"))??;
    consumer.join().map_err(|_| Error::msg("accumulate stage panicked"))?
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::Corpus;
    use crate::tensor::ops::fro;

    #[test]
    fn overlapped_matches_sequential() {
        if !crate::runtime::require_artifacts("scheduler::overlapped_matches_sequential") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let weights = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let batches = corpus.batches("calib", spec.batch, spec.seq_len, 3).unwrap();

        // sequential reference through the same accumulator interface
        let cap = ActivationCapture::new(&ex, &spec);
        let mut seq: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> = BTreeMap::new();
        for t in &batches {
            let (_l, chunks) = cap.capture(t, &weights).unwrap();
            for c in chunks {
                let acc = seq.entry((c.layer, c.stream.clone())).or_insert_with(|| {
                    make_accumulator(
                        AccumKind::RFactor,
                        c.xt.cols,
                        AccumBackend::Device(&ex),
                        Precision::F32,
                    )
                });
                acc.fold_chunk(&c.xt).unwrap();
            }
        }
        let seq: CalibStates = seq.into_iter().map(|(k, a)| (k, a.finish())).collect();

        let par =
            calibrate_overlapped("artifacts", "tiny", batches, 2, AccumKind::RFactor).unwrap();
        assert_eq!(par.len(), seq.len());
        for (k, s_seq) in &seq {
            let r_seq = s_seq.r().unwrap();
            let r_par = par[k].r().unwrap();
            // R is unique up to row signs; compare RᵀR
            let g_seq = crate::tensor::ops::matmul(&r_seq.transpose(), r_seq).unwrap();
            let g_par = crate::tensor::ops::matmul(&r_par.transpose(), r_par).unwrap();
            let err = fro(&g_seq.sub(&g_par).unwrap()) / fro(&g_seq).max(1e-9);
            assert!(err < 1e-4, "{k:?}: {err}");
        }
    }
}
