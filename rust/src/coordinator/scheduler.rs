//! Two-stage streaming scheduler: capture ∥ accumulate with backpressure.
//!
//! The sequential pipeline alternates "run fwd_acts" and "fold chunks
//! into R"; both are device-bound, so on a multi-device box they can
//! overlap.  This scheduler runs capture on one simulated device and
//! accumulation on another, connected by a **bounded** channel — if the
//! accumulator falls behind, the capture stage blocks (backpressure)
//! instead of buffering unbounded activation chunks (which is the whole
//! point of the streaming design: X must never materialize).

use crate::calib::activations::ActivationCapture;
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::runtime::ops;
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Outcome of the overlapped calibration: per-(layer, stream) R factors.
pub type RFactors = BTreeMap<(usize, String), Matrix<f32>>;

/// Overlapped calibrate-and-fold.  `queue_cap` bounds the number of
/// in-flight batches' chunks (backpressure knob).
pub fn calibrate_overlapped(
    artifacts_dir: &str,
    config: &str,
    batches: Vec<Value>,
    queue_cap: usize,
) -> Result<RFactors> {
    let (tx, rx) = mpsc::sync_channel::<Vec<(usize, String, Matrix<f32>)>>(queue_cap.max(1));
    let dir_a = artifacts_dir.to_string();
    let dir_b = artifacts_dir.to_string();
    let cfg_name = config.to_string();

    let producer = std::thread::spawn(move || -> Result<()> {
        let ex = Executor::new(&dir_a)?; // capture device
        let spec = ex.manifest.config(&cfg_name)?.clone();
        let weights = ModelWeights::load(&dir_a, &spec)?;
        let cap = ActivationCapture::new(&ex, &spec);
        for tokens in &batches {
            let (_logits, chunks) = cap.capture(tokens, &weights)?;
            let payload: Vec<(usize, String, Matrix<f32>)> =
                chunks.into_iter().map(|c| (c.layer, c.stream, c.xt)).collect();
            if tx.send(payload).is_err() {
                break; // consumer died; its error surfaces below
            }
        }
        Ok(())
    });

    let consumer = std::thread::spawn(move || -> Result<RFactors> {
        let ex = Executor::new(&dir_b)?; // accumulate device
        let mut rs: RFactors = BTreeMap::new();
        for payload in rx {
            for (layer, stream, xt) in payload {
                let n = xt.cols;
                let r = rs.entry((layer, stream)).or_insert_with(|| Matrix::zeros(n, n));
                *r = ops::tsqr_step(&ex, r, &xt)?;
            }
        }
        Ok(rs)
    });

    producer
        .join()
        .map_err(|_| Error::msg("capture stage panicked"))??;
    consumer.join().map_err(|_| Error::msg("accumulate stage panicked"))?
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::Corpus;
    use crate::tensor::ops::fro;

    #[test]
    fn overlapped_matches_sequential() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let weights = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let batches = corpus.batches("calib", spec.batch, spec.seq_len, 3).unwrap();

        // sequential reference
        let cap = ActivationCapture::new(&ex, &spec);
        let mut seq: RFactors = BTreeMap::new();
        for t in &batches {
            let (_l, chunks) = cap.capture(t, &weights).unwrap();
            for c in chunks {
                let n = c.xt.cols;
                let r = seq.entry((c.layer, c.stream)).or_insert_with(|| Matrix::zeros(n, n));
                *r = ops::tsqr_step(&ex, r, &c.xt).unwrap();
            }
        }

        let par = calibrate_overlapped("artifacts", "tiny", batches, 2).unwrap();
        assert_eq!(par.len(), seq.len());
        for (k, r_seq) in &seq {
            let r_par = &par[k];
            // R is unique up to row signs; compare RᵀR
            let g_seq =
                crate::tensor::ops::matmul(&r_seq.transpose(), r_seq).unwrap();
            let g_par =
                crate::tensor::ops::matmul(&r_par.transpose(), r_par).unwrap();
            let err = fro(&g_seq.sub(&g_par).unwrap()) / fro(&g_seq).max(1e-9);
            assert!(err < 1e-4, "{k:?}: {err}");
        }
    }
}
