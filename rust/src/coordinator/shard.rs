//! Shard planning for multi-process calibration.
//!
//! A [`ShardPlan`] partitions the `total_batches` of a calibration run
//! into contiguous, near-even batch ranges — one per worker process.
//! Each worker runs `coala shard` (→ [`super::engine::accumulate_shard`])
//! over its range and writes a state file through the
//! [`crate::calib::state`] codec; `coala merge`
//! (→ [`super::engine::merge_shard_states`]) folds the files back into
//! the canonical merge tree.  Because leaf indices are global batch
//! numbers and the tree shape depends only on `total_batches`, the
//! merged result is **bitwise identical** to the single-process engine
//! run at any shard count — sharding, like `--workers`, is a pure
//! deployment knob.

use super::engine::ShardRange;
use crate::error::{Error, Result};

/// Contiguous near-even partition of `[0, total_batches)` into
/// `shard_count` ranges.  The first `total % count` shards get one
/// extra batch, so any two shards differ by at most one batch of work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    pub total_batches: usize,
    pub shard_count: usize,
}

impl ShardPlan {
    pub fn new(total_batches: usize, shard_count: usize) -> Result<ShardPlan> {
        if total_batches == 0 {
            return Err(Error::Config("shard plan over zero batches".into()));
        }
        if shard_count == 0 {
            return Err(Error::Config("shard plan with zero shards".into()));
        }
        if shard_count > total_batches {
            return Err(Error::Config(format!(
                "{shard_count} shards over {total_batches} batches: some shards would be empty"
            )));
        }
        Ok(ShardPlan { total_batches, shard_count })
    }

    /// The batch range of shard `index` (0-based).
    pub fn range(&self, index: usize) -> Result<ShardRange> {
        if index >= self.shard_count {
            return Err(Error::Config(format!(
                "shard index {index} out of range (plan has {} shards)",
                self.shard_count
            )));
        }
        let base = self.total_batches / self.shard_count;
        let rem = self.total_batches % self.shard_count;
        let start = index * base + index.min(rem);
        let len = base + usize::from(index < rem);
        Ok(ShardRange { start, end: start + len, total: self.total_batches })
    }

    /// Every shard's range, in order (the shard manifest).
    pub fn ranges(&self) -> Vec<ShardRange> {
        (0..self.shard_count).map(|i| self.range(i).unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_the_batches_evenly() {
        for total in [1usize, 2, 5, 8, 17] {
            for count in 1..=total {
                let plan = ShardPlan::new(total, count).unwrap();
                let ranges = plan.ranges();
                assert_eq!(ranges.len(), count);
                let mut cursor = 0;
                let mut min_len = usize::MAX;
                let mut max_len = 0;
                for r in &ranges {
                    assert_eq!(r.start, cursor, "{total}/{count}");
                    assert_eq!(r.total, total);
                    assert!(!r.is_empty(), "{total}/{count}: empty shard");
                    min_len = min_len.min(r.len());
                    max_len = max_len.max(r.len());
                    cursor = r.end;
                }
                assert_eq!(cursor, total);
                assert!(max_len - min_len <= 1, "{total}/{count}: uneven ({min_len}..{max_len})");
            }
        }
    }

    #[test]
    fn degenerate_plans_are_rejected() {
        assert!(ShardPlan::new(0, 1).is_err());
        assert!(ShardPlan::new(4, 0).is_err());
        assert!(ShardPlan::new(4, 5).is_err());
        assert!(ShardPlan::new(4, 2).unwrap().range(2).is_err());
    }
}
