//! Multi-device tree TSQR (the paper's §4.2 binary-tree diagram) as a
//! thin configuration of the execution engine.
//!
//! Each chunk of Xᵀ becomes one engine batch; the engine's accumulate
//! shards QR the leaves in parallel and its canonical pairwise reduction
//! merges the R factors up the tree — tiny n × n matrices are the only
//! thing crossing tree edges, exactly like the multi-GPU
//! all-reduce-of-R pattern.  Because the reduction tree is fixed by the
//! chunk order, the final R is bitwise-independent of the worker count.
//!
//! On the device route the shards now share **one** executor (a single
//! PJRT client with a mutex-guarded compile cache), unlike the
//! pre-engine runner where every worker owned its own client; the tree
//! *communication* pattern is simulated faithfully, per-leaf device
//! state is not.
//!
//! Both the leaf folds and the reduction edges drive the
//! [`crate::calib::accumulate::CalibAccumulator`] interface, so the same
//! runner reduces any mergeable accumulator state and falls back to the
//! host route when no artifacts exist.

use super::engine::{self, EnginePlan, StageTimings};
use crate::calib::accumulate::{AccumBackend, AccumKind};
use crate::calib::activations::{ActivationSource, CalibChunk};
use crate::error::{Error, Result};
use crate::runtime::executor::Executor;
use crate::tensor::lowp::Precision;
use crate::tensor::Matrix;

/// The single pseudo-stream the chunk source publishes under.
const STREAM: &str = "tsqr";

/// An [`ActivationSource`] over a pre-chunked Xᵀ: batch `b` is chunk
/// `b`.  Chunks hand over by `take()` — each batch is pulled exactly
/// once, so no copy of Xᵀ is ever made.
struct ChunkSource {
    chunks: Vec<std::sync::Mutex<Option<Matrix<f32>>>>,
}

impl ActivationSource for ChunkSource {
    fn capture_batch(&self, b: usize) -> Result<Vec<CalibChunk>> {
        let xt = self
            .chunks
            .get(b)
            .ok_or_else(|| Error::Config(format!("tsqr chunk {b} out of range")))?
            .lock()
            .unwrap()
            .take()
            .ok_or_else(|| Error::Config(format!("tsqr chunk {b} pulled twice")))?;
        Ok(vec![CalibChunk { layer: 0, stream: STREAM.to_string(), xt }])
    }
}

/// Runs tree-TSQR over chunk streams with `workers` simulated devices.
pub struct TsqrTreeRunner {
    pub artifacts_dir: String,
    pub workers: usize,
    /// Fold through PJRT artifacts (default) or host linalg.
    pub host: bool,
}

impl TsqrTreeRunner {
    pub fn new(artifacts_dir: &str, workers: usize) -> TsqrTreeRunner {
        TsqrTreeRunner {
            artifacts_dir: artifacts_dir.to_string(),
            workers: workers.max(1),
            host: false,
        }
    }

    /// Same tree, pure-Rust host folds (no artifacts needed).
    pub fn host(workers: usize) -> TsqrTreeRunner {
        TsqrTreeRunner { artifacts_dir: String::new(), workers: workers.max(1), host: true }
    }

    /// Leaf phase: `workers` engine shards QR the chunks in parallel;
    /// reduction phase: the engine's canonical pairwise merge tree.
    ///
    /// `chunks` are (c × n) row-blocks of Xᵀ; all must share n (the AOT
    /// artifact is shape-specialized; the host route checks at merge).
    pub fn run(&self, chunks: Vec<Matrix<f32>>) -> Result<Matrix<f32>> {
        if chunks.is_empty() {
            return Err(Error::Config("tsqr over zero chunks".into()));
        }
        let batches = chunks.len();
        let source = ChunkSource {
            chunks: chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect(),
        };
        let ex;
        let backend = if self.host {
            AccumBackend::Host
        } else {
            ex = Executor::new(&self.artifacts_dir)?;
            AccumBackend::Device(&ex)
        };
        let plan = EnginePlan {
            capture_workers: 1,
            accum_shards: self.workers,
            queue_cap: self.workers.max(2),
            ..EnginePlan::sequential()
        };
        let mut timings = StageTimings::default();
        let mut states = engine::calibrate(
            &source,
            AccumKind::RFactor,
            batches,
            backend,
            Precision::F32,
            &plan,
            &mut timings,
        )?;
        let state = states
            .remove(&(0, STREAM.to_string()))
            .ok_or_else(|| Error::Config("tree-TSQR produced no state".into()))?;
        Ok(state.r()?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, gram_t, matmul};

    #[test]
    fn tree_matches_sequential_gram_identity() {
        if !crate::runtime::require_artifacts("tsqr_tree::tree_matches_sequential_gram_identity") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let chunks: Vec<Matrix<f32>> = (0..5).map(|i| Matrix::randn(c, n, 10 + i)).collect();
        let mut full = chunks[0].clone();
        for ch in &chunks[1..] {
            full = full.vstack(ch).unwrap();
        }
        let want = gram_t(&full);
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::new("artifacts", workers);
            let r = runner.run(chunks.clone()).unwrap();
            let got = matmul(&r.transpose(), &r).unwrap();
            let err = fro(&got.sub(&want).unwrap()) / fro(&want);
            assert!(err < 1e-4, "workers={workers}: {err}");
        }
    }

    #[test]
    fn host_tree_matches_direct_gram() {
        // no artifacts needed: the same tree reduction on the host route
        let n = 12;
        let chunks: Vec<Matrix<f32>> = (0..6).map(|i| Matrix::randn(17, n, 40 + i)).collect();
        let mut full = chunks[0].clone();
        for ch in &chunks[1..] {
            full = full.vstack(ch).unwrap();
        }
        let want = gram_t(&full);
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::host(workers);
            let r = runner.run(chunks.clone()).unwrap();
            let got = matmul(&r.transpose(), &r).unwrap();
            let err = fro(&got.sub(&want).unwrap()) / fro(&want);
            assert!(err < 1e-3, "workers={workers}: {err}");
        }
    }

    #[test]
    fn host_tree_is_bitwise_worker_count_invariant() {
        // the fixed reduction tree makes R independent of parallelism
        let chunks: Vec<Matrix<f32>> = (0..7).map(|i| Matrix::randn(11, 8, 70 + i)).collect();
        let want = TsqrTreeRunner::host(1).run(chunks.clone()).unwrap();
        for workers in [2usize, 4, 8] {
            let got = TsqrTreeRunner::host(workers).run(chunks.clone()).unwrap();
            assert_eq!(want.data, got.data, "workers={workers}");
        }
    }

    #[test]
    fn empty_rejected() {
        let runner = TsqrTreeRunner::new("artifacts", 2);
        assert!(runner.run(vec![]).is_err());
    }
}
