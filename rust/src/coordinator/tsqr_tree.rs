//! Multi-device tree TSQR (the paper's §4.2 binary-tree diagram).
//!
//! Each worker thread owns its **own PJRT client + executable cache** —
//! the faithful simulation of "one GPU per tree leaf": no shared device
//! state, R factors (tiny n × n matrices) are the only thing crossing
//! the tree edges, exactly like the multi-GPU all-reduce-of-R pattern.
//!
//! Both the leaf folds and the reduction edges drive the
//! [`CalibAccumulator`] interface from `calib::accumulate`, so the same
//! runner reduces any mergeable accumulator state and can fall back to
//! the host route when no artifacts exist.

use crate::calib::accumulate::{
    make_accumulator, merge_states, AccumBackend, AccumKind, CalibAccumulator, CalibState,
};
use crate::error::{Error, Result};
use crate::runtime::executor::Executor;
use crate::tensor::lowp::Precision;
use crate::tensor::Matrix;
use std::sync::mpsc;

/// Runs tree-TSQR over chunk streams with `workers` simulated devices.
pub struct TsqrTreeRunner {
    pub artifacts_dir: String,
    pub workers: usize,
    /// Fold through PJRT artifacts (default) or host linalg.
    pub host: bool,
}

impl TsqrTreeRunner {
    pub fn new(artifacts_dir: &str, workers: usize) -> TsqrTreeRunner {
        TsqrTreeRunner {
            artifacts_dir: artifacts_dir.to_string(),
            workers: workers.max(1),
            host: false,
        }
    }

    /// Same tree, pure-Rust host folds (no artifacts needed).
    pub fn host(workers: usize) -> TsqrTreeRunner {
        TsqrTreeRunner { artifacts_dir: String::new(), workers: workers.max(1), host: true }
    }

    fn fold_share(&self, share: &[&Matrix<f32>], n: usize) -> Result<CalibState> {
        let ex;
        let backend = if self.host {
            AccumBackend::Host
        } else {
            ex = Executor::new(&self.artifacts_dir)?; // own PJRT client
            AccumBackend::Device(&ex)
        };
        let mut acc = make_accumulator(AccumKind::RFactor, n, backend, Precision::F32);
        for &c in share {
            acc.fold_chunk(c)?;
        }
        Ok(acc.finish())
    }

    /// Leaf phase: worker w sequentially folds chunks w, w+P, w+2P, …
    /// into a local R; reduction phase: pairwise merges up the tree.
    ///
    /// `chunks` are (c × n) row-blocks of Xᵀ; all must share n and c
    /// (the AOT artifact is shape-specialized).
    pub fn run(&self, chunks: Vec<Matrix<f32>>) -> Result<Matrix<f32>> {
        if chunks.is_empty() {
            return Err(Error::Config("tsqr over zero chunks".into()));
        }
        let n = chunks[0].cols;
        let workers = self.workers.min(chunks.len());
        if workers <= 1 {
            // single device: plain streaming fold
            let share: Vec<&Matrix<f32>> = chunks.iter().collect();
            return self.fold_share(&share, n)?.r().cloned();
        }

        // ---- leaf phase: one thread per simulated device ----------------
        let (tx, rx) = mpsc::channel::<Result<(usize, CalibState)>>();
        std::thread::scope(|s| {
            // distribute chunks round-robin; each worker folds its share
            let mut shares: Vec<Vec<&Matrix<f32>>> = vec![Vec::new(); workers];
            for (i, c) in chunks.iter().enumerate() {
                shares[i % workers].push(c);
            }
            for (w, share) in shares.into_iter().enumerate() {
                let tx = tx.clone();
                s.spawn(move || {
                    let res = self.fold_share(&share, n);
                    let _ = tx.send(res.map(|r| (w, r)));
                });
            }
        });
        drop(tx);
        let mut leaves: Vec<(usize, CalibState)> = Vec::with_capacity(workers);
        for got in rx {
            leaves.push(got?);
        }
        leaves.sort_by_key(|(w, _)| *w); // deterministic reduction order
        let mut level: Vec<CalibState> = leaves.into_iter().map(|(_, r)| r).collect();

        // ---- reduction phase: binary tree of R merges --------------------
        let ex;
        let backend = if self.host {
            AccumBackend::Host
        } else {
            ex = Executor::new(&self.artifacts_dir)?;
            AccumBackend::Device(&ex)
        };
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(merge_states(a, b, backend, Precision::F32)?),
                    None => next.push(a),
                }
            }
            level = next;
        }
        level.pop().unwrap().r().cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, gram_t, matmul};

    #[test]
    fn tree_matches_sequential_gram_identity() {
        if !crate::runtime::require_artifacts("tsqr_tree::tree_matches_sequential_gram_identity") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let chunks: Vec<Matrix<f32>> = (0..5).map(|i| Matrix::randn(c, n, 10 + i)).collect();
        let mut full = chunks[0].clone();
        for ch in &chunks[1..] {
            full = full.vstack(ch).unwrap();
        }
        let want = gram_t(&full);
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::new("artifacts", workers);
            let r = runner.run(chunks.clone()).unwrap();
            let got = matmul(&r.transpose(), &r).unwrap();
            let err = fro(&got.sub(&want).unwrap()) / fro(&want);
            assert!(err < 1e-4, "workers={workers}: {err}");
        }
    }

    #[test]
    fn host_tree_matches_direct_gram() {
        // no artifacts needed: the same tree reduction on the host route
        let n = 12;
        let chunks: Vec<Matrix<f32>> = (0..6).map(|i| Matrix::randn(17, n, 40 + i)).collect();
        let mut full = chunks[0].clone();
        for ch in &chunks[1..] {
            full = full.vstack(ch).unwrap();
        }
        let want = gram_t(&full);
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::host(workers);
            let r = runner.run(chunks.clone()).unwrap();
            let got = matmul(&r.transpose(), &r).unwrap();
            let err = fro(&got.sub(&want).unwrap()) / fro(&want);
            assert!(err < 1e-3, "workers={workers}: {err}");
        }
    }

    #[test]
    fn empty_rejected() {
        let runner = TsqrTreeRunner::new("artifacts", 2);
        assert!(runner.run(vec![]).is_err());
    }
}
