//! Multi-device tree TSQR (the paper's §4.2 binary-tree diagram).
//!
//! Each worker thread owns its **own PJRT client + executable cache** —
//! the faithful simulation of "one GPU per tree leaf": no shared device
//! state, R factors (tiny n × n matrices) are the only thing crossing
//! the tree edges, exactly like the multi-GPU all-reduce-of-R pattern.

use crate::error::{Error, Result};
use crate::runtime::executor::Executor;
use crate::runtime::ops;
use crate::tensor::Matrix;
use std::sync::mpsc;

/// Runs tree-TSQR over chunk streams with `workers` simulated devices.
pub struct TsqrTreeRunner {
    pub artifacts_dir: String,
    pub workers: usize,
}

impl TsqrTreeRunner {
    pub fn new(artifacts_dir: &str, workers: usize) -> TsqrTreeRunner {
        TsqrTreeRunner { artifacts_dir: artifacts_dir.to_string(), workers: workers.max(1) }
    }

    /// Leaf phase: worker w sequentially folds chunks w, w+P, w+2P, …
    /// into a local R; reduction phase: pairwise merges up the tree.
    ///
    /// `chunks` are (c × n) row-blocks of Xᵀ; all must share n and c
    /// (the AOT artifact is shape-specialized).
    pub fn run(&self, chunks: Vec<Matrix<f32>>) -> Result<Matrix<f32>> {
        if chunks.is_empty() {
            return Err(Error::Config("tsqr over zero chunks".into()));
        }
        let n = chunks[0].cols;
        let workers = self.workers.min(chunks.len());
        if workers <= 1 {
            // single device: plain streaming fold
            let ex = Executor::new(&self.artifacts_dir)?;
            let mut r = Matrix::zeros(n, n);
            for c in &chunks {
                r = ops::tsqr_step(&ex, &r, c)?;
            }
            return Ok(r);
        }

        // ---- leaf phase: one thread per simulated device ----------------
        let (tx, rx) = mpsc::channel::<Result<(usize, Matrix<f32>)>>();
        std::thread::scope(|s| {
            // distribute chunks round-robin; each worker folds its share
            let mut shares: Vec<Vec<&Matrix<f32>>> = vec![Vec::new(); workers];
            for (i, c) in chunks.iter().enumerate() {
                shares[i % workers].push(c);
            }
            for (w, share) in shares.into_iter().enumerate() {
                let tx = tx.clone();
                let dir = self.artifacts_dir.clone();
                s.spawn(move || {
                    let res = (|| -> Result<Matrix<f32>> {
                        let ex = Executor::new(&dir)?; // own PJRT client
                        let mut r = Matrix::zeros(n, n);
                        for c in share {
                            r = ops::tsqr_step(&ex, &r, c)?;
                        }
                        Ok(r)
                    })();
                    let _ = tx.send(res.map(|r| (w, r)));
                });
            }
        });
        drop(tx);
        let mut leaves: Vec<(usize, Matrix<f32>)> = Vec::with_capacity(workers);
        for got in rx {
            leaves.push(got?);
        }
        leaves.sort_by_key(|(w, _)| *w); // deterministic reduction order
        let mut level: Vec<Matrix<f32>> = leaves.into_iter().map(|(_, r)| r).collect();

        // ---- reduction phase: binary tree of R merges --------------------
        let ex = Executor::new(&self.artifacts_dir)?;
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(ops::tsqr_merge(&ex, &a, &b)?),
                    None => next.push(a),
                }
            }
            level = next;
        }
        Ok(level.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, gram_t, matmul};

    #[test]
    fn tree_matches_sequential_gram_identity() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let chunks: Vec<Matrix<f32>> = (0..5).map(|i| Matrix::randn(c, n, 10 + i)).collect();
        let mut full = chunks[0].clone();
        for ch in &chunks[1..] {
            full = full.vstack(ch).unwrap();
        }
        let want = gram_t(&full);
        for workers in [1usize, 2, 4] {
            let runner = TsqrTreeRunner::new("artifacts", workers);
            let r = runner.run(chunks.clone()).unwrap();
            let got = matmul(&r.transpose(), &r).unwrap();
            let err = fro(&got.sub(&want).unwrap()) / fro(&want);
            assert!(err < 1e-4, "workers={workers}: {err}");
        }
    }

    #[test]
    fn empty_rejected() {
        let runner = TsqrTreeRunner::new("artifacts", 2);
        assert!(runner.run(vec![]).is_err());
    }
}
