//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the offline build has no
//! `thiserror`, and the variant set is small enough that the derive
//! buys nothing.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// Filesystem failure, with the offending path when the call site
    /// knows it (`Error::io`) — codec/checkpoint errors must name the
    /// file, not just "permission denied".
    Io { path: Option<String>, source: std::io::Error },

    #[cfg(feature = "pjrt")]
    Xla(xla::Error),

    Format { path: String, msg: String },

    Json(String),

    Shape(String),

    UnknownArtifact(String),

    Numerical(String),

    Config(String),

    Msg(String),

    /// A message layered over an underlying error (`Error::context`),
    /// so multi-stage failures — e.g. both the capture and accumulate
    /// stages of the execution engine dying — surface every cause.
    Context { msg: String, source: Box<Error> },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path: Some(p), source } => write!(f, "io error at {p}: {source}"),
            Error::Io { path: None, source } => write!(f, "io error: {source}"),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Format { path, msg } => write!(f, "format error in {path}: {msg}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::UnknownArtifact(a) => write!(f, "artifact `{a}` not found in manifest"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Msg(m) => write!(f, "{m}"),
            Error::Context { msg, source } => write!(f, "{msg}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Context { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io { path: None, source: e }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    /// An io error carrying the path it happened at.
    pub fn io(path: impl AsRef<std::path::Path>, e: std::io::Error) -> Self {
        Error::Io { path: Some(path.as_ref().display().to_string()), source: e }
    }
    /// Wrap with a higher-level message, keeping `self` as the source.
    pub fn context(self, msg: impl Into<String>) -> Self {
        Error::Context { msg: msg.into(), source: Box::new(self) }
    }
    /// True iff this error (or the root of its `Context` chain) is a
    /// numerical failure — the computation itself collapsed (e.g. a
    /// non-positive Cholesky pivot in a Gram inversion), as opposed to
    /// a setup/IO/config problem.  Drivers that *report* collapses
    /// (Table 4) use this to tell the two apart.
    pub fn is_numerical(&self) -> bool {
        match self {
            Error::Numerical(_) => true,
            Error::Context { source, .. } => source.is_numerical(),
            _ => false,
        }
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Msg(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error as _;

    #[test]
    fn context_chains_display_and_source() {
        let inner = Error::Numerical("collapse".into());
        let outer = inner.context("accumulate stage failed");
        assert_eq!(
            outer.to_string(),
            "accumulate stage failed: numerical failure: collapse"
        );
        let src = outer.source().expect("context keeps its source");
        assert_eq!(src.to_string(), "numerical failure: collapse");
    }

    #[test]
    fn io_errors_carry_the_offending_path() {
        let e = Error::io("/tmp/x.state", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("/tmp/x.state"), "{e}");
        assert!(e.source().is_some());
        let bare: Error = std::io::Error::new(std::io::ErrorKind::PermissionDenied, "oops").into();
        assert_eq!(bare.to_string(), "io error: oops");
    }

    #[test]
    fn numerical_detection_unwraps_context() {
        assert!(Error::Numerical("x".into()).is_numerical());
        assert!(Error::Numerical("x".into()).context("stage").is_numerical());
        assert!(!Error::Config("x".into()).is_numerical());
        assert!(!Error::Config("x".into()).context("stage").is_numerical());
    }
}
