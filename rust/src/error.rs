//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("format error in {path}: {msg}")]
    Format { path: String, msg: String },

    #[error("json error: {0}")]
    Json(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("artifact `{0}` not found in manifest")]
    UnknownArtifact(String),

    #[error("numerical failure: {0}")]
    Numerical(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("{0}")]
    Msg(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Msg(s)
    }
}
