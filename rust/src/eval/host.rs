//! Artifact-free evaluation: perplexity and probe-task scoring through
//! the synthetic [`HostModel`] forward instead of the `loss_<cfg>` /
//! `fwd_logits_<cfg>` artifacts.  Same windowing, same scoring rule
//! (argmax over the candidate logits at the query position), so tables
//! produced on either route have identical semantics.

use crate::calib::dataset::TaskBank;
use crate::error::{Error, Result};
use crate::eval::TaskScores;
use crate::model::synthetic::{nll, HostModel};
use crate::model::weights::ModelWeights;
use crate::runtime::executor::Value;
use crate::runtime::manifest::ModelSpec;

/// exp(mean NLL) over `n_batches` deterministic windows of a split —
/// the host twin of [`crate::eval::perplexity`].
pub fn perplexity_host(
    spec: &ModelSpec,
    weights: &ModelWeights,
    split_tokens: &[i32],
    n_batches: usize,
) -> Result<f64> {
    let model = HostModel::new(spec, weights)?;
    let table = model.logits_table();
    let win = spec.seq_len + 1;
    let need = spec.batch * win;
    if split_tokens.len() < need {
        return Err(Error::Config(format!(
            "split too small for perplexity: {} < {need}",
            split_tokens.len()
        )));
    }
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..n_batches.max(1) {
        let start = (b * need) % (split_tokens.len() - need + 1);
        let toks = &split_tokens[start..start + need];
        for row in 0..spec.batch {
            for t in 0..spec.seq_len {
                let cur = toks[row * win + t] as usize % spec.vocab;
                let next = toks[row * win + t + 1] as usize % spec.vocab;
                total += nll(&table[cur], next);
                count += 1;
            }
        }
    }
    Ok((total / count as f64).exp())
}

/// The teacher-forcing (current → next) token pairs of a pool of
/// (batch × seq_len+1) batches, in stream order.  One walk shared by
/// the pool-loss evaluator below and the host trainer's gradient
/// batches ([`crate::finetune::grad::GradModel`]), so the loss both
/// report is over literally the same pair multiset.
pub fn pool_pairs(spec: &ModelSpec, pool: &[Value]) -> Result<Vec<(usize, usize)>> {
    let mut pairs = Vec::new();
    for v in pool {
        let Value::I32(dims, data) = v else {
            return Err(Error::shape("token pool must be int batches".into()));
        };
        if dims.len() != 2 || dims[1] < 2 {
            return Err(Error::shape(format!("token batch dims {dims:?}")));
        }
        let win = dims[1];
        for row in 0..dims[0] {
            for t in 0..win - 1 {
                let cur = data[row * win + t] as usize % spec.vocab;
                let next = data[row * win + t + 1] as usize % spec.vocab;
                pairs.push((cur, next));
            }
        }
    }
    Ok(pairs)
}

/// Mean NLL over a pool of (batch × seq_len+1) token batches — the host
/// twin of the fine-tune loss (used by the Table 4 host route to score
/// adapter initializations).
pub fn pool_nll_host(
    spec: &ModelSpec,
    weights: &ModelWeights,
    pool: &[Value],
) -> Result<f64> {
    let model = HostModel::new(spec, weights)?;
    let table = model.logits_table();
    let pairs = pool_pairs(spec, pool)?;
    let total: f64 = pairs.iter().map(|&(cur, next)| nll(&table[cur], next)).sum();
    Ok(total / pairs.len().max(1) as f64)
}

/// Probe-task accuracy through the host forward — the host twin of
/// [`crate::eval::eval_tasks`].  Scoring looks only at the query (last)
/// token of each context, which for the per-token synthetic model is
/// exactly the information the device path's last-position logits carry.
pub fn eval_tasks_host(
    spec: &ModelSpec,
    weights: &ModelWeights,
    bank: &TaskBank,
    limit: Option<usize>,
) -> Result<TaskScores> {
    let model = HostModel::new(spec, weights)?;
    let table = model.logits_table();
    let n = limit.unwrap_or(bank.n).min(bank.n);
    let n_tasks = bank.task_names.len();
    let mut correct = vec![0usize; n_tasks];
    let mut total = vec![0usize; n_tasks];
    for r in 0..n {
        let query = *bank.context(r).last().unwrap() as usize % spec.vocab;
        let logits = &table[query];
        let choices = bank.choice_row(r);
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (ci, &c) in choices.iter().enumerate() {
            let v = logits[c as usize % spec.vocab];
            if v > best_v {
                best_v = v;
                best = ci;
            }
        }
        let tid = bank.task_ids[r] as usize;
        total[tid] += 1;
        if best == bank.labels[r] as usize {
            correct[tid] += 1;
        }
    }
    let mut accuracy = Vec::with_capacity(n_tasks);
    let mut stderr = Vec::with_capacity(n_tasks);
    for i in 0..n_tasks {
        let cnt = total[i].max(1);
        let acc = correct[i] as f64 / cnt as f64;
        accuracy.push(acc * 100.0);
        stderr.push((acc * (1.0 - acc) / cnt as f64).sqrt() * 100.0);
    }
    Ok(TaskScores { names: bank.task_names.clone(), accuracy, stderr, counts: total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::Corpus;
    use crate::model::synthetic::{
        synthetic_manifest, synthetic_weights, BANK_ROWS, DEFAULT_SEED, SPLIT_LEN, VOCAB,
    };
    use crate::tensor::Matrix;

    fn world() -> (ModelSpec, ModelWeights, Corpus) {
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, DEFAULT_SEED);
        let corpus = Corpus::synthetic(VOCAB, SPLIT_LEN, DEFAULT_SEED);
        (spec, w, corpus)
    }

    #[test]
    fn base_model_beats_uniform_ppl_and_chance_accuracy() {
        let (spec, w, corpus) = world();
        let ppl = perplexity_host(&spec, &w, corpus.split("val").unwrap(), 4).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
        // the bigram head must beat the uniform baseline (ppl = vocab)
        assert!(ppl < spec.vocab as f64 * 0.8, "ppl {ppl} vs uniform {}", spec.vocab);
        let bank = TaskBank::synthetic(
            VOCAB,
            spec.seq_len,
            "base",
            &synthetic_manifest().task_names,
            BANK_ROWS,
            DEFAULT_SEED,
        )
        .unwrap();
        let scores = eval_tasks_host(&spec, &w, &bank, None).unwrap();
        let avg = scores.average();
        // 4-way multiple choice: chance = 25 %
        assert!(avg > 35.0, "avg accuracy {avg}");
    }

    #[test]
    fn corrupting_weights_hurts_host_ppl() {
        let (spec, w, corpus) = world();
        let val = corpus.split("val").unwrap();
        let base = perplexity_host(&spec, &w, val, 2).unwrap();
        let mut bad = w.clone();
        // scramble the unembedding: the bigram head is the signal
        let u = bad.matrix("unembed").unwrap();
        bad.set_matrix("unembed", &Matrix::randn(u.rows, u.cols, 99)).unwrap();
        let worse = perplexity_host(&spec, &bad, val, 2).unwrap();
        assert!(worse > base, "{worse} vs {base}");
    }

    #[test]
    fn ft_bank_shows_the_adaptation_gap() {
        let (spec, w, _corpus) = world();
        let names = synthetic_manifest().task_names;
        let base = TaskBank::synthetic(VOCAB, spec.seq_len, "base", &names, BANK_ROWS, 3).unwrap();
        let ft = TaskBank::synthetic(VOCAB, spec.seq_len, "ft", &names, BANK_ROWS, 3).unwrap();
        let on_base = eval_tasks_host(&spec, &w, &base, None).unwrap().average();
        let on_ft = eval_tasks_host(&spec, &w, &ft, None).unwrap().average();
        assert!(
            on_base > on_ft + 5.0,
            "no adaptation gap: base {on_base} vs ft {on_ft}"
        );
    }

    #[test]
    fn pool_nll_matches_chain_quality() {
        let (spec, w, corpus) = world();
        let pool = corpus
            .train_batches("train", spec.batch, spec.seq_len, 3, 5)
            .unwrap();
        let pairs = pool_pairs(&spec, &pool).unwrap();
        assert_eq!(pairs.len(), 3 * spec.batch * spec.seq_len);
        assert!(pairs.iter().all(|&(c, n)| c < spec.vocab && n < spec.vocab));
        let base_nll = pool_nll_host(&spec, &w, &pool).unwrap();
        assert!(base_nll.is_finite() && base_nll > 0.0);
        // better than uniform guessing
        assert!(base_nll < (spec.vocab as f64).ln());
    }
}
