//! Evaluation harness (S12): perplexity + probe-task accuracy, with the
//! stderr formatting the paper's tables use.  Two backends share the
//! scoring semantics: the artifact route (`perplexity` / `eval_tasks`
//! through the `loss` / `fwd_logits` executables) and the artifact-free
//! [`host`] route (the synthetic model's pure-Rust forward).

pub mod host;
pub mod perplexity;
pub mod tasks;

pub use host::{eval_tasks_host, perplexity_host, pool_nll_host, pool_pairs};
pub use perplexity::perplexity;
pub use tasks::{eval_tasks, TaskScores};
