//! Evaluation harness (S12): perplexity + probe-task accuracy, with the
//! stderr formatting the paper's tables use.

pub mod perplexity;
pub mod tasks;

pub use perplexity::perplexity;
pub use tasks::{eval_tasks, TaskScores};
