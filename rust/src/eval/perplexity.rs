//! Held-out perplexity through the `loss_<cfg>` artifact.

use crate::error::Result;
use crate::model::weights::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::runtime::manifest::ModelSpec;

/// exp(mean NLL) over `n_batches` deterministic windows of a split.
pub fn perplexity(
    ex: &Executor,
    spec: &ModelSpec,
    weights: &ModelWeights,
    split_tokens: &[i32],
    n_batches: usize,
) -> Result<f64> {
    let artifact = format!("loss_{}", spec.name);
    let win = spec.seq_len + 1;
    let need = spec.batch * win;
    let wvals = weights.to_values(spec)?;
    let mut total = 0.0f64;
    for b in 0..n_batches {
        let start = (b * need) % (split_tokens.len().saturating_sub(need) + 1);
        let toks = Value::I32(vec![spec.batch, win], split_tokens[start..start + need].to_vec());
        let mut inputs = vec![toks];
        inputs.extend(wvals.iter().cloned());
        let out = ex.run(&artifact, &inputs)?;
        total += out[0].f32s()?[0] as f64;
    }
    Ok((total / n_batches as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::Corpus;

    #[test]
    fn trained_model_beats_uniform_and_matches_buildtime() {
        if !crate::runtime::require_artifacts("perplexity::trained_model_matches_buildtime") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let ppl = perplexity(&ex, &spec, &w, corpus.split("val").unwrap(), 4).unwrap();
        assert!(ppl < spec.vocab as f64 / 4.0, "ppl {ppl}");
        assert!(ppl > 1.0);
        // within 40% of the jax-side build-time measurement (different
        // batches, same distribution)
        let build = w.build_val_ppl as f64;
        assert!((ppl / build).ln().abs() < 0.4, "ppl {ppl} vs build {build}");
    }

    #[test]
    fn corrupting_weights_hurts_ppl() {
        if !crate::runtime::require_artifacts("perplexity::corrupting_weights_hurts_ppl") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let base = perplexity(&ex, &spec, &w, corpus.split("val").unwrap(), 2).unwrap();
        let mut bad = w.clone();
        let q = bad.matrix("l0.wq").unwrap();
        bad.set_matrix("l0.wq", &crate::tensor::Matrix::randn(q.rows, q.cols, 99)).unwrap();
        let worse = perplexity(&ex, &spec, &bad, corpus.split("val").unwrap(), 2).unwrap();
        assert!(worse > base, "{worse} vs {base}");
    }
}
