//! Probe-task scoring: multiple-choice accuracy from the fwd logits.
//!
//! The proxy analogue of the paper's commonsense suite (boolQ … OBQA):
//! a context ending in an (s, p) fact query, scored by argmax over the
//! four candidate-object logits at the last position — the same scoring
//! rule lm-eval-harness uses for multiple choice.

use crate::calib::dataset::TaskBank;
use crate::error::Result;
use crate::model::weights::ModelWeights;
use crate::runtime::executor::{Executor, Value};
use crate::runtime::manifest::ModelSpec;

/// Per-task accuracy ± stderr plus the macro average.
#[derive(Debug, Clone)]
pub struct TaskScores {
    pub names: Vec<String>,
    pub accuracy: Vec<f64>,
    pub stderr: Vec<f64>,
    pub counts: Vec<usize>,
}

impl TaskScores {
    /// Macro average over the tasks that were actually evaluated
    /// (a row `limit` may leave later task groups empty).
    pub fn average(&self) -> f64 {
        let evaluated: Vec<f64> = self
            .accuracy
            .iter()
            .zip(&self.counts)
            .filter(|(_, &c)| c > 0)
            .map(|(a, _)| *a)
            .collect();
        if evaluated.is_empty() {
            return 0.0;
        }
        evaluated.iter().sum::<f64>() / evaluated.len() as f64
    }
}

/// Evaluate a task bank.  Rows are packed into (batch)-sized fwd calls;
/// the trailing partial batch is padded with row 0 and ignored.
pub fn eval_tasks(
    ex: &Executor,
    spec: &ModelSpec,
    weights: &ModelWeights,
    bank: &TaskBank,
    limit: Option<usize>,
) -> Result<TaskScores> {
    let artifact = format!("fwd_logits_{}", spec.name);
    let wvals = weights.to_values(spec)?;
    let n = limit.unwrap_or(bank.n).min(bank.n);
    let n_tasks = bank.task_names.len();
    let mut correct = vec![0usize; n_tasks];
    let mut total = vec![0usize; n_tasks];

    let bsz = spec.batch;
    let t = spec.seq_len;
    let mut row = 0usize;
    while row < n {
        let take = bsz.min(n - row);
        let mut toks = Vec::with_capacity(bsz * t);
        for b in 0..bsz {
            let r = if b < take { row + b } else { 0 };
            toks.extend_from_slice(bank.context(r));
        }
        let mut inputs = vec![Value::I32(vec![bsz, t], toks)];
        inputs.extend(wvals.iter().cloned());
        let out = ex.run(&artifact, &inputs)?;
        let logits = out[0].f32s()?;
        let vocab = spec.vocab;
        for b in 0..take {
            let r = row + b;
            // logits at the LAST position predict the token after (s, p)
            let base = (b * t + (t - 1)) * vocab;
            let choices = bank.choice_row(r);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (ci, &c) in choices.iter().enumerate() {
                let v = logits[base + c as usize];
                if v > best_v {
                    best_v = v;
                    best = ci;
                }
            }
            let tid = bank.task_ids[r] as usize;
            total[tid] += 1;
            if best == bank.labels[r] as usize {
                correct[tid] += 1;
            }
        }
        row += take;
    }

    let mut accuracy = Vec::with_capacity(n_tasks);
    let mut stderr = Vec::with_capacity(n_tasks);
    for i in 0..n_tasks {
        let cnt = total[i].max(1);
        let acc = correct[i] as f64 / cnt as f64;
        accuracy.push(acc * 100.0);
        stderr.push((acc * (1.0 - acc) / cnt as f64).sqrt() * 100.0);
    }
    Ok(TaskScores { names: bank.task_names.clone(), accuracy, stderr, counts: total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::dataset::TaskBank;

    #[test]
    fn trained_model_beats_chance_on_base_tasks() {
        if !crate::runtime::require_artifacts("tasks::trained_model_beats_chance_on_base_tasks") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let bank = TaskBank::load("artifacts", "base", &ex.manifest.task_names).unwrap();
        let scores = eval_tasks(&ex, &spec, &w, &bank, None).unwrap();
        // 4-way multiple choice: chance = 25 %.  The trained model must
        // clearly beat it on average (it has seen the facts in training).
        let avg = scores.average();
        assert!(avg > 35.0, "avg accuracy {avg}");
        assert_eq!(scores.names.len(), 8);
        assert!(scores.counts.iter().sum::<usize>() >= 100);
    }

    #[test]
    fn random_model_is_at_chance() {
        if !crate::runtime::require_artifacts("tasks::random_model_is_at_chance") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let mut w = ModelWeights::load("artifacts", &spec).unwrap();
        // scramble every projection
        for name in spec.compressible.clone() {
            let m = w.matrix(&name).unwrap();
            w.set_matrix(&name, &crate::tensor::Matrix::randn(m.rows, m.cols, 7)).unwrap();
        }
        let bank = TaskBank::load("artifacts", "base", &ex.manifest.task_names).unwrap();
        let scores = eval_tasks(&ex, &spec, &w, &bank, Some(96)).unwrap();
        let avg = scores.average();
        assert!(avg < 45.0, "scrambled model too good: {avg}");
    }
}
