//! Manual fp64 backprop for the synthetic per-token forward — the host
//! twin of the `ft_step` artifact's gradient graph.
//!
//! [`GradModel`] mirrors [`crate::model::synthetic::HostModel`]'s
//! architecture (embedding → gated per-token attention block → SiLU MLP
//! → unembedding, RMS-norms throughout) with every compressible
//! projection in *adapted* form: `W_eff = W_res + A·B` with the base
//! `W_res` frozen and only the rank-r factors (A, B) trainable — exactly
//! the Table 4 parameterization.  The backward pass is hand-derived and
//! never materializes `∂L/∂W_eff` (an out×in matrix per projection per
//! token); it accumulates the factor gradients directly:
//!
//! ```text
//!   ∂L/∂A = dy · (B·x)ᵀ        (out × r)
//!   ∂L/∂B = (Aᵀ·dy) · xᵀ      (r × in)
//! ```
//!
//! where `x` is the projection input and `dy` the output cotangent —
//! O((out+in)·r) per token instead of O(out·in).
//!
//! Everything runs at fp64: the finite-difference checker
//! (`tests/grad_check.rs`) verifies every parameter group against
//! central differences, which is only meaningful above f32 rounding.
//!
//! **Determinism.** The per-token forward means a batch's loss depends
//! only on its (current, next) token-pair multiset.  Gradient
//! accumulation fans the *distinct* current tokens across
//! `util::threads` workers and reduces the per-token contributions in
//! ascending token order — the same canonical fixed-order reduction the
//! execution engine uses for calibration batches — so losses, gradients,
//! and therefore whole training runs are bitwise-independent of the
//! worker count.

use super::init::AdapterSet;
use crate::error::{Error, Result};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::Matrix;
use crate::util::threads::parallel_map;

/// Projection slots of one layer, in `spec.compressible` family order.
const SLOTS: [&str; 6] = ["wq", "wk", "wv", "wo", "w_up", "w_down"];

/// One adapted projection: frozen residual + trainable rank-r factors.
struct ProjParam {
    w_res: Matrix<f64>,
    a: Matrix<f64>,
    b: Matrix<f64>,
}

/// Gradients of one projection's adapter factors, aligned with
/// [`GradModel::proj_names`]: `(∂L/∂A, ∂L/∂B)`.
pub type AdapterGrads = Vec<(Matrix<f64>, Matrix<f64>)>;

/// The differentiable fp64 model: frozen base + trainable adapters.
pub struct GradModel {
    vocab: usize,
    d_model: usize,
    embed: Matrix<f64>,
    unembed: Matrix<f64>,
    lnf: Vec<f64>,
    ln1: Vec<Vec<f64>>,
    ln2: Vec<Vec<f64>>,
    /// `spec.compressible`, the canonical projection order.
    projs: Vec<String>,
    /// Parameters aligned with `projs`.
    params: Vec<ProjParam>,
    /// `idx[layer][slot]` → flat index into `projs`/`params`.
    idx: Vec<[usize; 6]>,
}

fn vec1_f64(w: &crate::model::ModelWeights, name: &str) -> Result<Vec<f64>> {
    let (dims, data) = w
        .tensors
        .get(name)
        .ok_or_else(|| Error::Config(format!("no parameter `{name}`")))?;
    if dims.len() != 1 {
        return Err(Error::shape(format!("{name} is {dims:?}, not 1-D")));
    }
    Ok(data.iter().map(|&x| x as f64).collect())
}

fn matvec(w: &Matrix<f64>, x: &[f64]) -> Vec<f64> {
    (0..w.rows)
        .map(|i| w.row(i).iter().zip(x).map(|(a, b)| a * b).sum::<f64>())
        .collect()
}

/// `wᵀ·y` without materializing the transpose.
fn matvec_t(w: &Matrix<f64>, y: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; w.cols];
    for (i, yi) in y.iter().enumerate() {
        for (o, wij) in out.iter_mut().zip(w.row(i)) {
            *o += wij * yi;
        }
    }
    out
}

/// `dst += dy·xᵀ` (rank-1 accumulate).
fn outer_acc(dst: &mut Matrix<f64>, dy: &[f64], x: &[f64]) {
    debug_assert_eq!((dst.rows, dst.cols), (dy.len(), x.len()));
    for (i, di) in dy.iter().enumerate() {
        for (d, xj) in dst.row_mut(i).iter_mut().zip(x) {
            *d += di * xj;
        }
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

fn silu(x: f64) -> f64 {
    x * sigmoid(x)
}

/// d silu / dx = σ(x)·(1 + x·(1 − σ(x))).
fn silu_d(x: f64) -> f64 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// The forward's normalization (same semantics as the f32 host model:
/// mean-square in f64, ε = 1e-6).
fn rmsnorm(x: &[f64], gain: &[f64]) -> Vec<f64> {
    let ms = x.iter().map(|v| v * v).sum::<f64>() / x.len().max(1) as f64;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(gain).map(|(v, g)| v * inv * g).collect()
}

/// Cotangent of [`rmsnorm`]: with `inv = (ms+ε)^{-1/2}` and
/// `s = Σⱼ dyⱼ gⱼ xⱼ`,  `dxᵢ = inv·(gᵢ·dyᵢ − xᵢ·inv²·s/n)`.
fn rmsnorm_bwd(x: &[f64], gain: &[f64], dy: &[f64]) -> Vec<f64> {
    let n = x.len().max(1) as f64;
    let ms = x.iter().map(|v| v * v).sum::<f64>() / n;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    let s: f64 = dy.iter().zip(gain).zip(x).map(|((d, g), v)| d * g * v).sum();
    x.iter()
        .zip(gain)
        .zip(dy)
        .map(|((v, g), d)| inv * (g * d - v * inv * inv * s / n))
        .collect()
}

/// Forward intermediates of one layer, recorded for the backward pass.
struct LayerTape {
    h_in: Vec<f64>,
    a: Vec<f64>,
    q: Vec<f64>,
    k: Vec<f64>,
    v: Vec<f64>,
    gate: f64,
    o_in: Vec<f64>,
    h_mid: Vec<f64>,
    m: Vec<f64>,
    upre: Vec<f64>,
    u: Vec<f64>,
}

impl GradModel {
    /// Build the fp64 model from an adapter set: `set.frozen` supplies
    /// the residual base (embedding, unembedding, norms, `W_res` per
    /// projection), `set.adapters` the trainable factors.
    pub fn new(spec: &ModelSpec, set: &AdapterSet) -> Result<GradModel> {
        let w = &set.frozen;
        let mut projs = Vec::with_capacity(spec.compressible.len());
        let mut params = Vec::with_capacity(spec.compressible.len());
        for proj in &spec.compressible {
            let (a, b) = set
                .adapters
                .get(proj)
                .ok_or_else(|| Error::Config(format!("no adapter for {proj}")))?;
            let w_res = w.matrix(proj)?.cast::<f64>();
            if a.rows != w_res.rows || b.cols != w_res.cols || a.cols != b.rows {
                return Err(Error::shape(format!(
                    "{proj}: adapter ({}x{})·({}x{}) does not match W {}x{}",
                    a.rows, a.cols, b.rows, b.cols, w_res.rows, w_res.cols
                )));
            }
            projs.push(proj.clone());
            params.push(ProjParam { w_res, a: a.cast(), b: b.cast() });
        }
        let mut idx = Vec::with_capacity(spec.n_layers);
        let mut ln1 = Vec::with_capacity(spec.n_layers);
        let mut ln2 = Vec::with_capacity(spec.n_layers);
        for l in 0..spec.n_layers {
            let mut row = [0usize; 6];
            for (s, slot) in SLOTS.iter().enumerate() {
                let name = format!("l{l}.{slot}");
                row[s] = projs
                    .iter()
                    .position(|p| *p == name)
                    .ok_or_else(|| Error::Config(format!("projection `{name}` missing")))?;
            }
            idx.push(row);
            ln1.push(vec1_f64(w, &format!("l{l}.ln1"))?);
            ln2.push(vec1_f64(w, &format!("l{l}.ln2"))?);
        }
        Ok(GradModel {
            vocab: spec.vocab,
            d_model: spec.d_model,
            embed: w.matrix("embed")?.cast(),
            unembed: w.matrix("unembed")?.cast(),
            lnf: vec1_f64(w, "lnf")?,
            ln1,
            ln2,
            projs,
            params,
            idx,
        })
    }

    pub fn n_projs(&self) -> usize {
        self.params.len()
    }

    pub fn proj_names(&self) -> &[String] {
        &self.projs
    }

    /// The trainable factors of projection `i` (mutable — the optimizer
    /// updates these in place between gradient evaluations).
    pub fn adapter_at_mut(&mut self, i: usize) -> (&mut Matrix<f64>, &mut Matrix<f64>) {
        let p = &mut self.params[i];
        (&mut p.a, &mut p.b)
    }

    /// Factor pair by projection name (the gradient checker's handle).
    pub fn adapter_mut(&mut self, proj: &str) -> Result<(&mut Matrix<f64>, &mut Matrix<f64>)> {
        let i = self
            .projs
            .iter()
            .position(|p| p == proj)
            .ok_or_else(|| Error::Config(format!("no adapter for {proj}")))?;
        Ok(self.adapter_at_mut(i))
    }

    /// Write the (trained) factors back into `set.adapters` as f32.
    /// `set.frozen` is untouched — the adapted model stays
    /// `W_res + A·B`.
    pub fn write_back(&self, set: &mut AdapterSet) {
        for (proj, p) in self.projs.iter().zip(&self.params) {
            set.adapters.insert(proj.clone(), (p.a.cast(), p.b.cast()));
        }
    }

    /// Effective projection weights `W_res + A·B`, aligned with
    /// `projs`.  Recomputed per loss/gradient call so factor mutations
    /// (optimizer steps, finite-difference probes) always take effect.
    fn effective(&self) -> Result<Vec<Matrix<f64>>> {
        self.params
            .iter()
            .map(|p| p.w_res.add(&crate::tensor::ops::matmul(&p.a, &p.b)?))
            .collect()
    }

    /// One per-token forward, recording the tape.  Returns the layer
    /// tapes, the final hidden state, and the logits.
    fn forward_token(
        &self,
        effs: &[Matrix<f64>],
        token: usize,
    ) -> (Vec<LayerTape>, Vec<f64>, Vec<f64>) {
        let sqrt_d = (self.d_model as f64).sqrt();
        let mut h: Vec<f64> = self.embed.row(token % self.vocab).to_vec();
        let mut tapes = Vec::with_capacity(self.idx.len());
        for (l, slots) in self.idx.iter().enumerate() {
            let h_in = h.clone();
            let a = rmsnorm(&h_in, &self.ln1[l]);
            let q = matvec(&effs[slots[0]], &a);
            let k = matvec(&effs[slots[1]], &a);
            let v = matvec(&effs[slots[2]], &a);
            let qk: f64 = q.iter().zip(&k).map(|(x, y)| x * y).sum();
            let gate = sigmoid(qk / sqrt_d);
            let o_in: Vec<f64> = v.iter().map(|x| x * gate).collect();
            let o = matvec(&effs[slots[3]], &o_in);
            let h_mid: Vec<f64> = h_in.iter().zip(&o).map(|(x, y)| x + y).collect();
            let m = rmsnorm(&h_mid, &self.ln2[l]);
            let upre = matvec(&effs[slots[4]], &m);
            let u: Vec<f64> = upre.iter().map(|&x| silu(x)).collect();
            let down = matvec(&effs[slots[5]], &u);
            h = h_mid.iter().zip(&down).map(|(x, y)| x + y).collect();
            tapes.push(LayerTape { h_in, a, q, k, v, gate, o_in, h_mid, m, upre, u });
        }
        let hf = rmsnorm(&h, &self.lnf);
        let logits = matvec(&self.unembed, &hf);
        (tapes, h, logits)
    }

    /// Backward through one token's tape, accumulating adapter-factor
    /// gradients into `grads` (aligned with `projs`).
    fn backward_token(
        &self,
        effs: &[Matrix<f64>],
        tapes: &[LayerTape],
        h_final: &[f64],
        dlogits: &[f64],
        grads: &mut [(Matrix<f64>, Matrix<f64>)],
    ) {
        let sqrt_d = (self.d_model as f64).sqrt();
        let accum = |grads: &mut [(Matrix<f64>, Matrix<f64>)], pi: usize, x: &[f64], dy: &[f64]| {
            let p = &self.params[pi];
            let bx = matvec(&p.b, x);
            outer_acc(&mut grads[pi].0, dy, &bx);
            let aty = matvec_t(&p.a, dy);
            outer_acc(&mut grads[pi].1, &aty, x);
        };

        let dhf = matvec_t(&self.unembed, dlogits);
        let mut dh = rmsnorm_bwd(h_final, &self.lnf, &dhf);
        for (l, slots) in self.idx.iter().enumerate().rev() {
            let t = &tapes[l];
            // --- MLP half: h_out = h_mid + W_down·silu(W_up·m) -------------
            let ddown = dh;
            accum(grads, slots[5], &t.u, &ddown);
            let du = matvec_t(&effs[slots[5]], &ddown);
            let dupre: Vec<f64> =
                du.iter().zip(&t.upre).map(|(d, &x)| d * silu_d(x)).collect();
            accum(grads, slots[4], &t.m, &dupre);
            let dm = matvec_t(&effs[slots[4]], &dupre);
            let dh_mid_norm = rmsnorm_bwd(&t.h_mid, &self.ln2[l], &dm);
            // residual: dL/dh_mid = dL/dh_out + (through ln2)
            let dh_mid: Vec<f64> =
                ddown.iter().zip(&dh_mid_norm).map(|(x, y)| x + y).collect();
            // --- attention half: h_mid = h_in + Wo·(gate·Wv·a) -------------
            let do_ = &dh_mid;
            accum(grads, slots[3], &t.o_in, do_);
            let do_in = matvec_t(&effs[slots[3]], do_);
            let dv: Vec<f64> = do_in.iter().map(|d| d * t.gate).collect();
            let dgate: f64 = do_in.iter().zip(&t.v).map(|(d, v)| d * v).sum();
            let dqk = dgate * t.gate * (1.0 - t.gate) / sqrt_d;
            let dq: Vec<f64> = t.k.iter().map(|k| dqk * k).collect();
            let dk: Vec<f64> = t.q.iter().map(|q| dqk * q).collect();
            accum(grads, slots[0], &t.a, &dq);
            accum(grads, slots[1], &t.a, &dk);
            accum(grads, slots[2], &t.a, &dv);
            let mut da = matvec_t(&effs[slots[0]], &dq);
            for (d, x) in da.iter_mut().zip(matvec_t(&effs[slots[1]], &dk)) {
                *d += x;
            }
            for (d, x) in da.iter_mut().zip(matvec_t(&effs[slots[2]], &dv)) {
                *d += x;
            }
            let dh_in_norm = rmsnorm_bwd(&t.h_in, &self.ln1[l], &da);
            dh = dh_mid.iter().zip(&dh_in_norm).map(|(x, y)| x + y).collect();
        }
        // dh now holds ∂L/∂embed-row — the embedding is frozen, so it is
        // dropped here.
    }

    /// Group (cur → next) pairs by current token: sorted distinct
    /// tokens, each with a vocab-length target-count vector.  The sort
    /// order is the canonical reduction order.
    fn group_pairs(&self, pairs: &[(usize, usize)]) -> Vec<(usize, Vec<f64>)> {
        let mut by_tok: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
        for &(cur, next) in pairs {
            by_tok.entry(cur % self.vocab).or_insert_with(|| vec![0.0; self.vocab])
                [next % self.vocab] += 1.0;
        }
        by_tok.into_iter().collect()
    }

    /// Mean cross-entropy of the adapted model over teacher-forcing
    /// pairs (fp64 end to end).
    pub fn loss(&self, pairs: &[(usize, usize)]) -> Result<f64> {
        Ok(self.loss_and_grads_inner(pairs, 1, false)?.0)
    }

    /// Mean cross-entropy and adapter-factor gradients over `pairs`,
    /// fanned across up to `workers` threads.  Bitwise-independent of
    /// `workers`: per-token contributions are reduced in ascending
    /// token order regardless of which thread produced them.
    pub fn loss_and_grads(
        &self,
        pairs: &[(usize, usize)],
        workers: usize,
    ) -> Result<(f64, AdapterGrads)> {
        let (loss, grads) = self.loss_and_grads_inner(pairs, workers, true)?;
        Ok((loss, grads.expect("gradients requested")))
    }

    fn loss_and_grads_inner(
        &self,
        pairs: &[(usize, usize)],
        workers: usize,
        want_grads: bool,
    ) -> Result<(f64, Option<AdapterGrads>)> {
        if pairs.is_empty() {
            return Err(Error::Config("loss needs ≥ 1 token pair".into()));
        }
        let effs = self.effective()?;
        let groups = self.group_pairs(pairs);
        let zero_grads = || -> AdapterGrads {
            self.params
                .iter()
                .map(|p| {
                    (
                        Matrix::zeros(p.a.rows, p.a.cols),
                        Matrix::zeros(p.b.rows, p.b.cols),
                    )
                })
                .collect()
        };

        // One forward (+ backward) per distinct current token, processed
        // in fixed-size chunks of the sorted group list with ONE gradient
        // accumulator per chunk (backward_token accumulates in place, so
        // per-token zero-initialized sets would be pure allocation
        // churn).  Chunk boundaries are a constant of the input — never
        // of `workers` — so the reduction stays bitwise-independent of
        // the worker count.
        const CHUNK: usize = 8;
        let n_chunks = (groups.len() + CHUNK - 1) / CHUNK;
        let per_chunk = parallel_map(n_chunks, workers, |ci| {
            let mut loss_c = 0.0;
            let mut g_c = want_grads.then(&zero_grads);
            for (token, counts) in &groups[ci * CHUNK..((ci + 1) * CHUNK).min(groups.len())] {
                let (tapes, h_final, logits) = self.forward_token(&effs, *token);
                let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = mx + logits.iter().map(|&x| (x - mx).exp()).sum::<f64>().ln();
                let ct: f64 = counts.iter().sum();
                loss_c += ct * lse;
                for (c, l) in counts.iter().zip(&logits) {
                    loss_c -= c * l;
                }
                if let Some(g) = g_c.as_mut() {
                    // dL/dlogits_j = ct·softmax_j − counts_j  (1/N later)
                    let dlogits: Vec<f64> = logits
                        .iter()
                        .zip(counts)
                        .map(|(&l, c)| ct * (l - lse).exp() - c)
                        .collect();
                    self.backward_token(&effs, &tapes, &h_final, &dlogits, g);
                }
            }
            (loss_c, g_c)
        });

        // canonical reduction: ascending chunk (= token) order
        let n = pairs.len() as f64;
        let mut total = 0.0;
        let mut grads = want_grads.then(&zero_grads);
        for (loss_c, g_c) in per_chunk {
            total += loss_c;
            if let (Some(acc), Some(g)) = (grads.as_mut(), g_c) {
                for ((aa, ab), (ga, gb)) in acc.iter_mut().zip(g) {
                    for (x, y) in aa.data.iter_mut().zip(ga.data) {
                        *x += y;
                    }
                    for (x, y) in ab.data.iter_mut().zip(gb.data) {
                        *x += y;
                    }
                }
            }
        }
        if let Some(acc) = grads.as_mut() {
            for (ga, gb) in acc.iter_mut() {
                for x in ga.data.iter_mut() {
                    *x /= n;
                }
                for x in gb.data.iter_mut() {
                    *x /= n;
                }
            }
        }
        Ok((total / n, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::SyntheticActivations;
    use crate::finetune::init::{init_adapters_from_source, AdapterInit};
    use crate::model::synthetic::{synthetic_manifest, synthetic_weights};

    fn model_for(strategy: AdapterInit) -> (crate::runtime::manifest::ModelSpec, AdapterSet) {
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 5);
        let src = SyntheticActivations::new(spec.clone(), 5);
        let set = init_adapters_from_source(&spec, &w, &src, strategy, 4, 2, 30).unwrap();
        (spec, set)
    }

    fn pairs() -> Vec<(usize, usize)> {
        let corpus = crate::calib::dataset::Corpus::synthetic(64, 512, 5);
        let toks = corpus.split("ft_train").unwrap();
        toks.windows(2).take(48).map(|w| (w[0] as usize, w[1] as usize)).collect()
    }

    #[test]
    fn loss_is_finite_and_grouping_preserves_it() {
        let (spec, set) = model_for(AdapterInit::PiSSA);
        let model = GradModel::new(&spec, &set).unwrap();
        let ps = pairs();
        let loss = model.loss(&ps).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // permuting the pair list must not change the grouped loss
        let mut rev = ps.clone();
        rev.reverse();
        assert_eq!(loss, model.loss(&rev).unwrap());
    }

    #[test]
    fn lora_init_has_zero_b_gradient_and_nonzero_a_gradient() {
        // LoRA: A = 0 ⇒ ∂L/∂B = Aᵀ·dy·xᵀ = 0 exactly; ∂L/∂A = dy·(Bx)ᵀ ≠ 0
        let (spec, set) = model_for(AdapterInit::LoRA);
        let model = GradModel::new(&spec, &set).unwrap();
        let (_, grads) = model.loss_and_grads(&pairs(), 1).unwrap();
        let a_norm: f64 = grads.iter().map(|(ga, _)| crate::tensor::ops::fro(ga)).sum();
        let b_norm: f64 = grads.iter().map(|(_, gb)| crate::tensor::ops::fro(gb)).sum();
        assert_eq!(b_norm, 0.0, "B gradient must vanish at A = 0");
        assert!(a_norm > 0.0, "A gradient must not vanish");
    }

    #[test]
    fn gradients_are_bitwise_worker_invariant() {
        let (spec, set) = model_for(AdapterInit::CoalaA1);
        let model = GradModel::new(&spec, &set).unwrap();
        let ps = pairs();
        let (l1, g1) = model.loss_and_grads(&ps, 1).unwrap();
        for workers in [2usize, 4, 8] {
            let (lw, gw) = model.loss_and_grads(&ps, workers).unwrap();
            assert_eq!(l1.to_bits(), lw.to_bits(), "loss differs at {workers} workers");
            for (i, ((a1, b1), (aw, bw))) in g1.iter().zip(&gw).enumerate() {
                assert_eq!(a1.data, aw.data, "dA[{i}] differs at {workers} workers");
                assert_eq!(b1.data, bw.data, "dB[{i}] differs at {workers} workers");
            }
        }
    }

    #[test]
    fn adapter_mutation_changes_the_loss() {
        let (spec, set) = model_for(AdapterInit::PiSSA);
        let mut model = GradModel::new(&spec, &set).unwrap();
        let ps = pairs();
        let before = model.loss(&ps).unwrap();
        {
            let (a, _) = model.adapter_mut("l0.wq").unwrap();
            a.set(0, 0, a.get(0, 0) + 0.5);
        }
        let after = model.loss(&ps).unwrap();
        assert_ne!(before, after, "effective weights must be recomputed per call");
    }

    #[test]
    fn write_back_round_trips_to_f32() {
        let (spec, set0) = model_for(AdapterInit::CoalaA2);
        let mut set = set0.clone();
        let mut model = GradModel::new(&spec, &set).unwrap();
        {
            let (a, b) = model.adapter_mut("l1.wv").unwrap();
            a.set(0, 0, 7.0);
            b.set(0, 0, -3.0);
        }
        model.write_back(&mut set);
        let (a, b) = &set.adapters["l1.wv"];
        assert_eq!(a.get(0, 0), 7.0);
        assert_eq!(b.get(0, 0), -3.0);
        // untouched projections survive the f32 round trip
        let (orig_a, _) = &set0.adapters["l0.wq"];
        let (new_a, _) = &set.adapters["l0.wq"];
        assert_eq!(orig_a.data, new_a.data);
    }
}
