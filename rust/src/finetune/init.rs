//! Adapter initialization strategies (the Table 4 rows).

use crate::calib::dataset::Corpus;
use crate::coala::compressor::Route;
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

/// Initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdapterInit {
    /// ΔW = 0: B ~ N(0, 0.02), A = 0 (the LoRA convention, transposed to
    /// our A·B layout).
    LoRA,
    /// top-r plain SVD of W (α = 0).
    PiSSA,
    /// original CorDA: SVD(W·XXᵀ) with explicit Gram inversion.
    CorDA,
    /// COALA α = 1 (Alg. 1, inversion-free).
    CoalaA1,
    /// COALA α = 2 (robustified CorDA).
    CoalaA2,
}

impl AdapterInit {
    pub fn name(&self) -> &'static str {
        match self {
            AdapterInit::LoRA => "LoRA",
            AdapterInit::PiSSA => "PiSSA",
            AdapterInit::CorDA => "CorDA",
            AdapterInit::CoalaA1 => "COALA(a=1)",
            AdapterInit::CoalaA2 => "COALA(a=2)",
        }
    }

    pub fn needs_calibration(&self) -> bool {
        matches!(self, AdapterInit::CorDA | AdapterInit::CoalaA1 | AdapterInit::CoalaA2)
    }

    /// Parse a strategy name (the `--init` CLI flag): case-insensitive,
    /// accepting both the display names and the registry-style aliases.
    pub fn resolve(name: &str) -> crate::error::Result<AdapterInit> {
        match name.to_ascii_lowercase().as_str() {
            "lora" => Ok(AdapterInit::LoRA),
            "pissa" | "svd" => Ok(AdapterInit::PiSSA),
            "corda" => Ok(AdapterInit::CorDA),
            "coala1" | "alpha1" | "coala(a=1)" => Ok(AdapterInit::CoalaA1),
            "coala2" | "alpha2" | "coala(a=2)" => Ok(AdapterInit::CoalaA2),
            other => Err(Error::Config(format!(
                "unknown adapter init `{other}` (try lora|pissa|corda|coala1|coala2)"
            ))),
        }
    }

    /// The compressor-registry spec computing this init's factorization
    /// (None for LoRA, which is not a factorization of W).  Table 4 is
    /// exactly a comparison of registry methods used as adapter inits.
    pub fn method_spec(&self) -> Option<&'static str> {
        match self {
            AdapterInit::LoRA => None,
            AdapterInit::PiSSA => Some("svd"),
            AdapterInit::CorDA => Some("corda"),
            AdapterInit::CoalaA1 => Some("alpha1"),
            AdapterInit::CoalaA2 => Some("alpha2"),
        }
    }
}

/// Initialized adapters + the residual base weights.
#[derive(Debug, Clone)]
pub struct AdapterSet {
    pub rank: usize,
    /// per projection: (A, B)
    pub adapters: BTreeMap<String, (Matrix<f32>, Matrix<f32>)>,
    /// base weights with W_res = W − A·B substituted into each projection
    pub frozen: ModelWeights,
}

impl AdapterSet {
    /// The adapted model as a full weight set: `W_res + A·B` merged back
    /// into every projection.  Used by the host evaluators (the device
    /// route keeps factors separate — its artifacts take them as inputs).
    pub fn merged(&self) -> Result<ModelWeights> {
        let mut out = self.frozen.clone();
        for (proj, (a, b)) in &self.adapters {
            let delta = crate::tensor::ops::matmul(a, b)?;
            let eff = out.matrix(proj)?.add(&delta)?;
            out.set_matrix(proj, &eff)?;
        }
        Ok(out)
    }

    /// True iff every adapter factor is finite (a Gram-inversion
    /// collapse shows up here as NaN/inf factors).
    pub fn all_finite(&self) -> bool {
        self.adapters.values().all(|(a, b)| a.all_finite() && b.all_finite())
    }
}

/// Split full factors into a balanced (A√σ, √σ⁻¹B) pair at rank r —
/// the PiSSA-style scaling that keeps both factors at comparable norm
/// so Adam's per-parameter steps are well-conditioned.
fn balanced_split(
    full: &crate::coala::factorize::FullFactors<f32>,
    r: usize,
) -> (Matrix<f32>, Matrix<f32>) {
    let f = full.truncate(r);
    let mut a = f.a.clone();
    let mut b = f.b.clone();
    for k in 0..r.min(full.sigma.len()) {
        let s = full.sigma[k].max(1e-12).sqrt();
        // column k of A scaled by √σ/σ … we want A·B unchanged:
        // A col *= s, B row /= s  — but A's columns are unit (U), B's rows
        // carry σ.  Scale A by √σ_k and B by 1/√σ_k.
        for i in 0..a.rows {
            a.set(i, k, a.get(i, k) * s);
        }
        for j in 0..b.cols {
            b.set(k, j, b.get(k, j) / s);
        }
    }
    (a, b)
}

/// Build adapters for every compressible projection.
///
/// Calibration (for the context-aware inits) uses `calib_batches` from
/// `split` — Table 4 uses 24 examples = 3 batches of 8: the low-data
/// regime where CorDA's Gram inversion degrades.
pub fn init_adapters(
    ex: &Executor,
    spec: &ModelSpec,
    weights: &ModelWeights,
    corpus: &Corpus,
    strategy: AdapterInit,
    rank: usize,
    split: &str,
    calib_batches: usize,
) -> Result<AdapterSet> {
    let source = crate::calib::activations::DeviceActivationSource::new(
        ex,
        spec,
        weights,
        corpus,
        split,
        calib_batches,
    )?;
    init_adapters_with(
        spec,
        weights,
        &source,
        strategy,
        rank,
        calib_batches,
        crate::coala::compressor::HOST_SWEEPS,
        Route::Device,
        Some(ex),
    )
}

/// Host-route adapter initialization: calibration chunks from any
/// [`crate::calib::activations::ActivationSource`], accumulation through
/// `calib::accumulate`, factorization through the compressor registry's
/// `factorize_host` — no artifacts, no PJRT.  A collapsing Gram
/// inversion (CorDA's low-data failure) surfaces as an `Err` or as
/// non-finite adapters; the Table 4 driver reports either honestly.
pub fn init_adapters_from_source(
    spec: &ModelSpec,
    weights: &ModelWeights,
    source: &dyn crate::calib::activations::ActivationSource,
    strategy: AdapterInit,
    rank: usize,
    calib_batches: usize,
    sweeps: usize,
) -> Result<AdapterSet> {
    init_adapters_with(
        spec,
        weights,
        source,
        strategy,
        rank,
        calib_batches,
        sweeps,
        Route::Host,
        None,
    )
}

/// The one adapter-init protocol, shared by both routes: stream the
/// calibration statistic the init's registry method consumes, factorize
/// per projection (device artifacts or host linalg), balanced-split into
/// (A, B), and residualize `W_res = W − A·B` so the adapted model starts
/// exactly at the base model.
#[allow(clippy::too_many_arguments)]
fn init_adapters_with(
    spec: &ModelSpec,
    weights: &ModelWeights,
    source: &dyn crate::calib::activations::ActivationSource,
    strategy: AdapterInit,
    rank: usize,
    calib_batches: usize,
    sweeps: usize,
    route: Route,
    ex: Option<&Executor>,
) -> Result<AdapterSet> {
    use crate::calib::accumulate::{make_accumulator, AccumBackend, CalibAccumulator, CalibState};
    use crate::coala::compressor::{resolve, Compressor};
    use crate::tensor::lowp::Precision;

    let backend = match (route, ex) {
        (Route::Device, Some(ex)) => AccumBackend::Device(ex),
        (Route::Device, None) => {
            return Err(Error::Config("device-route init needs an executor".into()))
        }
        (Route::Host, _) => AccumBackend::Host,
    };

    // 1. stream the calibration statistic the init's method consumes
    let mut states: BTreeMap<(usize, String), CalibState> = BTreeMap::new();
    if let Some(mspec) = strategy.method_spec() {
        let comp = resolve(mspec)?;
        let kind = comp.accum_kind();
        if strategy.needs_calibration() {
            let mut accums: BTreeMap<(usize, String), Box<dyn CalibAccumulator + '_>> =
                BTreeMap::new();
            for b in 0..calib_batches {
                for c in source.capture_batch(b)? {
                    use std::collections::btree_map::Entry;
                    let entry = match accums.entry((c.layer, c.stream.clone())) {
                        Entry::Occupied(e) => e.into_mut(),
                        Entry::Vacant(v) => {
                            v.insert(make_accumulator(kind, c.xt.cols, backend, Precision::F32)?)
                        }
                    };
                    entry.fold_chunk(&c.xt)?;
                }
            }
            states = accums.into_iter().map(|(k, a)| (k, a.finish())).collect();
        }
    }

    // 2. per-projection init through the registry
    let mut adapters = BTreeMap::new();
    let mut frozen = weights.clone();
    let mut rng = Rng::new(0xC0A1A);
    let none_state = CalibState::None;
    for proj in &spec.compressible {
        let w = weights.matrix(proj)?;
        let layer: usize = proj[1..].split('.').next().unwrap().parse().unwrap();
        let stream = spec.stream_of(proj)?.to_string();
        let (a, b) = match strategy.method_spec() {
            None => {
                // LoRA: ΔW = 0 (B ~ N(0, 0.02), A = 0 in our A·B layout)
                let mut bmat = Matrix::zeros(rank, w.cols);
                for v in bmat.data.iter_mut() {
                    *v = (rng.normal() * 0.02) as f32;
                }
                (Matrix::zeros(w.rows, rank), bmat)
            }
            Some(mspec) => {
                let comp = resolve(mspec)?;
                let calib = if strategy.needs_calibration() {
                    states.get(&(layer, stream)).ok_or_else(|| {
                        Error::Config(format!("no accumulator for {proj}"))
                    })?
                } else {
                    &none_state
                };
                let f = match route {
                    Route::Device => {
                        comp.factorize_device(ex.expect("checked above"), &w, calib, rank)?
                    }
                    Route::Host => comp.factorize_host(&w, calib, rank, sweeps)?,
                };
                balanced_split(&f.factors, rank)
            }
        };
        // residualize so the adapted model starts EXACTLY at the base
        // model: W_res = W − A·B
        let delta = crate::tensor::ops::matmul(&a, &b)?;
        frozen.set_matrix(proj, &w.sub(&delta)?)?;
        adapters.insert(proj.clone(), (a, b));
    }
    Ok(AdapterSet { rank, adapters, frozen })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::fro;

    fn setup() -> Option<(Executor, Corpus)> {
        if !crate::runtime::require_artifacts("init::setup") {
            return None;
        }
        Some((Executor::new("artifacts").unwrap(), Corpus::load("artifacts").unwrap()))
    }

    #[test]
    fn all_inits_start_at_base_model() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        for strat in [AdapterInit::LoRA, AdapterInit::PiSSA, AdapterInit::CoalaA1] {
            let set =
                init_adapters(&ex, &spec, &w, &corpus, strat, 8, "ft_calib", 2).unwrap();
            assert_eq!(set.adapters.len(), spec.compressible.len());
            for proj in &spec.compressible {
                let (a, b) = &set.adapters[proj];
                let delta = crate::tensor::ops::matmul(a, b).unwrap();
                let orig = w.matrix(proj).unwrap();
                let res = set.frozen.matrix(proj).unwrap();
                let rec = res.add(&delta).unwrap();
                let err = fro(&rec.sub(&orig).unwrap()) / fro(&orig);
                assert!(err < 1e-4, "{}/{proj}: {err}", strat.name());
            }
        }
    }

    #[test]
    fn host_route_inits_start_at_base_model() {
        // artifact-free twin of `all_inits_start_at_base_model`
        use crate::calib::synthetic::SyntheticActivations;
        use crate::model::synthetic::{synthetic_manifest, synthetic_weights};
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 2);
        let src = SyntheticActivations::new(spec.clone(), 2);
        for strat in [
            AdapterInit::LoRA,
            AdapterInit::PiSSA,
            AdapterInit::CoalaA1,
            AdapterInit::CoalaA2,
        ] {
            let set =
                init_adapters_from_source(&spec, &w, &src, strat, 4, 2, 40).unwrap();
            assert_eq!(set.adapters.len(), spec.compressible.len());
            for proj in &spec.compressible {
                let (a, b) = &set.adapters[proj];
                assert!(a.all_finite() && b.all_finite(), "{}/{proj}", strat.name());
                let delta = crate::tensor::ops::matmul(a, b).unwrap();
                let orig = w.matrix(proj).unwrap();
                let rec = set.frozen.matrix(proj).unwrap().add(&delta).unwrap();
                let err = fro(&rec.sub(&orig).unwrap()) / fro(&orig);
                assert!(err < 1e-3, "{}/{proj}: {err}", strat.name());
            }
        }
    }

    #[test]
    fn init_names_resolve() {
        assert_eq!(AdapterInit::resolve("LoRA").unwrap(), AdapterInit::LoRA);
        assert_eq!(AdapterInit::resolve("pissa").unwrap(), AdapterInit::PiSSA);
        assert_eq!(AdapterInit::resolve("coala1").unwrap(), AdapterInit::CoalaA1);
        assert_eq!(AdapterInit::resolve("ALPHA2").unwrap(), AdapterInit::CoalaA2);
        assert_eq!(AdapterInit::resolve("CoALA(a=1)").unwrap(), AdapterInit::CoalaA1);
        assert!(AdapterInit::resolve("nope").is_err());
    }

    #[test]
    fn merged_set_reconstructs_the_base_model_at_init() {
        // merged() = W_res + A·B must equal the original weights for any
        // residualized init (the adapted model starts at the base model)
        use crate::calib::synthetic::SyntheticActivations;
        use crate::model::synthetic::{synthetic_manifest, synthetic_weights};
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 7);
        let src = SyntheticActivations::new(spec.clone(), 7);
        let set = init_adapters_from_source(&spec, &w, &src, AdapterInit::PiSSA, 4, 2, 30)
            .unwrap();
        assert!(set.all_finite());
        let merged = set.merged().unwrap();
        for proj in &spec.compressible {
            let orig = w.matrix(proj).unwrap();
            let got = merged.matrix(proj).unwrap();
            let err = fro(&got.sub(&orig).unwrap()) / fro(&orig);
            assert!(err < 1e-3, "{proj}: {err}");
        }
    }

    #[test]
    fn lora_delta_is_zero_and_pissa_captures_top_spectrum() {
        let Some((ex, corpus)) = setup() else { return };
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let lora = init_adapters(&ex, &spec, &w, &corpus, AdapterInit::LoRA, 8, "ft_calib", 1).unwrap();
        let (a, _b) = &lora.adapters["l0.wq"];
        assert!(fro(a) == 0.0);
        let pissa =
            init_adapters(&ex, &spec, &w, &corpus, AdapterInit::PiSSA, 8, "ft_calib", 1).unwrap();
        let (a, b) = &pissa.adapters["l0.wq"];
        let delta = crate::tensor::ops::matmul(a, b).unwrap();
        // ΔW should carry a noticeable share of W's energy (top-8 SVD)
        let orig = w.matrix("l0.wq").unwrap();
        assert!(fro(&delta) > 0.1 * fro(&orig));
        // balanced: ‖A‖ ≈ ‖B‖ within an order of magnitude
        let (na, nb) = (fro(a), fro(b));
        assert!(na / nb < 10.0 && nb / na < 10.0, "{na} vs {nb}");
    }
}
