//! PEFT adapter initialization + fine-tuning (S15, Table 4).
//!
//! Each projection gets a rank-r adapter pair (A: out×r, B: r×in) with
//! W_eff = W_res + A·B.  The *initialization* is the experimental
//! variable: LoRA (zero ΔW), PiSSA (top-r SVD of W), CorDA (original,
//! Gram-inverting), and COALA α ∈ {1, 2} (robust, context-aware).
//! Training itself is the `ft_step_<cfg>_r<r>` artifact — one Adam step
//! over the adapters with the base frozen — driven from this module.

pub mod init;
pub mod trainer;

pub use init::{init_adapters, init_adapters_from_source, AdapterInit, AdapterSet};
pub use trainer::{FineTuner, FtReport};
