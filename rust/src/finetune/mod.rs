//! PEFT adapter initialization + fine-tuning (S15, Table 4).
//!
//! Each projection gets a rank-r adapter pair (A: out×r, B: r×in) with
//! W_eff = W_res + A·B.  The *initialization* is the experimental
//! variable: LoRA (zero ΔW), PiSSA (top-r SVD of W), CorDA (original,
//! Gram-inverting), and COALA α ∈ {1, 2} (robust, context-aware).
//! Training runs through the route-agnostic [`FineTuner`] trait:
//! [`DeviceFineTuner`] drives the `ft_step_<cfg>_r<r>` artifact, and
//! [`HostFineTuner`] is the pure-Rust training subsystem — the manual
//! fp64 backward pass of [`grad::GradModel`] plus [`optim::Adam`] under
//! the shared [`optim::cosine_decay_lr`] schedule — so Table 4's
//! fine-tuning loop closes with zero artifacts.

pub mod grad;
pub mod init;
pub mod optim;
pub mod trainer;

pub use grad::GradModel;
pub use init::{init_adapters, init_adapters_from_source, AdapterInit, AdapterSet};
pub use optim::{cosine_decay_lr, Adam};
pub use trainer::{DeviceFineTuner, FineTuner, FtReport, HostFineTuner};
