//! Optimizer + learning-rate schedule shared by both fine-tuning routes.
//!
//! The `ft_step` artifact bakes Adam with the hyperparameters below into
//! its AOT graph and takes the already-scheduled learning rate as a
//! scalar input; the host trainer ([`super::trainer::HostFineTuner`])
//! runs the same update in pure Rust at fp64.  The schedule
//! ([`cosine_decay_lr`]) was previously duplicated host-side in the
//! device trainer's step loop — both routes now call this one function,
//! so Table 4's training protocol cannot drift between backends.

use crate::tensor::Matrix;

/// Adam first-moment decay (the artifact trainer's value).
pub const ADAM_BETA1: f64 = 0.9;
/// Adam second-moment decay.
pub const ADAM_BETA2: f64 = 0.999;
/// Adam denominator fuzz.
pub const ADAM_EPS: f64 = 1e-8;
/// Linear-warmup length in steps.
pub const WARMUP_STEPS: usize = 10;
/// Fraction of the cosine half-period swept by `total_steps` (the decay
/// ends at ~10 % of the base LR rather than 0, matching the artifact
/// trainer).
pub const COSINE_HORIZON: f64 = 0.9;

/// The scheduled learning rate for `step` (0-based) of a `total_steps`
/// run: linear warmup over [`WARMUP_STEPS`] steps into a cosine decay
/// over [`COSINE_HORIZON`] of the half-period.
pub fn cosine_decay_lr(base: f64, step: usize, total_steps: usize) -> f64 {
    let warm = ((step + 1) as f64 / WARMUP_STEPS as f64).min(1.0);
    let cos = 0.5
        * (1.0
            + (std::f64::consts::PI * step as f64 / total_steps.max(1) as f64 * COSINE_HORIZON)
                .cos());
    base * warm * cos
}

/// Adam over an indexed set of parameter groups (one group per adapter
/// factor).  State is fp64 and allocated lazily on the first update of
/// each group, so the optimizer needs no shape bookkeeping up front.
/// The update order is fixed by the caller's group indices, and every
/// operation is a deterministic elementwise fp64 map — optimizer steps
/// are bitwise-reproducible for a given gradient sequence.
pub struct Adam {
    beta1: f64,
    beta2: f64,
    eps: f64,
    /// Bias-correction step counter (1-based after the first
    /// [`Adam::begin_step`]).
    t: usize,
    /// Per-group (m, v) moment estimates.
    state: Vec<Option<(Matrix<f64>, Matrix<f64>)>>,
}

impl Adam {
    pub fn new(n_groups: usize) -> Adam {
        Adam {
            beta1: ADAM_BETA1,
            beta2: ADAM_BETA2,
            eps: ADAM_EPS,
            t: 0,
            state: (0..n_groups).map(|_| None).collect(),
        }
    }

    /// Advance the bias-correction counter — call once per optimization
    /// step, before the group updates of that step.
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// One Adam update of `param` from `grad` for parameter group
    /// `group` at the (already scheduled) learning rate `lr`.
    pub fn update(&mut self, group: usize, lr: f64, param: &mut Matrix<f64>, grad: &Matrix<f64>) {
        assert!(self.t > 0, "Adam::begin_step before the first update");
        assert_eq!(
            (param.rows, param.cols),
            (grad.rows, grad.cols),
            "Adam group {group}: param/grad shape mismatch"
        );
        let (m, v) = self.state[group].get_or_insert_with(|| {
            (
                Matrix::zeros(param.rows, param.cols),
                Matrix::zeros(param.rows, param.cols),
            )
        });
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (mi, vi)) in param
            .data
            .iter_mut()
            .zip(&grad.data)
            .zip(m.data.iter_mut().zip(v.data.iter_mut()))
        {
            *mi = self.beta1 * *mi + (1.0 - self.beta1) * g;
            *vi = self.beta2 * *vi + (1.0 - self.beta2) * g * g;
            let m_hat = *mi / bc1;
            let v_hat = *vi / bc2;
            *p -= lr * m_hat / (v_hat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_matches_the_device_trainer_formula() {
        // the exact expression the device trainer used inline before the
        // dedup — byte-for-byte the same arithmetic
        for (step, total) in [(0usize, 100usize), (5, 100), (17, 100), (99, 100), (3, 16)] {
            let warm = ((step + 1) as f64 / 10.0).min(1.0);
            let cos =
                0.5 * (1.0 + (std::f64::consts::PI * step as f64 / total as f64 * 0.9).cos());
            assert_eq!(cosine_decay_lr(1e-3, step, total), 1e-3 * warm * cos);
        }
    }

    #[test]
    fn schedule_warms_up_then_decays() {
        let total = 100;
        let lrs: Vec<f64> = (0..total).map(|i| cosine_decay_lr(1.0, i, total)).collect();
        // warmup: strictly increasing at the start
        assert!(lrs[0] < lrs[4] && lrs[4] < lrs[9]);
        // decay: strictly decreasing after warmup
        assert!(lrs[20] > lrs[50] && lrs[50] > lrs[99]);
        // ends low but not at zero (COSINE_HORIZON < 1)
        assert!(lrs[99] > 0.0 && lrs[99] < 0.1);
        assert!(lrs.iter().all(|l| l.is_finite() && *l >= 0.0));
    }

    #[test]
    fn adam_minimizes_a_quadratic() {
        // minimize ½‖x − c‖² per entry; gradient is (x − c)
        let c = Matrix::<f64>::from_fn(3, 4, |i, j| (i as f64) - 0.5 * (j as f64));
        let mut x = Matrix::<f64>::zeros(3, 4);
        let mut adam = Adam::new(1);
        for _ in 0..400 {
            adam.begin_step();
            let grad = x.sub(&c).unwrap();
            adam.update(0, 0.05, &mut x, &grad);
        }
        let err = crate::tensor::ops::fro(&x.sub(&c).unwrap());
        assert!(err < 1e-2, "Adam did not converge: residual {err}");
    }

    #[test]
    fn adam_is_deterministic() {
        let run = || {
            let mut x = Matrix::<f64>::randn(4, 4, 7);
            let mut adam = Adam::new(1);
            for t in 0..50 {
                adam.begin_step();
                let g = Matrix::<f64>::randn(4, 4, 100 + t);
                adam.update(0, cosine_decay_lr(1e-2, t as usize, 50), &mut x, &g);
            }
            x
        };
        assert_eq!(run().data, run().data);
    }
}
