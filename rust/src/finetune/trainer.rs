//! Adapter fine-tuning — one [`FineTuner`] trait, two backends.
//!
//! [`DeviceFineTuner`] drives the `ft_step_<cfg>_r<r>` PJRT artifact
//! (Adam state lives host-side between steps, the artifact is pure);
//! [`HostFineTuner`] runs the same protocol in pure Rust: the fp64
//! backward pass of [`super::grad::GradModel`] plus
//! [`super::optim::Adam`] under the shared cosine-decay schedule.  Both
//! routes train over a fixed batch pool with the loss recorded *before*
//! each update, so Table 4's loss traces have identical semantics on
//! either backend.  Drivers obtain the right implementation from
//! [`crate::repro::common::Env::fine_tuner`] — route resolution lives
//! there, like the compressor registry, never in driver code.

use super::grad::GradModel;
use super::init::AdapterSet;
use super::optim::{cosine_decay_lr, Adam};
use crate::calib::dataset::{Corpus, TaskBank};
use crate::error::{Error, Result};
use crate::eval::TaskScores;
use crate::runtime::executor::{Executor, Value};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::Matrix;

/// Training + evaluation report for one init strategy.
#[derive(Debug, Clone)]
pub struct FtReport {
    pub init_name: String,
    pub losses: Vec<f32>,
    pub task_scores: crate::eval::TaskScores,
}

/// The route-agnostic fine-tuning interface (Table 4's protocol).
pub trait FineTuner {
    /// Train for `steps` Adam steps at base LR `lr` (cosine-decayed via
    /// [`super::optim::cosine_decay_lr`]), cycling over a fixed batch
    /// pool — the "small fine-tuning set, multiple epochs" regime.
    /// Mutates `set.adapters`; returns the per-step losses, each
    /// measured before its update.
    fn train_on_batches(
        &self,
        set: &mut AdapterSet,
        pool: &[Value],
        steps: usize,
        lr: f64,
    ) -> Result<Vec<f32>>;

    /// Probe-task accuracy of the adapted model `W_res + A·B`.
    fn eval_tasks(
        &self,
        set: &AdapterSet,
        bank: &TaskBank,
        limit: Option<usize>,
    ) -> Result<TaskScores>;
}

// ------------------------------------------------------------ device route

/// Drives the AOT train-step: state lives host-side between steps (the
/// artifact is pure), tokens stream from the ft_train split.
pub struct DeviceFineTuner<'a> {
    pub ex: &'a Executor,
    pub spec: ModelSpec,
    pub rank: usize,
    step_artifact: String,
    logits_artifact: String,
}

impl<'a> DeviceFineTuner<'a> {
    pub fn new(ex: &'a Executor, spec: &ModelSpec, rank: usize) -> DeviceFineTuner<'a> {
        DeviceFineTuner {
            ex,
            spec: spec.clone(),
            rank,
            step_artifact: format!("ft_step_{}_r{rank}", spec.name),
            logits_artifact: format!("ft_logits_{}_r{rank}", spec.name),
        }
    }

    /// Adapter tensors in the artifact ABI order (per projection: A, B).
    fn adapter_values(&self, set: &AdapterSet) -> Result<Vec<Value>> {
        let mut out = Vec::with_capacity(2 * self.spec.compressible.len());
        for proj in &self.spec.compressible {
            let (a, b) = set
                .adapters
                .get(proj)
                .ok_or_else(|| Error::Config(format!("no adapter for {proj}")))?;
            out.push(Value::from_matrix(a));
            out.push(Value::from_matrix(b));
        }
        Ok(out)
    }

    /// Train for `steps` Adam steps at `lr`, sampling fresh windows from
    /// ft_train.  Mutates `set.adapters`.
    pub fn train(
        &self,
        set: &mut AdapterSet,
        corpus: &Corpus,
        steps: usize,
        lr: f64,
        seed: u64,
    ) -> Result<Vec<f32>> {
        let batches =
            corpus.train_batches("ft_train", self.spec.batch, self.spec.seq_len, steps, seed)?;
        FineTuner::train_on_batches(self, set, &batches, steps, lr)
    }
}

impl FineTuner for DeviceFineTuner<'_> {
    fn train_on_batches(
        &self,
        set: &mut AdapterSet,
        pool: &[Value],
        steps: usize,
        lr: f64,
    ) -> Result<Vec<f32>> {
        let frozen_vals = set.frozen.to_values(&self.spec)?;
        let mut ad_vals = self.adapter_values(set)?;
        let mut m_vals: Vec<Value> = ad_vals
            .iter()
            .map(|v| Value::F32(v.dims().to_vec(), vec![0.0; v.f32s().unwrap().len()]))
            .collect();
        let mut v_vals = m_vals.clone();

        let mut losses = Vec::with_capacity(steps);
        for i in 0..steps {
            let tokens = &pool[i % pool.len()];
            let lr_i = cosine_decay_lr(lr, i, steps) as f32;
            let mut inputs =
                vec![tokens.clone(), Value::scalar_f32(lr_i), Value::scalar_f32(i as f32)];
            inputs.extend(frozen_vals.iter().cloned());
            inputs.extend(ad_vals.iter().cloned());
            inputs.extend(m_vals.iter().cloned());
            inputs.extend(v_vals.iter().cloned());
            let mut out = self.ex.run(&self.step_artifact, &inputs)?;
            let n_a = ad_vals.len();
            let rest = out.split_off(1);
            losses.push(out[0].f32s()?[0]);
            ad_vals = rest[0..n_a].to_vec();
            m_vals = rest[n_a..2 * n_a].to_vec();
            v_vals = rest[2 * n_a..3 * n_a].to_vec();
        }

        // write trained adapters back
        for (k, proj) in self.spec.compressible.iter().enumerate() {
            let a = ad_vals[2 * k].matrix()?;
            let b = ad_vals[2 * k + 1].matrix()?;
            set.adapters.insert(proj.clone(), (a, b));
        }
        Ok(losses)
    }

    /// Probe-task accuracy of the adapted model (ft_logits artifact).
    fn eval_tasks(
        &self,
        set: &AdapterSet,
        bank: &TaskBank,
        limit: Option<usize>,
    ) -> Result<TaskScores> {
        let frozen_vals = set.frozen.to_values(&self.spec)?;
        let ad_vals = self.adapter_values(set)?;
        let n = limit.unwrap_or(bank.n).min(bank.n);
        let n_tasks = bank.task_names.len();
        let (bsz, t, vocab) = (self.spec.batch, self.spec.seq_len, self.spec.vocab);
        let mut correct = vec![0usize; n_tasks];
        let mut total = vec![0usize; n_tasks];
        let mut row = 0usize;
        while row < n {
            let take = bsz.min(n - row);
            let mut toks = Vec::with_capacity(bsz * t);
            for b in 0..bsz {
                let r = if b < take { row + b } else { 0 };
                toks.extend_from_slice(bank.context(r));
            }
            let mut inputs = vec![Value::I32(vec![bsz, t], toks)];
            inputs.extend(frozen_vals.iter().cloned());
            inputs.extend(ad_vals.iter().cloned());
            let out = self.ex.run(&self.logits_artifact, &inputs)?;
            let logits = out[0].f32s()?;
            for b in 0..take {
                let r = row + b;
                let base = (b * t + (t - 1)) * vocab;
                let choices = bank.choice_row(r);
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (ci, &c) in choices.iter().enumerate() {
                    let v = logits[base + c as usize];
                    if v > best_v {
                        best_v = v;
                        best = ci;
                    }
                }
                let tid = bank.task_ids[r] as usize;
                total[tid] += 1;
                correct[tid] += usize::from(best == bank.labels[r] as usize);
            }
            row += take;
        }
        let mut accuracy = Vec::new();
        let mut stderr = Vec::new();
        for i in 0..n_tasks {
            let cnt = total[i].max(1);
            let acc = correct[i] as f64 / cnt as f64;
            accuracy.push(acc * 100.0);
            stderr.push((acc * (1.0 - acc) / cnt as f64).sqrt() * 100.0);
        }
        Ok(TaskScores {
            names: bank.task_names.clone(),
            accuracy,
            stderr,
            counts: total,
        })
    }
}

// -------------------------------------------------------------- host route

/// Pure-Rust fine-tuning for the synthetic environment: fp64 backprop
/// through [`GradModel`] + [`Adam`], no artifacts, no PJRT.  Gradient
/// accumulation fans across `workers` threads with a canonical
/// fixed-order reduction, so training runs are bitwise-independent of
/// the worker count (like calibration already is).
pub struct HostFineTuner {
    spec: ModelSpec,
    pub rank: usize,
    workers: usize,
    telemetry: crate::telemetry::TelemetrySink,
}

impl HostFineTuner {
    pub fn new(spec: ModelSpec, rank: usize) -> HostFineTuner {
        HostFineTuner { spec, rank, workers: 1, telemetry: Default::default() }
    }

    /// Fan gradient accumulation across up to `workers` threads
    /// (results are identical at any value).
    pub fn with_workers(mut self, workers: usize) -> HostFineTuner {
        self.workers = workers.max(1);
        self
    }

    /// Report per-step `trainer_step` timings to `sink` (observation
    /// only — training is bitwise unchanged).
    pub fn with_telemetry(mut self, sink: crate::telemetry::TelemetrySink) -> HostFineTuner {
        self.telemetry = sink;
        self
    }
}

impl FineTuner for HostFineTuner {
    fn train_on_batches(
        &self,
        set: &mut AdapterSet,
        pool: &[Value],
        steps: usize,
        lr: f64,
    ) -> Result<Vec<f32>> {
        if pool.is_empty() {
            return Err(Error::Config("host fine-tuning needs ≥ 1 batch".into()));
        }
        if set.rank != self.rank {
            return Err(Error::Config(format!(
                "adapter set is rank {} but the tuner was built for rank {} \
                 (the device route's artifacts are rank-specific; the host \
                 route enforces the same contract)",
                set.rank, self.rank
            )));
        }
        let mut model = GradModel::new(&self.spec, set)?;
        let mut adam = Adam::new(2 * model.n_projs());
        let pair_sets: Vec<Vec<(usize, usize)>> = pool
            .iter()
            .map(|v| crate::eval::pool_pairs(&self.spec, std::slice::from_ref(v)))
            .collect::<Result<_>>()?;

        let mut losses = Vec::with_capacity(steps);
        for i in 0..steps {
            let _t = self.telemetry.start_timer("trainer_step");
            let pairs = &pair_sets[i % pair_sets.len()];
            let (loss, grads) = model.loss_and_grads(pairs, self.workers)?;
            losses.push(loss as f32);
            // health probe: loss + global gradient norm per step.  The
            // norm is computed only when the knob is on, from gradients
            // that exist either way — the update itself never changes.
            if crate::telemetry::health::enabled() {
                let mut g2 = 0.0f64;
                for (ga, gb) in &grads {
                    for v in ga.data.iter().chain(gb.data.iter()) {
                        g2 += v * v;
                    }
                }
                self.telemetry.health_event(
                    None,
                    &crate::telemetry::health::HealthEvent::new("trainer_step")
                        .num("step", i as f64)
                        .num("loss", loss)
                        .num("grad_norm", g2.sqrt()),
                );
            }
            let lr_i = cosine_decay_lr(lr, i, steps);
            adam.begin_step();
            for gi in 0..model.n_projs() {
                let (ga, gb) = &grads[gi];
                let (a, b) = model.adapter_at_mut(gi);
                adam.update(2 * gi, lr_i, a, ga);
                adam.update(2 * gi + 1, lr_i, b, gb);
            }
        }
        model.write_back(set);
        Ok(losses)
    }

    fn eval_tasks(
        &self,
        set: &AdapterSet,
        bank: &TaskBank,
        limit: Option<usize>,
    ) -> Result<TaskScores> {
        crate::eval::eval_tasks_host(&self.spec, &set.merged()?, bank, limit)
    }
}

/// `set.adapters` as flat matrices — used by tests + the repro driver.
pub fn adapter_norms(set: &AdapterSet) -> Vec<(String, f64, f64)> {
    set.adapters
        .iter()
        .map(|(k, (a, b))| {
            (k.clone(), crate::tensor::ops::fro(a), crate::tensor::ops::fro(b))
        })
        .collect()
}

#[allow(unused_imports)]
use Matrix as _MatrixKeep;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::finetune::init::{init_adapters, AdapterInit};
    use crate::model::ModelWeights;

    #[test]
    fn training_reduces_loss_and_moves_adapters() {
        if !crate::runtime::require_artifacts("trainer::training_reduces_loss_and_moves_adapters") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let rank = ex.manifest.ft_rank;
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let mut set =
            init_adapters(&ex, &spec, &w, &corpus, AdapterInit::PiSSA, rank, "ft_calib", 2)
                .unwrap();
        let tuner = DeviceFineTuner::new(&ex, &spec, rank);
        // deterministic: cycle a small fixed pool (epochs over a tiny
        // fine-tuning set — the actual Table 4 regime)
        let pool = corpus
            .train_batches("ft_train", spec.batch, spec.seq_len, 2, 5)
            .unwrap();
        let losses = tuner.train_on_batches(&mut set, &pool, 16, 1e-3).unwrap();
        assert_eq!(losses.len(), 16);
        let head = (losses[0] + losses[1]) / 2.0;
        let tail = (losses[14] + losses[15]) / 2.0;
        assert!(tail < head - 0.05, "loss did not go down: {head} -> {tail}");
        // adapters actually changed
        let norms = adapter_norms(&set);
        assert!(norms.iter().any(|(_, na, _)| *na > 0.0));
    }

    #[test]
    fn task_eval_runs_on_adapted_model() {
        if !crate::runtime::require_artifacts("trainer::task_eval_runs_on_adapted_model") {
            return;
        }
        let ex = Executor::new("artifacts").unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let rank = ex.manifest.ft_rank;
        let w = ModelWeights::load("artifacts", &spec).unwrap();
        let corpus = Corpus::load("artifacts").unwrap();
        let set = init_adapters(&ex, &spec, &w, &corpus, AdapterInit::LoRA, rank, "ft_calib", 1)
            .unwrap();
        let tuner = DeviceFineTuner::new(&ex, &spec, rank);
        let bank = TaskBank::load("artifacts", "ft", &ex.manifest.task_names).unwrap();
        let scores = tuner.eval_tasks(&set, &bank, Some(32)).unwrap();
        assert_eq!(scores.names.len(), 8);
        // LoRA init = exactly the base model; ft facts are NEW, so
        // accuracy should be near chance (the adaptation gap exists)
        assert!(scores.average() < 60.0);
    }

    // ---- host route: artifact-free training ------------------------------

    fn host_world() -> (ModelSpec, AdapterSet, Corpus) {
        use crate::calib::synthetic::SyntheticActivations;
        use crate::finetune::init::init_adapters_from_source;
        use crate::model::synthetic::{synthetic_manifest, synthetic_weights};
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 3);
        let src = SyntheticActivations::new(spec.clone(), 3);
        let set =
            init_adapters_from_source(&spec, &w, &src, AdapterInit::CoalaA1, 4, 2, 30).unwrap();
        let corpus = Corpus::synthetic(spec.vocab, 4096, 3);
        (spec, set, corpus)
    }

    #[test]
    fn host_training_reduces_loss_and_keeps_adapters_finite() {
        let (spec, mut set, corpus) = host_world();
        let pool = corpus
            .train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)
            .unwrap();
        let tuner = HostFineTuner::new(spec.clone(), 4);
        let losses = tuner.train_on_batches(&mut set, &pool, 60, 3e-3).unwrap();
        assert_eq!(losses.len(), 60);
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        let head = (losses[0] + losses[1]) as f64 / 2.0;
        let tail = (losses[58] + losses[59]) as f64 / 2.0;
        assert!(tail < head - 0.02, "host loss did not go down: {head} -> {tail}");
        for (proj, (a, b)) in &set.adapters {
            assert!(a.all_finite() && b.all_finite(), "{proj} not finite");
        }
        // trained model evaluates end-to-end through the host forward
        let bank = TaskBank::synthetic(
            spec.vocab,
            spec.seq_len,
            "ft",
            &crate::model::synthetic::synthetic_manifest().task_names,
            96,
            3,
        )
        .unwrap();
        let scores = FineTuner::eval_tasks(&tuner, &set, &bank, None).unwrap();
        assert_eq!(scores.names.len(), 8);
    }

    #[test]
    fn host_training_is_bitwise_worker_invariant() {
        let (spec, set, corpus) = host_world();
        let pool = corpus
            .train_batches("ft_train", spec.batch, spec.seq_len, 2, 7)
            .unwrap();
        let run = |workers: usize| {
            let mut s = set.clone();
            let tuner = HostFineTuner::new(spec.clone(), 4).with_workers(workers);
            let losses = tuner.train_on_batches(&mut s, &pool, 20, 2e-3).unwrap();
            (losses, s)
        };
        let (l1, s1) = run(1);
        let (l4, s4) = run(4);
        assert_eq!(l1, l4, "losses differ across worker counts");
        for (proj, (a1, b1)) in &s1.adapters {
            let (a4, b4) = &s4.adapters[proj];
            assert_eq!(a1.data, a4.data, "{proj} A differs");
            assert_eq!(b1.data, b4.data, "{proj} B differs");
        }
    }
}
