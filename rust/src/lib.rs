//! # COALA — COntext-Aware Low-rank Approximation
//!
//! A reproduction of *“COALA: Numerically Stable and Efficient Framework
//! for Context-Aware Low-Rank Approximation”* (Parkina & Rakhuba, 2025)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: streaming calibration over a
//!   real (build-time-trained) transformer, TSQR tree scheduling, the
//!   per-layer compression pipeline, μ-selection (Eq. 5), rank budgeting,
//!   evaluation, and the experiment harness regenerating every table and
//!   figure of the paper.
//! * **L2 (python/compile, build time only)** — the factorization graphs
//!   (Alg. 1/2, Prop. 4 α-family, Gram-based baselines) hand-rolled in
//!   jnp (Householder QR, Brent–Luk one-sided Jacobi SVD, Cholesky, …)
//!   and lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the BLAS-3 hot
//!   spots (MXU-tiled matmul, Gram-chunk accumulation, blocked-QR
//!   trailing update).
//!
//! The `runtime` module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) — python never runs on the request path.  The `linalg`
//! module is an independent pure-Rust implementation of the same
//! numerics (including f64) used as ground truth for the stability
//! studies, for the host-side baseline paths, and by the property tests.

pub mod calib;
pub mod coala;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod finetune;
pub mod linalg;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod theory;
pub mod util;

pub use error::{Error, Result};

/// Default artifacts directory (overridable with `--artifacts` / env).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifacts directory: CLI flag > env > default.
pub fn artifacts_dir(flag: Option<&str>) -> String {
    if let Some(f) = flag {
        return f.to_string();
    }
    std::env::var("COALA_ARTIFACTS").unwrap_or_else(|_| DEFAULT_ARTIFACTS.to_string())
}
