//! # COALA — COntext-Aware Low-rank Approximation
//!
//! A reproduction of *“COALA: Numerically Stable and Efficient Framework
//! for Context-Aware Low-Rank Approximation”* (Parkina & Rakhuba, 2025)
//! as a three-layer Rust + JAX + Pallas system.
//!
//! ## Architecture
//!
//! The paper's central observation is that COALA and the Gram-based
//! baselines (SVD-LLM, CorDA, ASVD) differ only in *which statistic of
//! the calibration stream they accumulate* and *how they factorize it*.
//! The crate encodes exactly that split as two small traits:
//!
//! * [`calib::accumulate::CalibAccumulator`] — the streaming
//!   "accumulate" stage.  Four strategies (square R via out-of-core
//!   TSQR, streamed Gram, per-channel activation scales, and the seeded
//!   Gaussian range-finder sketch Y = Σ_b Ω_b·X_b behind `--accum
//!   sketch`) share one `fold_chunk`/`merge_state`/`finish` interface,
//!   each running on either backend: the PJRT artifacts (`Device`) or
//!   pure-Rust linalg (`Host`).  The execution engine folds every
//!   driver through this interface; the raw calibration matrix X is
//!   never materialized.  The sketch's Ω is derived from the *global*
//!   batch index, so its merge (plain addition through the canonical
//!   tree) keeps every bitwise-determinism guarantee below.
//! * [`coala::compressor::Compressor`] — one impl per compression
//!   method.  Each declares the accumulator kind it consumes and
//!   provides **two** factorization routes: `factorize_device` (the AOT
//!   PJRT artifacts via `runtime::ops`) and `factorize_host` (the pure
//!   fp32/fp64 implementations in `coala::factorize` /
//!   `coala::baselines`).  Methods resolve by name through the registry
//!   (`coala::compressor::resolve`), so the coordinator, repro harness,
//!   CLI, and benches never match on method variants.  The accumulate
//!   and factorize stages run end-to-end with no artifacts or PJRT
//!   runtime (the cross-method conformance suite exercises exactly
//!   that), and activation capture is an [`calib::activations::ActivationSource`]
//!   with two implementations: the `fwd_acts` artifacts and the
//!   synthetic PRNG generator.
//! * [`coordinator::engine`] — the one calibrate→accumulate→factorize
//!   control flow.  Capture workers stream any `ActivationSource` into
//!   a bounded channel (backpressure: X never materializes), accumulate
//!   shards build per-(layer, stream, batch) leaf states, a canonical
//!   pairwise `merge_state` tree reduces them in batch order, and the
//!   factorize stage fans per-projection factorizations across worker
//!   threads through the `Compressor` registry.  The sequential
//!   pipeline, the overlapped scheduler, and the multi-device tree-TSQR
//!   runner are thin [`coordinator::engine::EnginePlan`] configurations
//!   of this engine, and results are bitwise-independent of every
//!   worker count (the reduction tree is fixed by batch order), so
//!   `--workers`/`--queue-cap` are pure deployment knobs.
//! * [`calib::state`] + [`coordinator::shard`] — the same determinism,
//!   across *processes*.  A versioned binary codec (magic/version/kind
//!   header, floats as IEEE bit patterns — fp64 bit-exact round-trip,
//!   NaN payloads included) serializes every accumulator merge state
//!   (TSQR R, streamed Gram, activation scales, sketch), compressed factor
//!   outputs, and adapter sets.  A [`coordinator::shard::ShardPlan`]
//!   partitions the calibration batches into contiguous ranges with
//!   *global* leaf indices: `coala shard` accumulates one range and
//!   writes its pending merge-tree nodes to a state file, `coala merge`
//!   re-inserts the nodes of N files into the canonical tree — sibling
//!   merges happen between exactly the same operands in exactly the
//!   same order, so the merged factors are **bitwise identical** to the
//!   single-process run at any shard count (state files carry a source
//!   fingerprint, so shards of *different* runs refuse to merge).  The
//!   same machinery gives
//!   checkpoint/resume: any run can persist its pending states every N
//!   batches (`--checkpoint-dir`, atomic temp-file writes) and a
//!   killed run resumes (`--resume`) with no effect on the resulting
//!   bits — calibration bigger than one machine's RAM, one process's
//!   lifetime, or one node is now a deployment configuration.
//! * [`finetune`] — the Table 4 subsystem, split the same way.
//!   Initialization strategies (LoRA/PiSSA/CorDA/COALA-α) resolve
//!   through the compressor registry; *training* runs through the
//!   route-agnostic [`finetune::FineTuner`] trait with two backends:
//!   the `ft_step` PJRT artifact ([`finetune::DeviceFineTuner`]) and
//!   the pure-Rust host training subsystem
//!   ([`finetune::HostFineTuner`]) — a hand-derived fp64 backward pass
//!   for the synthetic per-token forward ([`finetune::grad::GradModel`],
//!   verified against central differences in `tests/grad_check.rs`)
//!   plus Adam under the shared cosine-decay schedule
//!   ([`finetune::optim`]).  Adapter gradients never materialize
//!   ∂L/∂W: the factor gradients `dA = dy·(Bx)ᵀ`, `dB = (Aᵀdy)·xᵀ`
//!   are accumulated directly, fanned across `util::threads` workers
//!   and reduced in canonical token order — training runs, like
//!   calibration, are bitwise-independent of the worker count.
//! * [`telemetry`] — per-stage observability, feature-gated
//!   (`--features telemetry`) and still zero-dependency.  A
//!   [`telemetry::TelemetrySink`] travels inside
//!   [`coordinator::engine::EnginePlan`]: the engine's *existing*
//!   busy-time tracking (`StageTimings`) is exported as JSONL `stage`
//!   records (capture / accumulate / merge_reduce / factorize) —
//!   never re-timed — while the stages with no pre-existing
//!   measurement (codec encode/decode, checkpoint write/resume,
//!   trainer step) use `start_timer` drop guards at the call site.
//!   Records carry structured labels (config, method, route, accum,
//!   workers, shards) **plus a deterministic `run_id` + `span`**: the
//!   run_id is an FNV-1a hash of the calibration-source fingerprint
//!   ([`telemetry::run_id_for`]), so all N `coala shard` processes and
//!   the `coala merge` stitch into one trace with zero coordination,
//!   distinguished by span (`shard/0` … `merge`; per-projection health
//!   events use `factorize/<proj>`).  Records append atomically to the
//!   `COALA_TELEMETRY` path, so multi-process shard runs can share one
//!   file.  `COALA_HEALTH=1` additionally arms the numerical-health
//!   probes ([`telemetry::health`]): R-diagonal condition estimates,
//!   exact σ extremes where an SVD already ran, Jacobi
//!   sweeps-to-converge, effective μ, sketch geometry, non-finite
//!   factor detection, and trainer loss/grad-norm traces — all
//!   observation-only (factors stay bitwise identical with health on
//!   or off).  `coala report <files…>` ([`telemetry::report`])
//!   aggregates traces into per-(run_id, stage) summaries, a
//!   busy-vs-stall breakdown (the engine measures its bounded-channel
//!   backpressure as `capture_stall`/`accum_idle`), per-shard skew,
//!   and a health digest, with `--json` for CI.
//!   `COALA_ALLOC_STATS=1` arms the memory layer
//!   ([`telemetry::alloc`]): a tracking `#[global_allocator]` whose
//!   scoped watermarks stamp every `stage` record with
//!   `peak_bytes`/`cur_bytes`, a queue-depth high-water gauge on the
//!   engine's bounded channel, and a `/proc/self/status` `VmHWM`
//!   cross-check — observation-only, like the health probes.
//!   `COALA_MEM_BUDGET_MB` turns stage peaks above the budget into
//!   `mem_budget` health *warnings* (never aborts).
//!   `coala report --trace out.json` ([`telemetry::trace`]) exports
//!   the same JSONL as a Chrome trace-event file — one pid per
//!   process, one tid per span, memory and queue-depth counter
//!   tracks — viewable in Perfetto or `chrome://tracing`.  The
//!   default build compiles the sink to a no-op unit struct and
//!   installs no global allocator: zero telemetry code paths (reading
//!   with `coala report`, including `--trace`, still works — it needs
//!   no feature).  `benches/pipeline.rs` embeds the same stage
//!   breakdowns plus the allocator peak in `BENCH_pipeline.json`, and
//!   CI's `perf-gate` job diffs both bench dumps against the
//!   committed baseline (`rust/benches/baseline/`) via
//!   `python/tools/perf_gate.py` — including memory coverage (a
//!   baseline that records `peak_bytes` keeps recording it).
//!
//! ## Reproducing the tables without artifacts
//!
//! ```text
//! COALA_REPRO_FAST=1 cargo run --release -- repro --route host
//! ```
//!
//! regenerates every table and figure of the paper with **zero
//! artifacts, zero PJRT, zero non-default features** — the CI
//! `repro-smoke` job runs exactly this.  `--route host` swaps the
//! environment ([`repro::common::Env`]) from the artifact/PJRT route to
//! the synthetic route:
//!
//! * **model** — [`model::synthetic`] generates a `ModelSpec` pair
//!   (tiny/small) with the same parameter families as the build-time
//!   transformer, PRNG weights whose unembedding implements the corpus'
//!   bigram head, and a pure-Rust forward pass for evaluation;
//! * **data** — [`calib::dataset::Corpus::synthetic`] (Markov-chain
//!   token splits) and [`calib::dataset::TaskBank::synthetic`] (probe
//!   tasks whose labels are the chain's top successors; the "ft" bank
//!   uses a shifted chain, reproducing the Table 4 adaptation gap);
//! * **activations** — [`calib::synthetic::SyntheticActivations`]
//!   generates per-layer calibration chunks with *controlled
//!   conditioning regimes* (well-conditioned / nearly singular /
//!   spiked), so the stability results exercise the paper's scenarios
//!   deterministically, and small batch counts give the k < n
//!   insufficient-data regime;
//! * **math** — accumulation through `CalibAccumulator` with
//!   `AccumBackend::Host` and factorization through
//!   `Compressor::factorize_host`; evaluation through
//!   [`eval::host`];
//! * **training** — Table 4's fine-tuning loop runs end-to-end on the
//!   host route: real Adam steps through [`finetune::HostFineTuner`]'s
//!   fp64 backprop, no `ft_step` artifact required (`coala finetune
//!   --route host` is the CLI entry; CI smoke-tests that the loss
//!   strictly decreases).
//!
//! Everything is seeded (`--seed`), so tables are bit-reproducible; the
//! golden regression suite (`tests/repro_host.rs`) pins determinism and
//! the headline stability claims under `cargo test`.
//!
//! Layers:
//!
//! * **L3 (this crate)** — the coordinator: streaming calibration over a
//!   real (build-time-trained) transformer, TSQR tree scheduling, the
//!   per-layer compression pipeline, μ-selection (Eq. 5), rank budgeting,
//!   evaluation, and the experiment harness regenerating every table and
//!   figure of the paper.
//! * **L2 (python/compile, build time only)** — the factorization graphs
//!   (Alg. 1/2, Prop. 4 α-family, Gram-based baselines) hand-rolled in
//!   jnp (Householder QR, Brent–Luk one-sided Jacobi SVD, Cholesky, …)
//!   and lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the BLAS-3 hot
//!   spots (MXU-tiled matmul, Gram-chunk accumulation, blocked-QR
//!   trailing update).
//!
//! The `runtime` module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the `pjrt` cargo feature) — python never runs on
//! the request path.  The `linalg` module is an independent pure-Rust
//! implementation of the same numerics (including f64) used as ground
//! truth for the stability studies, as the host route of every
//! compressor, and by the property tests.
//!
//! ### Host kernel performance
//!
//! The host route's BLAS-3 spine is hand-tiled rather than naive:
//! [`tensor::ops::matmul`]/[`tensor::ops::matmul_nt`] pack panels of
//! both operands and run a register-tiled microkernel (workers write
//! disjoint row ranges of the preallocated output; accumulation order
//! is ascending-k, so results are bitwise worker-count-independent),
//! and [`linalg::householder_qr_r`] is a compact-WY *blocked* QR whose
//! trailing updates are two of those GEMMs per panel.
//!
//! [`linalg::jacobi_svd`] is built the same way: tall inputs are QR
//! preconditioned (Jacobi then runs on the small square R and
//! U = Q·U_R is one packed GEMM), the rotation kernel caches column
//! squared-norms instead of rescanning them per pair, and sweeps follow
//! the Brent–Luk round-robin order, whose rounds are perfect matchings
//! — so wide Jacobi problems fan the rotations of a round across
//! `COALA_THREADS` workers with bitwise worker-count-independent
//! results (the cyclic-order original survives as
//! [`linalg::jacobi_svd_cyclic`], the property-test oracle and bench
//! baseline).  The sketch accumulator has a second Ω family for the
//! same reason: `COALA_SKETCH_KIND=srht` replaces the Gaussian GEMM
//! fold with sign flip + Walsh–Hadamard + row sampling, O(c·log c) per
//! column instead of O(s·c).
//!
//! `benches/kernels.rs` sweeps all of these against their
//! naive/unblocked references (GEMM, QR, blocked-vs-cyclic SVD,
//! SRHT-vs-Gaussian and sketch-vs-exact accumulation) and dumps
//! `BENCH_kernels.json` with the speedup ratios.
//!
//! ### Adding a method
//!
//! 1. implement the factorization in `coala::` (host) and, if an AOT
//!    graph exists, a typed wrapper in `runtime::ops` (device);
//! 2. add a `Compressor` impl in `coala::compressor` declaring its
//!    [`calib::accumulate::AccumKind`];
//! 3. register it in `compressor::resolve` / `compressor::registry`.
//!
//! Nothing else changes: the pipeline, schedulers, repro tables, CLI,
//! and the cross-method conformance suite pick it up from the registry.
//!
//! ## Environment knobs
//!
//! Every `COALA_*` variable is read through the strict parsers in
//! [`util::env`]: unset means the default, and a set-but-malformed
//! value is a hard error — a knob can never be silently ignored.
//! *Flags* accept `1`/`true`/`yes` (case-insensitive) for on and
//! `0`/`false`/`no` (or empty) for off.  “Fingerprint” marks knobs
//! folded into the run's source fingerprint: every worker/shard of a
//! run must agree on them, and shard states from runs that disagree
//! refuse to merge.
//!
//! | Variable             | Grammar              | Effect | Fingerprint |
//! |----------------------|----------------------|--------|-------------|
//! | `COALA_ARTIFACTS`    | path                 | artifacts dir when `--artifacts` is absent | no |
//! | `COALA_THREADS`      | integer ≥ 1          | worker count for large host GEMMs (panics loudly at first use if malformed — the call sites cannot return `Result`) | no |
//! | `COALA_REPRO_FAST`   | flag                 | shrink repro-driver budgets (CI smoke) | no |
//! | `COALA_BENCH_FAST`   | flag                 | shrink bench budgets (CI perf jobs) | no |
//! | `COALA_SKETCH_ROWS`  | integer in `[1, width]` | sketch-accumulator row count; out-of-range is an error, not a clamp | **yes** |
//! | `COALA_SKETCH_SEED`  | u64                  | sketch Ω seed base | **yes** |
//! | `COALA_SKETCH_KIND`  | `gaussian` \| `srht` | sketch Ω family: dense Gaussian GEMM or SRHT fast transform | **yes** |
//! | `COALA_SVD_PAR_COLS` | integer ≥ 1          | Jacobi column count at which the rotation fan goes parallel (default 192; results are bitwise identical either way) | no |
//! | `COALA_SVD_QR_PRECOND` | flag (default on)  | QR-precondition tall SVD inputs before the Jacobi iteration | no |
//! | `COALA_GOLDEN_REGEN` | flag                 | regenerate `tests/golden/stability.json` in `cargo test` | no |
//! | `COALA_TELEMETRY`    | path                 | JSONL telemetry sink (requires `--features telemetry`; setting it on a default build is an error) | no |
//! | `COALA_HEALTH`       | flag                 | arm the numerical-health probes ([`telemetry::health`]) — observation-only, factors stay bitwise identical (requires `--features telemetry`; setting it on a default build is an error) | no |
//! | `COALA_ALLOC_STATS`  | flag                 | arm the tracking allocator ([`telemetry::alloc`]) — stage records gain `peak_bytes`/`cur_bytes`, observation-only, factors stay bitwise identical (requires `--features telemetry`; setting it on a default build is an error) | no |
//! | `COALA_MEM_BUDGET_MB` | integer ≥ 1         | soft per-stage memory budget: peaks above it emit `mem_budget` health warnings, never aborts (requires `COALA_ALLOC_STATS=1` and `--features telemetry`; anything else is an error) | no |

pub mod calib;
pub mod coala;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod finetune;
pub mod linalg;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod theory;
pub mod util;

pub use error::{Error, Result};

/// Default artifacts directory (overridable with `--artifacts` / env).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifacts directory: CLI flag > env > default.  A set
/// `COALA_ARTIFACTS` must be a usable value — set-but-empty (or
/// non-UTF-8) is a hard error, not a silent fall-through to the
/// default directory.
pub fn artifacts_dir(flag: Option<&str>) -> Result<String> {
    if let Some(f) = flag {
        return Ok(f.to_string());
    }
    Ok(util::env::string("COALA_ARTIFACTS")?.unwrap_or_else(|| DEFAULT_ARTIFACTS.to_string()))
}
