//! Right-looking Cholesky — deliberately the unguarded textbook version
//! SVD-LLM relies on, so its breakdown on singular Gram matrices (the
//! paper's Fig. 1 phenomenon) is reproduced rather than papered over.

use crate::error::{Error, Result};
use crate::tensor::{Matrix, Scalar};

/// Lower Cholesky factor L with L·Lᵀ = S.
///
/// Returns `Err(Numerical)` on a non-positive pivot (what torch raises);
/// callers studying the failure mode can use [`cholesky_unchecked`] which
/// lets NaNs propagate instead (what fp16 GPU kernels do).
pub fn cholesky<T: Scalar>(s: &Matrix<T>) -> Result<Matrix<T>> {
    let l = cholesky_unchecked(s)?;
    if !l.all_finite() {
        return Err(Error::Numerical(
            "cholesky: non-positive pivot (singular Gram matrix)".into(),
        ));
    }
    Ok(l)
}

/// Cholesky that propagates NaN/Inf from non-PSD pivots.
pub fn cholesky_unchecked<T: Scalar>(s: &Matrix<T>) -> Result<Matrix<T>> {
    let n = s.rows;
    if s.cols != n {
        return Err(Error::shape(format!("cholesky needs square, got {}x{}", s.rows, s.cols)));
    }
    let mut a = s.clone();
    for j in 0..n {
        let d = a.get(j, j).sqrt();
        for i in j..n {
            let v = a.get(i, j) / d;
            a.set(i, j, v);
        }
        for c in (j + 1)..n {
            let ljc = a.get(c, j);
            if ljc.to_f64() == 0.0 && ljc.is_finite() {
                continue;
            }
            for i in c..n {
                let cur = a.get(i, c);
                a.set(i, c, cur - a.get(i, j) * ljc);
            }
        }
    }
    // zero strict upper triangle
    for i in 0..n {
        for j in (i + 1)..n {
            a.set(i, j, T::ZERO);
        }
    }
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, gram_t, matmul};

    #[test]
    fn factors_spd() {
        let x: Matrix<f64> = Matrix::randn(20, 7, 1);
        let mut g = gram_t(&x);
        for i in 0..7 {
            g.set(i, i, g.get(i, i) + 0.1);
        }
        let l = cholesky(&g).unwrap();
        let rec = matmul(&l, &l.transpose()).unwrap();
        assert!(fro(&rec.sub(&g).unwrap()) < 1e-10 * fro(&g));
        for i in 0..7 {
            for j in (i + 1)..7 {
                assert_eq!(l.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn singular_gram_fails_checked() {
        // rank-1 Gram: the SVD-LLM breakdown case
        let x: Matrix<f64> = Matrix::from_fn(4, 3, |_, j| (j + 1) as f64);
        let g = gram_t(&x);
        assert!(cholesky(&g).is_err());
        // unchecked lets non-finite through
        let l = cholesky_unchecked(&g).unwrap();
        assert!(!l.all_finite());
    }

    #[test]
    fn non_square_rejected() {
        let a: Matrix<f64> = Matrix::zeros(2, 3);
        assert!(cholesky(&a).is_err());
    }
}
