//! Symmetric eigendecomposition via the classical (two-sided) Jacobi
//! eigenvalue algorithm — the SVD-LLM v2 substrate.
//!
//! Shares the 2×2 rotation core ([`crate::linalg::svd::jacobi_coeffs`])
//! with the one-sided SVD, and tracks the off-diagonal Frobenius mass
//! incrementally: each rotation moves exactly 2·apq² from the
//! off-diagonal to the diagonal (orthogonal similarity preserves the
//! Frobenius norm), so `off` is updated per rotation instead of being
//! rescanned O(n²) every sweep.  An exact recompute confirms
//! convergence before the loop exits, so fp drift in the running sum
//! can delay the exit by one cheap check but never produce a wrong
//! early stop.

use crate::error::{Error, Result};
use crate::linalg::svd::{jacobi_coeffs, note_sweeps};
use crate::tensor::{Matrix, Scalar};

fn off_mass<T: Scalar>(a: &Matrix<T>, n: usize) -> f64 {
    let mut off = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let v = a.get(i, j).to_f64();
                off += v * v;
            }
        }
    }
    off
}

/// Eigendecomposition of a symmetric matrix: S = Q·diag(λ)·Qᵀ.
/// Returns (λ descending, Q with eigenvectors as columns).
pub fn eigh<T: Scalar>(s: &Matrix<T>, max_sweeps: usize) -> Result<(Vec<T>, Matrix<T>)> {
    let n = s.rows;
    if s.cols != n {
        return Err(Error::shape(format!("eigh needs square, got {}x{}", s.rows, s.cols)));
    }
    let mut a = s.clone();
    let mut q: Matrix<T> = Matrix::eye(n);
    let tol = T::EPSILON.to_f64() * 4.0;

    // ‖S‖²_F is invariant under the similarity rotations, so the
    // convergence threshold is fixed for the whole iteration
    let mut off = off_mass(&a, n);
    let total = off
        + (0..n)
            .map(|i| {
                let v = a.get(i, i).to_f64();
                v * v
            })
            .sum::<f64>();
    let thresh = tol * tol * total;

    let mut sweeps = 0u64;
    let mut converged = false;
    for _ in 0..max_sweeps {
        if off <= thresh {
            // heal running-sum drift before trusting the exit
            off = off_mass(&a, n);
            if off <= thresh {
                converged = true;
                break;
            }
        }
        let mut any = false;
        for p in 0..n {
            for qi in (p + 1)..n {
                let apq = a.get(p, qi).to_f64();
                if apq == 0.0 {
                    continue;
                }
                any = true;
                let app = a.get(p, p).to_f64();
                let aqq = a.get(qi, qi).to_f64();
                let (c, sn, _t) = jacobi_coeffs(app, aqq, apq);
                let (cs_t, sn_t) = (T::from_f64(c), T::from_f64(sn));
                // A ← JᵀAJ  (rows and columns p, q)
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, qi);
                    a.set(k, p, cs_t * akp - sn_t * akq);
                    a.set(k, qi, sn_t * akp + cs_t * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(qi, k);
                    a.set(p, k, cs_t * apk - sn_t * aqk);
                    a.set(qi, k, sn_t * apk + cs_t * aqk);
                }
                for k in 0..n {
                    let qkp = q.get(k, p);
                    let qkq = q.get(k, qi);
                    q.set(k, p, cs_t * qkp - sn_t * qkq);
                    q.set(k, qi, sn_t * qkp + cs_t * qkq);
                }
                // the rotation zeroes a_pq = a_qp; everything else in
                // rows/cols p,q shuffles mass without changing the sum
                off = (off - 2.0 * apq * apq).max(0.0);
            }
        }
        sweeps += 1;
        if !any {
            converged = true;
            break;
        }
    }
    note_sweeps(sweeps);

    // health probe: sweep count, convergence flag, and the running
    // off-diagonal mass already exist — pure reads
    if crate::telemetry::health::enabled() {
        crate::telemetry::health::note(
            crate::telemetry::health::HealthEvent::new("eigh")
                .num("sweeps", sweeps as f64)
                .num("converged", if converged { 1.0 } else { 0.0 })
                .num("off_mass", off)
                .num("n", n as f64),
        );
    }

    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| a.get(i, i).to_f64()).collect();
    order.sort_by(|&i, &j| diag[j].total_cmp(&diag[i])); // NaN-safe
    let lam: Vec<T> = order.iter().map(|&i| a.get(i, i)).collect();
    let mut qs = Matrix::zeros(n, n);
    for (k, &j) in order.iter().enumerate() {
        for i in 0..n {
            qs.set(i, k, q.get(i, j));
        }
    }
    Ok((lam, qs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, gram_t, matmul};

    #[test]
    fn reconstructs_psd() {
        let x: Matrix<f64> = Matrix::randn(30, 10, 1);
        let g = gram_t(&x);
        let (lam, q) = eigh(&g, 40).unwrap();
        // Q diag(λ) Qᵀ = G
        let mut ql = q.clone();
        for i in 0..10 {
            for j in 0..10 {
                ql.set(i, j, ql.get(i, j) * lam[j]);
            }
        }
        let rec = matmul(&ql, &q.transpose()).unwrap();
        assert!(fro(&rec.sub(&g).unwrap()) < 1e-9 * fro(&g));
    }

    #[test]
    fn eigenvalues_match_svd_squares() {
        let x: Matrix<f64> = Matrix::randn(25, 8, 2);
        let g = gram_t(&x);
        let (lam, _) = eigh(&g, 40).unwrap();
        let svd = crate::linalg::svd::jacobi_svd(&x, 30).unwrap();
        for (l, s) in lam.iter().zip(&svd.s) {
            assert!((l - s * s).abs() < 1e-8 * (1.0 + s * s), "{l} vs {}", s * s);
        }
    }

    #[test]
    fn orthogonal_q() {
        let x: Matrix<f64> = Matrix::randn(20, 6, 3);
        let g = gram_t(&x);
        let (_, q) = eigh(&g, 40).unwrap();
        let qtq = matmul(&q.transpose(), &q).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn non_square_rejected() {
        let a: Matrix<f64> = Matrix::zeros(3, 4);
        assert!(eigh(&a, 5).is_err());
    }

    #[test]
    fn already_diagonal_converges_immediately() {
        let mut d: Matrix<f64> = Matrix::zeros(5, 5);
        for i in 0..5 {
            d.set(i, i, (5 - i) as f64);
        }
        let (lam, q) = eigh(&d, 40).unwrap();
        for i in 0..5 {
            assert_eq!(lam[i], (5 - i) as f64);
            assert_eq!(q.get(i, i), 1.0);
        }
    }
}
