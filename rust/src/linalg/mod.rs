//! Pure-Rust numerical linear algebra (substrate S2).
//!
//! An independent implementation of every factorization the L2 jax graphs
//! use, in both f32 and f64.  Three jobs:
//!
//! 1. **fp64 ground truth** for the stability experiments (Fig. 1 needs a
//!    high-precision COALA reference; Example G.1 needs exact spectra);
//! 2. **host-side baselines** so the Gram-based methods can be studied at
//!    any precision (including the emulated fp16 of Table 2);
//! 3. **verification** — property tests cross-check the PJRT-executed
//!    artifacts against these routines on random instances.
//!
//! Algorithms mirror the L2 implementations (Householder QR, streaming /
//! tree TSQR, Brent–Luk one-sided Jacobi SVD, Jacobi eigensolver,
//! right-looking Cholesky, substitution solves) so discrepancies localize
//! bugs rather than algorithmic drift.

pub mod cholesky;
pub mod eigh;
pub mod qr;
pub mod svd;
pub mod triangular;
pub mod tsqr;

pub use cholesky::cholesky;
pub use eigh::eigh;
pub use qr::{householder_qr, householder_qr_r, qr_r_square};
pub use svd::{
    jacobi_svd, jacobi_svd_cyclic, jacobi_svd_with_workers, svd_sweep_total, Svd,
};
pub use triangular::{solve_lower, solve_upper};
pub use tsqr::{tsqr_sequential, tsqr_tree, TsqrFolder};
