//! Blocked Householder QR (compact-WY).  The R-only sweep is all
//! COALA's algorithms ever need; the explicit-Q variant
//! ([`householder_qr`]) exists for the property tests that pin the
//! orthogonality invariants (QᵀQ = I, A = QR) the R-only code relies on
//! implicitly.
//!
//! Panels of `NB` columns are factored with the textbook column sweep
//! while the block reflector Q = I − V·T·Vᵀ is accumulated (T upper
//! triangular, built by the compact-WY recurrence
//! T ← [[T, −τ·T·(Vᵀv)], [0, τ]]); the trailing matrix is then updated
//! with two packed GEMMs (C ← C − V·Tᵀ·(VᵀC)), which is where ~1−NB/n
//! of the flops land.  `tests/prop_linalg.rs` pins blocked ≡ unblocked.

use crate::error::{Error, Result};
use crate::tensor::ops::matmul;
use crate::tensor::{Matrix, Scalar};

/// Panel width for the blocked sweep.  32 keeps the unblocked panel
/// work ≤ NB/n of the flops at `large`-config shapes while the V/T
/// panels stay L1/L2 resident.
const NB: usize = 32;

/// R factor of A (m × n): returns min(m,n) × n upper triangular.
///
/// Compact-WY blocked Householder; O(mn²) with the trailing updates as
/// GEMMs.  No pivoting (mirrors the L2 graph).  Rank-deficient inputs
/// are fine: a zero column yields a zero reflector (τ = 0).
pub fn householder_qr_r<T: Scalar>(a: &Matrix<T>) -> Matrix<T> {
    let (m, n) = (a.rows, a.cols);
    let mut acc = a.clone();
    let mut v = vec![T::ZERO; m];
    householder_triangularize(&mut acc, m, &mut v);
    // extract the upper-triangular top block
    let k = m.min(n);
    let mut r = Matrix::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            r.set(i, j, acc.get(i, j));
        }
    }
    r
}

/// Triangularize the top `m` rows of `acc` **in place** (R-only
/// blocked Householder sweep); rows ≥ `m` of `acc` are never touched.
///
/// This is the core shared by [`householder_qr_r`] and the streaming
/// [`super::tsqr::TsqrFolder`], which reuses one scratch matrix across
/// folds instead of re-stacking `[R ; chunk]`.  `v` is the caller-owned
/// reflector workspace (`v.len() >= m`).
pub(crate) fn householder_triangularize<T: Scalar>(acc: &mut Matrix<T>, m: usize, v: &mut [T]) {
    let n = acc.cols;
    debug_assert!(m <= acc.rows && v.len() >= m);
    let steps = m.min(n);
    let mut j0 = 0;
    while j0 < steps {
        let nb = NB.min(steps - j0);
        let (vmat, tmat) = panel_factor(acc, m, j0, nb, v);
        if j0 + nb < n {
            // trailing update: C ← (I − V·T·Vᵀ)ᵀ·C = C − V·Tᵀ·(VᵀC)
            apply_block_left(acc, m, j0, &vmat, &tmat, j0 + nb, n, true);
        }
        j0 += nb;
    }
}

/// Factor panel columns `j0 .. j0+nb` of `acc` (rows `j0..m`) with the
/// unblocked column sweep, applying each reflector to the remaining
/// panel columns immediately.  Returns the panel reflectors V
/// ((m−j0) × nb, lower trapezoidal) and the compact-WY T (nb × nb,
/// upper triangular) such that H_{j0}·…·H_{j0+nb−1} = I − V·T·Vᵀ.
/// Skipped (zero) columns leave zero columns in both V and T, which
/// drop out of the block reflector exactly as an identity factor would.
fn panel_factor<T: Scalar>(
    acc: &mut Matrix<T>,
    m: usize,
    j0: usize,
    nb: usize,
    v: &mut [T],
) -> (Matrix<T>, Matrix<T>) {
    let mp = m - j0;
    let mut vmat = Matrix::zeros(mp, nb);
    let mut tmat = Matrix::zeros(nb, nb);
    let mut w = vec![T::ZERO; nb];
    for jj in 0..nb {
        let j = j0 + jj;
        // build the Householder vector from column j, rows j..m
        let mut norm2 = T::ZERO;
        for i in j..m {
            let x = acc.get(i, j);
            norm2 += x * x;
        }
        let normx = norm2.sqrt();
        if normx.to_f64() == 0.0 {
            continue;
        }
        let xj = acc.get(j, j);
        let alpha = if xj.to_f64() >= 0.0 { -normx } else { normx };
        for i in j..m {
            v[i] = acc.get(i, j);
        }
        v[j] -= alpha;
        let vnorm2 = {
            let mut s = T::ZERO;
            for &x in v.iter().take(m).skip(j) {
                s += x * x;
            }
            s
        };
        if vnorm2.to_f64() <= 0.0 {
            continue;
        }
        let beta = (T::ONE + T::ONE) / vnorm2;
        // acc −= β v (vᵀ acc) on the remaining panel columns
        for c in j..j0 + nb {
            let mut dot = T::ZERO;
            for i in j..m {
                dot += v[i] * acc.get(i, c);
            }
            let s = beta * dot;
            for i in j..m {
                let cur = acc.get(i, c);
                acc.set(i, c, cur - v[i] * s);
            }
        }
        // record V column jj and extend T:
        //   T[..jj, jj] = −β·T[..jj, ..jj]·(V[.., ..jj]ᵀ·v),  T[jj, jj] = β
        for i in j..m {
            vmat.set(i - j0, jj, v[i]);
        }
        for (p, wp) in w.iter_mut().enumerate().take(jj) {
            let mut dot = T::ZERO;
            for i in jj..mp {
                dot += vmat.get(i, p) * vmat.get(i, jj);
            }
            *wp = dot;
        }
        for p in 0..jj {
            let mut dot = T::ZERO;
            for (q, &wq) in w.iter().enumerate().take(jj).skip(p) {
                dot += tmat.get(p, q) * wq;
            }
            tmat.set(p, jj, -beta * dot);
        }
        tmat.set(jj, jj, beta);
    }
    (vmat, tmat)
}

/// Apply the block reflector of panel (`j0`, V, T) to columns
/// `c0 .. c1` of `acc`, rows `j0..m`:
///   `transpose_t == true`  → C ← C − V·Tᵀ·(VᵀC)   (i.e. Qᵀ·C)
///   `transpose_t == false` → C ← C − V·T·(VᵀC)    (i.e. Q·C)
/// All three products run through the packed GEMM.
fn apply_block_left<T: Scalar>(
    acc: &mut Matrix<T>,
    m: usize,
    j0: usize,
    vmat: &Matrix<T>,
    tmat: &Matrix<T>,
    c0: usize,
    c1: usize,
    transpose_t: bool,
) {
    let mp = m - j0;
    let c = acc.slice(j0, m, c0, c1);
    let vt_c = matmul(&vmat.transpose(), &c).expect("blocked QR: VᵀC shape");
    let t_eff = if transpose_t { tmat.transpose() } else { tmat.clone() };
    let s = matmul(&t_eff, &vt_c).expect("blocked QR: T·VᵀC shape");
    let vs = matmul(vmat, &s).expect("blocked QR: V·S shape");
    for i in 0..mp {
        for (jj, &x) in vs.row(i).iter().enumerate() {
            let cur = acc.get(j0 + i, c0 + jj);
            acc.set(j0 + i, c0 + jj, cur - x);
        }
    }
}

/// Full thin Householder QR: A (m × n, m ≥ n) = Q·R with Q (m × n)
/// having orthonormal columns and R (n × n) upper triangular.
///
/// Same blocked panel factorization as [`householder_qr_r`] (the R
/// factors agree bitwise); the kept (V, T) panels are applied in
/// reverse to the thin identity to materialize Q — the form the
/// property tests verify directly.
pub fn householder_qr<T: Scalar>(a: &Matrix<T>) -> Result<(Matrix<T>, Matrix<T>)> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::shape(format!("householder_qr needs m ≥ n, got {m}x{n}")));
    }
    let mut acc = a.clone();
    let mut v = vec![T::ZERO; m];
    let mut panels: Vec<(usize, Matrix<T>, Matrix<T>)> = Vec::new();
    let mut j0 = 0;
    while j0 < n {
        let nb = NB.min(n - j0);
        let (vmat, tmat) = panel_factor(&mut acc, m, j0, nb, &mut v);
        if j0 + nb < n {
            apply_block_left(&mut acc, m, j0, &vmat, &tmat, j0 + nb, n, true);
        }
        panels.push((j0, vmat, tmat));
        j0 += nb;
    }
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for c in i..n {
            r.set(i, c, acc.get(i, c));
        }
    }
    // Q = (I − V₀T₀V₀ᵀ)·…·(I − Vₖ Tₖ Vₖᵀ)·[Iₙ; 0]: panels in reverse
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, T::ONE);
    }
    for (p0, vmat, tmat) in panels.iter().rev() {
        apply_block_left(&mut q, m, *p0, vmat, tmat, 0, n, false);
    }
    Ok((q, r))
}

/// Square (n × n) R for the COALA preprocessing convention: zero-pads
/// when m < n so RᵀR = AᵀA always holds with a square R.
pub fn qr_r_square<T: Scalar>(a: &Matrix<T>) -> Result<Matrix<T>> {
    let n = a.cols;
    let r = householder_qr_r(a);
    if r.rows == n {
        return Ok(r);
    }
    let pad = Matrix::zeros(n - r.rows, n);
    r.vstack(&pad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{gram_t, matmul};

    fn gram_close<T: Scalar>(r: &Matrix<T>, a: &Matrix<T>, tol: f64) {
        let rt_r = matmul(&r.transpose(), r).unwrap();
        let at_a = gram_t(a);
        for (x, y) in rt_r.data.iter().zip(&at_a.data) {
            assert!(
                (x.to_f64() - y.to_f64()).abs() < tol * (1.0 + y.to_f64().abs()),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn qr_gram_identity_f64() {
        for (m, n, seed) in [(20usize, 8usize, 1u64), (8, 8, 2), (5, 9, 3), (100, 30, 4)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let r = householder_qr_r(&a);
            assert_eq!(r.rows, m.min(n));
            gram_close(&r, &a, 1e-10);
        }
    }

    #[test]
    fn qr_gram_identity_f32() {
        let a: Matrix<f32> = Matrix::randn(50, 20, 5);
        let r = householder_qr_r(&a);
        gram_close(&r, &a, 1e-3);
    }

    #[test]
    fn qr_gram_identity_beyond_panel_width() {
        // more columns than one NB panel: the compact-WY trailing
        // updates carry the factorization across panel boundaries
        for (m, n, seed) in [(96usize, 80usize, 8u64), (64, 33, 9), (40, 64, 10)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let r = householder_qr_r(&a);
            assert_eq!(r.rows, m.min(n));
            gram_close(&r, &a, 1e-10);
        }
    }

    #[test]
    fn upper_triangular() {
        let a: Matrix<f64> = Matrix::randn(12, 7, 6);
        let r = householder_qr_r(&a);
        for i in 0..r.rows {
            for j in 0..i.min(r.cols) {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_is_finite() {
        let mut a: Matrix<f64> = Matrix::zeros(10, 4);
        for i in 0..10 {
            for j in 0..4 {
                a.set(i, j, (i + 1) as f64); // rank 1
            }
        }
        let r = householder_qr_r(&a);
        assert!(r.all_finite());
        gram_close(&r, &a, 1e-9);
    }

    #[test]
    fn explicit_q_reconstructs_and_is_orthonormal() {
        for (m, n, seed) in [(12usize, 5usize, 1u64), (7, 7, 2), (30, 10, 3), (80, 50, 4)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let (q, r) = householder_qr(&a).unwrap();
            assert_eq!((q.rows, q.cols), (m, n));
            assert_eq!((r.rows, r.cols), (n, n));
            let qtq = matmul(&q.transpose(), &q).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq.get(i, j) - want).abs() < 1e-10, "QᵀQ[{i}][{j}]");
                }
            }
            let qr = matmul(&q, &r).unwrap();
            for (x, y) in qr.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-10 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
        // R agrees with the R-only sweep
        let a: Matrix<f64> = Matrix::randn(20, 6, 4);
        let (_q, r) = householder_qr(&a).unwrap();
        let r_only = householder_qr_r(&a);
        for (x, y) in r.data.iter().zip(&r_only.data) {
            assert!((x - y).abs() < 1e-12);
        }
        assert!(householder_qr(&Matrix::<f64>::zeros(3, 5)).is_err());
    }

    #[test]
    fn square_pads_wide() {
        let a: Matrix<f64> = Matrix::randn(3, 8, 7);
        let r = qr_r_square(&a).unwrap();
        assert_eq!((r.rows, r.cols), (8, 8));
        gram_close(&r, &a, 1e-10);
    }
}
