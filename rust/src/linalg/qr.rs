//! Householder QR.  The R-only sweep is all COALA's algorithms ever
//! need; the explicit-Q variant ([`householder_qr`]) exists for the
//! property tests that pin the orthogonality invariants (QᵀQ = I,
//! A = QR) the R-only code relies on implicitly.

use crate::error::{Error, Result};
use crate::tensor::{Matrix, Scalar};

/// R factor of A (m × n): returns min(m,n) × n upper triangular.
///
/// Column-by-column Householder reflections applied in place; O(mn²).
/// No pivoting (mirrors the L2 graph).  Rank-deficient inputs are fine:
/// a zero column yields a zero reflector (β = 0).
pub fn householder_qr_r<T: Scalar>(a: &Matrix<T>) -> Matrix<T> {
    let (m, n) = (a.rows, a.cols);
    let mut acc = a.clone();
    let mut v = vec![T::ZERO; m];
    householder_triangularize(&mut acc, m, &mut v);
    // extract the upper-triangular top block
    let k = m.min(n);
    let mut r = Matrix::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            r.set(i, j, acc.get(i, j));
        }
    }
    r
}

/// Triangularize the top `m` rows of `acc` **in place** (R-only
/// Householder sweep); rows ≥ `m` of `acc` are never touched.
///
/// This is the allocation-free core shared by [`householder_qr_r`] and
/// the streaming [`super::tsqr::TsqrFolder`], which reuses one scratch
/// matrix across folds instead of re-stacking `[R ; chunk]`.  `v` is the
/// caller-owned reflector workspace (`v.len() >= m`).
pub(crate) fn householder_triangularize<T: Scalar>(acc: &mut Matrix<T>, m: usize, v: &mut [T]) {
    let n = acc.cols;
    debug_assert!(m <= acc.rows && v.len() >= m);
    let steps = m.min(n);
    for j in 0..steps {
        // build the Householder vector from column j, rows j..m
        let mut norm2 = T::ZERO;
        for i in j..m {
            let x = acc.get(i, j);
            norm2 += x * x;
        }
        let normx = norm2.sqrt();
        if normx.to_f64() == 0.0 {
            continue;
        }
        let xj = acc.get(j, j);
        let alpha = if xj.to_f64() >= 0.0 { -normx } else { normx };
        for i in j..m {
            v[i] = acc.get(i, j);
        }
        v[j] -= alpha;
        let vnorm2 = {
            let mut s = T::ZERO;
            for &x in v.iter().take(m).skip(j) {
                s += x * x;
            }
            s
        };
        if vnorm2.to_f64() <= 0.0 {
            continue;
        }
        let beta = (T::ONE + T::ONE) / vnorm2;
        // acc -= beta * v (vᵀ acc)   — only rows j.. and cols j.. matter
        for c in j..n {
            let mut dot = T::ZERO;
            for i in j..m {
                dot += v[i] * acc.get(i, c);
            }
            let s = beta * dot;
            for i in j..m {
                let cur = acc.get(i, c);
                acc.set(i, c, cur - v[i] * s);
            }
        }
    }
}

/// Full thin Householder QR: A (m × n, m ≥ n) = Q·R with Q (m × n)
/// having orthonormal columns and R (n × n) upper triangular.
///
/// Same reflector construction as [`householder_qr_r`], but the
/// reflectors are kept and applied in reverse to the thin identity to
/// materialize Q — the form the property tests verify directly.
pub fn householder_qr<T: Scalar>(a: &Matrix<T>) -> Result<(Matrix<T>, Matrix<T>)> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::shape(format!("householder_qr needs m ≥ n, got {m}x{n}")));
    }
    let mut acc = a.clone();
    // per-column reflector (full-length v, β); β = 0 marks a skipped column
    let mut reflectors: Vec<(Vec<T>, T)> = Vec::with_capacity(n);
    for j in 0..n {
        let mut norm2 = T::ZERO;
        for i in j..m {
            let x = acc.get(i, j);
            norm2 += x * x;
        }
        let normx = norm2.sqrt();
        let mut v = vec![T::ZERO; m];
        if normx.to_f64() == 0.0 {
            reflectors.push((v, T::ZERO));
            continue;
        }
        let xj = acc.get(j, j);
        let alpha = if xj.to_f64() >= 0.0 { -normx } else { normx };
        for i in j..m {
            v[i] = acc.get(i, j);
        }
        v[j] -= alpha;
        let mut vnorm2 = T::ZERO;
        for &x in v.iter().take(m).skip(j) {
            vnorm2 += x * x;
        }
        if vnorm2.to_f64() <= 0.0 {
            reflectors.push((v, T::ZERO));
            continue;
        }
        let beta = (T::ONE + T::ONE) / vnorm2;
        for c in j..n {
            let mut dot = T::ZERO;
            for i in j..m {
                dot += v[i] * acc.get(i, c);
            }
            let s = beta * dot;
            for i in j..m {
                let cur = acc.get(i, c);
                acc.set(i, c, cur - v[i] * s);
            }
        }
        reflectors.push((v, beta));
    }
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for c in i..n {
            r.set(i, c, acc.get(i, c));
        }
    }
    // Q = H_0 · … · H_{n−1} · [I_n; 0]: reflectors applied in reverse
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, T::ONE);
    }
    for (j, (v, beta)) in reflectors.iter().enumerate().rev() {
        if beta.to_f64() == 0.0 {
            continue;
        }
        for c in 0..n {
            let mut dot = T::ZERO;
            for i in j..m {
                dot += v[i] * q.get(i, c);
            }
            let s = *beta * dot;
            for i in j..m {
                let cur = q.get(i, c);
                q.set(i, c, cur - v[i] * s);
            }
        }
    }
    Ok((q, r))
}

/// Square (n × n) R for the COALA preprocessing convention: zero-pads
/// when m < n so RᵀR = AᵀA always holds with a square R.
pub fn qr_r_square<T: Scalar>(a: &Matrix<T>) -> Result<Matrix<T>> {
    let n = a.cols;
    let r = householder_qr_r(a);
    if r.rows == n {
        return Ok(r);
    }
    let pad = Matrix::zeros(n - r.rows, n);
    r.vstack(&pad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{gram_t, matmul};

    fn gram_close<T: Scalar>(r: &Matrix<T>, a: &Matrix<T>, tol: f64) {
        let rt_r = matmul(&r.transpose(), r).unwrap();
        let at_a = gram_t(a);
        for (x, y) in rt_r.data.iter().zip(&at_a.data) {
            assert!(
                (x.to_f64() - y.to_f64()).abs() < tol * (1.0 + y.to_f64().abs()),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn qr_gram_identity_f64() {
        for (m, n, seed) in [(20usize, 8usize, 1u64), (8, 8, 2), (5, 9, 3), (100, 30, 4)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let r = householder_qr_r(&a);
            assert_eq!(r.rows, m.min(n));
            gram_close(&r, &a, 1e-10);
        }
    }

    #[test]
    fn qr_gram_identity_f32() {
        let a: Matrix<f32> = Matrix::randn(50, 20, 5);
        let r = householder_qr_r(&a);
        gram_close(&r, &a, 1e-3);
    }

    #[test]
    fn upper_triangular() {
        let a: Matrix<f64> = Matrix::randn(12, 7, 6);
        let r = householder_qr_r(&a);
        for i in 0..r.rows {
            for j in 0..i.min(r.cols) {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_is_finite() {
        let mut a: Matrix<f64> = Matrix::zeros(10, 4);
        for i in 0..10 {
            for j in 0..4 {
                a.set(i, j, (i + 1) as f64); // rank 1
            }
        }
        let r = householder_qr_r(&a);
        assert!(r.all_finite());
        gram_close(&r, &a, 1e-9);
    }

    #[test]
    fn explicit_q_reconstructs_and_is_orthonormal() {
        for (m, n, seed) in [(12usize, 5usize, 1u64), (7, 7, 2), (30, 10, 3)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let (q, r) = householder_qr(&a).unwrap();
            assert_eq!((q.rows, q.cols), (m, n));
            assert_eq!((r.rows, r.cols), (n, n));
            let qtq = matmul(&q.transpose(), &q).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq.get(i, j) - want).abs() < 1e-10, "QᵀQ[{i}][{j}]");
                }
            }
            let qr = matmul(&q, &r).unwrap();
            for (x, y) in qr.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-10 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
        // R agrees with the R-only sweep
        let a: Matrix<f64> = Matrix::randn(20, 6, 4);
        let (_q, r) = householder_qr(&a).unwrap();
        let r_only = householder_qr_r(&a);
        for (x, y) in r.data.iter().zip(&r_only.data) {
            assert!((x - y).abs() < 1e-12);
        }
        assert!(householder_qr(&Matrix::<f64>::zeros(3, 5)).is_err());
    }

    #[test]
    fn square_pads_wide() {
        let a: Matrix<f64> = Matrix::randn(3, 8, 7);
        let r = qr_r_square(&a).unwrap();
        assert_eq!((r.rows, r.cols), (8, 8));
        gram_close(&r, &a, 1e-10);
    }
}
