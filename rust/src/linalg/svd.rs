//! One-sided Jacobi SVD (Brent–Luk parallel ordering), host edition.
//!
//! Same algorithm the L2 graph runs on the PJRT runtime, so the two
//! implementations cross-validate.  Host edition adds a convergence test
//! (off-orthogonality threshold) since we are not bound to static HLO.

use crate::error::{Error, Result};
use crate::tensor::{Matrix, Scalar};

/// Thin SVD result: a = u · diag(s) · vᵀ, u is m × n, v is n × n.
#[derive(Debug, Clone)]
pub struct Svd<T: Scalar> {
    pub u: Matrix<T>,
    pub s: Vec<T>,
    pub v: Matrix<T>,
}

/// One-sided Jacobi SVD for m ≥ n (transpose externally for wide inputs).
///
/// Cyclic sweeps over all column pairs; each rotation zeroes one inner
/// product.  Converges when no pair exceeds `tol·‖aᵢ‖‖aⱼ‖` or after
/// `max_sweeps`.  Singular values are returned in descending order.
pub fn jacobi_svd<T: Scalar>(a: &Matrix<T>, max_sweeps: usize) -> Result<Svd<T>> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::shape(format!("jacobi_svd needs m ≥ n, got {m}x{n}")));
    }
    // column-major copies for cache-friendly column rotations
    let mut acol: Vec<Vec<T>> = (0..n).map(|j| a.col(j)).collect();
    let mut vcol: Vec<Vec<T>> = (0..n)
        .map(|j| {
            let mut e = vec![T::ZERO; n];
            e[j] = T::ONE;
            e
        })
        .collect();

    let tol = T::EPSILON.to_f64() * 8.0;
    for _sweep in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let xp = acol[p][i].to_f64();
                    let xq = acol[q][i].to_f64();
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                if apq.abs() <= tol * (app.sqrt() * aqq.sqrt()) {
                    continue;
                }
                rotated = true;
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cs, sn) = (T::from_f64(c), T::from_f64(s));
                for i in 0..m {
                    let xp = acol[p][i];
                    let xq = acol[q][i];
                    acol[p][i] = cs * xp - sn * xq;
                    acol[q][i] = sn * xp + cs * xq;
                }
                for i in 0..n {
                    let xp = vcol[p][i];
                    let xq = vcol[q][i];
                    vcol[p][i] = cs * xp - sn * xq;
                    vcol[q][i] = sn * xp + cs * xq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // singular values = column norms; sort descending with columns
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = acol
        .iter()
        .map(|c| c.iter().map(|x| x.to_f64() * x.to_f64()).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i])); // total_cmp: NaN-safe (failure studies feed NaNs through)

    let mut u = Matrix::zeros(m, n);
    let mut v = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (k, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(T::from_f64(nj));
        let inv = if nj > 0.0 { 1.0 / nj } else { 0.0 };
        for i in 0..m {
            u.set(i, k, T::from_f64(acol[j][i].to_f64() * inv));
        }
        for i in 0..n {
            v.set(i, k, vcol[j][i]);
        }
    }
    Ok(Svd { u, s, v })
}

impl<T: Scalar> Svd<T> {
    /// Reconstruct u[:, :r] · diag(s[:r]) · v[:, :r]ᵀ.
    pub fn truncate(&self, r: usize) -> Matrix<T> {
        let (m, n) = (self.u.rows, self.v.rows);
        let r = r.min(self.s.len());
        let mut out = Matrix::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            for i in 0..m {
                let uik = self.u.get(i, k) * sk;
                for j in 0..n {
                    let cur = out.get(i, j);
                    out.set(i, j, cur + uik * self.v.get(j, k));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, matmul};

    fn reconstruct<T: Scalar>(svd: &Svd<T>) -> Matrix<T> {
        svd.truncate(svd.s.len())
    }

    #[test]
    fn reconstructs_f64() {
        for (m, n, seed) in [(10usize, 6usize, 1u64), (8, 8, 2), (40, 15, 3)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let svd = jacobi_svd(&a, 30).unwrap();
            let diff = reconstruct(&svd).sub(&a).unwrap();
            assert!(fro(&diff) < 1e-9 * fro(&a), "m={m} n={n}: {}", fro(&diff));
        }
    }

    #[test]
    fn orthogonal_factors() {
        let a: Matrix<f64> = Matrix::randn(20, 9, 4);
        let svd = jacobi_svd(&a, 30).unwrap();
        let utu = matmul(&svd.u.transpose(), &svd.u).unwrap();
        let vtv = matmul(&svd.v.transpose(), &svd.v).unwrap();
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.get(i, j) - want).abs() < 1e-10);
                assert!((vtv.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn descending_and_nonnegative() {
        let a: Matrix<f64> = Matrix::randn(15, 10, 5);
        let svd = jacobi_svd(&a, 30).unwrap();
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn rank_deficient() {
        let u: Matrix<f64> = Matrix::randn(12, 2, 6);
        let v: Matrix<f64> = Matrix::randn(2, 7, 7);
        let a = matmul(&u, &v).unwrap();
        let svd = jacobi_svd(&a, 30).unwrap();
        assert!(svd.s[1] > 1e-8);
        for k in 2..7 {
            assert!(svd.s[k] < 1e-9, "s[{k}]={}", svd.s[k]);
        }
    }

    #[test]
    fn matches_known_2x2() {
        // A = [[3, 0], [4, 5]] has σ = √45, √5
        let a: Matrix<f64> = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 5.0]).unwrap();
        let svd = jacobi_svd(&a, 30).unwrap();
        assert!((svd.s[0] - 45f64.sqrt()).abs() < 1e-12);
        assert!((svd.s[1] - 5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn wide_rejected() {
        let a: Matrix<f64> = Matrix::zeros(2, 5);
        assert!(jacobi_svd(&a, 5).is_err());
    }

    #[test]
    fn truncate_rank() {
        let a: Matrix<f64> = Matrix::randn(10, 6, 8);
        let svd = jacobi_svd(&a, 30).unwrap();
        let t2 = svd.truncate(2);
        // best rank-2 error equals sqrt(sum of trailing σ²)
        let err = fro(&t2.sub(&a).unwrap());
        let want: f64 = svd.s[2..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err - want).abs() < 1e-9);
    }
}
