//! One-sided Jacobi SVD, host edition — blocked and parallel.
//!
//! Same algorithm family the L2 graph runs on the PJRT runtime, so the
//! two implementations cross-validate.  The host edition is built for
//! raw speed without giving up a single determinism guarantee:
//!
//! * **QR preconditioning** — tall inputs (m > n) are first reduced by
//!   the compact-WY blocked QR ([`crate::linalg::qr::householder_qr`]);
//!   all Jacobi work happens on the n × n R factor and U is recovered
//!   as Q·U_R with one packed GEMM.  Per-sweep cost drops from
//!   O(m·n²) to O(n³), plus one O(m·n²) QR for the whole call.
//! * **Cached column norms** — the classic per-pair rescan recomputes
//!   three length-m inner products; only ⟨a_p, a_q⟩ actually needs the
//!   scan.  ‖a_p‖² and ‖a_q‖² are cached and updated by the rotation
//!   identities (a′pp = app − t·apq, a′qq = aqq + t·apq), with an exact
//!   refresh at every sweep start to keep fp drift bounded.
//! * **Brent–Luk parallel ordering** — each sweep is the fixed
//!   round-robin tournament schedule: n−1 rounds of ⌊n/2⌋ pairwise-
//!   disjoint rotations.  Rotations within a round touch disjoint
//!   column pairs, so they fan across threads with a barrier between
//!   rounds; the schedule is static and the per-pair arithmetic is
//!   sequential, so results are **bitwise identical at every worker
//!   count** (including 1).  Thread count comes from
//!   [`crate::util::threads::default_workers`], gated by
//!   `COALA_SVD_PAR_COLS`, and collapses to 1 inside an engine worker
//!   ([`crate::util::threads::in_worker`]) to avoid oversubscription.
//!
//! Wide inputs (m < n) are handled by factoring the transpose and
//! swapping U/V on the way out — callers never special-case the aspect
//! ratio.  [`jacobi_svd_cyclic`] keeps the original cyclic-order,
//! rescan-per-pair implementation as the property-test oracle and the
//! bench baseline for the `svd blocked/naive` ratio.

use crate::error::{Error, Result};
use crate::linalg::qr::householder_qr;
use crate::tensor::ops::{matmul, matmul_nt};
use crate::tensor::{Matrix, Scalar};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

/// Thin SVD result: a = u · diag(s) · vᵀ with k = min(m, n) columns:
/// u is m × k, v is n × k (for tall inputs k = n and v is square).
#[derive(Debug, Clone)]
pub struct Svd<T: Scalar> {
    pub u: Matrix<T>,
    pub s: Vec<T>,
    pub v: Matrix<T>,
}

/// Default `COALA_SVD_PAR_COLS`: narrower Jacobi problems stay
/// sequential — a round of an n-column schedule only carries
/// ⌊n/2⌋·O(n) flops, and below this size the round barrier costs more
/// than the fan saves.
pub const DEFAULT_SVD_PAR_COLS: usize = 192;

/// Process-global count of completed Jacobi sweeps (one-sided SVD and
/// two-sided [`crate::linalg::eigh`]), monotone over the process
/// lifetime.  The pipeline's telemetry reads a before/after delta
/// around the factorize stage and emits it as the `svd_sweeps` counter;
/// the total is an atomic sum of per-call sweep counts, so it is
/// deterministic for a run regardless of worker interleaving.
static SWEEP_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Current value of the process-global Jacobi sweep counter.
pub fn svd_sweep_total() -> u64 {
    SWEEP_TOTAL.load(Ordering::Relaxed)
}

/// Credit `n` completed sweeps to the global counter (also called by
/// `linalg::eigh`, which runs the two-sided variant of the same
/// rotation core).
pub(crate) fn note_sweeps(n: u64) {
    SWEEP_TOTAL.fetch_add(n, Ordering::Relaxed);
}

/// The 2×2 Jacobi rotation core shared by the one-sided SVD and the
/// two-sided [`crate::linalg::eigh`]: given the implicit 2×2 Gram block
/// [[app, apq], [apq, aqq]] with apq ≠ 0, returns (c, s, t) — cosine,
/// sine, and tangent of the rotation that annihilates apq.  The smaller
/// root is chosen (|t| ≤ 1), which keeps the rotation closest to the
/// identity and the iteration numerically stable.
pub(crate) fn jacobi_coeffs(app: f64, aqq: f64, apq: f64) -> (f64, f64, f64) {
    let tau = (aqq - app) / (2.0 * apq);
    let t = if tau >= 0.0 {
        1.0 / (tau + (1.0 + tau * tau).sqrt())
    } else {
        -1.0 / (-tau + (1.0 + tau * tau).sqrt())
    };
    let c = 1.0 / (1.0 + t * t).sqrt();
    (c, c * t, t)
}

/// One-sided Jacobi SVD for any aspect ratio.
///
/// Sweeps follow the Brent–Luk round-robin ordering; each rotation
/// zeroes one column inner product.  Converges when no pair exceeds
/// `tol·‖aᵢ‖‖aⱼ‖` or after `max_sweeps`.  Singular values are returned
/// in descending order.  Wide inputs factor the transpose internally
/// (U and V swap); tall inputs are QR-preconditioned first (disable
/// with `COALA_SVD_QR_PRECOND=0` to A/B the fp-level difference).
///
/// The parallel fan engages when the Jacobi problem has at least
/// `COALA_SVD_PAR_COLS` columns (strictly parsed; default
/// [`DEFAULT_SVD_PAR_COLS`]) and the call is not already inside an
/// engine worker.  Results are bitwise identical at every worker count.
pub fn jacobi_svd<T: Scalar>(a: &Matrix<T>, max_sweeps: usize) -> Result<Svd<T>> {
    jacobi_dispatch(a, max_sweeps, None)
}

/// [`jacobi_svd`] with an explicit rotation-fan worker count (benches
/// and the determinism tests; normal callers let the env knobs decide).
pub fn jacobi_svd_with_workers<T: Scalar>(
    a: &Matrix<T>,
    max_sweeps: usize,
    workers: usize,
) -> Result<Svd<T>> {
    jacobi_dispatch(a, max_sweeps, Some(workers.max(1)))
}

fn jacobi_dispatch<T: Scalar>(
    a: &Matrix<T>,
    max_sweeps: usize,
    workers: Option<usize>,
) -> Result<Svd<T>> {
    if a.rows < a.cols {
        // wide: aᵀ = U·diag(s)·Vᵀ ⇒ a = V·diag(s)·Uᵀ
        let t = jacobi_dispatch(&a.transpose(), max_sweeps, workers)?;
        return Ok(Svd { u: t.v, s: t.s, v: t.u });
    }
    let (m, n) = (a.rows, a.cols);
    if crate::util::env::flag_or("COALA_SVD_QR_PRECOND", true)? && m > n && n > 0 {
        let (q, r) = householder_qr(a)?;
        let core = jacobi_core(&r, max_sweeps, workers)?;
        return Ok(Svd { u: matmul(&q, &core.u)?, s: core.s, v: core.v });
    }
    jacobi_core(a, max_sweeps, workers)
}

/// The Brent–Luk tournament: n−1 rounds (n padded to even) in which
/// every unordered column pair appears exactly once and the pairs of a
/// round are mutually disjoint.  Player 0 is pinned; the rest rotate
/// one seat per round (the classic circle method).
fn round_robin(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let np = n + (n % 2); // odd n gets a bye seat
    let mut others: Vec<usize> = (1..np).collect();
    let mut rounds = Vec::with_capacity(np - 1);
    for _ in 0..np - 1 {
        let mut ids = Vec::with_capacity(np);
        ids.push(0);
        ids.extend_from_slice(&others);
        let mut pairs = Vec::with_capacity(np / 2);
        for i in 0..np / 2 {
            let (a, b) = (ids[i], ids[np - 1 - i]);
            let (p, q) = if a < b { (a, b) } else { (b, a) };
            if q < n {
                pairs.push((p, q)); // drop pairs involving the bye seat
            }
        }
        rounds.push(pairs);
        others.rotate_right(1);
    }
    rounds
}

/// Shared mutable column storage for one Jacobi run.  Safety argument:
/// within a round every column index appears in at most one pair (the
/// tournament schedule is a perfect matching), workers only touch the
/// columns/norms of their own pairs, and a barrier separates rounds —
/// so no two threads ever alias a column and all writes are ordered by
/// the barrier before the next read.
struct JacobiCols<T> {
    a: *mut T,
    m: usize,
    v: *mut T,
    n: usize,
    norms: *mut f64,
}

unsafe impl<T: Send> Sync for JacobiCols<T> {}

impl<T: Scalar> JacobiCols<T> {
    /// Column j of the working matrix (length m, column-major).
    #[allow(clippy::mut_from_ref)]
    unsafe fn acol(&self, j: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.a.add(j * self.m), self.m)
    }

    /// Column j of the accumulated right factor (length n).
    #[allow(clippy::mut_from_ref)]
    unsafe fn vcol(&self, j: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.v.add(j * self.n), self.n)
    }

    unsafe fn norm(&self, j: usize) -> *mut f64 {
        self.norms.add(j)
    }
}

/// One pair's work inside a round: test convergence against the cached
/// norms, rotate both columns, update the cached norms by the rotation
/// identities.  Exactly the arithmetic of the cyclic reference minus
/// the two redundant norm scans.
fn rotate_pair<T: Scalar>(cols: &JacobiCols<T>, p: usize, q: usize, tol: f64, rotated: &AtomicBool) {
    let (ap, aq) = unsafe { (cols.acol(p), cols.acol(q)) };
    let mut apq = 0.0f64;
    for i in 0..ap.len() {
        apq += ap[i].to_f64() * aq[i].to_f64();
    }
    let (app, aqq) = unsafe { (*cols.norm(p), *cols.norm(q)) };
    if apq.abs() <= tol * (app.sqrt() * aqq.sqrt()) {
        return;
    }
    rotated.store(true, Ordering::Relaxed);
    let (c, s, t) = jacobi_coeffs(app, aqq, apq);
    let (cs, sn) = (T::from_f64(c), T::from_f64(s));
    for i in 0..ap.len() {
        let (xp, xq) = (ap[i], aq[i]);
        ap[i] = cs * xp - sn * xq;
        aq[i] = sn * xp + cs * xq;
    }
    let (vp, vq) = unsafe { (cols.vcol(p), cols.vcol(q)) };
    for i in 0..vp.len() {
        let (xp, xq) = (vp[i], vq[i]);
        vp[i] = cs * xp - sn * xq;
        vq[i] = sn * xp + cs * xq;
    }
    // rotation identities; clamped — the true values are column norm
    // squares and cannot go negative, only fp drift can
    unsafe {
        *cols.norm(p) = (app - t * apq).max(0.0);
        *cols.norm(q) = (aqq + t * apq).max(0.0);
    }
}

/// The blocked/parallel Jacobi iteration for m ≥ n (aspect handled by
/// the dispatcher).
fn jacobi_core<T: Scalar>(
    a: &Matrix<T>,
    max_sweeps: usize,
    workers: Option<usize>,
) -> Result<Svd<T>> {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    let w = match workers {
        Some(w) => w,
        None => {
            let par_cols = match crate::util::env::parse::<usize>("COALA_SVD_PAR_COLS")? {
                Some(0) => {
                    return Err(Error::Config("COALA_SVD_PAR_COLS: must be ≥ 1, got `0`".into()))
                }
                Some(k) => k,
                None => DEFAULT_SVD_PAR_COLS,
            };
            if n >= par_cols && !crate::util::threads::in_worker() {
                crate::util::threads::default_workers()
            } else {
                1
            }
        }
    }
    .max(1)
    .min((n / 2).max(1));

    // column-major working copies for cache-friendly column rotations
    let mut abuf: Vec<T> = vec![T::ZERO; m * n];
    for j in 0..n {
        for i in 0..m {
            abuf[j * m + i] = a.get(i, j);
        }
    }
    let mut vbuf: Vec<T> = vec![T::ZERO; n * n];
    for j in 0..n {
        vbuf[j * n + j] = T::ONE;
    }
    let mut norms: Vec<f64> = vec![0.0; n];

    let rounds = round_robin(n);
    let tol = T::EPSILON.to_f64() * 8.0;
    let cols = JacobiCols {
        a: abuf.as_mut_ptr(),
        m,
        v: vbuf.as_mut_ptr(),
        n,
        norms: norms.as_mut_ptr(),
    };
    let barrier = Barrier::new(w);
    let rotated = AtomicBool::new(false);
    let sweeps_run = AtomicU64::new(0);
    let converged = AtomicBool::new(false);

    let worker = |wid: usize| {
        for _sweep in 0..max_sweeps {
            // exact norm refresh: static column slices, then a barrier
            let mut j = wid;
            while j < n {
                let col = unsafe { cols.acol(j) };
                let mut s2 = 0.0f64;
                for x in col.iter() {
                    let xf = x.to_f64();
                    s2 += xf * xf;
                }
                unsafe { *cols.norm(j) = s2 };
                j += w;
            }
            barrier.wait();
            for round in &rounds {
                let mut k = wid;
                while k < round.len() {
                    let (p, q) = round[k];
                    rotate_pair(&cols, p, q, tol, &rotated);
                    k += w;
                }
                barrier.wait();
            }
            if wid == 0 {
                sweeps_run.fetch_add(1, Ordering::Relaxed);
            }
            // every worker reads the same flag between these barriers,
            // so the break decision is uniform; worker 0 resets it and
            // the next sweep's refresh barrier orders the reset before
            // any new store
            let any = rotated.load(Ordering::Relaxed);
            barrier.wait();
            if !any {
                if wid == 0 {
                    converged.store(true, Ordering::Relaxed);
                }
                break;
            }
            if wid == 0 {
                rotated.store(false, Ordering::Relaxed);
            }
        }
    };

    if w == 1 {
        worker(0);
    } else {
        std::thread::scope(|s| {
            let worker = &worker;
            for wid in 1..w {
                s.spawn(move || worker(wid));
            }
            worker(0);
        });
    }
    note_sweeps(sweeps_run.load(Ordering::Relaxed));

    // singular values = exact final column norms; sort descending with
    // columns (total_cmp: NaN-safe — failure studies feed NaNs through)
    let norms_f: Vec<f64> = (0..n)
        .map(|j| {
            let mut s2 = 0.0f64;
            for i in 0..m {
                let x = abuf[j * m + i].to_f64();
                s2 += x * x;
            }
            s2.sqrt()
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| norms_f[j].total_cmp(&norms_f[i]));

    // health probe: σ_max/σ_min are the sorted final column norms, the
    // sweep count and convergence flag already exist — pure reads
    if crate::telemetry::health::enabled() {
        let smax = order.first().map(|&j| norms_f[j]).unwrap_or(0.0);
        let smin = order.last().map(|&j| norms_f[j]).unwrap_or(0.0);
        crate::telemetry::health::note(
            crate::telemetry::health::HealthEvent::new("svd")
                .num("sweeps", sweeps_run.load(Ordering::Relaxed) as f64)
                .num("converged", if converged.load(Ordering::Relaxed) { 1.0 } else { 0.0 })
                .num("sigma_max", smax)
                .num("sigma_min", smin)
                .num("cols", n as f64),
        );
    }

    let mut u = Matrix::zeros(m, n);
    let mut v = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (k, &j) in order.iter().enumerate() {
        let nj = norms_f[j];
        s.push(T::from_f64(nj));
        let inv = if nj > 0.0 { 1.0 / nj } else { 0.0 };
        for i in 0..m {
            u.set(i, k, T::from_f64(abuf[j * m + i].to_f64() * inv));
        }
        for i in 0..n {
            v.set(i, k, vbuf[j * n + i]);
        }
    }
    Ok(Svd { u, s, v })
}

/// The original cyclic-order Jacobi with per-pair norm rescans — kept
/// verbatim as the property-test oracle and the `svd blocked/naive`
/// bench baseline.  Requires m ≥ n (transpose externally); the fast
/// path ([`jacobi_svd`]) has no such restriction.
pub fn jacobi_svd_cyclic<T: Scalar>(a: &Matrix<T>, max_sweeps: usize) -> Result<Svd<T>> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::shape(format!("jacobi_svd_cyclic needs m ≥ n, got {m}x{n}")));
    }
    let mut acol: Vec<Vec<T>> = (0..n).map(|j| a.col(j)).collect();
    let mut vcol: Vec<Vec<T>> = (0..n)
        .map(|j| {
            let mut e = vec![T::ZERO; n];
            e[j] = T::ONE;
            e
        })
        .collect();

    let tol = T::EPSILON.to_f64() * 8.0;
    for _sweep in 0..max_sweeps {
        let mut any = false;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let xp = acol[p][i].to_f64();
                    let xq = acol[q][i].to_f64();
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                if apq.abs() <= tol * (app.sqrt() * aqq.sqrt()) {
                    continue;
                }
                any = true;
                let (c, s, _t) = jacobi_coeffs(app, aqq, apq);
                let (cs, sn) = (T::from_f64(c), T::from_f64(s));
                for i in 0..m {
                    let xp = acol[p][i];
                    let xq = acol[q][i];
                    acol[p][i] = cs * xp - sn * xq;
                    acol[q][i] = sn * xp + cs * xq;
                }
                for i in 0..n {
                    let xp = vcol[p][i];
                    let xq = vcol[q][i];
                    vcol[p][i] = cs * xp - sn * xq;
                    vcol[q][i] = sn * xp + cs * xq;
                }
            }
        }
        if !any {
            break;
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = acol
        .iter()
        .map(|c| c.iter().map(|x| x.to_f64() * x.to_f64()).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i]));

    let mut u = Matrix::zeros(m, n);
    let mut v = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (k, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(T::from_f64(nj));
        let inv = if nj > 0.0 { 1.0 / nj } else { 0.0 };
        for i in 0..m {
            u.set(i, k, T::from_f64(acol[j][i].to_f64() * inv));
        }
        for i in 0..n {
            v.set(i, k, vcol[j][i]);
        }
    }
    Ok(Svd { u, s, v })
}

impl<T: Scalar> Svd<T> {
    /// Reconstruct u[:, :r] · diag(s[:r]) · v[:, :r]ᵀ as one packed
    /// GEMM: scale U's leading columns by σ, then one `matmul_nt`
    /// against V's leading columns.
    pub fn truncate(&self, r: usize) -> Matrix<T> {
        let r = r.min(self.s.len());
        if r == 0 {
            return Matrix::zeros(self.u.rows, self.v.rows);
        }
        let mut us = self.u.first_cols(r);
        for k in 0..r {
            let sk = self.s[k];
            for i in 0..us.rows {
                us.set(i, k, us.get(i, k) * sk);
            }
        }
        matmul_nt(&us, &self.v.first_cols(r)).expect("truncate: U/V column counts agree")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::fro;

    fn reconstruct<T: Scalar>(svd: &Svd<T>) -> Matrix<T> {
        svd.truncate(svd.s.len())
    }

    #[test]
    fn reconstructs_f64() {
        for (m, n, seed) in [(10usize, 6usize, 1u64), (8, 8, 2), (40, 15, 3)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let svd = jacobi_svd(&a, 30).unwrap();
            let diff = reconstruct(&svd).sub(&a).unwrap();
            assert!(fro(&diff) < 1e-9 * fro(&a), "m={m} n={n}: {}", fro(&diff));
        }
    }

    #[test]
    fn orthogonal_factors() {
        let a: Matrix<f64> = Matrix::randn(20, 9, 4);
        let svd = jacobi_svd(&a, 30).unwrap();
        let utu = matmul(&svd.u.transpose(), &svd.u).unwrap();
        let vtv = matmul(&svd.v.transpose(), &svd.v).unwrap();
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.get(i, j) - want).abs() < 1e-10);
                assert!((vtv.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn descending_and_nonnegative() {
        let a: Matrix<f64> = Matrix::randn(15, 10, 5);
        let svd = jacobi_svd(&a, 30).unwrap();
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn rank_deficient() {
        let u: Matrix<f64> = Matrix::randn(12, 2, 6);
        let v: Matrix<f64> = Matrix::randn(2, 7, 7);
        let a = matmul(&u, &v).unwrap();
        let svd = jacobi_svd(&a, 30).unwrap();
        assert!(svd.s[1] > 1e-8);
        for k in 2..7 {
            assert!(svd.s[k] < 1e-9, "s[{k}]={}", svd.s[k]);
        }
    }

    #[test]
    fn matches_known_2x2() {
        // A = [[3, 0], [4, 5]] has σ = √45, √5
        let a: Matrix<f64> = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 5.0]).unwrap();
        let svd = jacobi_svd(&a, 30).unwrap();
        assert!((svd.s[0] - 45f64.sqrt()).abs() < 1e-12);
        assert!((svd.s[1] - 5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn wide_inputs_factor_through_the_transpose() {
        let a: Matrix<f64> = Matrix::randn(4, 11, 9);
        let svd = jacobi_svd(&a, 30).unwrap();
        assert_eq!((svd.u.rows, svd.u.cols), (4, 4));
        assert_eq!((svd.v.rows, svd.v.cols), (11, 4));
        assert_eq!(svd.s.len(), 4);
        let diff = reconstruct(&svd).sub(&a).unwrap();
        assert!(fro(&diff) < 1e-10 * fro(&a), "{}", fro(&diff));
        // U and V swap relative to the transposed problem, bit for bit
        let t = jacobi_svd(&a.transpose(), 30).unwrap();
        assert_eq!(svd.u.data, t.v.data);
        assert_eq!(svd.v.data, t.u.data);
    }

    #[test]
    fn truncate_rank() {
        let a: Matrix<f64> = Matrix::randn(10, 6, 8);
        let svd = jacobi_svd(&a, 30).unwrap();
        let t2 = svd.truncate(2);
        // best rank-2 error equals sqrt(sum of trailing σ²)
        let err = fro(&t2.sub(&a).unwrap());
        let want: f64 = svd.s[2..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err - want).abs() < 1e-9);
    }

    #[test]
    fn worker_count_never_changes_a_bit() {
        for (m, n, seed) in [(40usize, 17usize, 11u64), (33, 33, 12), (9, 24, 13)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let one = jacobi_svd_with_workers(&a, 30, 1).unwrap();
            for w in [2usize, 3, 8] {
                let many = jacobi_svd_with_workers(&a, 30, w).unwrap();
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&one.u.data), bits(&many.u.data), "{m}x{n} w={w}: U");
                assert_eq!(bits(&one.v.data), bits(&many.v.data), "{m}x{n} w={w}: V");
                assert_eq!(
                    one.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    many.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{m}x{n} w={w}: σ"
                );
            }
        }
    }

    #[test]
    fn blocked_matches_cyclic_reference() {
        for (m, n, seed) in [(24usize, 10usize, 21u64), (16, 16, 22), (50, 8, 23)] {
            let a: Matrix<f64> = Matrix::randn(m, n, seed);
            let fast = jacobi_svd(&a, 40).unwrap();
            let slow = jacobi_svd_cyclic(&a, 40).unwrap();
            for (sf, ss) in fast.s.iter().zip(&slow.s) {
                assert!((sf - ss).abs() < 1e-9 * (1.0 + ss), "{m}x{n}: {sf} vs {ss}");
            }
            // same subspaces: reconstructions agree even if signs differ
            let diff = reconstruct(&fast).sub(&reconstruct(&slow)).unwrap();
            assert!(fro(&diff) < 1e-9 * (1.0 + fro(&a)));
        }
    }

    #[test]
    fn near_singular_still_factors() {
        // two nearly parallel column clusters: σ spans ~8 orders
        let mut a: Matrix<f64> = Matrix::randn(30, 6, 31);
        for i in 0..30 {
            let base = a.get(i, 0);
            a.set(i, 1, base + 1e-8 * a.get(i, 1));
        }
        let svd = jacobi_svd(&a, 60).unwrap();
        assert!(svd.u.all_finite() && svd.v.all_finite());
        let diff = reconstruct(&svd).sub(&a).unwrap();
        assert!(fro(&diff) < 1e-9 * fro(&a));
        let slow = jacobi_svd_cyclic(&a, 60).unwrap();
        for (sf, ss) in svd.s.iter().zip(&slow.s) {
            assert!((sf - ss).abs() < 1e-8 * (1.0 + ss), "{sf} vs {ss}");
        }
    }

    #[test]
    fn sweep_counter_is_monotone() {
        let before = svd_sweep_total();
        let a: Matrix<f64> = Matrix::randn(12, 6, 41);
        jacobi_svd(&a, 30).unwrap();
        assert!(svd_sweep_total() > before, "an SVD call must credit at least one sweep");
    }
}
