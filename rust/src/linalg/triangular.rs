//! Triangular solves by substitution (baseline substrate: the Gram-based
//! methods need S⁻¹ applied; COALA never does).

use crate::error::{Error, Result};
use crate::tensor::{Matrix, Scalar};

fn check<T: Scalar>(t: &Matrix<T>, b: &Matrix<T>) -> Result<usize> {
    let n = t.rows;
    if t.cols != n {
        return Err(Error::shape(format!("triangular solve: T is {}x{}", t.rows, t.cols)));
    }
    if b.rows != n {
        return Err(Error::shape(format!("triangular solve: B is {}x{} for n={n}", b.rows, b.cols)));
    }
    Ok(n)
}

/// Solve L·X = B for lower-triangular L (forward substitution).
pub fn solve_lower<T: Scalar>(l: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    let n = check(l, b)?;
    let k = b.cols;
    let mut x = b.clone();
    for i in 0..n {
        for r in 0..i {
            let lir = l.get(i, r);
            for c in 0..k {
                let cur = x.get(i, c);
                x.set(i, c, cur - lir * x.get(r, c));
            }
        }
        let d = l.get(i, i);
        for c in 0..k {
            let cur = x.get(i, c);
            x.set(i, c, cur / d);
        }
    }
    Ok(x)
}

/// Solve U·X = B for upper-triangular U (back substitution).
pub fn solve_upper<T: Scalar>(u: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    let n = check(u, b)?;
    let k = b.cols;
    let mut x = b.clone();
    for ii in 0..n {
        let i = n - 1 - ii;
        for r in (i + 1)..n {
            let uir = u.get(i, r);
            for c in 0..k {
                let cur = x.get(i, c);
                x.set(i, c, cur - uir * x.get(r, c));
            }
        }
        let d = u.get(i, i);
        for c in 0..k {
            let cur = x.get(i, c);
            x.set(i, c, cur / d);
        }
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro, matmul};
    use crate::tensor::Matrix;

    fn lower(n: usize, seed: u64) -> Matrix<f64> {
        let mut m: Matrix<f64> = Matrix::randn(n, n, seed);
        for i in 0..n {
            for j in (i + 1)..n {
                m.set(i, j, 0.0);
            }
            m.set(i, i, m.get(i, i) + 4.0);
        }
        m
    }

    #[test]
    fn forward_solve() {
        let l = lower(9, 1);
        let b: Matrix<f64> = Matrix::randn(9, 4, 2);
        let x = solve_lower(&l, &b).unwrap();
        assert!(fro(&matmul(&l, &x).unwrap().sub(&b).unwrap()) < 1e-10);
    }

    #[test]
    fn backward_solve() {
        let u = lower(7, 3).transpose();
        let b: Matrix<f64> = Matrix::randn(7, 3, 4);
        let x = solve_upper(&u, &b).unwrap();
        assert!(fro(&matmul(&u, &x).unwrap().sub(&b).unwrap()) < 1e-10);
    }

    #[test]
    fn transpose_pair_solves_gram_system() {
        // (L Lᵀ) X = B solved as two substitutions
        let l = lower(6, 5);
        let g = matmul(&l, &l.transpose()).unwrap();
        let b: Matrix<f64> = Matrix::randn(6, 2, 6);
        let y = solve_lower(&l, &b).unwrap();
        let x = solve_upper(&l.transpose(), &y).unwrap();
        assert!(fro(&matmul(&g, &x).unwrap().sub(&b).unwrap()) < 1e-9);
    }

    #[test]
    fn shape_errors() {
        let l = lower(4, 7);
        let b: Matrix<f64> = Matrix::zeros(5, 1);
        assert!(solve_lower(&l, &b).is_err());
        assert!(solve_upper(&Matrix::<f64>::zeros(3, 4), &Matrix::zeros(3, 1)).is_err());
    }
}
