//! TSQR (Tall-Skinny QR) — the paper's §4.2 out-of-core preprocessing.
//!
//! Host reference edition of the streaming and binary-tree variants; the
//! production path runs the same algorithm through the `tsqr_step` /
//! `tsqr_merge` PJRT artifacts orchestrated by `coordinator::tsqr_tree`,
//! and the host fallback route drives [`TsqrFolder`] through
//! `calib::accumulate`.

use crate::error::{Error, Result};
use crate::linalg::qr::{householder_triangularize, qr_r_square};
use crate::tensor::{Matrix, Scalar};
use crate::util::threads;

/// Streaming TSQR state with a reusable scratch buffer.
///
/// Folding a (c × n) chunk into the running R factorizes the stacked
/// (n + c) × n matrix `[R ; chunk]`.  The naive formulation re-allocates
/// that stack (and the QR working copy) on every fold; `TsqrFolder`
/// instead keeps one (n + c_max) × n scratch matrix and one reflector
/// workspace alive across folds, so steady-state folding is
/// allocation-free (`benches/kernels.rs` measures the delta).
pub struct TsqrFolder<T: Scalar> {
    n: usize,
    /// rows 0..n hold the current R (upper triangular); rows n.. are the
    /// chunk landing zone.
    scratch: Matrix<T>,
    /// Householder reflector workspace (len = scratch.rows).
    v: Vec<T>,
}

impl<T: Scalar> TsqrFolder<T> {
    /// Folder for n-column chunks; scratch sized for `chunk_capacity`
    /// rows per fold (grows automatically if a bigger chunk arrives).
    pub fn with_chunk_capacity(n: usize, chunk_capacity: usize) -> TsqrFolder<T> {
        let rows = n + chunk_capacity.max(1);
        TsqrFolder { n, scratch: Matrix::zeros(rows, n), v: vec![T::ZERO; rows] }
    }

    pub fn new(n: usize) -> TsqrFolder<T> {
        TsqrFolder::with_chunk_capacity(n, n)
    }

    /// Resume from an existing square R factor (RᵀR = partial XXᵀ): the
    /// seed is copied into the scratch head, costing no QR.
    pub fn from_r(r: &Matrix<T>) -> TsqrFolder<T> {
        let n = r.cols;
        debug_assert_eq!(r.rows, n, "TsqrFolder seeds from a square R");
        let mut folder = TsqrFolder::new(n);
        for i in 0..n.min(r.rows) {
            for j in 0..n {
                folder.scratch.set(i, j, r.get(i, j));
            }
        }
        folder
    }

    /// Fold one (c × n) row-block of Xᵀ into the running R.
    pub fn fold(&mut self, chunk: &Matrix<T>) -> Result<()> {
        let n = self.n;
        if chunk.cols != n {
            return Err(Error::shape(format!(
                "tsqr fold: chunk has {} cols, folder is {n}-wide",
                chunk.cols
            )));
        }
        let m = n + chunk.rows;
        if self.scratch.rows < m {
            // preserve R, grow the landing zone
            let mut bigger = Matrix::zeros(m, n);
            for i in 0..n {
                for j in i..n {
                    bigger.set(i, j, self.scratch.get(i, j));
                }
            }
            self.scratch = bigger;
            self.v.resize(m, T::ZERO);
        }
        // previous triangularization leaves reflector residue below the
        // diagonal — the stacked matrix is [R ; chunk], so clear it
        for i in 1..n {
            for j in 0..i.min(n) {
                self.scratch.set(i, j, T::ZERO);
            }
        }
        for i in 0..chunk.rows {
            for j in 0..n {
                self.scratch.set(n + i, j, chunk.get(i, j));
            }
        }
        householder_triangularize(&mut self.scratch, m, &mut self.v);
        Ok(())
    }

    /// Merge another square R (same convention: RᵀR = partial XXᵀ).
    pub fn merge_r(&mut self, other: &Matrix<T>) -> Result<()> {
        self.fold(other)
    }

    /// Copy out the current square n × n R factor.
    pub fn r(&self) -> Matrix<T> {
        let n = self.n;
        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r.set(i, j, self.scratch.get(i, j));
            }
        }
        r
    }

    /// Final R, consuming the folder.
    pub fn finish(self) -> Matrix<T> {
        self.r()
    }
}

/// Streaming (sequential) TSQR: fold chunks of Xᵀ into a running R.
///
/// `chunks` are (cᵢ × n) row-blocks of Xᵀ.  Returns square R with
/// RᵀR = Σ chunkᵢᵀ chunkᵢ = XXᵀ.  Peak memory is one chunk + the folder
/// scratch — this is how a calibration matrix larger than device memory
/// is processed.
pub fn tsqr_sequential<T: Scalar>(chunks: &[Matrix<T>]) -> Result<Matrix<T>> {
    assert!(!chunks.is_empty());
    let n = chunks[0].cols;
    let c_max = chunks.iter().map(|c| c.rows).max().unwrap_or(1);
    let mut folder = TsqrFolder::with_chunk_capacity(n, c_max);
    for c in chunks {
        folder.fold(c)?;
    }
    Ok(folder.finish())
}

/// Binary-tree TSQR: leaf QRs in parallel, then pairwise R merges.
///
/// The reduction shape matches the paper's multi-GPU diagram; here leaves
/// run on `workers` threads (simulated devices).
pub fn tsqr_tree<T: Scalar>(chunks: &[Matrix<T>], workers: usize) -> Result<Matrix<T>> {
    assert!(!chunks.is_empty());
    // leaf level
    let mut level: Vec<Matrix<T>> = threads::parallel_map(chunks.len(), workers, |i| {
        qr_r_square(&chunks[i]).expect("leaf qr")
    });
    // reduction levels
    while level.len() > 1 {
        let pairs = level.len() / 2;
        let odd = level.len() % 2 == 1;
        let merged: Vec<Matrix<T>> = {
            let level_ref = &level;
            threads::parallel_map(pairs, workers, |i| {
                let stacked = level_ref[2 * i].vstack(&level_ref[2 * i + 1]).expect("stack");
                qr_r_square(&stacked).expect("merge qr")
            })
        };
        let mut next = merged;
        if odd {
            next.push(level.pop().unwrap());
        }
        level = next;
    }
    Ok(level.pop().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{gram_t, matmul};

    fn gram_of_r<T: Scalar>(r: &Matrix<T>) -> Matrix<T> {
        matmul(&r.transpose(), r).unwrap()
    }

    fn assert_gram_eq<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, tol: f64) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                (x.to_f64() - y.to_f64()).abs() <= tol * (1.0 + y.to_f64().abs()),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn sequential_matches_full() {
        let n = 10;
        let chunks: Vec<Matrix<f64>> = (0..5).map(|i| Matrix::randn(33, n, i as u64)).collect();
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        let r = tsqr_sequential(&chunks).unwrap();
        assert_gram_eq(&gram_of_r(&r), &gram_t(&full), 1e-9);
    }

    #[test]
    fn folder_matches_naive_stacking() {
        let n = 9;
        let chunks: Vec<Matrix<f64>> = (0..4).map(|i| Matrix::randn(21, n, 50 + i as u64)).collect();
        // naive reference: re-stack and re-QR every fold
        let mut r_naive: Matrix<f64> = Matrix::zeros(n, n);
        for c in &chunks {
            r_naive = qr_r_square(&r_naive.vstack(c).unwrap()).unwrap();
        }
        let mut folder = TsqrFolder::with_chunk_capacity(n, 21);
        for c in &chunks {
            folder.fold(c).unwrap();
        }
        assert_gram_eq(&gram_of_r(&folder.finish()), &gram_of_r(&r_naive), 1e-9);
    }

    #[test]
    fn folder_grows_for_oversized_chunks() {
        let n = 6;
        let small: Matrix<f64> = Matrix::randn(4, n, 1);
        let big: Matrix<f64> = Matrix::randn(40, n, 2);
        let mut folder = TsqrFolder::with_chunk_capacity(n, 4);
        folder.fold(&small).unwrap();
        folder.fold(&big).unwrap();
        let full = small.vstack(&big).unwrap();
        assert_gram_eq(&gram_of_r(&folder.finish()), &gram_t(&full), 1e-9);
    }

    #[test]
    fn folder_rejects_width_mismatch() {
        let mut folder = TsqrFolder::<f64>::new(5);
        assert!(folder.fold(&Matrix::randn(3, 4, 1)).is_err());
    }

    #[test]
    fn tree_matches_sequential_gram() {
        let n = 8;
        let chunks: Vec<Matrix<f64>> = (0..7).map(|i| Matrix::randn(20, n, 100 + i as u64)).collect();
        let r_seq = tsqr_sequential(&chunks).unwrap();
        for workers in [1, 2, 4] {
            let r_tree = tsqr_tree(&chunks, workers).unwrap();
            assert_gram_eq(&gram_of_r(&r_tree), &gram_of_r(&r_seq), 1e-9);
        }
    }

    #[test]
    fn single_chunk() {
        let c: Matrix<f64> = Matrix::randn(12, 5, 1);
        let r = tsqr_tree(&[c.clone()], 4).unwrap();
        assert_gram_eq(&gram_of_r(&r), &gram_t(&c), 1e-10);
    }

    #[test]
    fn skinny_chunks_rank_deficient() {
        // each chunk has fewer rows than columns: forces the degenerate path
        let chunks: Vec<Matrix<f64>> = (0..3).map(|i| Matrix::randn(3, 9, i as u64)).collect();
        let r = tsqr_sequential(&chunks).unwrap();
        assert!(r.all_finite());
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        assert_gram_eq(&gram_of_r(&r), &gram_t(&full), 1e-9);
    }
}
