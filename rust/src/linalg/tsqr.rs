//! TSQR (Tall-Skinny QR) — the paper's §4.2 out-of-core preprocessing.
//!
//! Host reference edition of the streaming and binary-tree variants; the
//! production path runs the same algorithm through the `tsqr_step` /
//! `tsqr_merge` PJRT artifacts orchestrated by `coordinator::tsqr_tree`.

use crate::error::Result;
use crate::linalg::qr::qr_r_square;
use crate::tensor::{Matrix, Scalar};
use crate::util::threads;

/// Streaming (sequential) TSQR: fold chunks of Xᵀ into a running R.
///
/// `chunks` are (cᵢ × n) row-blocks of Xᵀ.  Returns square R with
/// RᵀR = Σ chunkᵢᵀ chunkᵢ = XXᵀ.  Peak memory is one chunk + R — this is
/// how a calibration matrix larger than device memory is processed.
pub fn tsqr_sequential<T: Scalar>(chunks: &[Matrix<T>]) -> Result<Matrix<T>> {
    assert!(!chunks.is_empty());
    let n = chunks[0].cols;
    let mut r = Matrix::zeros(n, n);
    for c in chunks {
        let stacked = r.vstack(c)?;
        r = qr_r_square(&stacked)?;
    }
    Ok(r)
}

/// Binary-tree TSQR: leaf QRs in parallel, then pairwise R merges.
///
/// The reduction shape matches the paper's multi-GPU diagram; here leaves
/// run on `workers` threads (simulated devices).
pub fn tsqr_tree<T: Scalar>(chunks: &[Matrix<T>], workers: usize) -> Result<Matrix<T>> {
    assert!(!chunks.is_empty());
    // leaf level
    let mut level: Vec<Matrix<T>> = threads::parallel_map(chunks.len(), workers, |i| {
        qr_r_square(&chunks[i]).expect("leaf qr")
    });
    // reduction levels
    while level.len() > 1 {
        let pairs = level.len() / 2;
        let odd = level.len() % 2 == 1;
        let merged: Vec<Matrix<T>> = {
            let level_ref = &level;
            threads::parallel_map(pairs, workers, |i| {
                let stacked = level_ref[2 * i].vstack(&level_ref[2 * i + 1]).expect("stack");
                qr_r_square(&stacked).expect("merge qr")
            })
        };
        let mut next = merged;
        if odd {
            next.push(level.pop().unwrap());
        }
        level = next;
    }
    Ok(level.pop().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{gram_t, matmul};

    fn gram_of_r<T: Scalar>(r: &Matrix<T>) -> Matrix<T> {
        matmul(&r.transpose(), r).unwrap()
    }

    fn assert_gram_eq<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, tol: f64) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                (x.to_f64() - y.to_f64()).abs() <= tol * (1.0 + y.to_f64().abs()),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn sequential_matches_full() {
        let n = 10;
        let chunks: Vec<Matrix<f64>> = (0..5).map(|i| Matrix::randn(33, n, i as u64)).collect();
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        let r = tsqr_sequential(&chunks).unwrap();
        assert_gram_eq(&gram_of_r(&r), &gram_t(&full), 1e-9);
    }

    #[test]
    fn tree_matches_sequential_gram() {
        let n = 8;
        let chunks: Vec<Matrix<f64>> = (0..7).map(|i| Matrix::randn(20, n, 100 + i as u64)).collect();
        let r_seq = tsqr_sequential(&chunks).unwrap();
        for workers in [1, 2, 4] {
            let r_tree = tsqr_tree(&chunks, workers).unwrap();
            assert_gram_eq(&gram_of_r(&r_tree), &gram_of_r(&r_seq), 1e-9);
        }
    }

    #[test]
    fn single_chunk() {
        let c: Matrix<f64> = Matrix::randn(12, 5, 1);
        let r = tsqr_tree(&[c.clone()], 4).unwrap();
        assert_gram_eq(&gram_of_r(&r), &gram_t(&c), 1e-10);
    }

    #[test]
    fn skinny_chunks_rank_deficient() {
        // each chunk has fewer rows than columns: forces the degenerate path
        let chunks: Vec<Matrix<f64>> = (0..3).map(|i| Matrix::randn(3, 9, i as u64)).collect();
        let r = tsqr_sequential(&chunks).unwrap();
        assert!(r.all_finite());
        let mut full = chunks[0].clone();
        for c in &chunks[1..] {
            full = full.vstack(c).unwrap();
        }
        assert_gram_eq(&gram_of_r(&r), &gram_t(&full), 1e-9);
    }
}
