//! `coala` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   selfcheck                 run the jax⇄PJRT conformance suite
//!   info                      print manifest / model / artifact summary
//!   methods                   list the registered compression methods
//!   compress  --model tiny --method coala --ratio 0.7 [--lambda 3]
//!             [--route device|host] [--workers N] [--queue-cap N]
//!   eval      --model tiny    perplexity + probe tasks of the base model
//!   finetune  --init coala1 --steps 60 --lr 3e-3 [--route device|host]
//!             [--rank R] [--check] [--save-adapters FILE]
//!                             initialize + Adam-train rank-r adapters on
//!                             the shifted fine-tune distribution.
//!                             `--route host` trains with the pure-Rust
//!                             fp64 backprop subsystem (no artifacts);
//!                             `--check` exits non-zero unless the loss
//!                             strictly decreased and every adapter is
//!                             finite (the CI smoke gate).
//!   repro [<id>] [--route device|host] [--workers N] [--queue-cap N]
//!                             regenerate a paper table/figure (default:
//!                             `all`).  `--route host` runs the synthetic
//!                             artifact-free environment end-to-end.
//!   shard     --shard-index I --shard-count N --calib-batches B
//!             --out FILE [--model tiny --method coala --route host]
//!                             accumulate-only over shard I of an
//!                             N-shard calibration plan and write the
//!                             merge states to FILE (no factorization)
//!   merge     <s0.state> <s1.state> … --out FILE [--ratio R]
//!             | --from-source --calib-batches B --out FILE
//!                             merge shard state files through the
//!                             canonical batch-order tree, factorize,
//!                             and write the factors to FILE — bitwise
//!                             identical to the single-process run
//!                             (`--from-source` runs that single-process
//!                             reference and writes the same file
//!                             format, so `cmp` checks the guarantee)
//!   report    <telemetry.jsonl> … [--json] [--cond-threshold T]
//!             [--trace out.json]
//!                             aggregate telemetry JSONL files into
//!                             per-(run_id, stage) timing summaries, a
//!                             busy-vs-stall breakdown, per-shard skew,
//!                             and a numerical-health digest (works on
//!                             any build — reading needs no feature).
//!                             `--trace` additionally exports the spans
//!                             as Chrome trace-event JSON for Perfetto
//!                             / chrome://tracing (one pid per process,
//!                             one tid per span, memory + queue-depth
//!                             counter tracks)
//!   tsqr-demo --workers 4     out-of-core tree-TSQR demonstration
//!
//! `--workers`/`--queue-cap` configure the execution engine
//! (`coordinator::engine`): capture, sharded accumulate, and parallel
//! factorize all scale with `--workers`, and results are identical at
//! any worker count.  `--checkpoint-dir DIR [--checkpoint-every N]
//! [--resume]` on compress/shard/merge/repro makes calibration durable:
//! pending merge states are written every N batches and a killed run
//! resumes bitwise-identically.  `--accum exact|sketch` on
//! compress/shard/merge/repro swaps the R-consuming methods' exact TSQR
//! accumulator for the O(rank)-per-batch randomized range-finder sketch
//! (`COALA_SKETCH_ROWS`/`COALA_SKETCH_SEED` tune it; see
//! `util::cli::Args::accum` for the error-bound rationale) — all the
//! determinism guarantees above hold for the sketch bitwise.
//!
//! Methods resolve by name through the `coala::compressor` registry —
//! `methods` prints every spec the registry accepts.

use coala::calib::dataset::{Corpus, TaskBank};
use coala::calib::state::ShardState;
use coala::coala::compressor::{registry, resolve, Compressor, Route};
use coala::coordinator::{
    engine, resolve_accum_kind, CompressionJob, Pipeline, ShardPlan, StageTimings, TsqrTreeRunner,
};
use coala::error::{Error, Result};
use coala::eval::{eval_tasks, perplexity};
use coala::model::ModelWeights;
use coala::runtime::{conformance, Executor};
use coala::tensor::Matrix;
use coala::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    if let Err(e) = dispatch(cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    let dir = coala::artifacts_dir(args.get("artifacts"))?;
    match cmd {
        "selfcheck" => conformance::selfcheck(&dir),
        "info" => {
            let ex = Executor::new(&dir)?;
            println!("artifacts dir : {dir}");
            println!("abi version   : {}", ex.manifest.abi_version);
            println!("artifacts     : {}", ex.manifest.artifacts.len());
            println!("probe tasks   : {}", ex.manifest.task_names.join(", "));
            for (name, cfg) in &ex.manifest.configs {
                let w = ModelWeights::load(&dir, cfg)?;
                println!(
                    "model {name:<6}: d={} ff={} L={} vocab={} params={} (build ppl {:.2})",
                    cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab,
                    w.param_count(), w.build_val_ppl
                );
            }
            Ok(())
        }
        "methods" => {
            println!("registered compression methods (--method accepts the spec column):");
            println!("  {:<16} {:<24} accumulator", "spec", "method");
            for comp in registry() {
                println!(
                    "  {:<16} {:<24} {:?}",
                    comp.spec(),
                    comp.name(),
                    comp.accum_kind()
                );
            }
            println!(
                "\nparameterized specs: coala:lambda=L (adaptive μ, Eq. 5) | coala:mu=M\n\
                 accumulate + factorize run on either route: --route device (PJRT\n\
                 artifacts) or --route host (pure Rust).  `compress` captures\n\
                 activations through the fwd_acts artifacts; `repro --route host`\n\
                 needs no artifacts at all (synthetic environment)"
            );
            Ok(())
        }
        "compress" => {
            let ex = Executor::new(&dir)?;
            let corpus = Corpus::load(&dir)?;
            let cfg = args.get_or("model", "tiny");
            let spec = ex.manifest.config(cfg)?.clone();
            let w = ModelWeights::load(&dir, &spec)?;
            let comp = resolve(&args.method_spec("coala"))?;
            let mut job =
                CompressionJob::new(cfg, comp.method(), args.get_f64("ratio", 0.7)?);
            job.calib_batches = args.get_usize("calib-batches", 8)?;
            let route = args.route()?;
            let accum = args.accum()?;
            let mut plan = args.engine_plan()?;
            println!(
                "compressing {cfg} with {} at {:.0}% kept ({:?} route, {} workers) …",
                comp.name(),
                job.ratio * 100.0,
                route,
                plan.factorize_workers
            );
            let kind = resolve_accum_kind(comp.as_ref(), accum)?;
            let workers = plan.capture_workers;
            plan.telemetry = coala::telemetry::TelemetrySink::from_env()?
                .with_labels(|l| {
                    l.config = cfg.to_string();
                    l.method = comp.name();
                    l.route = format!("{route:?}").to_lowercase();
                    l.accum = format!("{kind:?}").to_lowercase();
                    l.workers = workers;
                    l.shards = 1;
                    l.span = "run".to_string();
                })
                // same fingerprint shape as Env::source_id (the artifact
                // route has no seed knob, so seed is pinned to 0)
                .with_run(&format!("{cfg}:{route:?}:seed0:b{}", job.calib_batches));
            let pipe = Pipeline::new(&ex, spec.clone(), &w)
                .with_route(route)
                .with_plan(plan)
                .with_checkpoint(args.checkpoint()?)
                .with_accum(accum);
            let out = pipe.run(&job, &corpus)?;
            let t = &out.timings;
            println!(
                "done in {:.2}s (calibrate {:.2}s / accumulate {:.2}s / merge {:.2}s / \
                 factorize {:.2}s)",
                t.total_s, t.calibrate_s, t.accumulate_s, t.merge_s, t.factorize_s
            );
            println!("achieved ratio: {:.4}", out.model.achieved_ratio(&w, &spec));
            let rec = out.model.reconstruct_into(&w)?;
            let base = perplexity(&ex, &spec, &w, corpus.split("val")?, 4)?;
            let comp_ppl = perplexity(&ex, &spec, &rec, corpus.split("val")?, 4)?;
            println!("val ppl: {base:.2} -> {comp_ppl:.2}");
            let bank = TaskBank::load(&dir, "base", &ex.manifest.task_names)?;
            let s0 = eval_tasks(&ex, &spec, &w, &bank, Some(256))?;
            let s1 = eval_tasks(&ex, &spec, &rec, &bank, Some(256))?;
            println!("probe avg acc: {:.1}% -> {:.1}%", s0.average(), s1.average());
            Ok(())
        }
        "eval" => {
            let ex = Executor::new(&dir)?;
            let corpus = Corpus::load(&dir)?;
            let cfg = args.get_or("model", "tiny");
            let spec = ex.manifest.config(cfg)?.clone();
            let w = ModelWeights::load(&dir, &spec)?;
            let ppl = perplexity(&ex, &spec, &w, corpus.split("val")?, 8)?;
            println!("{cfg}: val ppl {ppl:.2} (build-time: {:.2})", w.build_val_ppl);
            let bank = TaskBank::load(&dir, "base", &ex.manifest.task_names)?;
            let s = eval_tasks(&ex, &spec, &w, &bank, None)?;
            for ((n, a), e) in s.names.iter().zip(&s.accuracy).zip(&s.stderr) {
                println!("  {n:<10} {a:5.1} ± {e:.1}");
            }
            println!("  avg        {:5.1}", s.average());
            Ok(())
        }
        "finetune" => {
            use coala::finetune::{AdapterInit, FineTuner as _};
            use coala::repro::common::Env;
            let env = Env::load(args)?;
            let cfg = args.get_or("model", "tiny");
            let (spec, w) = env.weights(cfg)?;
            let rank = args.get_usize("rank", env.ex.manifest.ft_rank)?;
            let strat = AdapterInit::resolve(args.get_or("init", "coala1"))?;
            let steps = args.get_usize("steps", 60)?.max(1);
            let lr = args.get_f64("lr", 3e-3)?;
            println!(
                "fine-tuning {cfg} from {} at rank {rank} for {steps} Adam steps ({} route) …",
                strat.name(),
                if env.is_synthetic() { "host" } else { "device" }
            );
            let mut set = env.init_adapters(&spec, &w, strat, rank, 3)?;
            let pool = env.ft_pool(&spec)?;
            let tuner = env.fine_tuner(&spec, rank);
            let losses = tuner.train_on_batches(&mut set, &pool, steps, lr)?;
            let (first, last) = (losses[0], *losses.last().unwrap());
            println!("loss: {first:.4} -> {last:.4} over {} steps", losses.len());
            let bank = env.task_bank("ft")?;
            let scores = tuner.eval_tasks(&set, &bank, None)?;
            println!("shifted-fact probe avg acc: {:.1}%", scores.average());
            if args.get_bool("check") {
                // losses are recorded *before* each update, so comparing
                // first vs last needs at least two of them
                if losses.len() < 2 {
                    return Err(coala::Error::Config(
                        "--check needs --steps ≥ 2 (losses are pre-update)".into(),
                    ));
                }
                if !losses.iter().all(|l| l.is_finite()) {
                    return Err(coala::Error::Numerical(format!(
                        "non-finite training loss: {losses:?}"
                    )));
                }
                if last >= first {
                    return Err(coala::Error::Numerical(format!(
                        "loss did not decrease: {first} -> {last}"
                    )));
                }
                if !set.all_finite() {
                    return Err(coala::Error::Numerical(
                        "trained adapters contain non-finite values".into(),
                    ));
                }
                println!("check passed: loss strictly decreased, all adapters finite");
            }
            if let Some(path) = args.get("save-adapters") {
                coala::calib::state::write_adapters(path, &set)?;
                println!("trained adapters written to {path}");
            }
            Ok(())
        }
        "shard" => {
            use coala::repro::common::Env;
            use coala::tensor::lowp::Precision;
            let mut env = Env::load(args)?;
            let cfg = args.get_or("model", "tiny");
            let (spec, w) = env.weights(cfg)?;
            let comp = resolve(&args.method_spec("coala"))?;
            let kind = resolve_accum_kind(comp.as_ref(), env.accum)?;
            let total = args.get_usize("calib-batches", 8)?;
            let shard_count = args.get_usize("shard-count", 1)?;
            let plan = ShardPlan::new(total, shard_count)?;
            let index = args.get_usize("shard-index", 0)?;
            let range = plan.range(index)?;
            env.plan.telemetry = env
                .plan
                .telemetry
                .clone()
                .with_labels(|l| {
                    l.config = cfg.to_string();
                    l.method = comp.name();
                    l.accum = format!("{kind:?}").to_lowercase();
                    l.shards = shard_count;
                    l.span = format!("shard/{index}");
                })
                // every shard of a run hashes the same source
                // fingerprint, so all N processes (and the merge) stamp
                // one run_id — the trace stitches with no coordination
                .with_run(&env.source_id(cfg, total)?);
            let out = args.get_or("out", "shard.state");
            println!(
                "accumulating {} shard: batches [{}, {}) of {total} for {} ({:?} statistic, {} route) …",
                cfg,
                range.start,
                range.end,
                comp.name(),
                kind,
                if env.is_synthetic() { "host" } else { "device" }
            );
            let src = env.calib_source(&spec, &w, total)?;
            let mut t = StageTimings::default();
            let state = engine::accumulate_shard(
                src.as_ref(),
                kind,
                range,
                env.accum_backend(),
                Precision::F32,
                &env.plan,
                &mut t,
                env.checkpoint.as_ref(),
                &env.source_id(cfg, total)?,
            )?;
            state.write(out)?;
            engine::emit_stage_records(&env.plan.telemetry, &t);
            println!(
                "wrote {out}: {} pending merge states in {:.2}s (capture {:.2}s / \
                 accumulate {:.2}s / merge {:.2}s)",
                state.nodes.len(),
                t.calibrate_s + t.accumulate_s + t.merge_s,
                t.calibrate_s,
                t.accumulate_s,
                t.merge_s
            );
            Ok(())
        }
        "merge" => {
            use coala::repro::common::Env;
            use coala::tensor::lowp::Precision;
            let mut env = Env::load(args)?;
            let cfg = args.get_or("model", "tiny");
            let (spec, w) = env.weights(cfg)?;
            let comp = resolve(&args.method_spec("coala"))?;
            let out_path = args.get_or("out", "factors.state");
            let n_shards =
                if args.get_bool("from-source") { 1 } else { args.positional[1..].len() };
            env.plan.telemetry = env.plan.telemetry.clone().with_labels(|l| {
                l.config = cfg.to_string();
                l.method = comp.name();
                l.shards = n_shards;
                l.span = "merge".to_string();
            });
            let mut t = StageTimings::default();
            let states = if args.get_bool("from-source") {
                // the single-process reference run, written in the same
                // file format — `cmp` against a sharded merge checks
                // the bitwise guarantee end-to-end
                let total = args.get_usize("calib-batches", 8)?;
                env.plan.telemetry =
                    env.plan.telemetry.clone().with_run(&env.source_id(cfg, total)?);
                println!("single-process calibration over {total} batches …");
                let src = env.calib_source(&spec, &w, total)?;
                engine::calibrate_checkpointed(
                    src.as_ref(),
                    resolve_accum_kind(comp.as_ref(), env.accum)?,
                    total,
                    env.accum_backend(),
                    Precision::F32,
                    &env.plan,
                    &mut t,
                    env.checkpoint.as_ref(),
                    &env.source_id(cfg, total)?,
                )?
            } else {
                let files = &args.positional[1..];
                if files.is_empty() {
                    return Err(Error::Config(
                        "merge needs shard state files (or --from-source for the \
                         single-process reference)"
                            .into(),
                    ));
                }
                println!("merging {} shard state files …", files.len());
                let parts = files.iter().map(|f| ShardState::read(f)).collect::<Result<Vec<_>>>()?;
                // the shard files carry the calibration-source
                // fingerprint the shard processes hashed their run_id
                // from; reusing it stitches merge into the same trace
                // (merge_shard_states rejects mixed fingerprints)
                if let Some(p) = parts.first() {
                    env.plan.telemetry = env.plan.telemetry.clone().with_run(&p.source);
                }
                engine::merge_shard_states(parts, env.accum_backend(), &mut t)?
            };
            let job = CompressionJob::new(cfg, comp.method(), args.get_f64("ratio", 0.5)?);
            let pipe = Pipeline::new(&env.ex, spec.clone(), &w)
                .with_route(env.route)
                .with_plan(env.plan.clone());
            let outcome = pipe.run_with_accums(&job, &states, t)?;
            coala::calib::state::write_factors(out_path, &outcome.model)?;
            println!(
                "wrote {out_path}: {} projections, achieved ratio {:.4}, all finite: {}",
                outcome.model.factors.len(),
                outcome.model.achieved_ratio(&w, &spec),
                outcome.model.all_finite()
            );
            Ok(())
        }
        "repro" => {
            // `coala repro --route host` (no id) regenerates everything
            let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
            coala::repro::run(id, args)
        }
        "report" => {
            // analyzer over telemetry JSONL — pure reading, so it works
            // on any build, including ones without the telemetry feature
            let files = args.positional[1..].to_vec();
            let opts = coala::telemetry::report::ReportOptions {
                json: args.get_bool("json"),
                cond_threshold: args.get_f64("cond-threshold", 1e8)?,
            };
            if let Some(out) = args.get("trace") {
                // Chrome trace-event export of the same JSONL: load it
                // in Perfetto / chrome://tracing to *see* the spans
                let trace = coala::telemetry::trace::export(&files)?;
                std::fs::write(out, &trace).map_err(|e| Error::io(out, e))?;
                println!("trace written to {out} (open in Perfetto or chrome://tracing)");
            }
            print!("{}", coala::telemetry::report::render(&files, &opts)?);
            Ok(())
        }
        "tsqr-demo" => {
            let workers = args.get_usize("workers", 4)?;
            let n = args.get_usize("n", 192)?;
            let chunks_n = args.get_usize("chunks", 8)?;
            let host = args.route()? == Route::Host;
            let (c, runner) = if host {
                (args.get_usize("chunk-rows", 256)?, TsqrTreeRunner::host(workers))
            } else {
                let ex = Executor::new(&dir)?;
                let cfg = ex.manifest.config(args.get_or("model", "tiny"))?;
                (cfg.chunk_cols(), TsqrTreeRunner::new(&dir, workers))
            };
            println!("tree-TSQR: {chunks_n} chunks of {c}×{n} across {workers} simulated devices");
            let chunks: Vec<Matrix<f32>> =
                (0..chunks_n).map(|i| Matrix::randn(c, n, i as u64)).collect();
            let t0 = std::time::Instant::now();
            let r = runner.run(chunks)?;
            println!("R ({}×{}) in {:.2}s, finite={}", r.rows, r.cols, t0.elapsed().as_secs_f64(), r.all_finite());
            Ok(())
        }
        _ => {
            println!(
                "coala — context-aware low-rank approximation (COALA) coordinator\n\n\
                 usage: coala <selfcheck|info|methods|compress|eval|finetune|repro|shard|merge|report|tsqr-demo> [--flags]\n\
                 see README.md for the full tour"
            );
            Ok(())
        }
    }
}
