//! A compressed model: base weights + per-projection low-rank factors.

use crate::coala::factorize::Factors;
use crate::error::Result;
use crate::model::weights::ModelWeights;
use crate::runtime::manifest::ModelSpec;
use std::collections::BTreeMap;

/// The result of compressing a model: factors per projection, plus the
/// reconstructed weight set for evaluation through the fwd artifacts.
#[derive(Debug, Clone)]
pub struct CompressedModel {
    pub base_config: String,
    pub factors: BTreeMap<String, Factors<f32>>,
}

impl CompressedModel {
    pub fn new(config: &str) -> CompressedModel {
        CompressedModel { base_config: config.to_string(), factors: BTreeMap::new() }
    }

    pub fn insert(&mut self, proj: &str, f: Factors<f32>) {
        self.factors.insert(proj.to_string(), f);
    }

    /// Parameters stored by the factored projections.
    pub fn factored_params(&self) -> usize {
        self.factors.values().map(|f| f.param_count()).sum()
    }

    /// Achieved ratio = factored / original parameters (projections only).
    pub fn achieved_ratio(&self, weights: &ModelWeights, spec: &ModelSpec) -> f64 {
        self.factored_params() as f64 / weights.compressible_params(spec) as f64
    }

    /// Produce the weight set with every factored projection replaced by
    /// its reconstruction A·B (same shapes ⇒ reusable fwd artifacts).
    pub fn reconstruct_into(&self, weights: &ModelWeights) -> Result<ModelWeights> {
        let mut out = weights.clone();
        for (proj, f) in &self.factors {
            out.set_matrix(proj, &f.reconstruct()?)?;
        }
        Ok(out)
    }

    /// Are all factors numerically sane?
    pub fn all_finite(&self) -> bool {
        self.factors.values().all(|f| f.a.all_finite() && f.b.all_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn dummy_factors(m: usize, n: usize, r: usize, seed: u64) -> Factors<f32> {
        Factors {
            a: Matrix::randn(m, r, seed),
            b: Matrix::randn(r, n, seed + 1),
            spectrum: vec![1.0; r],
        }
    }

    #[test]
    fn param_accounting() {
        let mut c = CompressedModel::new("tiny");
        c.insert("l0.wq", dummy_factors(8, 8, 2, 1));
        c.insert("l0.wk", dummy_factors(8, 8, 2, 2));
        assert_eq!(c.factored_params(), 2 * (8 * 2 + 2 * 8));
        assert!(c.all_finite());
    }

    #[test]
    fn reconstruction_swaps_only_factored() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let m = crate::runtime::Manifest::load("artifacts").unwrap();
        let spec = m.config("tiny").unwrap();
        let w = ModelWeights::load("artifacts", spec).unwrap();
        let mut c = CompressedModel::new("tiny");
        let d = spec.d_model;
        c.insert("l0.wq", dummy_factors(d, d, 4, 3));
        let w2 = c.reconstruct_into(&w).unwrap();
        // swapped
        assert_ne!(w2.matrix("l0.wq").unwrap().data, w.matrix("l0.wq").unwrap().data);
        // untouched
        assert_eq!(w2.matrix("l1.wq").unwrap().data, w.matrix("l1.wq").unwrap().data);
        let ratio = c.achieved_ratio(&w, spec);
        assert!(ratio > 0.0 && ratio < 1.0);
    }
}
