//! The compression-target model, runtime side (S9).
//!
//! The transformer itself was *defined and trained* at build time (L2,
//! `python/compile/model.py` + `pretrain.py`); here it exists as (a) a
//! bag of named weight matrices loaded from `weights_<cfg>.cbt` and (b)
//! the `fwd_logits` / `fwd_acts` / `loss` artifacts that consume those
//! weights **as inputs** — which is what lets the coordinator evaluate a
//! compressed model by simply swapping reconstructed matrices into the
//! input list, without ever re-lowering.
//!
//! The [`synthetic`] module is the artifact-free twin: a PRNG-generated
//! spec + weight set with the same parameter families and a pure-Rust
//! forward pass, so the repro drivers run end-to-end with no build step.

pub mod compressed;
pub mod synthetic;
pub mod weights;

pub use compressed::CompressedModel;
pub use synthetic::{synthetic_manifest, synthetic_weights, HostModel};
pub use weights::ModelWeights;
