//! The compression-target model, runtime side (S9).
//!
//! The transformer itself was *defined and trained* at build time (L2,
//! `python/compile/model.py` + `pretrain.py`); here it exists as (a) a
//! bag of named weight matrices loaded from `weights_<cfg>.cbt` and (b)
//! the `fwd_logits` / `fwd_acts` / `loss` artifacts that consume those
//! weights **as inputs** — which is what lets the coordinator evaluate a
//! compressed model by simply swapping reconstructed matrices into the
//! input list, without ever re-lowering.

pub mod compressed;
pub mod weights;

pub use compressed::CompressedModel;
pub use weights::ModelWeights;
