//! The synthetic, artifact-free model environment (the `--route host`
//! world).
//!
//! Everything the repro drivers need from `artifacts/` is generated
//! deterministically from a seed instead: [`ModelSpec`]s
//! (tiny/small/large) with the same parameter families as the build-time
//! transformer, PRNG [`ModelWeights`] whose unembedding is aligned with
//! the corpus' Markov chain (so the base model genuinely beats chance),
//! and a pure-Rust forward pass ([`HostModel`]) that evaluates any
//! weight set — original or compressed — with zero artifacts and zero
//! PJRT.
//!
//! The forward is a *per-token* gated residual stack (no cross-position
//! attention): with a first-order Markov corpus the optimal predictor is
//! a bigram model, so a per-token architecture loses nothing, and every
//! compressible projection (wq/wk/wv/wo/w_up/w_down) sits on the signal
//! path — compressing it badly measurably hurts perplexity and probe
//! accuracy, which is exactly what the accuracy tables need to rank
//! methods.

use crate::calib::dataset::markov_successors;
use crate::error::{Error, Result};
use crate::model::weights::ModelWeights;
use crate::runtime::manifest::{Manifest, ModelSpec};
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

/// Shared shape constants of the synthetic environment (every config
/// uses the same vocab/sequence geometry so one corpus and one task
/// bank serve them all).
pub const VOCAB: usize = 64;
pub const SEQ_LEN: usize = 16;
pub const BATCH: usize = 4;
pub const FT_RANK: usize = 4;
/// Tokens per corpus split.
pub const SPLIT_LEN: usize = 4096;
/// Rows per probe-task bank.
pub const BANK_ROWS: usize = 160;
/// Default environment seed (overridable with `--seed`).
pub const DEFAULT_SEED: u64 = 0xC0A1A;

fn synthetic_spec(name: &str, d_model: usize, d_ff: usize, n_layers: usize) -> ModelSpec {
    let mut param_names: Vec<String> =
        vec!["embed".into(), "unembed".into(), "lnf".into()];
    let mut param_shapes = BTreeMap::new();
    param_shapes.insert("embed".to_string(), vec![VOCAB, d_model]);
    param_shapes.insert("unembed".to_string(), vec![VOCAB, d_model]);
    param_shapes.insert("lnf".to_string(), vec![d_model]);
    let mut compressible = Vec::new();
    for l in 0..n_layers {
        let families: [(&str, Vec<usize>); 8] = [
            ("ln1", vec![d_model]),
            ("wq", vec![d_model, d_model]),
            ("wk", vec![d_model, d_model]),
            ("wv", vec![d_model, d_model]),
            ("wo", vec![d_model, d_model]),
            ("ln2", vec![d_model]),
            ("w_up", vec![d_ff, d_model]),
            ("w_down", vec![d_model, d_ff]),
        ];
        for (short, shape) in families {
            let full = format!("l{l}.{short}");
            param_names.push(full.clone());
            param_shapes.insert(full.clone(), shape);
            if !short.starts_with("ln") {
                compressible.push(full);
            }
        }
    }
    let proj_input_stream: BTreeMap<String, String> = [
        ("wq", "attn"),
        ("wk", "attn"),
        ("wv", "attn"),
        ("wo", "o"),
        ("w_up", "up"),
        ("w_down", "down"),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v.to_string()))
    .collect();
    ModelSpec {
        name: name.to_string(),
        vocab: VOCAB,
        d_model,
        n_layers,
        n_heads: 4,
        d_ff,
        seq_len: SEQ_LEN,
        batch: BATCH,
        param_names,
        param_shapes,
        compressible,
        proj_input_stream,
        act_streams: ["attn", "o", "up", "down"].iter().map(|s| s.to_string()).collect(),
        weights_file: String::new(),
    }
}

/// The synthetic manifest: tiny + small + large configs, no artifacts on
/// disk.  `tiny` has exactly 3 layers so the three activation regimes of
/// [`crate::calib::synthetic`] all appear; `large` (6 layers, 36
/// projections) exists to put real load on the engine's parallel
/// factorize stage and the host trainer's parallel gradient
/// accumulation — `benches/pipeline.rs` sweeps worker counts over it.
pub fn synthetic_manifest() -> Manifest {
    let mut configs = BTreeMap::new();
    configs.insert("tiny".to_string(), synthetic_spec("tiny", 32, 96, 3));
    configs.insert("small".to_string(), synthetic_spec("small", 48, 144, 4));
    configs.insert("large".to_string(), synthetic_spec("large", 64, 192, 6));
    let task_names: Vec<String> = (0..8).map(|i| format!("t{i}")).collect();
    Manifest::from_parts("<synthetic>", task_names, FT_RANK, configs)
}

fn mix(seed: u64, salt: u64) -> u64 {
    seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

fn gains(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| 1.0 + 0.05 * rng.normal() as f32).collect()
}

/// PRNG weights for a synthetic spec.  The residual-stream scaling keeps
/// the hidden state close to the token embedding while every projection
/// still contributes, and the unembedding is the Markov chain's bigram
/// head: `unembed[v] = γ Σ_t P(v|t)·embed[t] + noise`, which makes the
/// uncompressed model predict the chain's successors well above chance.
pub fn synthetic_weights(spec: &ModelSpec, seed: u64) -> ModelWeights {
    let (d, ff, v) = (spec.d_model, spec.d_ff, spec.vocab);
    // distinct streams per config so tiny/small weights are independent
    let seed = mix(seed, spec.d_model as u64 | ((spec.n_layers as u64) << 16));
    let mut tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)> = BTreeMap::new();

    let embed = Matrix::<f32>::randn(v, d, mix(seed, 1));
    let gamma = 6.0 / d as f32;
    let mut unembed = Matrix::<f32>::randn(v, d, mix(seed, 2)).scale(0.05);
    for t in 0..v {
        for (succ, p) in markov_successors(t, v, false) {
            for j in 0..d {
                let cur = unembed.get(succ, j);
                unembed.set(succ, j, cur + gamma * p as f32 * embed.get(t, j));
            }
        }
    }
    tensors.insert("embed".to_string(), (vec![v, d], embed.data.clone()));
    tensors.insert("unembed".to_string(), (vec![v, d], unembed.data));

    let mut rng = Rng::new(mix(seed, 3));
    tensors.insert("lnf".to_string(), (vec![d], gains(d, &mut rng)));

    let mut salt = 16u64;
    for l in 0..spec.n_layers {
        let mut mat = |shape: (usize, usize), scale: f32| -> (Vec<usize>, Vec<f32>) {
            salt += 1;
            let m = Matrix::<f32>::randn(shape.0, shape.1, mix(seed, salt)).scale(scale);
            (vec![shape.0, shape.1], m.data)
        };
        let inv_d = 1.0 / (d as f32).sqrt();
        let inv_ff = 1.0 / (ff as f32).sqrt();
        let wq = mat((d, d), inv_d);
        let wk = mat((d, d), inv_d);
        let wv = mat((d, d), inv_d);
        let wo = mat((d, d), 0.25 * inv_d);
        let w_up = mat((ff, d), inv_d);
        let w_down = mat((d, ff), 0.25 * inv_ff);
        tensors.insert(format!("l{l}.wq"), wq);
        tensors.insert(format!("l{l}.wk"), wk);
        tensors.insert(format!("l{l}.wv"), wv);
        tensors.insert(format!("l{l}.wo"), wo);
        tensors.insert(format!("l{l}.w_up"), w_up);
        tensors.insert(format!("l{l}.w_down"), w_down);
        tensors.insert(format!("l{l}.ln1"), (vec![d], gains(d, &mut rng)));
        tensors.insert(format!("l{l}.ln2"), (vec![d], gains(d, &mut rng)));
    }

    ModelWeights {
        config: spec.name.clone(),
        tensors,
        pretrain_loss: Vec::new(),
        build_val_ppl: f32::NAN,
    }
}

// ------------------------------------------------------------ host forward

struct HostLayer {
    ln1: Vec<f32>,
    wq: Matrix<f32>,
    wk: Matrix<f32>,
    wv: Matrix<f32>,
    wo: Matrix<f32>,
    ln2: Vec<f32>,
    w_up: Matrix<f32>,
    w_down: Matrix<f32>,
}

/// Pure-Rust forward of the synthetic architecture — the host analogue
/// of the `fwd_logits` / `loss` artifacts.  Works on any weight set with
/// the synthetic parameter families (original, compressed, or adapted).
pub struct HostModel {
    vocab: usize,
    embed: Matrix<f32>,
    unembed: Matrix<f32>,
    lnf: Vec<f32>,
    layers: Vec<HostLayer>,
}

fn vec1(w: &ModelWeights, name: &str) -> Result<Vec<f32>> {
    let (dims, data) = w
        .tensors
        .get(name)
        .ok_or_else(|| Error::Config(format!("no parameter `{name}`")))?;
    if dims.len() != 1 {
        return Err(Error::shape(format!("{name} is {dims:?}, not 1-D")));
    }
    Ok(data.clone())
}

fn rmsnorm(x: &[f32], gain: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / x.len().max(1) as f64;
    let inv = (1.0 / (ms + 1e-6).sqrt()) as f32;
    x.iter().zip(gain).map(|(v, g)| v * inv * g).collect()
}

fn matvec(w: &Matrix<f32>, x: &[f32]) -> Vec<f32> {
    (0..w.rows)
        .map(|i| w.row(i).iter().zip(x).map(|(a, b)| a * b).sum::<f32>())
        .collect()
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl HostModel {
    pub fn new(spec: &ModelSpec, w: &ModelWeights) -> Result<HostModel> {
        let mut layers = Vec::with_capacity(spec.n_layers);
        for l in 0..spec.n_layers {
            layers.push(HostLayer {
                ln1: vec1(w, &format!("l{l}.ln1"))?,
                wq: w.matrix(&format!("l{l}.wq"))?,
                wk: w.matrix(&format!("l{l}.wk"))?,
                wv: w.matrix(&format!("l{l}.wv"))?,
                wo: w.matrix(&format!("l{l}.wo"))?,
                ln2: vec1(w, &format!("l{l}.ln2"))?,
                w_up: w.matrix(&format!("l{l}.w_up"))?,
                w_down: w.matrix(&format!("l{l}.w_down"))?,
            });
        }
        Ok(HostModel {
            vocab: spec.vocab,
            embed: w.matrix("embed")?,
            unembed: w.matrix("unembed")?,
            lnf: vec1(w, "lnf")?,
            layers,
        })
    }

    /// Logits over the vocabulary for one input token.
    pub fn token_logits(&self, token: usize) -> Vec<f32> {
        let d = self.embed.cols;
        let mut h: Vec<f32> = self.embed.row(token % self.vocab).to_vec();
        for layer in &self.layers {
            let a = rmsnorm(&h, &layer.ln1);
            let q = matvec(&layer.wq, &a);
            let k = matvec(&layer.wk, &a);
            let vv = matvec(&layer.wv, &a);
            let qk = q.iter().zip(&k).map(|(x, y)| x * y).sum::<f32>();
            let gate = 1.0 / (1.0 + (-qk / (d as f32).sqrt()).exp());
            let o_in: Vec<f32> = vv.iter().map(|x| x * gate).collect();
            let o = matvec(&layer.wo, &o_in);
            for (hi, oi) in h.iter_mut().zip(&o) {
                *hi += oi;
            }
            let m = rmsnorm(&h, &layer.ln2);
            let u: Vec<f32> = matvec(&layer.w_up, &m).into_iter().map(silu).collect();
            let down = matvec(&layer.w_down, &u);
            for (hi, di) in h.iter_mut().zip(&down) {
                *hi += di;
            }
        }
        let hf = rmsnorm(&h, &self.lnf);
        matvec(&self.unembed, &hf)
    }

    /// The full per-token logits table (vocab rows) — the forward is
    /// position-independent, so every evaluation is a table lookup.
    pub fn logits_table(&self) -> Vec<Vec<f32>> {
        (0..self.vocab).map(|t| self.token_logits(t)).collect()
    }
}

/// Negative log-likelihood of `target` under a logits row (stable LSE).
pub fn nll(logits: &[f32], target: usize) -> f64 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse = mx
        + logits
            .iter()
            .map(|&x| ((x as f64) - mx).exp())
            .sum::<f64>()
            .ln();
    lse - logits[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_specs_are_consistent() {
        let m = synthetic_manifest();
        for name in ["tiny", "small", "large"] {
            let spec = m.config(name).unwrap();
            assert_eq!(spec.compressible.len(), 6 * spec.n_layers);
            // every compressible projection routes to a stream and has a
            // 2-D shape; every parameter has a shape entry
            for p in &spec.compressible {
                spec.proj_shape(p).unwrap();
                spec.stream_of(p).unwrap();
            }
            for p in &spec.param_names {
                assert!(spec.param_shapes.contains_key(p), "{p}");
            }
            let (o, i) = spec.proj_shape("l0.w_down").unwrap();
            assert_eq!((o, i), (spec.d_model, spec.d_ff));
            assert_eq!(spec.stream_of("l1.wq").unwrap(), "attn");
        }
        assert_eq!(m.task_names.len(), 8);
        assert!(m.artifacts.is_empty());
    }

    #[test]
    fn weights_match_spec_and_are_deterministic() {
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap();
        let w1 = synthetic_weights(spec, 9);
        let w2 = synthetic_weights(spec, 9);
        let w3 = synthetic_weights(spec, 10);
        assert_eq!(w1.tensors.len(), spec.param_names.len());
        for name in &spec.param_names {
            let (dims, data) = &w1.tensors[name];
            assert_eq!(dims, &spec.param_shapes[name], "{name}");
            assert!(data.iter().all(|x| x.is_finite()), "{name}");
            assert_eq!(data, &w2.tensors[name].1, "{name} not deterministic");
        }
        assert_ne!(w1.tensors["embed"].1, w3.tensors["embed"].1);
    }

    #[test]
    fn host_forward_is_finite_and_token_dependent() {
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap();
        let w = synthetic_weights(spec, 5);
        let model = HostModel::new(spec, &w).unwrap();
        let table = model.logits_table();
        assert_eq!(table.len(), spec.vocab);
        for row in &table {
            assert_eq!(row.len(), spec.vocab);
            assert!(row.iter().all(|x| x.is_finite()));
        }
        assert_ne!(table[0], table[1]);
    }

    #[test]
    fn bigram_head_prefers_chain_successors() {
        use crate::calib::dataset::markov_top;
        let m = synthetic_manifest();
        let spec = m.config("tiny").unwrap();
        let w = synthetic_weights(spec, DEFAULT_SEED);
        let model = HostModel::new(spec, &w).unwrap();
        let table = model.logits_table();
        // the chain's top successor must out-score the vocab median logit
        // for a clear majority of tokens (the "trained model beats
        // chance" property, synthesized)
        let mut wins = 0;
        for t in 0..spec.vocab {
            let succ = markov_top(t, spec.vocab, false);
            let mut sorted: Vec<f32> = table[t].clone();
            sorted.sort_by(f32::total_cmp);
            let median = sorted[spec.vocab / 2];
            if table[t][succ] > median {
                wins += 1;
            }
        }
        assert!(wins * 10 >= spec.vocab * 7, "successor wins only {wins}/{}", spec.vocab);
    }
}
