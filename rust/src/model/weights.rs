//! Trained-weights container + marshalling into artifact input lists.

use crate::error::{Error, Result};
use crate::runtime::cbt::{Cbt, Tensor};
use crate::runtime::executor::Value;
use crate::runtime::manifest::ModelSpec;
use std::collections::BTreeMap;

/// All parameters of one model config, in the manifest's ABI order.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: String,
    /// name → (dims, row-major data); includes 1-D norm gains.
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    /// pretrain loss curve (diagnostics / EXPERIMENTS.md)
    pub pretrain_loss: Vec<f32>,
    /// held-out perplexity recorded at build time
    pub build_val_ppl: f32,
}

impl ModelWeights {
    /// Load from `<artifacts>/<weights_file>` and validate against the spec.
    pub fn load(dir: &str, spec: &ModelSpec) -> Result<ModelWeights> {
        let cbt = Cbt::load(&format!("{dir}/{}", spec.weights_file))?;
        let mut tensors = BTreeMap::new();
        for name in &spec.param_names {
            let t = cbt.get(name)?;
            let want = spec
                .param_shapes
                .get(name)
                .ok_or_else(|| Error::Config(format!("no shape for `{name}`")))?;
            if t.dims() != want.as_slice() {
                return Err(Error::shape(format!(
                    "{name}: weights file has {:?}, manifest says {want:?}",
                    t.dims()
                )));
            }
            tensors.insert(name.clone(), (t.dims().to_vec(), t.f32s()?.to_vec()));
        }
        let pretrain_loss = cbt
            .get("pretrain_loss")
            .ok()
            .and_then(|t| t.f32s().ok().map(<[f32]>::to_vec))
            .unwrap_or_default();
        let build_val_ppl = cbt
            .get("val_ppl")
            .ok()
            .and_then(|t| t.f32s().ok().map(|v| v[0]))
            .unwrap_or(f32::NAN);
        Ok(ModelWeights { config: spec.name.clone(), tensors, pretrain_loss, build_val_ppl })
    }

    /// A 2-D parameter as a host matrix.
    pub fn matrix(&self, name: &str) -> Result<crate::tensor::Matrix<f32>> {
        let (dims, data) = self
            .tensors
            .get(name)
            .ok_or_else(|| Error::Config(format!("no parameter `{name}`")))?;
        if dims.len() != 2 {
            return Err(Error::shape(format!("{name} is {dims:?}, not 2-D")));
        }
        crate::tensor::Matrix::from_vec(dims[0], dims[1], data.clone())
    }

    /// Replace a 2-D parameter (the compression swap).
    pub fn set_matrix(&mut self, name: &str, m: &crate::tensor::Matrix<f32>) -> Result<()> {
        let (dims, data) = self
            .tensors
            .get_mut(name)
            .ok_or_else(|| Error::Config(format!("no parameter `{name}`")))?;
        if dims.as_slice() != [m.rows, m.cols] {
            return Err(Error::shape(format!(
                "set {name}: {dims:?} vs {}x{}",
                m.rows, m.cols
            )));
        }
        *data = m.data.clone();
        Ok(())
    }

    /// Flatten to artifact `Value`s in ABI order (appended after tokens).
    pub fn to_values(&self, spec: &ModelSpec) -> Result<Vec<Value>> {
        spec.param_names
            .iter()
            .map(|n| {
                let (dims, data) = self
                    .tensors
                    .get(n)
                    .ok_or_else(|| Error::Config(format!("missing `{n}`")))?;
                Ok(Value::F32(dims.clone(), data.clone()))
            })
            .collect()
    }

    /// Total parameter count (all tensors).
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|(d, _)| d.iter().product::<usize>()).sum()
    }

    /// Parameter count of the compressible projections only (the paper's
    /// compression-ratio denominator).
    pub fn compressible_params(&self, spec: &ModelSpec) -> usize {
        spec.compressible
            .iter()
            .map(|n| self.tensors[n].0.iter().product::<usize>())
            .sum()
    }
}

/// Convenience: tokens tensor → Value.
pub fn token_value(t: &Tensor) -> Result<Value> {
    Ok(Value::I32(t.dims().to_vec(), t.i32s()?.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn setup() -> Option<(Manifest, ModelWeights)> {
        let m = match Manifest::load("artifacts") {
            Ok(m) => m,
            Err(_) => {
                eprintln!("skipped: weights artifact test (artifacts/ not present)");
                return None;
            }
        };
        let spec = m.config("tiny").ok()?.clone();
        let w = ModelWeights::load("artifacts", &spec).ok()?;
        Some((m, w))
    }

    #[test]
    fn loads_trained_weights() {
        let Some((m, w)) = setup() else { return };
        let spec = m.config("tiny").unwrap();
        assert_eq!(w.tensors.len(), spec.param_names.len());
        // trained, not noise: loss curve decreased
        assert!(w.pretrain_loss.len() > 100);
        let head = w.pretrain_loss[..20].iter().sum::<f32>() / 20.0;
        let tail = w.pretrain_loss[w.pretrain_loss.len() - 20..].iter().sum::<f32>() / 20.0;
        assert!(tail < head * 0.7, "loss {head} -> {tail}");
        assert!(w.build_val_ppl < 100.0 && w.build_val_ppl > 1.0);
    }

    #[test]
    fn matrix_roundtrip_and_swap() {
        let Some((_m, mut w)) = setup() else { return };
        let q = w.matrix("l0.wq").unwrap();
        let doubled = q.scale(2.0);
        w.set_matrix("l0.wq", &doubled).unwrap();
        assert_eq!(w.matrix("l0.wq").unwrap().get(0, 0), q.get(0, 0) * 2.0);
        // shape guard
        let bad = crate::tensor::Matrix::<f32>::zeros(2, 2);
        assert!(w.set_matrix("l0.wq", &bad).is_err());
        assert!(w.matrix("l0.ln1").is_err()); // 1-D
    }

    #[test]
    fn value_marshalling_matches_abi() {
        let Some((m, w)) = setup() else { return };
        let spec = m.config("tiny").unwrap();
        let vals = w.to_values(spec).unwrap();
        assert_eq!(vals.len(), spec.param_names.len());
        let art = m.artifact(&format!("fwd_logits_{}", spec.name)).unwrap();
        for (v, s) in vals.iter().zip(&art.inputs[1..]) {
            assert_eq!(v.dims(), s.shape.as_slice(), "{}", s.name);
        }
    }

    #[test]
    fn param_counts() {
        let Some((m, w)) = setup() else { return };
        let spec = m.config("tiny").unwrap();
        let d = spec.d_model;
        let f = spec.d_ff;
        let per_layer = 4 * d * d + 2 * d * f;
        assert_eq!(w.compressible_params(spec), spec.n_layers * per_layer);
        assert!(w.param_count() > w.compressible_params(spec));
    }
}
