//! Accuracy-shaped experiments: Fig. 4 (adaptive vs constant μ),
//! Fig. 5 (λ sensitivity), Table 2 (90 %-kept, low precision),
//! Table 3 (80 %/70 % method comparison).

use super::common::{dump, Env};
use crate::calib::dataset::TaskBank;
use crate::coala::compressor::{resolve, Compressor};
use crate::coordinator::CompressionJob;
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::lowp::Precision;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

struct EvalCtx<'a> {
    env: &'a Env,
    spec: ModelSpec,
    weights: ModelWeights,
    bank: TaskBank,
}

impl<'a> EvalCtx<'a> {
    fn new(env: &'a Env, config: &str) -> Result<EvalCtx<'a>> {
        let (spec, weights) = env.weights(config)?;
        let bank = env.task_bank("base")?;
        Ok(EvalCtx { env, spec, weights, bank })
    }

    /// Compress with `job`, reconstruct, return (avg task acc, ppl, per-task accs).
    fn score(&self, job: &CompressionJob, limit: Option<usize>) -> Result<(f64, f64, Vec<f64>, Vec<f64>)> {
        let out = self.env.run_job(&self.spec, &self.weights, job)?;
        let rec = out.model.reconstruct_into(&self.weights)?;
        let scores = self.env.eval_tasks(&self.spec, &rec, &self.bank, limit)?;
        let ppl = self.env.perplexity(
            &self.spec,
            &rec,
            "val",
            if super::common::fast()? { 2 } else { 4 },
        )?;
        Ok((scores.average(), ppl, scores.accuracy, scores.stderr))
    }

    fn base_scores(&self, limit: Option<usize>) -> Result<(f64, f64, Vec<f64>, Vec<f64>)> {
        let scores = self.env.eval_tasks(&self.spec, &self.weights, &self.bank, limit)?;
        let ppl = self.env.perplexity(
            &self.spec,
            &self.weights,
            "val",
            if super::common::fast()? { 2 } else { 4 },
        )?;
        Ok((scores.average(), ppl, scores.accuracy, scores.stderr))
    }
}

fn limit() -> Option<usize> {
    // the task bank is cheap to evaluate in full (64 fwd batches); the
    // expensive knob is the number of compressions, not scoring rows
    None
}

fn calib_batches() -> Result<usize> {
    Ok(if super::common::fast()? { 2 } else { 8 })
}

/// Fig. 4: adaptive (Eq. 5, λ sweep) vs constant-μ sweep at 70 % kept.
pub fn fig4(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let ctx = EvalCtx::new(&env, "tiny")?;
    let ratio = args.get_f64("ratio", 0.08)?;
    // Operating point: the paper's "70 % compression" produces a clear
    // degradation regime on 1B-7B models; our tiny target is intrinsically
    // lower-rank, so the matching regime is keep ~8 % (DESIGN.md
    // substitutions - same degradation, different absolute ratio).
    let mut t = Table::new(
        &format!("Fig.4 — adaptive (Eq.5) vs constant μ at keep={ratio} (avg acc %)"),
        &["rule", "param", "avg acc", "ppl"],
    );
    let mut rows = Vec::new();
    for lambda in [0.3, 1.0, 3.0, 10.0] {
        let method = resolve(&format!("coala:lambda={lambda}"))?.method();
        let mut job = CompressionJob::new("tiny", method, ratio);
        job.calib_batches = calib_batches()?;
        let (acc, ppl, _, _) = ctx.score(&job, limit())?;
        t.row(vec!["adaptive λ".into(), format!("{lambda}"), format!("{acc:.1}"), format!("{ppl:.2}")]);
        rows.push(Json::from_f64s(&[1.0, lambda, acc, ppl]));
    }
    for mu in [1e-2, 1e-1, 1.0, 10.0] {
        let method = resolve(&format!("coala:mu={mu}"))?.method();
        let mut job = CompressionJob::new("tiny", method, ratio);
        job.calib_batches = calib_batches()?;
        let (acc, ppl, _, _) = ctx.score(&job, limit())?;
        t.row(vec!["constant μ".into(), format!("{mu}"), format!("{acc:.1}"), format!("{ppl:.2}")]);
        rows.push(Json::from_f64s(&[0.0, mu, acc, ppl]));
    }
    t.print();
    println!("expected shape (paper): layer-adaptive μ dominates any single constant μ.");
    dump("fig4", Json::Arr(rows))
}

/// Fig. 5: accuracy vs λ across models and ratios (stability of the
/// optimum in λ ∈ [1, 10]).
pub fn fig5(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let configs = args.get_list("configs", &["tiny", "small"]);
    let lambdas = [0.1, 0.3, 1.0, 3.0, 10.0, 30.0];
    let mut t = Table::new(
        "Fig.5 — avg accuracy vs λ",
        &["model", "ratio", "λ", "avg acc", "ppl"],
    );
    let mut rows = Vec::new();
    for cfg in &configs {
        let ctx = EvalCtx::new(&env, cfg)?;
        for ratio in [0.08, 0.12] {
            for &lambda in &lambdas {
                let method = resolve(&format!("coala:lambda={lambda}"))?.method();
                let mut job = CompressionJob::new(cfg, method, ratio);
                job.calib_batches = calib_batches()?;
                let (acc, ppl, _, _) = ctx.score(&job, limit())?;
                t.row(vec![
                    cfg.clone(),
                    format!("{ratio}"),
                    format!("{lambda}"),
                    format!("{acc:.1}"),
                    format!("{ppl:.2}"),
                ]);
                rows.push(Json::obj(vec![
                    ("model", Json::Str(cfg.clone())),
                    ("ratio", Json::Num(ratio)),
                    ("lambda", Json::Num(lambda)),
                    ("acc", Json::Num(acc)),
                    ("ppl", Json::Num(ppl)),
                ]));
            }
        }
    }
    t.print();
    println!("expected shape (paper): optimum λ is flat across ~[1, 10] for all settings.");
    dump("fig5", Json::Arr(rows))
}

/// `methods` rows are (display label, registry spec) — every method goes
/// through the `coala::compressor` registry, never a variant match.
fn method_rows(
    ctx: &EvalCtx,
    config: &str,
    ratio: f64,
    precision: Precision,
    methods: &[(&str, &str)],
    t: &mut Table,
    recs: &mut Vec<Json>,
) -> Result<()> {
    let task_names = ctx.bank.task_names.clone();
    let (bacc, bppl, baccs, bstds) = ctx.base_scores(limit())?;
    let mut cells = vec!["Original".to_string(), format!("{bppl:.2}"), format!("{bacc:.1}")];
    cells.extend(baccs.iter().zip(&bstds).map(|(a, s)| format!("{a:.1}±{s:.1}")));
    t.row(cells);
    recs.push(Json::obj(vec![
        ("method", Json::Str("Original".into())),
        ("ratio", Json::Num(1.0)),
        ("avg", Json::Num(bacc)),
        ("ppl", Json::Num(bppl)),
        ("accs", Json::from_f64s(&baccs)),
    ]));
    for (name, spec) in methods {
        let mut job = CompressionJob::new(config, resolve(spec)?.method(), ratio);
        job.calib_batches = calib_batches()?;
        job.accum_precision = precision;
        // A Gram-route method collapsing *numerically* on near-singular
        // calibration is a result (the paper's Table 2 story), not a
        // driver failure: report the collapse row and keep going.  Any
        // other error kind is a real bug and must fail the driver (and
        // the repro-smoke CI job with it).
        match ctx.score(&job, limit()) {
            Err(e @ Error::Numerical(_)) => {
                let mut cells = vec![name.to_string(), "collapse".into(), "—".into()];
                cells.extend(task_names.iter().map(|_| "—".to_string()));
                t.row(cells);
                println!("  [{name}: numerical collapse — {e}]");
                recs.push(Json::obj(vec![
                    ("method", Json::Str(name.to_string())),
                    ("ratio", Json::Num(ratio)),
                    ("collapsed", Json::Bool(true)),
                ]));
            }
            Err(e) => return Err(e),
            Ok((acc, ppl, accs, stds)) => {
                let mut cells =
                    vec![name.to_string(), format!("{ppl:.2}"), format!("{acc:.1}")];
                cells.extend(accs.iter().zip(&stds).map(|(a, s)| format!("{a:.1}±{s:.1}")));
                t.row(cells);
                recs.push(Json::obj(vec![
                    ("method", Json::Str(name.to_string())),
                    ("ratio", Json::Num(ratio)),
                    ("avg", Json::Num(acc)),
                    ("ppl", Json::Num(ppl)),
                    ("accs", Json::from_f64s(&accs)),
                ]));
            }
        }
    }
    Ok(())
}

/// Table 2: 90 % kept, Gram accumulation emulated in fp16.
pub fn table2(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let ctx = EvalCtx::new(&env, "tiny")?;
    let ratio = args.get_f64("ratio", 0.06)?;
    let mut header = vec!["method", "ppl", "avg"];
    let names: Vec<String> = ctx.bank.task_names.clone();
    for n in &names {
        header.push(n);
    }
    let mut t = Table::new(
        &format!("Table 2 — tiny @ {:.1}% kept (matching the paper 90%-compression regime), fp16 accumulation", ratio * 100.0),
        &header,
    );
    let methods: Vec<(&str, &str)> = vec![
        ("ASVD", "asvd"),
        ("SVD-LLM", "svdllm"),
        ("COALA(mu=0)", "coala"),
        ("COALA(adap λ=3)", "coala:lambda=3"),
    ];
    let mut recs = Vec::new();
    method_rows(&ctx, "tiny", ratio, Precision::F16, &methods, &mut t, &mut recs)?;
    t.print();
    println!("expected shape (paper Table 2): ASVD worst; COALA_μ ≥ COALA_{{μ=0}} ≈ SVD-LLM.");
    dump("table2", Json::Arr(recs))
}

/// Table 3: small model, 80 % and 70 % kept, all methods.
pub fn table3(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let cfg = args.get_or("config", "small");
    let ctx = EvalCtx::new(&env, cfg)?;
    let mut recs = Vec::new();
    for ratio in [0.12, 0.08] {
        let mut header = vec!["method", "ppl", "avg"];
        let names: Vec<String> = ctx.bank.task_names.clone();
        for n in &names {
            header.push(n);
        }
        let mut t = Table::new(&format!("Table 3 — {cfg} @ {:.0}% kept", ratio * 100.0), &header);
        let methods: Vec<(&str, &str)> = vec![
            ("SVD (FLAP-row)", "svd"),
            ("ASVD (SliceGPT-row)", "asvd"),
            ("SVD-LLM", "svdllm"),
            ("SVD-LLM-v2 (SoLA-row)", "svdllm2"),
            ("COALA(adap λ=3)", "coala:lambda=3"),
        ];
        method_rows(&ctx, cfg, ratio, Precision::F32, &methods, &mut t, &mut recs)?;
        t.print();
    }
    println!(
        "expected shape (paper Table 3): COALA best or second on most columns.\n\
         (FLAP/SliceGPT/SoLA are proxied by the closest implementable method —\n\
         see DESIGN.md §substitutions.)"
    );
    dump("table3", Json::Arr(recs))
}
