//! Shared experiment plumbing.

use crate::calib::dataset::Corpus;
use crate::error::Result;
use crate::model::ModelWeights;
use crate::runtime::executor::Executor;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Loaded environment for experiments that need the runtime.
pub struct Env {
    pub ex: Executor,
    pub corpus: Corpus,
}

impl Env {
    pub fn load(args: &Args) -> Result<Env> {
        let dir = crate::artifacts_dir(args.get("artifacts"));
        Ok(Env { ex: Executor::new(&dir)?, corpus: Corpus::load(&dir)? })
    }

    pub fn weights(&self, config: &str) -> Result<(crate::runtime::manifest::ModelSpec, ModelWeights)> {
        let spec = self.ex.manifest.config(config)?.clone();
        let dir = &self.ex.manifest.dir.clone();
        let w = ModelWeights::load(dir, &spec)?;
        Ok((spec, w))
    }
}

/// Dump an experiment result record to results/<id>.json.
pub fn dump(id: &str, value: Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{id}.json"), value.dump())?;
    println!("[results/{id}.json written]");
    Ok(())
}

/// Fast-mode row/batch scaling: COALA_REPRO_FAST=1 shrinks sweeps.
pub fn fast() -> bool {
    std::env::var("COALA_REPRO_FAST").as_deref() == Ok("1")
}
