//! Shared experiment plumbing: the backend-agnostic environment every
//! repro driver runs against.
//!
//! [`Env`] has two constructors behind one interface:
//!
//! * **artifact route** (`--route device`, the default): PJRT executor +
//!   on-disk artifacts, exactly the original behavior;
//! * **synthetic host route** (`--route host`): a deterministic,
//!   PRNG-generated model spec + weights + Markov corpus + regime-
//!   controlled activations ([`crate::model::synthetic`],
//!   [`crate::calib::synthetic`]) with evaluation through the pure-Rust
//!   forward — zero files, zero PJRT, zero non-default features.
//!
//! Drivers ask the environment for weights, calibration captures,
//! compression runs, task banks, and evaluation; they never branch on
//! the route themselves.

use crate::calib::accumulate::{AccumBackend, AccumKind, SketchCfg};
use crate::calib::activations::{chunk_for_proj, ActivationSource, DeviceActivationSource};
use crate::calib::dataset::{Corpus, TaskBank};
use crate::calib::synthetic::SyntheticActivations;
use crate::coala::compressor::Route;
use crate::coordinator::{CheckpointCfg, CompressionJob, CompressionOutcome, EnginePlan, Pipeline};
use crate::error::{Error, Result};
use crate::eval::TaskScores;
use crate::finetune::{AdapterInit, AdapterSet, DeviceFineTuner, FineTuner, HostFineTuner};
use crate::model::synthetic as synth;
use crate::model::ModelWeights;
use crate::runtime::executor::Executor;
use crate::runtime::manifest::ModelSpec;
use crate::telemetry::TelemetrySink;
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Loaded environment for experiments.
pub struct Env {
    /// Holds the manifest on both routes; executes artifacts only on the
    /// artifact route (the synthetic manifest has an empty artifact
    /// table, so stray device calls fail loudly).
    pub ex: Executor,
    pub corpus: Corpus,
    /// Which backend accumulates + factorizes in compression jobs.
    pub route: Route,
    /// Worker counts for the execution engine (`--workers`,
    /// `--queue-cap`); the sequential plan by default.  Results are
    /// identical at any worker count.
    pub plan: EnginePlan,
    /// Calibration checkpointing (`--checkpoint-dir`/`--resume`); off
    /// by default.  Results are identical with or without it.
    pub checkpoint: Option<CheckpointCfg>,
    /// Accumulator-kind override (`--accum sketch`) for the R-consuming
    /// methods; `None` keeps each method's declared kind.
    pub accum: Option<AccumKind>,
    seed: u64,
    synthetic: bool,
}

impl Env {
    /// Route dispatch: `--route host` builds the synthetic environment
    /// (seeded by `--seed`), anything else loads the artifacts.
    pub fn load(args: &Args) -> Result<Env> {
        let mut env = match args.route()? {
            Route::Host => Env::synthetic(args.seed(synth::DEFAULT_SEED)?)?,
            Route::Device => Env::from_artifacts(args)?,
        };
        env.accum = args.accum()?;
        // stamp the environment identity into the checkpoint config so
        // a stale checkpoint from a different seed/route/accumulator
        // never resumes
        let stamp = format!("{:?}:seed{}{}", env.route, env.seed, env.accum_stamp()?);
        env.checkpoint = args.checkpoint()?.map(|c| c.with_source(stamp));
        let mut env = env.with_plan(args.engine_plan()?);
        // one sink for the whole run (`COALA_TELEMETRY`), stamped with
        // the environment-level labels; run_job adds the per-job ones
        let (route, workers) = (env.route, env.plan.capture_workers);
        env.plan.telemetry = TelemetrySink::from_env()?.with_labels(|l| {
            l.route = format!("{route:?}").to_lowercase();
            l.workers = workers;
            l.shards = 1;
            l.span = "run".to_string();
        });
        Ok(env)
    }

    /// The artifact/PJRT environment (requires `artifacts/` on disk).
    pub fn from_artifacts(args: &Args) -> Result<Env> {
        let dir = crate::artifacts_dir(args.get("artifacts"))?;
        Ok(Env {
            ex: Executor::new(&dir)?,
            corpus: Corpus::load(&dir)?,
            route: Route::Device,
            plan: EnginePlan::default(),
            checkpoint: None,
            accum: None,
            seed: 0,
            synthetic: false,
        })
    }

    /// The synthetic host environment: everything generated from `seed`.
    pub fn synthetic(seed: u64) -> Result<Env> {
        let manifest = synth::synthetic_manifest();
        let corpus = Corpus::synthetic(synth::VOCAB, synth::SPLIT_LEN, seed);
        Ok(Env {
            ex: Executor::from_manifest(manifest)?,
            corpus,
            route: Route::Host,
            plan: EnginePlan::default(),
            checkpoint: None,
            accum: None,
            seed,
            synthetic: true,
        })
    }

    /// Same environment with an explicit engine plan (worker counts).
    pub fn with_plan(mut self, plan: EnginePlan) -> Env {
        self.plan = plan;
        self
    }

    pub fn is_synthetic(&self) -> bool {
        self.synthetic
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Spec + weights for a config, whichever route is active.
    pub fn weights(&self, config: &str) -> Result<(ModelSpec, ModelWeights)> {
        let spec = self.ex.manifest.config(config)?.clone();
        let w = if self.synthetic {
            synth::synthetic_weights(&spec, self.seed)
        } else {
            let dir = self.ex.manifest.dir.clone();
            ModelWeights::load(&dir, &spec)?
        };
        Ok((spec, w))
    }

    /// The synthetic activation source for a spec (None on the artifact
    /// route, where activations come from `fwd_acts` capture).
    pub fn activation_source(&self, spec: &ModelSpec) -> Option<SyntheticActivations> {
        self.synthetic
            .then(|| SyntheticActivations::new(spec.clone(), self.seed))
    }

    /// The active route's accumulate backend (pure-Rust host linalg or
    /// the PJRT artifacts).
    pub fn accum_backend(&self) -> AccumBackend<'_> {
        match self.route {
            Route::Host => AccumBackend::Host,
            Route::Device => AccumBackend::Device(&self.ex),
        }
    }

    /// Sketch-accumulator fingerprint fragment: empty for exact kinds;
    /// for `--accum sketch`, names the Ω family, sketch geometry, and
    /// seed (the three knobs every worker/shard must agree on) so
    /// states produced under different `COALA_SKETCH_KIND` /
    /// `COALA_SKETCH_ROWS` / `COALA_SKETCH_SEED` settings can never
    /// silently merge.
    fn accum_stamp(&self) -> Result<String> {
        if self.accum != Some(AccumKind::Sketch) {
            return Ok(String::new());
        }
        let cfg = SketchCfg::from_env()?;
        let rows = cfg.rows.map_or_else(|| "auto".to_string(), |r| r.to_string());
        Ok(format!(":sketch:{}:r{rows}:s{}", cfg.kind.label(), cfg.seed))
    }

    /// Fingerprint of this environment's calibration source for a
    /// (config, batch-count) run — stamped into shard state files and
    /// checkpoints so mismatched shards/checkpoints are rejected
    /// instead of silently merged (`coala shard`/`merge` use it).
    pub fn source_id(&self, config: &str, batches: usize) -> Result<String> {
        Ok(format!(
            "{config}:{:?}:seed{}:b{batches}{}",
            self.route,
            self.seed,
            self.accum_stamp()?
        ))
    }

    /// A boxed calibration source for whichever route is active — the
    /// synthetic generator or the `fwd_acts` device capture over
    /// `batches` batches of the calib split.  The `coala shard`/`merge`
    /// subcommands drive the engine through this without branching on
    /// the route.
    pub fn calib_source<'s>(
        &'s self,
        spec: &'s ModelSpec,
        weights: &'s ModelWeights,
        batches: usize,
    ) -> Result<Box<dyn ActivationSource + 's>> {
        match self.activation_source(spec) {
            Some(src) => Ok(Box::new(src)),
            None => Ok(Box::new(DeviceActivationSource::new(
                &self.ex,
                spec,
                weights,
                &self.corpus,
                "calib",
                batches,
            )?)),
        }
    }

    /// Run one compression job end-to-end on the active route.
    pub fn run_job(
        &self,
        spec: &ModelSpec,
        weights: &ModelWeights,
        job: &CompressionJob,
    ) -> Result<CompressionOutcome> {
        use crate::coala::compressor::{compressor_for, Compressor as _};
        // repro tables run Gram/Scales methods alongside the
        // R-consumers, so the harness applies `--accum sketch` only
        // where it is meaningful and leaves the rest on their declared
        // statistic.  (The single-method CLI paths — compress / shard /
        // merge — stay strict and reject the mismatch loudly.)
        let comp = compressor_for(&job.method);
        let accum = self.accum.filter(|_| comp.accum_kind() == AccumKind::RFactor);
        let mut plan = self.plan.clone();
        let kind = accum.unwrap_or_else(|| comp.accum_kind());
        plan.telemetry = plan
            .telemetry
            .with_labels(|l| {
                l.config = job.config.clone();
                l.method = job.method.name();
                l.accum = format!("{kind:?}").to_lowercase();
            })
            // the calibration-source fingerprint doubles as the trace
            // id: shard/merge processes of the same run derive the same
            // run_id with zero coordination
            .with_run(&self.source_id(&job.config, job.calib_batches)?);
        let pipe = Pipeline::new(&self.ex, spec.clone(), weights)
            .with_route(self.route)
            .with_plan(plan)
            .with_checkpoint(self.checkpoint.clone())
            .with_accum(accum);
        match self.activation_source(spec) {
            Some(src) => pipe.run_with_source(job, &src),
            None => pipe.run(job, &self.corpus),
        }
    }

    /// Capture the calibration matrix Xᵀ (rows) feeding one projection,
    /// plus the projection's weight matrix — the stability drivers' raw
    /// material.
    pub fn capture_xt(
        &self,
        config: &str,
        proj: &str,
        batches: usize,
    ) -> Result<(Matrix<f32>, Matrix<f32>)> {
        let (spec, w) = self.weights(config)?;
        let wm = w.matrix(proj)?;
        let src: Box<dyn ActivationSource + '_> = match self.activation_source(&spec) {
            Some(s) => Box::new(s),
            None => Box::new(DeviceActivationSource::new(
                &self.ex,
                &spec,
                &w,
                &self.corpus,
                "calib",
                batches,
            )?),
        };
        let mut xt: Option<Matrix<f32>> = None;
        for b in 0..batches {
            let chunks = src.capture_batch(b)?;
            let c = chunk_for_proj(&spec, &chunks, proj)?;
            xt = Some(match xt {
                None => c.xt.clone(),
                Some(prev) => prev.vstack(&c.xt)?,
            });
        }
        let xt = xt.ok_or_else(|| Error::Config("capture_xt needs ≥ 1 batch".into()))?;
        Ok((wm, xt))
    }

    /// Route-resolved adapter initialization (the Table 4 rows).  The
    /// device route calibrates on `calib_batches` batches of the
    /// artifact `ft_calib` split; the host route streams a
    /// separately-seeded regime-controlled activation source — in both
    /// cases the low-data regime where CorDA's Gram inversion degrades.
    pub fn init_adapters(
        &self,
        spec: &ModelSpec,
        weights: &ModelWeights,
        strategy: AdapterInit,
        rank: usize,
        calib_batches: usize,
    ) -> Result<AdapterSet> {
        if self.synthetic {
            // NOT derived from the shifted ft corpus (the synthetic
            // generator is chain-agnostic): the host route stresses the
            // *numerical* low-data behavior of each init
            let src = SyntheticActivations::new(spec.clone(), self.seed ^ 0xF7CA);
            crate::finetune::init_adapters_from_source(
                spec,
                weights,
                &src,
                strategy,
                rank,
                calib_batches,
                40,
            )
        } else {
            crate::finetune::init_adapters(
                &self.ex,
                spec,
                weights,
                &self.corpus,
                strategy,
                rank,
                "ft_calib",
                calib_batches,
            )
        }
    }

    /// The Table 4 fine-tuning pool: 3 fixed-seed batches of
    /// `batch × seq_len+1` shifted-distribution windows — 24 examples
    /// at the artifact geometry (batch 8), 12 at the synthetic one
    /// (batch 4); the small-pool/multi-epoch regime either way.  One
    /// definition shared by the repro driver and the `finetune` CLI/CI
    /// smoke gate, so they always train on the same pool as the table
    /// they guard.
    pub fn ft_pool(&self, spec: &ModelSpec) -> Result<Vec<crate::runtime::executor::Value>> {
        self.corpus.train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)
    }

    /// The active route's [`FineTuner`]: the `ft_step` artifact driver
    /// or the pure-Rust fp64 trainer (with gradient accumulation fanned
    /// across the engine plan's worker count).  Like compression jobs,
    /// drivers never branch on the route themselves.
    pub fn fine_tuner<'a>(&'a self, spec: &ModelSpec, rank: usize) -> Box<dyn FineTuner + 'a> {
        if self.synthetic {
            let tel = self
                .plan
                .telemetry
                .clone()
                .with_labels(|l| {
                    l.config = spec.name.clone();
                    l.span = "trainer".to_string();
                })
                .with_run(&format!("{}:{:?}:seed{}:ft", spec.name, self.route, self.seed));
            Box::new(
                HostFineTuner::new(spec.clone(), rank)
                    .with_workers(self.plan.factorize_workers)
                    .with_telemetry(tel),
            )
        } else {
            Box::new(DeviceFineTuner::new(&self.ex, spec, rank))
        }
    }

    /// The probe-task bank (`which` ∈ {"base", "ft"}).
    pub fn task_bank(&self, which: &str) -> Result<TaskBank> {
        if self.synthetic {
            TaskBank::synthetic(
                synth::VOCAB,
                synth::SEQ_LEN,
                which,
                &self.ex.manifest.task_names,
                synth::BANK_ROWS,
                self.seed,
            )
        } else {
            TaskBank::load(&self.ex.manifest.dir, which, &self.ex.manifest.task_names)
        }
    }

    /// Perplexity of a weight set over a corpus split, on the active
    /// route's evaluator.
    pub fn perplexity(
        &self,
        spec: &ModelSpec,
        weights: &ModelWeights,
        split: &str,
        n_batches: usize,
    ) -> Result<f64> {
        let toks = self.corpus.split(split)?;
        if self.synthetic {
            crate::eval::perplexity_host(spec, weights, toks, n_batches)
        } else {
            crate::eval::perplexity(&self.ex, spec, weights, toks, n_batches)
        }
    }

    /// Probe-task scores of a weight set, on the active route's
    /// evaluator.
    pub fn eval_tasks(
        &self,
        spec: &ModelSpec,
        weights: &ModelWeights,
        bank: &TaskBank,
        limit: Option<usize>,
    ) -> Result<TaskScores> {
        if self.synthetic {
            crate::eval::eval_tasks_host(spec, weights, bank, limit)
        } else {
            crate::eval::eval_tasks(&self.ex, spec, weights, bank, limit)
        }
    }
}

/// Dump an experiment result record to results/<id>.json.
pub fn dump(id: &str, value: Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{id}.json"), value.dump())?;
    println!("[results/{id}.json written]");
    Ok(())
}

/// Fast-mode row/batch scaling: `COALA_REPRO_FAST` (1/true/yes) shrinks
/// sweeps.  Any other non-empty value is a hard error — a typo'd flag
/// must not silently run the full sweep (or silently skip it).
pub fn fast() -> Result<bool> {
    crate::util::env::flag("COALA_REPRO_FAST")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_env_loads_without_any_files() {
        let env = Env::synthetic(7).unwrap();
        assert!(env.is_synthetic());
        assert_eq!(env.route, Route::Host);
        let (spec, w) = env.weights("tiny").unwrap();
        assert_eq!(w.tensors.len(), spec.param_names.len());
        // capture + routing works for every compressible projection
        let (wm, xt) = env.capture_xt("tiny", "l1.wq", 2).unwrap();
        assert_eq!((wm.rows, wm.cols), (spec.d_model, spec.d_model));
        assert_eq!(xt.rows, 2 * spec.batch * spec.seq_len);
        assert_eq!(xt.cols, spec.d_model);
        // evaluation works without artifacts
        let bank = env.task_bank("base").unwrap();
        let scores = env.eval_tasks(&spec, &w, &bank, Some(32)).unwrap();
        assert_eq!(scores.names.len(), 8);
        let ppl = env.perplexity(&spec, &w, "val", 2).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }

    #[test]
    fn synthetic_env_is_seed_deterministic() {
        let a = Env::synthetic(11).unwrap();
        let b = Env::synthetic(11).unwrap();
        let c = Env::synthetic(12).unwrap();
        let (_, wa) = a.weights("tiny").unwrap();
        let (_, wb) = b.weights("tiny").unwrap();
        let (_, wc) = c.weights("tiny").unwrap();
        assert_eq!(wa.tensors["embed"].1, wb.tensors["embed"].1);
        assert_ne!(wa.tensors["embed"].1, wc.tensors["embed"].1);
        let (_, xa) = a.capture_xt("tiny", "l0.wv", 1).unwrap();
        let (_, xb) = b.capture_xt("tiny", "l0.wv", 1).unwrap();
        assert_eq!(xa.data, xb.data);
    }

    #[test]
    fn synthetic_run_job_compresses_on_host() {
        use crate::coala::compressor::{resolve, Compressor};
        let env = Env::synthetic(3).unwrap();
        let (spec, w) = env.weights("tiny").unwrap();
        let mut job =
            CompressionJob::new("tiny", resolve("coala:lambda=3").unwrap().method(), 0.3);
        job.calib_batches = 2;
        let out = env.run_job(&spec, &w, &job).unwrap();
        assert!(out.model.all_finite());
        assert_eq!(out.model.factors.len(), spec.compressible.len());
        // the compressed model still evaluates end-to-end on the host
        let rec = out.model.reconstruct_into(&w).unwrap();
        let ppl = env.perplexity(&spec, &rec, "val", 2).unwrap();
        assert!(ppl.is_finite(), "compressed ppl {ppl}");
    }

    #[test]
    fn env_fine_tuner_trains_on_the_host_route() {
        let env = Env::synthetic(5).unwrap();
        let (spec, w) = env.weights("tiny").unwrap();
        let mut set = env
            .init_adapters(&spec, &w, AdapterInit::PiSSA, 4, 2)
            .unwrap();
        let pool = env
            .corpus
            .train_batches("ft_train", spec.batch, spec.seq_len, 2, 9)
            .unwrap();
        let tuner = env.fine_tuner(&spec, 4);
        let losses = tuner.train_on_batches(&mut set, &pool, 12, 2e-3).unwrap();
        assert_eq!(losses.len(), 12);
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        assert!(set.all_finite());
        let bank = env.task_bank("ft").unwrap();
        let scores = tuner.eval_tasks(&set, &bank, Some(32)).unwrap();
        assert_eq!(scores.names.len(), 8);
    }

    #[test]
    fn sketch_accum_stamps_the_source_id() {
        let mut env = Env::synthetic(4).unwrap();
        let plain = env.source_id("tiny", 6).unwrap();
        env.accum = Some(AccumKind::Sketch);
        let sk = env.source_id("tiny", 6).unwrap();
        assert_ne!(plain, sk);
        // the stamp names the Ω family too (kind divergence must show
        // up in the fingerprint, not just rows/seed)
        assert!(sk.contains(":sketch:gaussian:"), "{sk}");
    }

    #[test]
    fn sketch_run_job_compresses_on_host() {
        use crate::coala::compressor::{resolve, Compressor};
        let mut env = Env::synthetic(8).unwrap();
        env.accum = Some(AccumKind::Sketch);
        let (spec, w) = env.weights("tiny").unwrap();
        let mut job = CompressionJob::new("tiny", resolve("coala").unwrap().method(), 0.4);
        job.calib_batches = 2;
        let out = env.run_job(&spec, &w, &job).unwrap();
        assert!(out.model.all_finite());
        assert_eq!(out.model.factors.len(), spec.compressible.len());
        // multi-method repro tables also run Gram consumers under
        // --accum sketch: the harness leaves them on their declared
        // statistic (strict rejection lives in the compress/shard CLI
        // paths via resolve_accum_kind)
        let mut gram = CompressionJob::new("tiny", resolve("svdllm").unwrap().method(), 0.4);
        gram.calib_batches = 2;
        let out = env.run_job(&spec, &w, &gram).unwrap();
        assert!(out.model.all_finite());
    }

    #[test]
    fn checkpointed_run_job_matches_plain_run_bitwise() {
        use crate::coala::compressor::{resolve, Compressor};
        let mut job = CompressionJob::new("tiny", resolve("coala").unwrap().method(), 0.4);
        job.calib_batches = 3;
        let env = Env::synthetic(6).unwrap();
        let (spec, w) = env.weights("tiny").unwrap();
        let plain = env.run_job(&spec, &w, &job).unwrap();

        let dir = std::env::temp_dir().join(format!("coala-env-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut env_ck = Env::synthetic(6).unwrap();
        env_ck.checkpoint = Some(CheckpointCfg::new(dir.display().to_string(), 1, false));
        let ck = env_ck.run_job(&spec, &w, &job).unwrap();
        assert!(dir.exists(), "no checkpoint was written");
        for (proj, fa) in &plain.model.factors {
            let fb = &ck.model.factors[proj];
            assert_eq!(fa.a.data, fb.a.data, "{proj}");
            assert_eq!(fa.b.data, fb.b.data, "{proj}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parallel_plan_env_matches_sequential_bitwise() {
        use crate::coala::compressor::{resolve, Compressor};
        let mut job = CompressionJob::new("tiny", resolve("coala").unwrap().method(), 0.4);
        job.calib_batches = 2;
        let env_seq = Env::synthetic(3).unwrap();
        let env_par = Env::synthetic(3).unwrap().with_plan(EnginePlan::with_workers(4));
        let (spec, w) = env_seq.weights("tiny").unwrap();
        let a = env_seq.run_job(&spec, &w, &job).unwrap();
        let b = env_par.run_job(&spec, &w, &job).unwrap();
        for (proj, fa) in &a.model.factors {
            let fb = &b.model.factors[proj];
            assert_eq!(fa.a.data, fb.a.data, "{proj}");
            assert_eq!(fa.b.data, fb.b.data, "{proj}");
        }
    }
}
