//! Table 4: PEFT-initialization comparison at rank r (24-example
//! calibration, short fine-tune on the *shifted* fact distribution,
//! probe accuracy on the new facts).

use super::common::{dump, Env};
use crate::calib::dataset::TaskBank;
use crate::error::Result;
use crate::finetune::{init_adapters, AdapterInit, FineTuner};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

pub fn table4(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let (spec, weights) = env.weights("tiny")?;
    let rank = env.ex.manifest.ft_rank;
    let steps = if super::common::fast() { 100 } else { args.get_usize("steps", 200)? };
    let lr = args.get_f64("lr", 1e-3)?;
    let bank = TaskBank::load(&env.ex.manifest.dir, "ft", &env.ex.manifest.task_names)?;
    let limit = None;

    // 24-example fine-tuning pool (3 batches of 8) cycled for `steps`
    let pool = env.corpus.train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)?;

    let mut header = vec!["init", "loss₀", "loss_end", "avg"];
    let names = bank.task_names.clone();
    for n in &names {
        header.push(n);
    }
    let mut t = Table::new(
        &format!("Table 4 — PEFT init comparison (rank {rank}, {steps} steps)"),
        &header,
    );
    let strategies = [
        AdapterInit::LoRA,
        AdapterInit::PiSSA,
        AdapterInit::CorDA,
        AdapterInit::CoalaA2,
        AdapterInit::CoalaA1,
    ];
    let mut recs = Vec::new();
    for strat in strategies {
        let mut set = init_adapters(
            &env.ex,
            &spec,
            &weights,
            &env.corpus,
            strat,
            rank,
            "ft_calib",
            3, // 24 examples = 3 batches of 8: the low-data regime
        )?;
        let sane = set
            .adapters
            .values()
            .all(|(a, b)| a.all_finite() && b.all_finite());
        let tuner = FineTuner::new(&env.ex, &spec, rank);
        let (l0, lend, avg, accs, stds) = if sane {
            let losses = tuner.train_on_batches(&mut set, &pool, steps, lr)?;
            let scores = tuner.eval_tasks(&set, &bank, limit)?;
            (
                losses[0] as f64,
                *losses.last().unwrap() as f64,
                scores.average(),
                scores.accuracy.clone(),
                scores.stderr.clone(),
            )
        } else {
            // CorDA's Gram inversion can produce non-finite adapters in
            // the low-data regime — report the collapse honestly.
            (f64::NAN, f64::NAN, 0.0, vec![0.0; names.len()], vec![0.0; names.len()])
        };
        let mut cells = vec![
            strat.name().to_string(),
            format!("{l0:.3}"),
            format!("{lend:.3}"),
            format!("{avg:.1}"),
        ];
        cells.extend(accs.iter().zip(&stds).map(|(a, s)| format!("{a:.1}±{s:.1}")));
        t.row(cells);
        recs.push(Json::obj(vec![
            ("init", Json::Str(strat.name().into())),
            ("avg", Json::Num(avg)),
            ("loss_end", Json::Num(lend)),
            ("accs", Json::from_f64s(&accs)),
        ]));
    }
    t.print();
    println!(
        "expected shape (paper Table 4): unrobust CorDA degraded; COALA α=1/α=2\n\
         ≈ PiSSA ≥ LoRA, with α=1 slightly ahead."
    );
    dump("table4", Json::Arr(recs))
}
