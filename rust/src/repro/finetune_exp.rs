//! Table 4: PEFT-initialization comparison at rank r (3-batch low-data
//! calibration, short fine-tune on the *shifted* fact distribution,
//! probe accuracy on the new facts).
//!
//! One protocol, both routes: adapters are initialized through the
//! route's factorization backend (`Env::init_adapters`), trained with
//! real Adam steps through the route's [`crate::finetune::FineTuner`]
//! (`ft_step` artifact on the device route, the pure-Rust fp64
//! backprop trainer on the host route), and scored on the shifted task
//! bank by the route's evaluator.  The drivers below never branch on
//! the route.  CorDA's Gram inversion can collapse in the 24-example
//! low-data regime — a collapsed init is reported honestly (NaN losses,
//! zero scores) instead of being trained on garbage.

use super::common::{dump, Env};
use crate::error::Result;
use crate::finetune::{AdapterInit, FineTuner};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

pub fn table4(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let (spec, weights) = env.weights("tiny")?;
    let rank = env.ex.manifest.ft_rank;
    let steps =
        if super::common::fast()? { 100 } else { args.get_usize("steps", 200)? }.max(1);
    let lr = args.get_f64("lr", 1e-3)?;
    let bank = env.task_bank("ft")?;
    let limit = None;

    // small fixed fine-tuning pool (3 batches) cycled for `steps`
    let pool = env.ft_pool(&spec)?;

    let mut header = vec!["init", "loss₀", "loss_end", "avg"];
    let names = bank.task_names.clone();
    for n in &names {
        header.push(n);
    }
    let route = if env.is_synthetic() { "host backprop" } else { "ft_step artifact" };
    let title =
        format!("Table 4 — PEFT init comparison (rank {rank}, {steps} Adam steps, {route})");
    let mut t = Table::new(&title, &header);
    let strategies = [
        AdapterInit::LoRA,
        AdapterInit::PiSSA,
        AdapterInit::CorDA,
        AdapterInit::CoalaA2,
        AdapterInit::CoalaA1,
    ];
    let mut recs = Vec::new();
    for strat in strategies {
        let (l0, lend, avg, accs, stds) =
            score(&env, &spec, &weights, strat, rank, &pool, &bank, steps, lr, limit)?;
        let mut cells = vec![
            strat.name().to_string(),
            format!("{l0:.3}"),
            format!("{lend:.3}"),
            format!("{avg:.1}"),
        ];
        cells.extend(accs.iter().zip(&stds).map(|(a, s)| format!("{a:.1}±{s:.1}")));
        t.row(cells);
        recs.push(Json::obj(vec![
            ("init", Json::Str(strat.name().into())),
            ("avg", Json::Num(avg)),
            ("loss0", Json::Num(l0)),
            ("loss_end", Json::Num(lend)),
            ("accs", Json::from_f64s(&accs)),
        ]));
    }
    t.print();
    println!(
        "expected shape (paper Table 4): unrobust CorDA degraded/collapsed in the\n\
         low-data regime; COALA α=1/α=2 ≈ PiSSA ≥ LoRA after training, with α=1\n\
         slightly ahead."
    );
    dump("table4", Json::Arr(recs))
}

type Row = (f64, f64, f64, Vec<f64>, Vec<f64>);

/// Collapse row: the init produced non-finite adapters (or errored).
fn collapsed(n_tasks: usize) -> Row {
    (f64::NAN, f64::NAN, 0.0, vec![0.0; n_tasks], vec![0.0; n_tasks])
}

/// The one Table 4 scoring protocol: init → train → probe, entirely
/// through the environment's route-resolved backends.
#[allow(clippy::too_many_arguments)]
fn score(
    env: &Env,
    spec: &crate::runtime::manifest::ModelSpec,
    weights: &crate::model::ModelWeights,
    strat: AdapterInit,
    rank: usize,
    pool: &[crate::runtime::executor::Value],
    bank: &crate::calib::dataset::TaskBank,
    steps: usize,
    lr: f64,
    limit: Option<usize>,
) -> Result<Row> {
    // 3 calibration batches (24 examples at the artifact geometry): the
    // low-data regime where CorDA's Gram inversion degrades.  Only
    // *numerical* failures are the collapse Table 4 reports; setup/IO/
    // config errors (e.g. a missing artifact split on the device route)
    // still abort the run.
    let mut set = match env.init_adapters(spec, weights, strat, rank, 3) {
        Ok(set) => set,
        Err(e) if e.is_numerical() => {
            println!("  [{}: init collapsed — {e}]", strat.name());
            return Ok(collapsed(bank.task_names.len()));
        }
        Err(e) => return Err(e),
    };
    if !set.all_finite() {
        return Ok(collapsed(bank.task_names.len()));
    }
    let tuner = env.fine_tuner(spec, rank);
    let losses = tuner.train_on_batches(&mut set, pool, steps, lr)?;
    // divergence DURING training (finite-but-extreme init factors can
    // overflow the forward) is the same collapse: scoring NaN adapters
    // would fabricate choice-0 hit rates as accuracy
    if !set.all_finite() || losses.iter().any(|l| !l.is_finite()) {
        println!("  [{}: training diverged — reported as collapse]", strat.name());
        return Ok(collapsed(bank.task_names.len()));
    }
    let scores = tuner.eval_tasks(&set, bank, limit)?;
    Ok((
        losses[0] as f64,
        *losses.last().unwrap() as f64,
        scores.average(),
        scores.accuracy.clone(),
        scores.stderr.clone(),
    ))
}
