//! Table 4: PEFT-initialization comparison at rank r (24-example
//! calibration, short fine-tune on the *shifted* fact distribution,
//! probe accuracy on the new facts).
//!
//! Routes: the artifact route runs the full protocol (init → `ft_step`
//! Adam training → `ft_logits` scoring).  The synthetic host route runs
//! the *initialization-quality* protocol: adapters are built through the
//! compressor registry's host factorizations on the low-data shifted
//! calibration stream, and the adapted model (W_res + A·B) is scored
//! directly by the host forward — no training step, since backprop only
//! exists as an AOT artifact.  That is exactly the regime where the
//! paper's Table 4 separates methods anyway: CorDA's Gram inversion
//! collapses at 24 examples while α ∈ {1, 2} stays finite.

use super::common::{dump, Env};
use crate::error::Result;
use crate::finetune::{init_adapters, init_adapters_from_source, AdapterInit, FineTuner};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

pub fn table4(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let (spec, weights) = env.weights("tiny")?;
    let rank = env.ex.manifest.ft_rank;
    let steps = if super::common::fast() { 100 } else { args.get_usize("steps", 200)? };
    let lr = args.get_f64("lr", 1e-3)?;
    let bank = env.task_bank("ft")?;
    let limit = None;

    // 24-example fine-tuning pool (3 batches of 8) cycled for `steps`
    let pool = env.corpus.train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)?;

    let mut header = vec!["init", "loss₀", "loss_end", "avg"];
    let names = bank.task_names.clone();
    for n in &names {
        header.push(n);
    }
    let title = if env.is_synthetic() {
        format!("Table 4 — PEFT init quality, host route (rank {rank}, no training step)")
    } else {
        format!("Table 4 — PEFT init comparison (rank {rank}, {steps} steps)")
    };
    let mut t = Table::new(&title, &header);
    let strategies = [
        AdapterInit::LoRA,
        AdapterInit::PiSSA,
        AdapterInit::CorDA,
        AdapterInit::CoalaA2,
        AdapterInit::CoalaA1,
    ];
    let mut recs = Vec::new();
    for strat in strategies {
        let (l0, lend, avg, accs, stds) = if env.is_synthetic() {
            score_host(&env, &spec, &weights, strat, rank, &pool, &bank, limit)?
        } else {
            score_device(&env, &spec, &weights, strat, rank, &pool, &bank, steps, lr, limit)?
        };
        let mut cells = vec![
            strat.name().to_string(),
            format!("{l0:.3}"),
            format!("{lend:.3}"),
            format!("{avg:.1}"),
        ];
        cells.extend(accs.iter().zip(&stds).map(|(a, s)| format!("{a:.1}±{s:.1}")));
        t.row(cells);
        recs.push(Json::obj(vec![
            ("init", Json::Str(strat.name().into())),
            ("avg", Json::Num(avg)),
            ("loss_end", Json::Num(lend)),
            ("accs", Json::from_f64s(&accs)),
        ]));
    }
    t.print();
    if env.is_synthetic() {
        println!(
            "expected shape: CorDA's Gram inversion degrades/collapses in the\n\
             low-data regime; COALA α=1/α=2 and PiSSA stay finite.  (Training\n\
             steps need the ft_step artifact — run --route device for them.)"
        );
    } else {
        println!(
            "expected shape (paper Table 4): unrobust CorDA degraded; COALA α=1/α=2\n\
             ≈ PiSSA ≥ LoRA, with α=1 slightly ahead."
        );
    }
    dump("table4", Json::Arr(recs))
}

type Row = (f64, f64, f64, Vec<f64>, Vec<f64>);

/// Collapse row: the init produced non-finite adapters (or errored).
fn collapsed(n_tasks: usize) -> Row {
    (f64::NAN, f64::NAN, 0.0, vec![0.0; n_tasks], vec![0.0; n_tasks])
}

#[allow(clippy::too_many_arguments)]
fn score_device(
    env: &Env,
    spec: &crate::runtime::manifest::ModelSpec,
    weights: &crate::model::ModelWeights,
    strat: AdapterInit,
    rank: usize,
    pool: &[crate::runtime::executor::Value],
    bank: &crate::calib::dataset::TaskBank,
    steps: usize,
    lr: f64,
    limit: Option<usize>,
) -> Result<Row> {
    let mut set = init_adapters(
        &env.ex,
        spec,
        weights,
        &env.corpus,
        strat,
        rank,
        "ft_calib",
        3, // 24 examples = 3 batches of 8: the low-data regime
    )?;
    let sane = set.adapters.values().all(|(a, b)| a.all_finite() && b.all_finite());
    if !sane {
        // CorDA's Gram inversion can produce non-finite adapters in
        // the low-data regime — report the collapse honestly.
        return Ok(collapsed(bank.task_names.len()));
    }
    let tuner = FineTuner::new(&env.ex, spec, rank);
    let losses = tuner.train_on_batches(&mut set, pool, steps, lr)?;
    let scores = tuner.eval_tasks(&set, bank, limit)?;
    Ok((
        losses[0] as f64,
        *losses.last().unwrap() as f64,
        scores.average(),
        scores.accuracy.clone(),
        scores.stderr.clone(),
    ))
}

#[allow(clippy::too_many_arguments)]
fn score_host(
    env: &Env,
    spec: &crate::runtime::manifest::ModelSpec,
    weights: &crate::model::ModelWeights,
    strat: AdapterInit,
    rank: usize,
    pool: &[crate::runtime::executor::Value],
    bank: &crate::calib::dataset::TaskBank,
    limit: Option<usize>,
) -> Result<Row> {
    // A separately-seeded regime-controlled activation stream, 3 batches
    // — the low-data regime.  Note this is NOT derived from the shifted
    // ft corpus (the synthetic generator is chain-agnostic); the host
    // route stresses the *numerical* low-data behavior of each init, not
    // base-vs-shifted calibration distributions.
    let src = crate::calib::synthetic::SyntheticActivations::new(
        spec.clone(),
        env.seed() ^ 0xF7CA,
    );
    let set = match init_adapters_from_source(spec, weights, &src, strat, rank, 3, 40) {
        Ok(set) => set,
        Err(e) => {
            println!("  [{}: init collapsed — {e}]", strat.name());
            return Ok(collapsed(bank.task_names.len()));
        }
    };
    let sane = set.adapters.values().all(|(a, b)| a.all_finite() && b.all_finite());
    if !sane {
        return Ok(collapsed(bank.task_names.len()));
    }
    // adapted model = W_res + A·B swapped into the weight set
    let mut adapted = set.frozen.clone();
    for (proj, (a, b)) in &set.adapters {
        let delta = crate::tensor::ops::matmul(a, b)?;
        let eff = adapted.matrix(proj)?.add(&delta)?;
        adapted.set_matrix(proj, &eff)?;
    }
    let l0 = crate::eval::pool_nll_host(spec, &adapted, pool)?;
    let scores = env.eval_tasks(spec, &adapted, bank, limit)?;
    Ok((l0, l0, scores.average(), scores.accuracy.clone(), scores.stderr.clone()))
}
