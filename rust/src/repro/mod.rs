//! Experiment drivers: one entry per table/figure of the paper
//! (`coala repro <id>`).  Results print as tables and are also dumped to
//! `results/<id>.json` for EXPERIMENTS.md.
//!
//! Every driver runs on either environment route (`common::Env`):
//! `--route device` uses the PJRT artifacts; `--route host` uses the
//! synthetic artifact-free environment (deterministic PRNG model +
//! Markov corpus + regime-controlled activations) with pure-Rust
//! accumulate/factorize/eval, so `coala repro --route host` regenerates
//! every table with no build step and no `pjrt` feature.

pub mod accuracy;
pub mod common;
pub mod finetune_exp;
pub mod stability;
pub mod theory_exp;
pub mod timing;

use crate::error::{Error, Result};
use crate::util::cli::Args;

/// Dispatch an experiment id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => stability::fig1(args),
        "fig2" => stability::fig2(args),
        "g1" => stability::g1(args),
        "table1" => timing::table1(args),
        "fig3" => timing::fig3(args),
        "fig4" => accuracy::fig4(args),
        "fig5" => accuracy::fig5(args),
        "table2" => accuracy::table2(args),
        "table3" => accuracy::table3(args),
        "table4" => finetune_exp::table4(args),
        "fig6" => theory_exp::fig6(args),
        "thm1" => theory_exp::thm1(args),
        "all" => {
            for id in [
                "g1", "thm1", "fig6", "fig2", "fig1", "table1", "fig3", "fig4", "fig5",
                "table2", "table3", "table4",
            ] {
                println!("\n################ repro {id} ################");
                run(id, args)?;
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown experiment `{other}` (try fig1 fig2 fig3 fig4 fig5 fig6 table1 table2 table3 table4 g1 thm1 all)"
        ))),
    }
}
