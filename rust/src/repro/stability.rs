//! Fig. 1 (method stability vs rank), Fig. 2 (activation spectra),
//! Example G.1 (Gram precision loss).

use super::common::{dump, Env};
use crate::coala::baselines::{svdllm_factorize, svdllm_v2_factorize};
use crate::coala::coala_factorize;
use crate::error::Result;
use crate::linalg::qr_r_square;
use crate::tensor::lowp::{gram_lowp, quantize, Precision};
use crate::tensor::ops::{matmul, spectral_norm};
use crate::tensor::Matrix;
use crate::theory::example_g1;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

/// Capture the calibration matrix Xᵀ (rows) for one projection — the
/// environment dispatches between `fwd_acts` capture and the synthetic
/// regime-controlled generator.
fn capture_xt(env: &Env, config: &str, proj: &str, batches: usize) -> Result<(Matrix<f32>, Matrix<f32>)> {
    env.capture_xt(config, proj, batches)
}

/// Fig. 1: relative error (spectral norm) of each method's W′_r against
/// the fp64 inversion-free COALA reference, across ranks.
///
/// The Gram-based baselines run with the accumulation emulated in fp16
/// (the paper's working precision); COALA runs in f32.  The reference is
/// the same algorithm in f64.
pub fn fig1(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let proj = args.get_or("proj", "l1.wq");
    let (w, xt) = capture_xt(&env, "tiny", proj, if super::common::fast()? { 2 } else { 8 })?;
    let x = xt.transpose();

    // fp64 ground truth factors
    let w64: Matrix<f64> = w.cast();
    let x64: Matrix<f64> = x.cast();
    let r64 = qr_r_square(&x64.transpose())?;
    let ref_full = coala_factorize(&w64, &r64, 40)?;

    // f32 QR route (COALA) vs reduced-precision Gram routes (baselines).
    // fp16 overflows outright on unnormalized activation Grams (range
    // 6.5e4); bf16 has f32 range but an 8-bit mantissa — it survives the
    // accumulation and shows the paper's *plateau* failure shape.
    let xt16 = quantize(&xt, Precision::Bf16);
    let gram16 = gram_lowp(&xt16, Precision::Bf16);
    let r32 = qr_r_square(&x.transpose())?;
    let coala32 = coala_factorize(&w, &r32, 40)?;
    let svdllm16 = svdllm_factorize(&w, &gram16, 40)?;
    let svdllm2_16 = svdllm_v2_factorize(&w, &gram16, 40)?;
    // f32 Gram route: the subtler √ε-class loss
    let gram32 = crate::tensor::ops::gram_t(&xt);
    let svdllm32 = svdllm_factorize(&w, &gram32, 40)?;

    let max_rank = w.rows.min(w.cols);
    let ranks: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 96, 128, 160, 184]
        .into_iter()
        .filter(|&r| r <= max_rank)
        .collect();

    let mut t = Table::new(
        &format!("Fig.1 — relative ‖W'_m − W'_ref64‖₂/‖W'_ref64‖₂ on {proj}"),
        &["rank", "COALA(QR,f32)", "SVD-LLM(chol,f32)", "SVD-LLM(chol,bf16)", "SVD-LLM-v2(eig,bf16)"],
    );
    let mut rows = Vec::new();
    for &r in &ranks {
        let wref: Matrix<f64> = ref_full.truncate(r).reconstruct()?;
        let rel = |full: &crate::coala::factorize::FullFactors<f32>| -> f64 {
            let wp: Matrix<f64> = full.truncate(r).reconstruct().unwrap().cast();
            match wp.sub(&wref) {
                Ok(d) if wp.all_finite() => {
                    spectral_norm(&d, 60) / spectral_norm(&wref, 60).max(1e-300)
                }
                _ => f64::INFINITY,
            }
        };
        let (e_c, e_s32, e_s, e_s2) =
            (rel(&coala32), rel(&svdllm32), rel(&svdllm16), rel(&svdllm2_16));
        t.row(vec![
            r.to_string(),
            format!("{e_c:.2e}"),
            format!("{e_s32:.2e}"),
            format!("{e_s:.2e}"),
            format!("{e_s2:.2e}"),
        ]);
        rows.push(Json::from_f64s(&[r as f64, e_c, e_s32, e_s, e_s2]));
    }
    t.print();
    println!(
        "expected shape (paper): the Gram-based methods plateau at a large,\n\
         rank-independent error; the QR-based method tracks the fp64 reference."
    );
    dump("fig1", Json::obj(vec![("proj", Json::Str(proj.into())), ("rows", Json::Arr(rows))]))
}

/// Fig. 2: singular-value distribution of the activation matrix X per
/// layer (σ spectra via QR → SVD of R, all f64).
pub fn fig2(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    let (spec, _w) = env.weights("tiny")?;
    let mut t = Table::new(
        "Fig.2 — σ spectrum of X (q_proj input) per layer",
        &["layer", "σ_max", "σ_med", "σ_min", "cond", "drop σ_min/σ_med"],
    );
    let mut rows = Vec::new();
    for layer in 0..spec.n_layers {
        let proj = format!("l{layer}.wq");
        let (_wm, xt) = capture_xt(&env, "tiny", &proj, if super::common::fast()? { 2 } else { 8 })?;
        let xt64: Matrix<f64> = xt.cast();
        let r = qr_r_square(&xt64)?; // σ(R) = σ(X)
        let svd = crate::linalg::jacobi_svd(&r, 40)?;
        let (mx, md, mn) = (svd.s[0], svd.s[svd.s.len() / 2], *svd.s.last().unwrap());
        t.row(vec![
            layer.to_string(),
            format!("{mx:.3e}"),
            format!("{md:.3e}"),
            format!("{mn:.3e}"),
            format!("{:.2e}", mx / mn.max(1e-300)),
            format!("{:.2e}", mn / md.max(1e-300)),
        ]);
        rows.push(Json::from_f64s(&svd.s));
    }
    t.print();
    println!("expected shape (paper): a sharp drop in the smallest singular values.");
    dump("fig2", Json::obj(vec![("spectra", Json::Arr(rows))]))
}

/// Example G.1: σ_min of X vs σ_min recovered from the precision-p Gram.
pub fn g1(_args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Example G.1 — smallest singular value: exact vs via Gram matrix",
        &["precision", "σ_min exact", "σ_min via XᵀX", "lost factor"],
    );
    let mut rows = Vec::new();
    for (name, p) in [("fp16", Precision::F16), ("bf16", Precision::Bf16), ("fp32", Precision::F32)] {
        let (exact, via) = example_g1(p)?;
        t.row(vec![
            name.into(),
            format!("{exact:.3e}"),
            format!("{via:.3e}"),
            format!("{:.1e}", exact / via.max(1e-300)),
        ]);
        rows.push(Json::from_f64s(&[exact, via]));
    }
    t.print();
    println!("expected (paper): the Gram path loses ≈ √ε_machine of accuracy.");
    dump("g1", Json::obj(vec![("rows", Json::Arr(rows))]))
}

#[allow(unused_imports)]
use matmul as _keep;
