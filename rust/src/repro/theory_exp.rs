//! Fig. 6 (gap⁻¹ sensitivity) and Theorem 1/5 bound validation.
//!
//! These drivers are constructed-instance experiments (Examples G.2 and
//! random Gaussian instances) computed entirely in host linalg: they are
//! route-independent and need no environment, so `--route host` and
//! `--route device` produce identical tables by design.

use super::common::dump;
use crate::coala::{coala_from_x, coala_regularized};
use crate::error::Result;
use crate::linalg::qr_r_square;
use crate::tensor::ops::fro;
use crate::tensor::Matrix;
use crate::theory::bounds::{gap_info, theorem1_bound, theorem5_bound};
use crate::theory::example_g2;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

/// Fig. 6: slope of ‖W₀ − W_μ‖_F vs μ as a function of the spectral gap
/// (Example G.2 construction: everything fixed except σ_r − σ_{r+1}).
pub fn fig6(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 16)?;
    let rank = args.get_usize("rank", 4)?;
    let mu = 1e-4;
    let mut t = Table::new(
        "Fig.6 — sensitivity slope ‖W₀−W_μ‖/μ vs gap (Example G.2)",
        &["gap", "‖W₀−W_μ‖_F", "slope", "slope·gap (≈const?)"],
    );
    let mut rows = Vec::new();
    for gap in [2.0, 1.0, 0.5, 0.25, 0.1, 0.05, 0.02, 0.01] {
        let inst = example_g2(n, rank, gap, 5)?;
        let w0 = coala_from_x(&inst.w, &inst.x, 80)?.truncate(rank).reconstruct()?;
        let r = qr_r_square(&inst.x.transpose())?;
        let wmu = coala_regularized(&inst.w, &r, mu, 80)?.truncate(rank).reconstruct()?;
        let err = fro(&w0.sub(&wmu)?);
        let slope = err / mu;
        t.row(vec![
            format!("{gap}"),
            format!("{err:.3e}"),
            format!("{slope:.3e}"),
            format!("{:.3e}", slope * gap),
        ]);
        rows.push(Json::from_f64s(&[gap, err, slope]));
    }
    t.print();
    println!(
        "expected shape (paper): slope ∝ 1/gap (the right column stays ~constant)\n\
         — the gap dependence is intrinsic, matching the theoretical bound."
    );
    dump("fig6", Json::Arr(rows))
}

/// Theorem 1/5 validation: measured ‖W₀ − W_μ‖_F vs both bounds on
/// random instances across μ.
pub fn thm1(args: &Args) -> Result<()> {
    let trials = args.get_usize("trials", 5)?;
    let mut t = Table::new(
        "Theorem 1/5 — measured error vs bounds",
        &["seed", "μ", "measured", "Thm1 bound", "Thm5 bound", "holds"],
    );
    let mut rows = Vec::new();
    let mut violations = 0;
    for seed in 0..trials as u64 {
        let w: Matrix<f64> = Matrix::randn(12, 9, seed * 2 + 1);
        let x: Matrix<f64> = Matrix::randn(9, 40, seed * 2 + 2);
        let rank = 3;
        let gap = gap_info(&w, &x, rank)?;
        let w0 = coala_from_x(&w, &x, 80)?.truncate(rank).reconstruct()?;
        let r = qr_r_square(&x.transpose())?;
        for mu in [1e-4, 1e-3, 1e-2] {
            let wmu = coala_regularized(&w, &r, mu, 80)?.truncate(rank).reconstruct()?;
            let measured = fro(&w0.sub(&wmu)?);
            let b1 = theorem1_bound(&w, &gap, mu);
            let b5 = theorem5_bound(&w, &x, &gap, mu)?;
            let holds = measured <= b1 * (1.0 + 1e-9) && measured <= b5 * (1.0 + 1e-9);
            if !holds {
                violations += 1;
            }
            t.row(vec![
                seed.to_string(),
                format!("{mu:.0e}"),
                format!("{measured:.3e}"),
                format!("{b1:.3e}"),
                format!("{b5:.3e}"),
                (if holds { "✓" } else { "✗" }).into(),
            ]);
            rows.push(Json::from_f64s(&[seed as f64, mu, measured, b1, b5]));
        }
    }
    t.print();
    println!("bound violations: {violations} (expected 0)");
    dump("thm1", Json::Arr(rows))
}
