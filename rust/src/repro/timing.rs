//! Table 1 (method wall-clock) and Fig. 3 (QR-vs-Gram runtimes, TSQR
//! chunking).  Criterion-style `cargo bench` targets wrap the same
//! routines; this driver prints the paper-shaped tables.

use super::common::{dump, Env};
use crate::coala::compressor::{resolve, Compressor};
use crate::coordinator::CompressionJob;
use crate::error::{Error, Result};
use crate::linalg::{eigh, qr_r_square, tsqr_sequential, tsqr_tree};
use crate::tensor::ops::gram_t;
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{pm, Table};
use std::time::Instant;

/// Table 1: full-model compression wall-clock, mean ± std over runs.
pub fn table1(args: &Args) -> Result<()> {
    let env = Env::load(args)?;
    if env.plan.factorize_workers > 1 || env.plan.accum_shards > 1 {
        println!(
            "[engine plan: {} capture / {} accumulate / {} factorize workers, queue {}]",
            env.plan.capture_workers,
            env.plan.accum_shards,
            env.plan.factorize_workers,
            env.plan.queue_cap
        );
    }
    let runs = if super::common::fast()? { 1 } else { args.get_usize("runs", 3)? };
    let configs = args.get_list("configs", &["tiny", "small"]);
    // (display label, registry spec) — resolved through coala::compressor
    let methods = [
        ("SVD-LLM", "svdllm"),
        ("SVD-LLM-v2", "svdllm2"),
        ("COALA", "coala"),
    ];
    let mut t = Table::new(
        "Table 1 — compression wall-clock (s)",
        &["model", "method", "calibrate", "accumulate", "factorize", "total"],
    );
    let mut recs = Vec::new();
    for cfg in &configs {
        let (model_spec, w) = env.weights(cfg)?;
        for (name, spec) in methods {
            let method = resolve(spec)?.method();
            let mut totals = Vec::new();
            let mut parts = (0.0, 0.0, 0.0);
            let mut collapsed = false;
            for _ in 0..runs {
                let mut job = CompressionJob::new(cfg, method, 0.3);
                job.calib_batches = if super::common::fast()? { 2 } else { 8 };
                match env.run_job(&model_spec, &w, &job) {
                    Ok(out) => {
                        totals.push(out.timings.total_s);
                        parts = (
                            out.timings.calibrate_s,
                            out.timings.accumulate_s + out.timings.merge_s,
                            out.timings.factorize_s,
                        );
                    }
                    Err(e @ Error::Numerical(_)) => {
                        // a Gram method collapsing numerically has no
                        // meaningful wall-clock — report and move on;
                        // any other error kind is a real driver bug
                        println!("  [{cfg}/{name}: numerical collapse — {e}]");
                        collapsed = true;
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            if collapsed || totals.is_empty() {
                t.row(vec![
                    cfg.clone(),
                    name.into(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                    "collapse".into(),
                ]);
                recs.push(Json::obj(vec![
                    ("model", Json::Str(cfg.clone())),
                    ("method", Json::Str(name.into())),
                    ("collapsed", Json::Bool(true)),
                ]));
                continue;
            }
            let mean = totals.iter().sum::<f64>() / totals.len() as f64;
            let std = if totals.len() > 1 {
                (totals.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (totals.len() - 1) as f64)
                    .sqrt()
            } else {
                0.0
            };
            t.row(vec![
                cfg.clone(),
                name.into(),
                format!("{:.2}", parts.0),
                format!("{:.2}", parts.1),
                format!("{:.2}", parts.2),
                pm(mean, std, 2),
            ]);
            recs.push(Json::obj(vec![
                ("model", Json::Str(cfg.clone())),
                ("method", Json::Str(name.into())),
                ("mean_s", Json::Num(mean)),
                ("std_s", Json::Num(std)),
            ]));
        }
    }
    t.print();
    println!("expected shape (paper Table 1): COALA < SVD-LLM < SVD-LLM v2.");
    dump("table1", Json::Arr(recs))
}

/// Fig. 3 — left: computing S (SSᵀ = XXᵀ) by QR of Xᵀ vs eig of XXᵀ as
/// the column count grows; right: streamed TSQR chunk-size sweep vs the
/// chunked Gram accumulation (host linalg, f32).
pub fn fig3(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 192)?;
    let fast = super::common::fast()?;

    // ---- left: aspect-ratio sweep -----------------------------------------
    let mut t = Table::new(
        &format!("Fig.3 left — time to get S for X∈R^({rows}×k)"),
        &["k", "QR(Xᵀ) s", "Gram+eig s", "QR wins"],
    );
    let mut left = Vec::new();
    let ks: &[usize] = if fast { &[512, 2048] } else { &[256, 512, 1024, 2048, 4096, 8192, 16384] };
    for &k in ks {
        let x: Matrix<f32> = Matrix::randn(rows, k, 42);
        let xt = x.transpose();
        let t0 = Instant::now();
        let _r = qr_r_square(&xt)?;
        let qr_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let g = gram_t(&xt);
        let _ = eigh(&g, 30)?;
        let gram_s = t1.elapsed().as_secs_f64();
        t.row(vec![
            k.to_string(),
            format!("{qr_s:.3}"),
            format!("{gram_s:.3}"),
            (if qr_s < gram_s { "yes" } else { "no" }).into(),
        ]);
        left.push(Json::from_f64s(&[k as f64, qr_s, gram_s]));
    }
    t.print();

    // ---- right: chunk-size sweep at fixed k --------------------------------
    let total_k = if fast { 8192 } else { 32768 };
    let mut t2 = Table::new(
        &format!("Fig.3 right — S for X∈R^({rows}×{total_k}) in chunks"),
        &["chunk", "TSQR seq s", "TSQR tree(4) s", "Gram chunked s"],
    );
    let mut right = Vec::new();
    let chunk_sizes: &[usize] = if fast { &[1024, 4096] } else { &[512, 1024, 2048, 4096, 8192] };
    for &c in chunk_sizes {
        let chunks: Vec<Matrix<f32>> =
            (0..total_k / c).map(|i| Matrix::randn(c, rows, 100 + i as u64)).collect();
        let t0 = Instant::now();
        let _ = tsqr_sequential(&chunks)?;
        let seq_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let _ = tsqr_tree(&chunks, 4)?;
        let tree_s = t1.elapsed().as_secs_f64();
        let t2_ = Instant::now();
        let mut g = Matrix::<f32>::zeros(rows, rows);
        for ch in &chunks {
            g = g.add(&gram_t(ch))?;
        }
        let _ = eigh(&g, 30)?;
        let gram_s = t2_.elapsed().as_secs_f64();
        t2.row(vec![
            c.to_string(),
            format!("{seq_s:.3}"),
            format!("{tree_s:.3}"),
            format!("{gram_s:.3}"),
        ]);
        right.push(Json::from_f64s(&[c as f64, seq_s, tree_s, gram_s]));
    }
    t2.print();
    println!("expected shape (paper): QR preferred even at extreme aspect ratios;\nchunked TSQR both bounds memory and speeds up large-k processing.");
    dump(
        "fig3",
        Json::obj(vec![("left", Json::Arr(left)), ("right", Json::Arr(right))]),
    )
}
