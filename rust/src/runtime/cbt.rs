//! CBT — the COALA Binary Tensor container (reader side).
//!
//! Mirrors `python/compile/serialize.py`:
//!   magic "CBT1" · u32 count · per tensor:
//!   u16 name_len · name · u8 dtype (0=f32, 1=i32, 2=f64) · u8 ndim ·
//!   ndim × u32 dims · row-major little-endian payload.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// One tensor from a CBT file.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    F64 { dims: Vec<usize>, data: Vec<f64> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } | Tensor::F64 { dims, .. } => dims,
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::msg("tensor is not f32")),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::msg("tensor is not i32")),
        }
    }

    /// View as a host matrix (f32, 2-D).
    pub fn matrix(&self) -> Result<crate::tensor::Matrix<f32>> {
        let d = self.dims();
        if d.len() != 2 {
            return Err(Error::shape(format!("matrix() on {d:?}")));
        }
        crate::tensor::Matrix::from_vec(d[0], d[1], self.f32s()?.to_vec())
    }
}

/// A parsed CBT file.
#[derive(Debug, Default)]
pub struct Cbt {
    pub tensors: BTreeMap<String, Tensor>,
}

fn rd_u16(b: &[u8], pos: &mut usize) -> Result<u16> {
    let v = u16::from_le_bytes(
        b.get(*pos..*pos + 2)
            .ok_or_else(|| Error::msg("cbt: truncated"))?
            .try_into()
            .unwrap(),
    );
    *pos += 2;
    Ok(v)
}

fn rd_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let v = u32::from_le_bytes(
        b.get(*pos..*pos + 4)
            .ok_or_else(|| Error::msg("cbt: truncated"))?
            .try_into()
            .unwrap(),
    );
    *pos += 4;
    Ok(v)
}

impl Cbt {
    pub fn load(path: &str) -> Result<Cbt> {
        let buf = std::fs::read(path).map_err(|e| Error::Format {
            path: path.into(),
            msg: e.to_string(),
        })?;
        Self::parse(&buf).map_err(|e| Error::Format { path: path.into(), msg: e.to_string() })
    }

    pub fn parse(buf: &[u8]) -> Result<Cbt> {
        if buf.len() < 8 || &buf[0..4] != b"CBT1" {
            return Err(Error::msg("bad CBT magic"));
        }
        let mut pos = 4usize;
        let count = rd_u32(buf, &mut pos)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = rd_u16(buf, &mut pos)? as usize;
            let name = String::from_utf8(
                buf.get(pos..pos + nlen).ok_or_else(|| Error::msg("cbt: truncated name"))?.to_vec(),
            )
            .map_err(|e| Error::msg(e.to_string()))?;
            pos += nlen;
            let dt = *buf.get(pos).ok_or_else(|| Error::msg("cbt: truncated dtype"))?;
            let ndim = *buf.get(pos + 1).ok_or_else(|| Error::msg("cbt: truncated ndim"))? as usize;
            pos += 2;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(rd_u32(buf, &mut pos)? as usize);
            }
            let n: usize = if ndim == 0 { 1 } else { dims.iter().product() };
            let t = match dt {
                0 => {
                    let bytes = buf
                        .get(pos..pos + 4 * n)
                        .ok_or_else(|| Error::msg("cbt: truncated f32 payload"))?;
                    pos += 4 * n;
                    Tensor::F32 {
                        dims,
                        data: bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                    }
                }
                1 => {
                    let bytes = buf
                        .get(pos..pos + 4 * n)
                        .ok_or_else(|| Error::msg("cbt: truncated i32 payload"))?;
                    pos += 4 * n;
                    Tensor::I32 {
                        dims,
                        data: bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                    }
                }
                2 => {
                    let bytes = buf
                        .get(pos..pos + 8 * n)
                        .ok_or_else(|| Error::msg("cbt: truncated f64 payload"))?;
                    pos += 8 * n;
                    Tensor::F64 {
                        dims,
                        data: bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
                    }
                }
                other => return Err(Error::msg(format!("cbt: unknown dtype {other}"))),
            };
            tensors.insert(name, t);
        }
        Ok(Cbt { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::msg(format!("cbt: tensor `{name}` missing")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_cbt(tensors: &[(&str, u8, Vec<u32>, Vec<u8>)]) -> Vec<u8> {
        let mut b = b"CBT1".to_vec();
        b.extend((tensors.len() as u32).to_le_bytes());
        for (name, dt, dims, payload) in tensors {
            b.extend((name.len() as u16).to_le_bytes());
            b.extend(name.as_bytes());
            b.push(*dt);
            b.push(dims.len() as u8);
            for d in dims {
                b.extend(d.to_le_bytes());
            }
            b.extend(payload);
        }
        b
    }

    #[test]
    fn parses_f32_and_i32() {
        let f: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let i: Vec<u8> = [7i32, -3].iter().flat_map(|x| x.to_le_bytes()).collect();
        let buf = write_cbt(&[("m", 0, vec![2, 2], f), ("v", 1, vec![2], i)]);
        let cbt = Cbt::parse(&buf).unwrap();
        let m = cbt.get("m").unwrap().matrix().unwrap();
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(cbt.get("v").unwrap().i32s().unwrap(), &[7, -3]);
    }

    #[test]
    fn scalar_zero_dim() {
        let f: Vec<u8> = 9.5f64.to_le_bytes().to_vec();
        let buf = write_cbt(&[("s", 2, vec![], f)]);
        let cbt = Cbt::parse(&buf).unwrap();
        match cbt.get("s").unwrap() {
            Tensor::F64 { data, .. } => assert_eq!(data, &vec![9.5]),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Cbt::parse(b"NOPE").is_err());
        let buf = write_cbt(&[("t", 0, vec![4], vec![0u8; 8])]); // claims 4 f32, has 2
        assert!(Cbt::parse(&buf).is_err());
        let buf = write_cbt(&[("t", 9, vec![1], vec![0u8; 4])]);
        assert!(Cbt::parse(&buf).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let buf = write_cbt(&[]);
        let cbt = Cbt::parse(&buf).unwrap();
        assert!(cbt.get("nope").is_err());
    }

    #[test]
    fn roundtrips_real_artifact_if_present() {
        // integration-ish: read the built weights file when available
        if let Ok(cbt) = Cbt::load("artifacts/weights_tiny.cbt") {
            let emb = cbt.get("tok_emb").unwrap();
            assert_eq!(emb.dims().len(), 2);
            assert!(emb.f32s().unwrap().iter().all(|x| x.is_finite()));
        }
    }
}
