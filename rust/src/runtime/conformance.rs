//! jax ⇄ PJRT numerical-parity self-check (`coala selfcheck`).
//!
//! The pinned xla_extension 0.5.1 runtime *miscompiles* some valid HLO —
//! observed classes: gathers/scatters with runtime-computed index
//! operands inside while-loop bodies, and constant-index gathers at some
//! non-power-of-two widths.  The L2 graphs are written to avoid every
//! such construct (Brent–Luk ring shifts as slices, lax.sort instead of
//! argsort-gather, one-hot instead of take_along_axis) and THIS module
//! proves it: every case in artifacts/conformance/ is executed through
//! PJRT and compared against the jax-computed expected outputs.
//!
//! Requires the `pjrt` feature; without it `run_all`/`selfcheck` report
//! that the device backend is unavailable.

use crate::error::Result;

#[cfg(feature = "pjrt")]
use crate::error::Error;
#[cfg(feature = "pjrt")]
use crate::runtime::cbt::{Cbt, Tensor};

/// Result of one conformance case.
#[derive(Debug)]
pub struct CaseResult {
    pub name: String,
    pub worst_rel: f64,
    pub tol: f64,
    pub pass: bool,
}

/// Run every case under `<dir>/conformance`; returns per-case results.
#[cfg(feature = "pjrt")]
pub fn run_all(dir: &str) -> Result<Vec<CaseResult>> {
    let conf_dir = format!("{dir}/conformance");
    let list = std::fs::read_to_string(format!("{conf_dir}/cases.txt")).map_err(|e| {
        Error::Format { path: conf_dir.clone(), msg: format!("cases.txt: {e}") }
    })?;
    let client = xla::PjRtClient::cpu()?;
    let mut out = Vec::new();
    for case in list.split_whitespace() {
        out.push(run_case(&client, &conf_dir, case)?);
    }
    Ok(out)
}

#[cfg(not(feature = "pjrt"))]
pub fn run_all(_dir: &str) -> Result<Vec<CaseResult>> {
    Err(crate::error::Error::Config(
        "conformance suite needs the PJRT backend: the `pjrt` feature plus a \
         vendored `xla` crate wired into Cargo.toml (see the comment there)"
            .into(),
    ))
}

#[cfg(feature = "pjrt")]
fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    Ok(match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        Tensor::F64 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    })
}

#[cfg(feature = "pjrt")]
fn run_case(client: &xla::PjRtClient, dir: &str, case: &str) -> Result<CaseResult> {
    let cbt = Cbt::load(&format!("{dir}/{case}.cbt"))?;
    let tol = cbt
        .get("__tol")
        .ok()
        .and_then(|t| t.f32s().ok().map(|v| v[0] as f64))
        .unwrap_or(1e-3);
    let mut inputs = Vec::new();
    let mut i = 0;
    while let Ok(t) = cbt.get(&format!("in{i}")) {
        inputs.push(to_literal(t)?);
        i += 1;
    }
    let proto = xla::HloModuleProto::from_text_file(&format!("{dir}/{case}.hlo.txt"))?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;

    let mut worst = 0.0f64;
    for (j, p) in parts.iter().enumerate() {
        let want = cbt.get(&format!("out{j}"))?;
        let got = p.to_vec::<f32>()?;
        let want_f = want.f32s()?;
        if got.len() != want_f.len() {
            return Err(Error::shape(format!("{case}: out{j} length mismatch")));
        }
        for (a, b) in got.iter().zip(want_f) {
            let d = (a - b).abs() as f64 / (1.0 + b.abs() as f64);
            worst = worst.max(d);
        }
    }
    Ok(CaseResult { name: case.to_string(), worst_rel: worst, tol, pass: worst <= tol })
}

/// Run and pretty-print; Err if any case fails.
pub fn selfcheck(dir: &str) -> Result<()> {
    let results = run_all(dir)?;
    let mut failed = 0;
    for r in &results {
        println!(
            "{} {:<28} worst rel diff {:.2e} (tol {:.0e})",
            if r.pass { "PASS" } else { "FAIL" },
            r.name,
            r.worst_rel,
            r.tol
        );
        if !r.pass {
            failed += 1;
        }
    }
    if failed > 0 {
        return Err(crate::error::Error::Numerical(format!(
            "{failed} conformance case(s) FAILED"
        )));
    }
    println!("all {} conformance cases pass", results.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_suite_passes_when_built() {
        if !std::path::Path::new("artifacts/conformance/cases.txt").exists()
            || !cfg!(feature = "pjrt")
        {
            return;
        }
        let results = run_all("artifacts").unwrap();
        assert!(results.len() >= 20, "suite shrank: {}", results.len());
        for r in &results {
            assert!(r.pass, "{} failed: {:.2e} > {:.0e}", r.name, r.worst_rel, r.tol);
        }
    }
}
