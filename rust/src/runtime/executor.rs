//! Compile-once executable cache + literal marshalling.
//!
//! One `Executor` owns the PJRT CPU client and a lazily-populated cache
//! of compiled executables keyed by artifact name (one compiled
//! executable per model/shape variant).  Compilation happens on first
//! use; the request path afterwards only marshals literals and calls
//! `execute`.
//!
//! The PJRT client is only present when the crate is built with the
//! `pjrt` feature.  Without it the `Executor` still loads and validates
//! the manifest (so shape/ABI checks and everything host-side keeps
//! working) but `run` reports that the device backend is unavailable —
//! callers fall back to the host route (`coala::compressor`).

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactSpec, Manifest};

pub use crate::runtime::value::Value;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;
#[cfg(feature = "pjrt")]
use std::time::Instant;

/// Execution statistics (perf pass instrumentation).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// PJRT client + compiled-executable cache (manifest-only without the
/// `pjrt` feature).
pub struct Executor {
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    #[cfg(feature = "pjrt")]
    stats: Mutex<ExecStats>,
}

impl Executor {
    pub fn new(artifacts_dir: &str) -> Result<Executor> {
        Executor::from_manifest(Manifest::load(artifacts_dir)?)
    }

    /// Wrap an already-built manifest (e.g. the synthetic, artifact-free
    /// one from `model::synthetic`).  Executing any artifact against a
    /// manifest with an empty artifact table reports `UnknownArtifact`.
    #[cfg(feature = "pjrt")]
    pub fn from_manifest(manifest: Manifest) -> Result<Executor> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Executor {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(ExecStats::default()),
        })
    }

    /// Wrap an already-built manifest (e.g. the synthetic, artifact-free
    /// one from `model::synthetic`).  Executing any artifact against a
    /// manifest with an empty artifact table reports `UnknownArtifact`.
    #[cfg(not(feature = "pjrt"))]
    pub fn from_manifest(manifest: Manifest) -> Result<Executor> {
        Ok(Executor { manifest })
    }

    #[cfg(feature = "pjrt")]
    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn stats(&self) -> ExecStats {
        ExecStats::default()
    }

    fn validate(&self, spec: &ArtifactSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(Error::shape(format!(
                "{}: {} inputs given, {} expected",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            )));
        }
        for (v, s) in inputs.iter().zip(&spec.inputs) {
            if v.dims() != s.shape.as_slice() {
                return Err(Error::shape(format!(
                    "{}: input `{}` is {:?}, expected {:?}",
                    spec.name,
                    s.name,
                    v.dims(),
                    s.shape
                )));
            }
            let want_i32 = s.dtype.contains("int");
            let is_i32 = matches!(v, Value::I32(..));
            if want_i32 != is_i32 {
                return Err(Error::shape(format!(
                    "{}: input `{}` dtype mismatch (artifact wants {})",
                    spec.name, s.name, s.dtype
                )));
            }
        }
        Ok(())
    }

    /// Execute an artifact with shape/dtype validation against the ABI.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.validate(&spec, inputs)?;
        let out = self.execute(&spec, inputs)?;
        if out.len() != spec.outputs.len() {
            return Err(Error::shape(format!(
                "{}: produced {} outputs, manifest says {}",
                name,
                out.len(),
                spec.outputs.len()
            )));
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute(&self, spec: &ArtifactSpec, _inputs: &[Value]) -> Result<Vec<Value>> {
        Err(Error::Config(format!(
            "artifact `{}`: PJRT backend unavailable (crate built without the \
             `pjrt` feature); accumulate/factorize can run on the host route, \
             but this artifact has no host implementation",
            spec.name
        )))
    }

    #[cfg(feature = "pjrt")]
    fn execute(&self, spec: &ArtifactSpec, inputs: &[Value]) -> Result<Vec<Value>> {
        let exe = self.prepare(&spec.name)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(pjrt::to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        // all artifacts are lowered with return_tuple=True
        let parts = result.to_tuple()?;
        parts.iter().map(pjrt::from_literal).collect()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    #[cfg(feature = "pjrt")]
    pub fn prepare(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let exe = self.client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let exe = std::sync::Arc::new(exe);
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::Value;
    use crate::error::{Error, Result};

    pub fn to_literal(v: &Value) -> Result<xla::Literal> {
        let dims: Vec<i64> = v.dims().iter().map(|&d| d as i64).collect();
        Ok(match v {
            Value::F32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
            Value::I32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
        })
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Value::I32(dims, lit.to_vec::<i32>()?)),
            other => Err(Error::msg(format!("unsupported output dtype {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn executor() -> Option<Executor> {
        if crate::runtime::require_artifacts("executor artifact tests") {
            Some(Executor::new("artifacts").unwrap())
        } else {
            None
        }
    }

    #[test]
    fn runs_tsqr_step_and_caches() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let r = Matrix::<f32>::zeros(n, n);
        let chunk = Matrix::<f32>::randn(c, n, 1);
        let out = ex
            .run(
                &format!("tsqr_step_{n}x{c}"),
                &[Value::from_matrix(&r), Value::from_matrix(&chunk)],
            )
            .unwrap();
        let r1 = out[0].matrix().unwrap();
        assert_eq!((r1.rows, r1.cols), (n, n));
        // RᵀR = chunkᵀchunk
        let got = crate::tensor::ops::matmul(&r1.transpose(), &r1).unwrap();
        let want = crate::tensor::ops::gram_t(&chunk);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-1 * (1.0 + b.abs()), "{a} vs {b}");
        }
        assert_eq!(ex.stats().compiles, 1);
        // second call hits the cache
        let _ = ex
            .run(
                &format!("tsqr_step_{n}x{c}"),
                &[Value::from_matrix(&r1), Value::from_matrix(&chunk)],
            )
            .unwrap();
        assert_eq!(ex.stats().compiles, 1);
        assert_eq!(ex.stats().executions, 2);
    }

    #[test]
    fn validates_shapes_and_dtypes() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let name = format!("tsqr_step_{n}x{c}");
        // wrong arity
        assert!(ex.run(&name, &[]).is_err());
        // wrong shape
        let bad = Value::from_matrix(&Matrix::<f32>::zeros(3, 3));
        let chunk = Value::from_matrix(&Matrix::<f32>::zeros(c, n));
        assert!(ex.run(&name, &[bad, chunk.clone()]).is_err());
        // wrong dtype
        let ibad = Value::I32(vec![n, n], vec![0; n * n]);
        assert!(ex.run(&name, &[ibad, chunk]).is_err());
        // unknown artifact
        assert!(ex.run("nope", &[]).is_err());
    }
}
