//! Compile-once executable cache + literal marshalling.
//!
//! One `Executor` owns the PJRT CPU client and a lazily-populated cache
//! of compiled executables keyed by artifact name (one compiled
//! executable per model/shape variant).  Compilation happens on first
//! use; the request path afterwards only marshals literals and calls
//! `execute`.

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::tensor::Matrix;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Host-side value crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![], vec![v])
    }

    pub fn from_matrix(m: &Matrix<f32>) -> Value {
        Value::F32(vec![m.rows, m.cols], m.data.clone())
    }

    pub fn matrix(&self) -> Result<Matrix<f32>> {
        match self {
            Value::F32(dims, data) if dims.len() == 2 => {
                Matrix::from_vec(dims[0], dims[1], data.clone())
            }
            _ => Err(Error::shape(format!("not a 2-D f32 value: {:?}", self.dims()))),
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Value::F32(_, d) => Ok(d),
            _ => Err(Error::msg("value is not f32")),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32(d, _) | Value::I32(d, _) => d,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Value::F32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
            Value::I32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
        })
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Value::I32(dims, lit.to_vec::<i32>()?)),
            other => Err(Error::msg(format!("unsupported output dtype {other:?}"))),
        }
    }
}

/// Execution statistics (perf pass instrumentation).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// PJRT client + compiled-executable cache.
pub struct Executor {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<ExecStats>,
}

impl Executor {
    pub fn new(artifacts_dir: &str) -> Result<Executor> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Executor {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(ExecStats::default()),
        })
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn prepare(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let exe = self.client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let exe = std::sync::Arc::new(exe);
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn validate(&self, spec: &ArtifactSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(Error::shape(format!(
                "{}: {} inputs given, {} expected",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            )));
        }
        for (v, s) in inputs.iter().zip(&spec.inputs) {
            if v.dims() != s.shape.as_slice() {
                return Err(Error::shape(format!(
                    "{}: input `{}` is {:?}, expected {:?}",
                    spec.name,
                    s.name,
                    v.dims(),
                    s.shape
                )));
            }
            let want_i32 = s.dtype.contains("int");
            let is_i32 = matches!(v, Value::I32(..));
            if want_i32 != is_i32 {
                return Err(Error::shape(format!(
                    "{}: input `{}` dtype mismatch (artifact wants {})",
                    spec.name, s.name, s.dtype
                )));
            }
        }
        Ok(())
    }

    /// Execute an artifact with shape/dtype validation against the ABI.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.validate(&spec, inputs)?;
        let exe = self.prepare(name)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        // all artifacts are lowered with return_tuple=True
        let parts = result.to_tuple()?;
        let out: Vec<Value> = parts.iter().map(Value::from_literal).collect::<Result<_>>()?;
        if out.len() != spec.outputs.len() {
            return Err(Error::shape(format!(
                "{}: produced {} outputs, manifest says {}",
                name,
                out.len(),
                spec.outputs.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn executor() -> Option<Executor> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Executor::new("artifacts").unwrap())
        } else {
            None
        }
    }

    #[test]
    fn runs_tsqr_step_and_caches() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let r = Matrix::<f32>::zeros(n, n);
        let chunk = Matrix::<f32>::randn(c, n, 1);
        let out = ex
            .run(
                &format!("tsqr_step_{n}x{c}"),
                &[Value::from_matrix(&r), Value::from_matrix(&chunk)],
            )
            .unwrap();
        let r1 = out[0].matrix().unwrap();
        assert_eq!((r1.rows, r1.cols), (n, n));
        // RᵀR = chunkᵀchunk
        let got = crate::tensor::ops::matmul(&r1.transpose(), &r1).unwrap();
        let want = crate::tensor::ops::gram_t(&chunk);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-1 * (1.0 + b.abs()), "{a} vs {b}");
        }
        assert_eq!(ex.stats().compiles, 1);
        // second call hits the cache
        let _ = ex
            .run(
                &format!("tsqr_step_{n}x{c}"),
                &[Value::from_matrix(&r1), Value::from_matrix(&chunk)],
            )
            .unwrap();
        assert_eq!(ex.stats().compiles, 1);
        assert_eq!(ex.stats().executions, 2);
    }

    #[test]
    fn validates_shapes_and_dtypes() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let c = cfg.chunk_cols();
        let name = format!("tsqr_step_{n}x{c}");
        // wrong arity
        assert!(ex.run(&name, &[]).is_err());
        // wrong shape
        let bad = Value::from_matrix(&Matrix::<f32>::zeros(3, 3));
        let chunk = Value::from_matrix(&Matrix::<f32>::zeros(c, n));
        assert!(ex.run(&name, &[bad, chunk.clone()]).is_err());
        // wrong dtype
        let ibad = Value::I32(vec![n, n], vec![0; n * n]);
        assert!(ex.run(&name, &[ibad, chunk]).is_err());
        // unknown artifact
        assert!(ex.run("nope", &[]).is_err());
    }
}
