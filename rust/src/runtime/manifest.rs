//! Typed view of `artifacts/manifest.json` — the python↔rust ABI.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Input/output tensor spec of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One model configuration (tiny / small).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_names: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub compressible: Vec<String>,
    pub proj_input_stream: BTreeMap<String, String>,
    pub act_streams: Vec<String>,
    pub weights_file: String,
}

impl ModelSpec {
    /// Chunk width of one calibration forward: batch × seq_len columns.
    pub fn chunk_cols(&self) -> usize {
        self.batch * self.seq_len
    }

    /// (out, in) shape of a projection parameter.
    pub fn proj_shape(&self, proj: &str) -> Result<(usize, usize)> {
        let s = self
            .param_shapes
            .get(proj)
            .ok_or_else(|| Error::Config(format!("unknown projection `{proj}`")))?;
        if s.len() != 2 {
            return Err(Error::Config(format!("projection `{proj}` is not 2-D: {s:?}")));
        }
        Ok((s[0], s[1]))
    }

    /// The activation stream feeding a projection (short name, e.g. "wq").
    pub fn stream_of(&self, proj: &str) -> Result<&str> {
        let short = proj.rsplit('.').next().unwrap_or(proj);
        self.proj_input_stream
            .get(short)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Config(format!("no input stream for `{proj}`")))
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: String,
    pub abi_version: usize,
    pub task_names: Vec<String>,
    pub ft_rank: usize,
    pub configs: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn specs(v: &Json, default_prefix: &str) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| Error::Json("specs: expected array".into()))?
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Ok(TensorSpec {
                name: s
                    .get("name")
                    .and_then(|n| n.as_str())
                    .map(str::to_string)
                    .unwrap_or_else(|| format!("{default_prefix}{i}")),
                dtype: s
                    .req("dtype")?
                    .as_str()
                    .ok_or_else(|| Error::Json("dtype".into()))?
                    .to_string(),
                shape: s.req("shape")?.usize_arr()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Assemble a manifest directly from parts — no file IO.  This is the
    /// synthetic (artifact-free) environment route: `dir` is a sentinel
    /// that never gets opened, and the artifact table is empty, so any
    /// attempt to execute a device artifact against a synthetic manifest
    /// fails loudly with `UnknownArtifact` instead of silently.
    pub fn from_parts(
        dir: &str,
        task_names: Vec<String>,
        ft_rank: usize,
        configs: BTreeMap<String, ModelSpec>,
    ) -> Manifest {
        Manifest {
            dir: dir.to_string(),
            abi_version: 1,
            task_names,
            ft_rank,
            configs,
            artifacts: BTreeMap::new(),
        }
    }

    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let j = Json::parse_file(&path)?;
        let abi_version = j.req("abi_version")?.as_usize().unwrap_or(0);
        let task_names = j.req("task_names")?.str_arr()?;
        let ft_rank = j.req("ft_rank")?.as_usize().unwrap_or(8);

        let mut configs = BTreeMap::new();
        for (name, c) in j.req("configs")?.as_obj().ok_or_else(|| Error::Json("configs".into()))? {
            let mut param_shapes = BTreeMap::new();
            for (k, v) in c.req("param_shapes")?.as_obj().unwrap() {
                param_shapes.insert(k.clone(), v.usize_arr()?);
            }
            let mut proj_input_stream = BTreeMap::new();
            for (k, v) in c.req("proj_input_stream")?.as_obj().unwrap() {
                proj_input_stream.insert(k.clone(), v.as_str().unwrap_or("").to_string());
            }
            configs.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    vocab: c.req("vocab")?.as_usize().unwrap(),
                    d_model: c.req("d_model")?.as_usize().unwrap(),
                    n_layers: c.req("n_layers")?.as_usize().unwrap(),
                    n_heads: c.req("n_heads")?.as_usize().unwrap(),
                    d_ff: c.req("d_ff")?.as_usize().unwrap(),
                    seq_len: c.req("seq_len")?.as_usize().unwrap(),
                    batch: c.req("batch")?.as_usize().unwrap(),
                    param_names: c.req("param_names")?.str_arr()?,
                    param_shapes,
                    compressible: c.req("compressible")?.str_arr()?,
                    proj_input_stream,
                    act_streams: c.req("act_streams")?.str_arr()?,
                    weights_file: c.req("weights_file")?.as_str().unwrap_or("").to_string(),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().ok_or_else(|| Error::Json("artifacts".into()))? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.req("file")?.as_str().unwrap_or("").to_string(),
                    inputs: specs(a.req("inputs")?, "in")?,
                    outputs: specs(a.req("outputs")?, "out")?,
                },
            );
        }

        Ok(Manifest { dir: dir.to_string(), abi_version, task_names, ft_rank, configs, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::UnknownArtifact(name.to_string()))
    }

    pub fn config(&self, name: &str) -> Result<&ModelSpec> {
        self.configs
            .get(name)
            .ok_or_else(|| Error::Config(format!("unknown model config `{name}`")))
    }

    pub fn artifact_path(&self, name: &str) -> Result<String> {
        Ok(format!("{}/{}", self.dir, self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These run against the real artifacts dir when it exists (CI always
    /// builds it first via `make artifacts`).
    fn manifest() -> Option<Manifest> {
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn loads_and_has_expected_families() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.abi_version, 1);
        assert_eq!(m.task_names.len(), 8);
        for cfg in m.configs.values() {
            let d = cfg.d_model;
            let f = cfg.d_ff;
            let c = cfg.chunk_cols();
            for name in [
                format!("fwd_logits_{}", cfg.name),
                format!("fwd_acts_{}", cfg.name),
                format!("loss_{}", cfg.name),
                format!("tsqr_step_{d}x{c}"),
                format!("tsqr_step_{f}x{c}"),
                format!("factorize_{d}x{d}"),
                format!("factorize_{f}x{d}"),
                format!("factorize_{d}x{f}"),
                format!("svdllm_{d}x{d}"),
                format!("gram_update_{d}x{c}"),
            ] {
                assert!(m.artifacts.contains_key(&name), "missing {name}");
            }
        }
    }

    #[test]
    fn model_spec_helpers() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tiny").unwrap();
        let (o, i) = cfg.proj_shape("l0.wq").unwrap();
        assert_eq!((o, i), (cfg.d_model, cfg.d_model));
        let (o, i) = cfg.proj_shape("l0.w_down").unwrap();
        assert_eq!((o, i), (cfg.d_model, cfg.d_ff));
        assert_eq!(cfg.stream_of("l2.wq").unwrap(), "attn");
        assert_eq!(cfg.stream_of("l1.w_down").unwrap(), "down");
        assert_eq!(cfg.compressible.len(), 6 * cfg.n_layers);
        assert!(cfg.proj_shape("nope").is_err());
    }

    #[test]
    fn io_specs_consistent() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tiny").unwrap();
        let a = m.artifact(&format!("fwd_logits_{}", cfg.name)).unwrap();
        assert_eq!(a.inputs.len(), 1 + cfg.param_names.len());
        assert_eq!(a.inputs[0].dtype, "int32");
        assert_eq!(a.inputs[0].shape, vec![cfg.batch, cfg.seq_len]);
        assert_eq!(a.outputs.len(), 1);
        assert_eq!(a.outputs[0].shape, vec![cfg.batch, cfg.seq_len, cfg.vocab]);
        assert!(m.artifact("definitely_not_there").is_err());
    }
}
