//! PJRT runtime (S6): loads the AOT artifacts and runs them on-device.
//!
//! Python never executes here — `make artifacts` lowered every graph to
//! HLO **text** (the interchange the pinned xla_extension 0.5.1 parses;
//! serialized protos from jax ≥ 0.5 are rejected for 64-bit ids), and
//! this module compiles + caches + executes them through the `xla`
//! crate's PJRT C-API bindings.
//!
//! * [`cbt`]      — reader for the CBT tensor container (weights, corpus,
//!                  task banks, conformance fixtures)
//! * [`manifest`] — typed view of artifacts/manifest.json (the ABI)
//! * [`value`]    — backend-neutral host values crossing the boundary
//! * [`executor`] — compile-once executable cache + literal marshalling
//! * [`ops`]      — typed wrappers: tsqr_step, factorize, gram_update, …
//! * [`conformance`] — the jax-vs-PJRT parity self-check (`coala selfcheck`)
//!
//! Everything that actually touches PJRT sits behind the `pjrt` cargo
//! feature; the default (offline) build compiles the manifest/ABI layer
//! and the `Value` plumbing only, and `Executor::run` reports that the
//! device backend is unavailable so callers can fall back to the host
//! route (`coala::compressor` + `calib::accumulate`).

pub mod cbt;
pub mod conformance;
pub mod executor;
pub mod manifest;
pub mod ops;
pub mod value;

pub use cbt::{Cbt, Tensor};
pub use executor::Executor;
pub use manifest::Manifest;
pub use value::Value;

/// True when the device route can actually execute artifacts from
/// `dir`: the crate was built with the `pjrt` feature AND the AOT
/// artifacts exist.  Artifact-executing tests and benches use this to
/// self-skip instead of panicking on the no-pjrt `Executor::run` stub.
pub fn device_available(dir: &str) -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new(&format!("{dir}/manifest.json")).exists()
}

/// Artifact gate for tests: like [`device_available`], but when the gate
/// is closed it *says so* on stderr instead of letting the test count as
/// silently passed.  Every artifact-dependent test should early-return
/// through this helper so CI logs show the true coverage:
///
/// ```ignore
/// if !coala::runtime::require_artifacts("my_test") { return; }
/// ```
pub fn require_artifacts(test: &str) -> bool {
    if device_available("artifacts") {
        true
    } else {
        let why = if cfg!(feature = "pjrt") {
            "artifacts/ not present"
        } else {
            "built without the `pjrt` feature"
        };
        eprintln!("skipped: {test} ({why}; run `make artifacts` + enable pjrt to cover it)");
        false
    }
}
