//! PJRT runtime (S6): loads the AOT artifacts and runs them on-device.
//!
//! Python never executes here — `make artifacts` lowered every graph to
//! HLO **text** (the interchange the pinned xla_extension 0.5.1 parses;
//! serialized protos from jax ≥ 0.5 are rejected for 64-bit ids), and
//! this module compiles + caches + executes them through the `xla`
//! crate's PJRT C-API bindings.
//!
//! * [`cbt`]      — reader for the CBT tensor container (weights, corpus,
//!                  task banks, conformance fixtures)
//! * [`manifest`] — typed view of artifacts/manifest.json (the ABI)
//! * [`executor`] — compile-once executable cache + literal marshalling
//! * [`ops`]      — typed wrappers: tsqr_step, factorize, gram_update, …
//! * [`conformance`] — the jax-vs-PJRT parity self-check (`coala selfcheck`)

pub mod cbt;
pub mod conformance;
pub mod executor;
pub mod manifest;
pub mod ops;

pub use cbt::{Cbt, Tensor};
pub use executor::Executor;
pub use manifest::Manifest;
