//! Typed wrappers over the AOT artifacts — the accelerated mirror of
//! `crate::coala` / `crate::linalg`, keyed by matrix shape.

use crate::coala::factorize::FullFactors;
use crate::error::{Error, Result};
use crate::runtime::executor::{Executor, Value};
use crate::tensor::Matrix;

/// One streaming TSQR fold: R′ of [R ; chunk].
pub fn tsqr_step(ex: &Executor, r: &Matrix<f32>, chunk: &Matrix<f32>) -> Result<Matrix<f32>> {
    let (n, c) = (r.rows, chunk.rows);
    let out = ex.run(
        &format!("tsqr_step_{n}x{c}"),
        &[Value::from_matrix(r), Value::from_matrix(chunk)],
    )?;
    out[0].matrix()
}

/// Tree-TSQR merge of two R factors.
pub fn tsqr_merge(ex: &Executor, ra: &Matrix<f32>, rb: &Matrix<f32>) -> Result<Matrix<f32>> {
    let n = ra.rows;
    let out = ex.run(
        &format!("tsqr_merge_{n}"),
        &[Value::from_matrix(ra), Value::from_matrix(rb)],
    )?;
    out[0].matrix()
}

/// Streaming Gram update: G + chunkᵀ·chunk (baseline route).
pub fn gram_update(ex: &Executor, g: &Matrix<f32>, chunk: &Matrix<f32>) -> Result<Matrix<f32>> {
    let (n, c) = (g.rows, chunk.rows);
    let out = ex.run(
        &format!("gram_update_{n}x{c}"),
        &[Value::from_matrix(g), Value::from_matrix(chunk)],
    )?;
    out[0].matrix()
}

/// μ-augment the R factor (Alg. 2 preprocessing).
pub fn qr_aug(ex: &Executor, r: &Matrix<f32>, mu: f32) -> Result<Matrix<f32>> {
    let n = r.rows;
    let out = ex.run(&format!("qr_aug_{n}"), &[Value::from_matrix(r), Value::scalar_f32(mu)])?;
    out[0].matrix()
}

fn unpack_factors(out: Vec<Value>) -> Result<FullFactors<f32>> {
    if out.len() != 3 {
        return Err(Error::shape(format!("factorize: {} outputs", out.len())));
    }
    let u = out[0].matrix()?;
    let sigma = out[1].f32s()?.to_vec();
    let p = out[2].matrix()?;
    Ok(FullFactors { u, sigma, p })
}

/// COALA Alg. 1 on-device: (W, R) → (U, σ, P).
pub fn factorize(ex: &Executor, w: &Matrix<f32>, r: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("factorize_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(r)],
    )?)
}

/// COALA Alg. 2 on-device (μ is a traced input — one artifact serves the
/// whole λ sweep).
pub fn factorize_reg(
    ex: &Executor,
    w: &Matrix<f32>,
    r: &Matrix<f32>,
    mu: f32,
) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("factorize_reg_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(r), Value::scalar_f32(mu)],
    )?)
}

/// Prop. 4 α=2 (robust CorDA) on-device.
pub fn alpha2(ex: &Executor, w: &Matrix<f32>, r: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("alpha2_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(r)],
    )?)
}

/// Plain SVD (PiSSA) on-device.
pub fn plainsvd(ex: &Executor, w: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(&format!("plainsvd_{m}x{n}"), &[Value::from_matrix(w)])?)
}

/// SVD-LLM baseline on-device.
pub fn svdllm(ex: &Executor, w: &Matrix<f32>, gram: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("svdllm_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(gram)],
    )?)
}

/// SVD-LLM v2 baseline on-device.
pub fn svdllm2(ex: &Executor, w: &Matrix<f32>, gram: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("svdllm2_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(gram)],
    )?)
}

/// Original CorDA on-device.
pub fn corda(ex: &Executor, w: &Matrix<f32>, gram: &Matrix<f32>) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("corda_{m}x{n}"),
        &[Value::from_matrix(w), Value::from_matrix(gram)],
    )?)
}

/// ASVD on-device.
pub fn asvd(ex: &Executor, w: &Matrix<f32>, scales: &[f32]) -> Result<FullFactors<f32>> {
    let (m, n) = (w.rows, w.cols);
    unpack_factors(ex.run(
        &format!("asvd_{m}x{n}"),
        &[Value::from_matrix(w), Value::F32(vec![n], scales.to_vec())],
    )?)
}

/// Eq. 5 terms on-device: (‖(W₀−W)X‖², ‖W₀−W‖²).
pub fn mu_terms(
    ex: &Executor,
    w: &Matrix<f32>,
    full: &FullFactors<f32>,
    r: &Matrix<f32>,
    rank: usize,
) -> Result<(f32, f32)> {
    let (m, n) = (w.rows, w.cols);
    let p = full.sigma.len();
    let mask: Vec<f32> = (0..p).map(|i| if i < rank { 1.0 } else { 0.0 }).collect();
    let out = ex.run(
        &format!("mu_terms_{m}x{n}"),
        &[
            Value::from_matrix(w),
            Value::from_matrix(&full.u),
            Value::from_matrix(&full.p),
            Value::from_matrix(r),
            Value::F32(vec![p], mask),
        ],
    )?;
    Ok((out[0].f32s()?[0], out[1].f32s()?[0]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{context_rel_err, fro, gram_t, matmul};

    fn executor() -> Option<Executor> {
        if crate::runtime::require_artifacts("ops artifact tests") {
            Some(Executor::new("artifacts").unwrap())
        } else {
            None
        }
    }

    #[test]
    fn device_factorize_matches_host() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let w = Matrix::<f32>::randn(n, n, 1);
        let x = Matrix::<f32>::randn(n, cfg.chunk_cols(), 2);
        let chunk = x.transpose();
        let r = tsqr_step(&ex, &Matrix::zeros(n, n), &chunk).unwrap();
        let dev = factorize(&ex, &w, &r).unwrap();
        let host = crate::coala::coala_from_x(&w, &x, 30).unwrap();
        let rank = 16;
        let wd = dev.truncate(rank).reconstruct().unwrap();
        let wh = host.truncate(rank).reconstruct().unwrap();
        let ed = context_rel_err(&w, &wd, &x).unwrap();
        let eh = context_rel_err(&w, &wh, &x).unwrap();
        assert!((ed - eh).abs() < 1e-3, "device {ed} vs host {eh}");
    }

    #[test]
    fn device_regularized_interpolates() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let w = Matrix::<f32>::randn(n, n, 3);
        let chunk = Matrix::<f32>::randn(cfg.chunk_cols(), n, 4);
        let r = tsqr_step(&ex, &Matrix::zeros(n, n), &chunk).unwrap();
        let f0 = factorize(&ex, &w, &r).unwrap().truncate(8).reconstruct().unwrap();
        let fr = factorize_reg(&ex, &w, &r, 1e-4).unwrap().truncate(8).reconstruct().unwrap();
        // small μ ⇒ close to unregularized
        assert!(fro(&f0.sub(&fr).unwrap()) < 0.05 * (1.0 + fro(&f0)));
        // huge μ ⇒ approaches plain SVD truncation
        let fbig = factorize_reg(&ex, &w, &r, 1e6).unwrap().truncate(8).reconstruct().unwrap();
        let psvd = plainsvd(&ex, &w).unwrap().truncate(8).reconstruct().unwrap();
        assert!(fro(&fbig.sub(&psvd).unwrap()) < 0.05 * (1.0 + fro(&psvd)));
    }

    #[test]
    fn device_gram_route_consistent() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let chunk = Matrix::<f32>::randn(cfg.chunk_cols(), n, 5);
        let g = gram_update(&ex, &Matrix::zeros(n, n), &chunk).unwrap();
        let want = gram_t(&chunk);
        assert!(fro(&g.sub(&want).unwrap()) < 1e-2 * fro(&want));
        // svdllm on device runs and produces finite factors on good data
        let w = Matrix::<f32>::randn(n, n, 6);
        let f = svdllm(&ex, &w, &g).unwrap().truncate(8);
        assert!(f.a.all_finite() && f.b.all_finite());
    }

    #[test]
    fn device_mu_terms_match_host() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let w = Matrix::<f32>::randn(n, n, 7);
        let chunk = Matrix::<f32>::randn(cfg.chunk_cols(), n, 8);
        let r = tsqr_step(&ex, &Matrix::zeros(n, n), &chunk).unwrap();
        let full = factorize(&ex, &w, &r).unwrap();
        let (num, den) = mu_terms(&ex, &w, &full, &r, 8).unwrap();
        let w0 = full.truncate(8).reconstruct().unwrap();
        let diff = w0.sub(&w).unwrap();
        let num_h = fro(&matmul(&diff, &r.transpose()).unwrap()).powi(2);
        let den_h = fro(&diff).powi(2);
        assert!((num as f64 - num_h).abs() < 1e-2 * num_h.max(1.0), "{num} vs {num_h}");
        assert!((den as f64 - den_h).abs() < 1e-2 * den_h.max(1.0), "{den} vs {den_h}");
    }

    #[test]
    fn qr_aug_matches_gram_identity() {
        let Some(ex) = executor() else { return };
        let cfg = ex.manifest.config("tiny").unwrap();
        let n = cfg.d_model;
        let chunk = Matrix::<f32>::randn(cfg.chunk_cols(), n, 9);
        let r = tsqr_step(&ex, &Matrix::zeros(n, n), &chunk).unwrap();
        let mu = 0.7f32;
        let raug = qr_aug(&ex, &r, mu).unwrap();
        let got = matmul(&raug.transpose(), &raug).unwrap();
        let mut want = matmul(&r.transpose(), &r).unwrap();
        for i in 0..n {
            want.set(i, i, want.get(i, i) + mu);
        }
        assert!(fro(&got.sub(&want).unwrap()) < 1e-2 * fro(&want));
    }
}
