//! Host-side tensor values crossing the executor boundary.
//!
//! `Value` is backend-neutral: the calibration plumbing, schedulers, and
//! accumulators all traffic in it, whether the factorization work lands
//! on the PJRT device route or the pure-Rust host route.  The PJRT
//! literal marshalling lives behind the `pjrt` feature in
//! [`super::executor`].

use crate::error::{Error, Result};
use crate::tensor::Matrix;

/// Host-side value crossing the executor boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![], vec![v])
    }

    pub fn from_matrix(m: &Matrix<f32>) -> Value {
        Value::F32(vec![m.rows, m.cols], m.data.clone())
    }

    pub fn matrix(&self) -> Result<Matrix<f32>> {
        match self {
            Value::F32(dims, data) if dims.len() == 2 => {
                Matrix::from_vec(dims[0], dims[1], data.clone())
            }
            _ => Err(Error::shape(format!("not a 2-D f32 value: {:?}", self.dims()))),
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Value::F32(_, d) => Ok(d),
            _ => Err(Error::msg("value is not f32")),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32(d, _) | Value::I32(d, _) => d,
        }
    }
}
