//! Memory observability (`COALA_ALLOC_STATS`): a tracking global
//! allocator with per-stage peak accounting and an optional budget.
//!
//! COALA's first headline scenario is calibration data that exceeds
//! device memory — the bounded channel, sharded accumulate, windowed
//! checkpointing, and sketch accumulators all exist to bound the
//! working set — so the telemetry stack should be able to answer "how
//! many bytes does a run actually peak at, per stage?" with the same
//! rigor it answers "how long did it take?".
//!
//! With the `telemetry` feature compiled in, the crate installs a
//! `#[global_allocator]` that wraps `std::alloc::System`.  Disarmed
//! (the default), every hook is one relaxed atomic load and a passthru
//! call — the same order of cost as the [`super::health`] probes.
//! Armed via strict `COALA_ALLOC_STATS=1`, it maintains three relaxed
//! counters: current live bytes, the peak watermark, and a total
//! allocation count.  [`MemScope`] snapshots a *per-stage* peak by
//! resetting the watermark to the live count on entry and restoring
//! the outer watermark (via `fetch_max`, so the global peak stays
//! true) on exit.
//!
//! Contract — identical to `COALA_HEALTH`: **observation-only.**  The
//! accounting never branches on, allocates for, or perturbs the data
//! it observes; factors are bitwise-identical armed or not
//! (`rust/tests/telemetry.rs` proves it the same way it does for the
//! health probes).
//!
//! Concurrent scopes share the process-wide watermark: the engine's
//! calibration stages (capture ∥ sharded accumulate ∥ merge) genuinely
//! share one working set, so the driver opens *one* scope around the
//! calibration window and attributes the shared peak to all of them,
//! while serial stages (codec, checkpoint IO, factorize, trainer
//! steps) get true per-scope deltas.
//!
//! `COALA_MEM_BUDGET_MB` (strict `u64`, ≥ 1) arms a soft budget: a
//! stage whose peak crosses it emits a `budget_exceeded`-counting
//! `mem_budget` health record — a warning folded into the
//! `coala report` health summary, **never** an abort.  Setting the
//! budget without `COALA_ALLOC_STATS=1` is a hard error (there would
//! be no peaks to compare), as is setting either knob on a build
//! without the `telemetry` feature.
//!
//! A Linux `/proc/self/status` `VmHWM` read ([`vm_hwm_bytes`])
//! cross-checks the allocator at run end: the OS-level resident
//! high-water mark must be at least the allocator's peak (it also
//! counts code, stacks, and allocator slack), so the pair bounds the
//! true footprint from both sides.

use crate::error::Result;

/// One snapshot of the allocator counters.
///
/// From [`snapshot`], `peak_bytes`/`cur_bytes`/`allocs` are
/// process-lifetime totals; from [`MemScope::finish`], `peak_bytes` is
/// the scope-local watermark, `cur_bytes` the live count at scope
/// exit, and `allocs` the count delta inside the scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemStats {
    pub peak_bytes: u64,
    pub cur_bytes: u64,
    pub allocs: u64,
}

#[cfg(feature = "telemetry")]
mod imp {
    use super::MemStats;
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    static ARMED: AtomicBool = AtomicBool::new(false);
    static CUR: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);
    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    /// Soft budget in bytes; 0 = unset (`COALA_MEM_BUDGET_MB` rejects 0).
    static BUDGET: AtomicU64 = AtomicU64::new(0);

    /// System-allocator wrapper counting live/peak bytes when armed.
    ///
    /// The hooks must not allocate (they run *inside* the allocator)
    /// and must not branch on the data being allocated — relaxed
    /// atomics only, so arming cannot perturb program behavior.
    struct TrackingAlloc;

    #[global_allocator]
    static GLOBAL: TrackingAlloc = TrackingAlloc;

    #[inline]
    fn on_alloc(size: usize) {
        if ARMED.load(Ordering::Relaxed) {
            let cur = CUR.fetch_add(size, Ordering::Relaxed) + size;
            PEAK.fetch_max(cur, Ordering::Relaxed);
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    fn on_dealloc(size: usize) {
        if ARMED.load(Ordering::Relaxed) {
            // Saturating: blocks allocated before arming deallocate
            // after it, and the live count must not wrap.
            let _ = CUR.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| {
                Some(c.saturating_sub(size))
            });
        }
    }

    unsafe impl GlobalAlloc for TrackingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc_zeroed(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            on_dealloc(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                on_dealloc(layout.size());
                on_alloc(new_size);
            }
            p
        }
    }

    #[inline]
    pub fn armed() -> bool {
        ARMED.load(Ordering::Relaxed)
    }

    /// Direct toggle for tests and benches; production goes through
    /// [`super::init_from_env`].
    pub fn set_armed(on: bool) {
        ARMED.store(on, Ordering::Relaxed);
    }

    pub fn set_budget(bytes: Option<u64>) {
        BUDGET.store(bytes.unwrap_or(0), Ordering::Relaxed);
    }

    pub fn budget_bytes() -> Option<u64> {
        match BUDGET.load(Ordering::Relaxed) {
            0 => None,
            b => Some(b),
        }
    }

    /// Process-lifetime counters, `None` when disarmed.
    pub fn snapshot() -> Option<MemStats> {
        if !armed() {
            return None;
        }
        Some(MemStats {
            peak_bytes: PEAK.load(Ordering::Relaxed) as u64,
            cur_bytes: CUR.load(Ordering::Relaxed) as u64,
            allocs: ALLOCS.load(Ordering::Relaxed),
        })
    }

    struct ScopeStart {
        outer_peak: usize,
        start_allocs: u64,
    }

    /// Scoped peak watermark: resets the global watermark to the live
    /// count on entry, restores `max(scope peak, outer watermark)` on
    /// exit — so the global peak stays true while the scope observes
    /// only its own high water.
    pub struct MemScope {
        start: Option<ScopeStart>,
    }

    impl MemScope {
        pub fn enter() -> MemScope {
            if !armed() {
                return MemScope { start: None };
            }
            let cur = CUR.load(Ordering::Relaxed);
            MemScope {
                start: Some(ScopeStart {
                    outer_peak: PEAK.swap(cur, Ordering::Relaxed),
                    start_allocs: ALLOCS.load(Ordering::Relaxed),
                }),
            }
        }

        /// Close the scope: restore the outer watermark and return the
        /// scope-local stats.  Idempotent (`None` after the first
        /// call, or when entered disarmed).
        pub fn finish(&mut self) -> Option<MemStats> {
            let s = self.start.take()?;
            // `fetch_max` both reads the scope-local watermark and
            // restores the outer one in a single atomic op.
            let scope_peak = PEAK.fetch_max(s.outer_peak, Ordering::Relaxed);
            Some(MemStats {
                peak_bytes: scope_peak as u64,
                cur_bytes: CUR.load(Ordering::Relaxed) as u64,
                allocs: ALLOCS.load(Ordering::Relaxed).saturating_sub(s.start_allocs),
            })
        }
    }

    impl Drop for MemScope {
        fn drop(&mut self) {
            // An abandoned scope must still restore the outer
            // watermark, or the global peak would under-report.
            let _ = self.finish();
        }
    }
}

#[cfg(not(feature = "telemetry"))]
mod imp {
    use super::MemStats;

    /// Constant `false` on the default build: every call site
    /// compiles down to nothing and no global allocator is installed.
    #[inline]
    pub fn armed() -> bool {
        false
    }

    #[inline]
    pub fn set_armed(_on: bool) {}

    #[inline]
    pub fn set_budget(_bytes: Option<u64>) {}

    #[inline]
    pub fn budget_bytes() -> Option<u64> {
        None
    }

    #[inline]
    pub fn snapshot() -> Option<MemStats> {
        None
    }

    /// Zero-sized no-op scope for the default build.
    pub struct MemScope;

    impl MemScope {
        #[inline]
        pub fn enter() -> MemScope {
            MemScope
        }

        #[inline]
        pub fn finish(&mut self) -> Option<MemStats> {
            None
        }
    }
}

pub use imp::{armed, budget_bytes, set_armed, set_budget, snapshot, MemScope};

/// OS-level resident high-water mark from `/proc/self/status`
/// (`VmHWM`, reported in kB), as a run-end cross-check of the
/// allocator's own peak: `VmHWM >= alloc peak` always holds (the OS
/// also counts code, stacks, and allocator slack), so the pair bounds
/// the true footprint from both sides.  `None` off Linux or when the
/// proc read fails.
pub fn vm_hwm_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                return Some(kb * 1024);
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Arm the allocator counters from `COALA_ALLOC_STATS` (strict flag
/// grammar; unset means off) and the soft budget from
/// `COALA_MEM_BUDGET_MB` (strict `u64`, must be ≥ 1).  A budget
/// without armed alloc stats is a hard error — there would be no
/// stage peaks to compare it against.  Called by
/// `TelemetrySink::from_env`, so every driver entry point arms the
/// counters before any kernel runs.
#[cfg(feature = "telemetry")]
pub fn init_from_env() -> Result<bool> {
    let on = crate::util::env::flag("COALA_ALLOC_STATS")?;
    let budget_mb: Option<u64> = crate::util::env::parse("COALA_MEM_BUDGET_MB")?;
    if let Some(mb) = budget_mb {
        if mb == 0 {
            return Err(crate::error::Error::Config(
                "COALA_MEM_BUDGET_MB must be >= 1 (every stage would exceed a zero budget)"
                    .into(),
            ));
        }
        if !on {
            return Err(crate::error::Error::Config(
                "COALA_MEM_BUDGET_MB is set but COALA_ALLOC_STATS is not; the budget \
                 compares per-stage allocator peaks, so set COALA_ALLOC_STATS=1 or unset it"
                    .into(),
            ));
        }
    }
    imp::set_armed(on);
    imp::set_budget(budget_mb.map(|mb| mb.saturating_mul(1024 * 1024)));
    Ok(on)
}

/// Loud failure instead of a silently ignored knob: setting
/// `COALA_ALLOC_STATS` or `COALA_MEM_BUDGET_MB` against a build
/// without the `telemetry` feature is a config error.
#[cfg(not(feature = "telemetry"))]
pub fn init_from_env() -> Result<bool> {
    for knob in ["COALA_ALLOC_STATS", "COALA_MEM_BUDGET_MB"] {
        if std::env::var_os(knob).is_some() {
            return Err(crate::error::Error::Config(format!(
                "{knob} is set but this build lacks the `telemetry` \
                 feature; rebuild with `--features telemetry` or unset it"
            )));
        }
    }
    Ok(false)
}
