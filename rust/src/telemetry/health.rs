//! Numerical-health probes (`COALA_HEALTH`): per-stage evidence of how
//! *healthy* the math was, not just how long it took.
//!
//! COALA's pitch is numerical stability — avoiding Gram inversion,
//! surviving nearly singular activations, regularizing thin data — so
//! the runtime should surface the observable quantities its guarantees
//! are stated in: condition estimates of the accumulated R, exact
//! σ_min/σ_max where an SVD already ran, Jacobi sweeps-to-converge and
//! final off-diagonal mass, the effective regularization μ actually
//! applied, sketch geometry (rows s vs width, Ω family), non-finite
//! factor detection, and trainer grad-norm/loss traces.
//!
//! Probe sites deep in the kernels (`linalg::svd`, `linalg::eigh`,
//! `coala::regularized`) have no telemetry handle; they push
//! [`HealthEvent`]s into a thread-local buffer via [`note`], and the
//! stage driver that owns a `TelemetrySink` calls [`drain`] and emits
//! `health` records.  The engine factorizes each projection to
//! completion on one worker thread, so a drain right after a factorize
//! call collects exactly that projection's events.  Sites that already
//! hold a sink (pipeline, trainer) emit directly.
//!
//! Contract: **zero flops when off, observation-only when on.**  Every
//! probe is guarded by [`enabled`] (one relaxed atomic load; constant
//! `false` on the default build, so the probe blocks compile out) and
//! only *reads* state the kernel already computed.  Factors stay
//! bitwise-identical with health on or off.
//!
//! `COALA_HEALTH` follows the strict-knob contract: `1|true|yes` /
//! `0|false|no` (case-insensitive), garbage is a hard error naming the
//! knob, and setting it at all on a build without the `telemetry`
//! feature is a loud error — never a silently ignored knob.

use crate::error::Result;

/// One numerical observation from a probe site: a probe name plus
/// numeric and text fields, flattened into the emitted `health` record.
#[derive(Debug, Clone)]
pub struct HealthEvent {
    pub probe: &'static str,
    pub num: Vec<(&'static str, f64)>,
    pub txt: Vec<(&'static str, String)>,
}

impl HealthEvent {
    pub fn new(probe: &'static str) -> HealthEvent {
        HealthEvent { probe, num: Vec::new(), txt: Vec::new() }
    }

    pub fn num(mut self, key: &'static str, v: f64) -> HealthEvent {
        self.num.push((key, v));
        self
    }

    pub fn txt(mut self, key: &'static str, v: impl Into<String>) -> HealthEvent {
        self.txt.push((key, v.into()));
        self
    }
}

#[cfg(feature = "telemetry")]
mod imp {
    use super::HealthEvent;
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicBool, Ordering};

    static ENABLED: AtomicBool = AtomicBool::new(false);

    thread_local! {
        static PENDING: RefCell<Vec<HealthEvent>> = RefCell::new(Vec::new());
    }

    /// One relaxed load — the entire cost of a probe site when off.
    #[inline]
    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// Direct toggle for tests; production goes through
    /// [`super::init_from_env`].
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Buffer one observation on the current thread (no-op when off).
    pub fn note(ev: HealthEvent) {
        if enabled() {
            PENDING.with(|p| p.borrow_mut().push(ev));
        }
    }

    /// Take every observation buffered on the current thread.
    pub fn drain() -> Vec<HealthEvent> {
        PENDING.with(|p| std::mem::take(&mut *p.borrow_mut()))
    }
}

#[cfg(not(feature = "telemetry"))]
mod imp {
    use super::HealthEvent;

    /// Constant `false` on the default build: probe blocks compile out.
    #[inline]
    pub fn enabled() -> bool {
        false
    }

    #[inline]
    pub fn set_enabled(_on: bool) {}

    #[inline]
    pub fn note(_ev: HealthEvent) {}

    #[inline]
    pub fn drain() -> Vec<HealthEvent> {
        Vec::new()
    }
}

pub use imp::{drain, enabled, note, set_enabled};

/// Initialize the probe gate from `COALA_HEALTH` (strict flag grammar;
/// unset means off).  Called by `TelemetrySink::from_env`, so every
/// driver entry point arms the probes before any kernel runs.
#[cfg(feature = "telemetry")]
pub fn init_from_env() -> Result<bool> {
    let on = crate::util::env::flag("COALA_HEALTH")?;
    imp::set_enabled(on);
    Ok(on)
}

/// Loud failure instead of a silently ignored knob: setting
/// `COALA_HEALTH` against a build without the `telemetry` feature is a
/// config error.
#[cfg(not(feature = "telemetry"))]
pub fn init_from_env() -> Result<bool> {
    if std::env::var_os("COALA_HEALTH").is_some() {
        return Err(crate::error::Error::Config(
            "COALA_HEALTH is set but this build lacks the `telemetry` \
             feature; rebuild with `--features telemetry` or unset it"
                .into(),
        ));
    }
    Ok(false)
}
