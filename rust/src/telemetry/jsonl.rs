//! Append-only JSONL file sink.
//!
//! One [`Appender`] per output file; lines go out as a single
//! `write_all` on an `O_APPEND` handle, so concurrent appenders — the
//! in-process `Mutex` serializes threads, `O_APPEND` serializes
//! processes (e.g. `coala shard` workers pointed at one file) —
//! interleave at line granularity rather than mid-record.
//!
//! Crash tolerance: if a previous writer died mid-line the file ends
//! without `\n`; [`Appender::open`] terminates that partial line so
//! every later record starts on a fresh line and a reader that skips
//! unparsable lines loses exactly the torn record, nothing after it.

use crate::error::{Error, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug)]
pub struct Appender {
    path: PathBuf,
    file: Mutex<File>,
    /// Appends that failed since the last success (a dying disk must
    /// not turn into a stderr flood: the sink warns once via
    /// [`Appender::note_drop`], counts the rest, and surfaces the
    /// count as a `records_dropped` counter on the next success).
    dropped: AtomicU64,
    warned: AtomicBool,
}

impl Appender {
    /// Open (creating if absent) `path` for appending.
    pub fn open(path: impl AsRef<Path>) -> Result<Appender> {
        let p = path.as_ref();
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(p)
            .map_err(|e| Error::io(p, e))?;
        let len = file.metadata().map_err(|e| Error::io(p, e))?.len();
        if len > 0 {
            let mut tail = File::open(p).map_err(|e| Error::io(p, e))?;
            tail.seek(SeekFrom::End(-1)).map_err(|e| Error::io(p, e))?;
            let mut last = [0u8; 1];
            tail.read_exact(&mut last).map_err(|e| Error::io(p, e))?;
            if last[0] != b'\n' {
                file.write_all(b"\n").map_err(|e| Error::io(p, e))?;
            }
        }
        Ok(Appender {
            path: p.to_path_buf(),
            file: Mutex::new(file),
            dropped: AtomicU64::new(0),
            warned: AtomicBool::new(false),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record a failed append: warn on stderr exactly once for this
    /// appender's lifetime, then just count.
    pub fn note_drop(&self, err: &Error) {
        if !self.warned.swap(true, Ordering::Relaxed) {
            eprintln!(
                "telemetry: dropping records ({err}); further drops are \
                 counted and reported on the next successful append"
            );
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Take the pending drop count (0 if none) — the caller emits it
    /// as a `records_dropped` counter after a successful append.
    pub fn take_dropped(&self) -> u64 {
        self.dropped.swap(0, Ordering::Relaxed)
    }

    /// Append one record (without trailing newline) as a single write.
    pub fn append_line(&self, line: &str) -> Result<()> {
        let mut buf = Vec::with_capacity(line.len() + 1);
        buf.extend_from_slice(line.as_bytes());
        buf.push(b'\n');
        let mut f = self.file.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        f.write_all(&buf).map_err(|e| Error::io(&self.path, e))
    }
}
