//! `telemetry` — feature-gated, zero-dependency JSONL telemetry.
//!
//! The engine already measures per-stage busy time
//! (`coordinator::engine::StageTimings`); this module gives those
//! measurements a durable, structured home so perf work stops flying
//! blind.  With the `telemetry` cargo feature enabled and
//! `COALA_TELEMETRY=<path>` set, every instrumented stage appends one
//! JSON object per line to `<path>`:
//!
//! ```text
//! {"kind":"stage","stage":"accumulate","s":0.0123,
//!  "config":"tiny","method":"coala","route":"host","accum":"exact",
//!  "run_id":"91ab0c5de32f7a18","span":"run",
//!  "workers":4,"shards":1,"pid":4242,"t_unix_s":1754650000.5}
//! ```
//!
//! ## Record schema
//!
//! Every record carries the label set (`config`/`method`/`route`/
//! `accum`/`workers`/`shards`), `run_id` + `span` (trace stitching),
//! `pid`, and `t_unix_s`, plus per-kind fields:
//!
//! | `kind`    | fields            | meaning                                 |
//! |-----------|-------------------|-----------------------------------------|
//! | `run`     | `source`          | one header per process per run; `run_id`|
//! |           |                   | is the FNV-1a hash of the `source`      |
//! |           |                   | calibration fingerprint                 |
//! | `stage`   | `stage`, `s`      | busy seconds of one stage, incl. the    |
//! |           |                   | backpressure pair `capture_stall` /     |
//! |           |                   | `accum_idle` (bounded-channel waits)    |
//! | `counter` | `name`, `value`   | monotonic count (exact u64)             |
//! | `health`  | `probe`, …        | numerical evidence (see [`health`]):    |
//! |           |                   | σ extremes, Jacobi sweeps, R condition  |
//! |           |                   | estimates, μ, sketch geometry,          |
//! |           |                   | non-finite flags, trainer loss/grads    |
//!
//! `run_id` is derived deterministically ([`run_id_for`]) from the
//! calibration source fingerprint (`config:route:seed:batches[:accum]`)
//! — no wall-clock entropy — so the JSONL of a multi-process
//! `coala shard` × N + `coala merge` run stitches into **one trace**:
//! every shard and the merge stamp the same `run_id`, distinguished by
//! `span` (`shard/0`, `shard/1`, …, `merge`; per-projection health
//! events use `factorize/<proj>`; the trainer uses `trainer`).
//!
//! `coala report <files...>` ([`report`]) aggregates one or more such
//! files into per-(run_id, stage) summaries and a health digest.
//!
//! Instrumented stages: `capture`, `accumulate`, `merge_reduce`,
//! `factorize` (emitted from the engine's *existing* busy-time tracking
//! via [`TelemetrySink::stage_s`] — never re-timed), `capture_stall` /
//! `accum_idle` (the bounded-channel blocked time measured inside the
//! engine), plus `codec_encode` / `codec_decode`, `checkpoint_write` /
//! `checkpoint_resume`, and `trainer_step` (timed at the call site via
//! [`TelemetrySink::start_timer`], since no pre-existing measurement
//! covers them).  [`TelemetrySink::counter`] records monotonic counts
//! (e.g. batches folded) exactly — integer values never round-trip
//! through f64.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.**  Without the `telemetry` feature the
//!    sink is a unit struct and every method is an empty `#[inline]`
//!    body — the default build contains no telemetry code paths.  With
//!    the feature but no `COALA_TELEMETRY`, the sink holds no appender
//!    and every emit returns at one branch.
//! 2. **Never perturb determinism.**  The sink only *observes* wall
//!    time; it is carried by `EnginePlan` alongside the worker counts
//!    and touches no numeric state.  The [`health`] probes
//!    (`COALA_HEALTH=1`) likewise only *read* state the kernels already
//!    computed.  Results remain bitwise-identical with telemetry and
//!    health on, off, or pointed at different files.
//! 3. **Crash-tolerant appends.**  Lines are written with a single
//!    `write_all` on an `O_APPEND` handle; on open, a file whose last
//!    byte is not `\n` (a previous writer died mid-line) gets the
//!    partial line terminated first, so the file stays parsable
//!    line-by-line after any crash.  A failing disk warns on stderr
//!    **once**, then drops are counted and surfaced as a
//!    `records_dropped` counter on the next successful append — never a
//!    stderr flood.
//!
//! ## Memory layer (`COALA_ALLOC_STATS`, `COALA_MEM_BUDGET_MB`)
//!
//! [`alloc`] installs a feature-gated tracking `#[global_allocator]`
//! (relaxed-atomic live/peak/alloc-count accounting, armed by strict
//! `COALA_ALLOC_STATS=1`).  When armed, every `stage` record gains
//! `peak_bytes`/`cur_bytes` (exact `u64`): the engine attributes one
//! shared [`alloc::MemScope`] watermark to the concurrent calibration
//! stages, serial stages (factorize, codec, checkpoint IO, trainer
//! steps) get true per-scope deltas via the [`StageTimer`] guard, and
//! the engine's bounded channel reports a `queue_depth_hwm` counter.
//! Run-end counters `alloc_peak_bytes` / `alloc_count` /
//! `vm_hwm_bytes` cross-check the allocator against the OS
//! (`/proc/self/status` VmHWM).  `COALA_MEM_BUDGET_MB` arms a soft
//! budget: a stage peak crossing it emits a `mem_budget` health record
//! — a warning in the `coala report` summary, never an abort.  Same
//! contract as the health probes: observation-only, factors
//! bitwise-identical armed or not.
//!
//! ## Visual traces (`coala report --trace out.json`)
//!
//! [`trace`] exports the span-stitched JSONL into Chrome trace-event
//! JSON viewable in Perfetto / `chrome://tracing`: one pid per
//! process/shard, one tid per span, complete events from `stage`
//! records, counter tracks from `peak_bytes` and queue depth — the
//! shard-skew and `capture_stall` numbers the report computes, as a
//! timeline you can look at.
//!
//! `COALA_TELEMETRY`, `COALA_HEALTH`, `COALA_ALLOC_STATS`, and
//! `COALA_MEM_BUDGET_MB` are parsed through the strict `util::env`
//! helpers from day one: garbage values are errors, and setting any
//! of them on a build *without* the feature is a loud error rather
//! than a silently ignored knob.

use crate::error::Result;

pub mod alloc;
pub mod health;
pub mod report;
pub mod trace;

#[cfg(feature = "telemetry")]
mod jsonl;
#[cfg(feature = "telemetry")]
pub use jsonl::Appender;

/// Structured labels attached to every telemetry record.
///
/// `workers` is the engine-plan worker count; `shards` is the
/// multi-process shard count (1 for single-process runs).  `run_id` is
/// the deterministic trace id ([`run_id_for`]); `span` names the
/// process/stage scope inside the trace (`run`, `shard/3`, `merge`,
/// `trainer`).  Empty strings serialize as `""` — a record is always
/// schema-complete.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Labels {
    pub config: String,
    pub method: String,
    pub route: String,
    pub accum: String,
    pub run_id: String,
    pub span: String,
    pub workers: usize,
    pub shards: usize,
}

/// Deterministic trace id: FNV-1a over the calibration source
/// fingerprint (`config:route:seed:batches[:accum]`).  Every process
/// of a sharded run hashes the same fingerprint — the shard codec
/// already refuses to merge states whose fingerprints differ — so
/// shard and merge records stitch under one id with zero coordination
/// and zero wall-clock entropy.
pub fn run_id_for(fingerprint: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in fingerprint.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

// ---------------------------------------------------- enabled build

#[cfg(feature = "telemetry")]
mod sink {
    use super::health::HealthEvent;
    use super::Labels;
    use crate::error::Result;
    use crate::util::json::Json;
    use std::collections::{BTreeMap, BTreeSet};
    use std::sync::{Arc, Mutex, OnceLock};
    use std::time::{Instant, SystemTime, UNIX_EPOCH};

    /// Cloneable handle to the run's JSONL appender plus the label set
    /// records are stamped with.  Cloning is cheap (one `Arc` bump);
    /// [`TelemetrySink::with_labels`] refines labels per job without
    /// touching the shared appender.
    #[derive(Debug, Clone, Default)]
    pub struct TelemetrySink {
        inner: Option<Arc<super::Appender>>,
        labels: Labels,
    }

    /// One `run` header per (file, run_id) per process: sweeping
    /// drivers call [`TelemetrySink::with_run`] once per job, and jobs
    /// sharing a fingerprint must not spam duplicate headers.
    fn mark_run_emitted(path: &std::path::Path, run_id: &str) -> bool {
        static EMITTED: OnceLock<Mutex<BTreeSet<(std::path::PathBuf, String)>>> = OnceLock::new();
        let set = EMITTED.get_or_init(|| Mutex::new(BTreeSet::new()));
        let mut set = set.lock().unwrap_or_else(|e| e.into_inner());
        set.insert((path.to_path_buf(), run_id.to_string()))
    }

    impl TelemetrySink {
        /// A sink that drops everything.
        pub fn disabled() -> TelemetrySink {
            TelemetrySink::default()
        }

        /// Open the sink `COALA_TELEMETRY` points at, or a disabled
        /// sink when the variable is unset.  A set-but-empty value or
        /// an unopenable path is a hard error.  Also arms the
        /// [`super::health`] probes from `COALA_HEALTH` and the
        /// [`super::alloc`] counters from `COALA_ALLOC_STATS` /
        /// `COALA_MEM_BUDGET_MB` (all strict), so every driver entry
        /// point initializes the whole knob family together.
        pub fn from_env() -> Result<TelemetrySink> {
            super::health::init_from_env()?;
            super::alloc::init_from_env()?;
            match crate::util::env::string("COALA_TELEMETRY")? {
                None => Ok(TelemetrySink::disabled()),
                Some(path) => TelemetrySink::to_path(&path),
            }
        }

        /// Open a sink appending to `path` (used by tests; `from_env`
        /// is the production entry).
        pub fn to_path(path: &str) -> Result<TelemetrySink> {
            Ok(TelemetrySink {
                inner: Some(Arc::new(super::Appender::open(path)?)),
                labels: Labels::default(),
            })
        }

        pub fn is_enabled(&self) -> bool {
            self.inner.is_some()
        }

        /// Refine the label set (builder-style): the closure mutates a
        /// copy of the current labels, so per-job sinks inherit the
        /// run-level `route`/`workers` and add `config`/`method`.
        pub fn with_labels(mut self, f: impl FnOnce(&mut Labels)) -> TelemetrySink {
            f(&mut self.labels);
            self
        }

        /// Stamp the deterministic `run_id` derived from the
        /// calibration source fingerprint onto this sink and emit one
        /// `run` header record carrying the raw fingerprint (deduped
        /// per file × run_id within the process).
        pub fn with_run(self, fingerprint: &str) -> TelemetrySink {
            let rid = super::run_id_for(fingerprint);
            let sink = self.with_labels(|l| l.run_id = rid.clone());
            if let Some(appender) = &sink.inner {
                if mark_run_emitted(appender.path(), &rid) {
                    sink.emit("run", |o| {
                        o.insert("source".into(), Json::Str(fingerprint.into()));
                    });
                }
            }
            sink
        }

        /// Record an already-measured stage duration.  This is the
        /// bridge from the engine's existing `StageTimings` busy-time
        /// tracking — stages are never re-timed for telemetry.  With
        /// the allocator armed, the record carries the process-wide
        /// counters ([`super::alloc::snapshot`]); callers holding a
        /// scoped measurement use [`TelemetrySink::stage_mem`].
        pub fn stage_s(&self, stage: &str, seconds: f64) {
            self.stage_mem(stage, seconds, super::alloc::snapshot());
        }

        /// Record a stage duration plus its memory stats (`None` when
        /// the allocator is disarmed — the record then carries no
        /// memory fields).  When a [`super::alloc::budget_bytes`]
        /// budget is set and the stage peak crosses it, a
        /// `mem_budget` health record is emitted alongside — a
        /// warning in the report's health summary, never an abort.
        pub fn stage_mem(&self, stage: &str, seconds: f64, mem: Option<super::alloc::MemStats>) {
            self.emit("stage", |o| {
                o.insert("stage".into(), Json::Str(stage.into()));
                o.insert("s".into(), Json::Num(seconds));
                if let Some(m) = &mem {
                    o.insert("peak_bytes".into(), Json::UInt(m.peak_bytes));
                    o.insert("cur_bytes".into(), Json::UInt(m.cur_bytes));
                }
            });
            if let (Some(m), Some(budget)) = (mem, super::alloc::budget_bytes()) {
                if m.peak_bytes > budget {
                    self.health_event(
                        None,
                        &HealthEvent::new("mem_budget")
                            .num("peak_bytes", m.peak_bytes as f64)
                            .num("budget_bytes", budget as f64)
                            .txt("stage", stage),
                    );
                }
            }
        }

        /// Record a monotonic count, exactly: the value is serialized
        /// as an integer literal (`Json::UInt`), never rounded through
        /// f64 (which silently corrupts counts above 2^53).
        pub fn counter(&self, name: &str, value: u64) {
            self.emit("counter", |o| {
                o.insert("name".into(), Json::Str(name.into()));
                o.insert("value".into(), Json::UInt(value));
            });
        }

        /// Emit one `health` record (see [`super::health`]).  `span`
        /// overrides the label span — per-projection evidence lands
        /// under `factorize/<proj>` while the sink stays shared.
        pub fn health_event(&self, span: Option<&str>, ev: &HealthEvent) {
            self.emit("health", |o| {
                o.insert("probe".into(), Json::Str(ev.probe.into()));
                for (k, v) in &ev.num {
                    o.insert((*k).to_string(), Json::Num(*v));
                }
                for (k, v) in &ev.txt {
                    o.insert((*k).to_string(), Json::Str(v.clone()));
                }
                if let Some(sp) = span {
                    o.insert("span".into(), Json::Str(sp.into()));
                }
            });
        }

        /// Start a wall-clock timer for a stage that has no existing
        /// busy-time measurement (codec, checkpoint IO, trainer step).
        /// The guard also opens a [`super::alloc::MemScope`], so the
        /// record emitted on drop carries that stage's own peak
        /// delta when the allocator is armed.
        pub fn start_timer(&self, stage: &str) -> StageTimer<'_> {
            StageTimer {
                sink: self,
                stage,
                start: Instant::now(),
                mem: super::alloc::MemScope::enter(),
            }
        }

        fn emit(&self, kind: &str, fill: impl FnOnce(&mut BTreeMap<String, Json>)) {
            let Some(appender) = &self.inner else { return };
            let mut o = BTreeMap::new();
            o.insert("kind".to_string(), Json::Str(kind.into()));
            let l = &self.labels;
            o.insert("config".to_string(), Json::Str(l.config.clone()));
            o.insert("method".to_string(), Json::Str(l.method.clone()));
            o.insert("route".to_string(), Json::Str(l.route.clone()));
            o.insert("accum".to_string(), Json::Str(l.accum.clone()));
            o.insert("run_id".to_string(), Json::Str(l.run_id.clone()));
            o.insert("span".to_string(), Json::Str(l.span.clone()));
            o.insert("workers".to_string(), Json::Num(l.workers as f64));
            o.insert("shards".to_string(), Json::Num(l.shards as f64));
            o.insert("pid".to_string(), Json::Num(std::process::id() as f64));
            if let Ok(t) = SystemTime::now().duration_since(UNIX_EPOCH) {
                o.insert("t_unix_s".to_string(), Json::Num(t.as_secs_f64()));
            }
            // The fill runs last so a per-record span override wins
            // over the label default.
            fill(&mut o);
            // Telemetry must never kill the run it observes: a failed
            // append warns once, then drops are counted and surfaced
            // as a `records_dropped` counter on the next success.
            match appender.append_line(&Json::Obj(o).dump()) {
                Err(e) => appender.note_drop(&e),
                Ok(()) => {
                    let dropped = appender.take_dropped();
                    if dropped > 0 {
                        // One level of recursion only: the inner emit
                        // sees a zero drop count.  If this append fails
                        // too, the count restarts from its own drop.
                        self.counter("records_dropped", dropped);
                    }
                }
            }
        }
    }

    /// Drop guard emitting a `stage` record with the elapsed time and
    /// (allocator armed) the scope's own memory stats.
    pub struct StageTimer<'a> {
        sink: &'a TelemetrySink,
        stage: &'a str,
        start: Instant,
        mem: super::alloc::MemScope,
    }

    impl Drop for StageTimer<'_> {
        fn drop(&mut self) {
            let stats = self.mem.finish();
            self.sink.stage_mem(self.stage, self.start.elapsed().as_secs_f64(), stats);
        }
    }
}

#[cfg(feature = "telemetry")]
pub use sink::{StageTimer, TelemetrySink};

// --------------------------------------------------- disabled build

/// No-op sink: the default build compiles every call site against
/// empty inline bodies, so disabling the feature removes all telemetry
/// code paths.
#[cfg(not(feature = "telemetry"))]
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TelemetrySink;

#[cfg(not(feature = "telemetry"))]
impl TelemetrySink {
    #[inline]
    pub fn disabled() -> TelemetrySink {
        TelemetrySink
    }

    /// Loud failure instead of a silently ignored knob: setting
    /// `COALA_TELEMETRY` (or `COALA_HEALTH` / `COALA_ALLOC_STATS` /
    /// `COALA_MEM_BUDGET_MB`, via the sub-module `init_from_env`s)
    /// against a build without the `telemetry` feature is a config
    /// error.
    pub fn from_env() -> Result<TelemetrySink> {
        if std::env::var_os("COALA_TELEMETRY").is_some() {
            return Err(crate::error::Error::Config(
                "COALA_TELEMETRY is set but this build lacks the `telemetry` \
                 feature; rebuild with `--features telemetry` or unset it"
                    .into(),
            ));
        }
        health::init_from_env()?;
        alloc::init_from_env()?;
        Ok(TelemetrySink)
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        false
    }

    #[inline]
    pub fn with_labels(self, _f: impl FnOnce(&mut Labels)) -> TelemetrySink {
        self
    }

    #[inline]
    pub fn with_run(self, _fingerprint: &str) -> TelemetrySink {
        self
    }

    #[inline]
    pub fn stage_s(&self, _stage: &str, _seconds: f64) {}

    #[inline]
    pub fn stage_mem(&self, _stage: &str, _seconds: f64, _mem: Option<alloc::MemStats>) {}

    #[inline]
    pub fn counter(&self, _name: &str, _value: u64) {}

    #[inline]
    pub fn health_event(&self, _span: Option<&str>, _ev: &health::HealthEvent) {}

    #[inline]
    pub fn start_timer(&self, _stage: &str) -> StageTimer {
        StageTimer
    }
}

/// No-op guard for the disabled build.
#[cfg(not(feature = "telemetry"))]
pub struct StageTimer;
