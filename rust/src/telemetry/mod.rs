//! `telemetry` — feature-gated, zero-dependency JSONL telemetry.
//!
//! The engine already measures per-stage busy time
//! (`coordinator::engine::StageTimings`); this module gives those
//! measurements a durable, structured home so perf work stops flying
//! blind.  With the `telemetry` cargo feature enabled and
//! `COALA_TELEMETRY=<path>` set, every instrumented stage appends one
//! JSON object per line to `<path>`:
//!
//! ```text
//! {"kind":"stage","stage":"accumulate","s":0.0123,
//!  "config":"tiny","method":"coala","route":"host","accum":"exact",
//!  "workers":4,"shards":1,"pid":4242,"t_unix_s":1754650000.5}
//! ```
//!
//! Instrumented stages: `capture`, `accumulate`, `merge_reduce`,
//! `factorize` (emitted from the engine's *existing* busy-time tracking
//! via [`TelemetrySink::stage_s`] — never re-timed), plus
//! `codec_encode` / `codec_decode`, `checkpoint_write` /
//! `checkpoint_resume`, and `trainer_step` (timed at the call site via
//! [`TelemetrySink::start_timer`], since no pre-existing measurement
//! covers them).  [`TelemetrySink::counter`] records monotonic counts
//! (e.g. batches folded).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.**  Without the `telemetry` feature the
//!    sink is a unit struct and every method is an empty `#[inline]`
//!    body — the default build contains no telemetry code paths.  With
//!    the feature but no `COALA_TELEMETRY`, the sink holds no appender
//!    and every emit returns at one branch.
//! 2. **Never perturb determinism.**  The sink only *observes* wall
//!    time; it is carried by `EnginePlan` alongside the worker counts
//!    and touches no numeric state.  Results remain bitwise-identical
//!    with telemetry on, off, or pointed at different files.
//! 3. **Crash-tolerant appends.**  Lines are written with a single
//!    `write_all` on an `O_APPEND` handle; on open, a file whose last
//!    byte is not `\n` (a previous writer died mid-line) gets the
//!    partial line terminated first, so the file stays parsable
//!    line-by-line after any crash.
//!
//! `COALA_TELEMETRY` is parsed through the strict `util::env` helpers
//! from day one: an empty value is an error, and setting it on a build
//! *without* the feature is a loud error rather than a silently
//! ignored knob.

use crate::error::Result;

#[cfg(feature = "telemetry")]
mod jsonl;
#[cfg(feature = "telemetry")]
pub use jsonl::Appender;

/// Structured labels attached to every telemetry record.
///
/// `workers` is the engine-plan worker count; `shards` is the
/// multi-process shard count (1 for single-process runs).  Empty
/// strings serialize as `""` — a record is always schema-complete.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Labels {
    pub config: String,
    pub method: String,
    pub route: String,
    pub accum: String,
    pub workers: usize,
    pub shards: usize,
}

// ---------------------------------------------------- enabled build

#[cfg(feature = "telemetry")]
mod sink {
    use super::Labels;
    use crate::error::Result;
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::{Instant, SystemTime, UNIX_EPOCH};

    /// Cloneable handle to the run's JSONL appender plus the label set
    /// records are stamped with.  Cloning is cheap (one `Arc` bump);
    /// [`TelemetrySink::with_labels`] refines labels per job without
    /// touching the shared appender.
    #[derive(Debug, Clone, Default)]
    pub struct TelemetrySink {
        inner: Option<Arc<super::Appender>>,
        labels: Labels,
    }

    impl TelemetrySink {
        /// A sink that drops everything.
        pub fn disabled() -> TelemetrySink {
            TelemetrySink::default()
        }

        /// Open the sink `COALA_TELEMETRY` points at, or a disabled
        /// sink when the variable is unset.  A set-but-empty value or
        /// an unopenable path is a hard error.
        pub fn from_env() -> Result<TelemetrySink> {
            match crate::util::env::string("COALA_TELEMETRY")? {
                None => Ok(TelemetrySink::disabled()),
                Some(path) => TelemetrySink::to_path(&path),
            }
        }

        /// Open a sink appending to `path` (used by tests; `from_env`
        /// is the production entry).
        pub fn to_path(path: &str) -> Result<TelemetrySink> {
            Ok(TelemetrySink {
                inner: Some(Arc::new(super::Appender::open(path)?)),
                labels: Labels::default(),
            })
        }

        pub fn is_enabled(&self) -> bool {
            self.inner.is_some()
        }

        /// Refine the label set (builder-style): the closure mutates a
        /// copy of the current labels, so per-job sinks inherit the
        /// run-level `route`/`workers` and add `config`/`method`.
        pub fn with_labels(mut self, f: impl FnOnce(&mut Labels)) -> TelemetrySink {
            f(&mut self.labels);
            self
        }

        /// Record an already-measured stage duration.  This is the
        /// bridge from the engine's existing `StageTimings` busy-time
        /// tracking — stages are never re-timed for telemetry.
        pub fn stage_s(&self, stage: &str, seconds: f64) {
            self.emit("stage", |o| {
                o.insert("stage".into(), Json::Str(stage.into()));
                o.insert("s".into(), Json::Num(seconds));
            });
        }

        /// Record a monotonic count.
        pub fn counter(&self, name: &str, value: u64) {
            self.emit("counter", |o| {
                o.insert("name".into(), Json::Str(name.into()));
                o.insert("value".into(), Json::Num(value as f64));
            });
        }

        /// Start a wall-clock timer for a stage that has no existing
        /// busy-time measurement (codec, checkpoint IO, trainer step).
        /// The record is emitted when the guard drops.
        pub fn start_timer(&self, stage: &str) -> StageTimer<'_> {
            StageTimer { sink: self, stage, start: Instant::now() }
        }

        fn emit(&self, kind: &str, fill: impl FnOnce(&mut BTreeMap<String, Json>)) {
            let Some(appender) = &self.inner else { return };
            let mut o = BTreeMap::new();
            o.insert("kind".to_string(), Json::Str(kind.into()));
            fill(&mut o);
            let l = &self.labels;
            o.insert("config".to_string(), Json::Str(l.config.clone()));
            o.insert("method".to_string(), Json::Str(l.method.clone()));
            o.insert("route".to_string(), Json::Str(l.route.clone()));
            o.insert("accum".to_string(), Json::Str(l.accum.clone()));
            o.insert("workers".to_string(), Json::Num(l.workers as f64));
            o.insert("shards".to_string(), Json::Num(l.shards as f64));
            o.insert("pid".to_string(), Json::Num(std::process::id() as f64));
            if let Ok(t) = SystemTime::now().duration_since(UNIX_EPOCH) {
                o.insert("t_unix_s".to_string(), Json::Num(t.as_secs_f64()));
            }
            // Telemetry must never kill the run it observes: a failed
            // append drops the record with a note on stderr.
            if let Err(e) = appender.append_line(&Json::Obj(o).dump()) {
                eprintln!("telemetry: dropped record: {e}");
            }
        }
    }

    /// Drop guard emitting a `stage` record with the elapsed time.
    pub struct StageTimer<'a> {
        sink: &'a TelemetrySink,
        stage: &'a str,
        start: Instant,
    }

    impl Drop for StageTimer<'_> {
        fn drop(&mut self) {
            self.sink.stage_s(self.stage, self.start.elapsed().as_secs_f64());
        }
    }
}

#[cfg(feature = "telemetry")]
pub use sink::{StageTimer, TelemetrySink};

// --------------------------------------------------- disabled build

/// No-op sink: the default build compiles every call site against
/// empty inline bodies, so disabling the feature removes all telemetry
/// code paths.
#[cfg(not(feature = "telemetry"))]
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TelemetrySink;

#[cfg(not(feature = "telemetry"))]
impl TelemetrySink {
    #[inline]
    pub fn disabled() -> TelemetrySink {
        TelemetrySink
    }

    /// Loud failure instead of a silently ignored knob: setting
    /// `COALA_TELEMETRY` against a build without the `telemetry`
    /// feature is a config error.
    pub fn from_env() -> Result<TelemetrySink> {
        if std::env::var_os("COALA_TELEMETRY").is_some() {
            return Err(crate::error::Error::Config(
                "COALA_TELEMETRY is set but this build lacks the `telemetry` \
                 feature; rebuild with `--features telemetry` or unset it"
                    .into(),
            ));
        }
        Ok(TelemetrySink)
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        false
    }

    #[inline]
    pub fn with_labels(self, _f: impl FnOnce(&mut Labels)) -> TelemetrySink {
        self
    }

    #[inline]
    pub fn stage_s(&self, _stage: &str, _seconds: f64) {}

    #[inline]
    pub fn counter(&self, _name: &str, _value: u64) {}

    #[inline]
    pub fn start_timer(&self, _stage: &str) -> StageTimer {
        StageTimer
    }
}

/// No-op guard for the disabled build.
#[cfg(not(feature = "telemetry"))]
pub struct StageTimer;
