//! `coala report` — offline analyzer for telemetry JSONL traces.
//!
//! Parses one or more files produced by [`super::TelemetrySink`]
//! (possibly from different processes of one sharded run — they stitch
//! by `run_id`) and summarizes, per `(run_id, stage)`:
//! count / total / mean / p50 / p99, a busy-vs-stall breakdown
//! (`capture_stall` / `accum_idle` are waiting, everything else is
//! work), and per-shard skew (max/min of per-`(pid, span)` stage
//! totals), plus a health digest over the `health` records: condition
//! estimates above `--cond-threshold`, non-convergent Jacobi calls,
//! and non-finite factors/trainer state.
//!
//! Torn or malformed lines (a writer died mid-record before the
//! appender's crash repair ran, or the file was truncated) are
//! **skipped with a note**, never a crash — a trace is evidence, and
//! partial evidence still counts.
//!
//! This module is deliberately *not* feature-gated: it only reads
//! files, so the default build can analyze traces produced elsewhere.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Stages that measure *waiting* on the bounded channel rather than
/// work; everything else counts as busy time.
const STALL_STAGES: [&str; 2] = ["capture_stall", "accum_idle"];

#[derive(Debug, Clone, Copy)]
pub struct ReportOptions {
    /// Emit machine-readable JSON instead of the text report.
    pub json: bool,
    /// `r_cond` estimates above this are flagged as warnings.
    pub cond_threshold: f64,
}

impl Default for ReportOptions {
    fn default() -> ReportOptions {
        ReportOptions { json: false, cond_threshold: 1e8 }
    }
}

#[derive(Debug, Default)]
struct StageAgg {
    /// Every observed duration (seconds).
    samples: Vec<f64>,
    /// Per-(pid, span) totals — the skew axis across shard processes.
    by_worker: BTreeMap<(u64, String), f64>,
    /// Highest `peak_bytes` seen on this stage's records
    /// (`COALA_ALLOC_STATS=1`; 0 when the records carry no memory).
    peak_bytes_max: u64,
}

#[derive(Debug, Default)]
struct HealthAgg {
    records: u64,
    by_probe: BTreeMap<String, u64>,
    high_cond: u64,
    max_cond: f64,
    nonconverged: u64,
    /// `mem_budget` records: stage peaks that crossed the
    /// `COALA_MEM_BUDGET_MB` soft budget (a warning, never an abort).
    budget_exceeded: u64,
    nonfinite_factors: u64,
    trainer_nonfinite: u64,
}

impl HealthAgg {
    fn errors(&self) -> u64 {
        self.nonfinite_factors + self.trainer_nonfinite
    }
}

#[derive(Debug, Default)]
struct RunAgg {
    headers: u64,
    sources: BTreeSet<String>,
    stages: BTreeMap<String, StageAgg>,
    counters: BTreeMap<String, u64>,
    health: HealthAgg,
}

#[derive(Debug, Default)]
struct Report {
    files: usize,
    skipped_lines: u64,
    runs: BTreeMap<String, RunAgg>,
}

/// Nearest-rank percentile of an already-sorted sample set.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn ingest_line(rep: &mut Report, line: &str, opts: &ReportOptions) {
    let rec = match Json::parse(line) {
        Ok(v) => v,
        Err(_) => {
            rep.skipped_lines += 1;
            return;
        }
    };
    let field = |k: &str| rec.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    let num = |k: &str| rec.get(k).and_then(Json::as_f64);
    let run = rep.runs.entry(field("run_id")).or_default();
    match field("kind").as_str() {
        "run" => {
            run.headers += 1;
            run.sources.insert(field("source"));
        }
        "stage" => {
            let (stage, s) = (field("stage"), num("s").unwrap_or(0.0));
            let agg = run.stages.entry(stage).or_default();
            agg.samples.push(s);
            let pid = rec.get("pid").and_then(Json::as_u64).unwrap_or(0);
            *agg.by_worker.entry((pid, field("span"))).or_insert(0.0) += s;
            if let Some(p) = rec.get("peak_bytes").and_then(Json::as_u64) {
                agg.peak_bytes_max = agg.peak_bytes_max.max(p);
            }
        }
        "counter" => {
            let v = rec.get("value").and_then(Json::as_u64).unwrap_or(0);
            *run.counters.entry(field("name")).or_insert(0) += v;
        }
        "health" => {
            let h = &mut run.health;
            h.records += 1;
            let probe = field("probe");
            *h.by_probe.entry(probe.clone()).or_insert(0) += 1;
            if probe == "mem_budget" {
                h.budget_exceeded += 1;
            }
            if let Some(cond) = num("cond") {
                if cond > opts.cond_threshold || !cond.is_finite() {
                    h.high_cond += 1;
                }
                if cond > h.max_cond || !cond.is_finite() {
                    h.max_cond = cond;
                }
            }
            if num("converged") == Some(0.0) {
                h.nonconverged += 1;
            }
            if num("nonfinite").unwrap_or(0.0) > 0.0 {
                h.nonfinite_factors += num("nonfinite").unwrap_or(0.0) as u64;
            }
            // Non-finite floats serialize as JSON null: a trainer
            // record whose loss/grad vanished into null is an error.
            if probe == "trainer_step" {
                let gone = |k: &str| {
                    matches!(rec.get(k), Some(Json::Null))
                        || num(k).map(|v| !v.is_finite()).unwrap_or(false)
                };
                if gone("loss") || gone("grad_norm") {
                    h.trainer_nonfinite += 1;
                }
            }
        }
        // Unknown kinds from future schema revisions are tolerated,
        // exactly like perf_gate.py tolerates ours.
        _ => {}
    }
}

fn build(paths: &[String], opts: &ReportOptions) -> Result<Report> {
    if paths.is_empty() {
        return Err(Error::Config("report: no telemetry files given".into()));
    }
    let mut rep = Report::default();
    for path in paths {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        rep.files += 1;
        for line in text.lines() {
            if !line.trim().is_empty() {
                ingest_line(&mut rep, line, opts);
            }
        }
    }
    Ok(rep)
}

fn stage_json(name: &str, agg: &StageAgg) -> Json {
    let mut sorted = agg.samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let total: f64 = sorted.iter().sum();
    let n = sorted.len();
    let mut pairs = vec![
        ("stage", Json::Str(name.into())),
        ("count", Json::UInt(n as u64)),
        ("total_s", Json::Num(total)),
        ("mean_s", Json::Num(if n > 0 { total / n as f64 } else { 0.0 })),
        ("p50_s", Json::Num(percentile(&sorted, 50.0))),
        ("p99_s", Json::Num(percentile(&sorted, 99.0))),
    ];
    if agg.by_worker.len() > 1 {
        let min = agg.by_worker.values().cloned().fold(f64::INFINITY, f64::min);
        let max = agg.by_worker.values().cloned().fold(0.0, f64::max);
        pairs.push(("shard_min_s", Json::Num(min)));
        pairs.push(("shard_max_s", Json::Num(max)));
        pairs.push(("skew", Json::Num(if min > 0.0 { max / min } else { f64::INFINITY })));
    }
    if agg.peak_bytes_max > 0 {
        pairs.push(("peak_bytes_max", Json::UInt(agg.peak_bytes_max)));
    }
    Json::obj(pairs)
}

fn run_json(run_id: &str, run: &RunAgg, opts: &ReportOptions) -> Json {
    let mut busy = 0.0;
    let mut stall = 0.0;
    for (stage, agg) in &run.stages {
        let t: f64 = agg.samples.iter().sum();
        if STALL_STAGES.contains(&stage.as_str()) {
            stall += t;
        } else {
            busy += t;
        }
    }
    let h = &run.health;
    Json::obj(vec![
        ("run_id", Json::Str(run_id.into())),
        ("headers", Json::UInt(run.headers)),
        (
            "sources",
            Json::Arr(run.sources.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "stages",
            Json::Arr(run.stages.iter().map(|(k, v)| stage_json(k, v)).collect()),
        ),
        ("busy_s", Json::Num(busy)),
        ("stall_s", Json::Num(stall)),
        (
            "counters",
            Json::Obj(run.counters.iter().map(|(k, v)| (k.clone(), Json::UInt(*v))).collect()),
        ),
        (
            "health",
            Json::obj(vec![
                ("records", Json::UInt(h.records)),
                (
                    "probes",
                    Json::Obj(
                        h.by_probe.iter().map(|(k, v)| (k.clone(), Json::UInt(*v))).collect(),
                    ),
                ),
                (
                    "warnings",
                    Json::obj(vec![
                        ("high_cond", Json::UInt(h.high_cond)),
                        ("max_cond", Json::Num(h.max_cond)),
                        ("cond_threshold", Json::Num(opts.cond_threshold)),
                        ("nonconverged", Json::UInt(h.nonconverged)),
                        ("budget_exceeded", Json::UInt(h.budget_exceeded)),
                    ]),
                ),
                (
                    "errors",
                    Json::obj(vec![
                        ("nonfinite_factors", Json::UInt(h.nonfinite_factors)),
                        ("trainer_nonfinite", Json::UInt(h.trainer_nonfinite)),
                        ("total", Json::UInt(h.errors())),
                    ]),
                ),
            ]),
        ),
    ])
}

fn render_text(rep: &Report, opts: &ReportOptions) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "telemetry report: {} file(s), {} run(s)", rep.files, rep.runs.len());
    if rep.skipped_lines > 0 {
        let _ = writeln!(
            out,
            "note: skipped {} malformed line(s) (torn writes or truncation)",
            rep.skipped_lines
        );
    }
    for (run_id, run) in &rep.runs {
        let shown = if run_id.is_empty() { "(none)" } else { run_id };
        let _ = writeln!(out, "\n== run {shown} ({} header(s)) ==", run.headers);
        for src in &run.sources {
            let _ = writeln!(out, "  source: {src}");
        }
        let mut busy = 0.0;
        let mut stall = 0.0;
        for (stage, agg) in &run.stages {
            let mut sorted = agg.samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let total: f64 = sorted.iter().sum();
            if STALL_STAGES.contains(&stage.as_str()) {
                stall += total;
            } else {
                busy += total;
            }
            let mean = if sorted.is_empty() { 0.0 } else { total / sorted.len() as f64 };
            let _ = write!(
                out,
                "  stage {stage:<18} count {:>4}  total {total:9.4}s  mean {mean:9.4}s  \
                 p50 {:9.4}s  p99 {:9.4}s",
                sorted.len(),
                percentile(&sorted, 50.0),
                percentile(&sorted, 99.0),
            );
            if agg.by_worker.len() > 1 {
                let min = agg.by_worker.values().cloned().fold(f64::INFINITY, f64::min);
                let max = agg.by_worker.values().cloned().fold(0.0, f64::max);
                let skew = if min > 0.0 { max / min } else { f64::INFINITY };
                let _ = write!(out, "  skew {skew:5.2}x over {} worker(s)", agg.by_worker.len());
            }
            if agg.peak_bytes_max > 0 {
                let mib = agg.peak_bytes_max as f64 / (1024.0 * 1024.0);
                let _ = write!(out, "  peak {mib:8.2} MiB");
            }
            out.push('\n');
        }
        let frac = if busy + stall > 0.0 { 100.0 * stall / (busy + stall) } else { 0.0 };
        let _ = writeln!(out, "  busy {busy:.4}s, stalled {stall:.4}s ({frac:.1}% waiting)");
        if !run.counters.is_empty() {
            let _ = write!(out, "  counters:");
            for (k, v) in &run.counters {
                let _ = write!(out, " {k}={v}");
            }
            out.push('\n');
        }
        let h = &run.health;
        if h.records > 0 {
            let _ = write!(out, "  health: {} record(s)", h.records);
            for (k, v) in &h.by_probe {
                let _ = write!(out, " {k}={v}");
            }
            out.push('\n');
            let _ = writeln!(
                out,
                "    warnings: high_cond={} (max {:.3e}, threshold {:.1e}) nonconverged={} \
                 budget_exceeded={}",
                h.high_cond, h.max_cond, opts.cond_threshold, h.nonconverged, h.budget_exceeded
            );
            if h.errors() > 0 {
                let _ = writeln!(
                    out,
                    "    ERRORS: nonfinite_factors={} trainer_nonfinite={}",
                    h.nonfinite_factors, h.trainer_nonfinite
                );
            } else {
                let _ = writeln!(out, "    errors: none");
            }
        }
    }
    out
}

/// Analyze `paths` and return the rendered report (text or JSON per
/// `opts.json`).
pub fn render(paths: &[String], opts: &ReportOptions) -> Result<String> {
    let rep = build(paths, opts)?;
    if !opts.json {
        return Ok(render_text(&rep, opts));
    }
    let j = Json::obj(vec![
        ("files", Json::UInt(rep.files as u64)),
        ("skipped_lines", Json::UInt(rep.skipped_lines)),
        (
            "runs",
            Json::Arr(rep.runs.iter().map(|(k, v)| run_json(k, v, opts)).collect()),
        ),
    ]);
    Ok(j.dump())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(kind: &str, extra: &[(&str, Json)]) -> String {
        let mut pairs = vec![
            ("kind", Json::Str(kind.into())),
            ("run_id", Json::Str("r1".into())),
            ("span", Json::Str("run".into())),
            ("pid", Json::UInt(1)),
        ];
        pairs.extend(extra.iter().cloned());
        Json::obj(pairs).dump()
    }

    fn ingest(lines: &[String]) -> Report {
        let mut rep = Report::default();
        rep.files = 1;
        for l in lines {
            ingest_line(&mut rep, l, &ReportOptions::default());
        }
        rep
    }

    #[test]
    fn malformed_lines_are_skipped_not_fatal() {
        let rep = ingest(&[
            line("stage", &[("stage", Json::Str("capture".into())), ("s", Json::Num(0.5))]),
            r#"{"kind":"stage","stage":"tor"#.to_string(),
            "not json at all".to_string(),
        ]);
        assert_eq!(rep.skipped_lines, 2);
        assert_eq!(rep.runs["r1"].stages["capture"].samples, vec![0.5]);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn health_flags_classify_warnings_and_errors() {
        let rep = ingest(&[
            line("health", &[("probe", Json::Str("r_cond".into())), ("cond", Json::Num(1e12))]),
            line(
                "health",
                &[
                    ("probe", Json::Str("svd".into())),
                    ("converged", Json::Num(0.0)),
                    ("sweeps", Json::Num(40.0)),
                ],
            ),
            line(
                "health",
                &[("probe", Json::Str("factors".into())), ("nonfinite", Json::Num(2.0))],
            ),
            line(
                "health",
                &[("probe", Json::Str("trainer_step".into())), ("loss", Json::Null)],
            ),
        ]);
        let h = &rep.runs["r1"].health;
        assert_eq!(h.high_cond, 1);
        assert_eq!(h.nonconverged, 1);
        assert_eq!(h.nonfinite_factors, 2);
        assert_eq!(h.trainer_nonfinite, 1);
        assert_eq!(h.errors(), 3);
    }

    #[test]
    fn memory_fields_aggregate_as_peak_max_and_budget_warnings() {
        let rep = ingest(&[
            line(
                "stage",
                &[
                    ("stage", Json::Str("factorize".into())),
                    ("s", Json::Num(0.5)),
                    ("peak_bytes", Json::UInt(4096)),
                    ("cur_bytes", Json::UInt(1024)),
                ],
            ),
            line(
                "stage",
                &[
                    ("stage", Json::Str("factorize".into())),
                    ("s", Json::Num(0.4)),
                    ("peak_bytes", Json::UInt(16384)),
                    ("cur_bytes", Json::UInt(512)),
                ],
            ),
            // records without memory fields (allocator disarmed) mix in
            line("stage", &[("stage", Json::Str("factorize".into())), ("s", Json::Num(0.1))]),
            line(
                "health",
                &[
                    ("probe", Json::Str("mem_budget".into())),
                    ("stage", Json::Str("factorize".into())),
                    ("peak_bytes", Json::Num(16384.0)),
                    ("budget_bytes", Json::Num(8192.0)),
                ],
            ),
        ]);
        let run = &rep.runs["r1"];
        assert_eq!(run.stages["factorize"].peak_bytes_max, 16384);
        assert_eq!(run.health.budget_exceeded, 1);
        // a budget crossing is a warning, never an error
        assert_eq!(run.health.errors(), 0);
    }

    #[test]
    fn counters_sum_exactly_at_u64_scale() {
        let rep = ingest(&[
            line(
                "counter",
                &[("name", Json::Str("big".into())), ("value", Json::UInt(u64::MAX - 5))],
            ),
            line("counter", &[("name", Json::Str("big".into())), ("value", Json::UInt(5))]),
        ]);
        // wrapping is the caller's problem; exactness is ours
        assert_eq!(rep.runs["r1"].counters["big"], (u64::MAX - 5).wrapping_add(5));
    }
}
