//! Chrome trace-event export (`coala report --trace out.json`).
//!
//! Converts span-stitched telemetry JSONL into the Chrome trace-event
//! JSON format that Perfetto and `chrome://tracing` load directly —
//! the shard-skew, backpressure, and memory numbers [`super::report`]
//! aggregates, as a timeline you can look at:
//!
//! * one **pid** per process (shard processes of one run stitch side
//!   by side, labelled by their span set via `process_name` metadata),
//! * one **tid** per span within a process (`run`, `shard/0`, `merge`,
//!   `trainer`, …), labelled via `thread_name` metadata,
//! * one complete (`"ph":"X"`) event per `stage` record — start
//!   reconstructed as `t_unix_s − s` (the sink stamps records at stage
//!   *end*), normalized so the earliest stage start of the whole trace
//!   is `ts = 0`, durations in microseconds,
//! * counter (`"ph":"C"`) tracks from the memory layer: per-stage
//!   `peak_bytes`/`cur_bytes` when `COALA_ALLOC_STATS=1` was armed,
//!   and the engine's `queue_depth_hwm` channel gauge.
//!
//! Like the report, this module is *not* feature-gated — it only reads
//! files, so any build can export traces produced elsewhere.  Torn or
//! malformed lines are skipped, never fatal; every well-formed `stage`
//! record maps to exactly one complete event (CI asserts this).

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One parsed line we know how to draw.
enum Rec {
    Stage {
        pid: u64,
        span: String,
        stage: String,
        s: f64,
        end_unix_s: f64,
        run_id: String,
        peak_bytes: Option<u64>,
        cur_bytes: Option<u64>,
    },
    Counter {
        pid: u64,
        span: String,
        name: String,
        value: u64,
        end_unix_s: f64,
    },
}

fn parse_line(line: &str) -> Option<Rec> {
    let rec = Json::parse(line).ok()?;
    let field = |k: &str| rec.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    let num = |k: &str| rec.get(k).and_then(Json::as_f64);
    let pid = rec.get("pid").and_then(Json::as_u64).unwrap_or(0);
    match field("kind").as_str() {
        "stage" => Some(Rec::Stage {
            pid,
            span: field("span"),
            stage: field("stage"),
            s: num("s").unwrap_or(0.0).max(0.0),
            end_unix_s: num("t_unix_s").unwrap_or(0.0),
            run_id: field("run_id"),
            peak_bytes: rec.get("peak_bytes").and_then(Json::as_u64),
            cur_bytes: rec.get("cur_bytes").and_then(Json::as_u64),
        }),
        "counter" => Some(Rec::Counter {
            pid,
            span: field("span"),
            name: field("name"),
            value: rec.get("value").and_then(Json::as_u64).unwrap_or(0),
            end_unix_s: num("t_unix_s").unwrap_or(0.0),
        }),
        // run headers and health records carry no drawable duration
        _ => None,
    }
}

/// Export telemetry JSONL files as one Chrome trace-event JSON string.
pub fn export(paths: &[String]) -> Result<String> {
    if paths.is_empty() {
        return Err(Error::Config("trace: no telemetry files given".into()));
    }
    let mut recs: Vec<Rec> = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        recs.extend(text.lines().filter(|l| !l.trim().is_empty()).filter_map(parse_line));
    }

    // Normalize the time axis: t = 0 at the earliest stage *start*
    // (records are stamped at stage end, so start = end − duration).
    let t0 = recs
        .iter()
        .filter_map(|r| match r {
            Rec::Stage { s, end_unix_s, .. } => Some(end_unix_s - s),
            Rec::Counter { .. } => None,
        })
        .fold(f64::INFINITY, f64::min);
    let t0 = if t0.is_finite() { t0 } else { 0.0 };
    let us = |unix_s: f64| ((unix_s - t0) * 1e6).max(0.0);

    // tid = 1-based rank of the span within its pid (sorted, so the
    // mapping is deterministic and survives re-export).
    let mut spans: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for r in &recs {
        let (Rec::Stage { pid, span, .. } | Rec::Counter { pid, span, .. }) = r;
        let v = spans.entry(*pid).or_default();
        if !v.contains(span) {
            v.push(span.clone());
        }
    }
    for v in spans.values_mut() {
        v.sort();
    }
    let tid_of = |pid: u64, span: &str| -> u64 {
        spans[&pid].iter().position(|s| s == span).unwrap_or(0) as u64 + 1
    };

    let mut events: Vec<Json> = Vec::new();
    // Metadata first: name every process by its span set (a shard
    // process shows as "coala shard/1", the merge as "coala merge").
    for (pid, sp) in &spans {
        events.push(Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("process_name".into())),
            ("pid", Json::UInt(*pid)),
            ("args", Json::obj(vec![("name", Json::Str(format!("coala {}", sp.join(","))))])),
        ]));
        for span in sp {
            events.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::UInt(*pid)),
                ("tid", Json::UInt(tid_of(*pid, span))),
                ("args", Json::obj(vec![("name", Json::Str(span.clone()))])),
            ]));
        }
    }

    for r in &recs {
        match r {
            Rec::Stage { pid, span, stage, s, end_unix_s, run_id, peak_bytes, cur_bytes } => {
                let tid = tid_of(*pid, span);
                events.push(Json::obj(vec![
                    ("ph", Json::Str("X".into())),
                    ("name", Json::Str(stage.clone())),
                    ("cat", Json::Str("stage".into())),
                    ("pid", Json::UInt(*pid)),
                    ("tid", Json::UInt(tid)),
                    ("ts", Json::Num(us(end_unix_s - s))),
                    ("dur", Json::Num(s * 1e6)),
                    ("args", Json::obj(vec![("run_id", Json::Str(run_id.clone()))])),
                ]));
                if let (Some(peak), Some(cur)) = (peak_bytes, cur_bytes) {
                    // one memory sample per instrumented stage, on its
                    // own per-process counter track
                    events.push(Json::obj(vec![
                        ("ph", Json::Str("C".into())),
                        ("name", Json::Str("memory".into())),
                        ("pid", Json::UInt(*pid)),
                        ("tid", Json::UInt(tid)),
                        ("ts", Json::Num(us(*end_unix_s))),
                        (
                            "args",
                            Json::obj(vec![
                                ("peak_bytes", Json::UInt(*peak)),
                                ("cur_bytes", Json::UInt(*cur)),
                            ]),
                        ),
                    ]));
                }
            }
            Rec::Counter { pid, span, name, value, end_unix_s } => {
                // only gauges draw usefully as counter tracks; cumulative
                // bookkeeping counters (batches, sweeps, drops) stay in
                // the report
                if name != "queue_depth_hwm" {
                    continue;
                }
                events.push(Json::obj(vec![
                    ("ph", Json::Str("C".into())),
                    ("name", Json::Str(name.clone())),
                    ("pid", Json::UInt(*pid)),
                    ("tid", Json::UInt(tid_of(*pid, span))),
                    ("ts", Json::Num(us(*end_unix_s))),
                    ("args", Json::obj(vec![("batches", Json::UInt(*value))])),
                ]));
            }
        }
    }

    let trace = Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(events)),
    ]);
    Ok(trace.dump())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malformed_and_undrawable_lines_are_skipped() {
        assert!(parse_line("not json").is_none());
        assert!(parse_line(r#"{"kind":"run","run_id":"r1"}"#).is_none());
        assert!(parse_line(r#"{"kind":"health","probe":"svd"}"#).is_none());
        assert!(parse_line(r#"{"kind":"stage","stage":"capture","s":0.5,"pid":7}"#).is_some());
    }

    #[test]
    fn export_requires_input_files() {
        assert!(export(&[]).is_err());
    }
}
