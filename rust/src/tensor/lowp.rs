//! Reduced-precision (IEEE half / bfloat16) emulation — substrate S5.
//!
//! Table 2 runs "all computations except the weighted low-rank solve" in
//! fp16; Example G.1 shows the Gram matrix losing σ ≈ √ε_machine.  The
//! vendor runtime has no native f16 path, so we *emulate* the rounding:
//! every value is round-tripped through the target format after each
//! logical operation (round-to-nearest-even), which reproduces exactly
//! the precision-loss mechanism the paper studies.

use super::matrix::Matrix;

/// Round an f32 to the nearest representable IEEE-754 binary16 value
/// (round-to-nearest-even), returned as f32.  Overflow saturates to ±inf
/// like hardware fp16 does.
pub fn round_f16(x: f32) -> f32 {
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan pass through
        return x;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return f32::from_bits(sign | 0x7f80_0000); // ±inf (overflow)
    }
    if unbiased >= -14 {
        // normal half: keep 10 mantissa bits, RNE on the rest
        let shift = 13u32;
        let lsb = 1u32 << shift;
        let round_bit = lsb >> 1;
        let mut mant = frac;
        let rem = mant & (lsb - 1);
        mant &= !(lsb - 1);
        if rem > round_bit || (rem == round_bit && (mant & lsb) != 0) {
            mant += lsb;
        }
        let mut e = exp as u32;
        if mant > 0x007f_ffff {
            mant = 0;
            e += 1;
            if e as i32 - 127 > 15 {
                return f32::from_bits(sign | 0x7f80_0000);
            }
        }
        return f32::from_bits(sign | (e << 23) | mant);
    }
    // subnormal half: quantize to multiples of 2^-24
    let scale = (2.0f64).powi(-24);
    let q = (x as f64 / scale).round_ties_even();
    if q == 0.0 {
        return f32::from_bits(sign); // signed zero
    }
    (q * scale) as f32
}

/// Round to bfloat16 (8-bit mantissa) — the other common TPU format.
pub fn round_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        return x;
    }
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb) & 0xffff_0000;
    f32::from_bits(rounded)
}

/// Precision mode for the emulated pipelines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F16,
    Bf16,
}

impl Precision {
    pub fn round(self, x: f32) -> f32 {
        match self {
            Precision::F32 => x,
            Precision::F16 => round_f16(x),
            Precision::Bf16 => round_bf16(x),
        }
    }

    /// Unit roundoff of the format.
    pub fn eps(self) -> f64 {
        match self {
            Precision::F32 => f32::EPSILON as f64,
            Precision::F16 => 9.765625e-4, // 2^-10
            Precision::Bf16 => 7.8125e-3,  // 2^-7
        }
    }
}

/// Quantize every entry of a matrix to the given precision.
pub fn quantize(m: &Matrix<f32>, p: Precision) -> Matrix<f32> {
    Matrix {
        rows: m.rows,
        cols: m.cols,
        data: m.data.iter().map(|&x| p.round(x)).collect(),
    }
}

/// Gram matrix computed *entirely in low precision*: every partial sum is
/// rounded, as it would be on fp16 hardware without fp32 accumulation.
/// This is the operation Example G.1 shows losing σ_min ≈ √ε.
pub fn gram_lowp(xt: &Matrix<f32>, p: Precision) -> Matrix<f32> {
    let n = xt.cols;
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f32;
            for r in 0..xt.rows {
                let prod = p.round(p.round(xt.get(r, i)) * p.round(xt.get(r, j)));
                acc = p.round(acc + prod);
            }
            g.set(i, j, acc);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_values_pass_through() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 1.5, 65504.0] {
            assert_eq!(round_f16(v), v, "{v}");
        }
    }

    #[test]
    fn f16_rounds_to_10_bits() {
        // 1 + 2^-11 rounds to 1.0 (RNE, tie to even)
        assert_eq!(round_f16(1.0 + 2f32.powi(-11)), 1.0);
        // 1 + 3·2^-11 is exactly halfway between 1+2^-10 and 1+2^-9;
        // RNE ties to the even mantissa → 1 + 2^-9
        assert_eq!(round_f16(1.0 + 3.0 * 2f32.powi(-11)), 1.0 + 2f32.powi(-9));
        // just above the tie rounds down to the nearer 1 + 2^-10
        assert_eq!(round_f16(1.0 + 2.6 * 2f32.powi(-11)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn f16_overflow_saturates_to_inf() {
        assert!(round_f16(1e6).is_infinite());
        assert!(round_f16(-1e6).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 2f32.powi(-24);
        assert_eq!(round_f16(tiny), tiny);
        assert_eq!(round_f16(tiny * 0.4), 0.0);
    }

    #[test]
    fn bf16_rounds_to_8_bits() {
        assert_eq!(round_bf16(1.0), 1.0);
        assert_eq!(round_bf16(1.0 + 2f32.powi(-9)), 1.0);
        let r = round_bf16(3.14159265f32);
        assert!((r - 3.14159265).abs() < 2f32.powi(-7));
    }

    #[test]
    fn gram_lowp_loses_small_singular_values() {
        // Example G.1: X = [[1, 1], [0, √ε]], ε = ε_half/2.  The Gram
        // XᵀX = [[1, 1], [1, 1+ε]] collapses to the singular [[1,1],[1,1]]
        // because 1 + ε rounds to 1 in fp16.
        let e = (Precision::F16.eps() / 2.0) as f32;
        let xt = Matrix::from_vec(2, 2, vec![1.0, 1.0, 0.0, e.sqrt()]).unwrap();
        let g = gram_lowp(&xt, Precision::F16);
        let det = g.get(0, 0) as f64 * g.get(1, 1) as f64
            - g.get(0, 1) as f64 * g.get(1, 0) as f64;
        assert!(det.abs() < 1e-6, "det {det}");
        // exact Gram is nonsingular
        let gf = crate::tensor::ops::gram_t(&xt);
        let detf = gf.get(0, 0) as f64 * gf.get(1, 1) as f64
            - gf.get(0, 1) as f64 * gf.get(1, 0) as f64;
        assert!(detf > 0.0);
    }
}
