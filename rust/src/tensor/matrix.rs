//! Row-major dense matrix generic over `f32` / `f64`.

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Scalar abstraction over the two float widths we support.
pub trait Scalar:
    Copy
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    const EPSILON: Self;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn sqrt(self) -> Self;
    fn abs(self) -> Self;
    fn is_finite(self) -> bool;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f32::EPSILON;
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn sqrt(self) -> Self {
        self.sqrt()
    }
    fn abs(self) -> Self {
        self.abs()
    }
    fn is_finite(self) -> bool {
        self.is_finite()
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f64::EPSILON;
    fn from_f64(v: f64) -> Self {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn sqrt(self) -> Self {
        self.sqrt()
    }
    fn abs(self) -> Self {
        self.abs()
    }
    fn is_finite(self) -> bool {
        self.is_finite()
    }
}

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix<T: Scalar = f32> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Scalar> std::fmt::Debug for Matrix<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self.get(i, j).to_f64())?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl<T: Scalar> Matrix<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![T::ZERO; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, T::ONE);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "from_vec: {} elements for {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries (deterministic in the seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| T::from_f64(rng.normal())).collect(),
        }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<T> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn transpose(&self) -> Matrix<T> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.set(j, i, self.get(i, j));
                    }
                }
            }
        }
        out
    }

    /// Copy a sub-block [r0..r1) × [c0..c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix<T> {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        Matrix::from_fn(r1 - r0, c1 - c0, |i, j| self.get(r0 + i, c0 + j))
    }

    /// First `k` columns (the U_r slicing rule of the factor ABI).
    pub fn first_cols(&self, k: usize) -> Matrix<T> {
        self.slice(0, self.rows, 0, k.min(self.cols))
    }

    /// First `k` rows.
    pub fn first_rows(&self, k: usize) -> Matrix<T> {
        self.slice(0, k.min(self.rows), 0, self.cols)
    }

    /// Vertical stack: [self; other].
    pub fn vstack(&self, other: &Matrix<T>) -> Result<Matrix<T>> {
        if self.cols != other.cols {
            return Err(Error::shape(format!(
                "vstack: {}x{} on {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Horizontal stack: [self, other].
    pub fn hstack(&self, other: &Matrix<T>) -> Result<Matrix<T>> {
        if self.rows != other.rows {
            return Err(Error::shape("hstack row mismatch".to_string()));
        }
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        Ok(out)
    }

    pub fn scale(&self, s: T) -> Matrix<T> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Matrix<T>) -> Result<Matrix<T>> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix<T>) -> Result<Matrix<T>> {
        self.zip(other, |a, b| a - b)
    }

    fn zip(&self, other: &Matrix<T>, f: impl Fn(T, T) -> T) -> Result<Matrix<T>> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::shape(format!(
                "elementwise: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        Ok(Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        })
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Convert precision.
    pub fn cast<U: Scalar>(&self) -> Matrix<U> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| U::from_f64(x.to_f64())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let m: Matrix<f64> = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m: Matrix<f32> = Matrix::randn(37, 53, 1);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn stacking() {
        let a: Matrix<f64> = Matrix::eye(2);
        let b = Matrix::zeros(1, 2);
        let v = a.vstack(&b).unwrap();
        assert_eq!(v.rows, 3);
        let h = a.hstack(&a).unwrap();
        assert_eq!(h.cols, 4);
        assert_eq!(h.get(1, 3), 1.0);
    }

    #[test]
    fn shape_errors() {
        let a: Matrix<f64> = Matrix::eye(2);
        let b: Matrix<f64> = Matrix::eye(3);
        assert!(a.add(&b).is_err());
        assert!(a.vstack(&b).is_err());
        assert!(Matrix::<f32>::from_vec(2, 2, vec![0.0]).is_err());
    }

    #[test]
    fn slicing() {
        let m: Matrix<f64> = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = m.slice(1, 3, 2, 4);
        assert_eq!(s.rows, 2);
        assert_eq!(s.get(0, 0), 6.0);
        assert_eq!(m.first_cols(2).cols, 2);
        assert_eq!(m.first_rows(9).rows, 4);
    }

    #[test]
    fn cast_precision() {
        let m: Matrix<f64> = Matrix::randn(3, 3, 2);
        let f: Matrix<f32> = m.cast();
        let back: Matrix<f64> = f.cast();
        for (a, b) in m.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
