//! Host tensor substrate: row-major dense matrices over f32/f64.
//!
//! This is the foundation of the pure-Rust numerics stack (S1/S2 in
//! DESIGN.md) used for fp64 ground truth, host-side baselines, and
//! verification of everything the PJRT runtime computes.

pub mod lowp;
pub mod matrix;
pub mod ops;

pub use matrix::{Matrix, Scalar};
