//! Matrix-level numeric ops: GEMM (blocked + threaded), norms, dots.

use super::matrix::{Matrix, Scalar};
use crate::error::{Error, Result};
use crate::util::threads;

/// Blocked, multi-threaded GEMM: C = A·B.
///
/// Row-major ikj loop order with 64-wide column blocking — the host-side
/// hot path for weight reconstruction (W' = A·B) and the fp64 reference
/// computations.  Threads split the row dimension.
pub fn matmul<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    if a.cols != b.rows {
        return Err(Error::shape(format!(
            "matmul: {}x{} @ {}x{}",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let workers = if m * n * k > 1 << 20 { threads::default_workers() } else { 1 };
    let row_chunks = workers.min(m.max(1));
    let chunk = m.div_ceil(row_chunks.max(1));
    let pieces = threads::parallel_map(row_chunks, workers, |w| {
        let r0 = w * chunk;
        let r1 = ((w + 1) * chunk).min(m);
        let mut out = vec![T::ZERO; (r1.saturating_sub(r0)) * n];
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for l in 0..k {
                let av = arow[l];
                let brow = b.row(l);
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    });
    let mut data = Vec::with_capacity(m * n);
    for p in pieces {
        data.extend_from_slice(&p);
    }
    Matrix::from_vec(m, n, data)
}

/// C = A·Bᵀ without materializing Bᵀ.
pub fn matmul_nt<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    if a.cols != b.cols {
        return Err(Error::shape(format!(
            "matmul_nt: {}x{} @ ({}x{})ᵀ",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let workers = if m * n * k > 1 << 20 { threads::default_workers() } else { 1 };
    let rows = threads::parallel_map(m, workers, |i| {
        let arow = a.row(i);
        let mut out = vec![T::ZERO; n];
        for (j, o) in out.iter_mut().enumerate() {
            let brow = b.row(j);
            let mut acc = T::ZERO;
            for l in 0..k {
                acc += arow[l] * brow[l];
            }
            *o = acc;
        }
        out
    });
    let mut data = Vec::with_capacity(m * n);
    for r in rows {
        data.extend_from_slice(&r);
    }
    Matrix::from_vec(m, n, data)
}

/// C = Aᵀ·A (the Gram matrix of columns — exactly what the baselines
/// form and COALA avoids; exposed so the failure can be studied).
pub fn gram_t<T: Scalar>(a: &Matrix<T>) -> Matrix<T> {
    let n = a.cols;
    let mut g = Matrix::zeros(n, n);
    for i in 0..a.rows {
        let r = a.row(i);
        for p in 0..n {
            let v = r[p];
            let grow = g.row_mut(p);
            for q in 0..n {
                grow[q] += v * r[q];
            }
        }
    }
    g
}

/// Frobenius norm.
pub fn fro<T: Scalar>(a: &Matrix<T>) -> f64 {
    a.data.iter().map(|x| x.to_f64() * x.to_f64()).sum::<f64>().sqrt()
}

/// Spectral norm via power iteration on AᵀA (good to ~1e-8 with 100 its).
pub fn spectral_norm<T: Scalar>(a: &Matrix<T>, iters: usize) -> f64 {
    let n = a.cols;
    if n == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let mut norm = 0.0;
    for _ in 0..iters {
        // w = A v ; v' = Aᵀ w
        let mut w = vec![0.0f64; a.rows];
        for (i, wi) in w.iter_mut().enumerate() {
            let r = a.row(i);
            *wi = r.iter().zip(&v).map(|(x, y)| x.to_f64() * y).sum();
        }
        let mut v2 = vec![0.0f64; n];
        for i in 0..a.rows {
            let r = a.row(i);
            let wi = w[i];
            for (j, vj) in v2.iter_mut().enumerate() {
                *vj += r[j].to_f64() * wi;
            }
        }
        norm = v2.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        for x in v2.iter_mut() {
            *x /= norm;
        }
        v = v2;
    }
    norm.sqrt()
}

/// Relative reconstruction error ‖(W−W′)X‖_F / ‖WX‖_F — the Fig. 1 metric
/// (computed in the Scalar precision of the inputs).
pub fn context_rel_err<T: Scalar>(w: &Matrix<T>, wp: &Matrix<T>, x: &Matrix<T>) -> Result<f64> {
    let diff = w.sub(wp)?;
    let num = fro(&matmul(&diff, x)?);
    let den = fro(&matmul(w, x)?);
    Ok(if den == 0.0 { num } else { num / den })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a: Matrix<f64> =
            Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let b: Matrix<f64> =
            Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_matches_nt() {
        let a: Matrix<f64> = Matrix::randn(17, 9, 1);
        let b: Matrix<f64> = Matrix::randn(13, 9, 2);
        let c1 = matmul(&a, &b.transpose()).unwrap();
        let c2 = matmul_nt(&a, &b).unwrap();
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_threaded_matches_serial() {
        // large enough to cross the threading threshold
        let a: Matrix<f32> = Matrix::randn(128, 200, 3);
        let b: Matrix<f32> = Matrix::randn(200, 64, 4);
        let c = matmul(&a, &b).unwrap();
        // spot-check against direct dot products
        for &(i, j) in &[(0usize, 0usize), (64, 32), (127, 63)] {
            let want: f64 = (0..200).map(|l| a.get(i, l) as f64 * b.get(l, j) as f64).sum();
            assert!((c.get(i, j) as f64 - want).abs() < 1e-3);
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let a: Matrix<f64> = Matrix::randn(20, 8, 5);
        let g = gram_t(&a);
        for i in 0..8 {
            assert!(g.get(i, i) >= 0.0);
            for j in 0..8 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spectral_close_to_fro_for_rank1() {
        let u: Matrix<f64> = Matrix::randn(12, 1, 6);
        let v: Matrix<f64> = Matrix::randn(1, 9, 7);
        let a = matmul(&u, &v).unwrap();
        // rank-1: ‖A‖₂ = ‖A‖_F
        assert!((spectral_norm(&a, 60) - fro(&a)).abs() < 1e-6);
    }

    #[test]
    fn shape_checked() {
        let a: Matrix<f64> = Matrix::zeros(2, 3);
        assert!(matmul(&a, &a).is_err());
        assert!(matmul_nt(&a, &Matrix::zeros(2, 4)).is_err());
    }
}
